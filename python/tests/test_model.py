"""L2 loss heads and whole-model reference: gradients vs jax autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.zoo import load_zoo


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=2, max_value=32),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_loss_ce_grad_is_softmax_minus_onehot(b, c, seed):
    key = jax.random.PRNGKey(seed)
    kl, ky = jax.random.split(key)
    logits = rand(kl, b, c)
    labels = jax.random.randint(ky, (b,), 0, c)
    g, loss = model.loss_grad_ce(logits, labels)
    onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    want = (jax.nn.softmax(logits, axis=-1) - onehot) / b
    np.testing.assert_allclose(g, want, rtol=1e-5, atol=1e-6)
    assert loss.shape == (1,)
    assert float(loss[0]) >= 0.0


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=2, max_value=16),
    st.floats(min_value=0.0, max_value=1.0),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_loss_lwf_alpha_interpolates(b, c, alpha, seed):
    key = jax.random.PRNGKey(seed)
    kl, kt, ky = jax.random.split(key, 3)
    logits = rand(kl, b, c)
    teacher = rand(kt, b, c)
    labels = jax.random.randint(ky, (b,), 0, c)
    a = jnp.array([alpha], dtype=jnp.float32)
    g, loss = model.loss_grad_lwf(logits, labels, teacher, a)
    assert g.shape == logits.shape
    # alpha=0 degenerates to plain CE.
    g0, loss0 = model.loss_grad_lwf(logits, labels, teacher, jnp.zeros((1,), jnp.float32))
    gce, lce = model.loss_grad_ce(logits, labels)
    np.testing.assert_allclose(g0, gce, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(loss0, lce, rtol=1e-5, atol=1e-6)


def test_lwf_teacher_equals_student_zero_distill_grad():
    """If the student matches the teacher, the distillation term vanishes."""
    key = jax.random.PRNGKey(3)
    kl, ky = jax.random.split(key)
    logits = rand(kl, 4, 6)
    labels = jax.random.randint(ky, (4,), 0, 6)
    a = jnp.array([1.0], dtype=jnp.float32)  # pure distillation
    g, loss = model.loss_grad_lwf(logits, labels, logits, a)
    np.testing.assert_allclose(g, jnp.zeros_like(g), atol=1e-6)
    np.testing.assert_allclose(loss, jnp.zeros((1,)), atol=1e-6)


def test_model_fwd_shapes_all_zoo_models():
    zoo = load_zoo()
    key = jax.random.PRNGKey(0)
    for spec in zoo.models.values():
        params = []
        acts = []
        for k, n, act in spec.layers():
            key, kw, kb = jax.random.split(key, 3)
            params.append((rand(kw, k, n) * 0.01, rand(kb, n) * 0.01))
            acts.append(act)
        x = rand(key, 2, spec.features)
        out = model.model_fwd(x, params, acts)
        assert out.shape == (2, spec.classes), spec.name


def manual_step(x, y, params, acts, lr):
    """One training step through the artifact-boundary functions only —
    exactly the composition the rust coordinator performs at runtime:
    layer_fwd chain (stashing inputs) -> loss_grad_ce -> layer_bwd chain
    -> layer_sgd. Returns (new_params, loss)."""
    import jax.numpy as jnp

    inputs = []
    h = x
    for (w, b), act in zip(params, acts):
        inputs.append(h)
        (h,) = model.layer_fwd(h, w, b, act=act)
    g, loss = model.loss_grad_ce(h, y)
    new_params = []
    lr_arr = jnp.array([lr], dtype=jnp.float32)
    for (w, b), act, xin in zip(reversed(params), reversed(acts), reversed(inputs)):
        g, gw, gb = model.layer_bwd(xin, w, b, g, act=act)
        new_params.append(model.layer_sgd(w, b, gw, gb, lr_arr))
    return list(reversed(new_params)), float(loss[0])


def test_model_loss_decreases_under_manual_chain():
    """A few manual-chain GD steps reduce the training loss (this is the
    exact composition the rust pipeline performs per microbatch)."""
    zoo = load_zoo()
    spec = zoo.models["mlp"]
    key = jax.random.PRNGKey(7)
    params = []
    acts = []
    for k, n, act in spec.layers():
        key, kw, kb = jax.random.split(key, 3)
        params.append((rand(kw, k, n) * 0.1, rand(kb, n) * 0.0))
        acts.append(act)
    key, kx, ky = jax.random.split(key, 3)
    x = rand(kx, 16, spec.features)
    y = jax.random.randint(ky, (16,), 0, spec.classes)

    losses = []
    for _ in range(20):
        params, loss = manual_step(x, y, params, acts, lr=0.5)
        losses.append(loss)
    assert losses[-1] < losses[0], losses


def test_manual_chain_matches_autodiff_reference():
    """Gradient flow through the artifact chain equals jax.grad through
    the pure-jnp reference model."""
    from compile.kernels.ref import dense_fwd_ref

    zoo = load_zoo()
    spec = zoo.models["mlp"]
    key = jax.random.PRNGKey(11)
    params = []
    acts = []
    for k, n, act in spec.layers():
        key, kw, kb = jax.random.split(key, 3)
        params.append((rand(kw, k, n) * 0.1, rand(kb, n) * 0.01))
        acts.append(act)
    key, kx, ky = jax.random.split(key, 3)
    x = rand(kx, 8, spec.features)
    y = jax.random.randint(ky, (8,), 0, spec.classes)

    def ref_loss(params):
        h = x
        for (w, b), act in zip(params, acts):
            h = dense_fwd_ref(h, w, b, act=act)
        logp = jax.nn.log_softmax(h, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))

    auto = jax.grad(ref_loss)(params)
    new_params, _ = manual_step(x, y, params, acts, lr=1.0)
    for (w0, b0), (w1, b1), (agw, agb) in zip(params, new_params, auto):
        # w1 = w0 - 1.0 * gw  =>  gw = w0 - w1
        np.testing.assert_allclose(w0 - w1, agw, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(b0 - b1, agb, rtol=1e-4, atol=1e-5)
