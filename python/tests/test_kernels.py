"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

hypothesis sweeps shapes (batch, in_dim, out_dim) and both activations;
fixed-shape cases cover every real layer shape in the model zoo.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import compensate, dense_bwd, dense_fwd, sgd_update
from compile.kernels.ref import (
    compensate_ref,
    dense_bwd_ref,
    dense_fwd_ref,
    sgd_update_ref,
)
from compile.zoo import load_zoo

dims = st.integers(min_value=1, max_value=48)
batches = st.integers(min_value=1, max_value=8)
acts = st.sampled_from(["relu", "none"])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def split(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@settings(max_examples=25, deadline=None)
@given(batches, dims, dims, acts, seeds)
def test_dense_fwd_matches_ref(b, k, n, act, seed):
    kx, kw, kb = split(seed, 3)
    x, w, bias = rand(kx, b, k), rand(kw, k, n), rand(kb, n)
    got = dense_fwd(x, w, bias, act=act)
    want = dense_fwd_ref(x, w, bias, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(batches, dims, dims, acts, seeds)
def test_dense_bwd_matches_ref(b, k, n, act, seed):
    kx, kw, kb, kg = split(seed, 4)
    x, w, bias, g = rand(kx, b, k), rand(kw, k, n), rand(kb, n), rand(kg, b, n)
    gx, gw, gb = dense_bwd(x, w, bias, g, act=act)
    rgx, rgw, rgb = dense_bwd_ref(x, w, bias, g, act=act)
    np.testing.assert_allclose(gx, rgx, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gw, rgw, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gb, rgb, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(dims, dims, st.floats(min_value=-1.0, max_value=1.0), seeds)
def test_compensate_matches_ref(k, n, lam, seed):
    k1, k2, k3, k4 = split(seed, 4)
    gw, gb = rand(k1, k, n), rand(k2, n)
    dw, db = rand(k3, k, n), rand(k4, n)
    lam_arr = jnp.array([lam], dtype=jnp.float32)
    ow, ob = compensate(gw, gb, dw, db, lam_arr)
    rw, rb = compensate_ref(gw, gb, dw, db, lam_arr)
    np.testing.assert_allclose(ow, rw, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ob, rb, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(dims, dims, st.floats(min_value=0.0, max_value=0.1), seeds)
def test_sgd_matches_ref(k, n, lr, seed):
    k1, k2, k3, k4 = split(seed, 4)
    w, b = rand(k1, k, n), rand(k2, n)
    gw, gb = rand(k3, k, n), rand(k4, n)
    lr_arr = jnp.array([lr], dtype=jnp.float32)
    ow, ob = sgd_update(w, b, gw, gb, lr_arr)
    rw, rb = sgd_update_ref(w, b, gw, gb, lr_arr)
    np.testing.assert_allclose(ow, rw, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ob, rb, rtol=1e-6, atol=1e-6)


def test_dense_fwd_grad_wrt_autodiff():
    """dense_bwd must agree with jax autodiff through the reference fwd."""
    key = jax.random.PRNGKey(0)
    kx, kw, kb, kg = jax.random.split(key, 4)
    b, k, n = 4, 9, 7
    x, w, bias, g = rand(kx, b, k), rand(kw, k, n), rand(kb, n), rand(kg, b, n)

    def f(x, w, bias):
        return jnp.sum(dense_fwd_ref(x, w, bias, act="relu") * g)

    agx, agw, agb = jax.grad(f, argnums=(0, 1, 2))(x, w, bias)
    gx, gw, gb = dense_bwd(x, w, bias, g, act="relu")
    np.testing.assert_allclose(gx, agx, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gw, agw, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gb, agb, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", load_zoo().distinct_layer_shapes())
def test_zoo_shapes_fwd(shape):
    """Every real model-zoo layer shape round-trips through the kernel."""
    k, n, act = shape
    b = 2  # small batch keeps interpret-mode runtime low; shape logic identical
    key = jax.random.PRNGKey(k * 1000 + n)
    kx, kw, kb = jax.random.split(key, 3)
    x, w, bias = rand(kx, b, k), rand(kw, k, n), rand(kb, n)
    got = dense_fwd(x, w, bias, act=act)
    want = dense_fwd_ref(x, w, bias, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_compensate_zero_lambda_is_identity():
    key = jax.random.PRNGKey(1)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    gw, gb = rand(k1, 5, 6), rand(k2, 6)
    dw, db = rand(k3, 5, 6), rand(k4, 6)
    ow, ob = compensate(gw, gb, dw, db, jnp.zeros((1,), jnp.float32))
    np.testing.assert_allclose(ow, gw)
    np.testing.assert_allclose(ob, gb)
