"""The deployed artifact forms and the Pallas kernels must be twins.

EXPERIMENTS.md §Perf: on the CPU PJRT client the update artifacts lower
from the fused jnp form, with the Pallas kernels kept as the validated
TPU-deployment implementation. These tests pin the equivalence so the two
can never drift apart, and check the grid-free dense path used by aot.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import compensate, dense_fwd, sgd_update

dims = st.integers(min_value=1, max_value=40)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


@settings(max_examples=20, deadline=None)
@given(dims, dims, st.floats(min_value=-1.0, max_value=1.0), seeds)
def test_compensate_artifact_equals_pallas_kernel(k, n, lam, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    gw, gb = rand(keys[0], k, n), rand(keys[1], n)
    dw, db = rand(keys[2], k, n), rand(keys[3], n)
    lam_arr = jnp.array([lam], dtype=jnp.float32)
    aw, ab = model.layer_compensate(gw, gb, dw, db, lam_arr)
    pw, pb = compensate(gw, gb, dw, db, lam_arr)
    np.testing.assert_allclose(aw, pw, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ab, pb, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(dims, dims, st.floats(min_value=0.0, max_value=0.1), seeds)
def test_sgd_artifact_equals_pallas_kernel(k, n, lr, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    w, b = rand(keys[0], k, n), rand(keys[1], n)
    gw, gb = rand(keys[2], k, n), rand(keys[3], n)
    lr_arr = jnp.array([lr], dtype=jnp.float32)
    aw, ab = model.layer_sgd(w, b, gw, gb, lr_arr)
    pw, pb = sgd_update(w, b, gw, gb, lr_arr)
    np.testing.assert_allclose(aw, pw, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ab, pb, rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=8), dims, st.integers(min_value=1, max_value=200), seeds)
def test_whole_block_dense_fwd_equals_gridded(b, k, n, seed):
    """block_n=0 (the CPU artifact path) == the default MXU tiling."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    x, w, bias = rand(keys[0], b, k), rand(keys[1], k, n), rand(keys[2], n)
    for act in ("relu", "none"):
        whole = dense_fwd(x, w, bias, act=act, block_n=0)
        gridded = dense_fwd(x, w, bias, act=act, block_n=128)
        np.testing.assert_allclose(whole, gridded, rtol=1e-5, atol=1e-5)


def test_artifact_lowering_perf_properties():
    """The perf properties EXPERIMENTS.md §Perf relies on:
    - update artifacts (jnp form) lower to straight-line HLO (no `while`);
    - the grid-free dense forward lowers at most ONE one-trip `while`
      (interpret mode's pallas wrapper), not one per 128-wide block."""
    from compile import aot
    from compile.zoo import load_zoo

    zoo = load_zoo()
    name, fn, specs = next(
        (n, f, s) for n, f, s in aot.artifact_plan(zoo) if n.startswith("dense_fwd_")
    )
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.count("while(") <= 1, name
    # the update artifacts lower fully fused
    for prefix in ("sgd_", "compensate_"):
        name, fn, specs = next(
            (n, f, s) for n, f, s in aot.artifact_plan(zoo) if n.startswith(prefix)
        )
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert "while(" not in text, name
