"""AOT path: zoo parsing, artifact plan completeness, HLO-text emission."""

import os

import pytest

from compile import aot
from compile.zoo import load_zoo


def test_zoo_parses_and_is_consistent():
    zoo = load_zoo()
    assert zoo.batch >= 1
    assert len(zoo.models) >= 10
    for spec in zoo.models.values():
        assert len(spec.dims) >= 3, spec
        layers = spec.layers()
        # hidden layers relu, final none
        assert all(act == "relu" for _, _, act in layers[:-1])
        assert layers[-1][2] == "none"
        # consecutive dims chain
        for (k0, n0, _), (k1, _, _) in zip(layers, layers[1:]):
            assert n0 == k1


def test_artifact_plan_covers_every_layer_and_head():
    zoo = load_zoo()
    plan = aot.artifact_plan(zoo)
    names = {name for name, _, _ in plan}
    assert len(names) == len(plan), "duplicate artifact names"
    for k, n, act in zoo.distinct_layer_shapes():
        assert f"dense_fwd_{k}x{n}_{act}" in names
        assert f"dense_bwd_{k}x{n}_{act}" in names
        assert f"compensate_{k}x{n}" in names
        assert f"sgd_{k}x{n}" in names
    for c in zoo.distinct_class_counts():
        assert f"loss_ce_{c}" in names
        assert f"loss_lwf_{c}" in names


def test_emit_single_artifact_is_parseable_hlo(tmp_path):
    """Lower one real artifact and sanity-check the HLO text shape."""
    import jax

    zoo = load_zoo()
    name, fn, specs = aot.artifact_plan(zoo)[0]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True means the root is a tuple
    assert "tuple(" in text or "tuple<" in text or ")) tuple" in text or "ROOT" in text


def test_emit_writes_manifest(tmp_path, monkeypatch):
    """End-to-end emit over a tiny synthetic zoo."""
    cfg = tmp_path / "models.cfg"
    cfg.write_text("batch 4\nmodel tiny 6 5 3\n")
    out = tmp_path / "artifacts"
    n = aot.emit(str(out), str(cfg), verbose=False)
    # 2 layer shapes * (fwd+bwd+comp+sgd) artifacts... comp/sgd per (K,N):
    # shapes: (6,5,relu),(5,3,none) -> 2 fwd + 2 bwd + 2 comp + 2 sgd + 1 ce + 1 lwf
    assert n == 10
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert manifest[0] == "batch 4"
    arts = [l.split() for l in manifest[1:]]
    assert all(a[0] == "artifact" for a in arts)
    for _, name, fname in arts:
        assert (out / fname).exists(), name
        assert "HloModule" in (out / fname).read_text()[:200]


def test_zoo_rejects_bad_config(tmp_path):
    bad = tmp_path / "bad.cfg"
    bad.write_text("model nolayers 5\n")
    with pytest.raises(ValueError):
        load_zoo(str(bad))
    bad.write_text("batch 4\nmodel a 4 -2 3\n")
    with pytest.raises(ValueError):
        load_zoo(str(bad))
    bad.write_text("batch 4\nwat 1\n")
    with pytest.raises(ValueError):
        load_zoo(str(bad))
    bad.write_text("model a 4 2 3\n")  # missing batch
    with pytest.raises(ValueError):
        load_zoo(str(bad))
