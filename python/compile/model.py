"""L2: the JAX compute graph of a Ferret pipeline stage.

Every function here is an artifact boundary: `compile.aot` lowers each one
(at the shapes enumerated from configs/models.cfg) to HLO text that the
rust coordinator loads via PJRT and composes into pipeline stages. The
dense hot paths call the L1 Pallas kernels; the loss heads are plain jnp
(they are not the hot spot) and include the LwF-distillation variant used
by the OCL plugin layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import compensate, dense_bwd, dense_fwd, sgd_update

# Knowledge-distillation temperature for the LwF loss head (fixed at
# lowering time; matches the common LwF setting).
LWF_TEMPERATURE = 2.0


# --------------------------------------------------------------------------
# Layer-level artifacts (L1 Pallas kernels under the hood)
# --------------------------------------------------------------------------

def layer_fwd(x, w, b, *, act: str, block_n: int = None):
    """Forward of one dense layer: y = act(x @ w + b).

    `block_n=0` lowers a single whole-array block (the CPU-artifact form,
    see kernels/dense.py); the default keeps the MXU-width tiling.
    """
    kw = {} if block_n is None else {"block_n": block_n}
    return (dense_fwd(x, w, b, act=act, **kw),)


def layer_bwd(x, w, b, g, *, act: str):
    """Backward of one dense layer with activation recomputation (T1)."""
    return dense_bwd(x, w, b, g, act=act)


def layer_compensate(gw, gb, dw, db, lam):
    """One Iter-Fisher step (Eq. 8) over a layer's gradient pair.

    Perf note (EXPERIMENTS.md §Perf): the *artifact* lowers the straight
    jnp form — XLA fuses it into one elementwise loop, whereas the Pallas
    interpret lowering wraps the kernel in a one-trip `while` region that
    blocks fusion (~40x slower on the CPU PJRT client). The Pallas kernel
    `kernels.compensate` stays the validated TPU-deployment form (pytest
    asserts it matches this math exactly).
    """
    l = lam[0]
    return gw + l * gw * gw * dw, gb + l * gb * gb * db


def layer_sgd(w, b, gw, gb, lr):
    """Fused SGD parameter step over a layer's (w, b). Same perf note as
    `layer_compensate`; `kernels.sgd_update` is the Pallas twin."""
    r = lr[0]
    return w - r * gw, b - r * gb


# --------------------------------------------------------------------------
# Loss heads (plain jnp; lowered per distinct class count)
# --------------------------------------------------------------------------

def _ce(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def loss_grad_ce(logits, labels):
    """Softmax cross-entropy: returns (dL/dlogits, loss).

    logits: (B, C) f32, labels: (B,) i32.
    """
    loss, g = jax.value_and_grad(_ce)(logits, labels)
    return g, loss.reshape((1,))


def _lwf(logits, labels, teacher, alpha):
    t = LWF_TEMPERATURE
    ce = _ce(logits, labels)
    # KL(softmax(teacher/T) || softmax(student/T)) * T^2, the classic
    # Learning-without-Forgetting distillation penalty.
    pt = jax.nn.softmax(teacher / t, axis=-1)
    logps = jax.nn.log_softmax(logits / t, axis=-1)
    logpt = jax.nn.log_softmax(teacher / t, axis=-1)
    kl = jnp.mean(jnp.sum(pt * (logpt - logps), axis=-1)) * t * t
    a = alpha[0]
    return (1.0 - a) * ce + a * kl


def loss_grad_lwf(logits, labels, teacher, alpha):
    """LwF head: CE + distillation to the teacher logits.

    logits/teacher: (B, C) f32, labels: (B,) i32, alpha: (1,) f32.
    Returns (dL/dlogits, loss).
    """
    loss, g = jax.value_and_grad(_lwf)(logits, labels, teacher, alpha)
    return g, loss.reshape((1,))


# --------------------------------------------------------------------------
# Whole-model reference (python-side tests only; never lowered)
# --------------------------------------------------------------------------

def model_fwd(x, params, acts):
    """Run a dense stack; params = [(w, b), ...], acts aligned."""
    h = x
    for (w, b), act in zip(params, acts):
        (h,) = layer_fwd(h, w, b, act=act)
    return h


def model_loss(x, params, acts, labels):
    return _ce(model_fwd(x, params, acts), labels)
