"""L1 Pallas kernels for the dense-stage hot path.

These are the compute hot spots of every Ferret pipeline stage: the fused
dense forward (matmul + bias + activation) and the dense backward (with
activation recomputation — the execution-side half of the paper's T1).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the forward tiles the
output columns into MXU-friendly blocks via the Pallas grid + BlockSpec —
the HBM->VMEM schedule a CUDA kernel would express with threadblocks.
All kernels are lowered with interpret=True so the resulting HLO runs on
the CPU PJRT client (real-TPU lowering emits Mosaic custom-calls the CPU
plugin cannot execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-column block for the forward kernel. 128 matches the MXU lane
# width; the batch (sublane) dim is small in OCL so it is kept whole.
BLOCK_N = 128


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _apply_act(z, act: str):
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "none":
        return z
    raise ValueError(f"unknown activation {act!r}")


def _fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    # One (B, bn) output tile: full-K matmul against a (K, bn) weight slab.
    z = jnp.dot(x_ref[...], w_ref[...]) + b_ref[...][None, :]
    o_ref[...] = _apply_act(z, act)


# Same math, whole-array (used by the grid-free single-block path).
_fwd_kernel_whole = _fwd_kernel


@functools.partial(jax.jit, static_argnames=("act", "block_n"))
def dense_fwd(x, w, b, *, act: str = "relu", block_n: int = BLOCK_N):
    """y = act(x @ w + b), tiled over output columns.

    x: (B, K) f32, w: (K, N) f32, b: (N,) f32 -> (B, N) f32.
    """
    bsz, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    # block_n = 0 selects a single whole-array block: on the CPU PJRT
    # client the interpret-mode grid lowers to an unfused while-loop, so
    # the AOT path uses one block; the 128-wide default is the TPU story.
    bn = n if block_n == 0 else min(block_n, n)
    if bn == n:
        # single block: no grid at all, so interpret mode lowers straight-
        # line HLO (a grid of one still wraps the body in a `while` that
        # XLA will not fuse through on the CPU client).
        return pl.pallas_call(
            functools.partial(_fwd_kernel_whole, act=act),
            out_shape=jax.ShapeDtypeStruct((bsz, n), x.dtype),
            interpret=True,
        )(x, w, b)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, act=act),
        grid=(_cdiv(n, bn),),
        in_specs=[
            pl.BlockSpec((bsz, k), lambda i: (0, 0)),
            pl.BlockSpec((k, bn), lambda i: (0, i)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bsz, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), x.dtype),
        interpret=True,
    )(x, w, b)


def _bwd_kernel(x_ref, w_ref, b_ref, g_ref, gx_ref, gw_ref, gb_ref, *, act: str):
    # Activation recomputation (paper T1): z is never stored between the
    # forward and backward pass — only the layer *input* x is stashed, and
    # the pre-activation is recomputed here. This is what lets the memory
    # model (Eq. 4) drop the |a_hat| terms when c_n^r = 1.
    x = x_ref[...]
    w = w_ref[...]
    g = g_ref[...]
    if act == "relu":
        z = jnp.dot(x, w) + b_ref[...][None, :]
        g = g * (z > 0.0).astype(g.dtype)
    gx_ref[...] = jnp.dot(g, w.T)
    gw_ref[...] = jnp.dot(x.T, g)
    gb_ref[...] = jnp.sum(g, axis=0)


@functools.partial(jax.jit, static_argnames=("act",))
def dense_bwd(x, w, b, g, *, act: str = "relu"):
    """Backward of dense_fwd with activation recomputation.

    Inputs: layer input x (B, K), params w (K, N) / b (N,), upstream grad
    g (B, N). Returns (gx (B, K), gw (K, N), gb (N,)).
    """
    bsz, k = x.shape
    _, n = w.shape
    assert g.shape == (bsz, n), (g.shape, (bsz, n))
    return pl.pallas_call(
        functools.partial(_bwd_kernel, act=act),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, k), x.dtype),
            jax.ShapeDtypeStruct((k, n), w.dtype),
            jax.ShapeDtypeStruct((n,), b.dtype),
        ),
        interpret=True,
    )(x, w, b, g)
