"""L1 Pallas kernels (build-time only; lowered to HLO by compile.aot)."""

from .dense import dense_bwd, dense_fwd
from .update import compensate, sgd_update

__all__ = ["dense_fwd", "dense_bwd", "compensate", "sgd_update"]
