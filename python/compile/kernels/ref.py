"""Pure-jnp oracles for every L1 Pallas kernel.

pytest (python/tests/test_kernels.py) asserts allclose between these and
the Pallas implementations across hypothesis-swept shapes; the same math
is mirrored a third time by rust/src/backend/native.rs, which integration
tests cross-check against the XLA artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def _act(z, act: str):
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "none":
        return z
    raise ValueError(f"unknown activation {act!r}")


def dense_fwd_ref(x, w, b, *, act: str = "relu"):
    return _act(jnp.dot(x, w) + b[None, :], act)


def dense_bwd_ref(x, w, b, g, *, act: str = "relu"):
    if act == "relu":
        z = jnp.dot(x, w) + b[None, :]
        g = g * (z > 0.0).astype(g.dtype)
    gx = jnp.dot(g, w.T)
    gw = jnp.dot(x.T, g)
    gb = jnp.sum(g, axis=0)
    return gx, gw, gb


def compensate_ref(gw, gb, dw, db, lam):
    lam = lam[0]
    return gw + lam * gw * gw * dw, gb + lam * gb * gb * db


def sgd_update_ref(w, b, gw, gb, lr):
    lr = lr[0]
    return w - lr * gw, b - lr * gb
