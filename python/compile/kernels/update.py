"""L1 Pallas kernels for gradient compensation and parameter updates.

`compensate` is one application of the paper's Iter-Fisher approximator
(Eq. 8): A(g, dtheta; lam) = g + lam * g (*) g (*) dtheta, where (*) is the
elementwise (Hadamard) product and dtheta = theta_new - theta_old for one
model-version step. The L3 coordinator applies it tau times (Eq. 9 / Alg. 1)
with per-step dthetas pulled from the weight-version stash.

`sgd_update` is the fused parameter step theta' = theta - lr * g over a
layer's (w, b) pair.

Both kernels treat scalars (lam, lr) as (1,)-shaped f32 inputs so the
lowered HLO takes them as runtime arguments rather than baked constants.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compensate_kernel(gw_ref, gb_ref, dw_ref, db_ref, lam_ref, ow_ref, ob_ref):
    lam = lam_ref[0]
    gw = gw_ref[...]
    gb = gb_ref[...]
    # Diagonal-Fisher Taylor step (Eq. 8): the Hessian is approximated by
    # lam * g (*) g, so the first-order correction is lam * g*g*dtheta.
    ow_ref[...] = gw + lam * gw * gw * dw_ref[...]
    ob_ref[...] = gb + lam * gb * gb * db_ref[...]


@jax.jit
def compensate(gw, gb, dw, db, lam):
    """One Iter-Fisher compensation step over a layer's (gw, gb).

    gw/dw: (K, N) f32, gb/db: (N,) f32, lam: (1,) f32.
    Returns (gw', gb') compensated toward the newer model version.
    """
    assert gw.shape == dw.shape and gb.shape == db.shape
    return pl.pallas_call(
        _compensate_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(gw.shape, gw.dtype),
            jax.ShapeDtypeStruct(gb.shape, gb.dtype),
        ),
        interpret=True,
    )(gw, gb, dw, db, lam)


def _sgd_kernel(w_ref, b_ref, gw_ref, gb_ref, lr_ref, ow_ref, ob_ref):
    lr = lr_ref[0]
    ow_ref[...] = w_ref[...] - lr * gw_ref[...]
    ob_ref[...] = b_ref[...] - lr * gb_ref[...]


@jax.jit
def sgd_update(w, b, gw, gb, lr):
    """Fused SGD step over a layer's (w, b). lr: (1,) f32."""
    assert w.shape == gw.shape and b.shape == gb.shape
    return pl.pallas_call(
        _sgd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(b.shape, b.dtype),
        ),
        interpret=True,
    )(w, b, gw, gb, lr)
