"""AOT-lower every L2 artifact to HLO text for the rust coordinator.

Interchange format is HLO *text*, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Artifact set (enumerated from configs/models.cfg):
  dense_fwd_<K>x<N>_<act>   (x, w, b)            -> (y,)
  dense_bwd_<K>x<N>_<act>   (x, w, b, g)         -> (gx, gw, gb)
  compensate_<K>x<N>        (gw, gb, dw, db, lam)-> (gw', gb')
  sgd_<K>x<N>               (w, b, gw, gb, lr)   -> (w', b')
  loss_ce_<C>               (logits, labels)     -> (g, loss)
  loss_lwf_<C>              (logits, labels, teacher, alpha) -> (g, loss)

Run once at build time (`make artifacts`); python is never on the rust
request path.
"""

from __future__ import annotations

import argparse
import functools
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .zoo import Zoo, load_zoo

MANIFEST = "manifest.txt"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*dims: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def i32(*dims: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(dims, jnp.int32)


def artifact_plan(zoo: Zoo) -> list[tuple[str, object, tuple]]:
    """(name, fn, arg_specs) for every artifact implied by the zoo."""
    bsz = zoo.batch
    plan: list[tuple[str, object, tuple]] = []
    for k, n, act in zoo.distinct_layer_shapes():
        plan.append((
            # block_n=0: single whole-array block for the CPU PJRT client
            # (interpret-mode grids lower to unfused while loops; see
            # kernels/dense.py). The gridded form is exercised by pytest.
            f"dense_fwd_{k}x{n}_{act}",
            functools.partial(model.layer_fwd, act=act, block_n=0),
            (f32(bsz, k), f32(k, n), f32(n)),
        ))
        plan.append((
            f"dense_bwd_{k}x{n}_{act}",
            functools.partial(model.layer_bwd, act=act),
            (f32(bsz, k), f32(k, n), f32(n), f32(bsz, n)),
        ))
    # compensate/sgd are activation-independent: emit once per (K, N).
    for k, n in sorted({(k, n) for k, n, _ in zoo.distinct_layer_shapes()}):
        plan.append((
            f"compensate_{k}x{n}",
            model.layer_compensate,
            (f32(k, n), f32(n), f32(k, n), f32(n), f32(1)),
        ))
        plan.append((
            f"sgd_{k}x{n}",
            model.layer_sgd,
            (f32(k, n), f32(n), f32(k, n), f32(n), f32(1)),
        ))
    for c in zoo.distinct_class_counts():
        plan.append((
            f"loss_ce_{c}",
            model.loss_grad_ce,
            (f32(bsz, c), i32(bsz)),
        ))
        plan.append((
            f"loss_lwf_{c}",
            model.loss_grad_lwf,
            (f32(bsz, c), i32(bsz), f32(bsz, c), f32(1)),
        ))
    return plan


def emit(out_dir: str, cfg_path: str | None = None, verbose: bool = True) -> int:
    zoo = load_zoo(cfg_path)
    os.makedirs(out_dir, exist_ok=True)
    plan = artifact_plan(zoo)
    t0 = time.time()
    lines = [f"batch {zoo.batch}"]
    for i, (name, fn, specs) in enumerate(plan):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        with open(path, "w") as f:
            f.write(text)
        lines.append(f"artifact {name} {name}.hlo.txt")
        if verbose:
            print(f"[{i + 1}/{len(plan)}] {name} ({len(text)} chars)", flush=True)
    with open(os.path.join(out_dir, MANIFEST), "w") as f:
        f.write("\n".join(lines) + "\n")
    if verbose:
        print(f"emitted {len(plan)} artifacts in {time.time() - t0:.1f}s -> {out_dir}")
    return len(plan)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact output dir")
    p.add_argument("--cfg", default=None, help="models.cfg path (default: configs/)")
    args = p.parse_args()
    emit(args.out, args.cfg)


if __name__ == "__main__":
    sys.exit(main())
