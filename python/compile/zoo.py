"""Parse the shared model-zoo config (configs/models.cfg).

The same file is parsed by rust/src/config/zoo.rs; keep the format in sync.
"""

from __future__ import annotations

import os
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelSpec:
    name: str
    dims: tuple[int, ...]  # d0 -> d1 -> ... -> dk (dk = classes)

    @property
    def classes(self) -> int:
        return self.dims[-1]

    @property
    def features(self) -> int:
        return self.dims[0]

    def layers(self) -> list[tuple[int, int, str]]:
        """(in_dim, out_dim, act) per layer; hidden=relu, final=none."""
        out = []
        for i in range(len(self.dims) - 1):
            act = "none" if i == len(self.dims) - 2 else "relu"
            out.append((self.dims[i], self.dims[i + 1], act))
        return out


@dataclass(frozen=True)
class Zoo:
    batch: int
    models: dict[str, ModelSpec]

    def distinct_layer_shapes(self) -> list[tuple[int, int, str]]:
        seen: dict[tuple[int, int, str], None] = {}
        for m in self.models.values():
            for shape in m.layers():
                seen[shape] = None
        return list(seen)

    def distinct_class_counts(self) -> list[int]:
        return sorted({m.classes for m in self.models.values()})


def default_cfg_path() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.join(here, "..", "..", "configs", "models.cfg")


def load_zoo(path: str | None = None) -> Zoo:
    path = path or default_cfg_path()
    batch = None
    models: dict[str, ModelSpec] = {}
    with open(path) as f:
        for lineno, raw in enumerate(f, 1):
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split()
            if parts[0] == "batch":
                if len(parts) != 2:
                    raise ValueError(f"{path}:{lineno}: batch takes one int")
                batch = int(parts[1])
            elif parts[0] == "model":
                if len(parts) < 4:
                    raise ValueError(f"{path}:{lineno}: model needs >=2 dims")
                name = parts[1]
                dims = tuple(int(p) for p in parts[2:])
                if any(d <= 0 for d in dims):
                    raise ValueError(f"{path}:{lineno}: dims must be positive")
                if name in models:
                    raise ValueError(f"{path}:{lineno}: duplicate model {name}")
                models[name] = ModelSpec(name, dims)
            else:
                raise ValueError(f"{path}:{lineno}: unknown directive {parts[0]!r}")
    if batch is None:
        raise ValueError(f"{path}: missing 'batch' directive")
    if not models:
        raise ValueError(f"{path}: no models")
    return Zoo(batch=batch, models=models)
