//! Engine throughput bench: virtual-batches/second of each schedule on
//! the native backend (the end-to-end hot path minus PJRT), plus the
//! sim-vs-threaded executor comparison on the async engines.
//!
//!     cargo bench --bench engine

use ferret::backend::native::NativeBackend;
use ferret::baselines::{run_baseline_with_model, StreamPolicy};
use ferret::compensate::CompKind;
use ferret::config::zoo::default_zoo;
use ferret::ocl::OclKind;
use ferret::pipeline::engine::{AsyncCfg, AsyncSchedule};
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::sync::{run_sync, SyncSchedule};
use ferret::pipeline::{EngineParams, Session};
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn mk_stream(model: &ferret::config::ModelSpec, batch: usize, n: usize) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "bench".into(),
        features: model.features(),
        classes: model.classes(),
        batch,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 4.0,
        noise: 0.8,
        seed: 1,
    })
}

fn main() {
    let zoo = default_zoo().unwrap();
    let n = 60;
    println!("engine throughput (native backend, {n} microbatches)");
    println!("{:<28} {:>12} {:>14}", "engine/model", "wall ms", "batches/s");
    for model_name in ["mnistnet10", "convnet10", "resnet11"] {
        let model = zoo.model(model_name).unwrap().clone();
        let prof = Profile::analytic(&model, zoo.batch);
        let td = prof.default_td();
        let out = plan(&prof, td, f64::INFINITY, decay_for_td(td));
        let ep = EngineParams { lr: 0.04, seed: 1, ..Default::default() };

        // baseline single-device
        let t0 = std::time::Instant::now();
        let mut p = OclKind::Vanilla.build(1);
        let mut s = mk_stream(&model, zoo.batch, n);
        let _ =
            run_baseline_with_model(StreamPolicy::Oracle, &mut s, &NativeBackend, p.as_mut(), &ep, &model);
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<28} {:>12.1} {:>14.1}", format!("oracle/{model_name}"), dt * 1e3, n as f64 / dt);

        // sync pipeline
        let t0 = std::time::Instant::now();
        let mut p = OclKind::Vanilla.build(1);
        let mut s = mk_stream(&model, zoo.batch, n);
        let _ =
            run_sync(SyncSchedule::Dapple, &mut s, &NativeBackend, p.as_mut(), &ep, &model, &out.partition);
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<28} {:>12.1} {:>14.1}", format!("dapple/{model_name}"), dt * 1e3, n as f64 / dt);

        // async engines, on both executors (sim = inline virtual time,
        // threaded = one OS thread per (worker, stage) device)
        for sched in [AsyncSchedule::Pipedream, AsyncSchedule::Ferret] {
            for kind in [ExecutorKind::Sim, ExecutorKind::Threaded] {
                let cfg = match sched {
                    AsyncSchedule::Ferret => AsyncCfg::ferret(
                        out.partition.clone(),
                        out.config.clone(),
                        CompKind::IterFisher,
                    ),
                    s => AsyncCfg::baseline(s, out.partition.clone(), &prof, td),
                };
                let t0 = std::time::Instant::now();
                let mut p = OclKind::Vanilla.build(1);
                let mut s = mk_stream(&model, zoo.batch, n);
                let r = Session::builder(&NativeBackend, &model)
                    .config(cfg)
                    .plugin(p.as_mut())
                    .engine_params(ep)
                    .executor(kind)
                    .mode(Mode::Lockstep)
                    .batch(zoo.batch)
                    .build()
                    .expect("bench session")
                    .run_stream(&mut s);
                let dt = t0.elapsed().as_secs_f64();
                println!(
                    "{:<28} {:>12.1} {:>14.1}   ({} threads)",
                    format!("{}[{}]/{model_name}", sched.name().to_lowercase(), kind.name()),
                    dt * 1e3,
                    n as f64 / dt,
                    r.metrics.exec_threads
                );
            }
        }

        // free-running wall-clock mode: real arrival pacing + device-thread
        // updates; reports observed latency instead of replayed costs
        let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, out.partition.clone(), &prof, td);
        let mut p = OclKind::Vanilla.build(1);
        let mut s = mk_stream(&model, zoo.batch, n);
        let t0 = std::time::Instant::now();
        let r = Session::builder(&NativeBackend, &model)
            .config(cfg)
            .plugin(p.as_mut())
            .engine_params(ep)
            .executor(ExecutorKind::Threaded)
            .mode(Mode::Freerun)
            .batch(zoo.batch)
            .build()
            .expect("bench session")
            .run_stream(&mut s);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<28} {:>12.1} {:>14.1}   latency {} | staleness {}",
            format!("pipedream[freerun]/{model_name}"),
            dt * 1e3,
            n as f64 / dt,
            r.metrics.latency_summary(),
            r.metrics.staleness_summary()
        );
    }
}
