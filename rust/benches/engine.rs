//! Engine throughput bench: virtual-batches/second of each schedule on
//! the native backend (the end-to-end hot path minus PJRT), plus the
//! sim-vs-threaded executor comparison on the async engines and a
//! naive-vs-tiled kernel GFLOP/s section (the per-kernel view of the
//! committed BENCH trajectory).
//!
//!     cargo bench --bench engine            # full sweep
//!     cargo bench --bench engine -- --smoke # CI: one model, short stream
//!
//! For the machine-readable trajectory point use
//! `ferret_bench --exp perf` instead — it emits the BENCH_0006 JSON.

use ferret::backend::kernels;
use ferret::backend::native::NativeBackend;
use ferret::baselines::{run_baseline_with_model, StreamPolicy};
use ferret::compensate::CompKind;
use ferret::config::zoo::default_zoo;
use ferret::ocl::OclKind;
use ferret::pipeline::engine::{AsyncCfg, AsyncSchedule};
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::sync::{run_sync, SyncSchedule};
use ferret::pipeline::{EngineParams, Session};
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn mk_stream(model: &ferret::config::ModelSpec, batch: usize, n: usize) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "bench".into(),
        features: model.features(),
        classes: model.classes(),
        batch,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 4.0,
        noise: 0.8,
        seed: 1,
    })
}

/// GFLOP/s of `f` run `reps` times over a `flops`-FLOP kernel.
fn gfs(flops: usize, reps: u32, mut f: impl FnMut()) -> f64 {
    f(); // warm
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        f();
    }
    flops as f64 * reps as f64 / t0.elapsed().as_secs_f64().max(1e-12) / 1e9
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let zoo = default_zoo().unwrap();

    // --- kernel section: naive vs tiled vs tiled×4 on each model's
    // largest layer (dense operands; the sparse-skip path is off) ---
    println!("kernel GFLOP/s (largest layer per model, batch {})", zoo.batch);
    println!("{:<26} {:>10} {:>10} {:>10}", "kernel/shape", "naive", "tiled", "tiledx4");
    let models: &[&str] =
        if smoke { &["mnistnet10"] } else { &["mnistnet10", "convnet10", "resnet11"] };
    for model_name in models {
        let model = zoo.model(model_name).unwrap().clone();
        let l = model.layers().into_iter().max_by_key(|l| l.param_count()).unwrap();
        let (b, kin, kout) = (zoo.batch, l.in_dim, l.out_dim);
        let flops = 2 * b * kin * kout;
        let reps = if smoke { 2 } else { ((2e8 / flops as f64) as u32).clamp(3, 30) };
        let x: Vec<f32> = (0..b * kin).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
        let w: Vec<f32> = (0..kin * kout).map(|i| (i % 7) as f32 * 0.05 - 0.15).collect();
        let mut z = vec![0.0f32; b * kout];
        let naive = gfs(flops, reps, || kernels::naive_matmul_acc(&mut z, &x, &w, b, kin, kout));
        let tiled = gfs(flops, reps, || kernels::matmul_acc(&mut z, &x, &w, b, kin, kout, 1));
        let mt = gfs(flops, reps, || kernels::matmul_acc(&mut z, &x, &w, b, kin, kout, 4));
        println!(
            "{:<26} {:>10.2} {:>10.2} {:>10.2}",
            format!("fwd/{model_name} {b}x{kin}x{kout}"),
            naive,
            tiled,
            mt
        );
    }
    println!();

    let n = if smoke { 12 } else { 60 };
    println!("engine throughput (native backend, {n} microbatches)");
    println!("{:<28} {:>12} {:>14}", "engine/model", "wall ms", "batches/s");
    for model_name in models {
        let model = zoo.model(model_name).unwrap().clone();
        let prof = Profile::analytic(&model, zoo.batch);
        let td = prof.default_td();
        let out = plan(&prof, td, f64::INFINITY, decay_for_td(td));
        let ep = EngineParams { lr: 0.04, seed: 1, ..Default::default() };

        // baseline single-device
        let t0 = std::time::Instant::now();
        let mut p = OclKind::Vanilla.build(1);
        let mut s = mk_stream(&model, zoo.batch, n);
        let _ =
            run_baseline_with_model(StreamPolicy::Oracle, &mut s, &NativeBackend, p.as_mut(), &ep, &model);
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<28} {:>12.1} {:>14.1}", format!("oracle/{model_name}"), dt * 1e3, n as f64 / dt);

        // sync pipeline
        let t0 = std::time::Instant::now();
        let mut p = OclKind::Vanilla.build(1);
        let mut s = mk_stream(&model, zoo.batch, n);
        let _ =
            run_sync(SyncSchedule::Dapple, &mut s, &NativeBackend, p.as_mut(), &ep, &model, &out.partition);
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<28} {:>12.1} {:>14.1}", format!("dapple/{model_name}"), dt * 1e3, n as f64 / dt);

        // async engines, on both executors (sim = inline virtual time,
        // threaded = one OS thread per (worker, stage) device)
        for sched in [AsyncSchedule::Pipedream, AsyncSchedule::Ferret] {
            for kind in [ExecutorKind::Sim, ExecutorKind::Threaded] {
                let cfg = match sched {
                    AsyncSchedule::Ferret => AsyncCfg::ferret(
                        out.partition.clone(),
                        out.config.clone(),
                        CompKind::IterFisher,
                    ),
                    s => AsyncCfg::baseline(s, out.partition.clone(), &prof, td),
                };
                let t0 = std::time::Instant::now();
                let mut p = OclKind::Vanilla.build(1);
                let mut s = mk_stream(&model, zoo.batch, n);
                let r = Session::builder(&NativeBackend, &model)
                    .config(cfg)
                    .plugin(p.as_mut())
                    .engine_params(ep)
                    .executor(kind)
                    .mode(Mode::Lockstep)
                    .batch(zoo.batch)
                    .build()
                    .expect("bench session")
                    .run_stream(&mut s)
                    .expect("bench stream matches the model");
                let dt = t0.elapsed().as_secs_f64();
                println!(
                    "{:<28} {:>12.1} {:>14.1}   ({} threads)",
                    format!("{}[{}]/{model_name}", sched.name().to_lowercase(), kind.name()),
                    dt * 1e3,
                    n as f64 / dt,
                    r.metrics.exec_threads
                );
            }
        }

        // free-running wall-clock mode: real arrival pacing + device-thread
        // updates; reports observed latency instead of replayed costs
        let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, out.partition.clone(), &prof, td);
        let mut p = OclKind::Vanilla.build(1);
        let mut s = mk_stream(&model, zoo.batch, n);
        let t0 = std::time::Instant::now();
        let r = Session::builder(&NativeBackend, &model)
            .config(cfg)
            .plugin(p.as_mut())
            .engine_params(ep)
            .executor(ExecutorKind::Threaded)
            .mode(Mode::Freerun)
            .batch(zoo.batch)
            .build()
            .expect("bench session")
            .run_stream(&mut s)
                    .expect("bench stream matches the model");
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<28} {:>12.1} {:>14.1}   latency {} | staleness {}",
            format!("pipedream[freerun]/{model_name}"),
            dt * 1e3,
            n as f64 / dt,
            r.metrics.latency_summary(),
            r.metrics.staleness_summary()
        );
    }
}
