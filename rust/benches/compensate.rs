//! Compensation-policy throughput: parameters/second of one update-time
//! compensation across policies and staleness depths.
//!
//!     cargo bench --bench compensate

use ferret::backend::native::NativeBackend;
use ferret::compensate::{make, CompContext, CompKind, CompParams};
use ferret::model::GradBuf;
use ferret::util::Rng;

fn gb(rng: &mut Rng, n: usize) -> GradBuf {
    GradBuf {
        gw: (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect(),
        gb: (0..n / 64).map(|_| rng.normal_f32(0.0, 0.1)).collect(),
    }
}

fn main() {
    let be = NativeBackend;
    let mut rng = Rng::new(9);
    let n = 512 * 384; // a convnet stage worth of params
    println!("compensation throughput ({n} weights/stage)");
    println!("{:<14} {:>6} {:>12} {:>14}", "policy", "tau", "us/update", "Mparam/s");
    for kind in CompKind::all() {
        for tau in [1u64, 4, 8] {
            let chain: Vec<GradBuf> = (0..tau).map(|_| gb(&mut rng, n)).collect();
            let jump = gb(&mut rng, n);
            let mut comp = make(kind, CompParams::default());
            let reps = 20;
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                let g = gb(&mut rng, n);
                let ctx = CompContext {
                    backend: &be,
                    tau,
                    chain: &chain,
                    jump: Some(&jump),
                    lr: 0.05,
                };
                let _ = comp.compensate(g, &ctx);
            }
            let us = t0.elapsed().as_micros() as f64 / reps as f64;
            println!(
                "{:<14} {:>6} {:>12.1} {:>14.1}",
                kind.name(),
                tau,
                us,
                n as f64 / us
            );
        }
    }
}
