//! PJRT runtime bench: per-artifact execute latency through the XLA
//! backend (the L1/L2 hot path as deployed). Skips gracefully when
//! artifacts are missing.
//!
//!     make artifacts && cargo bench --bench runtime

use ferret::backend::{xla::XlaBackend, Backend};
use ferret::config::zoo::default_zoo;
use ferret::model::{GradBuf, LayerParams};
use ferret::util::Rng;

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn main() {
    let Ok(xla) = XlaBackend::open_default() else {
        println!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    };
    let zoo = default_zoo().unwrap();
    let batch = xla.runtime().batch();
    let mut rng = Rng::new(3);
    println!("PJRT execute latency (mean of 30 reps after warmup, batch {batch})");
    println!("{:<26} {:>12} {:>12}", "artifact", "us/exec", "GFLOP/s");
    for shape in zoo.distinct_layer_shapes().iter().step_by(4) {
        let p = LayerParams::init(shape, &mut rng);
        let x = randvec(&mut rng, batch * shape.in_dim);
        let g = randvec(&mut rng, batch * shape.out_dim);
        let _ = xla.dense_fwd(shape, &p, &x, batch); // warmup/compile
        let reps = 30;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let _ = xla.dense_fwd(shape, &p, &x, batch);
        }
        let us = t0.elapsed().as_micros() as f64 / reps as f64;
        let gflops = shape.fwd_flops(batch) as f64 / us / 1e3;
        println!(
            "{:<26} {:>12.1} {:>12.2}",
            format!("dense_fwd_{}x{}", shape.in_dim, shape.out_dim),
            us,
            gflops
        );
        let _ = xla.dense_bwd(shape, &p, &x, &g, batch);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let _ = xla.dense_bwd(shape, &p, &x, &g, batch);
        }
        let us = t0.elapsed().as_micros() as f64 / reps as f64;
        let gflops = shape.bwd_flops(batch) as f64 / us / 1e3;
        println!(
            "{:<26} {:>12.1} {:>12.2}",
            format!("dense_bwd_{}x{}", shape.in_dim, shape.out_dim),
            us,
            gflops
        );
        let grads = GradBuf { gw: randvec(&mut rng, p.w.len()), gb: randvec(&mut rng, p.b.len()) };
        let _ = xla.sgd(&p, &grads, 0.01);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let _ = xla.sgd(&p, &grads, 0.01);
        }
        let us = t0.elapsed().as_micros() as f64 / reps as f64;
        println!("{:<26} {:>12.1} {:>12}", format!("sgd_{}x{}", shape.in_dim, shape.out_dim), us, "-");
    }
}
