//! Planner latency bench: Alg. 2 (config search) and Alg. 3 (partition
//! planning) across the zoo — both run once per deployment, but the paper
//! bounds them at O(NP^2)/O(L^3), so wallclock should be trivially small.
//!
//!     cargo bench --bench planner

use ferret::config::zoo::default_zoo;
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, search, Partition, Profile};

fn main() {
    let zoo = default_zoo().unwrap();
    println!("planner latency (per call, mean of N reps)");
    println!("{:<16} {:>8} {:>14} {:>14}", "model", "layers", "Alg2 search us", "Alg3 plan us");
    for (name, model) in &zoo.models {
        let prof = Profile::analytic(model, zoo.batch);
        let td = prof.default_td();
        let decay = decay_for_td(td);
        let part = Partition::per_layer(prof.num_layers());
        let budget = plan(&prof, td, f64::INFINITY, decay).mem_bytes * 0.5;

        let reps = 200;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let _ = search(&part, &prof, td, budget, decay);
        }
        let search_us = t0.elapsed().as_micros() as f64 / reps as f64;

        let reps = 50;
        let t1 = std::time::Instant::now();
        for _ in 0..reps {
            let _ = plan(&prof, td, budget, decay);
        }
        let plan_us = t1.elapsed().as_micros() as f64 / reps as f64;
        println!(
            "{:<16} {:>8} {:>14.1} {:>14.1}",
            name,
            model.num_layers(),
            search_us,
            plan_us
        );
    }
}
