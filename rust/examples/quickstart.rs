//! Quickstart: plan a pipeline under a memory budget and run Ferret on a
//! drifting synthetic stream.
//!
//!     cargo run --release --example quickstart
//!
//! This is the 60-second tour: zoo -> profile -> plan (Alg. 2/3) ->
//! fine-grained async pipeline (T1-T4) with Iter-Fisher compensation.

use ferret::backend::native::NativeBackend;
use ferret::compensate::CompKind;
use ferret::config::zoo::default_zoo;
use ferret::ocl::OclKind;
use ferret::pipeline::engine::AsyncCfg;
use ferret::pipeline::{EngineParams, Session};
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn main() {
    // 1. Pick a model from the shared zoo (configs/models.cfg).
    let zoo = default_zoo().expect("configs/models.cfg");
    let model = zoo.model("convnet10").unwrap();
    println!("model: {} ({} params)", model.name, model.param_count());

    // 2. Profile it and plan under a 15 MB budget.
    let prof = Profile::analytic(model, zoo.batch);
    let td = prof.default_td();
    let budget = 15e6;
    let out = plan(&prof, td, budget, decay_for_td(td));
    println!(
        "plan: {} stages {:?}, {} workers, R_F={:.2e}, M_F={:.1} MB (budget {:.0} MB)",
        out.partition.num_stages(),
        out.partition.bounds,
        out.config.active_workers(),
        out.rate,
        out.mem_bytes / 1e6,
        budget / 1e6
    );

    // 3. A drifting stream with matched dims (CIFAR-like difficulty).
    let mut stream = SyntheticStream::new(StreamSpec {
        name: "quickstart".into(),
        features: model.features(),
        classes: model.classes(),
        batch: zoo.batch,
        num_batches: 120,
        kind: DriftKind::Covariate { cycles: 0.5 },
        margin: 4.0,
        noise: 0.8,
        seed: 7,
    });

    // 4. Build a session for the planned pipeline (Iter-Fisher
    //    compensation) and run the stream through it.
    let cfg = AsyncCfg::ferret(out.partition, out.config, CompKind::IterFisher);
    let ep = EngineParams { lr: 0.05, seed: 7, ..Default::default() };
    let mut plugin = OclKind::Vanilla.build(7);
    let t0 = std::time::Instant::now();
    let r = Session::builder(&NativeBackend, model)
        .config(cfg)
        .plugin(plugin.as_mut())
        .engine_params(ep)
        .batch(zoo.batch)
        .build()
        .expect("valid session config")
        .run_stream(&mut stream)
        .expect("stream matches the model");

    println!("--- results ---");
    println!("online accuracy : {:.2}%", r.metrics.oacc.value());
    println!("test accuracy   : {:.2}%", r.metrics.tacc);
    println!("adaptation rate : {:.4}", r.metrics.adaptation_rate());
    println!("memory (Eq. 4)  : {:.1} MB", r.metrics.mem_bytes / 1e6);
    println!("updates/drops   : {}/{}", r.metrics.trained, r.metrics.dropped);
    println!("wallclock       : {:.1}s", t0.elapsed().as_secs_f64());
    assert!(r.metrics.oacc.value() > 20.0, "quickstart should learn");
}
