//! Ablation of the four memory techniques T1–T4 (DESIGN.md §5) and
//! validation of the planner's cost model: for each single-knob ablation,
//! compare the *predicted* adaptation-rate/memory deltas (Eqs. 3/4 — what
//! Alg. 2 greedily ranks) against the *measured* engine behaviour.
//!
//!     cargo run --release --example ablation_t1t4

use ferret::backend::native::NativeBackend;
use ferret::compensate::CompKind;
use ferret::config::zoo::default_zoo;
use ferret::ocl::OclKind;
use ferret::pipeline::engine::AsyncCfg;
use ferret::pipeline::{EngineParams, Session};
use ferret::planner::costmodel::{adaptation_rate, decay_for_td, mem_footprint, PipeConfig};
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn run(
    cfg: &PipeConfig,
    partition: &ferret::planner::Partition,
    model: &ferret::config::ModelSpec,
    batch: usize,
) -> (f64, f64, f64) {
    let mut stream = SyntheticStream::new(StreamSpec {
        name: "ablate".into(),
        features: model.features(),
        classes: model.classes(),
        batch,
        num_batches: 120,
        kind: DriftKind::Stationary,
        margin: 4.0,
        noise: 0.8,
        seed: 5,
    });
    let acfg = AsyncCfg::ferret(partition.clone(), cfg.clone(), CompKind::NoComp);
    let ep = EngineParams { lr: 0.04, seed: 5, ..Default::default() };
    let mut plugin = OclKind::Vanilla.build(5);
    let r = Session::builder(&NativeBackend, model)
        .config(acfg)
        .plugin(plugin.as_mut())
        .engine_params(ep)
        .batch(batch)
        .build()
        .expect("valid session config")
        .run_stream(&mut stream)
        .expect("stream matches the model");
    (r.metrics.adaptation_rate(), r.metrics.oacc.value(), r.metrics.mem_bytes)
}

fn main() {
    let zoo = default_zoo().expect("zoo");
    let model = zoo.model("convnet10").unwrap();
    let prof = Profile::analytic(model, zoo.batch);
    let td = prof.default_td();
    let decay = decay_for_td(td);
    let base_plan = plan(&prof, td, f64::INFINITY, decay);
    let part = base_plan.partition.clone();
    let p = part.num_stages();
    let base = base_plan.config.clone();

    let mut variants: Vec<(&str, PipeConfig)> = vec![("baseline", base.clone())];
    // T1: recomputation on every worker
    let mut c = base.clone();
    for w in &mut c.workers {
        w.recompute = true;
    }
    variants.push(("T1 recompute", c));
    // T2: accumulation x3 on every stage
    let mut c = base.clone();
    for w in &mut c.workers {
        w.accum = vec![3; p];
    }
    variants.push(("T2 accum=3", c));
    // T3: fully omit stage 0
    let mut c = base.clone();
    for w in &mut c.workers {
        w.omit[0] = (p - 1) as u64;
    }
    variants.push(("T3 omit s0", c));
    // T4: remove half the workers
    let mut c = base.clone();
    let n = c.workers.len();
    for w in c.workers.iter_mut().skip(n.div_ceil(2)) {
        w.delay = -1;
    }
    variants.push(("T4 half workers", c));

    println!(
        "cost-model validation on {} (partition {:?}, {} workers)",
        model.name,
        part.bounds,
        base.active_workers()
    );
    println!(
        "{:<16} {:>11} {:>11} {:>9} {:>11} {:>11}",
        "variant", "pred R_F", "meas R", "oacc%", "pred MB", "eng MB"
    );
    let mut preds: Vec<f64> = Vec::new();
    let mut meas: Vec<f64> = Vec::new();
    for (name, cfg) in &variants {
        let pred_r = adaptation_rate(&part, &prof, cfg, decay);
        let pred_m = mem_footprint(&part, &prof, cfg);
        let (r_meas, oacc, m_eng) = run(cfg, &part, model, zoo.batch);
        println!(
            "{:<16} {:>11.3e} {:>11.4} {:>9.2} {:>11.2} {:>11.2}",
            name,
            pred_r,
            r_meas,
            oacc,
            pred_m / 1e6,
            m_eng / 1e6
        );
        preds.push(pred_r);
        meas.push(r_meas);
    }
    let corr = ferret::harness::pearson(&preds, &meas);
    println!("\nPearson(pred R_F, measured R) over ablations = {corr:.3}");
    assert!(corr > 0.5, "cost model should rank configurations correctly");
    println!("OK: Eq. 3 ranks the T1-T4 knobs the same way the engine does.");
}
