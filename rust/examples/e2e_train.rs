//! End-to-end driver (DESIGN.md §6): the full three-layer stack on a real
//! workload.
//!
//!   L1/L2  Pallas kernels + JAX stage functions, AOT-lowered to HLO text
//!          (`make artifacts`) — loaded here through PJRT; python is NOT
//!          running.
//!   L3     this binary: plan (Alg. 2/3) -> fine-grained async pipeline
//!          (T1-T4, 1F1B, weight stashing) -> Iter-Fisher compensation.
//!
//! Workload: a CLEAR-like slowly-drifting stream on the resnet11 tier
//! (~1.9M params, 8 layers — the repo's largest model) for a few hundred
//! microbatches, logging the loss/oacc curve. Recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_train

use ferret::backend::xla::XlaBackend;
use ferret::compensate::CompKind;
use ferret::config::zoo::default_zoo;
use ferret::ocl::OclKind;
use ferret::pipeline::engine::AsyncCfg;
use ferret::pipeline::{EngineParams, Session};
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn main() {
    let backend = XlaBackend::open_default()
        .expect("artifacts missing — run `make artifacts` first");
    let zoo = default_zoo().expect("zoo");
    let model = zoo.model("resnet11").unwrap();
    println!(
        "e2e: {} ({} params, {} layers) through the XLA/PJRT backend",
        model.name,
        model.param_count(),
        model.num_layers()
    );

    let prof = Profile::analytic(model, zoo.batch);
    let td = prof.default_td();
    let out = plan(&prof, td, 60e6, decay_for_td(td));
    println!(
        "plan: partition {:?} ({} stages), {} workers, M_F={:.1} MB <= 60 MB, R_F={:.2e}",
        out.partition.bounds,
        out.partition.num_stages(),
        out.config.active_workers(),
        out.mem_bytes / 1e6,
        out.rate
    );
    assert!(out.feasible, "plan must satisfy the budget");

    let steps = 300;
    let mut stream = SyntheticStream::new(StreamSpec {
        name: "clear-sim".into(),
        features: model.features(),
        classes: model.classes(),
        batch: zoo.batch,
        num_batches: steps,
        kind: DriftKind::Covariate { cycles: 0.5 },
        margin: 6.5,
        noise: 0.6,
        seed: 2026,
    });

    let cfg = AsyncCfg::ferret(out.partition, out.config, CompKind::IterFisher);
    let ep = EngineParams { lr: 0.05, seed: 2026, ..Default::default() };
    let mut plugin = OclKind::Er.build(2026);
    let t0 = std::time::Instant::now();
    let r = Session::builder(&backend, model)
        .config(cfg)
        .plugin(plugin.as_mut())
        .engine_params(ep)
        .batch(zoo.batch)
        .build()
        .expect("valid session config")
        .run_stream(&mut stream)
        .expect("stream matches the model");
    let wall = t0.elapsed().as_secs_f64();

    // loss / oacc curves, decimated
    println!("\n step    loss    oacc%");
    let curve = &r.metrics.oacc.curve;
    let losses = &r.metrics.losses;
    for k in (0..losses.len()).step_by(losses.len().div_ceil(15)) {
        let (t, loss) = losses[k];
        let oacc = curve
            .iter()
            .take_while(|(ct, _)| *ct <= t)
            .last()
            .map(|(_, v)| *v)
            .unwrap_or(0.0);
        println!("{:>5}  {:>6.3}  {:>6.2}", k, loss, oacc);
    }

    println!("\n--- e2e summary ---");
    println!("steps           : {steps} microbatches x {}", zoo.batch);
    println!("online accuracy : {:.2}%", r.metrics.oacc.value());
    println!("test accuracy   : {:.2}%", r.metrics.tacc);
    println!("adaptation rate : {:.4}", r.metrics.adaptation_rate());
    println!("memory (Eq. 4)  : {:.1} MB", r.metrics.mem_bytes / 1e6);
    println!("updates/drops   : {}/{}", r.metrics.trained, r.metrics.dropped);
    println!(
        "PJRT executions : {} over {} compiled artifacts",
        backend.runtime().exec_count(),
        backend.runtime().compiled_count()
    );
    println!("wallclock       : {wall:.1}s ({:.1} ms/batch)", wall * 1e3 / steps as f64);

    let first_loss = r.metrics.losses.first().map(|(_, l)| *l).unwrap_or(0.0);
    let last_loss = r.metrics.mean_recent_loss(16);
    assert!(
        last_loss < first_loss,
        "loss should decrease: {first_loss} -> {last_loss}"
    );
    assert!(r.metrics.oacc.value() > 30.0, "oacc {}", r.metrics.oacc.value());
    println!("OK: loss decreased {first_loss:.3} -> {last_loss:.3}; all layers composed.");
}
