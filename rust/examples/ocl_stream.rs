//! Class-incremental OCL scenario (the paper's Split-* settings):
//! compare forgetting mitigation plugins (Vanilla / ER / LwF / MAS) on a
//! 5-task class-incremental stream, inside the Ferret pipeline.
//!
//!     cargo run --release --example ocl_stream

use ferret::backend::native::NativeBackend;
use ferret::compensate::CompKind;
use ferret::config::zoo::default_zoo;
use ferret::ocl::OclKind;
use ferret::pipeline::engine::AsyncCfg;
use ferret::pipeline::{EngineParams, Session};
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn main() {
    let zoo = default_zoo().expect("zoo");
    let model = zoo.model("mnistnet10").unwrap();
    let prof = Profile::analytic(model, zoo.batch);
    let td = prof.default_td();
    let out = plan(&prof, td, f64::INFINITY, decay_for_td(td));
    println!(
        "Split-MNIST-like stream | {} stages, {} workers",
        out.partition.num_stages(),
        out.config.active_workers()
    );
    println!("{:<8} {:>8} {:>8} {:>10}", "plugin", "oacc%", "tacc%", "extra MB");

    for kind in [OclKind::Vanilla, OclKind::Er, OclKind::Lwf, OclKind::Mas] {
        let mut stream = SyntheticStream::new(StreamSpec {
            name: "split".into(),
            features: model.features(),
            classes: model.classes(),
            batch: zoo.batch,
            num_batches: 150,
            kind: DriftKind::ClassIncremental { tasks: 5 },
            margin: 6.0,
            noise: 0.6,
            seed: 11,
        });
        let cfg = AsyncCfg::ferret(
            out.partition.clone(),
            out.config.clone(),
            CompKind::IterFisher,
        );
        let ep = EngineParams { lr: 0.05, seed: 11, ..Default::default() };
        let mut plugin = kind.build(11);
        let extra = |p: &dyn ferret::ocl::OclPlugin| p.memory_bytes() as f64 / 1e6;
        let r = Session::builder(&NativeBackend, model)
            .config(cfg)
            .plugin(plugin.as_mut())
            .engine_params(ep)
            .batch(zoo.batch)
            .build()
            .expect("valid session config")
            .run_stream(&mut stream)
            .expect("stream matches the model");
        println!(
            "{:<8} {:>8.2} {:>8.2} {:>10.2}",
            kind.name(),
            r.metrics.oacc.value(),
            r.metrics.tacc,
            extra(plugin.as_ref())
        );
    }
    println!("\ntacc measures retention over ALL classes after the stream:");
    println!("replay/regularization plugins should hold more of it than Vanilla.");
}
