//! Push-based session: drive the engine one hand-fed batch at a time —
//! no closed-world stream object, live metrics between batches, and an
//! imperative mid-stream budget cut.
//!
//!     cargo run --release --example session
//!
//! This is the live-traffic shape of the API: the caller owns the batch
//! source (here a toy two-cluster generator, but equally a socket or a
//! queue), `ingest`s batches as they materialize, `step`s/`drain`s the
//! engine, watches `metrics()` evolve, calls `set_budget` when the
//! operator squeezes the deployment, and `finish`es whenever it decides
//! the session is over.

use ferret::backend::native::NativeBackend;
use ferret::config::ModelSpec;
use ferret::pipeline::{EngineParams, Session, SessionStep};
use ferret::stream::Batch;

/// Hand-rolled batch source: two noisy Gaussian clusters on the first two
/// feature axes. Deliberately not a `ferret::stream::Stream` — the point
/// is that a session does not need one.
fn make_batch(id: u64, features: usize, rows: usize) -> Batch {
    // tiny deterministic LCG, seeded by the batch id
    let mut state = id.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        (state >> 11) as f32 / (1u64 << 53) as f32 - 0.5
    };
    let mut x = Vec::with_capacity(rows * features);
    let mut y = Vec::with_capacity(rows);
    for r in 0..rows {
        let label = ((id as usize + r) % 2) as i32;
        for f in 0..features {
            let center = if f == label as usize { 3.0 } else { 0.0 };
            x.push(center + next());
        }
        y.push(label);
    }
    Batch { id, x, y }
}

fn main() {
    let model = ModelSpec { name: "session-demo".into(), dims: vec![16, 32, 16, 2] };
    let (rows, n_batches) = (8usize, 120u64);

    // No explicit config: the builder auto-plans an unconstrained Ferret
    // pipeline for the model. Only the batch row count is mandatory.
    let mut session = Session::builder(&NativeBackend, &model)
        .engine_params(EngineParams { lr: 0.1, seed: 9, ..Default::default() })
        .batch(rows)
        .build()
        .expect("valid session config");

    println!("push-based session on {} ({} params)", model.name, model.param_count());
    println!("{:>6} {:>8} {:>9} {:>9}", "batch", "oacc%", "trained", "replans");
    for id in 0..n_batches {
        session
            .ingest(make_batch(id, model.features(), rows))
            .expect("hand-made batch matches the model");
        // step until the engine is blocked on the next arrival — metrics
        // are observable at any point in between
        while session.step().expect("session step") == SessionStep::Progressed {}
        if id % 20 == 19 {
            let m = session.metrics();
            println!(
                "{:>6} {:>8.2} {:>9} {:>9}",
                id + 1,
                m.oacc.value(),
                m.trained,
                m.replans
            );
        }
        if id == n_batches / 2 {
            // the operator squeezes the deployment mid-stream: the session
            // drains in-flight work, re-plans at the new budget with the
            // learned weights carried over, and resumes
            let budget = 32e3; // 32 KB — tight for even this toy model
            println!("  -- set_budget({:.0} KB) --", budget / 1e3);
            session.set_budget(budget).expect("valid budget");
        }
    }

    let result = session.finish().expect("session finish");
    println!("\n--- session result ---");
    println!("arrivals        : {}", result.metrics.arrivals());
    println!("online accuracy : {:.2}%", result.metrics.oacc.value());
    println!("updates/drops   : {}/{}", result.metrics.trained, result.metrics.dropped);
    println!("replans         : {} (drains {:?})", result.metrics.replans, result.metrics.drains);
    println!(
        "ledger          : peak {:.2} MB | final {:.2} MB (measured)",
        result.metrics.ledger.peak_total as f64 / 1e6,
        result.metrics.ledger.last.total() as f64 / 1e6
    );
    assert_eq!(result.metrics.arrivals(), n_batches, "every pushed batch was processed");
    assert!(result.metrics.replans >= 1, "the imperative budget cut re-planned");
    assert!(result.metrics.oacc.value() > 60.0, "separable toy stream should learn");
    println!("OK: hand-fed ingestion, live metrics, and an imperative re-plan.");
}
