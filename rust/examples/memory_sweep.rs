//! Memory-budget sweep (the paper's Fig. 6 scenario): plan + run Ferret
//! at five budgets from starvation to unconstrained and print the
//! oacc-vs-memory frontier, alongside the fixed-memory PipeDream point.
//!
//!     cargo run --release --example memory_sweep

use ferret::backend::native::NativeBackend;
use ferret::compensate::CompKind;
use ferret::config::zoo::default_zoo;
use ferret::ocl::OclKind;
use ferret::pipeline::engine::{AsyncCfg, AsyncSchedule};
use ferret::pipeline::{EngineParams, Session};
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn mk_stream(model: &ferret::config::ModelSpec, batch: usize, seed: u64) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "sweep".into(),
        features: model.features(),
        classes: model.classes(),
        batch,
        num_batches: 120,
        kind: DriftKind::Stationary,
        margin: 4.5,
        noise: 0.8,
        seed,
    })
}

fn main() {
    let zoo = default_zoo().expect("zoo");
    let model = zoo.model("convnet10").unwrap();
    let prof = Profile::analytic(model, zoo.batch);
    let td = prof.default_td();
    let decay = decay_for_td(td);
    let ep = EngineParams { lr: 0.05, seed: 3, ..Default::default() };

    let unconstrained = plan(&prof, td, f64::INFINITY, decay);
    let hi = unconstrained.mem_bytes;
    let lo = hi / 12.0;
    println!("sweeping budgets {:.1}..{:.1} MB on {}", lo / 1e6, hi / 1e6, model.name);
    println!("{:<22} {:>9} {:>8} {:>8} {:>8}", "config", "mem MB", "oacc%", "R_meas", "workers");

    for k in 0..5 {
        let budget = lo * (hi / lo).powf(k as f64 / 4.0);
        let out = plan(&prof, td, budget, decay);
        let cfg = AsyncCfg::ferret(out.partition.clone(), out.config.clone(), CompKind::IterFisher);
        let mut plugin = OclKind::Vanilla.build(3);
        let mut stream = mk_stream(model, zoo.batch, 3);
        let r = Session::builder(&NativeBackend, model)
            .config(cfg)
            .plugin(plugin.as_mut())
            .engine_params(ep)
            .batch(zoo.batch)
            .build()
            .expect("valid session config")
            .run_stream(&mut stream)
            .expect("stream matches the model");
        println!(
            "{:<22} {:>9.2} {:>8.2} {:>8.4} {:>8}",
            format!("Ferret@{:.1}MB", budget / 1e6),
            r.metrics.mem_bytes / 1e6,
            r.metrics.oacc.value(),
            r.metrics.adaptation_rate(),
            out.config.active_workers()
        );
    }

    // the fixed-memory async baseline for contrast
    let cfg = AsyncCfg::baseline(
        AsyncSchedule::Pipedream,
        unconstrained.partition.clone(),
        &prof,
        td,
    );
    let mut plugin = OclKind::Vanilla.build(3);
    let mut stream = mk_stream(model, zoo.batch, 3);
    let r = Session::builder(&NativeBackend, model)
        .config(cfg)
        .plugin(plugin.as_mut())
        .engine_params(ep)
        .batch(zoo.batch)
        .build()
        .expect("valid session config")
        .run_stream(&mut stream)
        .expect("stream matches the model");
    println!(
        "{:<22} {:>9.2} {:>8.2} {:>8.4} {:>8}",
        "Pipedream (fixed)",
        r.metrics.mem_bytes / 1e6,
        r.metrics.oacc.value(),
        r.metrics.adaptation_rate(),
        "-"
    );
    println!("\nFerret scales across the whole budget axis; fixed strategies are one point.");
}
