//! Span-recorder integration tests (see `ferret::obs`).
//!
//! The observability contract has two halves. In lockstep, spans are
//! stamped from the virtual clock with analytic durations, so the
//! exported trace is part of the determinism surface: bit-for-bit
//! identical across executors and kernel-thread counts. In freerun,
//! spans are real wall intervals, so only structure is pinned: stamps
//! monotone, per-device lanes non-overlapping, and exactly one stage-0
//! Fwd span per admitted (non-dropped) batch.

use std::collections::HashMap;

use ferret::backend::native::NativeBackend;
use ferret::config::ModelSpec;
use ferret::ocl::Vanilla;
use ferret::pipeline::engine::{AsyncCfg, AsyncSchedule};
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::{EngineParams, RunResult, Session};
use ferret::planner::{Partition, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};
use ferret::trace::json;

fn model() -> ModelSpec {
    ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
}

fn stream(n: usize, seed: u64) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "obs".into(),
        features: 16,
        classes: 4,
        batch: 8,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 3.0,
        noise: 0.5,
        seed,
    })
}

fn tmp(name: &str) -> String {
    std::env::temp_dir().join(name).to_str().unwrap().to_string()
}

/// Pipedream run with the span recorder exporting to `path`.
fn span_run(
    kind: ExecutorKind,
    mode: Mode,
    kernel_threads: usize,
    n: usize,
    td: u64,
    path: &str,
) -> RunResult {
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, part, &prof, td);
    let ep = EngineParams { lr: 0.2, td, kernel_threads, ..Default::default() };
    let mut plugin = Vanilla;
    Session::builder(&NativeBackend, &m)
        .config(cfg)
        .plugin(&mut plugin)
        .engine_params(ep)
        .executor(kind)
        .mode(mode)
        .batch(8)
        .span_trace(path)
        .build()
        .expect("session builds")
        .run_stream(&mut stream(n, 17))
        .expect("stream runs")
}

/// `"X"` events from a Chrome trace as (pid, tid, ts, dur, name).
fn events(text: &str) -> Vec<(u64, u64, u64, u64, String)> {
    let j = json::parse(text).expect("span trace is valid json");
    let evs = j.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
    let num = |e: &json::Json, k: &str| e.get(k).and_then(|v| v.as_f64()).unwrap() as u64;
    evs.iter()
        .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X"))
        .map(|e| {
            let name = e.get("name").and_then(|v| v.as_str()).unwrap().to_string();
            (num(e, "pid"), num(e, "tid"), num(e, "ts"), num(e, "dur"), name)
        })
        .collect()
}

#[test]
fn lockstep_span_trace_is_identical_across_executors_and_kernel_threads() {
    let n = 40;
    let (pa, pb, pc) = (
        tmp("obs_spans_sim.json"),
        tmp("obs_spans_threaded.json"),
        tmp("obs_spans_kt4.json"),
    );
    let ra = span_run(ExecutorKind::Sim, Mode::Lockstep, 1, n, 400, &pa);
    let rb = span_run(ExecutorKind::Threaded, Mode::Lockstep, 1, n, 400, &pb);
    let rc = span_run(ExecutorKind::Threaded, Mode::Lockstep, 4, n, 400, &pc);
    let (a, b, c) = (
        std::fs::read_to_string(&pa).unwrap(),
        std::fs::read_to_string(&pb).unwrap(),
        std::fs::read_to_string(&pc).unwrap(),
    );
    assert_eq!(a, b, "sim vs threaded lockstep span traces diverged");
    assert_eq!(a, c, "kernel_threads changed the lockstep span trace");
    // the trace is non-trivial and numerically consistent with the run
    let evs = events(&a);
    assert!(!evs.is_empty(), "lockstep run recorded no spans");
    let fwd0 = evs.iter().filter(|e| e.4 == "Fwd" && e.1 == 0 && e.0 != 99).count();
    assert_eq!(fwd0 as u64, n as u64, "lockstep admits every batch: one stage-0 Fwd each");
    assert!(evs.iter().any(|e| e.4 == "Bwd"), "no backward spans recorded");
    assert!(evs.iter().any(|e| e.4 == "Update"), "no update spans recorded");
    assert_eq!(ra.metrics.oacc.value(), rb.metrics.oacc.value());
    assert_eq!(ra.metrics.oacc.value(), rc.metrics.oacc.value());
    for p in [pa, pb, pc] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn freerun_spans_are_monotone_nonoverlapping_and_count_admissions() {
    let n = 60;
    let path = tmp("obs_spans_freerun.json");
    // slow arrivals relative to the tiny model keep the run drop-free-ish
    let r = span_run(ExecutorKind::Threaded, Mode::Freerun, 1, n, 2000, &path);
    let evs = events(&std::fs::read_to_string(&path).unwrap());
    // exactly one stage-0 forward per admitted batch (drops are
    // predict-only and never dispatched)
    let fwd0 = evs.iter().filter(|e| e.4 == "Fwd" && e.1 == 0 && e.0 != 99).count();
    assert_eq!(fwd0 as u64, n as u64 - r.metrics.dropped);
    // per-device lanes: spans sorted by start must not overlap (each
    // device runs one flight at a time); engine lane (pid 99) included
    let mut lanes: HashMap<(u64, u64), Vec<(u64, u64)>> = HashMap::new();
    for (pid, tid, ts, dur, _) in &evs {
        lanes.entry((*pid, *tid)).or_default().push((*ts, *dur));
    }
    assert!(lanes.len() > 1, "expected more than one device lane");
    for ((pid, tid), mut lane) in lanes {
        lane.sort_unstable();
        for w in lane.windows(2) {
            let (s0, d0) = w[0];
            let (s1, _) = w[1];
            assert!(
                s1 >= s0 + d0,
                "device ({pid},{tid}): span at {s1} overlaps [{s0}, {})",
                s0 + d0
            );
        }
    }
    let _ = std::fs::remove_file(path);
}

#[test]
fn metrics_out_streams_schema_header_and_deterministic_cadence() {
    let n = 30;
    let path = tmp("obs_spans_stream.jsonl");
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, part, &prof, 400);
    let ep = EngineParams { lr: 0.2, td: 400, ..Default::default() };
    let mut plugin = Vanilla;
    let r = Session::builder(&NativeBackend, &m)
        .config(cfg)
        .plugin(&mut plugin)
        .engine_params(ep)
        .executor(ExecutorKind::Sim)
        .mode(Mode::Lockstep)
        .batch(8)
        .metrics_out(&path, 5)
        .build()
        .expect("session builds")
        .run_stream(&mut stream(n, 23))
        .expect("stream runs");
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    // header + one record per 5 arrivals + one final record at finish
    assert_eq!(lines.len(), 1 + n / 5 + 1, "snapshot cadence is arrival-deterministic");
    let hdr = json::parse(lines[0]).unwrap();
    assert_eq!(hdr.get("schema").and_then(|v| v.as_str()), Some("ferret-obs/1"));
    assert_eq!(hdr.get("interval_arrivals").and_then(|v| v.as_f64()), Some(5.0));
    let mut prev_arrivals = 0.0;
    for l in &lines[1..] {
        let j = json::parse(l).expect("snapshot line is valid json");
        let arrivals = j.get("arrivals").and_then(|v| v.as_f64()).unwrap();
        assert!(arrivals >= prev_arrivals, "arrivals regressed in the stream");
        prev_arrivals = arrivals;
        assert!(j.get("busy_us").is_some() && j.get("devices").is_some());
    }
    assert_eq!(prev_arrivals as u64, n as u64, "final record sees the whole stream");
    let last = json::parse(lines.last().unwrap()).unwrap();
    let oacc = last.get("oacc").and_then(|v| v.as_f64()).unwrap();
    assert!((oacc - r.metrics.oacc.value()).abs() < 1e-6, "final snapshot oacc matches metrics");
    let _ = std::fs::remove_file(path);
}

#[test]
fn obs_snapshot_is_live_midrun_and_zero_when_disabled() {
    let n = 20;
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let cfg = || {
        let part = Partition::per_layer(model().num_layers());
        AsyncCfg::baseline(AsyncSchedule::Pipedream, part, &prof, 400)
    };
    let ep = EngineParams { lr: 0.2, td: 400, ..Default::default() };
    let mut plugin = Vanilla;
    let mut s = stream(n, 5);
    let mut session = Session::builder(&NativeBackend, &m)
        .config(cfg())
        .plugin(&mut plugin)
        .engine_params(ep)
        .executor(ExecutorKind::Sim)
        .mode(Mode::Lockstep)
        .batch(8)
        .record_spans()
        .build()
        .expect("session builds");
    for _ in 0..n / 2 {
        session.ingest(s.next_batch().unwrap()).unwrap();
    }
    session.drain().expect("drain");
    let snap = session.obs_snapshot();
    assert!(snap.busy_us > 0, "mid-run snapshot sees device work");
    assert!(!snap.devices.is_empty());
    assert!(snap.arrivals > 0 && snap.t_us > 0);
    assert!((0.0..=1.0).contains(&snap.bubble_frac), "bubble {}", snap.bubble_frac);
    let r = session.finish().expect("finish");
    assert!(r.metrics.busy_us > 0, "always-on busy accounting populated");
    assert!(r.metrics.device_us >= r.metrics.busy_us, "util <= 1");

    // recorder off: snapshot is metrics-side only, and the always-on
    // busy/device accounting still fills RunMetrics
    let mut plugin2 = Vanilla;
    let mut session = Session::builder(&NativeBackend, &m)
        .config(cfg())
        .plugin(&mut plugin2)
        .engine_params(ep)
        .executor(ExecutorKind::Sim)
        .mode(Mode::Lockstep)
        .batch(8)
        .build()
        .expect("session builds");
    let mut s = stream(n, 5);
    for _ in 0..n / 2 {
        session.ingest(s.next_batch().unwrap()).unwrap();
    }
    session.drain().expect("drain");
    let snap = session.obs_snapshot();
    assert_eq!(snap.busy_us, 0, "disabled recorder claims no span accounting");
    assert!(snap.devices.is_empty());
    assert!(snap.arrivals > 0, "metrics-side counters are live either way");
    let r = session.finish().expect("finish");
    assert!(r.metrics.busy_us > 0 && r.metrics.utilization() > 0.0);
}
