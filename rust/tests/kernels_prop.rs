//! Property tests for the tiled/parallel kernel layer and the buffer
//! pool, from outside the crate: the naive reference loops are the
//! ground truth, the pool must never alias live buffers, and a warm
//! session must stop allocating on the steady-state path.

use ferret::backend::kernels;
use ferret::backend::native::NativeBackend;
use ferret::backend::{Backend, BufferPool, Workspace};
use ferret::compensate::CompKind;
use ferret::config::zoo::{default_zoo, Act, LayerShape};
use ferret::model::params::LayerParams;
use ferret::ocl::OclKind;
use ferret::pipeline::engine::AsyncCfg;
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::{EngineParams, Session};
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};
use ferret::util::{property, Rng};

fn randvec(rng: &mut Rng, n: usize, sparse: bool) -> Vec<f32> {
    (0..n)
        .map(|_| if sparse && rng.uniform() < 0.5 { 0.0 } else { rng.normal_f32(0.0, 1.0) })
        .collect()
}

#[test]
fn tiled_kernels_match_naive_reference_for_every_thread_count() {
    property("kprop_ref", 15, |rng| {
        let (m, k, n) = (1 + rng.below(40), 1 + rng.below(80), 1 + rng.below(48));
        let a = randvec(rng, m * k, rng.uniform() < 0.5);
        let b = randvec(rng, k * n, false);
        let at = randvec(rng, k * m, rng.uniform() < 0.5);
        let bt = randvec(rng, n * k, false);
        for threads in [1, 2, 5] {
            // fwd and bwd-weight flavors: bit-identical to naive
            let mut c0 = randvec(rng, m * n, false);
            let mut c1 = c0.clone();
            kernels::naive_matmul_acc(&mut c0, &a, &b, m, k, n);
            kernels::matmul_acc(&mut c1, &a, &b, m, k, n, threads);
            assert_eq!(c0, c1, "acc {m}x{k}x{n} t={threads}");

            let mut d0 = randvec(rng, m * n, false);
            let mut d1 = d0.clone();
            kernels::naive_matmul_at_acc(&mut d0, &at, &b, m, k, n);
            kernels::matmul_at_acc(&mut d1, &at, &b, m, k, n, threads);
            assert_eq!(d0, d1, "at {m}x{k}x{n} t={threads}");
        }
        // bwd-input flavor: tolerance vs naive, exact across thread counts
        let mut e0 = vec![0.0f32; m * n];
        kernels::naive_matmul_bt_acc(&mut e0, &a, &bt, m, k, n);
        let mut e1 = vec![0.0f32; m * n];
        kernels::matmul_bt_acc(&mut e1, &a, &bt, m, k, n, 1);
        let mut e5 = vec![0.0f32; m * n];
        kernels::matmul_bt_acc(&mut e5, &a, &bt, m, k, n, 5);
        assert_eq!(e1, e5, "bt thread-variant {m}x{k}x{n}");
        for (x, y) in e0.iter().zip(&e1) {
            assert!((x - y).abs() < 1e-3 * (1.0 + x.abs()), "bt {x} vs {y}");
        }
    });
}

/// The lane-blocked kernels must stay bit-identical to the naive loops on
/// every awkward shape: `n`/`k` off the 8-wide lane grid, `n < 8` (pure
/// scalar-tail columns), single rows, exact block boundaries, and fully
/// zero A rows (the sparse-skip path end to end) — at thread counts 1/2/5,
/// accumulating into dirty recycled pool buffers.
#[test]
fn simd_lanes_match_naive_bitwise_on_awkward_shapes() {
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),    // everything scalar
        (2, 3, 5),    // under one lane block on every axis
        (4, 7, 7),    // n < 8: no vector body at all
        (3, 9, 8),    // k past a block, n exactly one block
        (5, 16, 9),   // n one past a block
        (16, 33, 63), // off-grid everywhere, tails on every path
        (8, 64, 65),  // 64-wide fast path plus a scalar column
        (7, 100, 72), // 72 = 9 lanes: wide-tile boundary
    ];
    let mut rng = Rng::new(0xA11C);
    let pool = BufferPool::new();
    // seed the pool with poisoned buffers so every take below is a dirty
    // recycle, not a fresh zeroed allocation
    for &(m, _, n) in SHAPES {
        pool.put(vec![f32::NAN; m * n]);
    }
    for &(m, k, n) in SHAPES {
        let a = randvec(&mut rng, m * k, true); // exact zeros: sparse skip
        let b = randvec(&mut rng, k * n, false);
        let at = randvec(&mut rng, k * m, true);
        let zeros = vec![0.0f32; m * k];
        let init = randvec(&mut rng, m * n, false);
        for threads in [1usize, 2, 5] {
            let mut c0 = init.clone();
            kernels::naive_matmul_acc(&mut c0, &a, &b, m, k, n);
            let mut c1 = pool.take(m * n);
            c1.copy_from_slice(&init);
            kernels::matmul_acc(&mut c1, &a, &b, m, k, n, threads);
            assert_eq!(c0, c1, "acc {m}x{k}x{n} t={threads}");
            pool.put(c1);

            let mut d0 = init.clone();
            kernels::naive_matmul_at_acc(&mut d0, &at, &b, m, k, n);
            let mut d1 = pool.take(m * n);
            d1.copy_from_slice(&init);
            kernels::matmul_at_acc(&mut d1, &at, &b, m, k, n, threads);
            assert_eq!(d0, d1, "at {m}x{k}x{n} t={threads}");
            pool.put(d1);

            // an all-zero A must leave the accumulator untouched on both
            // paths (the skip never sees a lane boundary)
            let mut z0 = init.clone();
            kernels::naive_matmul_acc(&mut z0, &zeros, &b, m, k, n);
            let mut z1 = init.clone();
            kernels::matmul_acc(&mut z1, &zeros, &b, m, k, n, threads);
            assert_eq!(z0, z1, "zero-A {m}x{k}x{n} t={threads}");
            assert_eq!(z1, init, "zero-A must not perturb the accumulator");
        }
        // elementwise maps: same lane blocking, same tails, into a dirty
        // recycled buffer
        let g = randvec(&mut rng, m * n, true);
        let d = randvec(&mut rng, m * n, false);
        let mut out = pool.take(m * n);
        kernels::sgd_into(&mut out, &init, &g, 0.37);
        let want: Vec<f32> = init.iter().zip(&g).map(|(p, gv)| p - 0.37 * gv).collect();
        assert_eq!(out, want, "sgd {m}x{n}");
        kernels::compensate_into(&mut out, &g, &d, 0.21);
        let want: Vec<f32> =
            g.iter().zip(&d).map(|(gv, dv)| gv + 0.21 * gv * gv * dv).collect();
        assert_eq!(out, want, "compensate {m}x{n}");
        pool.put(out);
    }
}

#[test]
fn backend_pooled_paths_match_unpooled_bitwise() {
    property("kprop_pooled", 10, |rng| {
        let shape = LayerShape {
            in_dim: 1 + rng.below(24),
            out_dim: 1 + rng.below(24),
            act: if rng.uniform() < 0.5 { Act::Relu } else { Act::None },
        };
        let batch = 1 + rng.below(12);
        let p = LayerParams::init(&shape, rng);
        let x = randvec(rng, batch * shape.in_dim, false);
        let g = randvec(rng, batch * shape.out_dim, false);
        let be = NativeBackend;
        let ws = Workspace::new(BufferPool::new(), 1 + rng.below(3));

        let plain = be.dense_fwd(&shape, &p, &x, batch);
        // run the pooled path twice so the second pass consumes recycled
        // (dirty) buffers — contents must still be fully overwritten
        for pass in 0..2 {
            let z = be.dense_fwd_pooled(&shape, &p, &x, batch, &ws);
            assert_eq!(plain, z, "fwd pass {pass}");
            ws.pool.put(z);
            let b0 = be.dense_bwd(&shape, &p, &x, &g, batch);
            let b1 = be.dense_bwd_pooled(&shape, &p, &x, &g, batch, &ws);
            assert_eq!(b0.gx, b1.gx, "gx pass {pass}");
            assert_eq!(b0.grads.gw, b1.grads.gw, "gw pass {pass}");
            assert_eq!(b0.grads.gb, b1.grads.gb, "gb pass {pass}");
            ws.pool.put(b1.gx);
            ws.pool.put(b1.grads.gw);
            ws.pool.put(b1.grads.gb);
        }
    });
}

#[test]
fn pool_never_hands_out_aliased_buffers() {
    let pool = BufferPool::new();
    let mut live: Vec<Vec<f32>> = (0..12).map(|_| pool.take(64)).collect();
    let mut ptrs: Vec<*const f32> = live.iter().map(|v| v.as_ptr()).collect();
    ptrs.sort();
    ptrs.dedup();
    assert_eq!(ptrs.len(), 12, "concurrently-live buffers must be distinct");
    // recycle half, take again: still no aliasing among live buffers
    for v in live.drain(..6) {
        pool.put(v);
    }
    live.extend((0..6).map(|_| pool.take(64)));
    let mut ptrs: Vec<*const f32> = live.iter().map(|v| v.as_ptr()).collect();
    ptrs.sort();
    ptrs.dedup();
    assert_eq!(ptrs.len(), 12);
    // and the re-takes were recycles, not allocations
    assert_eq!(pool.stats().misses, 12);
}

fn mk_stream(model: &ferret::config::ModelSpec, batch: usize, n: usize) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "kprop".into(),
        features: model.features(),
        classes: model.classes(),
        batch,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 4.0,
        noise: 0.8,
        seed: 7,
    })
}

fn ferret_session_parts(
    model: &ferret::config::ModelSpec,
    batch: usize,
) -> AsyncCfg {
    let prof = Profile::analytic(model, batch);
    let td = prof.default_td();
    let out = plan(&prof, td, f64::INFINITY, decay_for_td(td));
    AsyncCfg::ferret(out.partition, out.config, CompKind::IterFisher)
}

#[test]
fn warm_session_stops_allocating_per_microbatch() {
    let zoo = default_zoo().expect("zoo");
    let model = zoo.model("mnistnet10").expect("model").clone();
    let mut plugin = OclKind::Vanilla.build(7);
    let (warm, measure) = (10, 10);
    let mut stream = mk_stream(&model, zoo.batch, warm + measure);
    let mut session = Session::builder(&NativeBackend, &model)
        .config(ferret_session_parts(&model, zoo.batch))
        .plugin(plugin.as_mut())
        .engine_params(EngineParams { lr: 0.05, seed: 7, ..Default::default() })
        .executor(ExecutorKind::Sim)
        .mode(Mode::Lockstep)
        .batch(zoo.batch)
        .build()
        .expect("session");
    let cold = session.pool_stats();
    for _ in 0..warm {
        session.ingest(stream.next_batch().expect("batch")).expect("ingest");
        session.drain().expect("drain");
    }
    let mid = session.pool_stats();
    for _ in 0..measure {
        session.ingest(stream.next_batch().expect("batch")).expect("ingest");
        session.drain().expect("drain");
    }
    let end = session.pool_stats();

    let first = mid.since(&cold);
    let second = end.since(&mid);
    assert!(second.takes > 0, "warm window must exercise the pool");
    // warm-up pays the allocations; the measured window must not pay
    // more, and must mostly recycle
    assert!(
        second.misses <= first.misses,
        "allocations grew when warm: {first:?} then {second:?}"
    );
    assert!(
        second.misses * 4 <= second.takes,
        "steady state mostly allocates: {second:?}"
    );
}

#[test]
fn lockstep_sim_metrics_are_invariant_to_kernel_threads() {
    let zoo = default_zoo().expect("zoo");
    let model = zoo.model("mnistnet10").expect("model").clone();
    let run = |kernel_threads: usize| {
        let mut plugin = OclKind::Vanilla.build(3);
        let mut stream = mk_stream(&model, zoo.batch, 24);
        Session::builder(&NativeBackend, &model)
            .config(ferret_session_parts(&model, zoo.batch))
            .plugin(plugin.as_mut())
            .engine_params(EngineParams {
                lr: 0.05,
                seed: 3,
                kernel_threads,
                ..Default::default()
            })
            .executor(ExecutorKind::Sim)
            .mode(Mode::Lockstep)
            .batch(zoo.batch)
            .build()
            .expect("session")
            .run_stream(&mut stream)
            .expect("stream matches the model")
    };
    let serial = run(1);
    let parallel = run(4);
    // the kernel determinism contract: thread count never changes bits
    assert_eq!(
        serial.metrics.oacc.value(),
        parallel.metrics.oacc.value(),
        "online accuracy diverged across kernel thread counts"
    );
    assert_eq!(
        serial.metrics.mean_recent_loss(16),
        parallel.metrics.mean_recent_loss(16),
        "loss trace diverged across kernel thread counts"
    );
}
