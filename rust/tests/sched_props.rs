//! Randomized property tests for the scheduling core.
//!
//! Drives `SchedCore` through the same control flow the async engine uses
//! (admit → forward chain → loss head → backward chain → retire) over
//! seeded random worker/stage/cost/arrival/capacity configurations — no
//! numerics, pure mechanism — and asserts the scheduler invariants:
//!
//!   - per-device FIFO completion (Done events pop in dispatch order)
//!   - no dispatch while a device is busy (`busy_until > t`)
//!   - 1F1B: backward work preempts queued forwards
//!   - every admitted job retires exactly once; none are lost or doubled
//!   - `inflight` never exceeds `inflight_cap * active_workers`
//!   - round-robin routing over the active workers

use std::collections::VecDeque;

use ferret::pipeline::sched::{Ev, Job, SchedCore, StageMeta, WorkSel};
use ferret::util::Rng;

/// Expected completion record: (job, bwd, end-time).
type Fifo = Vec<Vec<VecDeque<(usize, bool, u64)>>>;

fn mk_job(seq: u64, arrival: u64, stages: usize) -> Job {
    let mut stage_inputs: Vec<Option<Vec<f32>>> = vec![None; stages];
    stage_inputs[0] = Some(vec![]);
    Job {
        arrival,
        seq,
        y: vec![0],
        batch_x: vec![],
        stage_inputs,
        fwd_version: vec![0; stages],
        grad: None,
        done: false,
    }
}

/// Mimic the engine's kick: select work for an idle device, checking the
/// 1F1B and busy-gating invariants, and dispatch it with its stage cost.
fn kick(core: &mut SchedCore, w: usize, s: usize, t: u64, fifo: &mut Fifo) {
    if core.slots[w][s].busy_until > t {
        assert!(
            core.select_work(w, s, t).is_none(),
            "no dispatch while busy: w{w} s{s} busy_until={} t={t}",
            core.slots[w][s].busy_until
        );
        return;
    }
    let head_bwd = core.slots[w][s].bwd_q.front().copied();
    let head_fwd = core.slots[w][s].fwd_q.front().copied();
    match core.select_work(w, s, t) {
        None => {
            assert!(head_bwd.is_none() && head_fwd.is_none(), "idle device skipped work");
        }
        Some(WorkSel::Bwd(job)) => {
            assert_eq!(Some(job), head_bwd, "backward pops its queue FIFO");
            let end = t + core.stages[s].tb.max(1);
            core.dispatch(w, s, end, job, true);
            fifo[w][s].push_back((job, true, end));
        }
        Some(WorkSel::Fwd(job)) => {
            assert!(head_bwd.is_none(), "1F1B: backward preempts queued forwards");
            assert_eq!(Some(job), head_fwd, "forward pops its queue FIFO");
            let end = t + core.stages[s].tf.max(1);
            core.dispatch(w, s, end, job, false);
            fifo[w][s].push_back((job, false, end));
        }
    }
}

/// One full randomized schedule driven to quiescence.
fn run_random(seed: u64) {
    let mut rng = Rng::new(seed);
    let n_workers = 1 + rng.below(3);
    let n_stages = 1 + rng.below(3);
    let metas: Vec<StageMeta> = (0..n_stages)
        .map(|j| StageMeta {
            layers: j..j + 1,
            tf: 1 + rng.below(20) as u64,
            tb: 1 + rng.below(25) as u64,
            params: 1,
        })
        .collect();
    // random non-empty subset of workers is active (T4 removals)
    let mut active: Vec<usize> = (0..n_workers).filter(|_| rng.below(4) > 0).collect();
    if active.is_empty() {
        active = vec![0];
    }
    let mut core = SchedCore::new(metas, n_workers, active.clone());
    // tight random caps force the over-capacity drop path
    core.inflight_cap = 1 + rng.below(5);
    let n_arrivals = 30 + rng.below(40) as u64;
    let td = 1 + rng.below(30) as u64;

    let mut fifo: Fifo = (0..n_workers)
        .map(|_| (0..n_stages).map(|_| VecDeque::new()).collect())
        .collect();
    let mut retired: Vec<u32> = Vec::new();
    let mut drops = 0usize;
    let mut arrived = 0u64;
    let mut last_t = 0u64;
    core.events.push(0, Ev::Arrive);

    while let Some((t, ev)) = core.events.pop() {
        assert!(t >= last_t, "event times are non-decreasing");
        last_t = t;
        match ev {
            Ev::Arrive => {
                let seq = arrived;
                arrived += 1;
                if arrived < n_arrivals {
                    core.events.push(arrived * td, Ev::Arrive);
                }
                if core.over_capacity() {
                    drops += 1;
                    continue;
                }
                let expect_w = active[(seq as usize) % active.len()];
                let (id, w) = core.admit(mk_job(seq, t, n_stages)).expect("active workers");
                assert_eq!(w, expect_w, "round-robin routing over active workers");
                assert_eq!(id, retired.len(), "job ids are dense");
                retired.push(0);
                assert!(
                    core.inflight <= core.inflight_cap * core.active_workers.len(),
                    "inflight {} exceeds cap {} x {}",
                    core.inflight,
                    core.inflight_cap,
                    core.active_workers.len()
                );
                kick(&mut core, w, 0, t, &mut fifo);
            }
            Ev::Done { worker: w, stage: s, job, bwd } => {
                let (ej, ebwd, eend) =
                    fifo[w][s].pop_front().expect("completion without a dispatch");
                assert_eq!((ej, ebwd, eend), (job, bwd, t), "per-device FIFO completion");
                if !bwd {
                    if s + 1 < n_stages {
                        core.jobs[job].stage_inputs[s + 1] = Some(vec![]);
                        core.slots[w][s + 1].fwd_q.push_back(job);
                        kick(&mut core, w, s + 1, t, &mut fifo);
                    } else {
                        // loss head: turn around into the backward chain
                        core.jobs[job].grad = Some(vec![]);
                        core.slots[w][s].bwd_q.push_back(job);
                    }
                } else if s > 0 {
                    core.jobs[job].grad = Some(vec![]);
                    core.slots[w][s - 1].bwd_q.push_back(job);
                    kick(&mut core, w, s - 1, t, &mut fifo);
                } else {
                    retired[job] += 1;
                    core.retire(job);
                }
                kick(&mut core, w, s, t, &mut fifo);
            }
        }
    }

    // quiescence: every admitted job retired exactly once, nothing lost
    assert!(retired.iter().all(|&c| c == 1), "seed {seed}: retire counts {retired:?}");
    assert_eq!(core.inflight, 0, "seed {seed}: inflight drained");
    assert_eq!(
        retired.len() + drops,
        arrived as usize,
        "seed {seed}: every arrival admitted or dropped"
    );
    for w in 0..n_workers {
        for s in 0..n_stages {
            assert!(fifo[w][s].is_empty(), "seed {seed}: undelivered dispatch on ({w},{s})");
            assert!(core.slots[w][s].fwd_q.is_empty(), "seed {seed}: stranded forward");
            assert!(core.slots[w][s].bwd_q.is_empty(), "seed {seed}: stranded backward");
        }
    }
    // the config must have actually exercised the pipeline
    assert!(!retired.is_empty(), "seed {seed}: nothing admitted");
}

#[test]
fn scheduler_invariants_hold_across_random_configs() {
    for seed in 0..60 {
        run_random(seed);
    }
}

/// Degenerate but legal configurations must also drain cleanly.
#[test]
fn scheduler_invariants_hold_at_the_edges() {
    // single worker, single stage, cap 1: maximal contention
    for seed in [1000, 2000, 3000] {
        run_random(seed);
    }
}
