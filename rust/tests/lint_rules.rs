//! ferret-lint rule fixtures: each rule family must fire on a minimal
//! injected violation, stay quiet on the equivalent sound construct,
//! and honor the inline allow mechanism. The final test runs the real
//! checker over the real tree — the same gate CI enforces.

use std::path::Path;

use ferret::analysis::{lint_source, lint_tree};

fn rules(path: &str, src: &str) -> Vec<String> {
    lint_source(path, src).into_iter().map(|f| f.rule.to_string()).collect()
}

// ---------------------------------------------------------------- layering

#[test]
fn layering_flags_edge_outside_the_dag() {
    let src = "use crate::harness::Bench;\n";
    let found = lint_source("planner/ilp.rs", src);
    assert_eq!(found.len(), 1, "{found:?}");
    assert_eq!(found[0].rule, "layering");
    assert_eq!(found[0].line, 1);
    assert!(found[0].msg.contains("`planner`"), "{}", found[0].msg);
    assert!(found[0].msg.contains("`harness`"), "{}", found[0].msg);
}

#[test]
fn layering_accepts_committed_edges_and_util() {
    let src = "use crate::config::AsyncCfg;\nuse crate::util::Rng;\nuse crate::bail;\n";
    assert!(rules("planner/ilp.rs", src).is_empty());
}

#[test]
fn layering_enforces_pipeline_sublayers() {
    // sched is the bottom of the pipeline stack: it must not see the engine
    let up = "use crate::pipeline::engine::Engine;\n";
    assert_eq!(rules("pipeline/sched.rs", up), ["layering"]);
    // and the engine may see sched + executor, in either import style
    let down = "use super::sched::EventHeap;\nuse crate::pipeline::executor::Executor;\n";
    assert!(rules("pipeline/engine.rs", down).is_empty());
}

#[test]
fn layering_ignores_bin_and_test_code() {
    let src = "use crate::harness::Bench;\n";
    assert!(rules("bin/tool.rs", src).is_empty());
    assert!(rules("main.rs", src).is_empty());
    let test_only = "#[cfg(test)]\nmod tests {\n    use crate::harness::Bench;\n}\n";
    assert!(rules("planner/ilp.rs", test_only).is_empty());
}

// ------------------------------------------------------------- determinism

#[test]
fn determinism_flags_maps_time_threads_rng_in_core() {
    let src = "use std::collections::HashMap;\n";
    assert_eq!(rules("planner/ilp.rs", src), ["det-map"]);
    let src = "fn f() { let t = std::time::Instant::now(); }\n";
    assert_eq!(rules("metrics/oacc.rs", src), ["det-time"]);
    let src = "fn f() { std::thread::spawn(|| {}); }\n";
    assert_eq!(rules("stream/generator.rs", src), ["det-thread"]);
    let src = "fn f() { let s = RandomState::new(); }\n";
    assert_eq!(rules("budget/shift.rs", src), ["det-rng"]);
}

#[test]
fn determinism_exempts_non_core_modules_and_the_executor() {
    let src = "use std::collections::HashMap;\nfn f() { std::thread::spawn(|| {}); }\n";
    // harness is outside the deterministic core entirely
    assert!(rules("harness/perf.rs", src).is_empty());
    // the executor owns device threads (but still may not use HashMap
    // without an allow)
    assert_eq!(rules("pipeline/executor.rs", src), ["det-map"]);
}

#[test]
fn determinism_ignores_names_inside_strings_and_comments() {
    let src = "// HashMap is banned here\nfn f() -> &'static str { \"Instant::now\" }\n";
    assert!(rules("planner/ilp.rs", src).is_empty());
}

// ------------------------------------------------------------ panic freedom

#[test]
fn panics_flag_unwrap_on_entry_surfaces_only() {
    let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    assert_eq!(rules("pipeline/engine.rs", src), ["entry-panic"]);
    assert_eq!(rules("trace/json.rs", src), ["entry-panic"]);
    // same code elsewhere is not an entry surface
    assert!(rules("backend/native.rs", src).is_empty());
}

#[test]
fn panics_flag_macros_and_qualified_unwrap() {
    let src = "fn f() { panic!(\"boom\"); }\n";
    assert_eq!(rules("pipeline/session.rs", src), ["entry-panic"]);
    let src = "fn f(v: Vec<Option<u32>>) -> Vec<u32> { v.into_iter().map(Option::unwrap).collect() }\n";
    assert_eq!(rules("pipeline/session.rs", src), ["entry-panic"]);
}

#[test]
fn panics_flag_unchecked_indexing_in_trace_only() {
    let src = "fn f(xs: &[u8]) -> u8 { xs[0] }\n";
    assert_eq!(rules("trace/json.rs", src), ["entry-index"]);
    // indexing outside the parser surface is not flagged
    assert!(rules("pipeline/engine.rs", src).is_empty());
    // array literals and attributes don't look like indexing
    let src = "#[derive(Clone)]\nstruct S;\nfn f() -> [u8; 2] { [1, 2] }\n";
    assert!(rules("trace/json.rs", src).is_empty());
}

// ------------------------------------------------------------ lock ordering

#[test]
fn locks_flag_unregistered_receivers() {
    let src = "fn f(m: &std::sync::Mutex<u32>) { let _ = m.lock(); }\n";
    let found = lint_source("backend/pool.rs", src);
    assert_eq!(found.len(), 1, "{found:?}");
    assert_eq!(found[0].rule, "lock-order");
    assert!(found[0].msg.contains("unregistered receiver `m`"), "{}", found[0].msg);
}

#[test]
fn locks_flag_inverted_acquisition_order() {
    // StageCell (level 2) then PluginCell (level 1) in one function:
    // inversion against the registered hierarchy
    let src = "fn f(&self) {\n    let a = self.inner.lock();\n    let b = self.plugin.lock();\n}\n";
    let found = lint_source("pipeline/executor.rs", src);
    assert_eq!(found.len(), 1, "{found:?}");
    assert_eq!(found[0].rule, "lock-order");
    assert_eq!(found[0].line, 3);
    assert!(found[0].msg.contains("PluginCell"), "{}", found[0].msg);
    // the same two locks in hierarchy order are clean
    let src = "fn f(&self) {\n    let b = self.plugin.lock();\n    let a = self.inner.lock();\n}\n";
    assert!(rules("pipeline/executor.rs", src).is_empty());
}

// ------------------------------------------------------------------ allows

#[test]
fn allow_suppresses_exactly_one_finding() {
    // two violations, one allow: only the annotated line is forgiven
    let src = "use std::collections::HashMap;\n\
               fn f() { let t = std::time::Instant::now(); }\n";
    let allowed = format!(
        "// ferret-lint: allow(det-map) \u{2014} lookup-only fixture, never iterated\n{src}"
    );
    assert_eq!(rules("planner/ilp.rs", src), ["det-map", "det-time"]);
    assert_eq!(rules("planner/ilp.rs", &allowed), ["det-time"]);
}

#[test]
fn allow_skips_continuation_comment_lines() {
    let src = "// ferret-lint: allow(det-map) -- the justification runs long\n\
               // and wraps onto a second comment line before the code\n\
               use std::collections::HashMap;\n";
    assert!(rules("planner/ilp.rs", src).is_empty());
}

#[test]
fn allow_without_reason_is_itself_a_finding() {
    let src = "// ferret-lint: allow(det-map)\nuse std::collections::HashMap;\n";
    let got = rules("planner/ilp.rs", src);
    assert_eq!(got, ["allow-missing-reason", "det-map"], "{got:?}");
}

// ------------------------------------------------------------ the real tree

#[test]
fn the_crate_sources_are_lint_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = lint_tree(&src).expect("source tree readable");
    let report: Vec<String> = findings
        .iter()
        .map(|(f, x)| format!("{f}:{}: {}: {}", x.line, x.rule, x.msg))
        .collect();
    assert!(findings.is_empty(), "ferret-lint findings:\n{}", report.join("\n"));
}
