//! Free-running wall-clock mode smoke tests.
//!
//! Freerun is not bit-deterministic (completions land at real times), so
//! these tests pin the *structural* guarantees — monotone event stamps,
//! no lost or duplicated jobs, drop accounting consistent with the
//! predict-only path — and band the learning outcome against a lockstep
//! run of the same seeded stream. Arrival pacing is set slow relative to
//! the tiny model's compute so runs stay fast and drop-free-ish on any
//! CI box.

use std::sync::{Arc, Mutex};

use ferret::backend::native::NativeBackend;
use ferret::compensate::CompKind;
use ferret::config::ModelSpec;
use ferret::model::SharedParams;
use ferret::ocl::{OclCtx, OclPlugin, Vanilla};
use ferret::pipeline::engine::{run_async_with, AsyncCfg, AsyncSchedule};
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::{EngineParams, RunResult, Session};
use ferret::planner::{plan, Partition, Profile};
use ferret::stream::{Batch, DriftKind, StreamSpec, SyntheticStream};

fn model() -> ModelSpec {
    ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
}

fn stream(n: usize, seed: u64) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "freerun".into(),
        features: 16,
        classes: 4,
        batch: 8,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 3.0,
        noise: 0.5,
        seed,
    })
}

/// A seeded Pipedream run; `td` is in ticks (freerun replays 1 tick = 1µs,
/// so 2000 keeps arrivals far slower than the µs-scale stage compute).
fn run(kind: ExecutorKind, mode: Mode, n: usize, td: u64) -> RunResult {
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, part, &prof, td);
    let ep = EngineParams { lr: 0.2, td, ..Default::default() };
    run_async_with(cfg, &mut stream(n, 31), &NativeBackend, &mut Vanilla, &ep, &m, kind, mode)
}

#[test]
fn freerun_loses_no_jobs_and_time_is_monotone() {
    let n = 60;
    let r = run(ExecutorKind::Threaded, Mode::Freerun, n, 2000);
    // every arriving batch predicted exactly once: none lost, none doubled
    assert_eq!(r.metrics.oacc.count() as u64, n as u64, "one prediction per arrival");
    assert_eq!(r.metrics.arrivals(), n as u64);
    // drop accounting consistent with predict_only: a dropped batch is
    // predicted but never reaches the loss head; an admitted one does
    assert_eq!(r.metrics.losses.len() as u64, n as u64 - r.metrics.dropped);
    assert!(r.metrics.trained > 0, "updates landed");
    // event timestamps stamped by the wall clock are non-decreasing
    for w in r.metrics.oacc.curve.windows(2) {
        assert!(w[0].0 <= w[1].0, "prediction stamps regressed: {} > {}", w[0].0, w[1].0);
    }
    for w in r.metrics.losses.windows(2) {
        assert!(w[0].0 <= w[1].0, "loss stamps regressed");
    }
    // observability: one latency sample per admitted batch, ordered
    // percentiles, and a populated staleness histogram
    assert_eq!(r.metrics.latencies.len() as u64, n as u64 - r.metrics.dropped);
    assert!(r.metrics.latency_percentile(50.0) <= r.metrics.latency_percentile(95.0));
    assert!(r.metrics.latency_percentile(95.0) <= r.metrics.latency_percentile(99.0));
    let hist_total: u64 = r.metrics.staleness_hist.iter().sum();
    assert!(hist_total > 0, "staleness histogram populated");
}

#[test]
fn freerun_accuracy_within_band_of_lockstep() {
    let n = 80;
    let lock = run(ExecutorKind::Sim, Mode::Lockstep, n, 2000);
    let free = run(ExecutorKind::Threaded, Mode::Freerun, n, 2000);
    let (lo, fo) = (lock.metrics.oacc.value(), free.metrics.oacc.value());
    // same seeded stream, same model: wall-clock jitter moves the online
    // accuracy, but not out of a broad band around the simulated run
    assert!((lo - fo).abs() < 25.0, "lockstep {lo:.1}% vs freerun {fo:.1}%");
    assert!(fo > 25.0, "freerun must still learn (got {fo:.1}%)");
}

#[test]
fn freerun_runs_on_the_sim_executor_too() {
    // inline executor under wall pacing: degenerate but legal (used by
    // planner smoke runs); completions drain immediately
    let n = 30;
    let r = run(ExecutorKind::Sim, Mode::Freerun, n, 1000);
    assert_eq!(r.metrics.oacc.count() as u64, n as u64);
    assert!(r.metrics.trained > 0);
    assert_eq!(r.metrics.exec_threads, 1);
}

/// Records which thread ran the `augment` hook; numerically a passthrough.
struct AugmentThreadProbe {
    seen: Arc<Mutex<Vec<std::thread::ThreadId>>>,
}

impl OclPlugin for AugmentThreadProbe {
    fn name(&self) -> &'static str {
        "probe"
    }

    fn augment(
        &mut self,
        batch: Batch,
        _params: &[SharedParams],
        _ctx: &OclCtx,
    ) -> Batch {
        self.seen.lock().expect("probe lock").push(std::thread::current().id());
        batch
    }
}

#[test]
fn freerun_augment_runs_on_a_device_thread_not_the_scheduler() {
    // An owned plugin + threaded freerun is the offload configuration: the
    // session converts the plugin into a shared cell and stage-0 device
    // threads run `augment` at dispatch. The scheduler thread (this test
    // thread) must never execute the hook.
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, part, &prof, 2000);
    let ep = EngineParams { lr: 0.2, td: 2000, ..Default::default() };
    let seen = Arc::new(Mutex::new(Vec::new()));
    let n = 40;
    let session = Session::builder(&NativeBackend, &m)
        .config(cfg)
        .owned_plugin(Box::new(AugmentThreadProbe { seen: Arc::clone(&seen) }))
        .engine_params(ep)
        .executor(ExecutorKind::Threaded)
        .mode(Mode::Freerun)
        .batch(8)
        .build()
        .expect("session builds");
    let r = session.run_stream(&mut stream(n, 11)).expect("stream runs");
    assert_eq!(r.metrics.oacc.count() as u64, n as u64, "no lost jobs under offload");
    assert!(r.metrics.trained > 0, "updates landed");
    let seen = seen.lock().expect("probe lock");
    // dropped batches are predicted only — they never reach the hook
    assert_eq!(seen.len() as u64, n as u64 - r.metrics.dropped, "one augment per admission");
    assert!(!seen.is_empty(), "augment hook ran");
    let scheduler = std::thread::current().id();
    for &tid in seen.iter() {
        assert_ne!(tid, scheduler, "augment ran on the scheduler thread");
    }
}

#[test]
fn freerun_planned_ferret_with_compensation_trains() {
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let td = prof.default_td();
    let unconstrained = plan(&prof, td, f64::INFINITY, 1e-4);
    let planned = plan(&prof, td, unconstrained.mem_bytes * 0.5, 1e-4);
    assert!(planned.feasible);
    let cfg = AsyncCfg::ferret(planned.partition, planned.config, CompKind::IterFisher);
    let ep = EngineParams { lr: 0.2, td: 1500, ..Default::default() };
    let r = run_async_with(
        cfg,
        &mut stream(60, 7),
        &NativeBackend,
        &mut Vanilla,
        &ep,
        &m,
        ExecutorKind::Threaded,
        Mode::Freerun,
    );
    assert_eq!(r.metrics.oacc.count() as u64, 60, "no lost jobs under compensation");
    assert!(r.metrics.trained > 0);
    assert!(r.metrics.exec_threads > 1);
}
