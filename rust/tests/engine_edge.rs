//! Engine edge cases and failure injection: overload drops, degenerate
//! configurations, single-stage pipelines, stash-eviction fallback, and
//! plugin integration through every engine.

use ferret::backend::native::NativeBackend;
use ferret::compensate::CompKind;
use ferret::config::ModelSpec;
use ferret::ocl::{OclKind, Vanilla};
use ferret::pipeline::engine::{run_async, AsyncCfg, AsyncSchedule};
use ferret::pipeline::sync::{run_sync, SyncSchedule};
use ferret::pipeline::EngineParams;
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, Partition, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn model() -> ModelSpec {
    ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
}

fn stream(n: usize, kind: DriftKind) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "edge".into(),
        features: 16,
        classes: 4,
        batch: 8,
        num_batches: n,
        kind,
        margin: 3.0,
        noise: 0.5,
        seed: 23,
    })
}

fn ep() -> EngineParams {
    EngineParams { lr: 0.1, seed: 23, ..Default::default() }
}

#[test]
fn overloaded_single_worker_drops_but_survives() {
    // one worker, arrivals 16x faster than it can train: most batches must
    // be dropped, none lost silently, and the run still completes.
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let mut cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, part, &prof, prof.default_td());
    cfg.pipe.workers.truncate(1);
    let fast = EngineParams { td: prof.default_td() / 16, ..ep() };
    let r = run_async(cfg, &mut stream(100, DriftKind::Stationary), &NativeBackend, &mut Vanilla, &fast, &m);
    assert!(r.metrics.dropped > 30, "dropped {}", r.metrics.dropped);
    assert_eq!(
        r.metrics.oacc.count() as u64,
        100,
        "every arrival must still be predicted"
    );
    assert!(r.metrics.trained > 0);
}

#[test]
fn zero_worker_config_predicts_only() {
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let mut cfg = AsyncCfg::baseline(AsyncSchedule::Ferret, part, &prof, prof.default_td());
    for w in &mut cfg.pipe.workers {
        w.delay = -1; // T4 everywhere
    }
    let r = run_async(cfg, &mut stream(40, DriftKind::Stationary), &NativeBackend, &mut Vanilla, &ep(), &m);
    assert_eq!(r.metrics.trained, 0);
    assert_eq!(r.metrics.dropped, 40);
    assert_eq!(r.metrics.mem_bytes, 0.0);
    // untrained model predicts at chance-ish
    assert!(r.metrics.oacc.value() < 60.0);
}

#[test]
fn single_stage_pipeline_works() {
    // P = 1 degenerates to async data-parallel workers; no staleness
    // across stages, still learns.
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::trivial(m.num_layers());
    let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, part, &prof, prof.default_td());
    let r = run_async(cfg, &mut stream(120, DriftKind::Stationary), &NativeBackend, &mut Vanilla, &ep(), &m);
    assert!(r.metrics.oacc.value() > 35.0, "oacc {}", r.metrics.oacc.value());
}

#[test]
fn tiny_stash_forces_eviction_fallback_without_crash() {
    // Pipedream2BW caps effective versions; with heavy accumulation and a
    // deep pipeline the delta chain is often evicted — Iter-Fisher must
    // fall back to the jump without panicking.
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let mut cfg = AsyncCfg::baseline(AsyncSchedule::Ferret, part, &prof, prof.default_td());
    cfg.comp_kind = CompKind::IterFisher;
    for w in &mut cfg.pipe.workers {
        w.accum = vec![4; 3];
    }
    let r = run_async(cfg, &mut stream(80, DriftKind::Stationary), &NativeBackend, &mut Vanilla, &ep(), &m);
    assert!(r.metrics.trained > 0);
}

#[test]
fn sync_engine_handles_trickle_and_burst() {
    // arrivals far slower than a flight: no drops, every batch trained
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let slow = EngineParams { td: prof.default_td() * 64, ..ep() };
    let r = run_sync(
        SyncSchedule::Dapple,
        &mut stream(40, DriftKind::Stationary),
        &NativeBackend,
        &mut Vanilla,
        &slow,
        &m,
        &part,
    );
    assert_eq!(r.metrics.dropped, 0);
    assert_eq!(r.metrics.trained, 40);
    // burst: arrivals far faster than flights -> drops, but all predicted
    let fast = EngineParams { td: 1, ..ep() };
    let r = run_sync(
        SyncSchedule::Dapple,
        &mut stream(60, DriftKind::Stationary),
        &NativeBackend,
        &mut Vanilla,
        &fast,
        &m,
        &part,
    );
    assert!(r.metrics.dropped > 0);
    assert_eq!(r.metrics.oacc.count() as u64, 60);
}

#[test]
fn class_incremental_forgetting_is_visible_and_er_mitigates() {
    // Vanilla on a 4-task split should lose early-task tacc; ER recovers
    // a meaningful share (the Table 2 mechanism, engine-level).
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let kind = DriftKind::ClassIncremental { tasks: 4 };
    let run = |ocl: OclKind| {
        let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, part.clone(), &prof, prof.default_td());
        let mut plugin = ocl.build(23);
        run_async(cfg, &mut stream(160, kind), &NativeBackend, plugin.as_mut(), &ep(), &m)
    };
    let vanilla = run(OclKind::Vanilla);
    let er = run(OclKind::Er);
    assert!(
        er.metrics.tacc >= vanilla.metrics.tacc,
        "ER tacc {} < vanilla {}",
        er.metrics.tacc,
        vanilla.metrics.tacc
    );
}

#[test]
fn planner_output_always_runnable() {
    // every feasible plan across a budget sweep must produce a working
    // engine configuration (property-style over budgets).
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let td = prof.default_td();
    let decay = decay_for_td(td);
    let hi = plan(&prof, td, f64::INFINITY, decay).mem_bytes;
    for frac in [0.05, 0.2, 0.5, 1.0] {
        let out = plan(&prof, td, hi * frac, decay);
        let cfg = AsyncCfg::ferret(out.partition.clone(), out.config.clone(), CompKind::IterFisher);
        let mut s = stream(30, DriftKind::Stationary);
        let r = run_async(cfg, &mut s, &NativeBackend, &mut Vanilla, &ep(), &m);
        assert_eq!(r.metrics.oacc.count() as u64, 30, "frac {frac}");
    }
}

#[test]
fn temporal_and_covariate_streams_run_through_all_engines() {
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    for kind in [DriftKind::Temporal { dwell: 5 }, DriftKind::Covariate { cycles: 1.0 }] {
        let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream, part.clone(), &prof, prof.default_td());
        let r = run_async(cfg, &mut stream(50, kind), &NativeBackend, &mut Vanilla, &ep(), &m);
        assert!(r.metrics.trained > 0, "{kind:?}");
        let r = run_sync(
            SyncSchedule::ZeroBubble,
            &mut stream(50, kind),
            &NativeBackend,
            &mut Vanilla,
            &ep(),
            &m,
            &part,
        );
        assert!(r.metrics.trained > 0, "{kind:?}");
    }
}
