//! Trace record → replay: the determinism contract, end to end.
//!
//!   - a lockstep run recorded on either executor replays bit-for-bit
//!     (every diff field zero), including through a `--budget-schedule`
//!     re-plan — and an executor override still replays bit-for-bit
//!     (lockstep executor equivalence, now pinned through the artifact);
//!   - a perturbed configuration produces a structurally nonzero diff
//!     that trips the strict gate;
//!   - the artifact round-trips serialization line-for-line.

use ferret::backend::native::NativeBackend;
use ferret::budget::BudgetSchedule;
use ferret::compensate::CompKind;
use ferret::config::ModelSpec;
use ferret::ocl::Vanilla;
use ferret::pipeline::engine::AsyncCfg;
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::{EngineParams, RunResult, Session};
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};
use ferret::trace::{replay_trace, Event, GateThresholds, Trace, TraceWriter};

fn model() -> ModelSpec {
    ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
}

fn stream(n: usize, seed: u64) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "trace-replay".into(),
        features: 16,
        classes: 4,
        batch: 8,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 3.0,
        noise: 0.5,
        seed,
    })
}

/// A memory-constrained Ferret plan (stashing + compensation in play).
fn planned_cfg(m: &ModelSpec) -> AsyncCfg {
    let prof = Profile::analytic(m, 8);
    let td = prof.default_td();
    let unconstrained = plan(&prof, td, f64::INFINITY, 1e-4);
    let out = plan(&prof, td, unconstrained.mem_bytes * 0.5, 1e-4);
    AsyncCfg::ferret(out.partition, out.config, CompKind::IterFisher)
}

/// Record a run into an in-memory trace; returns the parsed trace and the
/// run's own result.
fn record_run(
    n: usize,
    budget: Option<BudgetSchedule>,
    kind: ExecutorKind,
) -> (Trace, RunResult) {
    let m = model();
    let mut src = stream(n, 31);
    let (writer, lines) = TraceWriter::in_memory();
    let mut builder = Session::builder(&NativeBackend, &m)
        .config(planned_cfg(&m))
        .owned_plugin(Box::new(Vanilla))
        .engine_params(EngineParams { lr: 0.2, ..Default::default() })
        .executor(kind)
        .mode(Mode::Lockstep)
        .batch(8)
        .record_trace_writer(writer);
    if let Some(b) = budget {
        builder = builder.budget(b);
    }
    let r = builder
        .build()
        .expect("session")
        .run_stream(&mut src)
        .expect("stream matches the model");
    let text = lines.lock().unwrap().join("\n");
    (Trace::parse(&text).expect("recorded trace parses"), r)
}

/// Half the model's unconstrained planner footprint — a budget step that
/// genuinely forces a tighter plan.
fn half_footprint() -> f64 {
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let td = prof.default_td();
    plan(&prof, td, f64::INFINITY, 1e-4).mem_bytes * 0.5
}

#[test]
fn recorded_run_replays_bit_for_bit_on_both_executors() {
    for kind in [ExecutorKind::Sim, ExecutorKind::Threaded] {
        let (recorded, r) = record_run(24, None, kind);
        assert_eq!(recorded.batches().len(), 24, "{kind:?}: every arrival recorded");
        assert!(recorded.stream.is_some(), "{kind:?}: provenance recorded");
        let fin = recorded.finish.as_ref().expect("finish record");
        assert_eq!(fin.oacc, r.metrics.oacc.value(), "{kind:?}: finish mirrors metrics");
        assert_eq!(fin.trained, r.metrics.trained);

        let outcome = replay_trace(&recorded, &[]).expect("replay runs");
        assert!(
            outcome.diff.is_zero(),
            "{kind:?}: replay must be bit-for-bit, got {:?}",
            outcome.diff
        );
        assert_eq!(outcome.replayed.finish, recorded.finish, "{kind:?}: identical outcome");
        assert!(outcome.diff.violations(&GateThresholds::default()).is_empty());
    }
}

#[test]
fn executor_override_preserves_bit_for_bit_replay() {
    // recorded under sim; replayed under threaded — lockstep executor
    // equivalence, pinned through the trace artifact
    let (recorded, _) = record_run(20, None, ExecutorKind::Sim);
    let overrides = vec![("executor".to_string(), "threaded".to_string())];
    let outcome = replay_trace(&recorded, &overrides).expect("replay runs");
    assert!(outcome.diff.is_zero(), "executor variance must not leak: {:?}", outcome.diff);
    assert_eq!(outcome.replayed.header.executor, "threaded");
}

#[test]
fn replay_reproduces_a_budget_schedule_replan_exactly() {
    let sched = BudgetSchedule::step_at_batch(12, half_footprint());
    let (recorded, r) = record_run(24, Some(sched), ExecutorKind::Sim);
    assert!(r.metrics.replans >= 1, "the schedule step must have re-planned");
    let replans = recorded.replans();
    assert_eq!(replans.len() as u64, r.metrics.replans, "every replan recorded");
    assert!(
        recorded.batches().iter().any(|b| b.held),
        "the batch at the budget boundary is recorded as held"
    );

    let outcome = replay_trace(&recorded, &[]).expect("replay runs");
    assert!(outcome.diff.is_zero(), "replan must replay exactly: {:?}", outcome.diff);
    // the planner decisions themselves are reproduced, not just the
    // end-of-run metrics
    let replayed_replans = outcome.replayed.replans();
    assert_eq!(replayed_replans.len(), replans.len());
    for (a, b) in replans.iter().zip(&replayed_replans) {
        assert_eq!(a.plan_id, b.plan_id, "same plan chosen at the same point");
        assert_eq!(a.partition, b.partition);
        assert_eq!(a.t, b.t, "transition lands at the same tick");
        assert_eq!(a.tf, b.tf, "same measured means seeded the planner");
    }
}

#[test]
fn perturbed_config_produces_a_nonzero_diff_and_trips_the_gate() {
    let (recorded, _) = record_run(24, None, ExecutorKind::Sim);
    assert_eq!(recorded.replans().len(), 0, "baseline: no replans recorded");
    // inject a mid-stream budget squeeze the recording never had: the
    // replay must re-plan at batch 12, which the diff reports as both a
    // replan-count delta and plan churn
    let overrides =
        vec![("budget-schedule".to_string(), format!("{}b@b12", half_footprint()))];
    let outcome = replay_trace(&recorded, &overrides).expect("replay runs");
    let d = &outcome.diff;
    assert!(!d.is_zero(), "a perturbed config must not diff to zero");
    assert!(d.replan_delta >= 1, "injected budget step re-plans: {d:?}");
    assert!(d.plan_churn >= 1, "extra plan shows up as churn: {d:?}");
    let violations = d.violations(&GateThresholds::default());
    assert!(!violations.is_empty(), "strict gate must trip");
}

#[test]
fn recorded_artifact_round_trips_serialization() {
    let sched = BudgetSchedule::step_at_batch(12, half_footprint());
    let (recorded, _) = record_run(24, Some(sched), ExecutorKind::Sim);
    let lines = recorded.to_lines();
    let reparsed = Trace::parse(&lines.join("\n")).expect("round-trip parse");
    assert_eq!(reparsed, recorded);
    assert_eq!(reparsed.to_lines(), lines, "line-for-line stable");
    // events preserve the batch/replan interleaving (a replan sits between
    // batch records, not appended at the end)
    let first_replan = recorded
        .events
        .iter()
        .position(|e| matches!(e, Event::Replan(_)))
        .expect("has a replan");
    assert!(first_replan < recorded.events.len() - 1, "replan recorded in stream order");
}
