//! Session API: the push-based surface must be a *behavior-preserving*
//! re-packaging of the historical run-to-completion entry point.
//!
//!   - lockstep `ingest`/`step`/`finish` (any drive style) reproduces
//!     `run_async_with`'s `RunMetrics` exactly, on both executors, with
//!     and without a dynamic budget schedule;
//!   - `set_budget` mid-stream triggers the drain → re-plan → transition
//!     protocol imperatively;
//!   - dropping a session without `finish` joins its device threads;
//!   - the builder rejects broken configurations with typed errors
//!     instead of engine panics.

use ferret::backend::native::NativeBackend;
use ferret::budget::BudgetSchedule;
use ferret::compensate::CompKind;
use ferret::config::ModelSpec;
use ferret::ocl::{OclKind, Vanilla};
use ferret::pipeline::engine::{run_async_with, AsyncCfg};
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::{EngineParams, RunResult, Session, SessionStep};
use ferret::planner::{plan, Partition, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn model() -> ModelSpec {
    ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
}

fn stream(n: usize, seed: u64) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "session".into(),
        features: 16,
        classes: 4,
        batch: 8,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 3.0,
        noise: 0.5,
        seed,
    })
}

/// A planned Ferret config at half the unconstrained footprint —
/// exercises stashing, compensation, and multi-stage scheduling.
fn planned_cfg(m: &ModelSpec) -> AsyncCfg {
    let prof = Profile::analytic(m, 8);
    let td = prof.default_td();
    let unconstrained = plan(&prof, td, f64::INFINITY, 1e-4);
    let out = plan(&prof, td, unconstrained.mem_bytes * 0.5, 1e-4);
    AsyncCfg::ferret(out.partition, out.config, CompKind::IterFisher)
}

/// Every metric the harness consumes, plus final weights, plus the
/// dynamic-budget observables — bit-identical.
fn assert_runs_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.metrics.oacc.value(), b.metrics.oacc.value(), "{what}: oacc");
    assert_eq!(a.metrics.oacc.curve, b.metrics.oacc.curve, "{what}: oacc curve");
    assert_eq!(a.metrics.losses, b.metrics.losses, "{what}: loss curve");
    assert_eq!(a.metrics.trained, b.metrics.trained, "{what}: trained");
    assert_eq!(a.metrics.dropped, b.metrics.dropped, "{what}: dropped");
    assert_eq!(a.metrics.arrivals(), b.metrics.arrivals(), "{what}: arrivals");
    assert_eq!(a.metrics.mem_bytes, b.metrics.mem_bytes, "{what}: mem");
    assert_eq!(a.metrics.peak_live_bytes, b.metrics.peak_live_bytes, "{what}: live bytes");
    assert_eq!(a.metrics.latencies, b.metrics.latencies, "{what}: latencies");
    assert_eq!(a.metrics.staleness_hist, b.metrics.staleness_hist, "{what}: staleness");
    assert_eq!(a.metrics.tacc, b.metrics.tacc, "{what}: tacc");
    assert_eq!(a.metrics.adaptation_rate(), b.metrics.adaptation_rate(), "{what}: adaptation");
    assert_eq!(a.metrics.replans, b.metrics.replans, "{what}: replans");
    assert_eq!(a.metrics.drains, b.metrics.drains, "{what}: drains");
    assert_eq!(a.metrics.plan_trace, b.metrics.plan_trace, "{what}: plan trace");
    assert_eq!(a.metrics.ledger.trace, b.metrics.ledger.trace, "{what}: ledger trace");
    assert_eq!(a.metrics.ledger.peak_total, b.metrics.ledger.peak_total, "{what}: ledger peak");
    assert_eq!(a.metrics.ledger.last, b.metrics.ledger.last, "{what}: ledger end");
    assert_eq!(a.params.len(), b.params.len(), "{what}: layer count");
    for (i, (pa, pb)) in a.params.iter().zip(&b.params).enumerate() {
        assert_eq!(pa.w, pb.w, "{what}: layer {i} weights");
        assert_eq!(pa.b, pb.b, "{what}: layer {i} bias");
    }
}

/// How a test drives the push-based session.
#[derive(Clone, Copy)]
enum Drive {
    /// ingest one batch, step until starved, repeat — the live shape
    StepPerBatch,
    /// ingest the whole stream up front, then finish — the batch shape
    IngestAll,
}

fn run_session(
    cfg: AsyncCfg,
    budget: Option<BudgetSchedule>,
    n: usize,
    kind: ExecutorKind,
    drive: Drive,
) -> RunResult {
    let m = model();
    let mut src = stream(n, 31);
    let mut plugin = Vanilla;
    let ep = EngineParams { lr: 0.2, ..Default::default() };
    let mut builder = Session::builder(&NativeBackend, &m)
        .config(cfg)
        .plugin(&mut plugin)
        .engine_params(ep)
        .executor(kind)
        .mode(Mode::Lockstep)
        .batch(8)
        .test_set(src.test_set(ep.tacc_per_class));
    if let Some(b) = budget {
        builder = builder.budget(b);
    }
    let mut session = builder.build().expect("valid config");
    match drive {
        Drive::StepPerBatch => {
            while let Some(b) = src.next_batch() {
                session.ingest(b).expect("well-formed batch");
                while session.step().expect("session step") == SessionStep::Progressed {}
            }
        }
        Drive::IngestAll => {
            while let Some(b) = src.next_batch() {
                session.ingest(b).expect("well-formed batch");
            }
        }
    }
    session.finish().expect("session finish")
}

#[test]
fn lockstep_session_is_metric_identical_to_run_async_with() {
    let m = model();
    let n = 80;
    for kind in [ExecutorKind::Sim, ExecutorKind::Threaded] {
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        let legacy = run_async_with(
            planned_cfg(&m),
            &mut stream(n, 31),
            &NativeBackend,
            &mut Vanilla,
            &ep,
            &m,
            kind,
            Mode::Lockstep,
        );
        assert!(legacy.metrics.trained > 0);
        for drive in [Drive::StepPerBatch, Drive::IngestAll] {
            let r = run_session(planned_cfg(&m), None, n, kind, drive);
            assert_runs_identical(&legacy, &r, "planned ferret");
        }
    }
}

/// Same equivalence through a mid-stream budget halving: the session's
/// drain → re-plan → transition (including `Executor::reconfigure` of the
/// owned device threads) replays the pull loop exactly.
#[test]
fn session_replans_identically_under_a_budget_schedule() {
    let m = model();
    let n = 120;
    let prof = Profile::analytic(&m, 8);
    let td = prof.default_td();
    let hi = plan(&prof, td, f64::INFINITY, 1e-4);
    let sched = BudgetSchedule::step_at_batch(60, hi.mem_bytes * 0.5);
    let mk = || AsyncCfg::ferret(hi.partition.clone(), hi.config.clone(), CompKind::IterFisher);
    for kind in [ExecutorKind::Sim, ExecutorKind::Threaded] {
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        let legacy = run_async_with(
            mk().with_budget(sched.clone()),
            &mut stream(n, 31),
            &NativeBackend,
            &mut Vanilla,
            &ep,
            &m,
            kind,
            Mode::Lockstep,
        );
        assert!(legacy.metrics.replans >= 1, "schedule step must fire");
        for drive in [Drive::StepPerBatch, Drive::IngestAll] {
            let r = run_session(mk(), Some(sched.clone()), n, kind, drive);
            assert_runs_identical(&legacy, &r, "budget halving");
        }
    }
}

/// Plugin-stateful equivalence (ER replays from a seeded buffer) — the
/// session must thread the plugin hooks through identically.
#[test]
fn session_matches_legacy_with_a_stateful_plugin() {
    let m = model();
    let n = 60;
    let ep = EngineParams { lr: 0.2, ..Default::default() };
    let mut legacy_plugin = OclKind::Er.build(23);
    let legacy = run_async_with(
        planned_cfg(&m),
        &mut stream(n, 9),
        &NativeBackend,
        legacy_plugin.as_mut(),
        &ep,
        &m,
        ExecutorKind::Sim,
        Mode::Lockstep,
    );
    let mut src = stream(n, 9);
    let mut plugin = OclKind::Er.build(23);
    let mut session = Session::builder(&NativeBackend, &m)
        .config(planned_cfg(&m))
        .plugin(plugin.as_mut())
        .engine_params(ep)
        .batch(8)
        .test_set(src.test_set(ep.tacc_per_class))
        .build()
        .expect("valid config");
    while let Some(b) = src.next_batch() {
        session.ingest(b).expect("well-formed batch");
        session.drain().expect("drain");
    }
    let r = session.finish().expect("session finish");
    assert_runs_identical(&legacy, &r, "ER plugin");
}

/// Imperative `set_budget` mid-stream: same drain/re-plan/transition
/// protocol as a schedule step, triggered by a method call.
#[test]
fn set_budget_mid_stream_drains_and_replans() {
    let m = model();
    let n = 160usize;
    let prof = Profile::analytic(&m, 8);
    let td = prof.default_td();
    let hi = plan(&prof, td, f64::INFINITY, 1e-4);
    assert!(hi.partition.num_stages() >= 2);
    let budget = hi.mem_bytes * 0.5;
    let cfg = AsyncCfg::ferret(hi.partition.clone(), hi.config.clone(), CompKind::NoComp);
    let mut src = stream(n, 31);
    let mut plugin = Vanilla;
    let ep = EngineParams { lr: 0.2, ..Default::default() };
    let mut session = Session::builder(&NativeBackend, &m)
        .config(cfg)
        .plugin(&mut plugin)
        .engine_params(ep)
        .batch(8)
        .test_set(src.test_set(ep.tacc_per_class))
        .build()
        .expect("valid config");
    for i in 0..n {
        if i == n / 2 {
            // invalid budgets are rejected with a typed error, no state change
            assert!(session.set_budget(f64::NAN).is_err());
            assert!(session.set_budget(-1.0).is_err());
            session.set_budget(budget).expect("valid budget");
            // the drain arms now; live metrics stay readable throughout
            assert_eq!(session.metrics().replans, 0, "transition waits for the drain");
        }
        session.ingest(src.next_batch().expect("stream batch")).expect("well-formed batch");
        session.drain().expect("drain");
    }
    let mid_trained = session.metrics().trained;
    assert!(mid_trained > 0, "live metrics observable before finish");
    let r = session.finish().expect("session finish");
    // zero batches lost across the imperative transition
    assert_eq!(r.metrics.arrivals(), n as u64);
    assert_eq!(r.metrics.oacc.count() as u64, n as u64, "one prediction per arrival");
    assert!(r.metrics.replans >= 1, "set_budget must re-plan");
    assert_eq!(r.metrics.drains.len() as u64, r.metrics.replans);
    // switching to dynamic accounting turns the ledger trace on
    assert!(!r.metrics.ledger.trace.is_empty(), "per-update ledger trace recorded");
    let final_bytes = r.metrics.ledger.last.total() as f64;
    assert!(
        final_bytes <= budget,
        "final ledger {final_bytes} B > imperative budget {budget} B ({:?})",
        r.metrics.ledger.last
    );
}

/// A session dropped without `finish` must join its device threads (the
/// executor owns them; drop closes the task channels and joins). A hang
/// here fails the suite by timeout; repeated drops also catch leaks that
/// would deadlock a later spawn.
#[test]
fn dropping_a_threaded_session_joins_device_threads() {
    let m = model();
    for round in 0..3u64 {
        let mut src = stream(40, round + 1);
        let mut plugin = Vanilla;
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        let mut session = Session::builder(&NativeBackend, &m)
            .config(planned_cfg(&m))
            .plugin(&mut plugin)
            .engine_params(ep)
            .executor(ExecutorKind::Threaded)
            .batch(8)
            .build()
            .expect("valid config");
        // leave work genuinely in flight: ingest several batches and stop
        // stepping midway through the pipeline
        for _ in 0..10 {
            session.ingest(src.next_batch().expect("batch")).expect("well-formed batch");
        }
        for _ in 0..7 {
            let _ = session.step();
        }
        assert!(session.metrics().arrivals() > 0, "mid-flight state reached");
        drop(session); // must return promptly, device threads joined
    }
}

fn err(b: ferret::util::error::Result<Session<'_>>, needle: &str) {
    let e = b.err().expect("expected a config error").to_string();
    assert!(e.contains(needle), "error `{e}` should mention `{needle}`");
}

#[test]
fn builder_rejects_broken_configs_with_typed_errors() {
    let m = model();
    // batch rows are mandatory
    err(Session::builder(&NativeBackend, &m).build(), "batch");
    // negative learning rate (lr == 0 stays legal: frozen-weights runs)
    err(
        Session::builder(&NativeBackend, &m)
            .batch(8)
            .engine_params(EngineParams { lr: -0.5, ..Default::default() })
            .build(),
        "learning rate",
    );
    // partition that does not cover the model
    let mut cfg = planned_cfg(&m);
    cfg.partition = Partition::per_layer(2);
    err(Session::builder(&NativeBackend, &m).batch(8).config(cfg).build(), "partition");
    // empty hand-built partition: typed error, not a debug-build underflow
    let mut cfg = planned_cfg(&m);
    cfg.partition = Partition { bounds: vec![] };
    err(Session::builder(&NativeBackend, &m).batch(8).config(cfg).build(), "partition");
    // no workers at all
    let mut cfg = planned_cfg(&m);
    cfg.pipe.workers.clear();
    err(Session::builder(&NativeBackend, &m).batch(8).config(cfg).build(), "workers");
    // zero accumulation count (would divide by zero in the engine)
    let mut cfg = planned_cfg(&m);
    cfg.pipe.workers[0].accum[0] = 0;
    err(Session::builder(&NativeBackend, &m).batch(8).config(cfg).build(), "accumulation");
    // zero plugin cadence (would take `x % 0`)
    let mut cfg = planned_cfg(&m);
    cfg.plugin_cadence = 0;
    err(Session::builder(&NativeBackend, &m).batch(8).config(cfg).build(), "cadence");
}

/// A misshapen hand-fed batch is rejected at `ingest` with a typed error
/// (not queued), instead of panicking later inside backend math.
#[test]
fn ingest_rejects_misshapen_batches() {
    use ferret::stream::Batch;
    let m = model();
    let mut session = Session::builder(&NativeBackend, &m)
        .config(planned_cfg(&m))
        .batch(8)
        .build()
        .expect("valid config");
    // 1 row but only 7 of 16 features
    let e = session.ingest(Batch { id: 0, x: vec![0.0; 7], y: vec![0] });
    assert!(e.unwrap_err().to_string().contains("features"));
    // more rows than the session's batch size
    let e = session.ingest(Batch { id: 1, x: vec![0.0; 16 * 9], y: vec![0; 9] });
    assert!(e.unwrap_err().to_string().contains("rows"));
    // empty batch
    assert!(session.ingest(Batch { id: 2, x: vec![], y: vec![] }).is_err());
    assert_eq!(session.backlog(), 0, "rejected batches are not queued");
    // a well-formed batch still flows end to end
    session
        .ingest(Batch { id: 3, x: vec![0.1; 16 * 8], y: vec![1; 8] })
        .expect("well-formed batch");
    session.drain().expect("drain");
    let r = session.finish().expect("session finish");
    assert_eq!(r.metrics.arrivals(), 1);
}

/// The builder's auto-planned default config (no `.config()`) runs.
#[test]
fn default_config_auto_plans_and_learns() {
    let m = model();
    let r = Session::builder(&NativeBackend, &m)
        .engine_params(EngineParams { lr: 0.2, ..Default::default() })
        .batch(8)
        .build()
        .expect("auto-planned config")
        .run_stream(&mut stream(80, 31))
        .expect("stream matches the model");
    assert_eq!(r.metrics.arrivals(), 80);
    assert!(r.metrics.trained > 0);
    assert!(r.metrics.oacc.value() > 30.0, "oacc {}", r.metrics.oacc.value());
    assert!(r.metrics.tacc > 50.0, "tacc {}", r.metrics.tacc);
}

/// A freerun session driven by ingest + finish keeps the freerun
/// structural guarantees (no lost or doubled jobs).
#[test]
fn freerun_session_loses_no_jobs() {
    let m = model();
    let n = 40u64;
    // per-layer PipeDream: every (worker, stage) device is active, so the
    // threaded session provably spawns real device threads
    let prof = Profile::analytic(&m, 8);
    let cfg = AsyncCfg::baseline(
        ferret::pipeline::engine::AsyncSchedule::Pipedream,
        Partition::per_layer(m.num_layers()),
        &prof,
        2000,
    );
    let mut src = stream(n as usize, 31);
    let mut plugin = Vanilla;
    // td 2000 ticks = 2000µs arrivals: far slower than the tiny model's
    // per-stage compute, so the run stays fast and mostly drop-free
    let ep = EngineParams { lr: 0.2, td: 2000, ..Default::default() };
    let mut session = Session::builder(&NativeBackend, &m)
        .config(cfg)
        .plugin(&mut plugin)
        .engine_params(ep)
        .executor(ExecutorKind::Threaded)
        .mode(Mode::Freerun)
        .batch(8)
        .test_set(src.test_set(ep.tacc_per_class))
        .build()
        .expect("valid config");
    while let Some(b) = src.next_batch() {
        session.ingest(b).expect("well-formed batch");
    }
    let r = session.finish().expect("session finish");
    assert_eq!(r.metrics.arrivals(), n);
    assert_eq!(r.metrics.oacc.count() as u64, n, "one prediction per arrival");
    assert_eq!(r.metrics.losses.len() as u64, n - r.metrics.dropped);
    assert!(r.metrics.trained > 0);
    assert!(r.metrics.exec_threads > 1, "session owns real device threads");
}

/// `run_stream` surfaces a misshapen batch from the stream as a typed
/// error to the caller — regression for the `.expect()` that used to
/// take the whole process down — and drops the session cleanly (device
/// threads joined) on the error path of both executors.
#[test]
fn run_stream_surfaces_bad_shape_streams_as_errors() {
    use ferret::stream::{Batch, Stream, TestSet};

    /// Two well-formed batches, then one whose data does not match its
    /// row count.
    struct BadStream {
        emitted: usize,
        inner: SyntheticStream,
    }
    impl Stream for BadStream {
        fn next_batch(&mut self) -> Option<Batch> {
            self.emitted += 1;
            match self.emitted {
                1 | 2 => self.inner.next_batch(),
                3 => Some(Batch { id: 99, x: vec![0.0; 5], y: vec![0; 8] }),
                _ => None,
            }
        }
        fn test_set(&self, per_class: usize) -> TestSet {
            self.inner.test_set(per_class)
        }
    }

    let m = model();
    for kind in [ExecutorKind::Sim, ExecutorKind::Threaded] {
        let mut bad = BadStream { emitted: 0, inner: stream(8, 31) };
        let session = Session::builder(&NativeBackend, &m)
            .config(planned_cfg(&m))
            .executor(kind)
            .batch(8)
            .build()
            .expect("valid config");
        let e = session.run_stream(&mut bad).unwrap_err().to_string();
        assert!(e.contains("features"), "{kind:?}: typed shape error, got: {e}");
    }
}
