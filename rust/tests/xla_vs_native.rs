//! Integration: the XLA/PJRT backend (AOT Pallas artifacts) must agree
//! with the pure-rust NativeBackend to float tolerance on every op, at
//! full artifact batch and at padded (smaller) batches.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use ferret::backend::{accuracy, backward_all, forward_all, Backend};
use ferret::backend::{native::NativeBackend, xla::XlaBackend};
use ferret::config::{zoo::default_zoo, LayerShape};
use ferret::model::{GradBuf, LayerParams, ModelParams};
use ferret::util::Rng;

fn open_xla() -> Option<XlaBackend> {
    match XlaBackend::open_default() {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("SKIP: artifacts unavailable ({e:#}); run `make artifacts`");
            None
        }
    }
}

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: xla={x} native={y}"
        );
    }
}

#[test]
fn dense_fwd_bwd_match_native_on_zoo_shapes() {
    let Some(xla) = open_xla() else { return };
    let native = NativeBackend;
    let zoo = default_zoo().unwrap();
    let batch = xla.runtime().batch();
    let mut rng = Rng::new(100);
    // spot-check a handful of real zoo shapes (full grid takes minutes)
    let shapes = zoo.distinct_layer_shapes();
    let picks: Vec<&LayerShape> = shapes.iter().step_by(3).collect();
    for shape in picks {
        let p = LayerParams::init(shape, &mut rng);
        let x = randvec(&mut rng, batch * shape.in_dim);
        let g = randvec(&mut rng, batch * shape.out_dim);
        let yx = xla.dense_fwd(shape, &p, &x, batch);
        let yn = native.dense_fwd(shape, &p, &x, batch);
        assert_close(&yx, &yn, 1e-4, &format!("fwd {shape:?}"));
        let bx = xla.dense_bwd(shape, &p, &x, &g, batch);
        let bn = native.dense_bwd(shape, &p, &x, &g, batch);
        assert_close(&bx.gx, &bn.gx, 1e-3, &format!("bwd.gx {shape:?}"));
        assert_close(&bx.grads.gw, &bn.grads.gw, 1e-3, &format!("bwd.gw {shape:?}"));
        assert_close(&bx.grads.gb, &bn.grads.gb, 1e-3, &format!("bwd.gb {shape:?}"));
    }
}

#[test]
fn loss_heads_match_native() {
    let Some(xla) = open_xla() else { return };
    let native = NativeBackend;
    let zoo = default_zoo().unwrap();
    let batch = xla.runtime().batch();
    let mut rng = Rng::new(200);
    for &classes in zoo.distinct_class_counts().iter().take(3) {
        let logits = randvec(&mut rng, batch * classes);
        let teacher = randvec(&mut rng, batch * classes);
        let labels: Vec<i32> = (0..batch).map(|_| rng.below(classes) as i32).collect();
        let (gx, lx) = xla.loss_grad_ce(classes, &logits, &labels);
        let (gn, ln) = native.loss_grad_ce(classes, &logits, &labels);
        assert_close(&gx, &gn, 1e-4, "ce grad");
        assert!((lx - ln).abs() < 1e-4, "ce loss {lx} vs {ln}");
        let (gx, lx) = xla.loss_grad_lwf(classes, &logits, &labels, &teacher, 0.4);
        let (gn, ln) = native.loss_grad_lwf(classes, &logits, &labels, &teacher, 0.4);
        assert_close(&gx, &gn, 1e-4, "lwf grad");
        assert!((lx - ln).abs() < 1e-4, "lwf loss {lx} vs {ln}");
    }
}

#[test]
fn compensate_and_sgd_match_native() {
    let Some(xla) = open_xla() else { return };
    let native = NativeBackend;
    let zoo = default_zoo().unwrap();
    let shape = zoo.distinct_layer_shapes()[0];
    let mut rng = Rng::new(300);
    let g = GradBuf {
        gw: randvec(&mut rng, shape.in_dim * shape.out_dim),
        gb: randvec(&mut rng, shape.out_dim),
    };
    let d = GradBuf {
        gw: randvec(&mut rng, shape.in_dim * shape.out_dim),
        gb: randvec(&mut rng, shape.out_dim),
    };
    let cx = xla.compensate(&g, &d, 0.2);
    let cn = native.compensate(&g, &d, 0.2);
    assert_close(&cx.gw, &cn.gw, 1e-5, "compensate gw");
    assert_close(&cx.gb, &cn.gb, 1e-5, "compensate gb");
    let p = LayerParams::init(&shape, &mut rng);
    let px = xla.sgd(&p, &g, 0.01);
    let pn = native.sgd(&p, &g, 0.01);
    assert_close(&px.w, &pn.w, 1e-6, "sgd w");
    assert_close(&px.b, &pn.b, 1e-6, "sgd b");
}

#[test]
fn padded_batch_matches_native() {
    let Some(xla) = open_xla() else { return };
    let native = NativeBackend;
    let zoo = default_zoo().unwrap();
    let spec = zoo.model("mlp").unwrap();
    let shapes = spec.layers();
    let mut rng = Rng::new(400);
    let p = ModelParams::init(spec, 4);
    for batch in [1usize, 5, 16] {
        let x = randvec(&mut rng, batch * spec.features());
        let labels: Vec<i32> = (0..batch).map(|_| rng.below(spec.classes()) as i32).collect();
        let (_, logits_x) = forward_all(&xla, &shapes, &p.layers, &x, batch);
        let (inputs_n, logits_n) = forward_all(&native, &shapes, &p.layers, &x, batch);
        assert_close(&logits_x, &logits_n, 1e-4, "padded fwd");
        let (glx, _) = xla.loss_grad_ce(spec.classes(), &logits_x, &labels);
        let (gln, _) = native.loss_grad_ce(spec.classes(), &logits_n, &labels);
        assert_close(&glx, &gln, 1e-4, "padded ce grad");
        let gx = backward_all(&xla, &shapes, &p.layers, &inputs_n, &glx, batch);
        let gn = backward_all(&native, &shapes, &p.layers, &inputs_n, &gln, batch);
        for (a, b) in gx.iter().zip(&gn) {
            assert_close(&a.gw, &b.gw, 1e-3, "padded gw");
            assert_close(&a.gb, &b.gb, 1e-3, "padded gb");
        }
    }
}

#[test]
fn full_training_loop_through_xla_learns() {
    let Some(xla) = open_xla() else { return };
    let zoo = default_zoo().unwrap();
    let spec = zoo.model("mlp").unwrap();
    let shapes = spec.layers();
    let batch = xla.runtime().batch();
    let mut rng = Rng::new(500);
    let mut params = ModelParams::init(spec, 7).layers;
    // Simple separable synthetic task: label = argmax of first C features.
    let c = spec.classes();
    let gen = |rng: &mut Rng| {
        let mut x = randvec(rng, batch * spec.features());
        let mut y = Vec::with_capacity(batch);
        for i in 0..batch {
            let label = rng.below(c);
            x[i * spec.features() + label] += 4.0;
            y.push(label as i32);
        }
        (x, y)
    };
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..30 {
        let (x, y) = gen(&mut rng);
        let (inputs, logits) = forward_all(&xla, &shapes, &params, &x, batch);
        let (gl, loss) = xla.loss_grad_ce(c, &logits, &y);
        if step == 0 {
            first = loss;
        }
        last = loss;
        let grads = backward_all(&xla, &shapes, &params, &inputs, &gl, batch);
        for (p, g) in params.iter_mut().zip(&grads) {
            *p = xla.sgd(p, g, 0.05);
        }
    }
    assert!(last < first * 0.7, "first={first} last={last}");
    let (x, y) = gen(&mut rng);
    let (_, logits) = forward_all(&xla, &shapes, &params, &x, batch);
    assert!(accuracy(c, &logits, &y) > 0.5);
}
