//! Executor equivalence: the threaded executor runs the *same schedule*
//! and the *same math* as the virtual-time simulation, so with the same
//! seed and plan both must produce identical `RunMetrics` and final
//! parameters ("lockstep" determinism). Plus stash-capacity edge cases
//! under deep pipelines.

use ferret::backend::native::NativeBackend;
use ferret::compensate::CompKind;
use ferret::config::ModelSpec;
use ferret::ocl::{OclKind, Vanilla};
use ferret::pipeline::engine::{run_async_with, AsyncCfg, AsyncSchedule};
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::{EngineParams, RunResult};
use ferret::planner::{plan, Partition, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn model() -> ModelSpec {
    ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
}

fn deep_model() -> ModelSpec {
    ModelSpec { name: "deep".into(), dims: vec![16, 16, 16, 16, 16, 16, 16, 4] }
}

fn stream(n: usize, seed: u64) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "equiv".into(),
        features: 16,
        classes: 4,
        batch: 8,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 3.0,
        noise: 0.5,
        seed,
    })
}

/// Assert two runs are observably identical: every metric the harness
/// consumes plus the final weights.
fn assert_runs_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.metrics.oacc.value(), b.metrics.oacc.value(), "{what}: oacc");
    assert_eq!(a.metrics.oacc.count(), b.metrics.oacc.count(), "{what}: predictions");
    assert_eq!(a.metrics.oacc.curve, b.metrics.oacc.curve, "{what}: oacc curve");
    assert_eq!(a.metrics.losses, b.metrics.losses, "{what}: loss curve");
    assert_eq!(a.metrics.trained, b.metrics.trained, "{what}: trained");
    assert_eq!(a.metrics.dropped, b.metrics.dropped, "{what}: dropped");
    assert_eq!(a.metrics.mem_bytes, b.metrics.mem_bytes, "{what}: mem");
    assert_eq!(a.metrics.peak_live_bytes, b.metrics.peak_live_bytes, "{what}: live bytes");
    assert_eq!(a.metrics.latencies, b.metrics.latencies, "{what}: latency samples");
    assert_eq!(a.metrics.staleness_hist, b.metrics.staleness_hist, "{what}: staleness");
    assert_eq!(a.metrics.tacc, b.metrics.tacc, "{what}: tacc");
    assert_eq!(
        a.metrics.adaptation_rate(),
        b.metrics.adaptation_rate(),
        "{what}: adaptation"
    );
    assert_eq!(a.params.len(), b.params.len(), "{what}: layer count");
    for (i, (pa, pb)) in a.params.iter().zip(&b.params).enumerate() {
        assert_eq!(pa.w, pb.w, "{what}: layer {i} weights");
        assert_eq!(pa.b, pb.b, "{what}: layer {i} bias");
    }
}

fn run_with(
    cfg_for: impl Fn() -> (AsyncCfg, ModelSpec),
    ep: &EngineParams,
    n: usize,
    kind: ExecutorKind,
) -> RunResult {
    let (cfg, m) = cfg_for();
    run_async_with(
        cfg,
        &mut stream(n, 31),
        &NativeBackend,
        &mut Vanilla,
        ep,
        &m,
        kind,
        Mode::Lockstep,
    )
}

#[test]
fn sim_and_threaded_produce_identical_metrics_pipedream() {
    let mk = || {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        (AsyncCfg::baseline(AsyncSchedule::Pipedream, part, &prof, prof.default_td()), m)
    };
    let ep = EngineParams { lr: 0.2, ..Default::default() };
    let sim = run_with(mk, &ep, 100, ExecutorKind::Sim);
    let thr = run_with(mk, &ep, 100, ExecutorKind::Threaded);
    assert_eq!(sim.metrics.exec_threads, 1);
    assert!(thr.metrics.exec_threads > 1, "threaded mode must spawn device threads");
    assert_runs_identical(&sim, &thr, "pipedream");
}

#[test]
fn sim_and_threaded_produce_identical_metrics_planned_ferret() {
    let mk = || {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let td = prof.default_td();
        let unconstrained = plan(&prof, td, f64::INFINITY, 1e-4);
        let out = plan(&prof, td, unconstrained.mem_bytes * 0.5, 1e-4);
        (AsyncCfg::ferret(out.partition, out.config, CompKind::IterFisher), m)
    };
    let ep = EngineParams { lr: 0.2, ..Default::default() };
    let sim = run_with(mk, &ep, 80, ExecutorKind::Sim);
    let thr = run_with(mk, &ep, 80, ExecutorKind::Threaded);
    assert_runs_identical(&sim, &thr, "ferret");
}

/// Lockstep equivalence must hold for every OCL plugin — bare SGD
/// (Vanilla) plus ER, MIR, LwF, and MAS — not just the friendly two:
/// replay mixing, interference scoring, distillation heads, and
/// importance-regularized updates all route through the executor.
#[test]
fn equivalence_holds_across_all_five_ocl_plugins() {
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let part = Partition::per_layer(m.num_layers());
    let td = prof.default_td();
    for ocl in OclKind::all() {
        let run = |kind: ExecutorKind| {
            let cfg = AsyncCfg::baseline(AsyncSchedule::Pipedream2BW, part.clone(), &prof, td);
            let mut plugin = ocl.build(23);
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            run_async_with(
                cfg,
                &mut stream(60, 9),
                &NativeBackend,
                plugin.as_mut(),
                &ep,
                &m,
                kind,
                Mode::Lockstep,
            )
        };
        let sim = run(ExecutorKind::Sim);
        let thr = run(ExecutorKind::Threaded);
        assert!(sim.metrics.trained > 0, "{}: plugin must train", ocl.name());
        assert_runs_identical(&sim, &thr, ocl.name());
    }
}

/// Same sweep on the planned-Ferret path (compensation enabled), pinning
/// equivalence where delta chains and plugin grad adjustment interact.
#[test]
fn equivalence_holds_across_plugins_with_compensation() {
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let td = prof.default_td();
    let unconstrained = plan(&prof, td, f64::INFINITY, 1e-4);
    let planned = plan(&prof, td, unconstrained.mem_bytes * 0.5, 1e-4);
    for ocl in [OclKind::Er, OclKind::Mas] {
        let run = |kind: ExecutorKind| {
            let cfg = AsyncCfg::ferret(
                planned.partition.clone(),
                planned.config.clone(),
                CompKind::IterFisher,
            );
            let mut plugin = ocl.build(5);
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            run_async_with(
                cfg,
                &mut stream(60, 13),
                &NativeBackend,
                plugin.as_mut(),
                &ep,
                &m,
                kind,
                Mode::Lockstep,
            )
        };
        let sim = run(ExecutorKind::Sim);
        let thr = run(ExecutorKind::Threaded);
        assert_runs_identical(&sim, &thr, ocl.name());
    }
}

/// Deep pipeline + heavy accumulation + a deliberately tiny stash: the
/// delta chain is evicted constantly and Iter-Fisher must fall back to the
/// jump (or nothing) without panicking — on both executors, identically.
#[test]
fn stash_capacity_overflow_under_deep_pipeline() {
    let mk = || {
        let m = deep_model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let mut cfg = AsyncCfg::baseline(AsyncSchedule::Ferret, part, &prof, prof.default_td());
        cfg.comp_kind = CompKind::IterFisher;
        for w in &mut cfg.pipe.workers {
            w.accum = vec![4; m.num_layers()];
        }
        (cfg, m)
    };
    // stash_cap 2 is the floor: versions are evicted almost immediately
    let ep = EngineParams { lr: 0.1, stash_cap: 2, ..Default::default() };
    let sim = run_with(mk, &ep, 80, ExecutorKind::Sim);
    assert!(sim.metrics.trained > 0, "deep pipeline must still train");
    assert_eq!(sim.metrics.oacc.count() as u64, 80, "every arrival predicted");
    let thr = run_with(mk, &ep, 80, ExecutorKind::Threaded);
    assert_runs_identical(&sim, &thr, "deep/tiny-stash");
    // the tiny stash must actually bound the live snapshot memory below
    // the auto-sized stash's
    let auto_ep = EngineParams { lr: 0.1, ..Default::default() };
    let auto = run_with(mk, &auto_ep, 80, ExecutorKind::Sim);
    assert!(
        sim.metrics.peak_live_bytes < auto.metrics.peak_live_bytes,
        "cap 2: {} !< auto: {}",
        sim.metrics.peak_live_bytes,
        auto.metrics.peak_live_bytes
    );
}
