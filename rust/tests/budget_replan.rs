//! Dynamic memory budgets: plan transitions, the measured-memory ledger,
//! and planner-vs-ledger agreement.
//!
//! The headline scenario: a lockstep run whose budget halves mid-stream
//! must drain, re-plan, migrate the learned weights into the new
//! partition, and resume the same stream — losing zero batches, ending
//! within the new budget, staying deterministic across executors, and
//! beating a restart-from-scratch baseline on online accuracy.

use ferret::backend::native::NativeBackend;
use ferret::budget::BudgetSchedule;
use ferret::compensate::CompKind;
use ferret::config::zoo::default_zoo;
use ferret::config::ModelSpec;
use ferret::ocl::Vanilla;
use ferret::pipeline::engine::{run_async_with, AsyncCfg};
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::{EngineParams, RunResult};
use ferret::planner::costmodel::decay_for_td;
use ferret::planner::{plan, Profile};
use ferret::stream::{DriftKind, StreamSpec, SyntheticStream};

fn model() -> ModelSpec {
    ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
}

/// Stationary streams do not depend on `num_batches`, so a shorter stream
/// is an exact prefix of a longer one with the same seed — the restart
/// baseline below relies on this.
fn stream(n: usize, seed: u64) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "budget".into(),
        features: 16,
        classes: 4,
        batch: 8,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 3.0,
        noise: 0.5,
        seed,
    })
}

/// Plan unconstrained, then run with a schedule that halves the budget at
/// batch `shift`. Returns the run and the halved budget in bytes.
fn dynamic_run(
    kind: ExecutorKind,
    mode: Mode,
    n: usize,
    shift: u64,
    comp: CompKind,
    td: u64,
) -> (RunResult, f64) {
    let m = model();
    let prof = Profile::analytic(&m, 8);
    let plan_td = prof.default_td();
    let decay = decay_for_td(plan_td);
    let hi = plan(&prof, plan_td, f64::INFINITY, decay);
    // the unconstrained plan pipelines (its footprint carries >= 2
    // stages' worth of versions), so half of it comfortably covers the
    // post-shift end state of one live copy + a plan-capped stash
    assert!(hi.partition.num_stages() >= 2, "{:?}", hi.partition);
    let budget = hi.mem_bytes * 0.5;
    let cfg = AsyncCfg::ferret(hi.partition.clone(), hi.config.clone(), comp)
        .with_budget(BudgetSchedule::step_at_batch(shift, budget));
    let ep = EngineParams { lr: 0.2, td, ..Default::default() };
    let r = run_async_with(cfg, &mut stream(n, 31), &NativeBackend, &mut Vanilla, &ep, &m, kind, mode);
    (r, budget)
}

#[test]
fn mid_stream_halving_replans_without_losing_batches() {
    let n = 160u64;
    let (r, budget) = dynamic_run(ExecutorKind::Sim, Mode::Lockstep, n as usize, 80, CompKind::NoComp, 0);
    // zero stream batches lost: every arrival predicted exactly once
    // (trained or predict-and-dropped — drop accounting unchanged)
    assert_eq!(r.metrics.arrivals(), n);
    assert_eq!(r.metrics.oacc.count() as u64, n, "one prediction per arrival");
    assert_eq!(
        r.metrics.losses.len() as u64,
        n - r.metrics.dropped,
        "admitted batches reach the loss head"
    );
    assert!(r.metrics.trained > 0, "updates landed on both sides of the shift");
    // exactly one schedule step; at most one extra ledger-breach replan
    assert!(
        (1..=2).contains(&r.metrics.replans),
        "replans {} (1 step + at most 1 breach)",
        r.metrics.replans
    );
    assert_eq!(r.metrics.drains.len() as u64, r.metrics.replans);
    assert_eq!(r.metrics.plan_trace.len() as u64, r.metrics.replans);
    // the run ends with ledger-measured memory within the halved budget
    let final_bytes = r.metrics.ledger.last.total() as f64;
    assert!(
        final_bytes <= budget,
        "final ledger {final_bytes} B > halved budget {budget} B ({:?})",
        r.metrics.ledger.last
    );
    // memory-over-time trace recorded through the transition
    assert!(!r.metrics.ledger.trace.is_empty());
    assert!(r.metrics.ledger.peak_total >= r.metrics.ledger.last.total());
    assert!(r.metrics.ledger.peak.params > 0 && r.metrics.ledger.peak.stash > 0);
}

/// Same schedule + seed ⇒ identical lockstep metrics across executors,
/// all the way through a drain + re-plan + device-thread reconfiguration.
#[test]
fn replan_is_deterministic_across_executors() {
    let run = |kind| dynamic_run(kind, Mode::Lockstep, 120, 60, CompKind::IterFisher, 0).0;
    let sim = run(ExecutorKind::Sim);
    let thr = run(ExecutorKind::Threaded);
    assert!(sim.metrics.replans >= 1, "the schedule step must fire");
    assert_eq!(sim.metrics.replans, thr.metrics.replans, "replans");
    assert_eq!(sim.metrics.drains, thr.metrics.drains, "drain latencies");
    assert_eq!(sim.metrics.plan_trace, thr.metrics.plan_trace, "plan trace");
    assert_eq!(sim.metrics.oacc.value(), thr.metrics.oacc.value(), "oacc");
    assert_eq!(sim.metrics.oacc.curve, thr.metrics.oacc.curve, "oacc curve");
    assert_eq!(sim.metrics.losses, thr.metrics.losses, "loss curve");
    assert_eq!(sim.metrics.trained, thr.metrics.trained, "trained");
    assert_eq!(sim.metrics.dropped, thr.metrics.dropped, "dropped");
    assert_eq!(sim.metrics.mem_bytes, thr.metrics.mem_bytes, "mem");
    assert_eq!(sim.metrics.latencies, thr.metrics.latencies, "latencies");
    assert_eq!(sim.metrics.staleness_hist, thr.metrics.staleness_hist, "staleness");
    assert_eq!(sim.metrics.tacc, thr.metrics.tacc, "tacc");
    assert_eq!(sim.metrics.ledger.trace, thr.metrics.ledger.trace, "ledger trace");
    assert_eq!(sim.metrics.ledger.peak_total, thr.metrics.ledger.peak_total, "ledger peak");
    assert_eq!(sim.metrics.ledger.last, thr.metrics.ledger.last, "ledger end state");
    assert_eq!(sim.params.len(), thr.params.len());
    for (i, (a, b)) in sim.params.iter().zip(&thr.params).enumerate() {
        assert_eq!(a.w, b.w, "layer {i} weights");
        assert_eq!(a.b, b.b, "layer {i} bias");
    }
}

/// The transition must retain the learned weights: the dynamic run's
/// aggregate online accuracy beats restarting the learner from scratch at
/// the shift point (same stream, fresh weights + the halved-budget plan).
#[test]
fn dynamic_replan_beats_restart_from_scratch() {
    let n = 160usize;
    let shift = 80usize;
    let (dynamic, budget) = dynamic_run(ExecutorKind::Sim, Mode::Lockstep, n, shift as u64, CompKind::NoComp, 0);

    let m = model();
    let prof = Profile::analytic(&m, 8);
    let td = prof.default_td();
    let decay = decay_for_td(td);
    let hi = plan(&prof, td, f64::INFINITY, decay);
    let lo = plan(&prof, td, budget, decay);
    assert!(lo.feasible, "halved budget must be plannable");
    let ep = EngineParams { lr: 0.2, ..Default::default() };
    // first half: the unconstrained plan on the stream prefix
    let a = run_async_with(
        AsyncCfg::ferret(hi.partition.clone(), hi.config.clone(), CompKind::NoComp),
        &mut stream(shift, 31),
        &NativeBackend,
        &mut Vanilla,
        &ep,
        &m,
        ExecutorKind::Sim,
        Mode::Lockstep,
    );
    // second half: restart — fresh weights, halved-budget plan, stream tail
    let mut tail = stream(n, 31);
    for _ in 0..shift {
        let _ = tail.next_batch();
    }
    let ep_restart = EngineParams { lr: 0.2, seed: 4242, ..Default::default() };
    let b = run_async_with(
        AsyncCfg::ferret(lo.partition.clone(), lo.config.clone(), CompKind::NoComp),
        &mut tail,
        &NativeBackend,
        &mut Vanilla,
        &ep_restart,
        &m,
        ExecutorKind::Sim,
        Mode::Lockstep,
    );
    let total = a.metrics.oacc.count() + b.metrics.oacc.count();
    let restart_oacc = (a.metrics.oacc.value() * a.metrics.oacc.count()
        + b.metrics.oacc.value() * b.metrics.oacc.count())
        / total;
    assert_eq!(total as usize, n, "restart baseline sees the whole stream");
    assert!(
        dynamic.metrics.oacc.value() > restart_oacc,
        "retained weights must beat a restart: dynamic {:.2}% vs restart {:.2}%",
        dynamic.metrics.oacc.value(),
        restart_oacc
    );
}

/// Planner-vs-ledger agreement: on real zoo models, the Eq. 4 footprint
/// the planner optimizes must bound/track the engine-measured peak within
/// a pinned tolerance. The engine run uses dynamic stash sizing (a
/// batch-0 step only — no mid-stream replan), which ties stash capacity
/// to the plan's version count; the slack factor covers in-flight job
/// payloads, which Eq. 4's steady-state activation term understates.
#[test]
fn planner_footprint_tracks_measured_peak_on_zoo_models() {
    let zoo = default_zoo().expect("zoo");
    for name in ["mlp", "mnistnet10", "convnet10"] {
        let spec = zoo.model(name).expect("model").clone();
        let prof = Profile::analytic(&spec, zoo.batch);
        let td = prof.default_td();
        let out = plan(&prof, td, f64::INFINITY, decay_for_td(td));
        assert!(out.feasible, "{name}");
        let schedule =
            BudgetSchedule::parse("inf@b0").expect("schedule (dynamic sizing, no replan)");
        let cfg = AsyncCfg::ferret(out.partition.clone(), out.config.clone(), CompKind::NoComp)
            .with_budget(schedule);
        let mut s = SyntheticStream::new(StreamSpec {
            name: name.into(),
            features: spec.features(),
            classes: spec.classes(),
            batch: zoo.batch,
            num_batches: 30,
            kind: DriftKind::Stationary,
            margin: 3.0,
            noise: 0.6,
            seed: 7,
        });
        let ep = EngineParams { lr: 0.05, ..Default::default() };
        let r = run_async_with(
            cfg,
            &mut s,
            &NativeBackend,
            &mut Vanilla,
            &ep,
            &spec,
            ExecutorKind::Sim,
            Mode::Lockstep,
        );
        assert_eq!(r.metrics.replans, 0, "{name}: batch-0 step is absorbed, not replanned");
        let measured = r.metrics.ledger.peak_total as f64;
        let predicted = out.mem_bytes;
        assert!(measured > 0.0 && predicted > 0.0, "{name}");
        let ratio = measured / predicted;
        assert!(
            ratio <= 2.5,
            "{name}: measured peak {measured:.0} B exceeds 2.5x planned {predicted:.0} B \
             (ratio {ratio:.2}, {:?})",
            r.metrics.ledger.peak
        );
        assert!(
            ratio >= 0.01,
            "{name}: measured peak {measured:.0} B implausibly small vs planned \
             {predicted:.0} B (ratio {ratio:.4})"
        );
    }
}

/// Freerun: the same schedule drains against the wall clock, re-plans on
/// the measured (µs) profile, re-spawns device threads, and resumes —
/// structurally lossless even though timing is not deterministic.
#[test]
fn freerun_replan_smoke() {
    let n = 60u64;
    // td in ticks; freerun replays 1 tick = 1µs, so 2000 keeps arrivals
    // far slower than the µs-scale stage compute of the tiny model
    let (r, _) = dynamic_run(
        ExecutorKind::Threaded,
        Mode::Freerun,
        n as usize,
        30,
        CompKind::NoComp,
        2000,
    );
    assert_eq!(r.metrics.arrivals(), n);
    assert_eq!(r.metrics.oacc.count() as u64, n, "no lost or doubled jobs");
    assert_eq!(r.metrics.losses.len() as u64, n - r.metrics.dropped);
    assert!(r.metrics.replans >= 1, "the schedule step fires in freerun too");
    assert!(r.metrics.trained > 0);
    assert!(!r.metrics.ledger.trace.is_empty());
}
