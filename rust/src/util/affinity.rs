//! CPU affinity pinning for device threads (Linux-only, zero-dep).
//!
//! [`ThreadedExecutor::spawn_pinned`] pins each device thread to one CPU
//! so a stage's working set (its parameter `Arc`s, stash, pooled buffers)
//! stays in one core's cache instead of migrating with the scheduler's
//! whims. Pinning is a pure placement hint: kernels are bit-identical
//! across thread counts and placements, so numerics never depend on it.
//!
//! Implementation notes:
//!
//!   - Calls the glibc wrappers `sched_getaffinity` / `sched_setaffinity`
//!     through `extern "C"` declarations — std already links libc, so
//!     this adds no dependency, and the wrappers are portable across
//!     architectures (raw syscall numbers are not).
//!   - Slots index into the *currently allowed* CPU set, not absolute CPU
//!     ids: under a container cpuset (say CPUs {2, 3, 6, 7}) slot 0 pins
//!     to CPU 2, slot 1 to CPU 3, and so on, wrapping round-robin. Device
//!     threads inherit the unpinned scheduler mask at spawn, so the
//!     allowed set read here is the full container set, not a previous
//!     pin.
//!   - On non-Linux targets (and on any syscall failure) pinning is a
//!     no-op returning `false`; callers treat it as best-effort.
//!
//! [`ThreadedExecutor::spawn_pinned`]:
//!     crate::pipeline::executor::ThreadedExecutor::spawn_pinned

/// Pin the calling thread to the `slot % allowed`-th CPU of its currently
/// allowed set. Returns whether a pin was actually applied.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(slot: usize) -> bool {
    // cpu_set_t is 1024 bits on glibc; represent it as a usize array so
    // the bit twiddling stays word-aligned on every architecture
    const CPUSET_BITS: usize = 1024;
    const WORDS: usize = CPUSET_BITS / usize::BITS as usize;
    extern "C" {
        // glibc signatures: (pid_t, size_t, cpu_set_t*); pid 0 = calling
        // thread. Declared with usize-array masks of the same layout.
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut usize) -> i32;
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const usize) -> i32;
    }
    let mut mask = [0usize; WORDS];
    let bytes = std::mem::size_of_val(&mask);
    // SAFETY: mask is a live, writable buffer of exactly `bytes` bytes,
    // and pid 0 addresses the calling thread only.
    if unsafe { sched_getaffinity(0, bytes, mask.as_mut_ptr()) } != 0 {
        return false;
    }
    let allowed: Vec<usize> = (0..CPUSET_BITS)
        .filter(|&c| mask[c / usize::BITS as usize] & (1usize << (c % usize::BITS as usize)) != 0)
        .collect();
    if allowed.is_empty() {
        return false;
    }
    let cpu = allowed[slot % allowed.len()];
    let mut pin = [0usize; WORDS];
    pin[cpu / usize::BITS as usize] = 1usize << (cpu % usize::BITS as usize);
    // SAFETY: pin is a live buffer of exactly `bytes` bytes; a failed set
    // (the mask raced with a cpuset shrink) is reported, not unsafe.
    unsafe { sched_setaffinity(0, bytes, pin.as_ptr()) == 0 }
}

/// Non-Linux: affinity is a best-effort hint; do nothing.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_slot: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pin inside a scratch thread so the test runner's own thread keeps
    /// its full mask. On Linux the pin must succeed for every slot
    /// (round-robin wraps past the allowed-set size); elsewhere the call
    /// is a documented no-op.
    #[test]
    fn pins_scratch_threads_round_robin() {
        for slot in [0usize, 1, 7, 4096] {
            let pinned = std::thread::spawn(move || pin_current_thread(slot))
                .join()
                .expect("scratch thread");
            if cfg!(target_os = "linux") {
                assert!(pinned, "slot {slot} failed to pin");
            } else {
                assert!(!pinned);
            }
        }
    }

    /// Two threads pinned to different slots still compute identical
    /// results — affinity must never leak into numerics.
    #[test]
    fn pinning_does_not_affect_computation() {
        let work = |slot: usize| {
            std::thread::spawn(move || {
                pin_current_thread(slot);
                (0..1000).map(|i| (i as f32).sqrt()).sum::<f32>()
            })
            .join()
            .expect("worker")
        };
        assert_eq!(work(0).to_bits(), work(1).to_bits());
    }
}
