//! Small integer/float helpers shared across the planner and engines.

/// Ceiling division.
#[inline]
pub fn cdiv(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Greatest common divisor.
pub fn gcd(a: u64, b: u64) -> u64 {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Least common multiple (saturating).
pub fn lcm(a: u64, b: u64) -> u64 {
    if a == 0 || b == 0 {
        return 0;
    }
    (a / gcd(a, b)).saturating_mul(b)
}

/// LCM over an iterator (identity 1).
pub fn lcm_all<I: IntoIterator<Item = u64>>(xs: I) -> u64 {
    xs.into_iter().fold(1, lcm)
}

/// Argmax over a slice of f32 (first max wins). Panics on empty input.
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean of an f64 iterator; 0.0 on empty.
pub fn mean<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    let (mut sum, mut n) = (0.0, 0u64);
    for x in xs {
        sum += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Population standard deviation; 0.0 on fewer than 2 items.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs.iter().copied());
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Pearson correlation coefficient; 0.0 on fewer than 2 points or zero
/// variance.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        0.0
    } else {
        cov / (vx.sqrt() * vy.sqrt())
    }
}

/// Dot product over f32 slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Squared L2 norm.
#[inline]
pub fn norm2(a: &[f32]) -> f64 {
    a.iter().map(|&x| x as f64 * x as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdiv_rounds_up() {
        assert_eq!(cdiv(10, 3), 4);
        assert_eq!(cdiv(9, 3), 3);
        assert_eq!(cdiv(0, 3), 0);
        assert_eq!(cdiv(1, 1), 1);
    }

    #[test]
    fn gcd_lcm_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(7, 13), 1);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(1, 9), 9);
        assert_eq!(lcm(0, 9), 0);
        assert_eq!(lcm_all([2, 3, 4]), 12);
        assert_eq!(lcm_all(std::iter::empty()), 1);
    }

    #[test]
    fn lcm_identity_property() {
        // lcm(a,b) divisible by both; property-swept over a seeded grid.
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..500 {
            let a = rng.below(100) as u64 + 1;
            let b = rng.below(100) as u64 + 1;
            let l = lcm(a, b);
            assert_eq!(l % a, 0);
            assert_eq!(l % b, 0);
            assert!(l <= a * b);
        }
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn stats() {
        assert_eq!(mean([1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(std::iter::empty()), 0.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn pearson_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1.0], &[1.0]), 0.0);
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0, "zero variance");
    }

    #[test]
    fn dot_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(norm2(&[3.0, 4.0]), 25.0);
    }
}
