//! Dependency-free error handling with an `anyhow`-shaped surface
//! (`Result`, `bail!`, `.context(..)` / `.with_context(..)`), so the crate
//! builds hermetically. The context chain is flattened into one message,
//! printed identically by `{}`, `{:#}`, and `{:?}`.

use std::fmt;

/// Crate-wide error: a single human-readable message with any context
/// prefixes already applied.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow's blanket conversion. Sound because `Error` itself does
// NOT implement `std::error::Error`, so this cannot overlap the reflexive
// `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

pub use crate::bail;

/// Attach context to errors/`None`, anyhow-style.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse()?; // std error converts via the blanket From
        if n == 0 {
            bail!("zero is not allowed (got {s:?})");
        }
        Ok(n)
    }

    #[test]
    fn conversions_and_bail() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        let e = parse("0").unwrap_err();
        assert!(e.to_string().contains("zero is not allowed"));
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        // `{:#}` prints the same flattened chain
        assert_eq!(format!("{e:#}"), format!("{e}"));
    }
}
