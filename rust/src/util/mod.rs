//! Dependency-free utilities: deterministic RNG, math helpers, and a tiny
//! property-testing harness used by unit tests across the crate.

pub mod error;
pub mod math;
pub mod rng;

pub use math::{argmax, cdiv, dot, gcd, lcm, lcm_all, mean, norm2, pearson, std_dev};
pub use rng::Rng;

/// Minimal property-test harness (proptest is not vendored): runs `f` over
/// `n` seeded cases, reporting the failing seed on panic so cases can be
/// replayed with `case(seed)`.
pub fn property<F: Fn(&mut Rng)>(name: &str, n: usize, f: F) {
    for i in 0..n {
        let seed = 0xFEE7 ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property {name:?} failed at case {i} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        property("counts", 10, |_| {
            counter.set(counter.get() + 1);
        });
        count += counter.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic]
    fn property_propagates_failure() {
        property("fails", 5, |rng| {
            assert!(rng.uniform() < 0.0, "always fails");
        });
    }
}
