//! Dependency-free utilities: deterministic RNG, math helpers, and a tiny
//! property-testing harness used by unit tests across the crate.

pub mod affinity;
pub mod error;
pub mod math;
pub mod rng;

pub use math::{argmax, cdiv, dot, gcd, lcm, lcm_all, mean, norm2, pearson, std_dev};
pub use rng::Rng;

/// Incremental FNV-1a 64-bit hasher. Used for content identities that
/// must be stable across runs, processes, and platforms (batch hashes
/// and plan ids in trace artifacts) — `std`'s `DefaultHasher` makes no
/// such guarantee, so it cannot appear in a serialized trace.
#[derive(Debug, Clone)]
pub struct Fnv(u64);

impl Fnv {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;

    pub fn new() -> Self {
        Fnv(Self::OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_i64(&mut self, v: i64) {
        self.write(&v.to_le_bytes());
    }

    /// Hashes the bit pattern, so -0.0 and 0.0 (or two NaNs with
    /// different payloads) hash differently — bit-for-bit identity is
    /// exactly what trace replay checks.
    pub fn write_f32(&mut self, v: f32) {
        self.write(&v.to_bits().to_le_bytes());
    }

    pub fn write_i32(&mut self, v: i32) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

/// Minimal property-test harness (proptest is not vendored): runs `f` over
/// `n` seeded cases, reporting the failing seed on panic so cases can be
/// replayed with `case(seed)`.
pub fn property<F: Fn(&mut Rng)>(name: &str, n: usize, f: F) {
    for i in 0..n {
        let seed = 0xFEE7 ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property {name:?} failed at case {i} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        property("counts", 10, |_| {
            counter.set(counter.get() + 1);
        });
        count += counter.get();
        assert_eq!(count, 10);
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // Standard FNV-1a 64 vectors: "" and "a".
        assert_eq!(Fnv::new().finish(), 0xcbf29ce484222325);
        let mut h = Fnv::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
        // Field-wise writes are order-sensitive.
        let (mut a, mut b) = (Fnv::new(), Fnv::new());
        a.write_u64(1);
        a.write_u64(2);
        b.write_u64(2);
        b.write_u64(1);
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    #[should_panic]
    fn property_propagates_failure() {
        property("fails", 5, |rng| {
            assert!(rng.uniform() < 0.0, "always fails");
        });
    }
}
