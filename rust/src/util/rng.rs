//! Deterministic, dependency-free PRNG (SplitMix64 + xoshiro256**).
//!
//! Every stochastic component in Ferret (stream generation, weight init,
//! replay-buffer sampling, baseline policies) draws from this generator so
//! that runs are exactly reproducible from a single seed.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child generator (for per-component streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style unbiased bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: first k entries are the sample
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        for _ in 0..50 {
            let s = r.sample_indices(20, 8);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
