//! Evaluation metrics: online accuracy (`oacc`), test accuracy (`tacc`),
//! the paper's memory-normalized gains `agm` (Eq. 18) / `tagm` (Eq. 17),
//! and the measured adaptation rate (Def. 4.1).

use crate::backend::{accuracy, forward_all, Backend};
use crate::budget::MemoryLedger;
use crate::config::LayerShape;
use crate::model::LayerParams;
use crate::stream::TestSet;

/// Online Accuracy Gain per unit of Memory (Eq. 18):
/// `log(exp(oacc_A - oacc_B) / (M_A / M_B))` with accuracies in percent
/// and `log = log10`. The base is recoverable from the paper's own
/// numbers: Table 1/7 give Oracle on MNIST Δoacc = 81.14 - 18.24 = 62.9
/// and agm = 27.32 = 62.9 / ln(10) (same for CIFAR100, CORe50, Covertype
/// rows, where M_Oracle ~ M_1-Skip so the memory term vanishes).
pub fn agm(oacc_a: f64, oacc_b: f64, mem_a: f64, mem_b: f64) -> f64 {
    assert!(mem_a > 0.0 && mem_b > 0.0);
    (oacc_a - oacc_b) / std::f64::consts::LN_10 - (mem_a / mem_b).log10()
}

/// Test Accuracy Gain per unit of Memory (Eq. 17) — same functional form.
pub fn tagm(tacc_a: f64, tacc_b: f64, mem_a: f64, mem_b: f64) -> f64 {
    agm(tacc_a, tacc_b, mem_a, mem_b)
}

/// Running-mean tracker with a stored curve.
#[derive(Debug, Clone, Default)]
pub struct RunningAcc {
    hits: f64,
    total: f64,
    pub curve: Vec<(u64, f64)>,
}

impl RunningAcc {
    pub fn record(&mut self, t: u64, acc: f64, weight: f64) {
        self.hits += acc * weight;
        self.total += weight;
        self.curve.push((t, self.value()));
    }

    /// Accuracy in percent.
    pub fn value(&self) -> f64 {
        if self.total == 0.0 {
            0.0
        } else {
            100.0 * self.hits / self.total
        }
    }

    pub fn count(&self) -> f64 {
        self.total
    }
}

/// Full per-run metric sink shared by all engines.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// online accuracy over every arriving batch's prediction
    pub oacc: RunningAcc,
    /// mean training loss curve
    pub losses: Vec<(u64, f32)>,
    /// measured adaptation-rate numerator: sum of e^{-c r} * value_frac
    /// (pub(crate): `RunMetrics` is built with struct-update syntax in
    /// `pipeline::session`, which needs every field visible there)
    pub(crate) adaptation_num: f64,
    pub(crate) adaptation_batches: u64,
    pub trained: u64,
    pub dropped: u64,
    /// analytic memory footprint in bytes (set by the engine from its
    /// config via the planner cost model)
    pub mem_bytes: f64,
    /// engine-measured peak live bytes (stash + activations + buffers)
    pub peak_live_bytes: usize,
    /// final test accuracy in percent (filled by `eval_tacc`)
    pub tacc: f64,
    /// compute threads the executor ran with (1 = inline simulation;
    /// 0 = engine predates executors / not applicable)
    pub exec_threads: usize,
    /// per-batch arrival→prediction latency samples for trained batches
    /// (virtual ticks in lockstep mode, real microseconds in freerun);
    /// bounded at [`OBS_SAMPLE_CAP`] by deterministic decimation
    pub latencies: Vec<u64>,
    /// decimation stride/pending for `latencies` (0 stride reads as 1, so
    /// `derive(Default)` starts at the dense sampling rate)
    pub(crate) lat_stride: u64,
    pub(crate) lat_pending: u64,
    /// decimation stride/pending for `drains`
    pub(crate) drain_stride: u64,
    pub(crate) drain_pending: u64,
    /// observed-staleness histogram: `staleness_hist[τ]` = updates applied
    /// τ versions stale; the last bucket aggregates τ ≥ STALENESS_BUCKETS
    pub staleness_hist: Vec<u64>,
    /// measured-memory accounting: per-category peaks, end-of-run state,
    /// and the memory-over-time trace (one point per update)
    pub ledger: MemoryLedger,
    /// number of mid-stream plan transitions executed
    pub replans: u64,
    /// drain latency of each plan transition (virtual ticks in lockstep,
    /// real microseconds in freerun); bounded like `latencies`
    pub drains: Vec<u64>,
    /// accumulated device busy time in ticks: every forward/backward/
    /// update/augment pass, summed over all devices. Always on (plain
    /// adds — independent of the opt-in span recorder); in lockstep it
    /// sums the replayed analytic costs, so it is deterministic and
    /// executor-independent like every other lockstep metric.
    pub busy_us: u64,
    /// integrated device-time in ticks: Σ over plan phases of
    /// (active devices × phase duration). `busy_us / device_us` is the
    /// fleet utilization; `1 − utilization` the pipeline bubble fraction.
    pub device_us: u64,
    /// planner-predicted footprint after each re-plan: `(t, bytes)`
    pub plan_trace: Vec<(u64, f64)>,
    /// final counters of the session's shared buffer pool (takes, misses,
    /// puts, drops) — `misses` ≪ `takes` is the zero-copy steady state
    pub pool: crate::backend::PoolStats,
}

/// Histogram cap: staleness beyond this lands in the overflow bucket.
pub const STALENESS_BUCKETS: usize = 32;

/// Upper bound on the per-run latency/drain sample vectors: reaching it
/// drops every other sample and doubles the sampling stride (the same
/// downsample-not-truncate policy as [`crate::budget::TRACE_CAP`]), so a
/// long-lived session cannot grow the metrics sink without limit.
/// Deterministic: the same sample sequence always keeps the same points.
pub const OBS_SAMPLE_CAP: usize = 4096;

/// Drop every other element (odd positions kept, so the newest element
/// of a just-filled vector always survives).
fn decimate<T>(v: &mut Vec<T>) {
    let mut i = 0usize;
    v.retain(|_| {
        i += 1;
        i % 2 == 0
    });
}

/// Largest per-window mean difference between two accuracy curves.
///
/// Each curve is split into `windows` equal index ranges over its *own*
/// length (curves from a recorded run and its replay can differ in point
/// count when one drops more batches); window means are compared pairwise
/// and the max absolute difference returned. An empty curve contributes a
/// 0 mean per window; both empty → 0. Used by trace replay to detect
/// localized accuracy regressions a final-value comparison would average
/// away.
pub fn curve_windowed_max_delta(a: &[(u64, f64)], b: &[(u64, f64)], windows: usize) -> f64 {
    let windows = windows.max(1);
    let mean_of = |c: &[(u64, f64)], w: usize| -> f64 {
        if c.is_empty() {
            return 0.0;
        }
        let lo = c.len() * w / windows;
        let hi = (c.len() * (w + 1) / windows).max(lo + 1).min(c.len());
        if lo >= c.len() {
            return c[c.len() - 1].1;
        }
        let slice = &c[lo..hi];
        slice.iter().map(|(_, v)| v).sum::<f64>() / slice.len() as f64
    };
    (0..windows)
        .map(|w| (mean_of(a, w) - mean_of(b, w)).abs())
        .fold(0.0, f64::max)
}

/// Nearest-rank percentile over an already-sorted sample slice (`p` in
/// 0..=100); 0 when empty. Single definition shared by every caller.
fn percentile_of_sorted(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil().max(1.0) as usize;
    sorted[rank.min(sorted.len()) - 1]
}

/// Nearest-rank percentile over an unsorted sample window (`p` in
/// 0..=100). Total for every input: an empty window is 0, a single
/// sample is that sample, and out-of-range `p` clamps to the extremes —
/// no panic, no division by zero. Used for the obs sliding-window
/// percentiles and anywhere a one-off window needs summarizing.
pub fn percentile_u64(samples: &[u64], p: f64) -> u64 {
    let mut v = samples.to_vec();
    v.sort_unstable();
    percentile_of_sorted(&v, p)
}

impl RunMetrics {
    pub fn record_prediction(&mut self, t: u64, acc: f64) {
        self.oacc.record(t, acc, 1.0);
    }

    pub fn record_loss(&mut self, t: u64, loss: f32) {
        self.losses.push((t, loss));
    }

    pub fn record_drop(&mut self) {
        self.dropped += 1;
    }

    /// Record a parameter update that landed with `latency` virtual ticks
    /// after its data arrived, updating `value_frac` of the model.
    pub fn record_update(&mut self, latency: u64, decay_c: f64, value_frac: f64) {
        self.trained += 1;
        self.adaptation_num += (-decay_c * latency as f64).exp() * value_frac;
    }

    /// Count an arrived batch toward the adaptation-rate denominator.
    pub fn record_arrival(&mut self) {
        self.adaptation_batches += 1;
    }

    /// Measured adaptation rate (Def. 4.1, V_D = 1).
    pub fn adaptation_rate(&self) -> f64 {
        if self.adaptation_batches == 0 {
            0.0
        } else {
            self.adaptation_num / self.adaptation_batches as f64
        }
    }

    pub fn observe_live_bytes(&mut self, bytes: usize) {
        self.peak_live_bytes = self.peak_live_bytes.max(bytes);
    }

    /// Record one batch's arrival→prediction latency. Past
    /// [`OBS_SAMPLE_CAP`] samples the vector is decimated — every other
    /// sample dropped, sampling stride doubled — so unbounded sessions
    /// keep a bounded, run-spanning sample set (percentiles become
    /// estimates over the retained sample, exact until the cap).
    pub fn record_latency(&mut self, latency: u64) {
        self.lat_pending += 1;
        if self.lat_pending < self.lat_stride.max(1) {
            return;
        }
        self.lat_pending = 0;
        self.latencies.push(latency);
        if self.latencies.len() >= OBS_SAMPLE_CAP {
            decimate(&mut self.latencies);
            self.lat_stride = self.lat_stride.max(1) * 2;
        }
    }

    /// Record one executed plan transition: when it landed, how long the
    /// in-flight drain took, and the footprint the new plan predicts.
    /// `drains` is bounded the same way as `latencies`.
    pub fn record_replan(&mut self, t: u64, drain: u64, planned_bytes: f64) {
        self.replans += 1;
        self.plan_trace.push((t, planned_bytes));
        self.drain_pending += 1;
        if self.drain_pending < self.drain_stride.max(1) {
            return;
        }
        self.drain_pending = 0;
        self.drains.push(drain);
        if self.drains.len() >= OBS_SAMPLE_CAP {
            decimate(&mut self.drains);
            self.drain_stride = self.drain_stride.max(1) * 2;
        }
    }

    /// Add device busy time (one pass's duration, in ticks).
    pub fn note_busy(&mut self, dur: u64) {
        self.busy_us += dur;
    }

    /// Integrate `devices` active devices over a `dur`-tick phase into
    /// the device-time denominator (called at plan-transition boundaries
    /// and at finish, when the active device set is known).
    pub fn integrate_device_time(&mut self, devices: usize, dur: u64) {
        self.device_us += devices as u64 * dur;
    }

    /// Fleet utilization: busy device time over integrated device time
    /// (0 when nothing was integrated yet).
    pub fn utilization(&self) -> f64 {
        if self.device_us == 0 {
            0.0
        } else {
            self.busy_us as f64 / self.device_us as f64
        }
    }

    /// Pipeline bubble fraction: the idle share of integrated device
    /// time, `1 − utilization`, clamped at 0 (freerun service times are
    /// measured, so rounding can nudge busy past the integral).
    pub fn bubble_frac(&self) -> f64 {
        if self.device_us == 0 {
            0.0
        } else {
            (1.0 - self.utilization()).max(0.0)
        }
    }

    /// Record the staleness an update was applied at.
    pub fn record_staleness(&mut self, tau: u64) {
        let b = (tau as usize).min(STALENESS_BUCKETS);
        if self.staleness_hist.len() <= b {
            self.staleness_hist.resize(b + 1, 0);
        }
        self.staleness_hist[b] += 1;
    }

    /// Nearest-rank latency percentile (`p` in 0..=100); 0 if no samples.
    pub fn latency_percentile(&self, p: f64) -> u64 {
        let mut v = self.latencies.clone();
        v.sort_unstable();
        percentile_of_sorted(&v, p)
    }

    /// "p50=.. p95=.. p99=.." one-liner for run reports (single sort).
    pub fn latency_summary(&self) -> String {
        let mut v = self.latencies.clone();
        v.sort_unstable();
        format!(
            "p50={} p95={} p99={} max={} (n={})",
            percentile_of_sorted(&v, 50.0),
            percentile_of_sorted(&v, 95.0),
            percentile_of_sorted(&v, 99.0),
            v.last().copied().unwrap_or(0),
            v.len()
        )
    }

    /// "τ=0:12 τ=1:3 ... τ≥32:N" histogram one-liner for run reports.
    pub fn staleness_summary(&self) -> String {
        if self.staleness_hist.is_empty() {
            return "(no updates)".into();
        }
        self.staleness_hist
            .iter()
            .enumerate()
            .map(|(tau, n)| {
                if tau == STALENESS_BUCKETS {
                    format!("τ≥{tau}:{n}")
                } else {
                    format!("τ={tau}:{n}")
                }
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Total batches that arrived (adaptation-rate denominator).
    pub fn arrivals(&self) -> u64 {
        self.adaptation_batches
    }

    /// Fold another run's latency samples, staleness histogram, and
    /// busy/device-time integrals into this sink (harness-level
    /// aggregation across a run matrix). Latencies go through
    /// [`RunMetrics::record_latency`], so the aggregate stays bounded.
    pub fn absorb_observability(&mut self, other: &RunMetrics) {
        for &l in &other.latencies {
            self.record_latency(l);
        }
        if self.staleness_hist.len() < other.staleness_hist.len() {
            self.staleness_hist.resize(other.staleness_hist.len(), 0);
        }
        for (i, n) in other.staleness_hist.iter().enumerate() {
            self.staleness_hist[i] += n;
        }
        self.busy_us += other.busy_us;
        self.device_us += other.device_us;
    }

    pub fn mean_recent_loss(&self, k: usize) -> f32 {
        let n = self.losses.len();
        if n == 0 {
            return 0.0;
        }
        let s = &self.losses[n.saturating_sub(k)..];
        s.iter().map(|(_, l)| l).sum::<f32>() / s.len() as f32
    }
}

/// Evaluate test accuracy (percent) of a parameter set over a test set.
pub fn eval_tacc<P: std::borrow::Borrow<LayerParams>>(
    backend: &dyn Backend,
    shapes: &[LayerShape],
    params: &[P],
    classes: usize,
    test: &TestSet,
    batch: usize,
) -> f64 {
    let features = test.x.len() / test.n;
    let mut hits = 0.0;
    let mut total = 0.0;
    let mut i = 0;
    while i < test.n {
        let n = batch.min(test.n - i);
        let x = &test.x[i * features..(i + n) * features];
        let y = &test.y[i..i + n];
        let (_, logits) = forward_all(backend, shapes, params, x, n);
        hits += accuracy(classes, &logits, y) * n as f64;
        total += n as f64;
        i += n;
    }
    100.0 * hits / total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windowed_curve_delta_localizes_regressions() {
        let a: Vec<(u64, f64)> = (0..64).map(|i| (i, 50.0)).collect();
        assert_eq!(curve_windowed_max_delta(&a, &a, 16), 0.0, "identical curves");
        // a dip in one window only: final values agree, windowed delta sees it
        let mut b = a.clone();
        for p in b[16..20].iter_mut() {
            p.1 = 30.0;
        }
        let d = curve_windowed_max_delta(&a, &b, 16);
        assert!((d - 20.0).abs() < 1e-9, "window mean catches the dip, got {d}");
        // different lengths are compared window-by-window, not point-by-point
        let c: Vec<(u64, f64)> = (0..101).map(|i| (i, 50.0)).collect();
        assert!(curve_windowed_max_delta(&a, &c, 16).abs() < 1e-9);
        assert_eq!(curve_windowed_max_delta(&[], &[], 16), 0.0);
        assert!((curve_windowed_max_delta(&a, &[], 16) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn agm_baseline_is_zero() {
        // A == B: equal accuracy, equal memory -> 0 (the tables' 1-Skip row)
        assert!((agm(50.0, 50.0, 1e6, 1e6)).abs() < 1e-12);
    }

    #[test]
    fn agm_orderings() {
        // higher accuracy at equal memory -> positive, monotone
        let a1 = agm(60.0, 50.0, 1e6, 1e6);
        let a2 = agm(70.0, 50.0, 1e6, 1e6);
        assert!(a1 > 0.0 && a2 > a1);
        // equal accuracy at higher memory -> negative
        assert!(agm(50.0, 50.0, 2e6, 1e6) < 0.0);
        // the memory penalty is logarithmic (diminishing)
        let p1 = agm(50.0, 50.0, 2e6, 1e6);
        let p2 = agm(50.0, 50.0, 4e6, 1e6);
        assert!((p2 - 2.0 * p1).abs() < 1e-9, "log ratio adds");
    }

    #[test]
    fn running_acc() {
        let mut r = RunningAcc::default();
        r.record(0, 1.0, 1.0);
        r.record(1, 0.0, 1.0);
        assert_eq!(r.value(), 50.0);
        assert_eq!(r.curve.len(), 2);
        assert_eq!(r.curve[0].1, 100.0);
    }

    #[test]
    fn adaptation_rate_decays_with_latency() {
        let mut m0 = RunMetrics::default();
        let mut m1 = RunMetrics::default();
        for _ in 0..10 {
            m0.record_arrival();
            m1.record_arrival();
            m0.record_update(0, 0.01, 1.0);
            m1.record_update(100, 0.01, 1.0);
        }
        assert!((m0.adaptation_rate() - 1.0).abs() < 1e-12);
        assert!((m1.adaptation_rate() - (-1.0f64).exp()).abs() < 1e-9);
        // dropped batches dilute the rate
        let mut m2 = RunMetrics::default();
        for i in 0..10 {
            m2.record_arrival();
            if i % 2 == 0 {
                m2.record_update(0, 0.01, 1.0);
            }
        }
        assert!((m2.adaptation_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles_and_staleness_histogram() {
        let mut m = RunMetrics::default();
        assert_eq!(m.latency_percentile(50.0), 0, "empty -> 0");
        for l in [10, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            m.record_latency(l);
        }
        assert_eq!(m.latency_percentile(50.0), 50);
        assert_eq!(m.latency_percentile(95.0), 100);
        assert_eq!(m.latency_percentile(99.0), 100);
        assert!(m.latency_percentile(50.0) <= m.latency_percentile(95.0));
        m.record_staleness(0);
        m.record_staleness(0);
        m.record_staleness(3);
        m.record_staleness(10_000); // overflow bucket
        assert_eq!(m.staleness_hist[0], 2);
        assert_eq!(m.staleness_hist[3], 1);
        assert_eq!(m.staleness_hist[STALENESS_BUCKETS], 1);
        // aggregation across runs
        let mut agg = RunMetrics::default();
        agg.record_staleness(3);
        agg.absorb_observability(&m);
        assert_eq!(agg.staleness_hist[3], 2);
        assert_eq!(agg.latencies.len(), 10);
        assert!(agg.staleness_summary().contains("τ=0:2"));
    }

    #[test]
    fn percentile_edge_cases_are_total() {
        // empty window: no panic, no division by zero, just 0
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile_u64(&[], p), 0);
        }
        // single sample: every percentile is that sample
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile_u64(&[7], p), 7);
        }
        // out-of-range p clamps to the extremes instead of indexing oob
        assert_eq!(percentile_u64(&[1, 2, 3], -5.0), 1);
        assert_eq!(percentile_u64(&[1, 2, 3], 250.0), 3);
        // unsorted input is handled (the helper sorts a copy)
        assert_eq!(percentile_u64(&[30, 10, 20], 50.0), 20);
        // RunMetrics paths stay safe on empty/single too
        let mut m = RunMetrics::default();
        assert_eq!(m.latency_percentile(99.0), 0);
        assert!(m.latency_summary().contains("n=0"));
        m.record_latency(5);
        assert_eq!(m.latency_percentile(50.0), 5);
        assert_eq!(m.latency_percentile(100.0), 5);
    }

    #[test]
    fn latency_and_drain_samples_are_bounded_and_deterministic() {
        let run = |n: u64| {
            let mut m = RunMetrics::default();
            for i in 0..n {
                m.record_latency(i);
                m.record_replan(i, i, 0.0);
            }
            m
        };
        let n = 10 * OBS_SAMPLE_CAP as u64;
        let m = run(n);
        assert!(m.latencies.len() <= OBS_SAMPLE_CAP, "{}", m.latencies.len());
        assert!(m.latencies.len() > OBS_SAMPLE_CAP / 4, "decimation keeps coverage");
        assert!(m.drains.len() <= OBS_SAMPLE_CAP);
        // downsampled, not truncated: samples still span the run
        assert!(m.latencies[0] < 64, "head stays early: {}", m.latencies[0]);
        assert!(*m.latencies.last().unwrap() > n / 2, "tail stays recent");
        // exact counters are unaffected by sample decimation
        assert_eq!(m.replans, n);
        assert_eq!(m.plan_trace.len(), n as usize);
        // deterministic: the same sequence keeps the same samples
        assert_eq!(m.latencies, run(n).latencies);
        assert_eq!(m.drains, run(n).drains);
        // below the cap nothing is dropped
        let small = run(100);
        assert_eq!(small.latencies.len(), 100);
        assert_eq!(small.drains.len(), 100);
    }

    #[test]
    fn utilization_and_bubble_fraction() {
        let mut m = RunMetrics::default();
        assert_eq!(m.utilization(), 0.0, "no device time integrated yet");
        assert_eq!(m.bubble_frac(), 0.0);
        m.note_busy(30);
        m.note_busy(30);
        m.integrate_device_time(2, 40); // 2 devices over 40 ticks
        assert!((m.utilization() - 0.75).abs() < 1e-12);
        assert!((m.bubble_frac() - 0.25).abs() < 1e-12);
        // measured busy nudged past the integral clamps at 0 bubble
        m.note_busy(100);
        assert_eq!(m.bubble_frac(), 0.0);
        // aggregation folds the integrals
        let mut agg = RunMetrics::default();
        agg.absorb_observability(&m);
        assert_eq!(agg.busy_us, 160);
        assert_eq!(agg.device_us, 80);
    }

    #[test]
    fn tacc_eval_on_separable_data() {
        use crate::backend::native::NativeBackend;
        use crate::config::Act;
        // identity-ish single layer, prototypes on axes -> near-perfect
        let shapes = [LayerShape { in_dim: 4, out_dim: 4, act: Act::None }];
        let mut w = vec![0.0f32; 16];
        for i in 0..4 {
            w[i * 4 + i] = 1.0;
        }
        let params = [LayerParams { w, b: vec![0.0; 4] }];
        let mut x = Vec::new();
        let mut y = Vec::new();
        for c in 0..4 {
            for _ in 0..5 {
                let mut row = vec![0.0f32; 4];
                row[c] = 3.0;
                x.extend(row);
                y.push(c as i32);
            }
        }
        let ts = TestSet { x, y, n: 20 };
        let acc = eval_tacc(&NativeBackend, &shapes, &params, 4, &ts, 7);
        assert_eq!(acc, 100.0);
    }
}
