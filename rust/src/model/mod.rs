//! Model state: per-layer parameters, gradients, initialization, and the
//! version stash used by asynchronous pipeline schedules (weight stashing /
//! Iter-Fisher delta chains).

pub mod params;
pub mod stash;

pub use params::{GradBuf, LayerParams, ModelParams};
pub use stash::VersionStash;
