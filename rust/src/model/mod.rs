//! Model state: per-layer parameters, gradients, initialization, and the
//! version stash used by asynchronous pipeline schedules (weight stashing /
//! Iter-Fisher delta chains). Live parameters are `Arc`-shared
//! ([`SharedParams`]) so the stash, the planner, and executor device
//! threads can all hold the same snapshot without copies.

pub mod params;
pub mod stash;

pub use params::{GradBuf, LayerParams, LiveParams, ModelParams, SharedParams};
pub use stash::{StashSet, VersionStash};
