//! Weight-version stash for asynchronous pipeline stages.
//!
//! Each pipeline stage keeps the parameter snapshots its in-flight
//! microbatches were forwarded with (PipeDream-style weight stashing).
//! Iter-Fisher additionally walks the chain of *consecutive* versions
//! between the stashed fwd version and the live version, so the stash keeps
//! a bounded history of `(version, params)` pairs and can produce the
//! per-step deltas Δθ^{v→v+1} needed by Eq. 9.
//!
//! Entries are [`SharedParams`] (`Arc`s): pushing a version is an `Arc`
//! clone, not a buffer copy, and the same snapshot can simultaneously be
//! live, stashed, and in flight on an executor device thread.
//! [`VersionStash::bytes`] still reports *logical* bytes (one full copy
//! per stashed version) to stay comparable with the analytic Eq. 4
//! footprint the planner optimizes.

use crate::model::params::{GradBuf, LiveParams, SharedParams};
use std::collections::VecDeque;

/// Bounded history of parameter versions for one layer.
#[derive(Debug, Clone)]
pub struct VersionStash {
    cap: usize,
    entries: VecDeque<(u64, SharedParams)>,
}

impl VersionStash {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 2, "stash must hold at least two versions");
        VersionStash { cap, entries: VecDeque::new() }
    }

    /// Record a new version snapshot (monotonically increasing versions).
    /// Returns the evicted snapshot, if the cap forced one out — callers
    /// on the hot path recycle its buffers through the engine pool once
    /// the `Arc` is unique (cap ≥ 2 means at most one eviction per push).
    pub fn push(&mut self, version: u64, params: SharedParams) -> Option<SharedParams> {
        if let Some((last, _)) = self.entries.back() {
            assert!(version > *last, "versions must increase");
        }
        self.entries.push_back((version, params));
        let mut evicted = None;
        while self.entries.len() > self.cap {
            evicted = self.entries.pop_front().map(|(_, p)| p);
        }
        evicted
    }

    pub fn latest_version(&self) -> Option<u64> {
        self.entries.back().map(|(v, _)| *v)
    }

    pub fn get(&self, version: u64) -> Option<&SharedParams> {
        self.entries.iter().find(|(v, _)| *v == version).map(|(_, p)| p)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Consecutive-version deltas Δθ^{v→v+1} for v in [from, to), oldest
    /// first — the chain Iter-Fisher applies one `A(·)` step per element.
    /// Returns None if any required version has been evicted.
    pub fn delta_chain(&self, from: u64, to: u64) -> Option<Vec<GradBuf>> {
        if from > to {
            return None;
        }
        let mut chain = Vec::with_capacity((to - from) as usize);
        for v in from..to {
            let old = self.get(v)?.clone();
            let new = self.get(v + 1)?;
            chain.push(new.delta(&old));
        }
        Some(chain)
    }

    /// Single-jump delta θ_to − θ_from (the non-iterative Fisher baseline).
    pub fn jump_delta(&self, from: u64, to: u64) -> Option<GradBuf> {
        let old = self.get(from)?.clone();
        Some(self.get(to)?.delta(&old))
    }

    /// Logical bytes held by the stash (for the measured-memory
    /// cross-check against Eq. 4; Arc sharing makes the physical footprint
    /// smaller).
    pub fn bytes(&self) -> usize {
        self.entries
            .iter()
            .map(|(_, p)| p.param_count() * std::mem::size_of::<f32>())
            .sum()
    }

    /// Bytes of stashed versions that are *not* the same physical `Arc`
    /// as `live` — the memory ledger's physical accounting (the newest
    /// entry aliases the live copy right after every update).
    pub fn bytes_excl(&self, live: &SharedParams) -> usize {
        self.entries
            .iter()
            .filter(|(_, p)| !std::sync::Arc::ptr_eq(p, live))
            .map(|(_, p)| p.param_count() * std::mem::size_of::<f32>())
            .sum()
    }
}

/// Per-layer version stashes of a whole model — the stage-state bookkeeping
/// the async engines delegate their weight stashing to.
#[derive(Debug, Clone)]
pub struct StashSet {
    stashes: Vec<VersionStash>,
}

impl StashSet {
    /// One stash per layer, seeded with version 0 of the live parameters.
    pub fn new(live: &LiveParams, cap: usize) -> Self {
        let stashes = live
            .layers
            .iter()
            .map(|p| {
                let mut s = VersionStash::new(cap.max(2));
                s.push(0, p.clone());
                s
            })
            .collect();
        StashSet { stashes }
    }

    /// Resolve the parameters layer `l` was forwarded with at `version`,
    /// falling back to the live copy (zero staleness) after eviction.
    pub fn resolve(&self, l: usize, version: u64, live: &LiveParams) -> SharedParams {
        match self.stashes[l].get(version) {
            Some(p) => p.clone(),
            None => live.layers[l].clone(),
        }
    }

    /// Record the new version of every layer in `layers` after a stage
    /// update. Returns the snapshots the caps evicted (at most one per
    /// layer) so the caller can recycle their buffers.
    pub fn push_stage(
        &mut self,
        layers: &[usize],
        version: u64,
        live: &LiveParams,
    ) -> Vec<SharedParams> {
        let mut evicted = Vec::new();
        for &l in layers {
            if let Some(p) = self.stashes[l].push(version, live.layers[l].clone()) {
                evicted.push(p);
            }
        }
        evicted
    }

    pub fn delta_chain(&self, l: usize, from: u64, to: u64) -> Option<Vec<GradBuf>> {
        self.stashes[l].delta_chain(from, to)
    }

    pub fn jump_delta(&self, l: usize, from: u64, to: u64) -> Option<GradBuf> {
        self.stashes[l].jump_delta(from, to)
    }

    /// Logical bytes across all layers (measured-memory cross-check).
    pub fn bytes(&self) -> usize {
        self.stashes.iter().map(|s| s.bytes()).sum()
    }

    /// Physical bytes across all layers, excluding entries that alias the
    /// live copy (see [`VersionStash::bytes_excl`]) — what the memory
    /// ledger charges for weight stashing.
    pub fn bytes_excl_live(&self, live: &LiveParams) -> usize {
        self.stashes.iter().zip(&live.layers).map(|(s, p)| s.bytes_excl(p)).sum()
    }

    pub fn layer(&self, l: usize) -> &VersionStash {
        &self.stashes[l]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::LayerParams;
    use std::sync::Arc;

    fn p(v: f32) -> SharedParams {
        Arc::new(LayerParams { w: vec![v, v * 2.0], b: vec![v * 3.0] })
    }

    #[test]
    fn push_get_evict() {
        let mut s = VersionStash::new(3);
        for v in 0..5u64 {
            s.push(v, p(v as f32));
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.latest_version(), Some(4));
        assert!(s.get(1).is_none(), "evicted");
        assert_eq!(s.get(3).unwrap().w, vec![3.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn non_monotone_push_panics() {
        let mut s = VersionStash::new(3);
        s.push(2, p(1.0));
        s.push(1, p(2.0));
    }

    #[test]
    fn delta_chain_consecutive() {
        let mut s = VersionStash::new(8);
        for v in 0..4u64 {
            s.push(v, p(v as f32));
        }
        let chain = s.delta_chain(1, 3).unwrap();
        assert_eq!(chain.len(), 2);
        // each step is +1.0 on first weight, +2.0 on second, +3.0 on bias
        for d in &chain {
            assert_eq!(d.gw, vec![1.0, 2.0]);
            assert_eq!(d.gb, vec![3.0]);
        }
        // empty chain when from == to
        assert_eq!(s.delta_chain(2, 2).unwrap().len(), 0);
        // missing (evicted) version -> None
        let mut s2 = VersionStash::new(2);
        for v in 0..4u64 {
            s2.push(v, p(v as f32));
        }
        assert!(s2.delta_chain(0, 3).is_none());
    }

    #[test]
    fn jump_delta_matches_chain_sum() {
        let mut s = VersionStash::new(8);
        for v in 0..4u64 {
            s.push(v, p((v * v) as f32));
        }
        let jump = s.jump_delta(0, 3).unwrap();
        let chain = s.delta_chain(0, 3).unwrap();
        let mut sum = GradBuf { gw: vec![0.0; 2], gb: vec![0.0; 1] };
        for d in &chain {
            sum.add(d);
        }
        assert_eq!(jump.gw, sum.gw);
        assert_eq!(jump.gb, sum.gb);
    }

    #[test]
    fn bytes_accounting() {
        let mut s = VersionStash::new(4);
        s.push(0, p(1.0));
        s.push(1, p(2.0));
        assert_eq!(s.bytes(), 2 * 3 * 4);
    }

    #[test]
    fn bytes_excl_skips_entries_aliasing_live() {
        let mut s = VersionStash::new(4);
        let live = p(9.0);
        s.push(0, p(1.0));
        s.push(1, live.clone()); // the newest entry aliases the live copy
        assert_eq!(s.bytes(), 2 * 3 * 4, "logical bytes count both");
        assert_eq!(s.bytes_excl(&live), 3 * 4, "physical bytes skip the alias");
        // an equal-valued but distinct allocation still counts
        let other = p(9.0);
        assert_eq!(s.bytes_excl(&other), 2 * 3 * 4);
    }

    #[test]
    fn stash_set_resolves_with_live_fallback() {
        let spec = crate::config::ModelSpec { name: "t".into(), dims: vec![3, 2, 2] };
        let mut live = LiveParams::init(&spec, 1);
        let mut set = StashSet::new(&live, 2);
        assert_eq!(set.bytes(), (3 * 2 + 2 + 2 * 2 + 2) * 4);
        // update layer 0 through three versions; cap 2 evicts version 0
        for ver in 1..=3u64 {
            live.set(0, LayerParams { w: vec![ver as f32; 6], b: vec![0.0; 2] });
            set.push_stage(&[0], ver, &live);
        }
        // evicted version resolves to the live copy (zero staleness)
        assert_eq!(set.resolve(0, 0, &live).w, live.layers[0].w);
        // retained version resolves to its snapshot
        assert_eq!(set.resolve(0, 2, &live).w, vec![2.0; 6]);
        assert!(set.delta_chain(0, 0, 3).is_none(), "evicted chain");
        assert!(set.delta_chain(0, 2, 3).is_some());
        assert_eq!(set.layer(1).latest_version(), Some(0), "untouched layer");
    }
}
