//! Layer parameter and gradient buffers.
//!
//! Live parameters are held as [`SharedParams`] (`Arc<LayerParams>`):
//! engines update copy-on-write (each SGD step replaces the `Arc`), so
//! executor device threads and the version stash keep the exact snapshot
//! they were handed without cloning buffers.

use crate::config::{LayerShape, ModelSpec};
use crate::util::Rng;
use std::sync::Arc;

/// Parameters of one dense layer (row-major w: in_dim x out_dim).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerParams {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl LayerParams {
    /// He-uniform init for ReLU stacks (bias zero).
    pub fn init(shape: &LayerShape, rng: &mut Rng) -> Self {
        let bound = (6.0 / shape.in_dim as f32).sqrt();
        let w = (0..shape.in_dim * shape.out_dim)
            .map(|_| rng.range_f32(-bound, bound))
            .collect();
        LayerParams { w, b: vec![0.0; shape.out_dim] }
    }

    pub fn zeros(shape: &LayerShape) -> Self {
        LayerParams {
            w: vec![0.0; shape.in_dim * shape.out_dim],
            b: vec![0.0; shape.out_dim],
        }
    }

    pub fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    /// Tear a (uniquely owned) layer into its raw buffers — how retired
    /// parameter versions travel back into the engine's
    /// [`crate::backend::BufferPool`] once their `Arc` count hits one.
    pub fn into_buffers(self) -> (Vec<f32>, Vec<f32>) {
        (self.w, self.b)
    }

    /// Elementwise delta `self - other` (for Iter-Fisher version steps).
    pub fn delta(&self, other: &LayerParams) -> GradBuf {
        debug_assert_eq!(self.w.len(), other.w.len());
        GradBuf {
            gw: self.w.iter().zip(&other.w).map(|(a, b)| a - b).collect(),
            gb: self.b.iter().zip(&other.b).map(|(a, b)| a - b).collect(),
        }
    }
}

/// Gradient (or parameter-delta) buffer of one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct GradBuf {
    pub gw: Vec<f32>,
    pub gb: Vec<f32>,
}

impl GradBuf {
    pub fn zeros_like(p: &LayerParams) -> Self {
        GradBuf { gw: vec![0.0; p.w.len()], gb: vec![0.0; p.b.len()] }
    }

    pub fn add(&mut self, other: &GradBuf) {
        for (a, b) in self.gw.iter_mut().zip(&other.gw) {
            *a += b;
        }
        for (a, b) in self.gb.iter_mut().zip(&other.gb) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        self.gw.iter_mut().for_each(|x| *x *= s);
        self.gb.iter_mut().for_each(|x| *x *= s);
    }

    pub fn norm2(&self) -> f64 {
        crate::util::norm2(&self.gw) + crate::util::norm2(&self.gb)
    }
}

/// Full-model parameters: one `LayerParams` per layer.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelParams {
    pub layers: Vec<LayerParams>,
}

impl ModelParams {
    pub fn init(spec: &ModelSpec, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x4D4F44454C);
        ModelParams {
            layers: spec.layers().iter().map(|s| LayerParams::init(s, &mut rng)).collect(),
        }
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Wrap each layer in an `Arc` for engines that share parameters with
    /// executor threads / the version stash.
    pub fn into_shared(self) -> Vec<SharedParams> {
        self.layers.into_iter().map(Arc::new).collect()
    }
}

/// One layer's parameters, shareable across threads and stash versions.
pub type SharedParams = Arc<LayerParams>;

/// The live (most recent) full-model parameters of an engine run.
///
/// Updates go through [`LiveParams::set`], which installs a *new* `Arc`
/// per layer: snapshots previously handed to in-flight pipeline work or
/// pushed into a [`crate::model::VersionStash`] stay untouched — this is
/// what makes asynchronous weight stashing race-free under the threaded
/// executor.
#[derive(Debug, Clone)]
pub struct LiveParams {
    pub layers: Vec<SharedParams>,
}

impl LiveParams {
    pub fn init(spec: &ModelSpec, seed: u64) -> Self {
        LiveParams { layers: ModelParams::init(spec, seed).into_shared() }
    }

    pub fn layer(&self, l: usize) -> &SharedParams {
        &self.layers[l]
    }

    /// Install freshly updated parameters for layer `l` (copy-on-write).
    pub fn set(&mut self, l: usize, p: LayerParams) {
        self.layers[l] = Arc::new(p);
    }

    /// Like [`LiveParams::set`], but hands back the retired snapshot so
    /// the engine can recycle its buffers once no stash/flight aliases it.
    pub fn replace(&mut self, l: usize, p: LayerParams) -> SharedParams {
        std::mem::replace(&mut self.layers[l], Arc::new(p))
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Act;

    fn shape(i: usize, o: usize) -> LayerShape {
        LayerShape { in_dim: i, out_dim: o, act: Act::Relu }
    }

    #[test]
    fn init_shapes_and_bounds() {
        let mut rng = Rng::new(0);
        let s = shape(24, 8);
        let p = LayerParams::init(&s, &mut rng);
        assert_eq!(p.w.len(), 24 * 8);
        assert_eq!(p.b, vec![0.0; 8]);
        let bound = (6.0f32 / 24.0).sqrt();
        assert!(p.w.iter().all(|&w| w.abs() <= bound));
        assert!(p.w.iter().any(|&w| w != 0.0));
        assert_eq!(p.param_count(), 24 * 8 + 8);
    }

    #[test]
    fn init_deterministic_by_seed() {
        let spec = ModelSpec { name: "t".into(), dims: vec![5, 4, 3] };
        let a = ModelParams::init(&spec, 9);
        let b = ModelParams::init(&spec, 9);
        let c = ModelParams::init(&spec, 10);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.param_count(), 5 * 4 + 4 + 4 * 3 + 3);
    }

    #[test]
    fn gradbuf_ops() {
        let p = LayerParams { w: vec![1.0, 2.0], b: vec![3.0] };
        let q = LayerParams { w: vec![0.5, 1.0], b: vec![1.0] };
        let d = p.delta(&q);
        assert_eq!(d.gw, vec![0.5, 1.0]);
        assert_eq!(d.gb, vec![2.0]);
        let mut g = GradBuf::zeros_like(&p);
        g.add(&d);
        g.add(&d);
        g.scale(0.5);
        assert_eq!(g.gw, vec![0.5, 1.0]);
        assert_eq!(g.gb, vec![2.0]);
        assert!((g.norm2() - (0.25 + 1.0 + 4.0)).abs() < 1e-9);
    }
}
