//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§6) on the synthetic settings grid. See DESIGN.md §5 for
//! the experiment index.
//!
//! Runs are cached inside a [`Bench`] so artifacts that share a run
//! matrix (Table 1 / Table 7 / Fig. 4 / Fig. 7) execute it only once.

pub mod perf;
pub mod report;

use std::collections::HashMap;

use crate::backend::{native::NativeBackend, Backend};
use crate::baselines::{run_baseline_with_model, StreamPolicy};
use crate::budget::{BudgetSchedule, StepAt};
use crate::compensate::CompKind;
use crate::config::{zoo::default_zoo, ModelSpec, Zoo};
use crate::metrics::{agm, RunMetrics};
use crate::ocl::{OclKind, OclPlugin};
use crate::pipeline::RunResult;
use crate::pipeline::engine::{AsyncCfg, AsyncSchedule};
use crate::pipeline::executor::ExecutorKind;
use crate::pipeline::sched::Mode;
use crate::pipeline::sync::{run_sync, SyncSchedule};
use crate::pipeline::{EngineParams, Session};
use crate::planner::{plan, Partition, Profile};
use crate::stream::{paper_settings, Setting, SyntheticStream};
pub use crate::util::math::pearson;
pub use report::{Cell, Table};

/// Ferret memory tiers of the paper's tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// minimal feasible budget ("Ferret_M-")
    Min,
    /// same budget as PipeDream-2BW ("Ferret_M")
    Med,
    /// unconstrained ("Ferret_M+")
    Max,
}

/// A method column in the tables.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    Baseline(StreamPolicy),
    Sync(SyncSchedule),
    Async(AsyncSchedule),
    Ferret { tier: Tier, comp: CompKind },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Baseline(p) => p.name().to_string(),
            Method::Sync(s) => s.name(),
            Method::Async(a) => a.name().to_string(),
            Method::Ferret { tier, comp } => {
                let t = match tier {
                    Tier::Min => "Ferret_M-",
                    Tier::Med => "Ferret_M",
                    Tier::Max => "Ferret_M+",
                };
                match comp {
                    CompKind::IterFisher => t.to_string(),
                    other => format!("{t}/{}", other.name()),
                }
            }
        }
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchCfg {
    /// stream length in microbatches per run
    pub num_batches: usize,
    pub seeds: Vec<u64>,
    /// indices into `paper_settings()`; None = all 20
    pub settings: Option<Vec<usize>>,
    pub lr: f32,
    pub quiet: bool,
    /// executor for the async engines (sim = virtual-time inline,
    /// threaded = one OS thread per (worker, stage) device)
    pub executor: ExecutorKind,
    /// time mode for the async engines (lockstep = virtual event heap,
    /// freerun = wall-clock pacing with device-thread updates)
    pub mode: Mode,
    /// budget schedule for the budget-shift table (None = halve the
    /// unconstrained footprint at mid-stream, per model)
    pub budget_schedule: Option<BudgetSchedule>,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg {
            num_batches: 160,
            seeds: vec![1, 2],
            settings: None,
            lr: 0.04,
            quiet: false,
            executor: ExecutorKind::Sim,
            mode: Mode::Lockstep,
            budget_schedule: None,
        }
    }
}

impl BenchCfg {
    /// CI/smoke configuration: two settings, short streams, one seed.
    pub fn quick() -> Self {
        BenchCfg {
            num_batches: 40,
            seeds: vec![1],
            settings: Some(vec![0, 19]),
            lr: 0.05,
            quiet: true,
            ..Default::default()
        }
    }
}

/// Run cache + model/plan memos.
pub struct Bench {
    pub cfg: BenchCfg,
    zoo: Zoo,
    backend: Box<dyn Backend>,
    runs: HashMap<(usize, String, u64), RunMetrics>,
    plans: HashMap<(String, u64), (Partition, Profile, u64)>, // model -> shared partition
    /// max executor threads observed across async runs (observability for
    /// the `--executor threaded` mode)
    pub max_threads_seen: usize,
    /// total microbatches pushed through engines (wall-clock throughput)
    pub batches_run: u64,
    /// latency samples + staleness histogram aggregated across every async
    /// run (reported after `--mode freerun` sweeps)
    pub observability: RunMetrics,
}

impl Bench {
    pub fn new(cfg: BenchCfg) -> Self {
        Bench {
            cfg,
            zoo: default_zoo().expect("zoo"),
            backend: Box::new(NativeBackend),
            runs: HashMap::new(),
            plans: HashMap::new(),
            max_threads_seen: 0,
            batches_run: 0,
            observability: RunMetrics::default(),
        }
    }

    /// Swap in a different backend (e.g. the XLA/PJRT one).
    pub fn with_backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.backend = backend;
        self
    }

    pub fn settings(&self) -> Vec<(usize, Setting)> {
        let all = paper_settings();
        match &self.cfg.settings {
            Some(idx) => idx.iter().map(|&i| (i, all[i].clone())).collect(),
            None => all.into_iter().enumerate().collect(),
        }
    }

    fn model(&self, s: &Setting) -> ModelSpec {
        self.zoo.model(s.model).expect("model").clone()
    }

    fn stream(&self, s: &Setting, seed: u64) -> SyntheticStream {
        let n = self.cfg.num_batches;
        self.stream_slice(s, seed, 0, n, n)
    }

    /// A window of a setting's stream: `skip` batches consumed, then
    /// `len` yielded, out of a `total`-batch stream. The drift/task
    /// schedule follows `total`, so windows of the same stream are
    /// distribution-consistent with each other (the restart baseline
    /// trains on the head and tail of one stream, not two different
    /// compressed ones).
    fn stream_slice(
        &self,
        s: &Setting,
        seed: u64,
        skip: usize,
        len: usize,
        total: usize,
    ) -> SyntheticStream {
        let m = self.model(s);
        let mut stream = SyntheticStream::new(s.stream_spec(
            m.features(),
            m.classes(),
            self.zoo.batch,
            total,
            seed,
        ));
        for _ in 0..skip {
            let _ = stream.next_batch();
        }
        stream.truncate_after(len);
        stream
    }

    /// One async-engine run through the session API with this bench's
    /// executor/mode/batch configuration — the single construction point
    /// for every engine session the harness opens.
    fn session_run(
        &self,
        cfg: AsyncCfg,
        ep: EngineParams,
        model: &ModelSpec,
        plugin: &mut dyn OclPlugin,
        stream: &mut SyntheticStream,
    ) -> RunResult {
        Session::builder(self.backend.as_ref(), model)
            .config(cfg)
            .plugin(plugin)
            .engine_params(ep)
            .executor(self.cfg.executor)
            .mode(self.cfg.mode)
            .batch(self.zoo.batch)
            .build()
            .expect("engine session")
            .run_stream(stream)
            .expect("harness stream matches the model")
    }

    /// Run one explicitly-configured engine outside the cached `run()`
    /// matrix — the shared bookkeeping (thread/observability/batch
    /// counters) every direct engine run must keep honest. `skip`/`len`
    /// window the stream; `weight_seed` seeds the model init (the restart
    /// baseline uses a fresh one).
    #[allow(clippy::too_many_arguments)]
    pub fn run_planned(
        &mut self,
        setting: &Setting,
        model: &ModelSpec,
        cfg: AsyncCfg,
        stream_seed: u64,
        skip: usize,
        len: usize,
        total: usize,
        weight_seed: u64,
    ) -> RunMetrics {
        let mut stream = self.stream_slice(setting, stream_seed, skip, len, total);
        let mut plugin = OclKind::Vanilla.build(stream_seed);
        let ep = EngineParams { lr: self.cfg.lr, seed: weight_seed, ..Default::default() };
        let r = self.session_run(cfg, ep, model, plugin.as_mut(), &mut stream);
        self.max_threads_seen = self.max_threads_seen.max(r.metrics.exec_threads);
        self.batches_run += len as u64;
        self.observability.absorb_observability(&r.metrics);
        r.metrics
    }

    /// Shared (unconstrained-planned) partition per model — §12: "L* and
    /// C* are pre-determined and shared for all pipeline parallelism
    /// strategies".
    fn shared_partition(&mut self, model: &ModelSpec) -> (Partition, Profile, u64) {
        if let Some(v) = self.plans.get(&(model.name.clone(), 0)) {
            return v.clone();
        }
        let prof = Profile::analytic(model, self.zoo.batch);
        let td = prof.default_td();
        let out = plan(&prof, td, f64::INFINITY, crate::planner::costmodel::decay_for_td(td));
        let v = (out.partition, prof, td);
        self.plans.insert((model.name.clone(), 0), v.clone());
        v
    }

    /// Budget (bytes) for a Ferret tier on this model.
    pub fn tier_budget(&mut self, model: &ModelSpec, tier: Tier) -> f64 {
        let (part, prof, td) = self.shared_partition(model);
        match tier {
            Tier::Max => f64::INFINITY,
            Tier::Med => {
                // PipeDream-2BW's footprint (Eq. 4 at accum=2, no T1/T3)
                let mut pipe = crate::planner::costmodel::PipeConfig::initial(
                    part.num_stages(),
                    part.tf(&prof),
                    part.tb(&prof),
                    false,
                    td,
                );
                for w in &mut pipe.workers {
                    w.accum = vec![2; part.num_stages()];
                }
                crate::planner::costmodel::mem_footprint(&part, &prof, &pipe)
            }
            Tier::Min => {
                // one worker, every reducible stage fully omitted
                let p = part.num_stages();
                let mut pipe = crate::planner::costmodel::PipeConfig::initial(
                    p,
                    part.tf(&prof),
                    part.tb(&prof),
                    false,
                    td,
                );
                pipe.workers.truncate(1);
                for j in 0..p.saturating_sub(1) {
                    pipe.workers[0].omit[j] = (p - 1 - j) as u64;
                }
                crate::planner::costmodel::mem_footprint(&part, &prof, &pipe) * 1.05
            }
        }
    }

    /// Execute (or fetch) one run.
    pub fn run(
        &mut self,
        setting_idx: usize,
        setting: &Setting,
        method: Method,
        ocl: OclKind,
        seed: u64,
    ) -> RunMetrics {
        let key = (setting_idx, format!("{}/{}", method.name(), ocl.name()), seed);
        if let Some(m) = self.runs.get(&key) {
            return m.clone();
        }
        if !self.cfg.quiet {
            eprintln!("[bench] {} {} {} seed={seed}", setting.label, method.name(), ocl.name());
        }
        let model = self.model(setting);
        let mut stream = self.stream(setting, seed);
        let mut plugin = ocl.build(seed);
        let ep = EngineParams { lr: self.cfg.lr, seed, ..Default::default() };
        let result = match method {
            Method::Baseline(policy) => run_baseline_with_model(
                policy,
                &mut stream,
                self.backend.as_ref(),
                plugin.as_mut(),
                &ep,
                &model,
            ),
            Method::Sync(schedule) => {
                let (part, _, _) = self.shared_partition(&model);
                run_sync(
                    schedule,
                    &mut stream,
                    self.backend.as_ref(),
                    plugin.as_mut(),
                    &ep,
                    &model,
                    &part,
                )
            }
            Method::Async(schedule) => {
                let (part, prof, td) = self.shared_partition(&model);
                let cfg = AsyncCfg::baseline(schedule, part, &prof, td);
                self.session_run(cfg, ep, &model, plugin.as_mut(), &mut stream)
            }
            Method::Ferret { tier, comp } => {
                let budget = self.tier_budget(&model, tier);
                let (_, prof, td) = self.shared_partition(&model);
                let out = plan(&prof, td, budget, crate::planner::costmodel::decay_for_td(td));
                let cfg = AsyncCfg::ferret(out.partition, out.config, comp);
                self.session_run(cfg, ep, &model, plugin.as_mut(), &mut stream)
            }
        };
        self.observability.absorb_observability(&result.metrics);
        self.max_threads_seen = self.max_threads_seen.max(result.metrics.exec_threads);
        self.batches_run += self.cfg.num_batches as u64;
        self.runs.insert(key, result.metrics.clone());
        result.metrics
    }

    // -----------------------------------------------------------------
    // Experiment families
    // -----------------------------------------------------------------

    /// Table 1's method list.
    pub fn table1_methods() -> Vec<Method> {
        let mut m: Vec<Method> = StreamPolicy::table1().into_iter().map(Method::Baseline).collect();
        for tier in [Tier::Min, Tier::Med, Tier::Max] {
            m.push(Method::Ferret { tier, comp: CompKind::IterFisher });
        }
        m
    }

    /// Table 3's method list (B = DAPPLE; async without compensation).
    pub fn table3_methods() -> Vec<Method> {
        vec![
            Method::Sync(SyncSchedule::Dapple),
            Method::Sync(SyncSchedule::ZeroBubble),
            Method::Sync(SyncSchedule::Hanayo { waves: 1 }),
            Method::Sync(SyncSchedule::Hanayo { waves: 2 }),
            Method::Sync(SyncSchedule::Hanayo { waves: 3 }),
            Method::Async(AsyncSchedule::Pipedream),
            Method::Async(AsyncSchedule::Pipedream2BW),
            Method::Ferret { tier: Tier::Med, comp: CompKind::NoComp },
        ]
    }

    /// One metric column table over (settings x methods), averaged over
    /// seeds, where `value` extracts the number from (run, baseline-run).
    fn grid_table(
        &mut self,
        title: &str,
        methods: &[Method],
        baseline: Method,
        ocl: OclKind,
        value: impl Fn(&RunMetrics, &RunMetrics) -> f64,
    ) -> Table {
        let cols = methods.iter().map(|m| m.name()).collect();
        let mut table = Table::new(title, cols);
        let seeds = self.cfg.seeds.clone();
        for (idx, setting) in self.settings() {
            let mut cells = Vec::new();
            for &method in methods {
                let samples: Vec<f64> = seeds
                    .iter()
                    .map(|&seed| {
                        let base = self.run(idx, &setting, baseline, ocl, seed);
                        let m = self.run(idx, &setting, method, ocl, seed);
                        value(&m, &base)
                    })
                    .collect();
                cells.push(Some(Cell::from_samples(&samples)));
            }
            table.push_row(setting.label, cells);
        }
        table
    }

    /// Table 1: agm vs 1-Skip across the 20-settings grid.
    pub fn table1(&mut self) -> Table {
        self.grid_table(
            "Table 1 — Online Accuracy Gain per unit of Memory (agm, B = 1-Skip)",
            &Self::table1_methods(),
            Method::Baseline(StreamPolicy::OneSkip),
            OclKind::Vanilla,
            |m, b| agm(m.oacc.value(), b.oacc.value(), m.mem_bytes, b.mem_bytes),
        )
    }

    /// Table 7 (appendix): raw online accuracy, same runs as Table 1.
    pub fn table7(&mut self) -> Table {
        self.grid_table(
            "Table 7 — Online Accuracy (%)",
            &Self::table1_methods(),
            Method::Baseline(StreamPolicy::OneSkip),
            OclKind::Vanilla,
            |m, _| m.oacc.value(),
        )
    }

    /// Fig. 4 / Fig. 10: consumed memory (MB) per method, same runs.
    pub fn fig4(&mut self) -> Table {
        self.grid_table(
            "Fig. 4 — Consumed memory (MB) of stream learning algorithms",
            &Self::table1_methods(),
            Method::Baseline(StreamPolicy::OneSkip),
            OclKind::Vanilla,
            |m, _| m.mem_bytes / 1e6,
        )
    }

    /// Table 3: agm vs DAPPLE for pipeline-parallel strategies.
    pub fn table3(&mut self) -> Table {
        self.grid_table(
            "Table 3 — agm of pipeline parallelism strategies (B = DAPPLE)",
            &Self::table3_methods(),
            Method::Sync(SyncSchedule::Dapple),
            OclKind::Vanilla,
            |m, b| agm(m.oacc.value(), b.oacc.value(), m.mem_bytes, b.mem_bytes),
        )
    }

    /// Table 2 + Table 8: OCL plugins on CORe50/ConvNet across frameworks.
    /// Returns (table2 agm/tagm, table8 oacc/tacc).
    pub fn table2_and_8(&mut self) -> (Table, Table) {
        let core50 = 7; // index in paper_settings()
        let setting = paper_settings()[core50].clone();
        let methods = Self::table1_methods();
        let cols: Vec<String> = methods.iter().map(|m| m.name()).collect();
        let mut t2 = Table::new(
            "Table 2 — agm / tagm of integrated OCL algorithms on CORe50/ConvNet (B = 1-Skip)",
            cols.clone(),
        );
        let mut t8 = Table::new(
            "Table 8 — oacc / tacc of integrated OCL algorithms on CORe50/ConvNet",
            cols,
        );
        let seeds = self.cfg.seeds.clone();
        for ocl in OclKind::all() {
            // Camel has its own forgetting component (paper: not integrable)
            let skip_camel = ocl != OclKind::Vanilla;
            let mut agm_cells = Vec::new();
            let mut tagm_cells = Vec::new();
            let mut oacc_cells = Vec::new();
            let mut tacc_cells = Vec::new();
            for &method in &methods {
                if skip_camel && matches!(method, Method::Baseline(StreamPolicy::Camel { .. })) {
                    agm_cells.push(None);
                    tagm_cells.push(None);
                    oacc_cells.push(None);
                    tacc_cells.push(None);
                    continue;
                }
                let mut agms = Vec::new();
                let mut tagms = Vec::new();
                let mut oaccs = Vec::new();
                let mut taccs = Vec::new();
                for &seed in &seeds {
                    let base =
                        self.run(core50, &setting, Method::Baseline(StreamPolicy::OneSkip), ocl, seed);
                    let m = self.run(core50, &setting, method, ocl, seed);
                    agms.push(agm(m.oacc.value(), base.oacc.value(), m.mem_bytes, base.mem_bytes));
                    tagms.push(agm(m.tacc, base.tacc, m.mem_bytes, base.mem_bytes));
                    oaccs.push(m.oacc.value());
                    taccs.push(m.tacc);
                }
                agm_cells.push(Some(Cell::from_samples(&agms)));
                tagm_cells.push(Some(Cell::from_samples(&tagms)));
                oacc_cells.push(Some(Cell::from_samples(&oaccs)));
                tacc_cells.push(Some(Cell::from_samples(&taccs)));
            }
            t2.push_row(format!("{} agm", ocl.name()), agm_cells);
            t2.push_row(format!("{} tagm", ocl.name()), tagm_cells);
            t8.push_row(format!("{} oacc", ocl.name()), oacc_cells);
            t8.push_row(format!("{} tacc", ocl.name()), tacc_cells);
        }
        (t2, t8)
    }

    /// Table 4: Δoacc of compensation policies vs no compensation, for
    /// Ferret_M+ and Ferret_M.
    pub fn table4(&mut self) -> Table {
        let comps = [CompKind::StepAware, CompKind::GapAware, CompKind::Fisher, CompKind::IterFisher];
        let mut cols = Vec::new();
        for tier_name in ["M+", "M"] {
            for c in comps {
                cols.push(format!("{tier_name}/{}", c.name()));
            }
        }
        let mut table = Table::new(
            "Table 4 — Online accuracy delta of gradient compensation vs none",
            cols,
        );
        let seeds = self.cfg.seeds.clone();
        for (idx, setting) in self.settings() {
            let mut cells = Vec::new();
            for tier in [Tier::Max, Tier::Med] {
                for comp in comps {
                    let samples: Vec<f64> = seeds
                        .iter()
                        .map(|&seed| {
                            let base = self.run(
                                idx,
                                &setting,
                                Method::Ferret { tier, comp: CompKind::NoComp },
                                OclKind::Vanilla,
                                seed,
                            );
                            let m = self.run(
                                idx,
                                &setting,
                                Method::Ferret { tier, comp },
                                OclKind::Vanilla,
                                seed,
                            );
                            m.oacc.value() - base.oacc.value()
                        })
                        .collect();
                    cells.push(Some(Cell::from_samples(&samples)));
                }
            }
            table.push_row(setting.label, cells);
        }
        table
    }

    /// Fig. 6 / Fig. 11: oacc vs memory across 5 budgets per strategy.
    /// Non-Ferret strategies cannot adapt to a budget, so they contribute
    /// one point each; Ferret contributes the full sweep.
    pub fn fig6(&mut self) -> Table {
        let mut table = Table::new(
            "Fig. 6 — Online accuracy vs memory (budget sweep)",
            vec!["mem_mb".into(), "oacc".into()],
        );
        let seeds = self.cfg.seeds.clone();
        let picks: Vec<(usize, Setting)> = self.settings().into_iter().take(4).collect();
        for (idx, setting) in picks {
            // fixed-memory strategies: one point each
            for method in Self::table3_methods() {
                if matches!(method, Method::Ferret { .. }) {
                    continue;
                }
                let (mems, oaccs): (Vec<f64>, Vec<f64>) = seeds
                    .iter()
                    .map(|&s| {
                        let m = self.run(idx, &setting, method, OclKind::Vanilla, s);
                        (m.mem_bytes / 1e6, m.oacc.value())
                    })
                    .unzip();
                table.push_row(
                    format!("{}/{}", setting.label, method.name()),
                    vec![Some(Cell::from_samples(&mems)), Some(Cell::from_samples(&oaccs))],
                );
            }
            // Ferret: 5 budgets, log-spaced between min and max footprint
            let model = self.model(&setting);
            let lo = self.tier_budget(&model, Tier::Min);
            let hi_run = self.run(
                idx,
                &setting,
                Method::Ferret { tier: Tier::Max, comp: CompKind::IterFisher },
                OclKind::Vanilla,
                seeds[0],
            );
            let hi = hi_run.mem_bytes.max(lo * 2.0);
            for k in 0..5 {
                let frac = k as f64 / 4.0;
                let budget = lo * (hi / lo).powf(frac);
                let (_, prof, td) = self.shared_partition(&model);
                let out = plan(&prof, td, budget, crate::planner::costmodel::decay_for_td(td));
                let n = self.cfg.num_batches;
                let mut mems = Vec::new();
                let mut oaccs = Vec::new();
                for &seed in &seeds {
                    let cfg = AsyncCfg::ferret(
                        out.partition.clone(),
                        out.config.clone(),
                        CompKind::IterFisher,
                    );
                    let m = self.run_planned(&setting, &model, cfg, seed, 0, n, n, seed);
                    mems.push(m.mem_bytes / 1e6);
                    oaccs.push(m.oacc.value());
                }
                table.push_row(
                    format!("{}/Ferret@B{k}", setting.label),
                    vec![Some(Cell::from_samples(&mems)), Some(Cell::from_samples(&oaccs))],
                );
            }
        }
        table
    }

    /// Budget-shift table (dynamic-memory headline): halve the memory
    /// budget mid-stream and compare three responses on the same seeded
    /// stream —
    ///
    ///   - `dynamic`    Ferret under a `BudgetSchedule`: runs the
    ///     unconstrained plan, then drains, re-plans at the halved budget
    ///     and resumes with its learned weights (replans ≥ 1);
    ///   - `static-min` plan once at the halved (post-shift) budget and
    ///     run the whole stream under it — pays the constraint everywhere;
    ///   - `restart`    run the unconstrained plan to the shift point,
    ///     then restart from scratch (fresh weights) at the halved budget
    ///     — what "stop and relaunch the learner" costs in oacc.
    ///
    /// `schedule` overrides the default per-model halving (e.g. from
    /// `ferret_bench --budget-schedule`).
    pub fn budget_shift(&mut self, schedule: Option<&BudgetSchedule>) -> Table {
        let mut table = Table::new(
            "Budget shift — mid-stream halving: live re-plan vs static-min vs restart",
            vec![
                "oacc".into(),
                "mem_mb".into(),
                "replans".into(),
                "util".into(),
                "bubble".into(),
            ],
        );
        let seeds = self.cfg.seeds.clone();
        let n = self.cfg.num_batches;
        let picks: Vec<(usize, Setting)> = self.settings().into_iter().take(2).collect();
        for (_, setting) in picks {
            let model = self.model(&setting);
            let (_, prof, td) = self.shared_partition(&model);
            let decay = crate::planner::costmodel::decay_for_td(td);
            let hi_plan = plan(&prof, td, f64::INFINITY, decay);
            let sched = schedule.cloned().unwrap_or_else(|| {
                BudgetSchedule::step_at_batch((n as u64 / 2).max(1), hi_plan.mem_bytes * 0.5)
            });
            // the baselines answer the same shift the dynamic run sees:
            // split where the schedule's first batch step fires, and size
            // the static-min/restart plans at its final budget
            let shift = sched
                .steps
                .iter()
                .find_map(|s| match s.at {
                    StepAt::Batch(b) if b > 0 => Some(b as usize),
                    _ => None,
                })
                .unwrap_or((n / 2).max(1))
                .min(n);
            let lo_budget = sched
                .steps
                .last()
                .map(|s| s.bytes)
                .unwrap_or(hi_plan.mem_bytes * 0.5);
            let lo_plan = plan(&prof, td, lo_budget, decay);

            // per-method samples: (label, oacc, mem_mb, replans, util, bubble)
            type Samples = (String, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>);
            let mut rows: Vec<Samples> = vec![
                ("dynamic".into(), vec![], vec![], vec![], vec![], vec![]),
                ("static-min".into(), vec![], vec![], vec![], vec![], vec![]),
                ("restart".into(), vec![], vec![], vec![], vec![], vec![]),
            ];
            for &seed in &seeds {
                // dynamic: live re-plan at the schedule step
                let cfg = AsyncCfg::ferret(
                    hi_plan.partition.clone(),
                    hi_plan.config.clone(),
                    CompKind::IterFisher,
                )
                .with_budget(sched.clone());
                let m = self.run_planned(&setting, &model, cfg, seed, 0, n, n, seed);
                rows[0].1.push(m.oacc.value());
                // peak analytic footprint across phases: the dynamic run
                // spent its pre-shift half at the unconstrained plan
                rows[0].2.push(hi_plan.mem_bytes.max(m.mem_bytes) / 1e6);
                rows[0].3.push(m.replans as f64);
                rows[0].4.push(m.utilization());
                rows[0].5.push(m.bubble_frac());

                // static-min: the post-shift budget for the whole stream
                let cfg = AsyncCfg::ferret(
                    lo_plan.partition.clone(),
                    lo_plan.config.clone(),
                    CompKind::IterFisher,
                );
                let m = self.run_planned(&setting, &model, cfg, seed, 0, n, n, seed);
                rows[1].1.push(m.oacc.value());
                rows[1].2.push(m.mem_bytes / 1e6);
                rows[1].3.push(0.0);
                rows[1].4.push(m.utilization());
                rows[1].5.push(m.bubble_frac());

                // restart: first half unconstrained, then fresh weights at
                // the halved budget on the tail of the same stream
                let cfg_a = AsyncCfg::ferret(
                    hi_plan.partition.clone(),
                    hi_plan.config.clone(),
                    CompKind::IterFisher,
                );
                let a = self.run_planned(&setting, &model, cfg_a, seed, 0, shift, n, seed);
                let cfg_b = AsyncCfg::ferret(
                    lo_plan.partition.clone(),
                    lo_plan.config.clone(),
                    CompKind::IterFisher,
                );
                let b = self.run_planned(
                    &setting,
                    &model,
                    cfg_b,
                    seed,
                    shift,
                    n - shift,
                    n,
                    seed ^ 0xFE55,
                );
                let total = a.oacc.count() + b.oacc.count();
                let oacc = if total > 0.0 {
                    (a.oacc.value() * a.oacc.count() + b.oacc.value() * b.oacc.count()) / total
                } else {
                    0.0
                };
                rows[2].1.push(oacc);
                // restart also ran its first half at the unconstrained plan
                rows[2].2.push(a.mem_bytes.max(b.mem_bytes) / 1e6);
                rows[2].3.push(0.0);
                // pool busy/device time across both halves before dividing
                let device = a.device_us + b.device_us;
                let util = if device == 0 {
                    0.0
                } else {
                    (a.busy_us + b.busy_us) as f64 / device as f64
                };
                rows[2].4.push(util);
                rows[2].5.push(if device == 0 { 0.0 } else { (1.0 - util).max(0.0) });
            }
            for (name, oaccs, mems, replans, utils, bubbles) in rows {
                table.push_row(
                    format!("{}/{}", setting.label, name),
                    vec![
                        Some(Cell::from_samples(&oaccs)),
                        Some(Cell::from_samples(&mems)),
                        Some(Cell::from_samples(&replans)),
                        Some(Cell::from_samples(&utils)),
                        Some(Cell::from_samples(&bubbles)),
                    ],
                );
            }
        }
        table
    }

    /// Fig. 7: oacc vs log10(measured adaptation rate) across all cached
    /// runs; last row holds the Pearson correlation.
    pub fn fig7(&mut self) -> Table {
        // ensure the family-A runs exist
        let _ = self.table7();
        let mut table = Table::new(
            "Fig. 7 — Relation between oacc and log10(R)",
            vec!["log10_R".into(), "oacc".into()],
        );
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        // the run cache is a HashMap: iterate in sorted key order so the
        // row order (and the emitted table) is identical across processes
        let mut runs: Vec<_> = self.runs.iter().collect();
        runs.sort_by(|a, b| a.0.cmp(b.0));
        for ((idx, name, seed), m) in runs {
            let r = m.adaptation_rate();
            if r > 0.0 {
                let x = r.log10();
                let y = m.oacc.value();
                xs.push(x);
                ys.push(y);
                table.push_row(
                    format!("s{idx}/{name}/{seed}"),
                    vec![
                        Some(Cell { mean: x, std: 0.0 }),
                        Some(Cell { mean: y, std: 0.0 }),
                    ],
                );
            }
        }
        let corr = pearson(&xs, &ys);
        table.push_row(
            "PEARSON_CORRELATION",
            vec![Some(Cell { mean: corr, std: 0.0 }), None],
        );
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table1_runs_and_has_expected_shape() {
        let mut b = Bench::new(BenchCfg::quick());
        let t = b.table1();
        assert_eq!(t.rows.len(), 2, "two quick settings");
        assert_eq!(t.columns.len(), 8, "5 baselines + 3 ferret tiers");
        // 1-Skip column must be exactly zero (it is its own baseline)
        let skip_col = t.col("1-Skip");
        for (_, cells) in &t.rows {
            let c = cells[skip_col].unwrap();
            assert!(c.mean.abs() < 1e-9, "1-skip agm {}", c.mean);
        }
    }

    #[test]
    fn quick_budget_shift_table_shape_and_replans() {
        let mut b = Bench::new(BenchCfg::quick());
        let t = b.budget_shift(None);
        assert_eq!(t.rows.len(), 6, "2 settings x 3 responses");
        assert_eq!(
            t.columns,
            vec!["oacc", "mem_mb", "replans", "util", "bubble"]
        );
        let replans = t.col("replans");
        let util = t.col("util");
        let bubble = t.col("bubble");
        for (label, cells) in &t.rows {
            let r = cells[replans].unwrap().mean;
            if label.ends_with("/dynamic") {
                assert!(r >= 1.0, "{label}: dynamic run must re-plan (got {r})");
            } else {
                assert_eq!(r, 0.0, "{label}: static baselines never re-plan");
            }
            let u = cells[util].unwrap().mean;
            let bf = cells[bubble].unwrap().mean;
            assert!((0.0..=1.0).contains(&u), "{label}: util {u} out of range");
            assert!((0.0..=1.0).contains(&bf), "{label}: bubble {bf} out of range");
            assert!(u > 0.0, "{label}: lockstep run must accrue busy time");
            assert!((u + bf - 1.0).abs() < 1e-9, "{label}: util+bubble != 1");
        }
    }

    #[test]
    fn quick_ferret_tiers_order_memory() {
        let mut b = Bench::new(BenchCfg::quick());
        let f4 = b.fig4();
        let (lo, mid, hi) = (f4.col("Ferret_M-"), f4.col("Ferret_M"), f4.col("Ferret_M+"));
        for (label, cells) in &f4.rows {
            let (l, m, h) = (
                cells[lo].unwrap().mean,
                cells[mid].unwrap().mean,
                cells[hi].unwrap().mean,
            );
            assert!(l <= m + 1e-9 && m <= h + 1e-9, "{label}: {l} {m} {h}");
        }
    }
}
