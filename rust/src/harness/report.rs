//! Table/figure reporting: mean±std cells, markdown + CSV emission.

use crate::util::{mean, std_dev};

/// One table cell: mean ± std over seeds.
#[derive(Debug, Clone, Copy)]
pub struct Cell {
    pub mean: f64,
    pub std: f64,
}

impl Cell {
    pub fn from_samples(xs: &[f64]) -> Self {
        Cell { mean: mean(xs.iter().copied()), std: std_dev(xs) }
    }
}

/// A paper table or figure-series dump.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<Option<Cell>>)>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: Vec<String>) -> Self {
        Table { title: title.into(), columns, rows: Vec::new() }
    }

    pub fn push_row(&mut self, label: impl Into<String>, cells: Vec<Option<Cell>>) {
        assert_eq!(cells.len(), self.columns.len());
        self.rows.push((label.into(), cells));
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| Setting | {} |\n", self.columns.join(" | ")));
        let dashes = self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|");
        out.push_str(&format!("|---|{dashes}|\n"));
        for (label, cells) in &self.rows {
            let cells_str: Vec<String> = cells
                .iter()
                .map(|c| match c {
                    Some(c) => format!("{:.2}±{:.2}", c.mean, c.std),
                    None => "-".to_string(),
                })
                .collect();
            out.push_str(&format!("| {} | {} |\n", label, cells_str.join(" | ")));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = format!("setting,{}\n", self.columns.join(","));
        for (label, cells) in &self.rows {
            let cells_str: Vec<String> = cells
                .iter()
                .map(|c| match c {
                    Some(c) => format!("{:.4},{:.4}", c.mean, c.std),
                    None => ",".to_string(),
                })
                .collect();
            out.push_str(&format!("{},{}\n", label, cells_str.join(",")));
        }
        out
    }

    /// Machine-readable dump (CI artifacts); no serde dependency, so the
    /// JSON is assembled by hand with minimal string escaping.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let cols: Vec<String> =
            self.columns.iter().map(|c| format!("\"{}\"", esc(c))).collect();
        let rows: Vec<String> = self
            .rows
            .iter()
            .map(|(label, cells)| {
                let cs: Vec<String> = cells
                    .iter()
                    .map(|c| match c {
                        Some(c) => format!("{{\"mean\":{},\"std\":{}}}", c.mean, c.std),
                        None => "null".into(),
                    })
                    .collect();
                format!(
                    "{{\"label\":\"{}\",\"cells\":[{}]}}",
                    esc(label),
                    cs.join(",")
                )
            })
            .collect();
        format!(
            "{{\"title\":\"{}\",\"columns\":[{}],\"rows\":[{}]}}\n",
            esc(&self.title),
            cols.join(","),
            rows.join(",")
        )
    }

    /// Write markdown + csv into `results/` under the repo root.
    pub fn save(&self, stem: &str) -> std::io::Result<()> {
        let dir = crate::config::repo_path("results");
        std::fs::create_dir_all(&dir)?;
        std::fs::write(format!("{dir}/{stem}.md"), self.to_markdown())?;
        std::fs::write(format!("{dir}/{stem}.csv"), self.to_csv())?;
        Ok(())
    }

    /// Write the JSON dump into `results/` (uploaded as a CI artifact by
    /// the budget-shift smoke run).
    pub fn save_json(&self, stem: &str) -> std::io::Result<()> {
        let dir = crate::config::repo_path("results");
        std::fs::create_dir_all(&dir)?;
        std::fs::write(format!("{dir}/{stem}.json"), self.to_json())
    }

    /// Column index by name (panics if missing).
    pub fn col(&self, name: &str) -> usize {
        self.columns.iter().position(|c| c == name).unwrap_or_else(|| panic!("no column {name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_stats() {
        let c = Cell::from_samples(&[1.0, 3.0]);
        assert_eq!(c.mean, 2.0);
        assert_eq!(c.std, 1.0);
    }

    #[test]
    fn markdown_and_csv_shapes() {
        let mut t = Table::new("Test", vec!["A".into(), "B".into()]);
        t.push_row("row1", vec![Some(Cell { mean: 1.0, std: 0.1 }), None]);
        let md = t.to_markdown();
        assert!(md.contains("| row1 | 1.00±0.10 | - |"));
        let csv = t.to_csv();
        assert!(csv.starts_with("setting,A,B\n"));
        assert!(csv.contains("row1,1.0000,0.1000,,"));
        assert_eq!(t.col("B"), 1);
        let json = t.to_json();
        assert!(json.contains("\"title\":\"Test\""));
        assert!(json.contains("\"columns\":[\"A\",\"B\"]"));
        assert!(json.contains("{\"label\":\"row1\",\"cells\":[{\"mean\":1,\"std\":0.1},null]}"));
    }
}
