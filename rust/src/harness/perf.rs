//! Performance trajectory experiment (`ferret_bench --exp perf`).
//!
//! Emits the numbers the committed bench trajectory tracks across PRs
//! (`BENCH_*.json` at the repo root):
//!
//!   - **kernels** — GFLOP/s of each matmul flavor (fwd `x@w`, bwd-input
//!     `g@wᵀ`, bwd-weight `xᵀ@g`) on the zoo's layer shapes, for the
//!     naive reference loops, the tiled kernels at 1 thread, and the
//!     tiled kernels at the requested thread count;
//!   - **engine** — end-to-end batches/sec of the async engine per
//!     executor×mode (sim/lockstep, threaded/lockstep, threaded/freerun)
//!     and per kernel-thread setting;
//!   - **steady_state** — buffer-pool allocations per microbatch after
//!     warm-up (the zero-copy contract: ~0 once every size class has been
//!     seen).
//!
//! The JSON schema is hand-rolled (no serde in this repo) and versioned
//! by the `schema` field; CI regenerates a `--quick` report per commit
//! and uploads it as an artifact, while the full report is regenerated
//! manually and committed as `BENCH_<issue>.json`.
//!
//! `--compare BASE.json` diffs a fresh sweep against a committed BENCH
//! file ([`PerfReport::compare`]): per-kernel `tiled_mt_gflops` and
//! per-model `batches_per_sec` ratios, with `--max-regress` turning the
//! worst fractional slowdown into a nonzero exit for CI.

use std::time::Instant;

use crate::backend::kernels;
use crate::backend::native::NativeBackend;
use crate::compensate::CompKind;
use crate::config::zoo::{default_zoo, LayerShape};
use crate::ocl::OclKind;
use crate::pipeline::engine::AsyncCfg;
use crate::pipeline::executor::ExecutorKind;
use crate::pipeline::sched::Mode;
use crate::pipeline::{EngineParams, Session};
use crate::planner::costmodel::decay_for_td;
use crate::planner::{plan, Profile};
use crate::stream::{DriftKind, StreamSpec, SyntheticStream};
use crate::util::Rng;

/// GFLOP/s of one matmul flavor on one layer shape, across kernel forms.
#[derive(Debug, Clone)]
pub struct KernelRecord {
    /// "fwd" (`matmul_acc`), "bwd_dx" (`matmul_bt_acc`) or "bwd_dw"
    /// (`matmul_at_acc`)
    pub kernel: &'static str,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub naive_gflops: f64,
    pub tiled_gflops: f64,
    /// tiled kernel at `threads` workers
    pub tiled_mt_gflops: f64,
    pub threads: usize,
}

/// End-to-end engine throughput for one executor×mode×threads cell.
#[derive(Debug, Clone)]
pub struct EngineRecord {
    pub model: String,
    pub executor: &'static str,
    pub mode: &'static str,
    pub kernel_threads: usize,
    pub batches: usize,
    pub wall_ms: f64,
    pub batches_per_sec: f64,
}

/// Buffer-pool behavior after warm-up: `allocs_per_batch` ≈ 0 is the
/// zero-copy steady state.
#[derive(Debug, Clone)]
pub struct SteadyRecord {
    pub model: String,
    pub warm_batches: usize,
    pub measured_batches: usize,
    pub takes_per_batch: f64,
    pub allocs_per_batch: f64,
}

/// One full perf sweep, serializable as a BENCH_*.json trajectory point.
#[derive(Debug, Clone, Default)]
pub struct PerfReport {
    pub kernels: Vec<KernelRecord>,
    pub engine: Vec<EngineRecord>,
    pub steady_state: Vec<SteadyRecord>,
}

fn fmt(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "0.0".into()
    }
}

impl PerfReport {
    /// The machine-readable trajectory point. Key order and field names
    /// are part of the schema — committed BENCH files must diff cleanly
    /// against regenerated ones.
    pub fn to_json(&self) -> String {
        let kernels: Vec<String> = self
            .kernels
            .iter()
            .map(|r| {
                format!(
                    "    {{\"kernel\":\"{}\",\"m\":{},\"k\":{},\"n\":{},\
                     \"naive_gflops\":{},\"tiled_gflops\":{},\
                     \"tiled_mt_gflops\":{},\"threads\":{}}}",
                    r.kernel,
                    r.m,
                    r.k,
                    r.n,
                    fmt(r.naive_gflops),
                    fmt(r.tiled_gflops),
                    fmt(r.tiled_mt_gflops),
                    r.threads
                )
            })
            .collect();
        let engine: Vec<String> = self
            .engine
            .iter()
            .map(|r| {
                format!(
                    "    {{\"model\":\"{}\",\"executor\":\"{}\",\"mode\":\"{}\",\
                     \"kernel_threads\":{},\"batches\":{},\"wall_ms\":{},\
                     \"batches_per_sec\":{}}}",
                    r.model,
                    r.executor,
                    r.mode,
                    r.kernel_threads,
                    r.batches,
                    fmt(r.wall_ms),
                    fmt(r.batches_per_sec)
                )
            })
            .collect();
        let steady: Vec<String> = self
            .steady_state
            .iter()
            .map(|r| {
                format!(
                    "    {{\"model\":\"{}\",\"warm_batches\":{},\
                     \"measured_batches\":{},\"takes_per_batch\":{},\
                     \"allocs_per_batch\":{}}}",
                    r.model,
                    r.warm_batches,
                    r.measured_batches,
                    fmt(r.takes_per_batch),
                    fmt(r.allocs_per_batch)
                )
            })
            .collect();
        format!(
            "{{\n  \"bench\": \"BENCH_0008\",\n  \"schema\": 1,\n  \
             \"kernels\": [\n{}\n  ],\n  \"engine\": [\n{}\n  ],\n  \
             \"steady_state\": [\n{}\n  ]\n}}\n",
            kernels.join(",\n"),
            engine.join(",\n"),
            steady.join(",\n")
        )
    }

    /// Human-readable summary for stdout.
    pub fn to_markdown(&self) -> String {
        let mut out = String::from("### Perf trajectory (BENCH_0008)\n\n");
        out.push_str("| kernel | m×k×n | naive GF/s | tiled GF/s | tiled×T GF/s | T |\n");
        out.push_str("|---|---|---|---|---|---|\n");
        for r in &self.kernels {
            out.push_str(&format!(
                "| {} | {}×{}×{} | {:.2} | {:.2} | {:.2} | {} |\n",
                r.kernel, r.m, r.k, r.n, r.naive_gflops, r.tiled_gflops, r.tiled_mt_gflops, r.threads
            ));
        }
        out.push_str("\n| engine | executor | mode | kthreads | batches/s |\n");
        out.push_str("|---|---|---|---|---|\n");
        for r in &self.engine {
            out.push_str(&format!(
                "| {} | {} | {} | {} | {:.1} |\n",
                r.model, r.executor, r.mode, r.kernel_threads, r.batches_per_sec
            ));
        }
        out.push_str("\n| steady state | warm | measured | takes/batch | allocs/batch |\n");
        out.push_str("|---|---|---|---|---|\n");
        for r in &self.steady_state {
            out.push_str(&format!(
                "| {} | {} | {} | {:.1} | {:.2} |\n",
                r.model, r.warm_batches, r.measured_batches, r.takes_per_batch, r.allocs_per_batch
            ));
        }
        out
    }
}

/// One matched row of a baseline comparison: the same logical measurement
/// in both reports, with the throughput ratio current/baseline.
#[derive(Debug, Clone)]
pub struct CompareRow {
    /// human-readable row identity, e.g. `fwd 16×3072×256` or
    /// `mnistnet10 threaded/freerun k1`
    pub key: String,
    pub baseline: f64,
    pub current: f64,
    /// `current / baseline` — > 1.0 is a speedup, < 1.0 a regression
    pub ratio: f64,
}

/// Result of [`PerfReport::compare`]: per-kernel and per-model throughput
/// deltas against a committed baseline BENCH file.
#[derive(Debug, Clone, Default)]
pub struct CompareReport {
    /// name the baseline file declares in its `bench` field
    pub baseline_name: String,
    /// `tiled_mt_gflops` deltas keyed by (kernel, m, k, n)
    pub kernels: Vec<CompareRow>,
    /// `batches_per_sec` deltas keyed by (model, executor, mode, threads)
    pub engine: Vec<CompareRow>,
    /// baseline rows with no counterpart in the current report (shape or
    /// combo drift across PRs) — reported, never silently dropped
    pub unmatched: usize,
}

impl CompareReport {
    /// Worst fractional regression across all matched rows: 0.0 when
    /// nothing got slower, 0.25 when the worst row runs at 75% of
    /// baseline. The `--max-regress` gate thresholds this.
    pub fn worst_regress(&self) -> f64 {
        self.kernels
            .iter()
            .chain(self.engine.iter())
            .map(|r| 1.0 - r.ratio)
            .fold(0.0, f64::max)
    }

    /// Human-readable delta table for stdout.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("### Perf delta vs {}\n\n", self.baseline_name);
        out.push_str("| row | baseline | current | ratio |\n|---|---|---|---|\n");
        for r in self.kernels.iter().chain(self.engine.iter()) {
            out.push_str(&format!(
                "| {} | {:.2} | {:.2} | {:.2}× |\n",
                r.key, r.baseline, r.current, r.ratio
            ));
        }
        if self.unmatched > 0 {
            out.push_str(&format!(
                "\n{} baseline row(s) had no counterpart in this run\n",
                self.unmatched
            ));
        }
        out.push_str(&format!("\nworst regression: {:.1}%\n", self.worst_regress() * 100.0));
        out
    }
}

fn field_f64(obj: &crate::trace::Json, key: &str) -> Option<f64> {
    obj.get(key).and_then(|v| v.as_f64())
}

fn field_str<'j>(obj: &'j crate::trace::Json, key: &str) -> Option<&'j str> {
    obj.get(key).and_then(|v| v.as_str())
}

impl PerfReport {
    /// Diff this report against a committed baseline BENCH file (the JSON
    /// text of [`PerfReport::to_json`] from an earlier PR). Rows are
    /// matched by identity — kernels by (kernel, m, k, n), engine rows by
    /// (model, executor, mode, kernel_threads) — and compared on their
    /// multi-thread throughput columns. Baseline rows missing from the
    /// current report are counted in `unmatched`, not dropped silently;
    /// steady-state rows are not compared (allocs/batch is a contract
    /// pinned by tests, not a throughput).
    pub fn compare(&self, baseline_json: &str) -> crate::util::error::Result<CompareReport> {
        let base = crate::trace::json::parse(baseline_json)?;
        let mut report = CompareReport {
            baseline_name: field_str(&base, "bench").unwrap_or("baseline").to_string(),
            ..CompareReport::default()
        };

        let empty: &[crate::trace::Json] = &[];
        let base_kernels = base.get("kernels").and_then(|v| v.as_arr()).unwrap_or(empty);
        for row in base_kernels {
            let (Some(kernel), Some(m), Some(k), Some(n), Some(gf)) = (
                field_str(row, "kernel"),
                field_f64(row, "m"),
                field_f64(row, "k"),
                field_f64(row, "n"),
                field_f64(row, "tiled_mt_gflops"),
            ) else {
                crate::bail!("bench baseline: malformed kernel row");
            };
            let cur = self.kernels.iter().find(|r| {
                r.kernel == kernel && r.m as f64 == m && r.k as f64 == k && r.n as f64 == n
            });
            match cur {
                Some(r) if gf > 0.0 => report.kernels.push(CompareRow {
                    key: format!("{kernel} {m}×{k}×{n}"),
                    baseline: gf,
                    current: r.tiled_mt_gflops,
                    ratio: r.tiled_mt_gflops / gf,
                }),
                _ => report.unmatched += 1,
            }
        }

        let base_engine = base.get("engine").and_then(|v| v.as_arr()).unwrap_or(empty);
        for row in base_engine {
            let (Some(model), Some(exec), Some(mode), Some(kt), Some(bps)) = (
                field_str(row, "model"),
                field_str(row, "executor"),
                field_str(row, "mode"),
                field_f64(row, "kernel_threads"),
                field_f64(row, "batches_per_sec"),
            ) else {
                crate::bail!("bench baseline: malformed engine row");
            };
            let cur = self.engine.iter().find(|r| {
                r.model == model
                    && r.executor == exec
                    && r.mode == mode
                    && r.kernel_threads as f64 == kt
            });
            match cur {
                Some(r) if bps > 0.0 => report.engine.push(CompareRow {
                    key: format!("{model} {exec}/{mode} k{kt}"),
                    baseline: bps,
                    current: r.batches_per_sec,
                    ratio: r.batches_per_sec / bps,
                }),
                _ => report.unmatched += 1,
            }
        }
        Ok(report)
    }
}

/// Median-of-reps wall seconds of `f` (one untimed warm-up call).
fn time_reps(reps: u32, mut f: impl FnMut()) -> f64 {
    f();
    let mut samples = Vec::with_capacity(reps as usize);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn gflops(flops: usize, secs: f64) -> f64 {
    flops as f64 / secs.max(1e-12) / 1e9
}

/// Kernel sweep over one layer shape at batch `b`: all three matmul
/// flavors, naive vs tiled vs tiled×threads. Operands are dense (no
/// ReLU zeros) so the sparse-skip path does not flatter either side.
fn kernel_records(shape: &LayerShape, b: usize, threads: usize, reps: u32) -> Vec<KernelRecord> {
    let (kin, kout) = (shape.in_dim, shape.out_dim);
    let mut rng = Rng::new(0x5EED_0006);
    let x: Vec<f32> = (0..b * kin).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let w: Vec<f32> = (0..kin * kout).map(|_| rng.normal_f32(0.0, 0.2)).collect();
    let gz: Vec<f32> = (0..b * kout).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let flops = 2 * b * kin * kout;
    let mut out = Vec::new();

    // fwd: z (b × out) += x (b × in) @ w (in × out)
    let mut z = vec![0.0f32; b * kout];
    let naive = time_reps(reps, || kernels::naive_matmul_acc(&mut z, &x, &w, b, kin, kout));
    let tiled = time_reps(reps, || kernels::matmul_acc(&mut z, &x, &w, b, kin, kout, 1));
    let mt = time_reps(reps, || kernels::matmul_acc(&mut z, &x, &w, b, kin, kout, threads));
    out.push(KernelRecord {
        kernel: "fwd",
        m: b,
        k: kin,
        n: kout,
        naive_gflops: gflops(flops, naive),
        tiled_gflops: gflops(flops, tiled),
        tiled_mt_gflops: gflops(flops, mt),
        threads,
    });

    // bwd-input: gx (b × in) += gz (b × out) @ wᵀ, w (in × out)
    let mut gx = vec![0.0f32; b * kin];
    let naive = time_reps(reps, || kernels::naive_matmul_bt_acc(&mut gx, &gz, &w, b, kout, kin));
    let tiled = time_reps(reps, || kernels::matmul_bt_acc(&mut gx, &gz, &w, b, kout, kin, 1));
    let mt = time_reps(reps, || kernels::matmul_bt_acc(&mut gx, &gz, &w, b, kout, kin, threads));
    out.push(KernelRecord {
        kernel: "bwd_dx",
        m: b,
        k: kout,
        n: kin,
        naive_gflops: gflops(flops, naive),
        tiled_gflops: gflops(flops, tiled),
        tiled_mt_gflops: gflops(flops, mt),
        threads,
    });

    // bwd-weight: gw (in × out) += xᵀ @ gz, x (b × in)
    let mut gw = vec![0.0f32; kin * kout];
    let naive = time_reps(reps, || kernels::naive_matmul_at_acc(&mut gw, &x, &gz, kin, b, kout));
    let tiled = time_reps(reps, || kernels::matmul_at_acc(&mut gw, &x, &gz, kin, b, kout, 1));
    let mt = time_reps(reps, || kernels::matmul_at_acc(&mut gw, &x, &gz, kin, b, kout, threads));
    out.push(KernelRecord {
        kernel: "bwd_dw",
        m: kin,
        k: b,
        n: kout,
        naive_gflops: gflops(flops, naive),
        tiled_gflops: gflops(flops, tiled),
        tiled_mt_gflops: gflops(flops, mt),
        threads,
    });
    out
}

fn mk_stream(model: &crate::config::ModelSpec, batch: usize, n: usize) -> SyntheticStream {
    SyntheticStream::new(StreamSpec {
        name: "perf".into(),
        features: model.features(),
        classes: model.classes(),
        batch,
        num_batches: n,
        kind: DriftKind::Stationary,
        margin: 4.0,
        noise: 0.8,
        seed: 1,
    })
}

/// Run the full perf sweep. `quick` trims shapes/batches for CI smoke;
/// `kernel_threads` = 0 resolves the usual knob chain (env, then 1) but
/// floors at 2 so the multi-thread column is always a real comparison.
pub fn run_perf(quick: bool, kernel_threads: usize) -> PerfReport {
    let zoo = default_zoo().expect("zoo");
    let threads = kernels::resolve_threads(kernel_threads).max(2);
    let mut report = PerfReport::default();

    // --- kernels: the largest layer of each model (the time sink) plus
    // the smallest distinct shape (overhead-dominated regime) ---
    let mut shapes: Vec<LayerShape> = zoo
        .models
        .values()
        .filter_map(|m| m.layers().into_iter().max_by_key(|l| l.param_count()))
        .collect();
    shapes.sort();
    shapes.dedup();
    if let Some(small) = zoo.distinct_layer_shapes().into_iter().min_by_key(|l| l.param_count()) {
        if !shapes.contains(&small) {
            shapes.insert(0, small);
        }
    }
    if quick {
        shapes.truncate(2);
    }
    for shape in &shapes {
        let flops = 2 * zoo.batch * shape.in_dim * shape.out_dim;
        let reps = ((2e8 / flops.max(1) as f64) as u32).clamp(3, 40) / if quick { 3 } else { 1 };
        report.kernels.extend(kernel_records(shape, zoo.batch, threads, reps.max(1)));
    }

    // --- engine: batches/sec per executor×mode on the unconstrained
    // Ferret plan (the paper's hot path) ---
    let models: &[&str] = if quick { &["mnistnet10"] } else { &["mnistnet10", "convnet10", "resnet11"] };
    let n = if quick { 16 } else { 60 };
    for model_name in models {
        let model = zoo.model(model_name).expect("model").clone();
        let prof = Profile::analytic(&model, zoo.batch);
        let td = prof.default_td();
        let out = plan(&prof, td, f64::INFINITY, decay_for_td(td));
        let combos: [(ExecutorKind, Mode, usize); 4] = [
            (ExecutorKind::Sim, Mode::Lockstep, 1),
            (ExecutorKind::Sim, Mode::Lockstep, threads),
            (ExecutorKind::Threaded, Mode::Lockstep, 1),
            (ExecutorKind::Threaded, Mode::Freerun, 1),
        ];
        for (exec, mode, kthreads) in combos {
            let cfg = AsyncCfg::ferret(out.partition.clone(), out.config.clone(), CompKind::IterFisher);
            let ep = EngineParams { lr: 0.04, seed: 1, kernel_threads: kthreads, ..Default::default() };
            let mut plugin = OclKind::Vanilla.build(1);
            let mut stream = mk_stream(&model, zoo.batch, n);
            let t0 = Instant::now();
            let _ = Session::builder(&NativeBackend, &model)
                .config(cfg)
                .plugin(plugin.as_mut())
                .engine_params(ep)
                .executor(exec)
                .mode(mode)
                .batch(zoo.batch)
                .build()
                .expect("perf session")
                .run_stream(&mut stream)
                .expect("perf stream matches the model");
            let dt = t0.elapsed().as_secs_f64();
            report.engine.push(EngineRecord {
                model: model_name.to_string(),
                executor: exec.name(),
                mode: mode.name(),
                kernel_threads: kthreads,
                batches: n,
                wall_ms: dt * 1e3,
                batches_per_sec: n as f64 / dt.max(1e-12),
            });
        }
    }

    // --- steady state: pool allocations per microbatch once warm (push
    // API: warm W batches, snapshot, measure the next M) ---
    let warm = if quick { 8 } else { 16 };
    let measure = if quick { 8 } else { 32 };
    for model_name in models {
        let model = zoo.model(model_name).expect("model").clone();
        let prof = Profile::analytic(&model, zoo.batch);
        let td = prof.default_td();
        let out = plan(&prof, td, f64::INFINITY, decay_for_td(td));
        let cfg = AsyncCfg::ferret(out.partition, out.config, CompKind::IterFisher);
        let ep = EngineParams { lr: 0.04, seed: 1, ..Default::default() };
        let mut plugin = OclKind::Vanilla.build(1);
        let mut stream = mk_stream(&model, zoo.batch, warm + measure);
        let mut session = Session::builder(&NativeBackend, &model)
            .config(cfg)
            .plugin(plugin.as_mut())
            .engine_params(ep)
            .executor(ExecutorKind::Sim)
            .mode(Mode::Lockstep)
            .batch(zoo.batch)
            .build()
            .expect("perf session");
        for _ in 0..warm {
            let b = stream.next_batch().expect("warm batch");
            session.ingest(b).expect("ingest");
            session.drain().expect("drain");
        }
        let before = session.pool_stats();
        for _ in 0..measure {
            let b = stream.next_batch().expect("measure batch");
            session.ingest(b).expect("ingest");
            session.drain().expect("drain");
        }
        let delta = session.pool_stats().since(&before);
        report.steady_state.push(SteadyRecord {
            model: model_name.to_string(),
            warm_batches: warm,
            measured_batches: measure,
            takes_per_batch: delta.takes as f64 / measure as f64,
            allocs_per_batch: delta.misses as f64 / measure as f64,
        });
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_schema_has_three_sections_and_stable_keys() {
        let report = PerfReport {
            kernels: vec![KernelRecord {
                kernel: "fwd",
                m: 8,
                k: 4,
                n: 2,
                naive_gflops: 1.0,
                tiled_gflops: 2.5,
                tiled_mt_gflops: 4.0,
                threads: 4,
            }],
            engine: vec![EngineRecord {
                model: "mnistnet10".into(),
                executor: "sim",
                mode: "lockstep",
                kernel_threads: 1,
                batches: 16,
                wall_ms: 10.0,
                batches_per_sec: 1600.0,
            }],
            steady_state: vec![SteadyRecord {
                model: "mnistnet10".into(),
                warm_batches: 8,
                measured_batches: 8,
                takes_per_batch: 36.0,
                allocs_per_batch: 0.0,
            }],
        };
        let json = report.to_json();
        for key in [
            "\"bench\": \"BENCH_0008\"",
            "\"schema\": 1",
            "\"kernels\"",
            "\"engine\"",
            "\"steady_state\"",
            "\"naive_gflops\":1.000",
            "\"tiled_mt_gflops\":4.000",
            "\"batches_per_sec\":1600.000",
            "\"allocs_per_batch\":0.000",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        let md = report.to_markdown();
        assert!(md.contains("| fwd | 8×4×2 |"));
    }

    #[test]
    fn compare_matches_rows_and_finds_the_worst_regression() {
        let current = PerfReport {
            kernels: vec![KernelRecord {
                kernel: "fwd",
                m: 8,
                k: 4,
                n: 2,
                naive_gflops: 1.0,
                tiled_gflops: 2.5,
                tiled_mt_gflops: 8.0,
                threads: 4,
            }],
            engine: vec![EngineRecord {
                model: "mnistnet10".into(),
                executor: "sim",
                mode: "lockstep",
                kernel_threads: 1,
                batches: 16,
                wall_ms: 10.0,
                batches_per_sec: 750.0,
            }],
            steady_state: vec![],
        };
        // baseline: same kernel row at 4 GF/s (current is 2× faster), the
        // same engine row at 1000 b/s (current regressed 25%), plus one
        // engine row this run does not produce
        let mut baseline = current.clone();
        baseline.kernels[0].tiled_mt_gflops = 4.0;
        baseline.engine[0].batches_per_sec = 1000.0;
        baseline.engine.push(EngineRecord {
            model: "ghostnet".into(),
            executor: "sim",
            mode: "lockstep",
            kernel_threads: 1,
            batches: 16,
            wall_ms: 1.0,
            batches_per_sec: 1.0,
        });
        let cmp = current.compare(&baseline.to_json()).expect("baseline parses");
        assert_eq!(cmp.baseline_name, "BENCH_0008");
        assert_eq!(cmp.kernels.len(), 1);
        assert_eq!(cmp.engine.len(), 1);
        assert_eq!(cmp.unmatched, 1, "ghostnet row has no counterpart");
        assert!((cmp.kernels[0].ratio - 2.0).abs() < 1e-9);
        assert!((cmp.engine[0].ratio - 0.75).abs() < 1e-9);
        assert!((cmp.worst_regress() - 0.25).abs() < 1e-9);
        let md = cmp.to_markdown();
        assert!(md.contains("fwd 8×4×2"), "{md}");
        assert!(md.contains("worst regression: 25.0%"), "{md}");
        // a report diffed against itself has zero regression
        assert!(current.compare(&current.to_json()).unwrap().worst_regress() <= 0.0);
    }

    #[test]
    fn compare_rejects_malformed_baselines() {
        let r = PerfReport::default();
        assert!(r.compare("not json").is_err());
        assert!(r.compare("{\"kernels\":[{\"kernel\":\"fwd\"}]}").is_err(), "missing fields");
    }

    #[test]
    fn quick_sweep_runs_and_is_plausible() {
        let r = run_perf(true, 2);
        assert!(!r.kernels.is_empty() && !r.engine.is_empty() && !r.steady_state.is_empty());
        for k in &r.kernels {
            assert!(k.naive_gflops > 0.0 && k.tiled_gflops > 0.0, "{k:?}");
        }
        for e in &r.engine {
            assert!(e.batches_per_sec > 0.0, "{e:?}");
        }
        for s in &r.steady_state {
            // warm steady state must at least not allocate on every take
            assert!(s.allocs_per_batch < s.takes_per_batch, "{s:?}");
        }
    }
}
