//! ferret — CLI launcher for the Ferret OCL framework.
//!
//! Subcommands:
//!   plan  --model <name> [--budget-mb M] [--batch B]
//!         Run Alg. 3 (partitioning) + Alg. 2 (configuration search) and
//!         print the chosen partition, worker configuration, predicted
//!         adaptation rate (Eq. 3) and memory footprint (Eq. 4).
//!
//!   run   --setting <idx|label> [--budget-mb M] [--batches N] [--seed S]
//!         [--comp none|step|gap|fisher|iter] [--ocl vanilla|er|mir|lwf|mas]
//!         [--backend native|xla] [--executor sim|threaded]
//!         [--mode lockstep|freerun]
//!         [--budget-schedule <bytes>@<at>[,...]]
//!         [--kernel-threads K] [--warmup-profile R] [--pin-devices on]
//!         [--record-trace PATH] [--span-trace PATH]
//!         [--metrics-out PATH] [--metrics-interval N]
//!         Plan + run full Ferret on one of the paper's 20 settings and
//!         report oacc/tacc/memory/adaptation rate. `--executor threaded`
//!         runs one OS thread per (worker, stage) device (real
//!         parallelism); `sim` is the virtual-time simulation.
//!         `--mode freerun` paces the run against the wall clock (1 tick
//!         = 1µs), runs stage updates on the owning device threads, and
//!         reports observed per-batch latency percentiles plus the
//!         staleness histogram; `lockstep` replays virtual time.
//!
//!         `--budget-schedule` makes the memory budget a time-varying
//!         signal: e.g. `12mb@b60` halves the budget at batch 60 — the
//!         engine drains, re-plans against measured stage times, migrates
//!         the learned weights into the new partition, and resumes.
//!
//!         `--kernel-threads K` parallelizes the native stage kernels
//!         K-way (bit-identical to serial; default 1, or the
//!         FERRET_KERNEL_THREADS env var). `--warmup-profile R` seeds the
//!         *initial* plan from a measured profile (R timed reps per layer)
//!         instead of the analytic FLOPs model; default off — measured
//!         profiles are wall-clock dependent, so deterministic runs keep
//!         the analytic base.
//!
//!         `--pin-devices on` pins each threaded-executor device thread
//!         to a CPU from the process's allowed set, round-robin in spawn
//!         order (Linux `sched_setaffinity`; a no-op elsewhere). A
//!         placement hint only — it never affects numerics, and replayed
//!         traces always run unpinned.
//!
//!         `--record-trace PATH` records the run as a `ferret-trace/1`
//!         JSON-lines artifact (stream identity + every planner decision;
//!         see `ferret::trace`) that `ferret replay` can re-drive.
//!
//!         `--span-trace PATH` enables the span recorder and exports the
//!         per-device Fwd/Bwd/Update/Augment/Drain/Replan timeline as
//!         Chrome trace-event JSON — open it at <https://ui.perfetto.dev>
//!         (see `ferret::obs` and `docs/observability.md`). Lockstep
//!         span traces are deterministic: bit-for-bit identical across
//!         executors and kernel-thread counts.
//!
//!         `--metrics-out PATH` streams pipeline snapshots (oacc-so-far,
//!         ledger bytes, pool stats, per-device utilization/bubble,
//!         latency percentiles over a sliding window) as JSON lines, one
//!         record every `--metrics-interval N` arrivals (default 10)
//!         plus a final record at finish; the first line is a
//!         `ferret-obs/1` schema header.
//!
//!   replay <trace> [--config-override k=v[,k=v...]] [--out PATH] [--gate]
//!         Re-drive a recorded trace through a lockstep session: the exact
//!         stream is rebuilt from the trace's seeded spec and verified
//!         batch-by-batch against the recorded content hashes. With no
//!         overrides a trace recorded under the determinism contract
//!         replays bit-for-bit; `--config-override` varies the planner/
//!         compensation/plugin/kernel configuration (keys: comp, ocl,
//!         executor, kernel-threads, lr, stash-cap, plugin-cadence,
//!         budget-schedule, seed). Emits a machine-readable diff (plan
//!         churn, windowed oacc delta, latency-percentile deltas,
//!         replan-count delta) to stdout or `--out`; `--gate` exits 1
//!         when the diff is not bit-for-bit.
//!
//!   settings
//!         List the 20 paper settings with their indices.

use ferret::backend::{native::NativeBackend, xla::XlaBackend, Backend};
use ferret::budget::BudgetSchedule;
use ferret::compensate::CompKind;
use ferret::config::zoo::default_zoo;
use ferret::ocl::OclKind;
use ferret::pipeline::engine::AsyncCfg;
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;
use ferret::pipeline::{EngineParams, Session};
use ferret::planner::{plan, Profile};
use ferret::stream::{paper_settings, SyntheticStream};
use ferret::trace::{replay_trace, GateThresholds, Trace};

fn usage() -> ! {
    eprintln!("usage: ferret <plan|run|replay|settings> [options]   (see --help in source docs)");
    std::process::exit(2)
}

struct Opts {
    map: std::collections::HashMap<String, String>,
}

impl Opts {
    fn parse(args: &[String]) -> Self {
        let mut map = std::collections::HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let k = args[i].trim_start_matches("--").to_string();
            if !args[i].starts_with("--") {
                usage();
            }
            i += 1;
            let v = args.get(i).cloned().unwrap_or_else(|| usage());
            map.insert(k, v);
            i += 1;
        }
        Opts { map }
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.map.get(k).map(|s| s.as_str())
    }
}

/// Parse a flag value or exit with a message naming the flag — never
/// panic on user-supplied input.
fn parse_or_exit<T: std::str::FromStr>(v: &str, flag: &str, expected: &str) -> T {
    v.parse().unwrap_or_else(|_| {
        eprintln!("error: --{flag} expects {expected}, got '{v}'");
        std::process::exit(2)
    })
}

fn cmd_settings() {
    println!("idx  label                          model          drift");
    for (i, s) in paper_settings().iter().enumerate() {
        println!("{i:>3}  {:<30} {:<14} {:?}", s.label, s.model, s.kind);
    }
}

fn cmd_plan(opts: &Opts) {
    let zoo = default_zoo().expect("zoo");
    let model = zoo.model(opts.get("model").unwrap_or("convnet10")).expect("model");
    let batch = opts
        .get("batch")
        .map(|b| parse_or_exit::<usize>(b, "batch", "a batch size"))
        .unwrap_or(zoo.batch);
    let prof = Profile::analytic(model, batch);
    let td = prof.default_td();
    let budget = opts
        .get("budget-mb")
        .map(|m| parse_or_exit::<f64>(m, "budget-mb", "a budget in MB") * 1e6)
        .unwrap_or(f64::INFINITY);
    let out = plan(&prof, td, budget, ferret::planner::costmodel::decay_for_td(td));
    println!("model      : {} ({} params, {} layers)", model.name, model.param_count(), model.num_layers());
    println!("t^d        : {td} ticks (= max layer fwd)");
    println!("t^c*       : {} ticks", out.tc);
    println!("partition L: {:?} ({} stages)", out.partition.bounds, out.partition.num_stages());
    println!("feasible   : {}", out.feasible);
    println!("R_F (Eq.3) : {:.6}", out.rate);
    let budget_str =
        if budget.is_finite() { format!("{:.2} MB", budget / 1e6) } else { "∞".into() };
    println!("M_F (Eq.4) : {:.2} MB (budget {budget_str})", out.mem_bytes / 1e6);
    for (n, w) in out.config.workers.iter().enumerate() {
        println!(
            "worker {n}: delay={} recompute={} accum={:?} omit={:?}",
            w.delay, w.recompute, w.accum, w.omit
        );
    }
}

fn cmd_run(opts: &Opts) {
    let settings = paper_settings();
    let setting = match opts.get("setting") {
        Some(s) => match s.parse::<usize>() {
            Ok(i) => settings.get(i).cloned().unwrap_or_else(|| {
                eprintln!(
                    "error: --setting index {i} out of range (0..{})",
                    settings.len() - 1
                );
                std::process::exit(2)
            }),
            Err(_) => settings
                .iter()
                .find(|st| st.label.eq_ignore_ascii_case(s))
                .cloned()
                .unwrap_or_else(|| {
                    eprintln!(
                        "error: --setting '{s}' matches no label (try `ferret settings`)"
                    );
                    std::process::exit(2)
                }),
        },
        None => settings[0].clone(),
    };
    let zoo = default_zoo().expect("zoo");
    let model = zoo.model(setting.model).expect("model").clone();
    let batches = opts
        .get("batches")
        .map(|b| parse_or_exit::<usize>(b, "batches", "a stream length"))
        .unwrap_or(120);
    let seed = opts
        .get("seed")
        .map(|s| parse_or_exit::<u64>(s, "seed", "an integer seed"))
        .unwrap_or(42);
    let comp = match opts.get("comp").unwrap_or("iter") {
        "none" => CompKind::NoComp,
        "step" => CompKind::StepAware,
        "gap" => CompKind::GapAware,
        "fisher" => CompKind::Fisher,
        "iter" => CompKind::IterFisher,
        _ => usage(),
    };
    let ocl = match opts.get("ocl").unwrap_or("vanilla") {
        "vanilla" => OclKind::Vanilla,
        "er" => OclKind::Er,
        "mir" => OclKind::Mir,
        "lwf" => OclKind::Lwf,
        "mas" => OclKind::Mas,
        _ => usage(),
    };
    let backend: Box<dyn Backend> = match opts.get("backend").unwrap_or("native") {
        "native" => Box::new(NativeBackend),
        "xla" => Box::new(XlaBackend::open_default().expect("artifacts (run `make artifacts`)")),
        _ => usage(),
    };
    let executor = match ExecutorKind::parse(opts.get("executor").unwrap_or("sim")) {
        Some(k) => k,
        None => usage(),
    };
    let mode = match Mode::parse(opts.get("mode").unwrap_or("lockstep")) {
        Some(m) => m,
        None => usage(),
    };
    let budget_sched = match opts.get("budget-schedule") {
        Some(s) => match BudgetSchedule::parse(s) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("error: --budget-schedule: {e}");
                std::process::exit(2);
            }
        },
        None => BudgetSchedule::fixed(),
    };

    let prof = Profile::analytic(&model, zoo.batch);
    let td = prof.default_td();
    if budget_sched.is_dynamic() && mode == Mode::Freerun {
        // show where batch-index steps land on the wall clock
        for st in &budget_sched.steps {
            if let ferret::budget::StepAt::Batch(b) = st.at {
                eprintln!(
                    "[ferret] budget step at batch {b} ≈ {}µs wall-clock",
                    ferret::stream::batch_arrival_us(b, td)
                );
            }
        }
    }
    let budget = opts
        .get("budget-mb")
        .map(|m| parse_or_exit::<f64>(m, "budget-mb", "a budget in MB") * 1e6)
        .unwrap_or(f64::INFINITY);
    let out = plan(&prof, td, budget, ferret::planner::costmodel::decay_for_td(td));
    eprintln!(
        "[ferret] {} | partition {:?} | {} workers | plan R={:.4} M={:.2}MB",
        setting.label,
        out.partition.bounds,
        out.config.active_workers(),
        out.rate,
        out.mem_bytes / 1e6
    );

    let mut stream = SyntheticStream::new(setting.stream_spec(
        model.features(),
        model.classes(),
        zoo.batch,
        batches,
        seed,
    ));
    let mut plugin = ocl.build(seed);
    let kernel_threads = opts
        .get("kernel-threads")
        .map(|t| parse_or_exit::<usize>(t, "kernel-threads", "a thread count"))
        .unwrap_or(0); // 0 = FERRET_KERNEL_THREADS env, default serial
    let warmup_reps = opts
        .get("warmup-profile")
        .map(|r| parse_or_exit::<u32>(r, "warmup-profile", "a rep count"))
        .unwrap_or(0); // 0 = analytic initial profile (deterministic)
    let pin_devices = opts
        .get("pin-devices")
        .map(|v| match v {
            "1" | "true" | "on" => true,
            "0" | "false" | "off" => false,
            _ => {
                eprintln!("error: --pin-devices expects on|off, got '{v}'");
                std::process::exit(2)
            }
        })
        .unwrap_or(false);
    let ep = EngineParams { lr: 0.1, seed, kernel_threads, pin_devices, ..Default::default() };
    let dynamic = budget_sched.is_dynamic();
    let cfg = AsyncCfg::ferret(out.partition, out.config, comp).with_budget(budget_sched);
    let t0 = std::time::Instant::now();
    let mut builder = Session::builder(backend.as_ref(), &model)
        .config(cfg)
        .plugin(plugin.as_mut())
        .engine_params(ep)
        .executor(executor)
        .mode(mode)
        .batch(zoo.batch)
        .measured_profile(warmup_reps);
    if let Some(path) = opts.get("record-trace") {
        builder = builder.record_trace(path);
        eprintln!("[ferret] recording trace to {path}");
    }
    let span_trace = opts.get("span-trace").map(str::to_string);
    if let Some(path) = &span_trace {
        builder = builder.span_trace(path);
        eprintln!("[ferret] recording span trace to {path}");
    }
    if let Some(path) = opts.get("metrics-out") {
        let interval = opts
            .get("metrics-interval")
            .map(|n| parse_or_exit::<u64>(n, "metrics-interval", "an arrival count"))
            .unwrap_or(10);
        builder = builder.metrics_out(path, interval);
        eprintln!("[ferret] streaming snapshots to {path} every {} arrivals", interval.max(1));
    } else if opts.get("metrics-interval").is_some() {
        eprintln!("error: --metrics-interval requires --metrics-out PATH");
        std::process::exit(2);
    }
    let session = match builder.build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: invalid engine configuration: {e}");
            std::process::exit(2);
        }
    };
    let r = match session.run_stream(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    println!("setting    : {}", setting.label);
    println!("ocl/comp   : {} / {}", ocl.name(), comp.name());
    println!("executor   : {} ({} worker threads)", executor.name(), r.metrics.exec_threads);
    println!("mode       : {}", mode.name());
    println!("oacc       : {:.2}%", r.metrics.oacc.value());
    println!("tacc       : {:.2}%", r.metrics.tacc);
    println!("adaptation : {:.4}", r.metrics.adaptation_rate());
    println!("memory     : {:.2} MB (analytic Eq. 4)", r.metrics.mem_bytes / 1e6);
    if dynamic {
        println!(
            "replans    : {} (drain latencies {:?} ticks)",
            r.metrics.replans, r.metrics.drains
        );
        println!(
            "ledger     : peak {:.2} MB | final {:.2} MB (measured)",
            r.metrics.ledger.peak_total as f64 / 1e6,
            r.metrics.ledger.last.total() as f64 / 1e6
        );
    }
    println!("trained    : {} updates, dropped {}", r.metrics.trained, r.metrics.dropped);
    println!(
        "pipeline   : {:.1}% device utilization, {:.1}% bubble",
        100.0 * r.metrics.utilization(),
        100.0 * r.metrics.bubble_frac()
    );
    if mode == Mode::Freerun {
        println!("latency µs : {}", r.metrics.latency_summary());
        println!("staleness  : {}", r.metrics.staleness_summary());
    }
    println!("final loss : {:.4}", r.metrics.mean_recent_loss(16));
    println!(
        "buffer pool: {} takes, {} allocs, {} recycled ({:.1}% hit)",
        r.metrics.pool.takes,
        r.metrics.pool.misses,
        r.metrics.pool.puts,
        100.0 * (r.metrics.pool.takes.saturating_sub(r.metrics.pool.misses)) as f64
            / (r.metrics.pool.takes.max(1)) as f64
    );
    println!("wallclock  : {:.1}s", t0.elapsed().as_secs_f64());
}

fn cmd_replay(args: &[String]) {
    let path = match args.first() {
        Some(p) if !p.starts_with("--") => p.as_str(),
        _ => {
            eprintln!(
                "usage: ferret replay <trace> [--config-override k=v[,k=v...]] [--out PATH] \
                 [--gate]"
            );
            std::process::exit(2);
        }
    };
    // --gate is a boolean flag: pull it out before Opts::parse, which
    // requires a value after every flag
    let mut gate = false;
    let rest: Vec<String> = args[1..]
        .iter()
        .filter(|a| {
            if a.as_str() == "--gate" {
                gate = true;
                false
            } else {
                true
            }
        })
        .cloned()
        .collect();
    let opts = Opts::parse(&rest);
    let overrides: Vec<(String, String)> = match opts.get("config-override") {
        Some(s) => s
            .split(',')
            .filter(|e| !e.trim().is_empty())
            .map(|e| match e.split_once('=') {
                Some((k, v)) => (k.trim().to_string(), v.trim().to_string()),
                None => {
                    eprintln!("error: --config-override entries must be key=value, got '{e}'");
                    std::process::exit(2);
                }
            })
            .collect(),
        None => Vec::new(),
    };
    let recorded = match Trace::read(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let outcome = match replay_trace(&recorded, &overrides) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let d = &outcome.diff;
    eprintln!(
        "[replay] {} recorded / {} replayed batches | oacc {:.2} -> {:.2} | replans {} -> {} | \
         plan churn {}",
        d.batches_a, d.batches_b, d.oacc_a, d.oacc_b, d.replans_a, d.replans_b, d.plan_churn
    );
    let json = d.to_json();
    match opts.get("out") {
        Some(p) => {
            if let Err(e) = std::fs::write(p, format!("{json}\n")) {
                eprintln!("error: writing {p}: {e}");
                std::process::exit(2);
            }
            eprintln!("[replay] diff written to {p}");
        }
        None => println!("{json}"),
    }
    if gate {
        let violations = d.violations(&GateThresholds::default());
        if violations.is_empty() {
            eprintln!("[replay] gate: PASS (bit-for-bit)");
        } else {
            eprintln!("[replay] gate: FAIL");
            for v in &violations {
                eprintln!("  - {v}");
            }
            std::process::exit(1);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("settings") => cmd_settings(),
        Some("plan") => cmd_plan(&Opts::parse(&args[1..])),
        Some("run") => cmd_run(&Opts::parse(&args[1..])),
        Some("replay") => cmd_replay(&args[1..]),
        _ => usage(),
    }
}
