//! Synthetic data streams.
//!
//! The paper evaluates on 18 image/tabular datasets; those are not
//! available here (repro gate), so per DESIGN.md §3 each (dataset, model)
//! setting is substituted by a seeded synthetic stream with matched
//! feature dimension, class count, and *drift structure*:
//!   - `Stationary`    — iid mixture (MNIST/CIFAR/SVHN/Covertype-like)
//!   - `ClassIncremental` — 5-task class splits (Split-* datasets)
//!   - `Covariate`     — slowly rotating class prototypes (CLEAR-like)
//!   - `Temporal`      — temporally-correlated object visits (CORe50-like)
//!
//! Everything downstream (admission, scheduling, staleness, memory) only
//! sees `(x, y)` microbatches arriving at a fixed virtual-time cadence, so
//! the framework comparison is preserved.

pub mod generator;
pub mod settings;

pub use generator::{Batch, DriftKind, StreamSpec, SyntheticStream, TestSet};
pub use settings::{arrival_interval_us, batch_arrival_us, paper_settings, Setting, WALL_TICK_US};
