//! Synthetic data streams.
//!
//! The paper evaluates on 18 image/tabular datasets; those are not
//! available here (repro gate), so per DESIGN.md §3 each (dataset, model)
//! setting is substituted by a seeded synthetic stream with matched
//! feature dimension, class count, and *drift structure*:
//!   - `Stationary`    — iid mixture (MNIST/CIFAR/SVHN/Covertype-like)
//!   - `ClassIncremental` — 5-task class splits (Split-* datasets)
//!   - `Covariate`     — slowly rotating class prototypes (CLEAR-like)
//!   - `Temporal`      — temporally-correlated object visits (CORe50-like)
//!
//! Everything downstream (admission, scheduling, staleness, memory) only
//! sees `(x, y)` microbatches arriving at a fixed virtual-time cadence, so
//! the framework comparison is preserved.

pub mod generator;
pub mod replay;
pub mod settings;

pub use generator::{Batch, DriftKind, StreamSpec, SyntheticStream, TestSet};
pub use replay::ReplayStream;
pub use settings::{arrival_interval_us, batch_arrival_us, paper_settings, Setting, WALL_TICK_US};

use crate::util::Fnv;

/// FNV-1a content hash of one microbatch: id, row count, every feature
/// (by f32 bit pattern) and label. Stable across runs and platforms, so
/// it doubles as the replay-time identity check for rebuilt streams.
pub fn batch_hash(b: &Batch) -> u64 {
    let mut h = Fnv::new();
    h.write_u64(b.id);
    h.write_u64(b.y.len() as u64);
    for &v in &b.x {
        h.write_f32(v);
    }
    for &y in &b.y {
        h.write_i32(y);
    }
    h.finish()
}

/// Abstract microbatch source for the engines.
///
/// The engine layer consumes batches in arrival order and never looks at
/// how they are produced: [`SyntheticStream`] is the built-in seeded
/// generator, and a [`Session`](crate::pipeline::session::Session) caller
/// can skip `Stream` entirely and push hand-made batches with
/// [`Session::ingest`](crate::pipeline::session::Session::ingest).
/// [`Session::run_stream`](crate::pipeline::session::Session::run_stream)
/// bridges the two: it ingests any `Stream` and drives the session to
/// completion. Feature dimension and class count must match the model the
/// session was built for.
pub trait Stream {
    /// Next microbatch in arrival order, or `None` once exhausted.
    fn next_batch(&mut self) -> Option<Batch>;

    /// Held-out evaluation set (`per_class` samples per class) used for
    /// the final test-accuracy measurement.
    fn test_set(&self, per_class: usize) -> TestSet;

    /// Batches remaining, when known. A capacity hint only — callers must
    /// not rely on it for termination.
    fn len_hint(&self) -> Option<usize> {
        None
    }

    /// The seeded spec this stream can be re-materialized from, when it
    /// has one. Trace recording stores it so replay can rebuild the exact
    /// stream; hand-fed or external streams return `None` (their traces
    /// carry batch hashes but cannot be replayed from spec alone).
    fn provenance(&self) -> Option<StreamSpec> {
        None
    }
}
