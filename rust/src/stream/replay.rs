//! Replay stream: re-materializes a recorded run's exact batch sequence.
//!
//! A trace artifact stores the stream's [`StreamSpec`] (seed included)
//! plus an FNV-1a content hash per recorded batch. Because
//! [`SyntheticStream`] is fully seeded, rebuilding it from the spec
//! reproduces the batches bit-for-bit — the hashes are not the data, they
//! are the *check* that the rebuilt stream really is the recorded one
//! (a changed generator, spec drift, or a hand-fed stream all surface as
//! a hash mismatch instead of a silently different replay).

use super::generator::{Batch, StreamSpec, SyntheticStream, TestSet};
use super::Stream;
use crate::stream::batch_hash;

/// First point where the rebuilt stream diverged from the recording.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplayMismatch {
    /// replay position (0-based batch index within the trace)
    pub index: u64,
    /// hash the trace recorded at this position
    pub expected: u64,
    /// hash of the batch the rebuilt stream produced; `None` when the
    /// rebuilt stream ended before reaching this position
    pub got: Option<u64>,
}

/// A [`Stream`] that re-drives a recorded run: a seeded
/// [`SyntheticStream`] rebuilt from the trace's spec, verified batch by
/// batch against the trace's content hashes. Ends at the recorded length.
/// On the first mismatch the stream ends early and
/// [`ReplayStream::mismatch`] reports where — callers check it after the
/// run and refuse the replay's results.
pub struct ReplayStream {
    inner: SyntheticStream,
    expected: Vec<u64>,
    pos: u64,
    mismatch: Option<ReplayMismatch>,
}

impl ReplayStream {
    /// Rebuild the stream a trace recorded: `spec` and one content hash
    /// per recorded batch, in arrival order.
    pub fn new(spec: StreamSpec, expected: Vec<u64>) -> Self {
        ReplayStream { inner: SyntheticStream::new(spec), expected, pos: 0, mismatch: None }
    }

    /// Where the rebuilt stream first diverged from the recording, if it
    /// did. `None` after a clean (so far) replay.
    pub fn mismatch(&self) -> Option<ReplayMismatch> {
        self.mismatch
    }

    /// Batches recorded in the trace (the replay's length).
    pub fn recorded_len(&self) -> usize {
        self.expected.len()
    }
}

impl Stream for ReplayStream {
    fn next_batch(&mut self) -> Option<Batch> {
        if self.mismatch.is_some() || self.pos >= self.expected.len() as u64 {
            return None;
        }
        let want = self.expected[self.pos as usize];
        match self.inner.next_batch() {
            Some(b) => {
                let got = batch_hash(&b);
                if got != want {
                    self.mismatch =
                        Some(ReplayMismatch { index: self.pos, expected: want, got: Some(got) });
                    return None;
                }
                self.pos += 1;
                Some(b)
            }
            None => {
                self.mismatch = Some(ReplayMismatch { index: self.pos, expected: want, got: None });
                None
            }
        }
    }

    fn test_set(&self, per_class: usize) -> TestSet {
        self.inner.test_set(per_class)
    }

    fn len_hint(&self) -> Option<usize> {
        Some((self.expected.len() as u64).saturating_sub(self.pos) as usize)
    }

    fn provenance(&self) -> Option<StreamSpec> {
        Some(self.inner.spec().clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::DriftKind;

    fn spec() -> StreamSpec {
        StreamSpec {
            name: "replay-test".into(),
            features: 8,
            classes: 3,
            batch: 4,
            num_batches: 10,
            kind: DriftKind::Stationary,
            margin: 3.0,
            noise: 0.5,
            seed: 11,
        }
    }

    fn recorded_hashes(n: usize) -> Vec<u64> {
        let mut s = SyntheticStream::new(spec());
        (0..n).map(|_| batch_hash(&s.next_batch().unwrap())).collect()
    }

    #[test]
    fn replay_reproduces_the_recorded_prefix() {
        let hashes = recorded_hashes(6);
        let mut original = SyntheticStream::new(spec());
        let mut replay = ReplayStream::new(spec(), hashes);
        assert_eq!(replay.len_hint(), Some(6));
        let mut n = 0;
        while let Some(b) = Stream::next_batch(&mut replay) {
            let o = original.next_batch().unwrap();
            assert_eq!(b.x, o.x);
            assert_eq!(b.y, o.y);
            n += 1;
        }
        assert_eq!(n, 6, "ends at the recorded length, not the spec's");
        assert_eq!(replay.mismatch(), None);
    }

    #[test]
    fn hash_mismatch_ends_the_stream_and_is_reported() {
        let mut hashes = recorded_hashes(6);
        hashes[3] ^= 1; // corrupt one recorded hash
        let mut replay = ReplayStream::new(spec(), hashes);
        let mut n = 0;
        while Stream::next_batch(&mut replay).is_some() {
            n += 1;
        }
        assert_eq!(n, 3, "stops at the diverging batch");
        let m = replay.mismatch().expect("mismatch recorded");
        assert_eq!(m.index, 3);
        assert!(m.got.is_some());
        assert_ne!(Some(m.expected), m.got);
    }

    #[test]
    fn short_rebuilt_stream_is_a_mismatch() {
        // trace claims more batches than the spec can produce
        let mut hashes = recorded_hashes(10);
        hashes.push(0xDEAD);
        let mut replay = ReplayStream::new(spec(), hashes);
        while Stream::next_batch(&mut replay).is_some() {}
        let m = replay.mismatch().expect("short stream recorded as mismatch");
        assert_eq!(m.index, 10);
        assert_eq!(m.got, None);
    }
}
