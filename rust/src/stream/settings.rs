//! The paper's 20 (dataset, model) evaluation settings, mapped to
//! synthetic stream specs (DESIGN.md §3). Margins/noise are calibrated so
//! the *relative* difficulty ordering matches Table 7 (MNIST easy ...
//! TinyImageNet hard); absolute accuracies are not meaningful targets on
//! synthetic data.

use super::generator::{DriftKind, StreamSpec};

/// Wall-clock replay rate of the free-running pipeline mode: one virtual
/// tick of a setting's arrival interval is replayed as this many real
/// microseconds, so virtual-tick metrics (decay constants, adaptation
/// rates) stay directly comparable between lockstep and freerun runs.
pub const WALL_TICK_US: u64 = 1;

/// Microseconds between consecutive `Arrive` events when a setting's
/// stream is replayed against the wall clock (`Mode::Freerun`). Floored at
/// 1µs so a degenerate profile cannot busy-spin the scheduler.
pub fn arrival_interval_us(td_ticks: u64) -> u64 {
    (td_ticks * WALL_TICK_US).max(1)
}

/// Wall-clock stamp (µs) at which stream batch `seq` arrives in freerun —
/// the bridge between a budget schedule's batch-index steps (the lockstep
/// replan boundaries, `budget::StepAt::Batch`) and their wall-time
/// equivalents (`budget::StepAt::Us`).
pub fn batch_arrival_us(seq: u64, td_ticks: u64) -> u64 {
    seq * arrival_interval_us(td_ticks)
}

/// One evaluation setting of the paper's grid.
#[derive(Debug, Clone)]
pub struct Setting {
    /// paper row label, e.g. "MNIST/MNISTNet"
    pub label: &'static str,
    /// synthetic dataset tag
    pub dataset: &'static str,
    /// model-zoo name
    pub model: &'static str,
    pub kind: DriftKind,
    pub margin: f32,
    pub noise: f32,
}

impl Setting {
    /// Concretize into a stream spec. `features`/`classes`/`batch` come
    /// from the model zoo so stream and model always agree.
    pub fn stream_spec(
        &self,
        features: usize,
        classes: usize,
        batch: usize,
        num_batches: usize,
        seed: u64,
    ) -> StreamSpec {
        StreamSpec {
            name: self.dataset.to_string(),
            features,
            classes,
            batch,
            num_batches,
            kind: self.kind,
            margin: self.margin,
            noise: self.noise,
            seed,
        }
    }
}

/// The 20 settings of Tables 1/3/7 in paper order.
pub fn paper_settings() -> Vec<Setting> {
    use DriftKind::*;
    let s = |label, dataset, model, kind, margin, noise| Setting {
        label,
        dataset,
        model,
        kind,
        margin,
        noise,
    };
    // margins calibrated so the Oracle's oacc lands near Table 7's value
    // for each dataset (MNIST ~81, CIFAR10 ~52, TinyImageNet ~6, ...)
    vec![
        s("MNIST/MNISTNet", "mnist", "mnistnet10", Stationary, 6.0, 0.6),
        s("FMNIST/MNISTNet", "fmnist", "mnistnet10", Stationary, 4.6, 0.7),
        s("EMNIST/MNISTNet", "emnist", "mnistnet62", Stationary, 6.0, 0.6),
        s("CIFAR10/ConvNet", "cifar10", "convnet10", Stationary, 4.0, 0.8),
        s("CIFAR100/ConvNet", "cifar100", "convnet100", Stationary, 2.8, 1.0),
        s("SVHN/ConvNet", "svhn", "convnet10", Stationary, 6.0, 0.7),
        s("TinyImagenet/ConvNet", "tinyimagenet", "convnet200", Stationary, 1.8, 1.0),
        s("CORe50/ConvNet", "core50", "convnet50", Temporal { dwell: 6 }, 6.5, 0.8),
        s("CORe50-iid/ConvNet", "core50-iid", "convnet50", Stationary, 5.0, 0.8),
        s("SplitMNIST/MNISTNet", "split-mnist", "mnistnet10", ClassIncremental { tasks: 5 }, 6.0, 0.6),
        s("SplitFMNIST/MNISTNet", "split-fmnist", "mnistnet10", ClassIncremental { tasks: 5 }, 4.6, 0.7),
        s("SplitCIFAR10/ConvNet", "split-cifar10", "convnet10", ClassIncremental { tasks: 5 }, 4.0, 0.8),
        s("SplitCIFAR100/ConvNet", "split-cifar100", "convnet100", ClassIncremental { tasks: 5 }, 2.8, 1.0),
        s("SplitSVHN/ConvNet", "split-svhn", "convnet10", ClassIncremental { tasks: 5 }, 6.0, 0.7),
        s(
            "SplitTinyImagenet/ConvNet",
            "split-tinyimagenet",
            "convnet200",
            ClassIncremental { tasks: 5 },
            1.8,
            1.0,
        ),
        s("CLEAR10/ResNet", "clear10", "resnet11", Covariate { cycles: 0.5 }, 7.0, 0.6),
        s("CLEAR10/MobileNet", "clear10", "mobilenet11", Covariate { cycles: 0.5 }, 5.0, 0.6),
        s("CLEAR100/ResNet", "clear100", "resnet101", Covariate { cycles: 0.5 }, 6.0, 0.8),
        s("CLEAR100/MobileNet", "clear100", "mobilenet101", Covariate { cycles: 0.5 }, 4.0, 0.8),
        s("Covertype/MLP", "covertype", "mlp", Stationary, 3.2, 0.8),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo::default_zoo;
    use crate::stream::SyntheticStream;

    #[test]
    fn wall_arrival_interval_scales_and_floors() {
        assert_eq!(arrival_interval_us(500), 500 * WALL_TICK_US);
        assert!(arrival_interval_us(0) >= 1, "degenerate td floored");
        // batch index -> wall stamp follows the arrival cadence
        assert_eq!(batch_arrival_us(0, 500), 0);
        assert_eq!(batch_arrival_us(7, 500), 7 * arrival_interval_us(500));
    }

    #[test]
    fn grid_has_20_settings_with_known_models() {
        let settings = paper_settings();
        assert_eq!(settings.len(), 20);
        let zoo = default_zoo().unwrap();
        for s in &settings {
            assert!(zoo.models.contains_key(s.model), "{} -> {}", s.label, s.model);
        }
    }

    #[test]
    fn every_setting_streams() {
        let zoo = default_zoo().unwrap();
        for s in paper_settings() {
            let m = zoo.model(s.model).unwrap();
            let spec = s.stream_spec(m.features(), m.classes(), 4, 10, 42);
            let mut stream = SyntheticStream::new(spec);
            let mut n = 0;
            while let Some(b) = stream.next_batch() {
                assert_eq!(b.x.len(), 4 * m.features());
                assert!(b.y.iter().all(|&y| (y as usize) < m.classes()));
                n += 1;
            }
            assert_eq!(n, 10, "{}", s.label);
        }
    }

    #[test]
    fn split_settings_are_class_incremental() {
        for s in paper_settings() {
            if s.dataset.starts_with("split-") {
                assert!(matches!(s.kind, DriftKind::ClassIncremental { tasks: 5 }), "{}", s.label);
            }
        }
    }
}
