//! Gaussian-mixture stream generator with drift processes.

use crate::util::Rng;

/// One microbatch of the stream. `id` is the arrival index; virtual
/// arrival time is `id * t_d` (assigned by the engine).
#[derive(Debug, Clone)]
pub struct Batch {
    pub id: u64,
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

/// Drift structure of the stream (see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DriftKind {
    /// iid mixture for the whole stream.
    Stationary,
    /// classes split into `tasks` contiguous task phases (Split-*).
    ClassIncremental { tasks: usize },
    /// prototypes rotate in a random 2-plane; `cycles` full rotations over
    /// the stream (CLEAR-like slow domain drift).
    Covariate { cycles: f64 },
    /// temporally-correlated visits: the stream dwells on one class for
    /// `dwell` consecutive batches (CORe50-like video sessions).
    Temporal { dwell: usize },
}

impl DriftKind {
    /// Compact text form carried by trace artifacts:
    /// `stationary`, `class_incremental:<tasks>`, `covariate:<cycles>`,
    /// `temporal:<dwell>`. Round-trips through [`DriftKind::parse`].
    pub fn spec_str(&self) -> String {
        match self {
            DriftKind::Stationary => "stationary".into(),
            DriftKind::ClassIncremental { tasks } => format!("class_incremental:{tasks}"),
            DriftKind::Covariate { cycles } => format!("covariate:{cycles}"),
            DriftKind::Temporal { dwell } => format!("temporal:{dwell}"),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        match (kind, arg) {
            ("stationary", None) => Some(DriftKind::Stationary),
            ("class_incremental", Some(a)) => {
                a.parse().ok().map(|tasks| DriftKind::ClassIncremental { tasks })
            }
            ("covariate", Some(a)) => a.parse().ok().map(|cycles| DriftKind::Covariate { cycles }),
            ("temporal", Some(a)) => a.parse().ok().map(|dwell| DriftKind::Temporal { dwell }),
            _ => None,
        }
    }
}

/// Static description of a stream.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpec {
    pub name: String,
    pub features: usize,
    pub classes: usize,
    pub batch: usize,
    /// stream length in microbatches
    pub num_batches: usize,
    pub kind: DriftKind,
    /// class-separation margin (prototype norm); higher = easier
    pub margin: f32,
    /// per-feature Gaussian noise std
    pub noise: f32,
    pub seed: u64,
}

/// Held-out evaluation set (all classes, base prototypes).
#[derive(Debug, Clone)]
pub struct TestSet {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
}

/// Seeded synthetic stream. Prototypes live on random unit directions
/// scaled by `margin`; samples add isotropic noise. Drift transforms the
/// prototypes as a function of the batch index.
pub struct SyntheticStream {
    spec: StreamSpec,
    /// base prototypes: classes x features
    protos: Vec<Vec<f32>>,
    /// orthogonal partners for covariate rotation
    protos_b: Vec<Vec<f32>>,
    rng: Rng,
    pos: u64,
    /// optional early cut-off (exclusive batch index). The drift/task
    /// schedule keeps following `spec.num_batches`, so a truncated stream
    /// is an exact prefix of the full one — unlike shrinking
    /// `num_batches`, which would compress the schedule.
    stop: Option<u64>,
}

impl SyntheticStream {
    pub fn new(spec: StreamSpec) -> Self {
        let mut rng = Rng::new(spec.seed ^ 0x5354524541); // "STREA"
        let mk = |rng: &mut Rng| -> Vec<Vec<f32>> {
            (0..spec.classes)
                .map(|_| {
                    let v: Vec<f32> = (0..spec.features).map(|_| rng.normal() as f32).collect();
                    let norm = (crate::util::norm2(&v) as f32).sqrt().max(1e-6);
                    v.into_iter().map(|x| x * spec.margin / norm).collect()
                })
                .collect()
        };
        let protos = mk(&mut rng);
        let protos_b = mk(&mut rng);
        let rng = rng.fork(1);
        SyntheticStream { spec, protos, protos_b, rng, pos: 0, stop: None }
    }

    pub fn spec(&self) -> &StreamSpec {
        &self.spec
    }

    /// Prototype of class `c` at batch index `t` under the drift process.
    fn proto_at(&self, c: usize, t: u64) -> Vec<f32> {
        match self.spec.kind {
            DriftKind::Covariate { cycles } => {
                let frac = t as f64 / self.spec.num_batches.max(1) as f64;
                let theta = 2.0 * std::f64::consts::PI * cycles * frac;
                let (s, co) = (theta.sin() as f32, theta.cos() as f32);
                self.protos[c]
                    .iter()
                    .zip(&self.protos_b[c])
                    .map(|(&a, &b)| co * a + s * b)
                    .collect()
            }
            _ => self.protos[c].clone(),
        }
    }

    /// Classes admissible at batch index `t`.
    fn active_classes(&self, t: u64) -> std::ops::Range<usize> {
        match self.spec.kind {
            DriftKind::ClassIncremental { tasks } => {
                let tasks = tasks.max(1).min(self.spec.classes);
                let per = self.spec.num_batches.div_ceil(tasks);
                let task = ((t as usize) / per.max(1)).min(tasks - 1);
                let cls_per = self.spec.classes / tasks;
                let lo = task * cls_per;
                let hi = if task == tasks - 1 { self.spec.classes } else { lo + cls_per };
                lo..hi
            }
            _ => 0..self.spec.classes,
        }
    }

    fn sample_label(&mut self, t: u64) -> usize {
        match self.spec.kind {
            DriftKind::Temporal { dwell } => {
                // deterministic class schedule with correlated dwells
                let session = (t as usize) / dwell.max(1);
                let mut srng = Rng::new(self.spec.seed ^ (session as u64).wrapping_mul(0x9E37));
                srng.below(self.spec.classes)
            }
            _ => {
                let range = self.active_classes(t);
                range.start + self.rng.below(range.end - range.start)
            }
        }
    }

    /// End the stream after `k` more batches without altering its drift
    /// schedule (`spec.num_batches` keeps shaping content): the truncated
    /// stream is an exact prefix of the full one. Used by baselines that
    /// train on a window of a longer stream.
    pub fn truncate_after(&mut self, k: usize) {
        self.stop = Some(self.pos + k as u64);
    }

    /// Next microbatch, or None when the stream is exhausted.
    pub fn next_batch(&mut self) -> Option<Batch> {
        if self.pos >= self.spec.num_batches as u64
            || self.stop.is_some_and(|s| self.pos >= s)
        {
            return None;
        }
        let t = self.pos;
        let b = self.spec.batch;
        let f = self.spec.features;
        let mut x = Vec::with_capacity(b * f);
        let mut y = Vec::with_capacity(b);
        for _ in 0..b {
            let label = self.sample_label(t);
            let proto = self.proto_at(label, t);
            for j in 0..f {
                x.push(proto[j] + self.rng.normal_f32(0.0, self.spec.noise));
            }
            y.push(label as i32);
        }
        self.pos += 1;
        Some(Batch { id: t, x, y })
    }

    /// Total microbatches remaining.
    pub fn remaining(&self) -> usize {
        self.spec.num_batches - self.pos as usize
    }

    /// Held-out test set over all classes, base (undrifted) prototypes —
    /// the `tacc` reference distribution (forgetting measurement).
    pub fn test_set(&self, per_class: usize) -> TestSet {
        let mut rng = Rng::new(self.spec.seed ^ 0x54455354); // "TEST"
        let f = self.spec.features;
        let n = per_class * self.spec.classes;
        let mut x = Vec::with_capacity(n * f);
        let mut y = Vec::with_capacity(n);
        for c in 0..self.spec.classes {
            for _ in 0..per_class {
                for j in 0..f {
                    x.push(self.protos[c][j] + rng.normal_f32(0.0, self.spec.noise));
                }
                y.push(c as i32);
            }
        }
        TestSet { x, y, n }
    }
}

impl crate::stream::Stream for SyntheticStream {
    fn next_batch(&mut self) -> Option<Batch> {
        SyntheticStream::next_batch(self)
    }

    fn test_set(&self, per_class: usize) -> TestSet {
        SyntheticStream::test_set(self, per_class)
    }

    fn len_hint(&self) -> Option<usize> {
        let cut = match self.stop {
            Some(s) => s.saturating_sub(self.pos) as usize,
            None => usize::MAX,
        };
        Some(self.remaining().min(cut))
    }

    fn provenance(&self) -> Option<StreamSpec> {
        Some(self.spec.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kind: DriftKind) -> StreamSpec {
        StreamSpec {
            name: "t".into(),
            features: 12,
            classes: 6,
            batch: 4,
            num_batches: 30,
            kind,
            margin: 3.0,
            noise: 0.5,
            seed: 7,
        }
    }

    #[test]
    fn truncate_is_an_exact_prefix_under_drift() {
        // truncation must not compress the task schedule the way
        // shrinking num_batches does
        let k = DriftKind::ClassIncremental { tasks: 5 };
        let mut full = SyntheticStream::new(spec(k));
        let mut cut = SyntheticStream::new(spec(k));
        cut.truncate_after(12);
        let mut n = 0;
        while let Some(b) = cut.next_batch() {
            let f = full.next_batch().expect("full stream is longer");
            assert_eq!(b.x, f.x);
            assert_eq!(b.y, f.y);
            n += 1;
        }
        assert_eq!(n, 12, "stops exactly at the cut");
        assert!(full.next_batch().is_some(), "full stream continues");
    }

    #[test]
    fn deterministic_and_exhausts() {
        let mut a = SyntheticStream::new(spec(DriftKind::Stationary));
        let mut b = SyntheticStream::new(spec(DriftKind::Stationary));
        let mut count = 0;
        while let (Some(ba), Some(bb)) = (a.next_batch(), b.next_batch()) {
            assert_eq!(ba.x, bb.x);
            assert_eq!(ba.y, bb.y);
            assert_eq!(ba.id, count);
            count += 1;
        }
        assert_eq!(count, 30);
        assert!(a.next_batch().is_none());
        assert_eq!(a.remaining(), 0);
    }

    #[test]
    fn shapes_and_label_range() {
        let mut s = SyntheticStream::new(spec(DriftKind::Stationary));
        let b = s.next_batch().unwrap();
        assert_eq!(b.x.len(), 4 * 12);
        assert_eq!(b.y.len(), 4);
        assert!(b.y.iter().all(|&y| (0..6).contains(&y)));
    }

    #[test]
    fn class_incremental_respects_task_phases() {
        let mut s = SyntheticStream::new(spec(DriftKind::ClassIncremental { tasks: 3 }));
        // 30 batches / 3 tasks = 10 per task; classes 0-1, 2-3, 4-5
        let mut seen_by_phase = [std::collections::BTreeSet::new(), Default::default(), Default::default()];
        while let Some(b) = s.next_batch() {
            let phase = (b.id as usize) / 10;
            for &y in &b.y {
                seen_by_phase[phase].insert(y);
            }
        }
        assert!(seen_by_phase[0].iter().all(|&y| y < 2), "{:?}", seen_by_phase[0]);
        assert!(seen_by_phase[1].iter().all(|&y| (2..4).contains(&y)));
        assert!(seen_by_phase[2].iter().all(|&y| (4..6).contains(&y)));
    }

    #[test]
    fn covariate_prototypes_move() {
        let s = SyntheticStream::new(spec(DriftKind::Covariate { cycles: 1.0 }));
        let p0 = s.proto_at(0, 0);
        let p_half = s.proto_at(0, 15);
        let d: f32 = p0.iter().zip(&p_half).map(|(a, b)| (a - b).abs()).sum();
        assert!(d > 0.1, "prototypes did not drift: {d}");
        // stationary does not move
        let st = SyntheticStream::new(spec(DriftKind::Stationary));
        assert_eq!(st.proto_at(0, 0), st.proto_at(0, 29));
    }

    #[test]
    fn temporal_dwells_on_classes() {
        let mut s = SyntheticStream::new(spec(DriftKind::Temporal { dwell: 5 }));
        let mut labels = Vec::new();
        while let Some(b) = s.next_batch() {
            labels.push(b.y[0]);
        }
        // within each dwell window the label is constant
        for chunk in labels.chunks(5) {
            assert!(chunk.iter().all(|&y| y == chunk[0]), "{chunk:?}");
        }
    }

    #[test]
    fn drift_kind_spec_round_trips() {
        for k in [
            DriftKind::Stationary,
            DriftKind::ClassIncremental { tasks: 5 },
            DriftKind::Covariate { cycles: 1.5 },
            DriftKind::Temporal { dwell: 7 },
        ] {
            assert_eq!(DriftKind::parse(&k.spec_str()), Some(k), "{}", k.spec_str());
        }
        assert_eq!(DriftKind::parse("warp"), None);
        assert_eq!(DriftKind::parse("temporal"), None, "missing arg");
    }

    #[test]
    fn test_set_covers_all_classes() {
        let s = SyntheticStream::new(spec(DriftKind::ClassIncremental { tasks: 3 }));
        let ts = s.test_set(3);
        assert_eq!(ts.n, 18);
        assert_eq!(ts.x.len(), 18 * 12);
        for c in 0..6 {
            assert_eq!(ts.y.iter().filter(|&&y| y == c).count(), 3);
        }
    }

    #[test]
    fn margin_controls_separability() {
        // a nearest-prototype classifier should do well at high margin and
        // poorly at tiny margin
        let mut hi = spec(DriftKind::Stationary);
        hi.margin = 6.0;
        hi.noise = 0.5;
        let mut lo = spec(DriftKind::Stationary);
        lo.margin = 0.05;
        lo.noise = 1.0;
        let acc = |sp: StreamSpec| -> f64 {
            let mut s = SyntheticStream::new(sp.clone());
            let protos: Vec<Vec<f32>> = (0..sp.classes).map(|c| s.proto_at(c, 0)).collect();
            let mut hit = 0usize;
            let mut total = 0usize;
            while let Some(b) = s.next_batch() {
                for i in 0..b.y.len() {
                    let xi = &b.x[i * sp.features..(i + 1) * sp.features];
                    let best = (0..sp.classes)
                        .min_by(|&a, &bb| {
                            let da: f32 = xi.iter().zip(&protos[a]).map(|(x, p)| (x - p) * (x - p)).sum();
                            let db: f32 = xi.iter().zip(&protos[bb]).map(|(x, p)| (x - p) * (x - p)).sum();
                            da.partial_cmp(&db).unwrap()
                        })
                        .unwrap();
                    hit += (best as i32 == b.y[i]) as usize;
                    total += 1;
                }
            }
            hit as f64 / total as f64
        };
        assert!(acc(hi) > 0.95);
        assert!(acc(lo) < 0.6);
    }
}
