//! Time-varying memory budgets and measured-memory accounting.
//!
//! Ferret's headline claim is adapting to *varying* memory constraints;
//! this module makes the budget a first-class time-varying signal:
//!
//!   - [`BudgetSchedule`] — a piecewise-constant budget over the run: a
//!     list of `(at, bytes)` steps, where `at` is a stream batch index
//!     (lockstep replans at batch boundaries) or a wall-clock microsecond
//!     stamp (freerun). Parseable from the CLI (`--budget-schedule`), e.g.
//!     `"24mb@0,12mb@b80,8mb@u500000"`.
//!   - [`BudgetState`]    — the engine-side cursor: advances through due
//!     steps, exposes the budget currently in force, and arms a one-shot
//!     measured-bytes breach trigger per schedule window.
//!   - [`LedgerSnapshot`] / [`MemoryLedger`] — the live memory ledger: the
//!     engine meters the bytes it *actually* holds (live parameters,
//!     stashed weight versions distinct from the live copy, in-flight
//!     activations/gradients, compensator state) and the ledger keeps
//!     per-category peaks, the latest snapshot, and a memory-over-time
//!     trace (one point per parameter update).
//!
//! When a step fires (or the ledger breaches the budget in force) the
//! engine drains in-flight work, re-invokes the planner against the new
//! budget with a profile refreshed from this run's measured stage times,
//! and executes a plan transition — see `pipeline::engine`.

/// When a budget step takes effect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepAt {
    /// at the arrival of stream batch `seq` (the lockstep replan boundary)
    Batch(u64),
    /// at wall-clock microsecond `us` since the run started (freerun
    /// only; lockstep drops wall-time steps up front —
    /// [`BudgetState::without_wall_steps`] — so they cannot block
    /// batch-index steps queued behind them)
    Us(u64),
}

/// One step of the budget schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetStep {
    pub at: StepAt,
    pub bytes: f64,
}

/// A piecewise-constant memory budget over the run. Steps fire in list
/// order; a step at batch 0 (or µs 0) sets the budget in force from the
/// start without triggering a re-plan.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct BudgetSchedule {
    pub steps: Vec<BudgetStep>,
}

/// Parse a byte size with an optional `b`/`kb`/`mb`/`gb` suffix; a bare
/// number means megabytes; `inf` means unconstrained.
fn parse_bytes(s: &str) -> Result<f64, String> {
    let t = s.trim().to_ascii_lowercase();
    if t == "inf" || t == "unlimited" {
        return Ok(f64::INFINITY);
    }
    let (num, mult) = if let Some(x) = t.strip_suffix("gb") {
        (x, 1e9)
    } else if let Some(x) = t.strip_suffix("mb") {
        (x, 1e6)
    } else if let Some(x) = t.strip_suffix("kb") {
        (x, 1e3)
    } else if let Some(x) = t.strip_suffix('b') {
        (x, 1.0)
    } else {
        (t.as_str(), 1e6)
    };
    match num.trim().parse::<f64>() {
        Ok(v) if v >= 0.0 => Ok(v * mult),
        _ => Err(format!("bad byte size '{s}' (expected e.g. '12mb', '800kb', 'inf')")),
    }
}

fn parse_at(s: &str) -> Result<StepAt, String> {
    let t = s.trim().to_ascii_lowercase();
    let (kind, digits) = if let Some(x) = t.strip_prefix('b') {
        ("b", x)
    } else if let Some(x) = t.strip_prefix('u') {
        ("u", x)
    } else {
        ("b", t.as_str())
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| format!("bad step position '{s}' (expected e.g. 'b80' or 'u500000')"))?;
    Ok(if kind == "u" { StepAt::Us(n) } else { StepAt::Batch(n) })
}

impl StepAt {
    /// The `<at>` half of a schedule-spec entry: `b<N>` / `u<N>`.
    pub fn spec_string(&self) -> String {
        match self {
            StepAt::Batch(b) => format!("b{b}"),
            StepAt::Us(u) => format!("u{u}"),
        }
    }
}

impl BudgetSchedule {
    /// The static (non-varying) schedule: the budget the run was planned
    /// for stays in force for the whole stream.
    pub fn fixed() -> Self {
        BudgetSchedule::default()
    }

    /// Serialize back to the [`BudgetSchedule::parse`] format, so a trace
    /// can carry the schedule's provenance as plain text:
    /// `parse(s.spec_string())` reproduces `s` exactly. Byte counts are
    /// written with the exact-unit `b` suffix (`f64` Display is
    /// shortest-roundtrip, so fractional plan-derived budgets survive);
    /// an unconstrained window is `inf`. Empty (static) schedules
    /// serialize to `""` — callers treat that as "no schedule".
    pub fn spec_string(&self) -> String {
        self.steps
            .iter()
            .map(|s| {
                let bytes = if s.bytes.is_infinite() {
                    "inf".to_string()
                } else {
                    format!("{}b", s.bytes)
                };
                format!("{bytes}@{}", s.at.spec_string())
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// True when the schedule carries any step — the engine then meters
    /// the ledger against the budget in force and re-plans at steps.
    pub fn is_dynamic(&self) -> bool {
        !self.steps.is_empty()
    }

    /// Convenience: a single mid-stream step to `bytes` at batch `at`.
    pub fn step_at_batch(at: u64, bytes: f64) -> Self {
        BudgetSchedule { steps: vec![BudgetStep { at: StepAt::Batch(at), bytes }] }
    }

    /// Parse a comma-separated schedule spec: each entry is
    /// `<bytes>@<at>` where `<bytes>` takes a `b|kb|mb|gb` suffix (bare
    /// number = MB, `inf` = unconstrained) and `<at>` is `b<N>` (batch
    /// index, the default for a bare number) or `u<N>` (microseconds).
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut steps = Vec::new();
        for entry in s.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (bytes_s, at_s) = entry
                .split_once('@')
                .ok_or_else(|| format!("bad schedule entry '{entry}' (expected '<bytes>@<at>')"))?;
            steps.push(BudgetStep { at: parse_at(at_s)?, bytes: parse_bytes(bytes_s)? });
        }
        if steps.is_empty() {
            return Err("empty budget schedule".into());
        }
        // steps fire in list order: a same-kind position that does not
        // increase can never fire at its stated point — reject it rather
        // than silently running at the wrong budget (batch/µs
        // interleavings are the caller's call)
        let (mut last_batch, mut last_us) = (None, None);
        for step in &steps {
            let out_of_order = match step.at {
                StepAt::Batch(b) => {
                    let bad = last_batch.map_or(false, |x| b <= x);
                    last_batch = Some(b);
                    bad
                }
                StepAt::Us(u) => {
                    let bad = last_us.map_or(false, |x| u <= x);
                    last_us = Some(u);
                    bad
                }
            };
            if out_of_order {
                return Err(format!(
                    "schedule steps out of order: {:?} cannot fire after a later same-kind step",
                    step.at
                ));
            }
        }
        Ok(BudgetSchedule { steps })
    }
}

/// Engine-side cursor over a [`BudgetSchedule`]: tracks the budget in
/// force and arms the measured-bytes breach trigger once per window.
#[derive(Debug, Clone)]
pub struct BudgetState {
    steps: Vec<BudgetStep>,
    idx: usize,
    current: f64,
    breach_armed: bool,
}

impl BudgetState {
    /// Start the cursor; steps already due at (batch 0, µs 0) set the
    /// initial budget in force without counting as a re-plan trigger.
    pub fn new(schedule: &BudgetSchedule) -> Self {
        let mut st = BudgetState {
            steps: schedule.steps.clone(),
            idx: 0,
            current: f64::INFINITY,
            breach_armed: true,
        };
        while st.idx < st.steps.len()
            && matches!(st.steps[st.idx].at, StepAt::Batch(0) | StepAt::Us(0))
        {
            st.current = st.steps[st.idx].bytes;
            st.idx += 1;
        }
        st
    }

    /// Lockstep cursor: virtual time never reaches wall-clock stamps, so
    /// `u<N>` steps are dropped up front — otherwise an early wall-time
    /// step would sit at the head of the queue and block every
    /// batch-index step behind it from ever firing.
    pub fn without_wall_steps(schedule: &BudgetSchedule) -> Self {
        let filtered = BudgetSchedule {
            steps: schedule
                .steps
                .iter()
                .copied()
                .filter(|s| matches!(s.at, StepAt::Batch(_)))
                .collect(),
        };
        BudgetState::new(&filtered)
    }

    /// The budget currently in force, in bytes.
    pub fn current(&self) -> f64 {
        self.current
    }

    /// Advance past every step due at stream position `seq` / wall time
    /// `us`; returns true when any step fired (the engine must then drain
    /// and re-plan). Lockstep passes `us = 0`, so wall-time steps only
    /// fire in freerun.
    pub fn step_due(&mut self, seq: u64, us: u64) -> bool {
        let mut fired = false;
        while self.idx < self.steps.len() {
            let due = match self.steps[self.idx].at {
                StepAt::Batch(b) => seq >= b,
                StepAt::Us(u) => us >= u,
            };
            if !due {
                break;
            }
            self.current = self.steps[self.idx].bytes;
            self.idx += 1;
            self.breach_armed = true;
            fired = true;
        }
        fired
    }

    /// One-shot measured-bytes breach: true the first time the ledger
    /// exceeds the budget in force within the current schedule window.
    /// (Re-planning at the same budget cannot loop: the trigger re-arms
    /// only when the next step fires.)
    pub fn breached(&mut self, total_bytes: usize) -> bool {
        if self.breach_armed && (total_bytes as f64) > self.current {
            self.breach_armed = false;
            true
        } else {
            false
        }
    }

    /// Steps not yet fired.
    pub fn remaining_steps(&self) -> usize {
        self.steps.len() - self.idx
    }

    /// Imperatively override the budget in force
    /// ([`Session::set_budget`](crate::pipeline::session::Session::set_budget)):
    /// the scheduled steps keep their cursor, and the measured-bytes
    /// breach trigger re-arms for the new window.
    pub fn set_current(&mut self, bytes: f64) {
        self.current = bytes;
        self.breach_armed = true;
    }
}

/// Measured bytes by category at one observation point. `stash` counts
/// only versions physically distinct from the live copy (the newest stash
/// entry aliases the live `Arc` by construction), so `total` reflects
/// bytes actually held, not the logical Eq. 4 accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LedgerSnapshot {
    /// live model parameters
    pub params: usize,
    /// stashed weight versions distinct from the live copy
    pub stash: usize,
    /// in-flight activations, gradients, labels, and accumulators
    pub acts: usize,
    /// compensator EMA state (Alg. 1's O(2Σ|w|))
    pub comps: usize,
}

impl LedgerSnapshot {
    pub fn total(&self) -> usize {
        self.params + self.stash + self.acts + self.comps
    }
}

/// Upper bound on the memory-over-time trace: reaching it halves the
/// stored points and doubles the sampling stride, so a long-lived session
/// holds at most this many points while the trace still spans the run.
pub const TRACE_CAP: usize = 4096;

/// Accumulated measured-memory accounting over one engine run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoryLedger {
    /// per-category peaks (not necessarily simultaneous)
    pub peak: LedgerSnapshot,
    /// peak of the summed snapshot
    pub peak_total: usize,
    /// the latest snapshot (end-of-run state after the final event)
    pub last: LedgerSnapshot,
    /// memory-over-time trace: `(t, total_bytes)` points, at most one per
    /// parameter update and never more than [`TRACE_CAP`] in total
    /// (long sessions are downsampled, not truncated)
    pub trace: Vec<(u64, usize)>,
    /// record one trace point per this many updates (doubles at each
    /// downsampling pass; peaks/last stay exact regardless)
    stride: u64,
    /// updates observed since the last appended trace point
    pending: u64,
}

impl Default for MemoryLedger {
    fn default() -> Self {
        MemoryLedger {
            peak: LedgerSnapshot::default(),
            peak_total: 0,
            last: LedgerSnapshot::default(),
            trace: Vec::new(),
            stride: 1,
            pending: 0,
        }
    }
}

impl MemoryLedger {
    /// Fold one snapshot into the peaks without extending the trace (the
    /// engine observes at every scheduler event).
    pub fn observe(&mut self, snap: LedgerSnapshot) {
        self.peak.params = self.peak.params.max(snap.params);
        self.peak.stash = self.peak.stash.max(snap.stash);
        self.peak.acts = self.peak.acts.max(snap.acts);
        self.peak.comps = self.peak.comps.max(snap.comps);
        self.peak_total = self.peak_total.max(snap.total());
        self.last = snap;
    }

    /// Observe and append a trace point (called once per update). Past
    /// [`TRACE_CAP`] points the trace is decimated — every other point
    /// dropped, sampling stride doubled — so unbounded sessions cannot
    /// grow the metrics sink without limit. Deterministic: the same update
    /// sequence always yields the same trace.
    pub fn record(&mut self, t: u64, snap: LedgerSnapshot) {
        self.observe(snap);
        self.pending += 1;
        if self.pending < self.stride {
            return;
        }
        self.pending = 0;
        self.trace.push((t, snap.total()));
        if self.trace.len() >= TRACE_CAP {
            // keep odd positions so the just-pushed (newest) point always
            // survives — live metrics readers see a fresh tail; the head
            // loses at most one stride of early history per pass
            let mut i = 0usize;
            self.trace.retain(|_| {
                i += 1;
                i % 2 == 0
            });
            self.stride *= 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sizes_positions_and_errors() {
        let s = BudgetSchedule::parse("24mb@0,12mb@b80,1gb@u5000,800kb@b90,64b@b99").unwrap();
        assert_eq!(s.steps.len(), 5);
        assert_eq!(s.steps[0], BudgetStep { at: StepAt::Batch(0), bytes: 24e6 });
        assert_eq!(s.steps[1], BudgetStep { at: StepAt::Batch(80), bytes: 12e6 });
        assert_eq!(s.steps[2], BudgetStep { at: StepAt::Us(5000), bytes: 1e9 });
        assert_eq!(s.steps[3], BudgetStep { at: StepAt::Batch(90), bytes: 800e3 });
        assert_eq!(s.steps[4], BudgetStep { at: StepAt::Batch(99), bytes: 64.0 });
        // bare number = MB; inf = unconstrained
        let s = BudgetSchedule::parse("3@b10,inf@b20").unwrap();
        assert_eq!(s.steps[0].bytes, 3e6);
        assert!(s.steps[1].bytes.is_infinite());
        assert!(BudgetSchedule::parse("").is_err());
        assert!(BudgetSchedule::parse("12mb").is_err(), "missing @at");
        assert!(BudgetSchedule::parse("x@b3").is_err(), "bad bytes");
        assert!(BudgetSchedule::parse("5mb@z3").is_err(), "bad position");
        assert!(BudgetSchedule::parse("-5mb@b3").is_err(), "negative budget");
        assert!(
            BudgetSchedule::parse("8mb@b100,2mb@b20").is_err(),
            "out-of-order steps can never fire at their stated point"
        );
        assert!(BudgetSchedule::parse("8mb@u90,2mb@u20").is_err(), "same for wall time");
        assert!(!BudgetSchedule::fixed().is_dynamic());
        assert!(BudgetSchedule::step_at_batch(8, 1e6).is_dynamic());
    }

    #[test]
    fn spec_string_round_trips_through_parse() {
        for spec in ["24mb@0,12mb@b80,1gb@u5000,800kb@b90,64b@b99", "inf@b20", "3@b10"] {
            let s = BudgetSchedule::parse(spec).unwrap();
            let rt = BudgetSchedule::parse(&s.spec_string()).unwrap();
            assert_eq!(s, rt, "spec {spec:?} -> {:?}", s.spec_string());
        }
        // fractional plan-derived budgets survive the text round trip
        let s = BudgetSchedule::step_at_batch(60, 1234567.89);
        let rt = BudgetSchedule::parse(&s.spec_string()).unwrap();
        assert_eq!(s, rt);
        assert_eq!(BudgetSchedule::fixed().spec_string(), "");
    }

    #[test]
    fn state_absorbs_initial_step_and_fires_in_order() {
        let s = BudgetSchedule::parse("24mb@0,12mb@b80,6mb@b120").unwrap();
        let mut st = BudgetState::new(&s);
        assert_eq!(st.current(), 24e6, "batch-0 step sets the initial budget");
        assert_eq!(st.remaining_steps(), 2);
        assert!(!st.step_due(79, 0));
        assert_eq!(st.current(), 24e6);
        assert!(st.step_due(80, 0));
        assert_eq!(st.current(), 12e6);
        assert!(!st.step_due(81, 0), "a step fires once");
        // jumping past several steps fires them all, landing on the last
        assert!(st.step_due(500, 0));
        assert_eq!(st.current(), 6e6);
        assert_eq!(st.remaining_steps(), 0);
    }

    #[test]
    fn wall_time_steps_never_fire_in_lockstep() {
        let s = BudgetSchedule::parse("12mb@u100").unwrap();
        let mut st = BudgetState::new(&s);
        assert!(!st.step_due(1_000_000, 0), "lockstep passes us=0");
        assert!(st.step_due(0, 100), "freerun wall time fires it");
        assert_eq!(st.current(), 12e6);
    }

    #[test]
    fn lockstep_cursor_drops_wall_steps_instead_of_wedging() {
        // a mixed schedule: a never-due-in-lockstep wall step queued ahead
        // of a batch step must not block it
        let s = BudgetSchedule::parse("24mb@u1000,12mb@b80").unwrap();
        let mut st = BudgetState::without_wall_steps(&s);
        assert_eq!(st.remaining_steps(), 1, "wall step dropped");
        assert!(st.step_due(80, 0), "the batch step behind it still fires");
        assert_eq!(st.current(), 12e6);
        // a wall step at 0 would have set the initial budget; dropped too
        let st0 = BudgetState::without_wall_steps(&BudgetSchedule::parse("9mb@u0").unwrap());
        assert_eq!(st0.current(), f64::INFINITY);
    }

    #[test]
    fn breach_fires_once_per_window() {
        let s = BudgetSchedule::parse("1kb@0,2kb@b10").unwrap();
        let mut st = BudgetState::new(&s);
        assert!(!st.breached(1000), "at the budget is not a breach");
        assert!(st.breached(1001));
        assert!(!st.breached(5000), "disarmed until the next step");
        assert!(st.step_due(10, 0));
        assert!(st.breached(2001), "re-armed by the step");
        // static schedules (infinite budget) never breach
        let mut free = BudgetState::new(&BudgetSchedule::fixed());
        assert!(!free.breached(usize::MAX));
        assert_eq!(free.current(), f64::INFINITY);
    }

    #[test]
    fn set_current_overrides_and_rearms_breach() {
        let mut st = BudgetState::new(&BudgetSchedule::fixed());
        assert_eq!(st.current(), f64::INFINITY);
        st.set_current(1000.0);
        assert_eq!(st.current(), 1000.0);
        assert!(st.breached(1001), "override arms the breach trigger");
        assert!(!st.breached(5000), "one-shot until re-armed");
        st.set_current(100.0);
        assert!(st.breached(101), "each override re-arms");
    }

    #[test]
    fn trace_is_downsampled_past_the_cap() {
        let mut l = MemoryLedger::default();
        let n = 10 * TRACE_CAP as u64;
        for t in 0..n {
            l.record(t, LedgerSnapshot { params: 1, stash: 0, acts: t as usize, comps: 0 });
        }
        assert!(l.trace.len() <= TRACE_CAP, "trace {} > cap", l.trace.len());
        assert!(l.trace.len() > TRACE_CAP / 4, "downsampling keeps coverage");
        // downsampled, not truncated: the trace still spans the whole run
        // (each pass sheds at most a stride of early history, and the
        // newest point always survives — live readers see a fresh tail)
        assert!(l.trace[0].0 < 64, "head stays early: {}", l.trace[0].0);
        assert!(l.trace.last().unwrap().0 > n - 2 * (n / TRACE_CAP as u64).next_power_of_two());
        // strictly increasing stamps (every point is a real observation)
        for w in l.trace.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        // peaks/last are exact regardless of decimation
        assert_eq!(l.peak.acts, n as usize - 1);
        assert_eq!(l.last.acts, n as usize - 1);
        // determinism: the same sequence yields the same trace
        let mut m = MemoryLedger::default();
        for t in 0..n {
            m.record(t, LedgerSnapshot { params: 1, stash: 0, acts: t as usize, comps: 0 });
        }
        assert_eq!(l.trace, m.trace);
    }

    #[test]
    fn ledger_tracks_peaks_last_and_trace() {
        let mut l = MemoryLedger::default();
        l.observe(LedgerSnapshot { params: 10, stash: 20, acts: 5, comps: 1 });
        l.observe(LedgerSnapshot { params: 10, stash: 5, acts: 50, comps: 1 });
        assert_eq!(l.peak.stash, 20);
        assert_eq!(l.peak.acts, 50);
        assert_eq!(l.peak_total, 66, "peak of totals, not total of peaks");
        assert_eq!(l.last.acts, 50);
        assert!(l.trace.is_empty(), "observe does not trace");
        l.record(7, LedgerSnapshot { params: 10, stash: 5, acts: 0, comps: 1 });
        assert_eq!(l.trace, vec![(7, 16)]);
        assert_eq!(l.last.total(), 16);
        assert_eq!(l.peak_total, 66);
    }
}
