//! obs — live pipeline observability.
//!
//! Ferret's claims are about *where time goes*: fine-grained pipeline
//! parallelism hiding latency, bounded staleness, cheap plan transitions.
//! [`crate::metrics::RunMetrics`] only reports end-of-run aggregates;
//! this module makes the pipeline inspectable while it runs:
//!
//!   - [`Recorder`] — a per-device **span recorder**. Every device pass
//!     (forward, backward, parameter update, offloaded augment) and every
//!     engine-level stall (drain, re-plan) is a [`Span`] with start/end
//!     stamps taken from the run's [`Clock`](crate::pipeline::Clock): in
//!     lockstep the stamps are virtual ticks, so span traces are
//!     bit-deterministic and executor-independent like everything else;
//!     in freerun they are real microseconds. Recording is opt-in — the
//!     disabled recorder is a one-arm enum match on the hot path, pinned
//!     by the `--exp perf --compare` CI gate.
//!   - Pipeline **accounting**, computed incrementally at record time
//!     (never by re-scanning the rings): per-device busy time and span
//!     counts, bubble fraction, drain/re-plan stall attribution, a live
//!     staleness gauge, and latency percentiles over a sliding window.
//!     Exposed as a [`Snapshot`] via
//!     [`Session::obs_snapshot()`](crate::pipeline::Session::obs_snapshot).
//!   - A **snapshot streamer** ([`SnapshotWriter`]): `ferret run
//!     --metrics-out PATH --metrics-interval N` appends one JSON-lines
//!     [`Snapshot`] record every N stream arrivals (a deterministic
//!     cadence — lockstep streams replay identically).
//!   - **Perfetto/Chrome trace-event export**
//!     ([`write_chrome_trace`]): `ferret run --span-trace out.json`
//!     writes the recorded spans as Chrome trace-event JSON — open it in
//!     `ui.perfetto.dev` and read the 1F1B schedule off the timeline.
//!
//! Span rings are bounded ([`SPAN_CAP`] per device, oldest dropped), so
//! a long-lived session cannot grow the recorder without limit; the
//! accounting is folded in before a span can be evicted and stays exact
//! regardless. See `docs/observability.md` for the snapshot schema and
//! the Perfetto how-to.

use std::collections::VecDeque;
use std::io::Write;

use crate::bail;
use crate::metrics::percentile_u64;
use crate::trace::json::fmt_f64;
use crate::util::error::Result;

/// Max spans retained per device ring; recording past it drops the
/// oldest span (the accounting has already absorbed it, so busy/stall
/// totals stay exact).
pub const SPAN_CAP: usize = 4096;

/// Sliding-window size for the live latency percentiles.
pub const WINDOW_CAP: usize = 256;

/// JSON-lines schema tag of the snapshot stream (`--metrics-out`).
pub const SNAPSHOT_SCHEMA: &str = "ferret-obs/1";

/// Pseudo-device for engine-scope spans (drain / re-plan): they belong
/// to the transition protocol, not to any (worker, stage) device.
pub const ENGINE_DEVICE: (usize, usize) = (usize::MAX, 0);

/// What a span measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// forward pass of one microbatch on one stage
    Fwd,
    /// backward pass
    Bwd,
    /// parameter update (T2-accumulated)
    Update,
    /// offloaded augment hook (freerun, carved out of the stage-0 Fwd)
    Augment,
    /// plan transition: in-flight work draining under the old plan
    Drain,
    /// plan transition: re-plan + weight migration + reconfigure
    Replan,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Fwd => "Fwd",
            SpanKind::Bwd => "Bwd",
            SpanKind::Update => "Update",
            SpanKind::Augment => "Augment",
            SpanKind::Drain => "Drain",
            SpanKind::Replan => "Replan",
        }
    }

    fn idx(&self) -> usize {
        match self {
            SpanKind::Fwd => 0,
            SpanKind::Bwd => 1,
            SpanKind::Update => 2,
            SpanKind::Augment => 3,
            SpanKind::Drain => 4,
            SpanKind::Replan => 5,
        }
    }

    /// Device-scope spans contribute to per-device busy time;
    /// engine-scope spans (drain/re-plan) are stall attribution instead.
    fn is_device_work(&self) -> bool {
        !matches!(self, SpanKind::Drain | SpanKind::Replan)
    }
}

/// One recorded interval. Stamps are clock ticks: virtual ticks in
/// lockstep (deterministic), microseconds since run start in freerun.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// (worker, stage); [`ENGINE_DEVICE`] for drain/re-plan
    pub device: (usize, usize),
    pub kind: SpanKind,
    /// microbatch seq for Fwd/Bwd/Augment, contributing-arrival count
    /// for Update, re-plan ordinal for Drain/Replan
    pub mb: u64,
    pub start_us: u64,
    pub end_us: u64,
    /// stage parameter version: the version the pass read (Fwd/Bwd) or
    /// produced (Update); 0 where versioning does not apply
    pub version: u64,
}

/// Ring + incrementally-maintained totals for one device.
#[derive(Debug, Clone)]
struct DeviceTrack {
    device: (usize, usize),
    spans: VecDeque<Span>,
    evicted: u64,
    busy_us: u64,
    counts: [u64; 6],
    last_end_us: u64,
}

impl DeviceTrack {
    fn new(device: (usize, usize)) -> Self {
        DeviceTrack {
            device,
            spans: VecDeque::new(),
            evicted: 0,
            busy_us: 0,
            counts: [0; 6],
            last_end_us: 0,
        }
    }

    fn push(&mut self, span: Span) {
        self.counts[span.kind.idx()] += 1;
        let dur = span.end_us.saturating_sub(span.start_us);
        if span.kind.is_device_work() {
            self.busy_us += dur;
        }
        self.last_end_us = self.last_end_us.max(span.end_us);
        if self.spans.len() >= SPAN_CAP {
            self.spans.pop_front();
            self.evicted += 1;
        }
        self.spans.push_back(span);
    }
}

/// Recorder state behind [`Recorder::On`]. Tracks are kept sorted by
/// device key so every derived view iterates in a canonical order no
/// matter which device recorded first.
#[derive(Debug, Clone, Default)]
pub struct RecorderState {
    tracks: Vec<DeviceTrack>,
    stall_us: [u64; 2], // [drain, replan]
    stalls: [u64; 2],
    staleness_last: u64,
    staleness_max: u64,
    window: VecDeque<u64>,
}

impl RecorderState {
    fn track_mut(&mut self, device: (usize, usize)) -> &mut DeviceTrack {
        let at = match self.tracks.binary_search_by_key(&device, |t| t.device) {
            Ok(i) => i,
            Err(i) => {
                self.tracks.insert(i, DeviceTrack::new(device));
                i
            }
        };
        &mut self.tracks[at]
    }
}

/// The span recorder. `Off` is the default and costs one enum match per
/// would-be span; `On` records into per-device rings and folds the
/// accounting incrementally.
#[derive(Debug, Clone, Default)]
pub enum Recorder {
    #[default]
    Off,
    On(Box<RecorderState>),
}

impl Recorder {
    /// An enabled recorder with empty rings.
    pub fn on() -> Self {
        Recorder::On(Box::default())
    }

    pub fn is_on(&self) -> bool {
        matches!(self, Recorder::On(_))
    }

    /// Record one span. No-op when disabled.
    #[inline]
    pub fn record(
        &mut self,
        device: (usize, usize),
        kind: SpanKind,
        mb: u64,
        start_us: u64,
        end_us: u64,
        version: u64,
    ) {
        let Recorder::On(st) = self else { return };
        let end_us = end_us.max(start_us);
        if let SpanKind::Drain | SpanKind::Replan = kind {
            let i = (kind.idx() - SpanKind::Drain.idx()).min(1);
            st.stall_us[i] += end_us - start_us;
            st.stalls[i] += 1;
        }
        st.track_mut(device).push(Span { device, kind, mb, start_us, end_us, version });
    }

    /// Update the live staleness gauge (called at each parameter update
    /// with the observed version gap τ). No-op when disabled.
    #[inline]
    pub fn gauge_staleness(&mut self, tau: u64) {
        if let Recorder::On(st) = self {
            st.staleness_last = tau;
            st.staleness_max = st.staleness_max.max(tau);
        }
    }

    /// Feed one observed per-batch latency into the sliding window.
    /// No-op when disabled.
    #[inline]
    pub fn note_latency(&mut self, us: u64) {
        if let Recorder::On(st) = self {
            if st.window.len() >= WINDOW_CAP {
                st.window.pop_front();
            }
            st.window.push_back(us);
        }
    }

    /// All recorded spans in canonical order: devices by (worker, stage)
    /// key — engine-scope spans last — each ring oldest-first.
    pub fn spans(&self) -> Vec<Span> {
        match self {
            Recorder::Off => Vec::new(),
            Recorder::On(st) => {
                st.tracks.iter().flat_map(|t| t.spans.iter().copied()).collect()
            }
        }
    }

    /// Spans evicted from full rings (accounting still covers them).
    pub fn evicted(&self) -> u64 {
        match self {
            Recorder::Off => 0,
            Recorder::On(st) => st.tracks.iter().map(|t| t.evicted).sum(),
        }
    }

    /// The recorder-side half of a [`Snapshot`] at time `now_us`:
    /// per-device busy/utilization, bubble fraction, stall attribution,
    /// staleness gauge, and windowed latency percentiles. The session
    /// fills in the metrics-side fields (oacc, ledger, pool, counters).
    pub fn snapshot(&self, now_us: u64) -> Snapshot {
        let mut snap = Snapshot { t_us: now_us, ..Snapshot::default() };
        let Recorder::On(st) = self else { return snap };
        let elapsed = now_us.max(1);
        for tr in &st.tracks {
            if tr.device == ENGINE_DEVICE {
                continue;
            }
            snap.busy_us += tr.busy_us;
            snap.devices.push(DeviceSnap {
                worker: tr.device.0,
                stage: tr.device.1,
                busy_us: tr.busy_us,
                spans: tr.counts.iter().sum::<u64>(),
                util: tr.busy_us as f64 / elapsed as f64,
            });
        }
        if !snap.devices.is_empty() {
            let cap = snap.devices.len() as u64 * elapsed;
            snap.bubble_frac = 1.0 - (snap.busy_us.min(cap) as f64 / cap as f64);
        }
        snap.drain_us = st.stall_us[0];
        snap.drains = st.stalls[0];
        snap.replan_us = st.stall_us[1];
        snap.replans = st.stalls[1];
        snap.staleness_last = st.staleness_last;
        snap.staleness_max = st.staleness_max;
        snap.window_n = st.window.len();
        let w: Vec<u64> = st.window.iter().copied().collect();
        snap.p50_us = percentile_u64(&w, 50.0);
        snap.p95_us = percentile_u64(&w, 95.0);
        snap.p99_us = percentile_u64(&w, 99.0);
        snap
    }
}

/// Per-device slice of a [`Snapshot`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceSnap {
    pub worker: usize,
    pub stage: usize,
    pub busy_us: u64,
    pub spans: u64,
    /// busy fraction of the run so far (approximate for devices created
    /// by a mid-run plan transition — they are charged from t=0)
    pub util: f64,
}

/// One streamed observability record (`--metrics-out` JSON lines; also
/// what `Session::obs_snapshot()` returns live).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    /// clock now: virtual ticks (lockstep) or µs since start (freerun)
    pub t_us: u64,
    // -- metrics-side (filled by the session) --
    pub oacc: f64,
    pub ledger_bytes: u64,
    pub pool_takes: u64,
    pub pool_misses: u64,
    pub pool_puts: u64,
    pub arrivals: u64,
    pub trained: u64,
    pub dropped: u64,
    // -- recorder-side --
    pub busy_us: u64,
    pub bubble_frac: f64,
    pub drain_us: u64,
    pub drains: u64,
    pub replan_us: u64,
    pub replans: u64,
    pub staleness_last: u64,
    pub staleness_max: u64,
    pub window_n: usize,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub devices: Vec<DeviceSnap>,
}

impl Snapshot {
    /// One JSON line (no trailing newline), fields in canonical order.
    pub fn to_json_line(&self) -> String {
        let mut s = String::with_capacity(256);
        s.push_str(&format!("{{\"t_us\":{}", self.t_us));
        s.push_str(&format!(",\"oacc\":{}", fmt_f64(self.oacc)));
        s.push_str(&format!(",\"ledger_bytes\":{}", self.ledger_bytes));
        s.push_str(&format!(
            ",\"pool\":{{\"takes\":{},\"misses\":{},\"puts\":{}}}",
            self.pool_takes, self.pool_misses, self.pool_puts
        ));
        s.push_str(&format!(
            ",\"arrivals\":{},\"trained\":{},\"dropped\":{}",
            self.arrivals, self.trained, self.dropped
        ));
        s.push_str(&format!(
            ",\"busy_us\":{},\"bubble_frac\":{}",
            self.busy_us,
            fmt_f64(self.bubble_frac)
        ));
        s.push_str(&format!(
            ",\"drains\":{},\"drain_us\":{},\"replans\":{},\"replan_us\":{}",
            self.drains, self.drain_us, self.replans, self.replan_us
        ));
        s.push_str(&format!(
            ",\"staleness_last\":{},\"staleness_max\":{}",
            self.staleness_last, self.staleness_max
        ));
        s.push_str(&format!(
            ",\"lat_window\":{{\"n\":{},\"p50_us\":{},\"p95_us\":{},\"p99_us\":{}}}",
            self.window_n, self.p50_us, self.p95_us, self.p99_us
        ));
        s.push_str(",\"devices\":[");
        for (i, d) in self.devices.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"worker\":{},\"stage\":{},\"busy_us\":{},\"spans\":{},\"util\":{}}}",
                d.worker,
                d.stage,
                d.busy_us,
                d.spans,
                fmt_f64(d.util)
            ));
        }
        s.push_str("]}");
        s
    }
}

/// Appends JSON-lines [`Snapshot`] records to a file (`--metrics-out`).
/// The first line is a header carrying the schema tag and the cadence.
pub struct SnapshotWriter {
    out: std::io::BufWriter<std::fs::File>,
    path: String,
}

impl SnapshotWriter {
    /// Create/truncate `path` and write the stream header.
    pub fn create(path: &str, interval_arrivals: u64) -> Result<Self> {
        let f = match std::fs::File::create(path) {
            Ok(f) => f,
            Err(e) => bail!("obs: cannot create metrics stream {path}: {e}"),
        };
        let mut w = SnapshotWriter { out: std::io::BufWriter::new(f), path: path.to_string() };
        let hdr = format!(
            "{{\"schema\":\"{SNAPSHOT_SCHEMA}\",\"interval_arrivals\":{interval_arrivals}}}"
        );
        w.line(&hdr)?;
        Ok(w)
    }

    fn line(&mut self, s: &str) -> Result<()> {
        if let Err(e) = writeln!(self.out, "{s}").and_then(|_| self.out.flush()) {
            bail!("obs: writing metrics stream {}: {e}", self.path);
        }
        Ok(())
    }

    /// Append one snapshot record (flushed — the stream is for tailing).
    pub fn write(&mut self, snap: &Snapshot) -> Result<()> {
        self.line(&snap.to_json_line())
    }
}

/// Perfetto export maps engine-scope spans (drain/re-plan) to this pid
/// so they get their own track instead of colliding with a worker.
const CHROME_ENGINE_PID: u64 = 99;

/// Write spans as Chrome trace-event JSON (complete `"ph":"X"` events;
/// `ts`/`dur` in microseconds, `pid` = worker, `tid` = stage). The file
/// opens directly in `ui.perfetto.dev` or `chrome://tracing`.
pub fn write_chrome_trace(path: &str, spans: &[Span]) -> Result<()> {
    let f = match std::fs::File::create(path) {
        Ok(f) => f,
        Err(e) => bail!("obs: cannot create span trace {path}: {e}"),
    };
    let mut out = std::io::BufWriter::new(f);
    let mut sorted: Vec<&Span> = spans.iter().collect();
    sorted.sort_by_key(|s| (s.start_us, s.device, s.kind.idx(), s.mb));
    let mut write = |s: String| -> Result<()> {
        if let Err(e) = out.write_all(s.as_bytes()) {
            bail!("obs: writing span trace {path}: {e}");
        }
        Ok(())
    };
    write("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[".into())?;
    // name the engine track so the drain/re-plan lane reads at a glance
    write(format!(
        "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{CHROME_ENGINE_PID},\
         \"args\":{{\"name\":\"engine (plan transitions)\"}}}}"
    ))?;
    for s in sorted {
        let (pid, tid) = if s.device == ENGINE_DEVICE {
            (CHROME_ENGINE_PID, 0)
        } else {
            (s.device.0 as u64, s.device.1 as u64)
        };
        write(format!(
            ",{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{pid},\
             \"tid\":{tid},\"args\":{{\"mb\":{},\"version\":{}}}}}",
            s.kind.name(),
            s.start_us,
            s.end_us - s.start_us,
            s.mb,
            s.version
        ))?;
    }
    write("]}".into())?;
    if let Err(e) = out.flush() {
        bail!("obs: flushing span trace {path}: {e}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_recorder_records_nothing() {
        let mut r = Recorder::default();
        assert!(!r.is_on());
        r.record((0, 0), SpanKind::Fwd, 1, 0, 10, 0);
        r.gauge_staleness(3);
        r.note_latency(100);
        assert!(r.spans().is_empty());
        let s = r.snapshot(1000);
        assert_eq!(s.busy_us, 0);
        assert!(s.devices.is_empty());
        assert_eq!(s.bubble_frac, 0.0, "no devices -> no bubble claim");
    }

    #[test]
    fn accounting_folds_incrementally_and_canonically() {
        let mut r = Recorder::on();
        // record out of device order: the snapshot must still iterate
        // devices canonically
        r.record((1, 0), SpanKind::Fwd, 0, 0, 10, 0);
        r.record((0, 0), SpanKind::Fwd, 0, 0, 20, 0);
        r.record((0, 0), SpanKind::Bwd, 0, 20, 50, 0);
        r.record((0, 1), SpanKind::Update, 2, 60, 60, 1);
        r.record(ENGINE_DEVICE, SpanKind::Drain, 0, 70, 90, 0);
        r.record(ENGINE_DEVICE, SpanKind::Replan, 0, 90, 95, 0);
        r.gauge_staleness(2);
        r.gauge_staleness(1);
        let s = r.snapshot(100);
        assert_eq!(
            s.devices.iter().map(|d| (d.worker, d.stage)).collect::<Vec<_>>(),
            vec![(0, 0), (0, 1), (1, 0)]
        );
        assert_eq!(s.busy_us, 50 + 0 + 10, "engine stalls are not device busy time");
        assert_eq!(s.devices[0].busy_us, 50);
        assert_eq!(s.devices[0].spans, 2);
        assert_eq!(s.drain_us, 20);
        assert_eq!(s.replan_us, 5);
        assert_eq!((s.drains, s.replans), (1, 1));
        assert_eq!((s.staleness_last, s.staleness_max), (1, 2));
        let expect = 1.0 - 60.0 / 300.0;
        assert!((s.bubble_frac - expect).abs() < 1e-12, "{}", s.bubble_frac);
        // spans() lists engine-scope spans last (usize::MAX sorts high)
        let spans = r.spans();
        assert_eq!(spans.len(), 6);
        assert_eq!(spans.last().unwrap().kind, SpanKind::Replan);
    }

    #[test]
    fn ring_bounds_spans_but_keeps_totals_exact() {
        let mut r = Recorder::on();
        let n = (SPAN_CAP + 100) as u64;
        for i in 0..n {
            r.record((0, 0), SpanKind::Fwd, i, i * 10, i * 10 + 5, 0);
        }
        assert_eq!(r.spans().len(), SPAN_CAP);
        assert_eq!(r.evicted(), 100);
        // oldest evicted, newest kept
        assert_eq!(r.spans().last().unwrap().mb, n - 1);
        assert_eq!(r.spans()[0].mb, 100);
        // busy accounting covers evicted spans too
        assert_eq!(r.snapshot(n * 10).busy_us, n * 5);
    }

    #[test]
    fn latency_window_slides_and_percentiles_are_safe() {
        let mut r = Recorder::on();
        assert_eq!(r.snapshot(10).p50_us, 0, "empty window must not panic");
        r.note_latency(42);
        let s = r.snapshot(10);
        assert_eq!((s.window_n, s.p50_us, s.p99_us), (1, 42, 42));
        for i in 0..(WINDOW_CAP as u64 + 50) {
            r.note_latency(i);
        }
        let s = r.snapshot(10);
        assert_eq!(s.window_n, WINDOW_CAP);
        // the 42 and the first 50 samples have slid out
        assert!(s.p50_us >= 50);
    }

    #[test]
    fn snapshot_json_line_parses_and_carries_fields() {
        let mut r = Recorder::on();
        r.record((0, 0), SpanKind::Fwd, 7, 0, 10, 3);
        let mut s = r.snapshot(20);
        s.oacc = 62.5;
        s.ledger_bytes = 1024;
        s.arrivals = 9;
        let j = crate::trace::json::parse(&s.to_json_line()).expect("valid json");
        assert_eq!(j.get("t_us").and_then(|v| v.as_f64()), Some(20.0));
        assert_eq!(j.get("oacc").and_then(|v| v.as_f64()), Some(62.5));
        assert_eq!(j.get("ledger_bytes").and_then(|v| v.as_f64()), Some(1024.0));
        let devs = j.get("devices").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(devs.len(), 1);
        assert_eq!(devs[0].get("busy_us").and_then(|v| v.as_f64()), Some(10.0));
        assert!(j.get("lat_window").is_some());
    }

    #[test]
    fn snapshot_writer_streams_json_lines() {
        let dir = std::env::temp_dir();
        let path = dir.join("ferret_obs_stream_test.jsonl");
        let path = path.to_str().unwrap();
        let mut w = SnapshotWriter::create(path, 8).unwrap();
        let mut r = Recorder::on();
        r.record((0, 0), SpanKind::Fwd, 0, 0, 10, 0);
        w.write(&r.snapshot(10)).unwrap();
        w.write(&r.snapshot(20)).unwrap();
        drop(w);
        let text = std::fs::read_to_string(path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 snapshots");
        let hdr = crate::trace::json::parse(lines[0]).unwrap();
        assert_eq!(hdr.get("schema").and_then(|v| v.as_str()), Some(SNAPSHOT_SCHEMA));
        assert_eq!(hdr.get("interval_arrivals").and_then(|v| v.as_f64()), Some(8.0));
        for l in &lines[1..] {
            crate::trace::json::parse(l).expect("snapshot line is valid json");
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn chrome_trace_is_valid_json_with_one_event_per_span() {
        let mut r = Recorder::on();
        r.record((0, 0), SpanKind::Fwd, 1, 5, 15, 0);
        r.record((1, 2), SpanKind::Bwd, 1, 20, 50, 4);
        r.record(ENGINE_DEVICE, SpanKind::Drain, 0, 60, 80, 0);
        let dir = std::env::temp_dir();
        let path = dir.join("ferret_obs_chrome_test.json");
        let path = path.to_str().unwrap();
        write_chrome_trace(path, &r.spans()).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let j = crate::trace::json::parse(&text).expect("chrome trace is one json doc");
        let evs = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        // metadata record + 3 spans
        assert_eq!(evs.len(), 4);
        let fwd = evs
            .iter()
            .find(|e| e.get("name").and_then(|v| v.as_str()) == Some("Fwd"))
            .unwrap();
        assert_eq!(fwd.get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(fwd.get("ts").and_then(|v| v.as_f64()), Some(5.0));
        assert_eq!(fwd.get("dur").and_then(|v| v.as_f64()), Some(10.0));
        assert_eq!(fwd.get("pid").and_then(|v| v.as_f64()), Some(0.0));
        let drain = evs
            .iter()
            .find(|e| e.get("name").and_then(|v| v.as_str()) == Some("Drain"))
            .unwrap();
        assert_eq!(drain.get("pid").and_then(|v| v.as_f64()), Some(99.0));
        let _ = std::fs::remove_file(path);
    }
}
