//! Machine-readable diff between a recorded trace and its replay.

use super::{FinishRec, Trace};
use crate::metrics::curve_windowed_max_delta;

/// Accuracy curves are compared over this many equal windows: fine enough
/// to localize a mid-run dip, coarse enough that per-batch noise averages
/// out within a window.
pub const OACC_WINDOWS: usize = 16;

/// Gate thresholds for `ferret replay --gate`. The default is the strict
/// bit-for-bit contract: every threshold zero, so any deviation at all is
/// a violation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GateThresholds {
    /// max allowed |final oacc delta| (percentage points)
    pub oacc: f64,
    /// max allowed per-window oacc mean delta (percentage points)
    pub oacc_window: f64,
    /// max allowed |final tacc delta| (percentage points)
    pub tacc: f64,
    /// max allowed latency percentile delta (ticks), applied to p50/p95/p99
    pub latency: u64,
    /// max allowed replan-count delta
    pub replans: u64,
    /// max allowed plan churn (number of differing plan ids)
    pub plan_churn: u64,
    /// max allowed |mean-utilization delta| (fraction of device time,
    /// 0..1, from the finish record's busy/device-time accounting)
    pub util: f64,
}

impl Default for GateThresholds {
    fn default() -> Self {
        GateThresholds {
            oacc: 0.0,
            oacc_window: 0.0,
            tacc: 0.0,
            latency: 0,
            replans: 0,
            plan_churn: 0,
            util: 0.0,
        }
    }
}

/// Structured comparison of two traces (recorded vs replayed). All deltas
/// are `replayed - recorded` for signed fields, absolute for magnitudes.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayDiff {
    /// both traces saw the same batch sequence (count and content hashes)
    pub stream_ok: bool,
    pub batches_a: usize,
    pub batches_b: usize,
    pub oacc_a: f64,
    pub oacc_b: f64,
    /// signed final-oacc delta (b - a)
    pub oacc_delta: f64,
    /// max per-window |oacc mean delta| over [`OACC_WINDOWS`] windows
    pub oacc_window_max_delta: f64,
    /// signed final-tacc delta (b - a)
    pub tacc_delta: f64,
    /// absolute latency percentile deltas
    pub p50_delta: u64,
    pub p95_delta: u64,
    pub p99_delta: u64,
    pub replans_a: u64,
    pub replans_b: u64,
    /// absolute replan-count delta
    pub replan_delta: u64,
    /// differing plan ids: initial plans compared, then replans pairwise,
    /// plus any length mismatch
    pub plan_churn: u64,
    /// signed trained-count delta (b - a)
    pub trained_delta: i64,
    /// signed dropped-count delta (b - a)
    pub dropped_delta: i64,
    /// signed measured-footprint delta in bytes (b - a)
    pub mem_delta: f64,
    /// recorded mean utilization (busy_us / device_us; 0 when the trace
    /// predates the observability fields)
    pub util_a: f64,
    /// replayed mean utilization
    pub util_b: f64,
    /// signed utilization delta (b - a); the bubble-fraction delta is its
    /// negation, so one threshold gates both
    pub util_delta: f64,
}

fn finish_or_zero(t: &Trace) -> FinishRec {
    t.finish.clone().unwrap_or(FinishRec {
        oacc: 0.0,
        tacc: 0.0,
        arrivals: 0,
        trained: 0,
        dropped: 0,
        replans: 0,
        mem_bytes: 0.0,
        peak_ledger: 0,
        p50: 0,
        p95: 0,
        p99: 0,
        oacc_curve: Vec::new(),
        busy_us: 0,
        device_us: 0,
    })
}

impl ReplayDiff {
    /// Compare recorded trace `a` against replayed trace `b`.
    pub fn compute(a: &Trace, b: &Trace) -> ReplayDiff {
        let (ba, bb) = (a.batches(), b.batches());
        let stream_ok = ba.len() == bb.len()
            && ba.iter().zip(&bb).all(|(x, y)| x.hash == y.hash && x.seq == y.seq);

        let fa = finish_or_zero(a);
        let fb = finish_or_zero(b);

        let (ra, rb) = (a.replans(), b.replans());
        let mut plan_churn = if a.header.plan_id != b.header.plan_id { 1u64 } else { 0 };
        plan_churn += ra
            .iter()
            .zip(&rb)
            .filter(|(x, y)| x.plan_id != y.plan_id)
            .count() as u64;
        plan_churn += ra.len().abs_diff(rb.len()) as u64;

        let util =
            |f: &FinishRec| if f.device_us == 0 { 0.0 } else { f.busy_us as f64 / f.device_us as f64 };
        let (util_a, util_b) = (util(&fa), util(&fb));

        ReplayDiff {
            stream_ok,
            batches_a: ba.len(),
            batches_b: bb.len(),
            oacc_a: fa.oacc,
            oacc_b: fb.oacc,
            oacc_delta: fb.oacc - fa.oacc,
            oacc_window_max_delta: curve_windowed_max_delta(
                &fa.oacc_curve,
                &fb.oacc_curve,
                OACC_WINDOWS,
            ),
            tacc_delta: fb.tacc - fa.tacc,
            p50_delta: fa.p50.abs_diff(fb.p50),
            p95_delta: fa.p95.abs_diff(fb.p95),
            p99_delta: fa.p99.abs_diff(fb.p99),
            replans_a: fa.replans,
            replans_b: fb.replans,
            replan_delta: fa.replans.abs_diff(fb.replans),
            plan_churn,
            trained_delta: fb.trained as i64 - fa.trained as i64,
            dropped_delta: fb.dropped as i64 - fa.dropped as i64,
            mem_delta: fb.mem_bytes - fa.mem_bytes,
            util_a,
            util_b,
            util_delta: util_b - util_a,
        }
    }

    /// True when the replay was bit-for-bit: same stream, same plans, same
    /// metrics.
    pub fn is_zero(&self) -> bool {
        self.stream_ok
            && self.batches_a == self.batches_b
            && self.oacc_delta == 0.0
            && self.oacc_window_max_delta == 0.0
            && self.tacc_delta == 0.0
            && self.p50_delta == 0
            && self.p95_delta == 0
            && self.p99_delta == 0
            && self.replan_delta == 0
            && self.plan_churn == 0
            && self.trained_delta == 0
            && self.dropped_delta == 0
            && self.mem_delta == 0.0
            && self.util_delta == 0.0
    }

    /// Threshold violations, one human-readable line each; empty when the
    /// diff passes the gate.
    pub fn violations(&self, g: &GateThresholds) -> Vec<String> {
        let mut v = Vec::new();
        if !self.stream_ok {
            v.push(format!(
                "stream mismatch: recorded {} batches, replayed {}",
                self.batches_a, self.batches_b
            ));
        }
        if self.oacc_delta.abs() > g.oacc {
            v.push(format!(
                "oacc delta {:+.4}pp exceeds {:.4}pp ({:.4} -> {:.4})",
                self.oacc_delta, g.oacc, self.oacc_a, self.oacc_b
            ));
        }
        if self.oacc_window_max_delta > g.oacc_window {
            v.push(format!(
                "windowed oacc delta {:.4}pp exceeds {:.4}pp",
                self.oacc_window_max_delta, g.oacc_window
            ));
        }
        if self.tacc_delta.abs() > g.tacc {
            v.push(format!("tacc delta {:+.4}pp exceeds {:.4}pp", self.tacc_delta, g.tacc));
        }
        for (name, d) in [("p50", self.p50_delta), ("p95", self.p95_delta), ("p99", self.p99_delta)]
        {
            if d > g.latency {
                v.push(format!("{name} latency delta {d} exceeds {}", g.latency));
            }
        }
        if self.replan_delta > g.replans {
            v.push(format!(
                "replan count delta {} exceeds {} ({} -> {})",
                self.replan_delta, g.replans, self.replans_a, self.replans_b
            ));
        }
        if self.plan_churn > g.plan_churn {
            v.push(format!("plan churn {} exceeds {}", self.plan_churn, g.plan_churn));
        }
        if self.util_delta.abs() > g.util {
            v.push(format!(
                "utilization delta {:+.4} exceeds {:.4} ({:.4} -> {:.4})",
                self.util_delta, g.util, self.util_a, self.util_b
            ));
        }
        v
    }

    /// One-object JSON rendering for `--out` / CI artifacts.
    pub fn to_json(&self) -> String {
        use super::json::fmt_f64;
        format!(
            "{{\"stream_ok\":{},\"batches_a\":{},\"batches_b\":{},\"oacc_a\":{},\"oacc_b\":{},\
             \"oacc_delta\":{},\"oacc_window_max_delta\":{},\"tacc_delta\":{},\
             \"p50_delta\":{},\"p95_delta\":{},\"p99_delta\":{},\
             \"replans_a\":{},\"replans_b\":{},\"replan_delta\":{},\"plan_churn\":{},\
             \"trained_delta\":{},\"dropped_delta\":{},\"mem_delta\":{},\
             \"util_a\":{},\"util_b\":{},\"util_delta\":{},\"bit_for_bit\":{}}}",
            self.stream_ok,
            self.batches_a,
            self.batches_b,
            fmt_f64(self.oacc_a),
            fmt_f64(self.oacc_b),
            fmt_f64(self.oacc_delta),
            fmt_f64(self.oacc_window_max_delta),
            fmt_f64(self.tacc_delta),
            self.p50_delta,
            self.p95_delta,
            self.p99_delta,
            self.replans_a,
            self.replans_b,
            self.replan_delta,
            self.plan_churn,
            self.trained_delta,
            self.dropped_delta,
            fmt_f64(self.mem_delta),
            fmt_f64(self.util_a),
            fmt_f64(self.util_b),
            fmt_f64(self.util_delta),
            self.is_zero(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests_support::tiny_trace;
    use super::*;

    #[test]
    fn identical_traces_diff_to_zero() {
        let t = tiny_trace();
        let d = ReplayDiff::compute(&t, &t);
        assert!(d.is_zero(), "{d:?}");
        assert!(d.violations(&GateThresholds::default()).is_empty());
        assert!(d.to_json().contains("\"bit_for_bit\":true"));
    }

    #[test]
    fn perturbations_show_up_and_trip_the_gate() {
        let a = tiny_trace();
        let mut b = tiny_trace();
        if let Some(f) = b.finish.as_mut() {
            f.oacc += 1.5;
            f.p95 += 40;
            f.replans += 1;
        }
        b.header.plan_id ^= 1;
        let d = ReplayDiff::compute(&a, &b);
        assert!(!d.is_zero());
        assert!((d.oacc_delta - 1.5).abs() < 1e-12);
        assert_eq!(d.p95_delta, 40);
        assert_eq!(d.replan_delta, 1);
        assert_eq!(d.plan_churn, 1);
        let strict = d.violations(&GateThresholds::default());
        assert!(strict.len() >= 4, "every perturbation reported: {strict:?}");
        // loose thresholds absorb the same deltas
        let loose = GateThresholds {
            oacc: 2.0,
            oacc_window: 100.0,
            tacc: 1.0,
            latency: 100,
            replans: 1,
            plan_churn: 1,
            util: 0.0,
        };
        assert!(d.violations(&loose).is_empty());
    }

    #[test]
    fn utilization_regressions_trip_the_gate() {
        let a = tiny_trace();
        let mut b = tiny_trace();
        if let Some(f) = b.finish.as_mut() {
            f.busy_us /= 2; // mean utilization 0.75 -> 0.375
        }
        let d = ReplayDiff::compute(&a, &b);
        assert!((d.util_a - 0.75).abs() < 1e-12);
        assert!((d.util_delta + 0.375).abs() < 1e-12);
        assert!(!d.is_zero());
        assert!(!d.violations(&GateThresholds::default()).is_empty());
        assert!(d.to_json().contains("\"util_delta\":"));
        // a tolerant utilization threshold absorbs it
        let loose = GateThresholds { util: 0.5, ..Default::default() };
        assert!(d.violations(&loose).is_empty());
    }

    #[test]
    fn stream_divergence_is_always_a_violation() {
        let a = tiny_trace();
        let mut b = tiny_trace();
        if let Some(super::super::Event::Batch(br)) = b.events.first_mut() {
            br.hash ^= 1;
        }
        let d = ReplayDiff::compute(&a, &b);
        assert!(!d.stream_ok);
        assert!(!d.violations(&GateThresholds::default()).is_empty());
    }
}
