//! Run tracing: record a session's stream identity and planner decisions,
//! replay them later under variant configurations (ISSUE: PR 7 tentpole).
//!
//! # Artifact format — `ferret-trace/1`
//!
//! A trace is a JSON-lines file: one self-describing JSON object per line,
//! discriminated by its `"rec"` field, in the order the run produced them:
//!
//! | `rec`    | cardinality | contents |
//! |----------|-------------|----------|
//! | `header` | first line  | schema tag, model spec, engine params, the full initial plan (schedule, partition, worker configs, compensation, plugin, budget schedule) and its content-hashed `plan_id` |
//! | `stream` | ≤1          | the seeded [`StreamSpec`] the stream can be re-materialized from (absent for hand-fed streams) |
//! | `batch`  | per batch   | sequence number, batch id, row count, FNV-1a content `hash`, `arrival` / `admitted` stamps, whether the batch was `held` by a drain |
//! | `replan` | per replan  | drain window (`t0`..`t`), budget in force, the measured per-stage `tf`/`tb` means that seeded the planner, and the chosen plan: `plan_id`, partition bounds, active workers, predicted `mem_bytes` / `rate`, feasibility, winning `tc` |
//! | `finish` | last line   | run outcome: final oacc/tacc, counts, latency percentiles, busy/device-time accounting (utilization), the full oacc curve |
//!
//! Serialization rules (so artifacts are stable and exactly re-parseable):
//! u64 values that may exceed 2^53 are strings — seeds in decimal, content
//! hashes and `plan_id` as 16-digit lowercase hex; floats use Rust's
//! shortest-roundtrip `Display`; non-finite floats (an `inf` budget) are
//! the strings `"inf"` / `"-inf"` / `"nan"`. Future schema revisions bump
//! [`SCHEMA`]; readers reject tags they do not know.
//!
//! # Record / replay wiring
//!
//! Recording: `Session::builder(..).record_trace(path)` (or
//! `record_trace_writer` for an in-memory sink) attaches a [`TraceWriter`];
//! the session writes the header at build time, the stream line when
//! `run_stream` starts, batch/replan lines as the run progresses, and the
//! finish line from `finish()`. The CLI exposes this as
//! `ferret run --record-trace PATH`.
//!
//! Replay: `ferret replay <trace> [--config-override k=v,..] [--gate]`
//! parses the artifact ([`Trace::read`]), rebuilds the exact stream as a
//! [`ReplayStream`](crate::stream::ReplayStream) (hash-verified batch by
//! batch), re-drives a lockstep session under the recorded — or overridden
//! — configuration while recording a second trace in memory, and emits a
//! machine-readable [`ReplayDiff`] (plan churn, per-window oacc delta,
//! latency-percentile deltas, replan-count delta). `--gate` exits nonzero
//! when the diff exceeds thresholds.
//!
//! # Determinism contract
//!
//! Replay is bit-for-bit (all diff fields zero) when ALL of the following
//! held for the recorded run, and no overrides are given:
//!
//! - **Lockstep mode.** Virtual time makes arrival interleaving, drains,
//!   and budget steps exact; lockstep is also executor-equivalent, so a
//!   trace recorded under `--executor sim` replays bit-for-bit under
//!   `--executor threaded` and vice versa. Freerun runs can be recorded,
//!   but their wall-clock arrivals are not reproducible — replay always
//!   drives lockstep.
//! - **Seeded stream.** The stream reported a [`StreamSpec`] provenance
//!   (every `SyntheticStream` does). Hand-fed streams record batch hashes
//!   but cannot be rebuilt from the trace.
//! - **Analytic profile.** `measured_reps = 0` (the default). Measured
//!   profiling samples wall time; replay forces the analytic profile and
//!   will diverge from a measured-profile recording's plans. The header
//!   records `measured_reps` so the replayer can warn.
//! - **Native backend.** The trace does not capture backend identity;
//!   replay always uses the native backend, so a run recorded on an
//!   accelerated backend replays its *plans* faithfully only insofar as
//!   the backends agree numerically.
//! - **Stream-driven budget changes only.** Budget steps from the
//!   `--budget-schedule` in the header replay exactly; imperative
//!   `Session::set_budget` calls between pushes are not recorded.

pub mod diff;
pub mod driver;
pub mod json;

use std::fs;
use std::io::Write as _;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::bail;
use crate::stream::{DriftKind, StreamSpec};
use crate::util::error::Result;

pub use diff::{GateThresholds, ReplayDiff};
pub use driver::{replay_trace, ReplayOutcome};
pub use json::Json;

/// Artifact schema tag. Bump on any incompatible record change.
pub const SCHEMA: &str = "ferret-trace/1";

/// Batch content hash, re-exported from [`crate::stream`] (where the data
/// lives) for the existing `trace::batch_hash` callers.
pub use crate::stream::batch_hash;

// ---------------------------------------------------------------- records

/// One pipeline worker's configuration, as recorded in the header.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerRec {
    pub delay: i64,
    pub recompute: bool,
    pub accum: Vec<u64>,
    pub omit: Vec<u64>,
}

/// `rec:"header"` — everything needed to rebuild the recorded session.
#[derive(Debug, Clone, PartialEq)]
pub struct Header {
    pub schema: String,
    pub model: String,
    pub dims: Vec<usize>,
    pub batch: usize,
    pub features: usize,
    pub classes: usize,
    pub mode: String,
    pub executor: String,
    pub lr: f32,
    pub decay_c: f64,
    /// resolved inter-arrival time (ticks), not the possibly-0 requested one
    pub td: u64,
    pub tacc_per_class: usize,
    pub seed: u64,
    pub stash_cap: usize,
    /// resolved kernel thread count
    pub kernel_threads: usize,
    pub schedule: String,
    pub partition: Vec<usize>,
    pub workers: Vec<WorkerRec>,
    pub comp: String,
    pub comp_params: [f32; 4],
    pub plugin: String,
    pub plugin_cadence: u64,
    /// budget schedule spec string (`""` = fixed/unbounded)
    pub budget: String,
    /// content hash of the initial plan
    pub plan_id: u64,
    pub measured_reps: u32,
}

/// `rec:"batch"` — one arriving microbatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchRec {
    pub seq: u64,
    pub id: u64,
    pub rows: usize,
    pub hash: u64,
    /// stream arrival stamp (virtual ticks in lockstep, µs in freerun)
    pub arrival: u64,
    /// engine time at admission (or at hold, for held batches)
    pub admitted: u64,
    /// true when a drain/budget-step parked the batch instead of admitting
    pub held: bool,
}

/// `rec:"replan"` — one planner decision at a drain boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplanRec {
    /// time the replan executed
    pub t: u64,
    /// time the drain began
    pub t0: u64,
    /// drain duration (`t - t0`)
    pub drain: u64,
    /// budget in force when the planner ran
    pub budget: f64,
    /// measured per-stage forward means seeding the refreshed profile
    pub tf: Vec<Option<f64>>,
    /// measured per-stage backward means
    pub tb: Vec<Option<f64>>,
    /// content hash of the chosen plan
    pub plan_id: u64,
    pub partition: Vec<usize>,
    pub active_workers: usize,
    /// planner-predicted footprint of the chosen plan
    pub mem_bytes: f64,
    /// planner-predicted adaptation rate
    pub rate: f64,
    pub feasible: bool,
    /// winning stage time bound
    pub tc: u64,
}

/// `rec:"finish"` — run outcome summary.
#[derive(Debug, Clone, PartialEq)]
pub struct FinishRec {
    pub oacc: f64,
    pub tacc: f64,
    pub arrivals: u64,
    pub trained: u64,
    pub dropped: u64,
    pub replans: u64,
    pub mem_bytes: f64,
    pub peak_ledger: usize,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub oacc_curve: Vec<(u64, f64)>,
    /// summed device busy time in clock ticks. Lockstep sums the replayed
    /// analytic service costs, so it is deterministic and replays
    /// bit-for-bit; `busy_us / device_us` is the run's mean utilization.
    pub busy_us: u64,
    /// integral of active-device count over run time (same ticks)
    pub device_us: u64,
}

/// A batch or replan event, in recorded order (interleaving preserved so
/// [`Trace::to_lines`] reproduces the artifact exactly).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Batch(BatchRec),
    Replan(ReplanRec),
}

/// A fully parsed trace artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub header: Header,
    pub stream: Option<StreamSpec>,
    pub events: Vec<Event>,
    pub finish: Option<FinishRec>,
}

// ----------------------------------------------------------- line writers

/// Append `,"key":value` (keys are plain identifiers; never escaped).
fn kv(s: &mut String, key: &str, val: &str) {
    s.push_str(",\"");
    s.push_str(key);
    s.push_str("\":");
    s.push_str(val);
}

fn fmt_arr<T: std::fmt::Display>(v: &[T]) -> String {
    let items: Vec<String> = v.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(","))
}

fn fmt_opt_arr(v: &[Option<f64>]) -> String {
    let items: Vec<String> = v
        .iter()
        .map(|x| match x {
            Some(n) => json::fmt_f64(*n),
            None => "null".into(),
        })
        .collect();
    format!("[{}]", items.join(","))
}

/// u64 that may exceed 2^53: decimal string.
fn fmt_u64s(v: u64) -> String {
    format!("\"{v}\"")
}

/// content hash / plan id: 16-digit lowercase hex string.
fn fmt_hex(v: u64) -> String {
    format!("\"{v:016x}\"")
}

impl WorkerRec {
    fn to_json(&self) -> String {
        let mut s = String::from("{\"delay\":");
        s.push_str(&self.delay.to_string());
        kv(&mut s, "recompute", if self.recompute { "true" } else { "false" });
        kv(&mut s, "accum", &fmt_arr(&self.accum));
        kv(&mut s, "omit", &fmt_arr(&self.omit));
        s.push('}');
        s
    }
}

impl Header {
    pub fn to_line(&self) -> String {
        let mut s = String::from("{\"rec\":\"header\"");
        kv(&mut s, "schema", &json::escape(&self.schema));
        kv(&mut s, "model", &json::escape(&self.model));
        kv(&mut s, "dims", &fmt_arr(&self.dims));
        kv(&mut s, "batch", &self.batch.to_string());
        kv(&mut s, "features", &self.features.to_string());
        kv(&mut s, "classes", &self.classes.to_string());
        kv(&mut s, "mode", &json::escape(&self.mode));
        kv(&mut s, "executor", &json::escape(&self.executor));
        kv(&mut s, "lr", &format!("{}", self.lr));
        kv(&mut s, "decay_c", &json::fmt_f64(self.decay_c));
        kv(&mut s, "td", &self.td.to_string());
        kv(&mut s, "tacc_per_class", &self.tacc_per_class.to_string());
        kv(&mut s, "seed", &fmt_u64s(self.seed));
        kv(&mut s, "stash_cap", &self.stash_cap.to_string());
        kv(&mut s, "kernel_threads", &self.kernel_threads.to_string());
        kv(&mut s, "schedule", &json::escape(&self.schedule));
        kv(&mut s, "partition", &fmt_arr(&self.partition));
        let workers: Vec<String> = self.workers.iter().map(|w| w.to_json()).collect();
        kv(&mut s, "workers", &format!("[{}]", workers.join(",")));
        kv(&mut s, "comp", &json::escape(&self.comp));
        kv(&mut s, "comp_params", &fmt_arr(&self.comp_params));
        kv(&mut s, "plugin", &json::escape(&self.plugin));
        kv(&mut s, "plugin_cadence", &self.plugin_cadence.to_string());
        kv(&mut s, "budget", &json::escape(&self.budget));
        kv(&mut s, "plan_id", &fmt_hex(self.plan_id));
        kv(&mut s, "measured_reps", &self.measured_reps.to_string());
        s.push('}');
        s
    }

    fn parse(j: &Json) -> Result<Header> {
        let schema = str_of(j, "schema")?;
        if schema != SCHEMA {
            bail!("trace: unknown schema '{schema}' (this reader understands '{SCHEMA}')");
        }
        let workers_j = arr_of(j, "workers")?;
        let mut workers = Vec::with_capacity(workers_j.len());
        for w in workers_j {
            workers.push(WorkerRec {
                delay: i64_of(w, "delay")?,
                recompute: bool_of(w, "recompute")?,
                accum: u64_arr_of(w, "accum")?,
                omit: u64_arr_of(w, "omit")?,
            });
        }
        let cp = f64_arr_of(j, "comp_params")?;
        let &[lam0, eta_lam, alpha, nu] = cp.as_slice() else {
            bail!("trace: comp_params must have 4 entries, got {}", cp.len());
        };
        Ok(Header {
            schema,
            model: str_of(j, "model")?,
            dims: usize_arr_of(j, "dims")?,
            batch: usize_of(j, "batch")?,
            features: usize_of(j, "features")?,
            classes: usize_of(j, "classes")?,
            mode: str_of(j, "mode")?,
            executor: str_of(j, "executor")?,
            lr: f64_of(j, "lr")? as f32,
            decay_c: f64_of(j, "decay_c")?,
            td: u64_of(j, "td")?,
            tacc_per_class: usize_of(j, "tacc_per_class")?,
            seed: u64s_of(j, "seed")?,
            stash_cap: usize_of(j, "stash_cap")?,
            kernel_threads: usize_of(j, "kernel_threads")?,
            schedule: str_of(j, "schedule")?,
            partition: usize_arr_of(j, "partition")?,
            workers,
            comp: str_of(j, "comp")?,
            comp_params: [lam0 as f32, eta_lam as f32, alpha as f32, nu as f32],
            plugin: str_of(j, "plugin")?,
            plugin_cadence: u64_of(j, "plugin_cadence")?,
            budget: str_of(j, "budget")?,
            plan_id: hex_of(j, "plan_id")?,
            measured_reps: u64_of(j, "measured_reps")? as u32,
        })
    }
}

fn stream_to_line(spec: &StreamSpec) -> String {
    let mut s = String::from("{\"rec\":\"stream\"");
    kv(&mut s, "name", &json::escape(&spec.name));
    kv(&mut s, "features", &spec.features.to_string());
    kv(&mut s, "classes", &spec.classes.to_string());
    kv(&mut s, "batch", &spec.batch.to_string());
    kv(&mut s, "num_batches", &spec.num_batches.to_string());
    kv(&mut s, "kind", &json::escape(&spec.kind.spec_str()));
    kv(&mut s, "margin", &format!("{}", spec.margin));
    kv(&mut s, "noise", &format!("{}", spec.noise));
    kv(&mut s, "seed", &fmt_u64s(spec.seed));
    s.push('}');
    s
}

fn stream_parse(j: &Json) -> Result<StreamSpec> {
    let kind_s = str_of(j, "kind")?;
    let Some(kind) = DriftKind::parse(&kind_s) else {
        bail!("trace: unknown stream kind '{kind_s}'");
    };
    Ok(StreamSpec {
        name: str_of(j, "name")?,
        features: usize_of(j, "features")?,
        classes: usize_of(j, "classes")?,
        batch: usize_of(j, "batch")?,
        num_batches: usize_of(j, "num_batches")?,
        kind,
        margin: f64_of(j, "margin")? as f32,
        noise: f64_of(j, "noise")? as f32,
        seed: u64s_of(j, "seed")?,
    })
}

impl BatchRec {
    pub fn to_line(&self) -> String {
        let mut s = String::from("{\"rec\":\"batch\"");
        kv(&mut s, "seq", &self.seq.to_string());
        kv(&mut s, "id", &self.id.to_string());
        kv(&mut s, "rows", &self.rows.to_string());
        kv(&mut s, "hash", &fmt_hex(self.hash));
        kv(&mut s, "arrival", &self.arrival.to_string());
        kv(&mut s, "admitted", &self.admitted.to_string());
        kv(&mut s, "held", if self.held { "true" } else { "false" });
        s.push('}');
        s
    }

    fn parse(j: &Json) -> Result<BatchRec> {
        Ok(BatchRec {
            seq: u64_of(j, "seq")?,
            id: u64_of(j, "id")?,
            rows: usize_of(j, "rows")?,
            hash: hex_of(j, "hash")?,
            arrival: u64_of(j, "arrival")?,
            admitted: u64_of(j, "admitted")?,
            held: bool_of(j, "held")?,
        })
    }
}

impl ReplanRec {
    pub fn to_line(&self) -> String {
        let mut s = String::from("{\"rec\":\"replan\"");
        kv(&mut s, "t", &self.t.to_string());
        kv(&mut s, "t0", &self.t0.to_string());
        kv(&mut s, "drain", &self.drain.to_string());
        kv(&mut s, "budget", &json::fmt_f64(self.budget));
        kv(&mut s, "tf", &fmt_opt_arr(&self.tf));
        kv(&mut s, "tb", &fmt_opt_arr(&self.tb));
        kv(&mut s, "plan_id", &fmt_hex(self.plan_id));
        kv(&mut s, "partition", &fmt_arr(&self.partition));
        kv(&mut s, "active_workers", &self.active_workers.to_string());
        kv(&mut s, "mem_bytes", &json::fmt_f64(self.mem_bytes));
        kv(&mut s, "rate", &json::fmt_f64(self.rate));
        kv(&mut s, "feasible", if self.feasible { "true" } else { "false" });
        kv(&mut s, "tc", &self.tc.to_string());
        s.push('}');
        s
    }

    fn parse(j: &Json) -> Result<ReplanRec> {
        Ok(ReplanRec {
            t: u64_of(j, "t")?,
            t0: u64_of(j, "t0")?,
            drain: u64_of(j, "drain")?,
            budget: f64_of(j, "budget")?,
            tf: opt_f64_arr_of(j, "tf")?,
            tb: opt_f64_arr_of(j, "tb")?,
            plan_id: hex_of(j, "plan_id")?,
            partition: usize_arr_of(j, "partition")?,
            active_workers: usize_of(j, "active_workers")?,
            mem_bytes: f64_of(j, "mem_bytes")?,
            rate: f64_of(j, "rate")?,
            feasible: bool_of(j, "feasible")?,
            tc: u64_of(j, "tc")?,
        })
    }
}

impl FinishRec {
    pub fn to_line(&self) -> String {
        let mut s = String::from("{\"rec\":\"finish\"");
        kv(&mut s, "oacc", &json::fmt_f64(self.oacc));
        kv(&mut s, "tacc", &json::fmt_f64(self.tacc));
        kv(&mut s, "arrivals", &self.arrivals.to_string());
        kv(&mut s, "trained", &self.trained.to_string());
        kv(&mut s, "dropped", &self.dropped.to_string());
        kv(&mut s, "replans", &self.replans.to_string());
        kv(&mut s, "mem_bytes", &json::fmt_f64(self.mem_bytes));
        kv(&mut s, "peak_ledger", &self.peak_ledger.to_string());
        kv(&mut s, "p50", &self.p50.to_string());
        kv(&mut s, "p95", &self.p95.to_string());
        kv(&mut s, "p99", &self.p99.to_string());
        let pts: Vec<String> = self
            .oacc_curve
            .iter()
            .map(|(t, v)| format!("[{},{}]", t, json::fmt_f64(*v)))
            .collect();
        kv(&mut s, "oacc_curve", &format!("[{}]", pts.join(",")));
        kv(&mut s, "busy_us", &self.busy_us.to_string());
        kv(&mut s, "device_us", &self.device_us.to_string());
        s.push('}');
        s
    }

    fn parse(j: &Json) -> Result<FinishRec> {
        Ok(FinishRec {
            oacc: f64_of(j, "oacc")?,
            tacc: f64_of(j, "tacc")?,
            arrivals: u64_of(j, "arrivals")?,
            trained: u64_of(j, "trained")?,
            dropped: u64_of(j, "dropped")?,
            replans: u64_of(j, "replans")?,
            mem_bytes: f64_of(j, "mem_bytes")?,
            peak_ledger: usize_of(j, "peak_ledger")?,
            p50: u64_of(j, "p50")?,
            p95: u64_of(j, "p95")?,
            p99: u64_of(j, "p99")?,
            oacc_curve: curve_of(j, "oacc_curve")?,
            // added after ferret-trace/1 shipped: parse leniently so
            // pre-observability artifacts stay readable
            busy_us: u64_or_zero(j, "busy_us")?,
            device_us: u64_or_zero(j, "device_us")?,
        })
    }
}

// ---------------------------------------------------------- field helpers

fn get<'a>(j: &'a Json, k: &str) -> Result<&'a Json> {
    match j.get(k) {
        Some(v) => Ok(v),
        None => bail!("trace: missing field '{k}'"),
    }
}

fn str_of(j: &Json, k: &str) -> Result<String> {
    match get(j, k)?.as_str() {
        Some(s) => Ok(s.to_string()),
        None => bail!("trace: field '{k}' is not a string"),
    }
}

fn f64_of(j: &Json, k: &str) -> Result<f64> {
    match json::num_of(get(j, k)?) {
        Some(v) => Ok(v),
        None => bail!("trace: field '{k}' is not a number"),
    }
}

fn bool_of(j: &Json, k: &str) -> Result<bool> {
    match get(j, k)?.as_bool() {
        Some(b) => Ok(b),
        None => bail!("trace: field '{k}' is not a bool"),
    }
}

fn int_check(v: f64, k: &str) -> Result<f64> {
    if v < 0.0 || v.fract() != 0.0 || v > 9.007199254740992e15 {
        bail!("trace: field '{k}' is not an unsigned integer");
    }
    Ok(v)
}

fn u64_of(j: &Json, k: &str) -> Result<u64> {
    Ok(int_check(f64_of(j, k)?, k)? as u64)
}

fn usize_of(j: &Json, k: &str) -> Result<usize> {
    Ok(u64_of(j, k)? as usize)
}

/// Optional u64 defaulting to 0: fields added to a record after the
/// schema shipped parse leniently so older artifacts stay readable.
fn u64_or_zero(j: &Json, k: &str) -> Result<u64> {
    if j.get(k).is_none() {
        return Ok(0);
    }
    u64_of(j, k)
}

fn i64_of(j: &Json, k: &str) -> Result<i64> {
    let v = f64_of(j, k)?;
    if v.fract() != 0.0 || v.abs() > 9.007199254740992e15 {
        bail!("trace: field '{k}' is not an integer");
    }
    Ok(v as i64)
}

/// u64 written as a decimal string (may exceed 2^53); plain numbers are
/// accepted too for hand-written traces.
fn u64s_of(j: &Json, k: &str) -> Result<u64> {
    match get(j, k)? {
        Json::Str(s) => match s.parse::<u64>() {
            Ok(v) => Ok(v),
            Err(_) => bail!("trace: field '{k}' is not a decimal u64 string"),
        },
        other => match other.as_f64() {
            Some(v) => Ok(int_check(v, k)? as u64),
            None => bail!("trace: field '{k}' is not a u64"),
        },
    }
}

/// 16-digit lowercase hex string (content hashes, plan ids).
fn hex_of(j: &Json, k: &str) -> Result<u64> {
    let s = str_of(j, k)?;
    match u64::from_str_radix(&s, 16) {
        Ok(v) => Ok(v),
        Err(_) => bail!("trace: field '{k}' is not a hex u64 string"),
    }
}

fn arr_of<'a>(j: &'a Json, k: &str) -> Result<&'a [Json]> {
    match get(j, k)?.as_arr() {
        Some(a) => Ok(a),
        None => bail!("trace: field '{k}' is not an array"),
    }
}

fn f64_arr_of(j: &Json, k: &str) -> Result<Vec<f64>> {
    arr_of(j, k)?
        .iter()
        .map(|v| match json::num_of(v) {
            Some(n) => Ok(n),
            None => bail!("trace: field '{k}' has a non-numeric entry"),
        })
        .collect()
}

fn u64_arr_of(j: &Json, k: &str) -> Result<Vec<u64>> {
    f64_arr_of(j, k)?
        .into_iter()
        .map(|v| Ok(int_check(v, k)? as u64))
        .collect()
}

fn usize_arr_of(j: &Json, k: &str) -> Result<Vec<usize>> {
    Ok(u64_arr_of(j, k)?.into_iter().map(|v| v as usize).collect())
}

fn opt_f64_arr_of(j: &Json, k: &str) -> Result<Vec<Option<f64>>> {
    arr_of(j, k)?
        .iter()
        .map(|v| match v {
            Json::Null => Ok(None),
            other => match json::num_of(other) {
                Some(n) => Ok(Some(n)),
                None => bail!("trace: field '{k}' has a non-numeric entry"),
            },
        })
        .collect()
}

fn curve_of(j: &Json, k: &str) -> Result<Vec<(u64, f64)>> {
    arr_of(j, k)?
        .iter()
        .map(|pt| {
            let Some(pair) = pt.as_arr() else {
                bail!("trace: field '{k}' entries must be [t,v] pairs");
            };
            let [t_j, v_j] = pair else {
                bail!("trace: field '{k}' entries must be [t,v] pairs");
            };
            let t = match t_j.as_f64() {
                Some(v) => int_check(v, k)? as u64,
                None => bail!("trace: field '{k}' has a non-numeric t"),
            };
            let v = match json::num_of(v_j) {
                Some(v) => v,
                None => bail!("trace: field '{k}' has a non-numeric value"),
            };
            Ok((t, v))
        })
        .collect()
}

// ----------------------------------------------------------------- writer

enum Sink {
    File(std::io::BufWriter<fs::File>),
    Mem(Arc<Mutex<Vec<String>>>),
}

/// Streaming JSON-lines sink for trace records. Mid-run writes are
/// best-effort (an I/O error never aborts the run); `finish` flushes.
pub struct TraceWriter {
    sink: Sink,
}

impl TraceWriter {
    /// Write to a file, creating parent directories as needed.
    pub fn to_path(path: &str) -> Result<TraceWriter> {
        if let Some(dir) = Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)?;
            }
        }
        let f = fs::File::create(path)?;
        Ok(TraceWriter { sink: Sink::File(std::io::BufWriter::new(f)) })
    }

    /// Collect lines in memory; the returned handle reads them back after
    /// the session (which owns the writer) finishes.
    pub fn in_memory() -> (TraceWriter, Arc<Mutex<Vec<String>>>) {
        let store = Arc::new(Mutex::new(Vec::new()));
        (TraceWriter { sink: Sink::Mem(store.clone()) }, store)
    }

    fn line(&mut self, l: String) {
        match &mut self.sink {
            Sink::File(w) => {
                let _ = writeln!(w, "{l}");
            }
            // ferret-lint: allow(entry-panic) — poisoning-only: the mem sink is the innermost lock and no holder panics
            Sink::Mem(v) => v.lock().expect("trace sink lock").push(l),
        }
    }

    pub(crate) fn header(&mut self, h: &Header) {
        self.line(h.to_line());
    }

    pub(crate) fn stream(&mut self, spec: &StreamSpec) {
        self.line(stream_to_line(spec));
    }

    pub(crate) fn batch(&mut self, b: &BatchRec) {
        self.line(b.to_line());
    }

    pub(crate) fn replan(&mut self, r: &ReplanRec) {
        self.line(r.to_line());
    }

    pub(crate) fn finish(&mut self, f: &FinishRec) {
        self.line(f.to_line());
        if let Sink::File(w) = &mut self.sink {
            let _ = w.flush();
        }
    }
}

// ----------------------------------------------------------------- reader

impl Trace {
    /// Parse a JSON-lines trace artifact. The header must come first;
    /// record order is otherwise preserved in [`Trace::events`].
    pub fn parse(text: &str) -> Result<Trace> {
        let mut header: Option<Header> = None;
        let mut stream = None;
        let mut events = Vec::new();
        let mut finish = None;
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let n = i + 1;
            let j = match json::parse(line) {
                Ok(j) => j,
                Err(e) => bail!("trace line {n}: {e}"),
            };
            let rec = match str_of(&j, "rec") {
                Ok(r) => r,
                Err(e) => bail!("trace line {n}: {e}"),
            };
            let res: Result<()> = (|| {
                match rec.as_str() {
                    "header" => {
                        if header.is_some() {
                            bail!("trace: duplicate header");
                        }
                        header = Some(Header::parse(&j)?);
                    }
                    "stream" => {
                        if stream.is_some() {
                            bail!("trace: duplicate stream record");
                        }
                        stream = Some(stream_parse(&j)?);
                    }
                    "batch" => events.push(Event::Batch(BatchRec::parse(&j)?)),
                    "replan" => events.push(Event::Replan(ReplanRec::parse(&j)?)),
                    "finish" => {
                        if finish.is_some() {
                            bail!("trace: duplicate finish record");
                        }
                        finish = Some(FinishRec::parse(&j)?);
                    }
                    other => bail!("trace: unknown record type '{other}'"),
                }
                if header.is_none() {
                    bail!("trace: first record must be the header");
                }
                Ok(())
            })();
            if let Err(e) = res {
                bail!("trace line {n}: {e}");
            }
        }
        let Some(header) = header else { bail!("trace: empty artifact (no header)") };
        Ok(Trace { header, stream, events, finish })
    }

    /// Read and parse an artifact from disk.
    pub fn read(path: &str) -> Result<Trace> {
        let text = fs::read_to_string(path)?;
        Trace::parse(&text)
    }

    /// Re-serialize: line-for-line identical to the artifact this trace
    /// was parsed from (canonical field order, preserved event order).
    pub fn to_lines(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(self.events.len() + 3);
        out.push(self.header.to_line());
        if let Some(spec) = &self.stream {
            out.push(stream_to_line(spec));
        }
        for ev in &self.events {
            out.push(match ev {
                Event::Batch(b) => b.to_line(),
                Event::Replan(r) => r.to_line(),
            });
        }
        if let Some(f) = &self.finish {
            out.push(f.to_line());
        }
        out
    }

    /// Batch records in arrival order.
    pub fn batches(&self) -> Vec<&BatchRec> {
        self.events
            .iter()
            .filter_map(|e| match e {
                Event::Batch(b) => Some(b),
                Event::Replan(_) => None,
            })
            .collect()
    }

    /// Replan records in decision order.
    pub fn replans(&self) -> Vec<&ReplanRec> {
        self.events
            .iter()
            .filter_map(|e| match e {
                Event::Replan(r) => Some(r),
                Event::Batch(_) => None,
            })
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod tests_support {
    use super::*;
    use crate::stream::Batch;

    /// A small hand-built trace exercising every record type, string-coded
    /// u64s past 2^53, leading-zero hex ids, and an infinite budget —
    /// shared by the mod/diff round-trip tests.
    pub fn tiny_trace() -> Trace {
        sample_trace()
    }

    pub fn sample_header() -> Header {
        Header {
            schema: SCHEMA.into(),
            model: "mlp-4".into(),
            dims: vec![16, 32, 32, 4],
            batch: 8,
            features: 16,
            classes: 4,
            mode: "lockstep".into(),
            executor: "sim".into(),
            lr: 0.1,
            decay_c: 0.0005,
            td: 40,
            tacc_per_class: 8,
            seed: u64::MAX - 7, // exceeds 2^53: exercises the string path
            stash_cap: 0,
            kernel_threads: 1,
            schedule: "Ferret".into(),
            partition: vec![0, 2, 4],
            workers: vec![
                WorkerRec { delay: 0, recompute: false, accum: vec![1, 1], omit: vec![0, 0] },
                WorkerRec { delay: -1, recompute: true, accum: vec![2], omit: vec![1] },
            ],
            comp: "Iter-Fisher".into(),
            comp_params: [0.2, 0.001, 0.9, 2e-6],
            plugin: "Vanilla".into(),
            plugin_cadence: 8,
            budget: "4194304b@b0,inf@b60".into(),
            plan_id: 0x00ab_cdef_0123_4567, // leading zeros: exercises {:016x}
            measured_reps: 0,
        }
    }

    pub fn sample_trace() -> Trace {
        let spec = StreamSpec {
            name: "s0".into(),
            features: 16,
            classes: 4,
            batch: 8,
            num_batches: 40,
            kind: DriftKind::ClassIncremental { tasks: 5 },
            margin: 3.0,
            noise: 0.5,
            seed: 1 << 60,
        };
        Trace {
            header: sample_header(),
            stream: Some(spec),
            events: vec![
                Event::Batch(BatchRec {
                    seq: 0,
                    id: 0,
                    rows: 8,
                    hash: 0xdead_beef_cafe_f00d,
                    arrival: 40,
                    admitted: 40,
                    held: false,
                }),
                Event::Replan(ReplanRec {
                    t: 95,
                    t0: 80,
                    drain: 15,
                    budget: f64::INFINITY,
                    tf: vec![Some(40.0), None],
                    tb: vec![Some(81.5), None],
                    plan_id: 0x1111_2222_3333_4444,
                    partition: vec![0, 4],
                    active_workers: 1,
                    mem_bytes: 123456.0,
                    rate: 0.0125,
                    feasible: true,
                    tc: 120,
                }),
                Event::Batch(BatchRec {
                    seq: 1,
                    id: 1,
                    rows: 8,
                    hash: 0x0000_0000_0000_0001,
                    arrival: 80,
                    admitted: 80,
                    held: true,
                }),
            ],
            finish: Some(FinishRec {
                oacc: 62.5,
                tacc: 71.875,
                arrivals: 40,
                trained: 38,
                dropped: 2,
                replans: 1,
                mem_bytes: 98304.0,
                peak_ledger: 131072,
                p50: 120,
                p95: 480,
                p99: 520,
                oacc_curve: vec![(40, 0.0), (80, 50.0), (1600, 62.5)],
                busy_us: 2400,
                device_us: 3200,
            }),
        }
    }

    #[test]
    fn trace_round_trips_through_parse() {
        let t = sample_trace();
        let lines = t.to_lines();
        let text = lines.join("\n");
        let parsed = Trace::parse(&text).unwrap();
        assert_eq!(parsed, t);
        // byte-exact re-serialization, interleaving preserved
        assert_eq!(parsed.to_lines(), lines);
        assert_eq!(parsed.batches().len(), 2);
        assert_eq!(parsed.replans().len(), 1);
    }

    #[test]
    fn finish_parses_without_observability_fields() {
        // a pre-observability artifact has no busy_us/device_us on its
        // finish line — it must still parse, with both defaulting to 0
        let t = sample_trace();
        let mut lines = t.to_lines();
        let last = lines.last_mut().unwrap();
        *last = last.replace(",\"busy_us\":2400", "").replace(",\"device_us\":3200", "");
        let parsed = Trace::parse(&lines.join("\n")).unwrap();
        let f = parsed.finish.unwrap();
        assert_eq!((f.busy_us, f.device_us), (0, 0));
    }

    #[test]
    fn parse_rejects_malformed_artifacts() {
        assert!(Trace::parse("").is_err(), "no header");
        let t = sample_trace();
        let lines = t.to_lines();
        // batch before header
        let swapped = format!("{}\n{}", lines[2], lines[0]);
        assert!(Trace::parse(&swapped).is_err());
        // unknown schema
        let bad = lines[0].replace("ferret-trace/1", "ferret-trace/99");
        assert!(Trace::parse(&bad).is_err());
        // duplicate header
        let dup = format!("{}\n{}", lines[0], lines[0]);
        assert!(Trace::parse(&dup).is_err());
    }

    #[test]
    fn batch_hash_covers_id_shape_and_content() {
        let b = Batch { id: 3, x: vec![1.0, 2.0, 3.0, 4.0], y: vec![0, 1] };
        let h = batch_hash(&b);
        assert_eq!(h, batch_hash(&b.clone()), "deterministic");
        let mut id = b.clone();
        id.id = 4;
        assert_ne!(batch_hash(&id), h, "id is hashed");
        let mut x = b.clone();
        x.x[2] = 3.5;
        assert_ne!(batch_hash(&x), h, "features are hashed");
        let mut y = b.clone();
        y.y[1] = 0;
        assert_ne!(batch_hash(&y), h, "labels are hashed");
    }

    #[test]
    fn writer_in_memory_collects_lines() {
        let (mut w, store) = TraceWriter::in_memory();
        let t = sample_trace();
        w.header(&t.header);
        w.stream(t.stream.as_ref().unwrap());
        for ev in &t.events {
            match ev {
                Event::Batch(b) => w.batch(b),
                Event::Replan(r) => w.replan(r),
            }
        }
        w.finish(t.finish.as_ref().unwrap());
        drop(w);
        let lines = store.lock().unwrap().clone();
        assert_eq!(lines, t.to_lines());
    }
}
