//! Minimal hand-rolled JSON for trace artifacts (the crate vendors no
//! dependencies). The writer side lives in the record types' `to_line`
//! methods — records format themselves directly; this module provides
//! the string escaping they share and the parser replay reads traces
//! back with.

use crate::bail;
use crate::util::error::Result;

/// Parsed JSON value. Objects keep insertion order (a `Vec`, not a map):
/// canonical re-serialization and duplicate detection stay trivial.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Escape a string for inclusion in a JSON document (quotes added).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a float as a JSON value. Rust's `Display` for floats is
/// shortest-roundtrip (never exponent notation), so `parse` reads the
/// exact bits back; non-finite values — invalid JSON numbers — are
/// written as the strings `"inf"` / `"-inf"` / `"nan"` and read back by
/// [`num_of`].
pub fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else if v.is_nan() {
        "\"nan\"".into()
    } else if v > 0.0 {
        "\"inf\"".into()
    } else {
        "\"-inf\"".into()
    }
}

/// Read a number that may have been written by [`fmt_f64`] as a
/// non-finite string.
pub fn num_of(j: &Json) -> Option<f64> {
    match j {
        Json::Num(n) => Some(*n),
        Json::Str(s) => match s.as_str() {
            "inf" => Some(f64::INFINITY),
            "-inf" => Some(f64::NEG_INFINITY),
            "nan" => Some(f64::NAN),
            _ => None,
        },
        _ => None,
    }
}

/// Parse one JSON document (one trace line).
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("json: trailing content at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    // ferret-lint: allow(entry-index) — b[*pos] is guarded by *pos < b.len() in the same condition
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    // ferret-lint: allow(entry-index) — b[*pos] is guarded by *pos < b.len() in the same condition
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        bail!("json: expected '{}' at byte {}", c as char, *pos)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_num(b, pos),
        _ => bail!("json: unexpected input at byte {}", *pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    // ferret-lint: allow(entry-index) — every caller advances *pos only past matched bytes, so *pos <= b.len()
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("json: bad literal at byte {}", *pos)
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    // ferret-lint: allow(entry-index) — b[*pos] is guarded by *pos < b.len() in the same condition
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    // ferret-lint: allow(entry-index) — start <= *pos <= b.len(): the scan above only advances within bounds
    let Ok(s) = std::str::from_utf8(&b[start..*pos]) else {
        bail!("json: non-ascii bytes inside a number at byte {start}");
    };
    match s.parse::<f64>() {
        Ok(n) => Ok(Json::Num(n)),
        Err(_) => bail!("json: bad number '{s}' at byte {start}"),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else { bail!("json: unterminated string") };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else { bail!("json: unterminated escape") };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            bail!("json: truncated \\u escape");
                        }
                        // ferret-lint: allow(entry-index) — guarded by the *pos + 4 > b.len() bail just above
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .ok()
                            .and_then(|h| u32::from_str_radix(h, 16).ok())
                            .and_then(char::from_u32);
                        // surrogate pairs are not needed for trace content
                        let Some(ch) = hex else { bail!("json: bad \\u escape") };
                        out.push(ch);
                        *pos += 4;
                    }
                    _ => bail!("json: bad escape '\\{}'", e as char),
                }
            }
            c => {
                // continue a multi-byte UTF-8 sequence verbatim
                let len = match c {
                    0x00..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                let start = *pos - 1;
                *pos = start + len;
                if *pos > b.len() {
                    bail!("json: truncated utf-8 in string");
                }
                // ferret-lint: allow(entry-index) — guarded by the *pos > b.len() bail just above
                match std::str::from_utf8(&b[start..*pos]) {
                    Ok(s) => out.push_str(s),
                    Err(_) => bail!("json: invalid utf-8 in string"),
                }
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("json: expected ',' or ']' at byte {}", *pos),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        fields.push((key, val));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => bail!("json: expected ',' or '}}' at byte {}", *pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_arrays_objects() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse(r#""a\"b""#).unwrap(), Json::Str("a\"b".into()));
        let v = parse(r#"{"a":[1,2,null],"b":{"c":"x"}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x"));
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err(), "trailing content");
    }

    #[test]
    fn escape_round_trips() {
        for s in ["plain", "with \"quotes\"", "tab\tnewline\n", "unicode é τ"] {
            let parsed = parse(&escape(s)).unwrap();
            assert_eq!(parsed.as_str(), Some(s), "{s:?}");
        }
    }

    #[test]
    fn nonfinite_floats_round_trip_as_strings() {
        for v in [f64::INFINITY, f64::NEG_INFINITY] {
            let j = parse(&fmt_f64(v)).unwrap();
            assert_eq!(num_of(&j), Some(v));
        }
        assert!(num_of(&parse(&fmt_f64(f64::NAN)).unwrap()).unwrap().is_nan());
        // finite floats stay plain JSON numbers, bit-exact
        for v in [0.1f64, -3.25, 1234567.89, 2e-9] {
            assert_eq!(num_of(&parse(&fmt_f64(v)).unwrap()), Some(v));
        }
    }
}
