//! Replay driver: re-drive a recorded trace through a lockstep session —
//! under the recorded configuration or a variant — and diff the outcomes.

use crate::backend::native::NativeBackend;
use crate::bail;
use crate::budget::BudgetSchedule;
use crate::compensate::{CompKind, CompParams};
use crate::config::ModelSpec;
use crate::ocl::OclKind;
use crate::pipeline::engine::{AsyncCfg, AsyncSchedule};
use crate::pipeline::executor::ExecutorKind;
use crate::pipeline::sched::Mode;
use crate::pipeline::{EngineParams, Session};
use crate::planner::costmodel::{PipeConfig, WorkerCfg};
use crate::planner::Partition;
use crate::stream::ReplayStream;
use crate::util::error::Result;

use super::{Event, ReplayDiff, Trace, TraceWriter};

/// Result of [`replay_trace`]: the trace the replay itself produced, plus
/// its diff against the recording.
pub struct ReplayOutcome {
    /// trace recorded by the replay session (in memory)
    pub replayed: Trace,
    /// structured comparison: recorded vs replayed
    pub diff: ReplayDiff,
}

fn comp_kind_named(name: &str) -> Option<CompKind> {
    // accept both the display names the trace records and the CLI's
    // short forms, so overrides read naturally either way
    let short = match name {
        "none" => Some(CompKind::NoComp),
        "step" => Some(CompKind::StepAware),
        "gap" => Some(CompKind::GapAware),
        "fisher" => Some(CompKind::Fisher),
        "iter" => Some(CompKind::IterFisher),
        _ => None,
    };
    short.or_else(|| CompKind::all().into_iter().find(|k| k.name() == name))
}

fn ocl_kind_named(name: &str) -> Option<OclKind> {
    let short = match name {
        "vanilla" => Some(OclKind::Vanilla),
        "er" => Some(OclKind::Er),
        "mir" => Some(OclKind::Mir),
        "lwf" => Some(OclKind::Lwf),
        "mas" => Some(OclKind::Mas),
        _ => None,
    };
    short.or_else(|| OclKind::all().into_iter().find(|k| k.name() == name))
}

fn schedule_named(name: &str) -> Option<AsyncSchedule> {
    match name {
        "Pipedream" => Some(AsyncSchedule::Pipedream),
        "Pipedream2BW" => Some(AsyncSchedule::Pipedream2BW),
        "Ferret" => Some(AsyncSchedule::Ferret),
        _ => None,
    }
}

/// Re-drive `recorded` through a fresh lockstep session and diff the two
/// runs. `overrides` are `(key, value)` config variations applied on top
/// of the recorded configuration — supported keys: `comp`, `ocl`,
/// `executor`, `kernel-threads`, `lr`, `stash-cap`, `plugin-cadence`,
/// `budget-schedule`, `seed`. With no overrides, a trace recorded under
/// the determinism contract (see the module docs) replays bit-for-bit:
/// `diff.is_zero()`.
///
/// The stream is rebuilt from the trace's [`StreamSpec`] and verified
/// batch-by-batch against the recorded content hashes; any divergence is
/// a hard error, not a diff entry (the replay would be comparing
/// different data, so every downstream number would be meaningless).
/// Replay always runs `Mode::Lockstep` on the native backend with the
/// analytic profile — the terms of the determinism contract.
pub fn replay_trace(recorded: &Trace, overrides: &[(String, String)]) -> Result<ReplayOutcome> {
    let h = &recorded.header;
    let Some(spec) = recorded.stream.clone() else {
        bail!(
            "trace has no stream provenance (hand-fed stream?): the batch hashes alone \
             cannot rebuild the data, so this trace is not replayable"
        );
    };
    if h.measured_reps > 0 {
        eprintln!(
            "[replay] warning: trace was recorded with a measured initial profile \
             (--warmup-profile {}); replay uses the analytic profile and plans may diverge",
            h.measured_reps
        );
    }
    if h.mode != "lockstep" {
        eprintln!(
            "[replay] warning: trace was recorded in {} mode; replay runs lockstep and \
             wall-clock-dependent metrics will differ",
            h.mode
        );
    }

    let Some(schedule) = schedule_named(&h.schedule) else {
        bail!("trace: unknown schedule '{}'", h.schedule);
    };
    let mut comp = match comp_kind_named(&h.comp) {
        Some(k) => k,
        None => bail!("trace: unknown compensation kind '{}'", h.comp),
    };
    let mut ocl = match ocl_kind_named(&h.plugin) {
        Some(k) => k,
        None => bail!("trace: unknown plugin '{}'", h.plugin),
    };
    let mut executor = match ExecutorKind::parse(&h.executor) {
        Some(k) => k,
        None => bail!("trace: unknown executor '{}'", h.executor),
    };
    let mut budget = if h.budget.is_empty() {
        BudgetSchedule::fixed()
    } else {
        match BudgetSchedule::parse(&h.budget) {
            Ok(b) => b,
            Err(e) => bail!("trace: bad budget schedule '{}': {e}", h.budget),
        }
    };
    let mut ep = EngineParams {
        lr: h.lr,
        decay_c: h.decay_c,
        td: h.td,
        tacc_per_class: h.tacc_per_class,
        seed: h.seed,
        stash_cap: h.stash_cap,
        kernel_threads: h.kernel_threads,
        // perf placement knob, not part of the numerics contract — replay
        // always runs unpinned (the header deliberately omits it)
        pin_devices: false,
    };
    let mut plugin_cadence = h.plugin_cadence;

    for (k, v) in overrides {
        match k.as_str() {
            "comp" => {
                comp = match comp_kind_named(v) {
                    Some(c) => c,
                    None => bail!("override comp={v}: unknown compensation kind"),
                }
            }
            "ocl" => {
                ocl = match ocl_kind_named(v) {
                    Some(o) => o,
                    None => bail!("override ocl={v}: unknown plugin kind"),
                }
            }
            "executor" => {
                executor = match ExecutorKind::parse(v) {
                    Some(e) => e,
                    None => bail!("override executor={v}: expected sim|threaded"),
                }
            }
            "kernel-threads" => {
                ep.kernel_threads = match v.parse() {
                    Ok(n) => n,
                    Err(_) => bail!("override kernel-threads={v}: expected a thread count"),
                }
            }
            "lr" => {
                ep.lr = match v.parse() {
                    Ok(l) => l,
                    Err(_) => bail!("override lr={v}: expected a learning rate"),
                }
            }
            "stash-cap" => {
                ep.stash_cap = match v.parse() {
                    Ok(s) => s,
                    Err(_) => bail!("override stash-cap={v}: expected a capacity"),
                }
            }
            "plugin-cadence" => {
                plugin_cadence = match v.parse() {
                    Ok(c) if c > 0 => c,
                    _ => bail!("override plugin-cadence={v}: expected a cadence >= 1"),
                }
            }
            "budget-schedule" => {
                budget = match BudgetSchedule::parse(v) {
                    Ok(b) => b,
                    Err(e) => bail!("override budget-schedule={v}: {e}"),
                }
            }
            "seed" => {
                ep.seed = match v.parse() {
                    Ok(s) => s,
                    Err(_) => bail!("override seed={v}: expected an integer seed"),
                }
            }
            other => bail!(
                "unknown override '{other}' (supported: comp, ocl, executor, kernel-threads, \
                 lr, stash-cap, plugin-cadence, budget-schedule, seed)"
            ),
        }
    }

    let model = ModelSpec { name: h.model.clone(), dims: h.dims.clone() };
    let cfg = AsyncCfg {
        schedule,
        partition: Partition { bounds: h.partition.clone() },
        pipe: PipeConfig {
            workers: h
                .workers
                .iter()
                .map(|w| WorkerCfg {
                    delay: w.delay,
                    recompute: w.recompute,
                    accum: w.accum.clone(),
                    omit: w.omit.clone(),
                })
                .collect(),
        },
        comp_kind: comp,
        comp_params: {
            let [lam0, eta_lam, alpha, nu] = h.comp_params;
            CompParams { lam0, eta_lam, alpha, nu }
        },
        plugin_cadence,
        budget,
    };

    let expected: Vec<u64> = recorded
        .events
        .iter()
        .filter_map(|e| match e {
            Event::Batch(b) => Some(b.hash),
            Event::Replan(_) => None,
        })
        .collect();
    let mut stream = ReplayStream::new(spec, expected);

    let backend = NativeBackend;
    let (writer, lines) = TraceWriter::in_memory();
    let session = Session::builder(&backend, &model)
        .config(cfg)
        .owned_plugin(ocl.build(ep.seed))
        .engine_params(ep)
        .executor(executor)
        .mode(Mode::Lockstep)
        .batch(h.batch)
        .record_trace_writer(writer)
        .build()?;
    let _ = session.run_stream(&mut stream)?;

    if let Some(m) = stream.mismatch() {
        match m.got {
            Some(got) => bail!(
                "replay stream diverged at batch {}: recorded hash {:016x}, rebuilt stream \
                 produced {:016x} (generator or spec drift — the trace is not replayable \
                 against this build)",
                m.index,
                m.expected,
                got
            ),
            None => bail!(
                "replay stream ended early at batch {} of {} recorded (spec drift — the \
                 trace is not replayable against this build)",
                m.index,
                stream.recorded_len()
            ),
        }
    }

    // ferret-lint: allow(entry-panic) — poisoning-only: the mem sink is the innermost lock and no holder panics
    let text = lines.lock().expect("trace sink lock").join("\n");
    let replayed = Trace::parse(&text)?;
    let diff = ReplayDiff::compute(recorded, &replayed);
    Ok(ReplayOutcome { replayed, diff })
}
