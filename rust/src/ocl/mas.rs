//! MAS — Memory Aware Synapses [2]: per-parameter importance Ω accumulated
//! from the gradient of the squared output norm; update-time penalty
//! `g += λ · Ω ⊙ (θ − θ*)` anchored to a periodically refreshed snapshot.

use super::{OclCtx, OclPlugin};
use crate::backend::{backward_all, forward_all};
use crate::model::{GradBuf, LayerParams, SharedParams};
use crate::stream::Batch;

pub struct MasPlugin {
    lambda: f32,
    /// refresh the anchor θ* and importance every `refresh` after_update calls
    refresh: u64,
    updates: u64,
    /// per-layer importance Ω (grad-magnitude EMA)
    omega: Option<Vec<GradBuf>>,
    /// anchor parameters θ* (`Arc` clones of the live model)
    anchor: Option<Vec<SharedParams>>,
    /// most recent batch input kept for importance estimation
    last_x: Option<Vec<f32>>,
    last_rows: usize,
}

impl MasPlugin {
    pub fn new(lambda: f32, refresh: u64) -> Self {
        MasPlugin {
            lambda,
            refresh: refresh.max(1),
            updates: 0,
            omega: None,
            anchor: None,
            last_x: None,
            last_rows: 0,
        }
    }

    /// Accumulate Ω += |∂ ||f(x)||² / ∂θ| on the stored batch.
    fn accumulate_importance(&mut self, params: &[SharedParams], ctx: &OclCtx) {
        let Some(x) = &self.last_x else { return };
        let rows = self.last_rows;
        let (inputs, logits) = forward_all(ctx.backend, ctx.shapes, params, x, rows);
        // d/dlogits ||logits||²/rows = 2*logits/rows
        let gout: Vec<f32> = logits.iter().map(|v| 2.0 * v / rows as f32).collect();
        let grads = backward_all(ctx.backend, ctx.shapes, params, &inputs, &gout, rows);
        let omega = self.omega.get_or_insert_with(|| {
            grads.iter().map(|g| GradBuf { gw: vec![0.0; g.gw.len()], gb: vec![0.0; g.gb.len()] }).collect()
        });
        const EMA: f32 = 0.9;
        for (o, g) in omega.iter_mut().zip(&grads) {
            for (ov, gv) in o.gw.iter_mut().zip(&g.gw) {
                *ov = EMA * *ov + (1.0 - EMA) * gv.abs();
            }
            for (ov, gv) in o.gb.iter_mut().zip(&g.gb) {
                *ov = EMA * *ov + (1.0 - EMA) * gv.abs();
            }
        }
    }
}

impl OclPlugin for MasPlugin {
    fn name(&self) -> &'static str {
        "MAS"
    }

    fn augment(&mut self, batch: Batch, _params: &[SharedParams], _ctx: &OclCtx) -> Batch {
        self.last_x = Some(batch.x.clone());
        self.last_rows = batch.y.len();
        batch
    }

    fn adjust_layer_grad(
        &mut self,
        layer: usize,
        grad: &mut GradBuf,
        params: &LayerParams,
        _ctx: &OclCtx,
    ) {
        if let (Some(omega), Some(anchor)) = (&self.omega, &self.anchor) {
            if layer < omega.len() {
                let (o, a) = (&omega[layer], &anchor[layer]);
                for ((g, &ov), (&pv, &av)) in grad
                    .gw
                    .iter_mut()
                    .zip(&o.gw)
                    .zip(params.w.iter().zip(&a.w))
                {
                    *g += self.lambda * ov * (pv - av);
                }
                for ((g, &ov), (&pv, &av)) in grad
                    .gb
                    .iter_mut()
                    .zip(&o.gb)
                    .zip(params.b.iter().zip(&a.b))
                {
                    *g += self.lambda * ov * (pv - av);
                }
            }
        }
    }

    fn after_update(&mut self, params: &[SharedParams], ctx: &OclCtx) {
        if self.updates % self.refresh == 0 {
            self.accumulate_importance(params, ctx);
            self.anchor = Some(params.to_vec());
        }
        self.updates += 1;
    }

    fn memory_bytes(&self) -> usize {
        let omega: usize = self
            .omega
            .as_ref()
            .map(|o| o.iter().map(|g| (g.gw.len() + g.gb.len()) * 4).sum())
            .unwrap_or(0);
        let anchor: usize = self
            .anchor
            .as_ref()
            .map(|a| a.iter().map(|p| p.param_count() * 4).sum())
            .unwrap_or(0);
        omega + anchor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::{Act, LayerShape};
    use crate::model::ModelParams;

    fn setup() -> ([LayerShape; 1], Vec<SharedParams>) {
        let shapes = [LayerShape { in_dim: 3, out_dim: 2, act: Act::None }];
        let spec = crate::config::ModelSpec { name: "t".into(), dims: vec![3, 2] };
        (shapes, ModelParams::init(&spec, 5).into_shared())
    }

    #[test]
    fn importance_accumulates_after_seeing_data() {
        let be = NativeBackend;
        let (shapes, params) = setup();
        let ctx = OclCtx { backend: &be, shapes: &shapes, classes: 2, batch: 2, features: 3 };
        let mut mas = MasPlugin::new(0.5, 1);
        assert_eq!(mas.memory_bytes(), 0);
        let b = Batch { id: 0, x: vec![1.0, 2.0, 3.0, -1.0, 0.5, 2.0], y: vec![0, 1] };
        let _ = mas.augment(b, &params, &ctx);
        mas.after_update(&params, &ctx);
        assert!(mas.memory_bytes() > 0);
        assert!(mas.omega.as_ref().unwrap()[0].gw.iter().any(|&v| v > 0.0));
    }

    #[test]
    fn penalty_pulls_toward_anchor() {
        let be = NativeBackend;
        let (shapes, params) = setup();
        let ctx = OclCtx { backend: &be, shapes: &shapes, classes: 2, batch: 2, features: 3 };
        let mut mas = MasPlugin::new(1.0, 1);
        let b = Batch { id: 0, x: vec![1.0; 6], y: vec![0, 1] };
        let _ = mas.augment(b, &params, &ctx);
        mas.after_update(&params, &ctx);
        // drift the params away from the anchor
        let drifted = LayerParams {
            w: params[0].w.iter().map(|v| v + 1.0).collect(),
            b: params[0].b.clone(),
        };
        let mut grad = GradBuf { gw: vec![0.0; 6], gb: vec![0.0; 2] };
        mas.adjust_layer_grad(0, &mut grad, &drifted, &ctx);
        // gradient now points back toward the anchor (positive where Ω>0)
        let omega = &mas.omega.as_ref().unwrap()[0];
        for (g, &o) in grad.gw.iter().zip(&omega.gw) {
            if o > 1e-6 {
                assert!(*g > 0.0, "penalty should push drifted weights back");
            }
        }
        // no drift -> no penalty
        let mut g2 = GradBuf { gw: vec![0.0; 6], gb: vec![0.0; 2] };
        mas.adjust_layer_grad(0, &mut g2, &params[0], &ctx);
        assert!(g2.gw.iter().all(|&v| v.abs() < 1e-6));
    }
}
