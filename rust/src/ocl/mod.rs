//! OCL algorithm plugins (Table 2): Vanilla, ER, MIR, LwF, MAS.
//!
//! Ferret is an OCL *framework*; these plugins are the orthogonal
//! catastrophic-forgetting algorithms it integrates. Each hooks into the
//! engines at three points: batch admission (`augment` — replay mixing),
//! the loss head (`loss_grad` — distillation), and the parameter update
//! (`adjust_layer_grad` / `after_update` — importance regularization and
//! teacher/anchor refresh).

mod er;
mod lwf;
mod mas;
mod mir;

pub use er::ErPlugin;
pub use lwf::LwfPlugin;
pub use mas::MasPlugin;
pub use mir::MirPlugin;

use std::sync::{Arc, Mutex, MutexGuard};

use crate::backend::Backend;
use crate::config::LayerShape;
use crate::model::{GradBuf, LayerParams, SharedParams};
use crate::stream::Batch;
use crate::util::Rng;

/// Static context handed to every hook.
pub struct OclCtx<'a> {
    pub backend: &'a dyn Backend,
    pub shapes: &'a [LayerShape],
    pub classes: usize,
    /// microbatch rows
    pub batch: usize,
    pub features: usize,
}

/// Which plugin to run (Table 2 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OclKind {
    Vanilla,
    Er,
    Mir,
    Lwf,
    Mas,
}

impl OclKind {
    pub fn name(&self) -> &'static str {
        match self {
            OclKind::Vanilla => "Vanilla",
            OclKind::Er => "ER",
            OclKind::Mir => "MIR",
            OclKind::Lwf => "LwF",
            OclKind::Mas => "MAS",
        }
    }

    pub fn all() -> [OclKind; 5] {
        [OclKind::Vanilla, OclKind::Er, OclKind::Mir, OclKind::Lwf, OclKind::Mas]
    }

    pub fn build(&self, seed: u64) -> Box<dyn OclPlugin> {
        match self {
            OclKind::Vanilla => Box::new(Vanilla),
            OclKind::Er => Box::new(ErPlugin::new(er::DEFAULT_BUFFER, seed)),
            OclKind::Mir => Box::new(MirPlugin::new(er::DEFAULT_BUFFER, seed)),
            OclKind::Lwf => Box::new(LwfPlugin::new(0.3, 32)),
            OclKind::Mas => Box::new(MasPlugin::new(0.1, 32)),
        }
    }
}

/// Plugin hook surface. Default impls are no-ops (Vanilla behaviour).
/// Full-model hooks receive [`SharedParams`] slices — the engines' live
/// `Arc` snapshots — so teacher/anchor copies are `Arc` clones, not buffer
/// copies.
pub trait OclPlugin: Send {
    fn name(&self) -> &'static str;

    /// Observe/modify an admitted batch (replay mixing). `params` is the
    /// current full model (for interference scoring).
    fn augment(&mut self, batch: Batch, _params: &[SharedParams], _ctx: &OclCtx) -> Batch {
        batch
    }

    /// Loss head: dL/dlogits and loss. Default: plain CE.
    fn loss_grad(
        &mut self,
        logits: &[f32],
        labels: &[i32],
        batch_x: &[f32],
        ctx: &OclCtx,
    ) -> (Vec<f32>, f32) {
        let _ = batch_x;
        ctx.backend.loss_grad_ce(ctx.classes, logits, labels)
    }

    /// True when [`OclPlugin::loss_grad`] is exactly plain softmax CE. The
    /// freerun engine uses this to offload the loss head onto the
    /// last-stage device thread (the device computes CE without plugin
    /// state). A plugin that overrides `loss_grad` MUST override this to
    /// return false, or its head will be bypassed in freerun mode.
    fn ce_loss_head(&self) -> bool {
        true
    }

    /// Per-layer gradient adjustment at update time (importance penalty).
    fn adjust_layer_grad(
        &mut self,
        _layer: usize,
        _grad: &mut GradBuf,
        _params: &LayerParams,
        _ctx: &OclCtx,
    ) {
    }

    /// Called periodically with the assembled live model (teacher/anchor
    /// refresh, importance accumulation).
    fn after_update(&mut self, _params: &[SharedParams], _ctx: &OclCtx) {}

    /// Extra memory the plugin holds (buffers, teachers, importances).
    fn memory_bytes(&self) -> usize {
        0
    }
}

/// A plugin shared between the scheduler thread and device threads: the
/// freerun engine moves an owned plugin into a cell so the stage-0 device
/// thread can run the `augment` hook itself (replay mixing / interference
/// scoring off the scheduler's critical path). Hooks are `&mut self`, so
/// the cell serializes them behind one lock; the scheduler takes the lock
/// per engine step, a device takes it for the duration of one `augment`.
#[derive(Clone)]
pub struct PluginCell(Arc<Mutex<Box<dyn OclPlugin>>>);

impl PluginCell {
    pub fn new(plugin: Box<dyn OclPlugin>) -> Self {
        PluginCell(Arc::new(Mutex::new(plugin)))
    }

    /// Exclusive access to the plugin (blocks on contention).
    pub fn lock(&self) -> MutexGuard<'_, Box<dyn OclPlugin>> {
        self.0.lock().expect("ocl plugin cell")
    }
}

/// Vanilla: no forgetting mitigation.
pub struct Vanilla;

impl OclPlugin for Vanilla {
    fn name(&self) -> &'static str {
        "Vanilla"
    }
}

/// Sample-level reservoir buffer shared by ER/MIR.
pub struct ReplayBuffer {
    pub cap: usize,
    pub features: usize,
    x: Vec<f32>,
    y: Vec<i32>,
    seen: u64,
    rng: Rng,
}

impl ReplayBuffer {
    pub fn new(cap: usize, seed: u64) -> Self {
        ReplayBuffer { cap, features: 0, x: Vec::new(), y: Vec::new(), seen: 0, rng: Rng::new(seed) }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Reservoir-sample the rows of a batch into the buffer.
    pub fn observe(&mut self, batch: &Batch, features: usize) {
        self.features = features;
        for i in 0..batch.y.len() {
            let row = &batch.x[i * features..(i + 1) * features];
            if self.len() < self.cap {
                self.x.extend_from_slice(row);
                self.y.push(batch.y[i]);
            } else {
                let j = self.rng.below(self.seen as usize + 1);
                if j < self.cap {
                    self.x[j * features..(j + 1) * features].copy_from_slice(row);
                    self.y[j] = batch.y[i];
                }
            }
            self.seen += 1;
        }
    }

    /// Draw `k` (index) samples uniformly (with replacement if k > len).
    pub fn draw(&mut self, k: usize) -> Vec<usize> {
        if self.is_empty() {
            return Vec::new();
        }
        if k <= self.len() {
            self.rng.sample_indices(self.len(), k)
        } else {
            (0..k).map(|_| self.rng.below(self.len())).collect()
        }
    }

    pub fn row(&self, i: usize) -> (&[f32], i32) {
        (&self.x[i * self.features..(i + 1) * self.features], self.y[i])
    }

    pub fn bytes(&self) -> usize {
        self.x.len() * 4 + self.y.len() * 4
    }
}

/// Replace the trailing half of a batch with replay rows (the standard
/// "half new / half replay" ER composition).
pub fn mix_replay(batch: &mut Batch, buf: &ReplayBuffer, picks: &[usize], features: usize) {
    let rows = batch.y.len();
    let half = (rows / 2).min(picks.len());
    for (slot, &pick) in (rows - half..rows).zip(picks) {
        let (x, y) = buf.row(pick);
        batch.x[slot * features..(slot + 1) * features].copy_from_slice(x);
        batch.y[slot] = y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_batch(id: u64, rows: usize, features: usize, label: i32) -> Batch {
        Batch {
            id,
            x: (0..rows * features).map(|i| i as f32 + 100.0 * id as f32).collect(),
            y: vec![label; rows],
        }
    }

    #[test]
    fn reservoir_fills_then_bounds() {
        let mut buf = ReplayBuffer::new(8, 1);
        for i in 0..10 {
            buf.observe(&mk_batch(i, 4, 3, i as i32), 3);
        }
        assert_eq!(buf.len(), 8);
        assert_eq!(buf.bytes(), 8 * 3 * 4 + 8 * 4);
        let picks = buf.draw(4);
        assert_eq!(picks.len(), 4);
        assert!(picks.iter().all(|&p| p < 8));
    }

    #[test]
    fn reservoir_keeps_old_samples_sometimes() {
        let mut buf = ReplayBuffer::new(50, 2);
        for i in 0..100 {
            buf.observe(&mk_batch(i, 4, 2, (i % 10) as i32), 2);
        }
        // with 400 samples through a 50-slot reservoir, labels from the
        // first half should survive with high probability
        let old = (0..buf.len()).filter(|&i| buf.row(i).1 < 5).count();
        assert!(old > 5, "only {old} old labels survived");
    }

    #[test]
    fn mix_replay_replaces_trailing_half() {
        let mut buf = ReplayBuffer::new(4, 3);
        buf.observe(&mk_batch(9, 4, 2, 7), 2);
        let mut b = mk_batch(0, 4, 2, 1);
        let picks = vec![0, 1];
        mix_replay(&mut b, &buf, &picks, 2);
        assert_eq!(b.y, vec![1, 1, 7, 7]);
    }

    #[test]
    fn vanilla_hooks_are_noops() {
        use crate::backend::native::NativeBackend;
        use crate::config::{Act, LayerShape};
        let be = NativeBackend;
        let shapes = [LayerShape { in_dim: 2, out_dim: 2, act: Act::None }];
        let ctx = OclCtx { backend: &be, shapes: &shapes, classes: 2, batch: 2, features: 2 };
        let mut v = Vanilla;
        let b = mk_batch(0, 2, 2, 1);
        let b2 = v.augment(b.clone(), &[], &ctx);
        assert_eq!(b2.x, b.x);
        assert_eq!(v.memory_bytes(), 0);
        let (g, _) = v.loss_grad(&[1.0, 0.0, 0.0, 1.0], &[0, 1], &b.x, &ctx);
        let (ge, _) = be.loss_grad_ce(2, &[1.0, 0.0, 0.0, 1.0], &[0, 1]);
        assert_eq!(g, ge);
    }
}
