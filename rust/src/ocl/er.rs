//! ER — Experience Replay [12]: reservoir buffer + half-replay batches.

use super::{mix_replay, OclCtx, OclPlugin, ReplayBuffer};
use crate::model::SharedParams;
use crate::stream::Batch;

/// Paper §12 uses a 5e3-sample buffer; scaled to the synthetic streams.
pub const DEFAULT_BUFFER: usize = 512;

pub struct ErPlugin {
    buf: ReplayBuffer,
}

impl ErPlugin {
    pub fn new(cap: usize, seed: u64) -> Self {
        ErPlugin { buf: ReplayBuffer::new(cap, seed ^ 0xE5) }
    }
}

impl OclPlugin for ErPlugin {
    fn name(&self) -> &'static str {
        "ER"
    }

    fn augment(&mut self, mut batch: Batch, _params: &[SharedParams], ctx: &OclCtx) -> Batch {
        // mix first so the incoming rows aren't immediately replayed back
        if !self.buf.is_empty() {
            let picks = self.buf.draw(batch.y.len() / 2);
            mix_replay(&mut batch, &self.buf, &picks, ctx.features);
        }
        self.buf.observe(&batch, ctx.features);
        batch
    }

    fn memory_bytes(&self) -> usize {
        self.buf.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::{Act, LayerShape};

    fn ctx<'a>(be: &'a NativeBackend, shapes: &'a [LayerShape]) -> OclCtx<'a> {
        OclCtx { backend: be, shapes, classes: 4, batch: 4, features: 3 }
    }

    #[test]
    fn er_mixes_old_labels_into_new_batches() {
        let be = NativeBackend;
        let shapes = [LayerShape { in_dim: 3, out_dim: 4, act: Act::None }];
        let c = ctx(&be, &shapes);
        let mut er = ErPlugin::new(64, 5);
        // phase 1: label 0 only
        for i in 0..20 {
            let b = Batch { id: i, x: vec![0.5; 12], y: vec![0; 4] };
            let _ = er.augment(b, &[], &c);
        }
        // phase 2: label 3 only; replay should reintroduce label 0
        let mut saw_old = false;
        for i in 20..40 {
            let b = Batch { id: i, x: vec![1.5; 12], y: vec![3; 4] };
            let out = er.augment(b, &[], &c);
            saw_old |= out.y.contains(&0);
        }
        assert!(saw_old, "replay never surfaced phase-1 labels");
        assert!(er.memory_bytes() > 0);
    }
}
