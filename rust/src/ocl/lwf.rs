//! LwF — Learning without Forgetting [47]: distill toward a periodically
//! frozen teacher copy of the model via the `loss_lwf` artifact head.

use super::{OclCtx, OclPlugin};
use crate::backend::forward_all;
use crate::model::SharedParams;

pub struct LwfPlugin {
    /// distillation weight α of the LwF head
    alpha: f32,
    /// refresh the teacher every `refresh` after_update calls
    refresh: u64,
    updates: u64,
    /// frozen teacher snapshot (`Arc` clones of the live model)
    teacher: Option<Vec<SharedParams>>,
}

impl LwfPlugin {
    pub fn new(alpha: f32, refresh: u64) -> Self {
        LwfPlugin { alpha, refresh: refresh.max(1), updates: 0, teacher: None }
    }

    pub fn has_teacher(&self) -> bool {
        self.teacher.is_some()
    }
}

impl OclPlugin for LwfPlugin {
    fn name(&self) -> &'static str {
        "LwF"
    }

    /// LwF's head distills toward a frozen teacher — it is NOT plain CE,
    /// so the freerun engine must keep it on the scheduler thread.
    fn ce_loss_head(&self) -> bool {
        false
    }

    fn loss_grad(
        &mut self,
        logits: &[f32],
        labels: &[i32],
        batch_x: &[f32],
        ctx: &OclCtx,
    ) -> (Vec<f32>, f32) {
        match &self.teacher {
            Some(teacher) => {
                let (_, t_logits) =
                    forward_all(ctx.backend, ctx.shapes, teacher, batch_x, labels.len());
                ctx.backend
                    .loss_grad_lwf(ctx.classes, logits, labels, &t_logits, self.alpha)
            }
            None => ctx.backend.loss_grad_ce(ctx.classes, logits, labels),
        }
    }

    fn after_update(&mut self, params: &[SharedParams], _ctx: &OclCtx) {
        if self.updates % self.refresh == 0 {
            self.teacher = Some(params.to_vec());
        }
        self.updates += 1;
    }

    fn memory_bytes(&self) -> usize {
        self.teacher
            .as_ref()
            .map(|t| t.iter().map(|p| p.param_count() * 4).sum())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::backend::Backend;
    use crate::config::{Act, LayerShape};
    use crate::model::ModelParams;

    #[test]
    fn teacher_refresh_cadence_and_memory() {
        let be = NativeBackend;
        let shapes = [LayerShape { in_dim: 3, out_dim: 2, act: Act::None }];
        let ctx = OclCtx { backend: &be, shapes: &shapes, classes: 2, batch: 2, features: 3 };
        let spec = crate::config::ModelSpec { name: "t".into(), dims: vec![3, 2] };
        let p = ModelParams::init(&spec, 1).into_shared();
        let mut lwf = LwfPlugin::new(0.3, 4);
        assert!(!lwf.has_teacher());
        assert_eq!(lwf.memory_bytes(), 0);
        lwf.after_update(&p, &ctx);
        assert!(lwf.has_teacher());
        assert_eq!(lwf.memory_bytes(), (3 * 2 + 2) * 4);
    }

    #[test]
    fn loss_falls_back_to_ce_without_teacher_and_distills_with() {
        let be = NativeBackend;
        let shapes = [LayerShape { in_dim: 3, out_dim: 2, act: Act::None }];
        let ctx = OclCtx { backend: &be, shapes: &shapes, classes: 2, batch: 2, features: 3 };
        let spec = crate::config::ModelSpec { name: "t".into(), dims: vec![3, 2] };
        let p = ModelParams::init(&spec, 2).into_shared();
        let mut lwf = LwfPlugin::new(0.5, 1);
        let x = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0];
        let logits = vec![0.3, -0.2, 0.1, 0.4];
        let labels = vec![0, 1];
        let (g0, _) = lwf.loss_grad(&logits, &labels, &x, &ctx);
        let (gce, _) = be.loss_grad_ce(2, &logits, &labels);
        assert_eq!(g0, gce, "no teacher -> plain CE");
        lwf.after_update(&p, &ctx);
        let (g1, _) = lwf.loss_grad(&logits, &labels, &x, &ctx);
        assert_ne!(g1, gce, "teacher distillation changes the gradient");
    }
}
