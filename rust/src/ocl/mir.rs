//! MIR — Maximal Interfered Retrieval [3]: replay the buffered samples
//! whose loss would *increase* most after a virtual SGD step on the
//! incoming data.

use super::{mix_replay, OclCtx, OclPlugin, ReplayBuffer};
use crate::backend::{backward_all, ce_loss, forward_all};
use crate::model::{LayerParams, SharedParams};
use crate::stream::Batch;

/// candidate pool multiplier: score 2x the replay slots, keep the top half
const CANDIDATE_FACTOR: usize = 2;
const VIRTUAL_LR: f32 = 0.05;

pub struct MirPlugin {
    buf: ReplayBuffer,
}

impl MirPlugin {
    pub fn new(cap: usize, seed: u64) -> Self {
        MirPlugin { buf: ReplayBuffer::new(cap, seed ^ 0x313) }
    }

    /// One virtual SGD step of the current model on the incoming batch.
    fn virtual_step(
        &self,
        params: &[SharedParams],
        batch: &Batch,
        ctx: &OclCtx,
    ) -> Vec<LayerParams> {
        let (inputs, logits) = forward_all(ctx.backend, ctx.shapes, params, &batch.x, batch.y.len());
        let (gl, _) = ctx.backend.loss_grad_ce(ctx.classes, &logits, &batch.y);
        let grads = backward_all(ctx.backend, ctx.shapes, params, &inputs, &gl, batch.y.len());
        params
            .iter()
            .zip(&grads)
            .map(|(p, g)| ctx.backend.sgd(p, g, VIRTUAL_LR))
            .collect()
    }

    /// Per-candidate interference: loss(θ_virtual) − loss(θ).
    fn interference(
        &self,
        cands: &[usize],
        params: &[SharedParams],
        virt: &[LayerParams],
        ctx: &OclCtx,
    ) -> Vec<(usize, f32)> {
        let mut scored = Vec::with_capacity(cands.len());
        for &idx in cands {
            let (x, y) = self.buf.row(idx);
            let (_, l0) = forward_all(ctx.backend, ctx.shapes, params, x, 1);
            let (_, l1) = forward_all(ctx.backend, ctx.shapes, virt, x, 1);
            let before = ce_loss(ctx.classes, &l0, &[y]);
            let after = ce_loss(ctx.classes, &l1, &[y]);
            scored.push((idx, after - before));
        }
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored
    }
}

impl OclPlugin for MirPlugin {
    fn name(&self) -> &'static str {
        "MIR"
    }

    fn augment(&mut self, mut batch: Batch, params: &[SharedParams], ctx: &OclCtx) -> Batch {
        let half = batch.y.len() / 2;
        if !self.buf.is_empty() && half > 0 && !params.is_empty() {
            let cands = self.buf.draw(half * CANDIDATE_FACTOR);
            let virt = self.virtual_step(params, &batch, ctx);
            let scored = self.interference(&cands, params, &virt, ctx);
            let picks: Vec<usize> = scored.into_iter().take(half).map(|(i, _)| i).collect();
            mix_replay(&mut batch, &self.buf, &picks, ctx.features);
        }
        self.buf.observe(&batch, ctx.features);
        batch
    }

    fn memory_bytes(&self) -> usize {
        self.buf.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::{Act, LayerShape};
    use crate::model::ModelParams;

    #[test]
    fn mir_prefers_maximally_interfered_samples() {
        let be = NativeBackend;
        let shapes = [LayerShape { in_dim: 4, out_dim: 4, act: Act::None }];
        let ctx = OclCtx { backend: &be, shapes: &shapes, classes: 4, batch: 4, features: 4 };
        let spec = crate::config::ModelSpec { name: "t".into(), dims: vec![4, 4] };
        let params = ModelParams::init(&spec, 3).into_shared();
        let mut mir = MirPlugin::new(32, 7);
        // seed the buffer with class-0 and class-1 prototype samples
        for i in 0..8 {
            let y = (i % 2) as i32;
            let mut x = vec![0.0f32; 16];
            for r in 0..4 {
                x[r * 4 + y as usize] = 3.0;
            }
            let b = Batch { id: i, x, y: vec![y; 4] };
            let _ = mir.augment(b, &params, &ctx);
        }
        // now feed a batch that pushes hard toward class 2; interference
        // scoring must run without panicking and mix some replay rows in
        let mut x = vec![0.0f32; 16];
        for r in 0..4 {
            x[r * 4 + 2] = 3.0;
        }
        let out = mir.augment(Batch { id: 100, x, y: vec![2; 4] }, &params, &ctx);
        assert_eq!(out.y.len(), 4);
        // trailing half replaced by buffer rows (classes 0/1)
        assert!(out.y[2..].iter().all(|&y| y == 0 || y == 1), "{:?}", out.y);
    }

    #[test]
    fn virtual_step_changes_params() {
        let be = NativeBackend;
        let shapes = [LayerShape { in_dim: 2, out_dim: 2, act: Act::None }];
        let ctx = OclCtx { backend: &be, shapes: &shapes, classes: 2, batch: 2, features: 2 };
        let spec = crate::config::ModelSpec { name: "t".into(), dims: vec![2, 2] };
        let params = ModelParams::init(&spec, 1).into_shared();
        let mir = MirPlugin::new(8, 1);
        let b = Batch { id: 0, x: vec![1.0, 0.0, 0.0, 1.0], y: vec![0, 1] };
        let virt = mir.virtual_step(&params, &b, &ctx);
        assert_ne!(virt[0].w, params[0].w);
    }
}
