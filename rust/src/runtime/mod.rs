//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client.
//!
//! Interchange is HLO *text* — jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Executables are compiled
//! lazily on first use and cached for the lifetime of the runtime, so the
//! request path pays compile cost exactly once per artifact. The cache is
//! behind a `Mutex` (not `RefCell`): [`crate::backend::Backend`] is
//! `Send + Sync` so the threaded pipeline executor can share one runtime
//! across device threads.
//!
//! The PJRT client itself lives behind the `xla` cargo feature (the crate
//! is vendored, not on crates.io). Without the feature this module compiles
//! a stub [`Runtime`] whose `open()` fails with a clear message — callers
//! (tests, benches, the CLI `--backend xla` path) degrade gracefully.

use crate::util::error::{bail, Context, Result};
use std::collections::HashMap;

/// Artifact naming — MUST stay in sync with `python/compile/aot.py`.
pub mod names {
    use crate::config::LayerShape;

    pub fn dense_fwd(s: &LayerShape) -> String {
        format!("dense_fwd_{}x{}_{}", s.in_dim, s.out_dim, s.act.as_str())
    }
    pub fn dense_bwd(s: &LayerShape) -> String {
        format!("dense_bwd_{}x{}_{}", s.in_dim, s.out_dim, s.act.as_str())
    }
    pub fn compensate(s: &LayerShape) -> String {
        format!("compensate_{}x{}", s.in_dim, s.out_dim)
    }
    pub fn sgd(s: &LayerShape) -> String {
        format!("sgd_{}x{}", s.in_dim, s.out_dim)
    }
    pub fn loss_ce(classes: usize) -> String {
        format!("loss_ce_{classes}")
    }
    pub fn loss_lwf(classes: usize) -> String {
        format!("loss_lwf_{classes}")
    }
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub batch: usize,
    /// artifact name -> file name (relative to the artifact dir)
    pub artifacts: HashMap<String, String>,
}

pub fn parse_manifest(text: &str) -> Result<Manifest> {
    let mut batch = None;
    let mut artifacts = HashMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        match parts[0] {
            "batch" if parts.len() == 2 => batch = Some(parts[1].parse()?),
            "artifact" if parts.len() == 3 => {
                artifacts.insert(parts[1].to_string(), parts[2].to_string());
            }
            _ => bail!("manifest:{}: malformed line {line:?}", lineno + 1),
        }
    }
    Ok(Manifest {
        batch: batch.context("manifest missing batch")?,
        artifacts,
    })
}

#[cfg(feature = "xla")]
mod pjrt {
    use super::{parse_manifest, Manifest};
    use crate::util::error::{bail, Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    /// PJRT-backed executor over an artifact directory.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
        execs: Mutex<u64>,
    }

    impl Runtime {
        /// Open the artifact dir (e.g. `artifacts/`) and start a CPU client.
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref().to_path_buf();
            let manifest_path = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest_path).with_context(|| {
                format!(
                    "reading {} — run `make artifacts` first",
                    manifest_path.display()
                )
            })?;
            let manifest = parse_manifest(&text)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime {
                client,
                dir,
                manifest,
                cache: Mutex::new(HashMap::new()),
                execs: Mutex::new(0),
            })
        }

        /// Default artifact dir resolved against the repo root.
        pub fn open_default() -> Result<Self> {
            Self::open(crate::config::repo_path("artifacts"))
        }

        pub fn batch(&self) -> usize {
            self.manifest.batch
        }

        /// Number of PJRT executions performed (perf counters).
        pub fn exec_count(&self) -> u64 {
            *self.execs.lock().unwrap()
        }

        fn load(&self, name: &str) -> Result<()> {
            // Hold the cache lock across the compile: two device threads
            // first-touching the same artifact must not both pay the
            // (dominant) compile cost — the cache's once-per-artifact
            // contract is per runtime, not per thread.
            let mut cache = self.cache.lock().unwrap();
            if cache.contains_key(name) {
                return Ok(());
            }
            let file = self
                .manifest
                .artifacts
                .get(name)
                .with_context(|| format!("artifact {name:?} not in manifest"))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            cache.insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute artifact `name` with literal inputs; returns the
        /// flattened tuple elements (all artifacts are lowered with
        /// return_tuple=True). The executable cache lock is held for the
        /// duration of the dispatch, serializing device threads per
        /// runtime — one PJRT CPU client is a single device anyway.
        pub fn exec(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            self.load(name)?;
            let cache = self.cache.lock().unwrap();
            let exe = cache.get(name).unwrap();
            *self.execs.lock().unwrap() += 1;
            let result = exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("executing artifact {name}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .with_context(|| format!("fetching result of {name}"))?;
            lit.to_tuple().with_context(|| format!("untupling result of {name}"))
        }

        /// Number of artifacts compiled so far.
        pub fn compiled_count(&self) -> usize {
            self.cache.lock().unwrap().len()
        }
    }

    /// f32 literal of the given logical dims from a flat slice.
    pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let expect: i64 = dims.iter().product();
        if expect != data.len() as i64 {
            bail!("lit_f32: {} values for dims {dims:?}", data.len());
        }
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// i32 literal (1-D).
    pub fn lit_i32(data: &[i32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// Scalar-as-(1,) f32 literal (the artifact calling convention for
    /// lam/lr).
    pub fn lit_scalar(v: f32) -> xla::Literal {
        xla::Literal::vec1(&[v])
    }

    /// Flatten a literal back to Vec<f32>.
    pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{lit_f32, lit_i32, lit_scalar, to_f32, Runtime};

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::util::error::{bail, Result};
    use std::path::Path;

    /// Stub runtime compiled without the `xla` feature: `open()` always
    /// fails, so every artifact-dependent path reports a clear skip
    /// message instead of failing to build.
    pub struct Runtime {
        never: std::convert::Infallible,
    }

    impl Runtime {
        pub fn open(_dir: impl AsRef<Path>) -> Result<Self> {
            bail!(
                "ferret was built without the `xla` cargo feature; rebuild with \
                 --features xla (requires the vendored xla crate)"
            )
        }

        pub fn open_default() -> Result<Self> {
            Self::open("artifacts")
        }

        pub fn batch(&self) -> usize {
            match self.never {}
        }

        pub fn exec_count(&self) -> u64 {
            match self.never {}
        }

        pub fn compiled_count(&self) -> usize {
            match self.never {}
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::Runtime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = parse_manifest("batch 16\nartifact a a.hlo.txt\nartifact b b.hlo.txt\n").unwrap();
        assert_eq!(m.batch, 16);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts["a"], "a.hlo.txt");
        assert!(parse_manifest("artifact a\n").is_err());
        assert!(parse_manifest("artifact a a.hlo.txt\n").is_err(), "missing batch");
    }

    #[test]
    fn names_match_python_convention() {
        use crate::config::{Act, LayerShape};
        let s = LayerShape { in_dim: 784, out_dim: 256, act: Act::Relu };
        assert_eq!(names::dense_fwd(&s), "dense_fwd_784x256_relu");
        assert_eq!(names::dense_bwd(&s), "dense_bwd_784x256_relu");
        assert_eq!(names::compensate(&s), "compensate_784x256");
        assert_eq!(names::sgd(&s), "sgd_784x256");
        assert_eq!(names::loss_ce(10), "loss_ce_10");
        assert_eq!(names::loss_lwf(62), "loss_lwf_62");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn lit_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit_f32(&[1.0], &[2, 2]).is_err());
    }
}
