//! ferret-lint CLI: walk the crate sources and enforce the layering,
//! determinism, panic-freedom, and lock-discipline invariants.
//!
//!     cargo run --release --bin ferret_lint -- [SRC_DIR] [--json OUT]
//!
//! `SRC_DIR` defaults to `rust/src` (repo root) or `src` (crate dir),
//! whichever exists. Prints one `file:line: rule: message` per finding
//! and exits nonzero if any survive; `--json` additionally writes the
//! findings as a machine-readable report for CI artifacts.

use std::path::Path;
use std::process::ExitCode;

use ferret::analysis::{lint_tree, Finding};
use ferret::trace::json::escape;

fn usage() -> ! {
    eprintln!("usage: ferret_lint [SRC_DIR] [--json OUT.json]");
    std::process::exit(2)
}

fn json_report(findings: &[(String, Finding)]) -> String {
    let mut out = String::from("{\n \"findings\": [");
    for (i, (file, f)) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"file\": {}, \"line\": {}, \"rule\": {}, \"msg\": {}}}",
            escape(file),
            f.line,
            escape(f.rule),
            escape(&f.msg)
        ));
    }
    out.push_str(&format!("\n ],\n \"count\": {}\n}}\n", findings.len()));
    out
}

fn main() -> ExitCode {
    let mut src: Option<String> = None;
    let mut json_out: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_out = Some(p),
                None => usage(),
            },
            "--help" | "-h" => usage(),
            _ if src.is_none() => src = Some(a),
            _ => usage(),
        }
    }
    let root = match src {
        Some(p) => p,
        None if Path::new("rust/src").is_dir() => "rust/src".to_string(),
        None if Path::new("src").is_dir() => "src".to_string(),
        None => {
            eprintln!("ferret_lint: no source tree found (run from the repo or crate root)");
            return ExitCode::from(2);
        }
    };
    let findings = match lint_tree(Path::new(&root)) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("ferret_lint: failed to read {root}: {e}");
            return ExitCode::from(2);
        }
    };
    for (file, f) in &findings {
        println!("{file}:{}: {}: {}", f.line, f.rule, f.msg);
    }
    println!("ferret-lint: {} finding(s)", findings.len());
    if let Some(p) = json_out {
        if let Err(e) = std::fs::write(&p, json_report(&findings)) {
            eprintln!("ferret_lint: failed to write {p}: {e}");
            return ExitCode::from(2);
        }
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
