//! ferret-bench — regenerate the paper's tables and figures.
//!
//! Usage:
//!   ferret_bench --exp table1|table2|table3|table4|table7|table8|fig4|fig6|fig7|budget_shift|all
//!                [--quick] [--batches N] [--seeds a,b,...] [--settings i,j,...]
//!                [--executor sim|threaded] [--mode lockstep|freerun]
//!                [--budget-schedule <bytes>@<at>[,...]]
//!
//! `--exp budget_shift` emits the dynamic-memory table: the budget halves
//! mid-stream and Ferret's live re-plan is compared against a
//! static-min-budget plan and a restart-from-scratch baseline; results
//! are also dumped as results/budget_shift.json (CI artifact).
//! `--budget-schedule` overrides the default per-model halving, e.g.
//! `12mb@b20` or `24mb@0,8mb@b100` (`u<N>` positions are wall-clock µs
//! for freerun runs).
//!
//! `--executor threaded` runs the async engines on one OS thread per
//! (worker, stage) device and reports real wall-clock samples/sec; `sim`
//! (default) is the single-threaded virtual-time simulation.
//!
//! `--mode freerun` paces each async run against the wall clock (1 tick =
//! 1µs) with stage updates on the owning device threads, and reports
//! observed per-batch latency percentiles plus the staleness histogram;
//! `lockstep` (default) replays virtual time (deterministic).
//!
//! Results are printed as markdown and saved under results/ as .md + .csv.

use ferret::budget::BudgetSchedule;
use ferret::harness::{Bench, BenchCfg, Table};
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;

fn usage() -> ! {
    eprintln!(
        "usage: ferret_bench --exp \
         <table1|table2|table3|table4|table7|table8|fig4|fig6|fig7|budget_shift|all> \
         [--quick] [--batches N] [--seeds a,b] [--settings i,j] [--executor sim|threaded] \
         [--mode lockstep|freerun] [--budget-schedule <bytes>@<at>[,...]]"
    );
    std::process::exit(2)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut exp = String::from("all");
    let mut cfg = BenchCfg::default();
    // apply the --quick preset first so explicit --batches/--seeds/
    // --settings override it regardless of flag order
    if args.iter().any(|a| a == "--quick") {
        cfg = BenchCfg {
            quiet: cfg.quiet,
            executor: cfg.executor,
            mode: cfg.mode,
            ..BenchCfg::quick()
        };
    }
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--exp" => {
                i += 1;
                exp = args.get(i).unwrap_or_else(|| usage()).clone();
            }
            "--quick" => {} // applied above
            "--batches" => {
                i += 1;
                cfg.num_batches =
                    args.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| usage());
            }
            "--seeds" => {
                i += 1;
                cfg.seeds = args
                    .get(i)
                    .unwrap_or_else(|| usage())
                    .split(',')
                    .map(|s| s.parse().expect("seed"))
                    .collect();
            }
            "--settings" => {
                i += 1;
                cfg.settings = Some(
                    args.get(i)
                        .unwrap_or_else(|| usage())
                        .split(',')
                        .map(|s| s.parse().expect("setting index"))
                        .collect(),
                );
            }
            "--executor" => {
                i += 1;
                cfg.executor = args
                    .get(i)
                    .and_then(|s| ExecutorKind::parse(s))
                    .unwrap_or_else(|| usage());
            }
            "--mode" => {
                i += 1;
                cfg.mode = args.get(i).and_then(|s| Mode::parse(s)).unwrap_or_else(|| usage());
            }
            "--budget-schedule" => {
                i += 1;
                let spec = args.get(i).unwrap_or_else(|| usage());
                cfg.budget_schedule = match BudgetSchedule::parse(spec) {
                    Ok(b) => Some(b),
                    Err(e) => {
                        eprintln!("error: --budget-schedule: {e}");
                        std::process::exit(2);
                    }
                };
            }
            "--quiet" => cfg.quiet = true,
            _ => usage(),
        }
        i += 1;
    }

    let t0 = std::time::Instant::now();
    let executor = cfg.executor;
    let mode = cfg.mode;
    let mut bench = Bench::new(cfg);
    let emit = |name: &str, table: Table| {
        println!("\n{}", table.to_markdown());
        table.save(name).expect("writing results/");
        eprintln!(
            "[ferret-bench] saved results/{name}.{{md,csv}} ({:.0}s)",
            t0.elapsed().as_secs_f64()
        );
    };

    let want = |e: &str| exp == "all" || exp == e;
    if want("table1") {
        let t = bench.table1();
        emit("table1", t);
    }
    if want("table7") {
        let t = bench.table7();
        emit("table7", t);
    }
    if want("fig4") {
        let t = bench.fig4();
        emit("fig4", t);
    }
    if want("table3") {
        let t = bench.table3();
        emit("table3", t);
    }
    if want("table2") || want("table8") {
        let (t2, t8) = bench.table2_and_8();
        emit("table2", t2);
        emit("table8", t8);
    }
    if want("table4") {
        let t = bench.table4();
        emit("table4", t);
    }
    if want("fig6") {
        let t = bench.fig6();
        emit("fig6", t);
    }
    if want("budget_shift") {
        let sched = bench.cfg.budget_schedule.clone();
        let t = bench.budget_shift(sched.as_ref());
        t.save_json("budget_shift").expect("writing results/");
        emit("budget_shift", t);
    }
    if want("fig7") {
        let t = bench.fig7();
        emit("fig7", t);
    }
    if mode == Mode::Freerun {
        eprintln!(
            "[ferret-bench] wall-clock batch latency µs: {}",
            bench.observability.latency_summary()
        );
        eprintln!(
            "[ferret-bench] observed staleness histogram: {}",
            bench.observability.staleness_summary()
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    eprintln!(
        "[ferret-bench] done in {wall:.0}s | executor={} | mode={} | \
         max worker threads observed={} | {:.1} engine-batches/s wall-clock",
        executor.name(),
        mode.name(),
        bench.max_threads_seen,
        bench.batches_run as f64 / wall.max(1e-9),
    );
}
