//! ferret-bench — regenerate the paper's tables and figures.
//!
//! Usage:
//!   ferret_bench --exp table1|table2|table3|table4|table7|table8|fig4|fig6|fig7|budget_shift|perf|all
//!                [--quick] [--batches N] [--seeds a,b,...] [--settings i,j,...]
//!                [--executor sim|threaded] [--mode lockstep|freerun]
//!                [--budget-schedule <bytes>@<at>[,...]]
//!                [--kernel-threads K] [--bench-out PATH]
//!                [--compare BASE.json] [--max-regress X]
//!
//! `--exp perf` runs the performance trajectory sweep instead of a paper
//! table: per-kernel GFLOP/s (naive vs tiled vs tiled×K), engine
//! batches/sec per executor×mode, and steady-state buffer-pool
//! allocations per microbatch. The JSON lands at `--bench-out` (default
//! results/perf.json); the committed trajectory point at the repo root
//! (BENCH_0008.json) is a full, non-quick run of the same sweep. `perf`
//! is excluded from `--exp all` — it measures this machine, not the
//! paper.
//!
//! `--compare BASE.json` additionally diffs the fresh sweep against a
//! committed BENCH file: per-kernel `tiled_mt_gflops` and per-model
//! `batches_per_sec` ratios. With `--max-regress X` (a fraction, e.g.
//! 0.5) the process exits 1 when any matched row runs slower than
//! `(1 - X) ×` baseline — the CI perf smoke uses a loose threshold to
//! catch order-of-magnitude cliffs without flaking on machine noise.
//!
//! `--exp budget_shift` emits the dynamic-memory table: the budget halves
//! mid-stream and Ferret's live re-plan is compared against a
//! static-min-budget plan and a restart-from-scratch baseline; results
//! are also dumped as results/budget_shift.json (CI artifact).
//! `--budget-schedule` overrides the default per-model halving, e.g.
//! `12mb@b20` or `24mb@0,8mb@b100` (`u<N>` positions are wall-clock µs
//! for freerun runs).
//!
//! `--executor threaded` runs the async engines on one OS thread per
//! (worker, stage) device and reports real wall-clock samples/sec; `sim`
//! (default) is the single-threaded virtual-time simulation.
//!
//! `--mode freerun` paces each async run against the wall clock (1 tick =
//! 1µs) with stage updates on the owning device threads, and reports
//! observed per-batch latency percentiles plus the staleness histogram;
//! `lockstep` (default) replays virtual time (deterministic).
//!
//! Results are printed as markdown and saved under results/ as .md + .csv.

use ferret::budget::BudgetSchedule;
use ferret::harness::{Bench, BenchCfg, Table};
use ferret::pipeline::executor::ExecutorKind;
use ferret::pipeline::sched::Mode;

fn usage() -> ! {
    eprintln!(
        "usage: ferret_bench --exp \
         <table1|table2|table3|table4|table7|table8|fig4|fig6|fig7|budget_shift|perf|all> \
         [--quick] [--batches N] [--seeds a,b] [--settings i,j] [--executor sim|threaded] \
         [--mode lockstep|freerun] [--budget-schedule <bytes>@<at>[,...]] \
         [--kernel-threads K] [--bench-out PATH] [--compare BASE.json] [--max-regress X]"
    );
    std::process::exit(2)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut exp = String::from("all");
    let mut cfg = BenchCfg::default();
    let mut kernel_threads = 0usize;
    let mut bench_out: Option<String> = None;
    let mut compare: Option<String> = None;
    let mut max_regress: Option<f64> = None;
    // apply the --quick preset first so explicit --batches/--seeds/
    // --settings override it regardless of flag order
    if args.iter().any(|a| a == "--quick") {
        cfg = BenchCfg {
            quiet: cfg.quiet,
            executor: cfg.executor,
            mode: cfg.mode,
            ..BenchCfg::quick()
        };
    }
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--exp" => {
                i += 1;
                exp = args.get(i).unwrap_or_else(|| usage()).clone();
            }
            "--quick" => {} // applied above
            "--batches" => {
                i += 1;
                cfg.num_batches =
                    args.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| usage());
            }
            "--seeds" => {
                i += 1;
                cfg.seeds = args
                    .get(i)
                    .unwrap_or_else(|| usage())
                    .split(',')
                    .map(|s| s.parse().expect("seed"))
                    .collect();
            }
            "--settings" => {
                i += 1;
                cfg.settings = Some(
                    args.get(i)
                        .unwrap_or_else(|| usage())
                        .split(',')
                        .map(|s| s.parse().expect("setting index"))
                        .collect(),
                );
            }
            "--executor" => {
                i += 1;
                cfg.executor = args
                    .get(i)
                    .and_then(|s| ExecutorKind::parse(s))
                    .unwrap_or_else(|| usage());
            }
            "--mode" => {
                i += 1;
                cfg.mode = args.get(i).and_then(|s| Mode::parse(s)).unwrap_or_else(|| usage());
            }
            "--budget-schedule" => {
                i += 1;
                let spec = args.get(i).unwrap_or_else(|| usage());
                cfg.budget_schedule = match BudgetSchedule::parse(spec) {
                    Ok(b) => Some(b),
                    Err(e) => {
                        eprintln!("error: --budget-schedule: {e}");
                        std::process::exit(2);
                    }
                };
            }
            "--kernel-threads" => {
                i += 1;
                kernel_threads =
                    args.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| usage());
            }
            "--bench-out" => {
                i += 1;
                bench_out = Some(args.get(i).unwrap_or_else(|| usage()).clone());
            }
            "--compare" => {
                i += 1;
                compare = Some(args.get(i).unwrap_or_else(|| usage()).clone());
            }
            "--max-regress" => {
                i += 1;
                max_regress =
                    Some(args.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| usage()));
            }
            "--quiet" => cfg.quiet = true,
            _ => usage(),
        }
        i += 1;
    }

    // the perf trajectory sweep is its own harness (no run matrix, no
    // paper tables) and is excluded from `--exp all`: it measures this
    // machine's kernels/engine, not the paper's evaluation
    if exp == "perf" {
        let quick = args.iter().any(|a| a == "--quick");
        let t0 = std::time::Instant::now();
        let report = ferret::harness::perf::run_perf(quick, kernel_threads);
        println!("\n{}", report.to_markdown());
        let path = bench_out.unwrap_or_else(|| {
            let dir = ferret::config::repo_path("results");
            let _ = std::fs::create_dir_all(&dir);
            format!("{dir}/perf.json")
        });
        std::fs::write(&path, report.to_json()).expect("writing bench json");
        eprintln!(
            "[ferret-bench] perf sweep saved to {path} ({:.0}s)",
            t0.elapsed().as_secs_f64()
        );
        if let Some(base_path) = compare {
            let base = match std::fs::read_to_string(&base_path) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("error: --compare {base_path}: {e}");
                    std::process::exit(2);
                }
            };
            let cmp = match report.compare(&base) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("error: --compare {base_path}: {e}");
                    std::process::exit(2);
                }
            };
            println!("\n{}", cmp.to_markdown());
            let worst = cmp.worst_regress();
            if let Some(limit) = max_regress {
                if worst > limit {
                    eprintln!(
                        "[ferret-bench] FAIL: worst regression {:.1}% exceeds \
                         --max-regress {:.1}% vs {}",
                        worst * 100.0,
                        limit * 100.0,
                        cmp.baseline_name
                    );
                    std::process::exit(1);
                }
                eprintln!(
                    "[ferret-bench] perf gate ok: worst regression {:.1}% within {:.1}%",
                    worst * 100.0,
                    limit * 100.0
                );
            }
        } else if max_regress.is_some() {
            eprintln!("error: --max-regress requires --compare BASE.json");
            std::process::exit(2);
        }
        return;
    }

    let t0 = std::time::Instant::now();
    let executor = cfg.executor;
    let mode = cfg.mode;
    let mut bench = Bench::new(cfg);
    let emit = |name: &str, table: Table| {
        println!("\n{}", table.to_markdown());
        table.save(name).expect("writing results/");
        eprintln!(
            "[ferret-bench] saved results/{name}.{{md,csv}} ({:.0}s)",
            t0.elapsed().as_secs_f64()
        );
    };

    let want = |e: &str| exp == "all" || exp == e;
    if want("table1") {
        let t = bench.table1();
        emit("table1", t);
    }
    if want("table7") {
        let t = bench.table7();
        emit("table7", t);
    }
    if want("fig4") {
        let t = bench.fig4();
        emit("fig4", t);
    }
    if want("table3") {
        let t = bench.table3();
        emit("table3", t);
    }
    if want("table2") || want("table8") {
        let (t2, t8) = bench.table2_and_8();
        emit("table2", t2);
        emit("table8", t8);
    }
    if want("table4") {
        let t = bench.table4();
        emit("table4", t);
    }
    if want("fig6") {
        let t = bench.fig6();
        emit("fig6", t);
    }
    if want("budget_shift") {
        let sched = bench.cfg.budget_schedule.clone();
        let t = bench.budget_shift(sched.as_ref());
        t.save_json("budget_shift").expect("writing results/");
        emit("budget_shift", t);
    }
    if want("fig7") {
        let t = bench.fig7();
        emit("fig7", t);
    }
    if mode == Mode::Freerun {
        eprintln!(
            "[ferret-bench] wall-clock batch latency µs: {}",
            bench.observability.latency_summary()
        );
        eprintln!(
            "[ferret-bench] observed staleness histogram: {}",
            bench.observability.staleness_summary()
        );
        eprintln!(
            "[ferret-bench] fleet utilization: {:.1}% busy, {:.1}% bubble \
             (aggregated over every async run)",
            100.0 * bench.observability.utilization(),
            100.0 * bench.observability.bubble_frac()
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    eprintln!(
        "[ferret-bench] done in {wall:.0}s | executor={} | mode={} | \
         max worker threads observed={} | {:.1} engine-batches/s wall-clock",
        executor.name(),
        mode.name(),
        bench.max_threads_seen,
        bench.batches_run as f64 / wall.max(1e-9),
    );
}
