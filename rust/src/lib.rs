//! Ferret: an efficient Online Continual Learning framework under varying
//! memory constraints — rust + JAX + Pallas (AOT via PJRT) reproduction.
//!
//! See DESIGN.md for the paper -> system mapping. Layer 3 (this crate)
//! owns scheduling, planning, compensation, metrics and the request path;
//! Layers 2/1 (python/compile) are AOT-lowered to `artifacts/*.hlo.txt`
//! and executed through [`runtime::Runtime`].

pub mod analysis;
pub mod backend;
pub mod baselines;
pub mod budget;
pub mod compensate;
pub mod config;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod ocl;
pub mod pipeline;
pub mod planner;
pub mod runtime;
pub mod stream;
pub mod trace;
pub mod util;
