//! ferret-lint: a zero-dependency invariant checker for this crate's
//! layering, determinism, panic-freedom, and lock-discipline contracts.
//!
//! The crate carries several invariants that rustc cannot see:
//!
//! - **layering** — module dependency edges must stay inside the
//!   committed DAG ([`layering::ALLOWED_EDGES`], mirrored in ROADMAP's
//!   module map), including the sched ← executor ← engine ← session
//!   sub-layering inside `pipeline`;
//! - **det-map / det-time / det-thread / det-rng** — the replay-critical
//!   core ([`determinism::DET_CORE`]) must not consume randomized
//!   iteration order, wall-clock time, ad-hoc threads, or RNGs outside
//!   `util::rng`;
//! - **entry-panic / entry-index** — the session entry surfaces and the
//!   trace parser must not panic on caller input;
//! - **lock-order** — every Mutex is registered at a level in
//!   [`locks::LOCK_LEVELS`] and acquired in increasing level order.
//!
//! `cargo run --release --bin ferret_lint` walks `rust/src/**`, prints
//! findings as `file:line: rule: message`, and exits nonzero if any
//! survive. A finding that is intentional is suppressed inline with
//!
//! ```text
//! // ferret-lint: allow(rule-id) — reason the construct is sound
//! ```
//!
//! on its own line (suppresses the next code line) or trailing the
//! flagged line. The reason is mandatory: a bare allow is itself a
//! finding (`allow-missing-reason`). See docs/static-analysis.md for the
//! full invariant catalog.
//!
//! The checker is character-level, not a Rust parser: comments and
//! string contents are blanked first ([`strip`]), `#[cfg(test)]` spans
//! are exempt, and every rule is line-oriented. That makes it fast,
//! dependency-free, and easy to reason about; the cost — no type or
//! flow information — is covered by keeping the rules conservative and
//! the allow mechanism cheap.

pub mod determinism;
pub mod layering;
pub mod locks;
pub mod panics;
pub mod strip;

use std::collections::BTreeSet;
use std::io;
use std::path::{Path, PathBuf};

/// One lint finding, 1-based line, stable rule id, human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// Preprocessed view of one source file handed to the rule passes.
pub struct Sf<'a> {
    /// Stripped code, split into lines (comments/strings blanked).
    pub lines: Vec<&'a str>,
    /// Per-line test-code flags (same length as `lines`).
    pub test: Vec<bool>,
    /// The stripped code as one flat string (for cross-line scans).
    pub flat: &'a str,
}

/// Lint one file. `path` is the `src/`-relative path (forward slashes)
/// that selects which rule families apply; `src` is the file content.
/// Returns findings sorted by (line, rule, message), deduplicated per
/// (line, rule), with inline allows already applied.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let stripped = strip::strip(src);
    let sf = Sf {
        lines: stripped.code.split('\n').collect(),
        test: strip::test_lines(&stripped.code),
        flat: &stripped.code,
    };
    let (supp, meta) = strip::allows(&stripped.comments, &sf.lines);
    let mut finds = Vec::new();
    finds.extend(layering::check(path, &sf));
    finds.extend(determinism::check(path, &sf));
    finds.extend(panics::check(path, &sf));
    finds.extend(locks::check(path, &sf));
    let mut out = Vec::new();
    let mut seen: BTreeSet<(usize, &'static str)> = BTreeSet::new();
    for f in finds {
        let suppressed = supp.get(f.rule).is_some_and(|s| s.contains(&(f.line - 1)));
        if suppressed || !seen.insert((f.line, f.rule)) {
            continue;
        }
        out.push(f);
    }
    out.extend(meta);
    out.sort_by(|a, b| (a.line, a.rule, &a.msg).cmp(&(b.line, b.rule, &b.msg)));
    out
}

/// Walk a source tree and lint every `.rs` file. Returns
/// (src-relative path, finding) pairs in path order.
pub fn lint_tree(root: &Path) -> io::Result<Vec<(String, Finding)>> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for full in files {
        let rel = full
            .strip_prefix(root)
            .unwrap_or(&full)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&full)?;
        for f in lint_source(&rel, &src) {
            out.push((rel.clone(), f));
        }
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
