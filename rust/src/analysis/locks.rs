//! Lock-discipline rule: every `.lock()` receiver must be registered in
//! the committed Mutex hierarchy, and within one function body locks may
//! only be acquired in increasing level order.
//!
//! The hierarchy (level 1 acquired first):
//!   1. PluginCell        — the shared OCL plugin cell
//!   2. StageCell         — per-stage parameter cells in the executor
//!   3. BufferPool        — the backend shelf pool
//!   4. runtime cache     — the executable cache
//!   5. runtime execs     — the exec counter
//!   6. trace mem sink    — the in-memory trace line store
//!
//! The scan is intra-function and lexical: it catches the ordering bugs
//! that actually happen (two locks taken back-to-back in one function)
//! without pretending to be a whole-program analysis. An unregistered
//! receiver is itself a finding — new Mutexes must be placed in the
//! hierarchy (or allowed) deliberately.

use super::{Finding, Sf};

/// (module-path-prefix, receiver-last-token, level, name).
pub const LOCK_LEVELS: &[(&str, &str, u32, &str)] = &[
    ("ocl", "0", 1, "PluginCell"),
    ("pipeline", "plugin", 1, "PluginCell"),
    ("pipeline/session.rs", "c", 1, "PluginCell"),
    ("pipeline/executor.rs", "inner", 2, "StageCell"),
    ("backend/pool.rs", "shelves", 3, "BufferPool"),
    ("runtime", "cache", 4, "runtime executable cache"),
    ("runtime", "execs", 5, "runtime exec counter"),
    ("trace", "v", 6, "trace mem sink"),
    ("trace", "lines", 6, "trace mem sink"),
];

fn classify(path: &str, recv: &str) -> Option<(u32, &'static str)> {
    LOCK_LEVELS
        .iter()
        .find(|(prefix, tok, _, _)| path.starts_with(prefix) && recv == *tok)
        .map(|&(_, _, level, name)| (level, name))
}

/// Byte offsets of every `.lock()` call (whitespace tolerated inside),
/// as the offset of the leading `.`.
fn lock_sites(flat: &str) -> Vec<usize> {
    let b = flat.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        if b[i] == b'.' {
            let mut j = i + 1;
            while j < b.len() && (b[j] as char).is_whitespace() {
                j += 1;
            }
            if flat[j..].starts_with("lock") {
                let mut k = j + 4;
                while k < b.len() && (b[k] as char).is_whitespace() {
                    k += 1;
                }
                if k < b.len() && b[k] == b'(' {
                    let mut l = k + 1;
                    while l < b.len() && (b[l] as char).is_whitespace() {
                        l += 1;
                    }
                    if l < b.len() && b[l] == b')' {
                        out.push(i);
                        i = l + 1;
                        continue;
                    }
                }
            }
        }
        i += 1;
    }
    out
}

/// Last identifier before the `.lock()` chain, skipping whitespace and
/// newlines (method chains are often line-broken).
fn receiver(flat: &str, dot: usize) -> String {
    let b = flat.as_bytes();
    let mut j = dot as isize - 1;
    while j >= 0 && matches!(b[j as usize], b' ' | b'\n' | b'\t') {
        j -= 1;
    }
    let end = (j + 1) as usize;
    while j >= 0 && (b[j as usize].is_ascii_alphanumeric() || b[j as usize] == b'_') {
        j -= 1;
    }
    flat[(j + 1) as usize..end].to_string()
}

/// Rough function spans (start, end) byte offsets, by brace matching
/// from each `fn <name>` item; trait method declarations are skipped.
fn fn_spans(flat: &str) -> Vec<(usize, usize)> {
    let b = flat.as_bytes();
    let mut spans = Vec::new();
    let mut from = 0usize;
    while let Some(off) = flat[from..].find("fn") {
        let start = from + off;
        from = start + 2;
        let before_ok = start == 0 || !(b[start - 1].is_ascii_alphanumeric() || b[start - 1] == b'_');
        if !before_ok {
            continue;
        }
        // `fn` must be followed by whitespace then an identifier
        let mut p = start + 2;
        if p >= b.len() || !(b[p] as char).is_whitespace() {
            continue;
        }
        while p < b.len() && (b[p] as char).is_whitespace() {
            p += 1;
        }
        if p >= b.len() || !(b[p].is_ascii_alphanumeric() || b[p] == b'_') {
            continue;
        }
        while p < b.len() && (b[p].is_ascii_alphanumeric() || b[p] == b'_') {
            p += 1;
        }
        let open = flat[p..].find('{').map(|r| p + r);
        let semi = flat[p..].find(';').map(|r| p + r);
        let Some(j) = open else { continue };
        if let Some(s) = semi {
            if s < j {
                continue;
            }
        }
        let mut depth = 0i32;
        let mut k = j;
        while k < b.len() {
            match b[k] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        spans.push((start, k));
    }
    spans
}

fn line_of(flat: &str, off: usize) -> usize {
    flat.as_bytes()[..off].iter().filter(|&&b| b == b'\n').count()
}

pub fn check(path: &str, sf: &Sf) -> Vec<Finding> {
    let flat = sf.flat;
    let mut finds = Vec::new();
    // (offset, 1-based line, level, name)
    let mut acquisitions: Vec<(usize, usize, u32, &'static str)> = Vec::new();
    for dot in lock_sites(flat) {
        let line = line_of(flat, dot);
        if sf.test[line] {
            continue;
        }
        let recv = receiver(flat, dot);
        match classify(path, &recv) {
            None => finds.push(Finding {
                line: line + 1,
                rule: "lock-order",
                msg: format!(
                    "`.lock()` on unregistered receiver `{recv}`; add it to the \
                     hierarchy table in analysis::locks or allow"
                ),
            }),
            Some((level, name)) => acquisitions.push((dot, line + 1, level, name)),
        }
    }
    for (start, end) in fn_spans(flat) {
        let inside: Vec<_> =
            acquisitions.iter().filter(|a| start <= a.0 && a.0 <= end).collect();
        for pair in inside.windows(2) {
            let (prev, cur) = (pair[0], pair[1]);
            if cur.2 < prev.2 {
                finds.push(Finding {
                    line: cur.1,
                    rule: "lock-order",
                    msg: format!(
                        "{} (level {}) acquired after {} (level {}); the registered \
                         order is PluginCell -> StageCell -> BufferPool -> runtime cache",
                        cur.3, cur.2, prev.3, prev.2
                    ),
                });
            }
        }
    }
    finds
}
