//! Layering rule: every `use crate::…` / `use super::…` edge between
//! top-level modules must appear in the committed allowed-edge table.
//!
//! The table is the DAG from ROADMAP's module map. The `pipeline` module
//! is additionally split into its submodules (sched ← executor ← engine
//! ← session) so the intra-pipeline layering is enforced too; from the
//! outside, `pipeline` is one unit. `util` is the root of the DAG and is
//! always importable; `bin/` and the crate roots see everything.

use std::collections::BTreeSet;

use super::{Finding, Sf};

pub const PIPELINE_SUBS: [&str; 5] = ["sched", "executor", "engine", "session", "sync"];

/// The committed allowed-edge table. An import edge (from, to) not in
/// this list is a layering violation; extending the architecture means
/// extending this table in the same PR, which is the point — the edge
/// becomes a reviewed artifact.
pub const ALLOWED_EDGES: &[(&str, &str)] = &[
    ("backend", "config"),
    ("backend", "model"),
    ("backend", "util"),
    ("backend", "runtime"),
    ("backend", "obs"),
    ("baselines", "backend"),
    ("baselines", "config"),
    ("baselines", "metrics"),
    ("baselines", "model"),
    ("baselines", "ocl"),
    ("baselines", "stream"),
    ("baselines", "util"),
    ("baselines", "compensate"),
    ("baselines", "pipeline"),
    ("baselines", "planner"),
    ("budget", "util"),
    ("budget", "planner"),
    ("compensate", "backend"),
    ("compensate", "config"),
    ("compensate", "model"),
    ("compensate", "util"),
    ("config", "util"),
    ("harness", "backend"),
    ("harness", "baselines"),
    ("harness", "budget"),
    ("harness", "compensate"),
    ("harness", "config"),
    ("harness", "metrics"),
    ("harness", "model"),
    ("harness", "ocl"),
    ("harness", "pipeline"),
    ("harness", "planner"),
    ("harness", "stream"),
    ("harness", "util"),
    ("harness", "obs"),
    ("metrics", "util"),
    ("metrics", "backend"),
    ("metrics", "budget"),
    ("metrics", "config"),
    ("metrics", "model"),
    ("metrics", "stream"),
    ("model", "config"),
    ("model", "util"),
    ("obs", "util"),
    ("obs", "metrics"),
    ("obs", "trace"),
    ("ocl", "backend"),
    ("ocl", "config"),
    ("ocl", "model"),
    ("ocl", "stream"),
    ("ocl", "util"),
    ("planner", "config"),
    ("planner", "model"),
    ("planner", "util"),
    ("planner", "backend"),
    ("runtime", "config"),
    ("runtime", "util"),
    ("stream", "config"),
    ("stream", "util"),
    ("trace", "config"),
    ("trace", "metrics"),
    ("trace", "model"),
    ("trace", "planner"),
    ("trace", "stream"),
    ("trace", "util"),
    ("trace", "budget"),
    ("trace", "compensate"),
    ("trace", "ocl"),
    ("trace", "pipeline"),
    ("trace", "backend"),
    ("analysis", "util"),
    // pipeline internals: strict layering sched <- executor <- engine <- session
    ("pipeline", "backend"),
    ("pipeline", "budget"),
    ("pipeline", "compensate"),
    ("pipeline", "config"),
    ("pipeline", "metrics"),
    ("pipeline", "model"),
    ("pipeline", "obs"),
    ("pipeline", "ocl"),
    ("pipeline", "planner"),
    ("pipeline", "stream"),
    ("pipeline", "util"),
    ("pipeline", "trace"),
    ("pipeline/sched", "pipeline"),
    ("pipeline/executor", "pipeline"),
    ("pipeline/executor", "pipeline/sched"),
    ("pipeline/engine", "pipeline"),
    ("pipeline/engine", "pipeline/sched"),
    ("pipeline/engine", "pipeline/executor"),
    ("pipeline/session", "pipeline"),
    ("pipeline/session", "pipeline/sched"),
    ("pipeline/session", "pipeline/executor"),
    ("pipeline/session", "pipeline/engine"),
    ("pipeline/sync", "pipeline"),
    ("pipeline/sync", "pipeline/sched"),
];

/// Module identity of a source path relative to `src/`. `pipeline/*.rs`
/// files get their own `pipeline/<sub>` identity so the intra-pipeline
/// layering is visible; the crate roots and `bin/` are exempt.
pub fn module_of(path: &str) -> String {
    let p = path.replace('\\', "/");
    if p == "lib.rs" {
        return "lib".to_string();
    }
    if p == "main.rs" || p.starts_with("bin/") {
        return "bin".to_string();
    }
    let top = match p.split_once('/') {
        Some((top, _)) => top.to_string(),
        None => p.strip_suffix(".rs").unwrap_or(&p).to_string(),
    };
    if top == "pipeline" && p != "pipeline/mod.rs" {
        let sub = p.split('/').nth(1).unwrap_or("");
        let sub = sub.strip_suffix(".rs").unwrap_or(sub);
        if PIPELINE_SUBS.contains(&sub) {
            return format!("pipeline/{sub}");
        }
    }
    top
}

/// First path segment of `s`, up to the first `::` or whitespace.
fn first_token(s: &str) -> &str {
    let mut end = s.len();
    if let Some(p) = s.find("::") {
        end = end.min(p);
    }
    if let Some(p) = s.find(char::is_whitespace) {
        end = end.min(p);
    }
    &s[..end]
}

/// Parse `a::b::{c, d}` → (root, first-segments-after-root). Only
/// `crate` and `super` roots matter; external/std imports are free.
fn use_roots(s: &str) -> Option<(String, Vec<String>)> {
    let s = s.trim_end_matches(';').trim();
    let (root, tail) = s.split_once("::")?;
    let root = root.trim();
    if root != "crate" && root != "super" {
        return None;
    }
    let tail = tail.trim();
    if let Some(tail) = tail.strip_prefix('{') {
        let inner = match tail.rfind('}') {
            Some(p) => &tail[..p],
            None => tail,
        };
        let mut segs: Vec<String> = Vec::new();
        let mut depth = 0i32;
        let mut cur = String::new();
        for ch in inner.chars() {
            if ch == '{' {
                depth += 1;
            } else if ch == '}' {
                depth -= 1;
            }
            if ch == ',' && depth == 0 {
                segs.push(std::mem::take(&mut cur));
            } else {
                cur.push(ch);
            }
        }
        segs.push(cur);
        let firsts: Vec<String> = segs
            .iter()
            .map(|seg| first_token(seg.trim()).to_string())
            .filter(|f| !f.is_empty())
            .collect();
        return Some((root.to_string(), firsts));
    }
    let first = first_token(tail);
    if first.is_empty() {
        None
    } else {
        Some((root.to_string(), vec![first.to_string()]))
    }
}

/// Modules referenced by a `use` line of stripped code.
fn use_targets(code_line: &str, from_mod: &str) -> Vec<String> {
    let s = code_line.trim();
    let rest = if let Some(r) = s.strip_prefix("pub use ") {
        r
    } else if let Some(r) = s.strip_prefix("use ") {
        r
    } else {
        return Vec::new();
    };
    let mut out = Vec::new();
    if let Some((root, firsts)) = use_roots(rest) {
        if root == "crate" {
            for seg in firsts {
                if seg == "bail" {
                    // crate::bail is the util::error macro's export point
                    out.push("util".to_string());
                } else {
                    out.push(seg);
                }
            }
        } else if root == "super" && from_mod.starts_with("pipeline") {
            for seg in firsts {
                if PIPELINE_SUBS.contains(&seg.as_str()) {
                    out.push(format!("pipeline/{seg}"));
                } else {
                    out.push("pipeline".to_string());
                }
            }
        }
    }
    out
}

/// Refine `crate::pipeline::<sub>` references anywhere on the line into
/// submodule-precise targets (fully-qualified paths count as edges too).
fn refine_pipeline_subs(line: &str, targets: &mut BTreeSet<String>) {
    const NEEDLE: &str = "crate::pipeline::";
    let mut from = 0usize;
    while let Some(off) = line[from..].find(NEEDLE) {
        let start = from + off + NEEDLE.len();
        let word: String = line[start..]
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        from = start;
        if !word.is_empty() && PIPELINE_SUBS.contains(&word.as_str()) {
            targets.remove("pipeline");
            targets.insert(format!("pipeline/{word}"));
        }
    }
}

pub fn check(path: &str, sf: &Sf) -> Vec<Finding> {
    let module = module_of(path);
    if module == "bin" || module == "lib" {
        return Vec::new();
    }
    let mut finds = Vec::new();
    for (i, line) in sf.lines.iter().enumerate() {
        if sf.test[i] {
            continue;
        }
        let mut targets: BTreeSet<String> = use_targets(line, &module).into_iter().collect();
        refine_pipeline_subs(line, &mut targets);
        for t in &targets {
            let mut t = t.as_str();
            if t == "util" || t == module {
                continue;
            }
            let edge: (String, String) =
                if t.starts_with("pipeline") && module.starts_with("pipeline") {
                    // intra-pipeline: strict sub-layering
                    (module.clone(), t.to_string())
                } else if t.starts_with("pipeline/") {
                    // outsiders see pipeline as one unit
                    t = "pipeline";
                    (module.clone(), "pipeline".to_string())
                } else if module.starts_with("pipeline/") {
                    // submodules inherit pipeline's external edges
                    ("pipeline".to_string(), t.to_string())
                } else {
                    (module.clone(), t.to_string())
                };
            if edge.0 == edge.1 || (edge.1 == "pipeline" && edge.0.starts_with("pipeline/")) {
                continue;
            }
            if !ALLOWED_EDGES.iter().any(|(a, b)| *a == edge.0 && *b == edge.1) {
                finds.push(Finding {
                    line: i + 1,
                    rule: "layering",
                    msg: format!(
                        "module `{module}` must not depend on `{t}` \
                         (edge not in the allowed-edge table)"
                    ),
                });
            }
        }
    }
    finds
}
