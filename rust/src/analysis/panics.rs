//! Panic-freedom rules for the entry surfaces.
//!
//! The session API (`pipeline/{session,engine,executor}.rs`) and the
//! trace reader (`trace/*`) are the two places untrusted input reaches
//! this crate: hand-fed batches and on-disk trace files. A panic there
//! is a caller-visible crash on bad input, so these surfaces must route
//! failures through `util::error` — every `.unwrap()`/`.expect(`/
//! `panic!` is flagged, and in the trace parser so is unchecked
//! indexing. Provably-unreachable cases carry an allow with the proof.

use super::{Finding, Sf};

/// Files forming the push-based session entry surface.
pub const ENTRY_SURFACES: [&str; 3] =
    ["pipeline/session.rs", "pipeline/engine.rs", "pipeline/executor.rs"];

fn word_boundary_before(line: &str, start: usize) -> bool {
    start == 0
        || !line[..start].chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_')
}

fn word_boundary_after(line: &str, end: usize) -> bool {
    !line[end..].chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// Leftmost occurrence of `macro_name!` (word-bounded) followed by
/// optional whitespace and `(`.
fn find_macro(line: &str, name: &str) -> Option<usize> {
    let mut from = 0usize;
    let bang = format!("{name}!");
    while let Some(off) = line[from..].find(&bang) {
        let start = from + off;
        let end = start + bang.len();
        from = end;
        if !word_boundary_before(line, start) {
            continue;
        }
        let after = line[end..].trim_start();
        if after.starts_with('(') {
            return Some(start);
        }
    }
    None
}

/// Leftmost `Type::unwrap` (trailing word boundary).
fn find_fn_path(line: &str, path: &str) -> Option<usize> {
    let mut from = 0usize;
    while let Some(off) = line[from..].find(path) {
        let start = from + off;
        let end = start + path.len();
        from = end;
        if word_boundary_after(line, end) {
            return Some(start);
        }
    }
    None
}

/// Leftmost panicking construct on the line, with its display token.
fn panic_match(line: &str) -> Option<(usize, String)> {
    let mut best: Option<(usize, String)> = None;
    let mut consider = |pos: Option<usize>, tok: &str| {
        if let Some(p) = pos {
            if best.as_ref().map_or(true, |(bp, _)| p < *bp) {
                best = Some((p, tok.to_string()));
            }
        }
    };
    consider(line.find(".unwrap()"), ".unwrap()");
    consider(line.find(".expect("), ".expect");
    consider(find_macro(line, "panic"), "panic!");
    consider(find_macro(line, "unreachable"), "unreachable!");
    consider(find_macro(line, "todo"), "todo!");
    consider(find_macro(line, "unimplemented"), "unimplemented!");
    consider(find_fn_path(line, "Option::unwrap"), "Option::unwrap");
    consider(find_fn_path(line, "Result::unwrap"), "Result::unwrap");
    best
}

/// Any `x[`-style index expression: `[` directly after an identifier
/// character, `)`, or `]`. Array literals and attribute lines don't
/// match; slicing ranges do (they panic on bad bounds all the same).
fn index_match(line: &str) -> bool {
    let b = line.as_bytes();
    for (i, &c) in b.iter().enumerate().skip(1) {
        if c == b'['
            && (b[i - 1].is_ascii_alphanumeric()
                || b[i - 1] == b'_'
                || b[i - 1] == b')'
                || b[i - 1] == b']')
        {
            return true;
        }
    }
    false
}

pub fn check(path: &str, sf: &Sf) -> Vec<Finding> {
    let in_trace = path.starts_with("trace/");
    if !in_trace && !ENTRY_SURFACES.contains(&path) {
        return Vec::new();
    }
    let mut finds = Vec::new();
    for (i, line) in sf.lines.iter().enumerate() {
        if sf.test[i] {
            continue;
        }
        if let Some((_, tok)) = panic_match(line) {
            finds.push(Finding {
                line: i + 1,
                rule: "entry-panic",
                msg: format!(
                    "`{tok}` on a session/trace entry surface; return a typed \
                     util::error::Error or allow with a reachability proof"
                ),
            });
        }
        if in_trace && !line.trim_start().starts_with('#') && index_match(line) {
            finds.push(Finding {
                line: i + 1,
                rule: "entry-index",
                msg: "unchecked indexing while parsing trace input; use .get() or \
                      allow with a bounds proof"
                    .to_string(),
            });
        }
    }
    finds
}
