//! Source preprocessing for the lint rules: comment/string stripping,
//! `#[cfg(test)]` span detection, and the inline allow directive.
//!
//! The linter never parses Rust properly — it classifies each character
//! of a file as *code* or *comment* (string-literal contents are blanked
//! from both) and runs line-oriented rules over the code view. That is
//! deliberately dumb: it keeps the checker dependency-free, fast, and
//! predictable, at the cost of requiring the rules to be conservative.

use std::collections::{BTreeMap, BTreeSet};

use super::Finding;

/// A source file split into two same-shaped views: `code` has comments
/// and string contents blanked to spaces, `comments` has everything
/// *except* comment text blanked. Newlines survive in both, so line
/// numbers line up with the original file.
pub struct Stripped {
    pub code: String,
    pub comments: String,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Blank comments and string-literal contents out of `src`.
///
/// Handles line comments, nested block comments, plain strings with
/// escapes, raw strings (`r"…"` / `r#"…"#` with any hash count), char
/// literals, and lifetimes (left as code). Anything it misclassifies
/// fails safe: a rule sees extra blanks, not phantom code.
pub fn strip(src: &str) -> Stripped {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut code: Vec<char> = s.clone();
    let mut comm: Vec<char> = s.iter().map(|&c| if c == '\n' { '\n' } else { ' ' }).collect();
    let mut i = 0usize;
    while i < n {
        let c = s[i];
        let nxt = if i + 1 < n { s[i + 1] } else { '\0' };
        let prev = if i > 0 { s[i - 1] } else { '\0' };
        if c == '/' && nxt == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                comm[j] = s[j];
                code[j] = ' ';
                j += 1;
            }
            i = j;
        } else if c == '/' && nxt == '*' {
            let mut depth = 0i32;
            let mut j = i;
            while j < n {
                if s[j] == '/' && j + 1 < n && s[j + 1] == '*' {
                    depth += 1;
                    comm[j] = s[j];
                    code[j] = ' ';
                    comm[j + 1] = s[j + 1];
                    code[j + 1] = ' ';
                    j += 2;
                } else if s[j] == '*' && j + 1 < n && s[j + 1] == '/' {
                    depth -= 1;
                    comm[j] = s[j];
                    code[j] = ' ';
                    comm[j + 1] = s[j + 1];
                    code[j + 1] = ' ';
                    j += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    if s[j] != '\n' {
                        comm[j] = s[j];
                        code[j] = ' ';
                    }
                    j += 1;
                }
            }
            i = j;
        } else if c == 'r' && !is_ident(prev) && i + 1 < n && (s[i + 1] == '"' || s[i + 1] == '#')
        {
            // raw string r"…" / r#"…"# (any hash count); blank the interior
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && s[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && s[j] == '"' {
                j += 1;
                let mut end = n;
                let mut k = j;
                while k < n {
                    if s[k] == '"' {
                        let mut h = 0usize;
                        while h < hashes && k + 1 + h < n && s[k + 1 + h] == '#' {
                            h += 1;
                        }
                        if h == hashes {
                            end = k;
                            break;
                        }
                    }
                    k += 1;
                }
                for t in j..end.min(n) {
                    if s[t] != '\n' {
                        code[t] = ' ';
                    }
                }
                i = (end + 1 + hashes).min(n);
            } else {
                i += 1;
            }
        } else if c == '"' {
            let mut j = i + 1;
            while j < n {
                if s[j] == '\\' {
                    code[j] = ' ';
                    if j + 1 < n && s[j + 1] != '\n' {
                        code[j + 1] = ' ';
                    }
                    j += 2;
                } else if s[j] == '"' {
                    break;
                } else {
                    if s[j] != '\n' {
                        code[j] = ' ';
                    }
                    j += 1;
                }
            }
            i = j + 1;
        } else if c == '\'' {
            // char literal vs lifetime
            if nxt == '\\' {
                let mut j = i + 2;
                while j < n && s[j] != '\'' {
                    code[j] = ' ';
                    j += 1;
                }
                if i + 1 < n {
                    code[i + 1] = ' ';
                }
                i = j + 1;
            } else if nxt != '\0' && i + 2 < n && s[i + 2] == '\'' {
                code[i + 1] = ' ';
                i += 3;
            } else if nxt.is_alphabetic() || nxt == '_' {
                // lifetime: skip the label, leave it as code
                let mut j = i + 1;
                while j < n && is_ident(s[j]) {
                    j += 1;
                }
                i = j;
            } else {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    Stripped { code: code.into_iter().collect(), comments: comm.into_iter().collect() }
}

fn line_of(code: &str, byte_off: usize) -> usize {
    code.as_bytes()[..byte_off].iter().filter(|&&b| b == b'\n').count()
}

/// Per-line flags: `true` when the line sits inside a `#[cfg(test)]` or
/// `#[test]` item (from the attribute to the matching close brace). Test
/// code is exempt from every rule — tests are *supposed* to unwrap.
pub fn test_lines(code: &str) -> Vec<bool> {
    let nlines = code.split('\n').count();
    let mut marks = vec![false; nlines];
    let bytes = code.as_bytes();
    for needle in ["#[cfg(test)]", "#[test]"] {
        let mut from = 0usize;
        while let Some(off) = code[from..].find(needle) {
            let start = from + off;
            let mend = start + needle.len();
            from = mend;
            let Some(jrel) = code[mend..].find('{') else { continue };
            let j = mend + jrel;
            let mut depth = 0i32;
            let mut k = j;
            while k < bytes.len() {
                match bytes[k] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            let a = line_of(code, start);
            let b = line_of(code, k.min(bytes.len()));
            for m in marks.iter_mut().take((b + 1).min(nlines)).skip(a) {
                *m = true;
            }
        }
    }
    marks
}

/// Rule-name → set of suppressed 0-based lines, parsed from the allow
/// directives in the comment view.
pub type Suppressions = BTreeMap<String, BTreeSet<usize>>;

const DIRECTIVE: &str = "ferret-lint:";

fn parse_allow(text: &str) -> Option<(Vec<String>, String)> {
    let i = text.find(DIRECTIVE)?;
    let rest = text[i + DIRECTIVE.len()..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let mut tail = rest[close + 1..].trim_start();
    for sep in ["\u{2014}", "--", "-"] {
        if let Some(t) = tail.strip_prefix(sep) {
            tail = t;
            break;
        }
    }
    Some((rules, tail.trim().to_string()))
}

/// Collect allow directives. A trailing comment suppresses its own line;
/// an own-line comment suppresses the next line that carries code
/// (continuation comment lines are skipped). A directive without a
/// reason is itself a finding — the reason is the review artifact.
pub fn allows(comments: &str, code_lines: &[&str]) -> (Suppressions, Vec<Finding>) {
    let mut supp: Suppressions = BTreeMap::new();
    let mut meta = Vec::new();
    for (idx, text) in comments.split('\n').enumerate() {
        let Some((rules, reason)) = parse_allow(text) else { continue };
        if reason.is_empty() {
            meta.push(Finding {
                line: idx + 1,
                rule: "allow-missing-reason",
                msg: "allow directive without a reason".to_string(),
            });
            continue;
        }
        let target = if idx < code_lines.len() && !code_lines[idx].trim().is_empty() {
            idx
        } else {
            let mut t = idx + 1;
            while t < code_lines.len() && code_lines[t].trim().is_empty() {
                t += 1;
            }
            t
        };
        for r in rules {
            let set = supp.entry(r).or_default();
            set.insert(idx);
            set.insert(target);
        }
    }
    (supp, meta)
}
