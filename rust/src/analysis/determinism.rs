//! Determinism rules for the replay-critical core.
//!
//! Everything between ingestion and the trace writer must be a pure
//! function of (config, seed, stream): same inputs, same bytes out, or
//! the record/replay gate and the executor-equivalence tests are
//! meaningless. These rules ban the three classic leaks — randomized
//! iteration order, wall-clock time, and ad-hoc threads/RNGs — from the
//! modules that carry that contract.

use super::{Finding, Sf};

/// Modules under the determinism contract (top-level names).
pub const DET_CORE: [&str; 7] =
    ["planner", "pipeline", "trace", "obs", "metrics", "budget", "stream"];

/// Files allowed to spawn threads: the executor owns all device threads.
const DET_EXEMPT_THREAD: [&str; 1] = ["pipeline/executor.rs"];

/// `needle` present in `line` with non-identifier characters (or line
/// edges) on both sides.
fn word_match(line: &str, needle: &str) -> bool {
    let mut from = 0usize;
    while let Some(off) = line[from..].find(needle) {
        let start = from + off;
        let end = start + needle.len();
        let before_ok = start == 0
            || !line[..start].chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after_ok =
            !line[end..].chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        from = end;
    }
    false
}

/// `rand::` with a word boundary before the `rand` (so `operand::` and
/// `crate::util::rng` stay legal).
fn rand_path(line: &str) -> bool {
    let mut from = 0usize;
    while let Some(off) = line[from..].find("rand::") {
        let start = from + off;
        let before_ok = start == 0
            || !line[..start].chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok {
            return true;
        }
        from = start + "rand::".len();
    }
    false
}

fn top_module(path: &str) -> &str {
    match path.split_once('/') {
        Some((top, _)) => top,
        None => path.strip_suffix(".rs").unwrap_or(path),
    }
}

pub fn check(path: &str, sf: &Sf) -> Vec<Finding> {
    if !DET_CORE.contains(&top_module(path)) {
        return Vec::new();
    }
    let mut finds = Vec::new();
    for (i, line) in sf.lines.iter().enumerate() {
        if sf.test[i] {
            continue;
        }
        if word_match(line, "HashMap") || word_match(line, "HashSet") {
            finds.push(Finding {
                line: i + 1,
                rule: "det-map",
                msg: "HashMap/HashSet in the deterministic core (iteration order is \
                      random; use BTreeMap/Vec or allow with proof of no iteration)"
                    .to_string(),
            });
        }
        if line.contains("Instant::now") || line.contains("SystemTime") {
            finds.push(Finding {
                line: i + 1,
                rule: "det-time",
                msg: "wall-clock time in the deterministic core".to_string(),
            });
        }
        if line.contains("thread::spawn") && !DET_EXEMPT_THREAD.contains(&path) {
            finds.push(Finding {
                line: i + 1,
                rule: "det-thread",
                msg: "thread::spawn in the deterministic core (only the executor owns \
                      threads)"
                    .to_string(),
            });
        }
        if word_match(line, "RandomState")
            || word_match(line, "DefaultHasher")
            || word_match(line, "thread_rng")
            || rand_path(line)
        {
            finds.push(Finding {
                line: i + 1,
                rule: "det-rng",
                msg: "randomness not routed through util::rng".to_string(),
            });
        }
    }
    finds
}
