//! Single-device stream-learning baselines (Table 1):
//! Oracle, 1-Skip, Random-N / Last-N B-Skip, and Camel.
//!
//! These run a sequential trainer over virtual time: one device, one model
//! copy, training a microbatch costs `Σ_i (t̂f_i + t̂b_i)` ticks, and the
//! admission policy decides what happens when data arrives while the
//! device is busy.

pub mod coreset;

use crate::backend::{accuracy, backward_all, forward_all, Backend};
use crate::metrics::{eval_tacc, RunMetrics};
use crate::model::{LiveParams, SharedParams};
use crate::ocl::{OclCtx, OclPlugin};
use crate::pipeline::{EngineParams, RunResult};
use crate::planner::costmodel::single_copy_bytes;
use crate::planner::Profile;
use crate::stream::{Batch, SyntheticStream};
use crate::util::Rng;
use std::sync::Arc;

/// Admission policy of the sequential trainer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamPolicy {
    /// idealized: every batch trained with zero latency (paper's Oracle)
    Oracle,
    /// train if idle, otherwise skip the batch entirely [29]
    OneSkip,
    /// buffer the latest `buf` batches; when idle train a random one
    RandomN { buf: usize },
    /// buffer the latest `buf` batches; when idle train the newest
    LastN { buf: usize },
    /// buffer + coreset selection before each training step [46]
    Camel { buf: usize },
}

impl StreamPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            StreamPolicy::Oracle => "Oracle",
            StreamPolicy::OneSkip => "1-Skip",
            StreamPolicy::RandomN { .. } => "Random-N",
            StreamPolicy::LastN { .. } => "Last-N",
            StreamPolicy::Camel { .. } => "Camel",
        }
    }

    /// Table 1's method list with default buffer sizes.
    pub fn table1() -> Vec<StreamPolicy> {
        vec![
            StreamPolicy::Oracle,
            StreamPolicy::OneSkip,
            StreamPolicy::RandomN { buf: 8 },
            StreamPolicy::LastN { buf: 8 },
            StreamPolicy::Camel { buf: 8 },
        ]
    }
}

pub struct Pending {
    /// microbatch payload
    pub batch: Batch,
    /// virtual arrival time (ticks)
    pub arrival: u64,
}

/// Run a stream baseline to completion.
pub fn run_baseline(
    policy: StreamPolicy,
    stream: &mut SyntheticStream,
    backend: &dyn Backend,
    plugin: &mut dyn OclPlugin,
    ep: &EngineParams,
) -> RunResult {
    let spec = stream.spec().clone();
    let zoo = crate::config::zoo::default_zoo().expect("zoo");
    let model = zoo
        .models
        .values()
        .find(|m| m.features() == spec.features && m.classes() == spec.classes)
        .cloned()
        .unwrap_or_else(|| crate::config::ModelSpec {
            name: "adhoc".into(),
            dims: vec![spec.features, 64, spec.classes],
        });
    run_baseline_with_model(policy, stream, backend, plugin, ep, &model)
}

/// Run with an explicit model spec (the harness always uses this).
pub fn run_baseline_with_model(
    policy: StreamPolicy,
    stream: &mut SyntheticStream,
    backend: &dyn Backend,
    plugin: &mut dyn OclPlugin,
    ep: &EngineParams,
    model: &crate::config::ModelSpec,
) -> RunResult {
    let spec = stream.spec().clone();
    let shapes = model.layers();
    let prof = Profile::analytic(model, spec.batch);
    let td = if ep.td == 0 { prof.default_td() } else { ep.td };
    let t_train: u64 = prof.t_f.iter().sum::<u64>() + prof.t_b.iter().sum::<u64>();
    let mut params = LiveParams::init(model, ep.seed).layers;
    let mut metrics = RunMetrics::default();
    let mut rng = Rng::new(ep.seed ^ 0xBA5E);
    let ctx = OclCtx {
        backend,
        shapes: &shapes,
        classes: spec.classes,
        batch: spec.batch,
        features: spec.features,
    };

    let mut busy_until: u64 = 0;
    let mut buffer: Vec<Pending> = Vec::new();
    let buf_cap = match policy {
        StreamPolicy::RandomN { buf } | StreamPolicy::LastN { buf } | StreamPolicy::Camel { buf } => buf,
        _ => 0,
    };

    let mut plugin_mem_peak = 0usize;
    let test = stream.test_set(ep.tacc_per_class);

    while let Some(batch) = stream.next_batch() {
        let t = batch.id * td;
        metrics.record_arrival();

        // Every arrival is predicted with the live model (oacc).
        let (_, logits) = forward_all(backend, &shapes, &params, &batch.x, batch.y.len());
        metrics.record_prediction(t, accuracy(spec.classes, &logits, &batch.y));

        // Admission.
        let trainable: Option<Pending> = match policy {
            StreamPolicy::Oracle => Some(Pending { batch, arrival: t }),
            StreamPolicy::OneSkip => {
                if busy_until > t {
                    metrics.record_drop();
                    None
                } else {
                    Some(Pending { batch, arrival: t })
                }
            }
            StreamPolicy::RandomN { .. } | StreamPolicy::LastN { .. } | StreamPolicy::Camel { .. } => {
                buffer.push(Pending { batch, arrival: t });
                if buffer.len() > buf_cap {
                    buffer.remove(0);
                    metrics.record_drop();
                }
                if busy_until > t {
                    None
                } else {
                    match policy {
                        StreamPolicy::RandomN { .. } => {
                            let i = rng.below(buffer.len());
                            Some(buffer.remove(i))
                        }
                        StreamPolicy::LastN { .. } => buffer.pop(),
                        StreamPolicy::Camel { .. } => {
                            // coreset-select rows across the whole buffer
                            Some(coreset::select(&mut buffer, spec.batch, spec.features))
                        }
                        _ => unreachable!(),
                    }
                }
            }
        };

        if let Some(pending) = trainable {
            let start = t.max(busy_until);
            let select_cost = match policy {
                // Camel pays a selection pass over the buffer
                StreamPolicy::Camel { buf } => {
                    (buf * spec.batch * spec.features) as u64 / crate::planner::profile::FLOPS_PER_TICK as u64
                }
                _ => 0,
            };
            let done = match policy {
                StreamPolicy::Oracle => start, // idealized zero-latency
                _ => start + t_train + select_cost,
            };
            busy_until = done;
            train_step(
                backend,
                &shapes,
                &mut params,
                plugin,
                &ctx,
                pending,
                done,
                ep,
                ep.decay(td),
                &mut metrics,
            );
            plugin_mem_peak = plugin_mem_peak.max(plugin.memory_bytes());
        }
        let buffer_bytes = buffer.len() * (spec.batch * spec.features * 4 + spec.batch * 4);
        metrics.observe_live_bytes(buffer_bytes);
    }

    // memory: one model copy (+grads+acts) + buffer + plugin state
    let buffer_bytes = buf_cap * (spec.batch * spec.features * 4 + spec.batch * 4);
    metrics.mem_bytes = single_copy_bytes(&prof) + buffer_bytes as f64 + plugin_mem_peak as f64;
    metrics.tacc = eval_tacc(backend, &shapes, &params, spec.classes, &test, spec.batch);
    RunResult { metrics, params }
}

#[allow(clippy::too_many_arguments)]
fn train_step(
    backend: &dyn Backend,
    shapes: &[crate::config::LayerShape],
    params: &mut Vec<SharedParams>,
    plugin: &mut dyn OclPlugin,
    ctx: &OclCtx,
    pending: Pending,
    done: u64,
    ep: &EngineParams,
    decay: f64,
    metrics: &mut RunMetrics,
) {
    let batch = plugin.augment(pending.batch, params, ctx);
    let (inputs, logits) = forward_all(backend, shapes, params, &batch.x, batch.y.len());
    let (gl, loss) = plugin.loss_grad(&logits, &batch.y, &batch.x, ctx);
    let mut grads = backward_all(backend, shapes, params, &inputs, &gl, batch.y.len());
    for (i, (g, p)) in grads.iter_mut().zip(params.iter()).enumerate() {
        plugin.adjust_layer_grad(i, g, p, ctx);
    }
    for (p, g) in params.iter_mut().zip(&grads) {
        *p = Arc::new(backend.sgd(p, g, ep.lr));
    }
    plugin.after_update(params, ctx);
    metrics.record_loss(done, loss);
    metrics.record_update(done - pending.arrival, decay, 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::ocl::Vanilla;
    use crate::stream::{DriftKind, StreamSpec};

    fn mk_stream(n: usize) -> SyntheticStream {
        SyntheticStream::new(StreamSpec {
            name: "t".into(),
            features: 16,
            classes: 4,
            batch: 8,
            num_batches: n,
            kind: DriftKind::Stationary,
            margin: 3.0,
            noise: 0.5,
            seed: 11,
        })
    }

    fn model() -> crate::config::ModelSpec {
        crate::config::ModelSpec { name: "t".into(), dims: vec![16, 32, 4] }
    }

    fn run(policy: StreamPolicy, n: usize) -> RunResult {
        let mut stream = mk_stream(n);
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        run_baseline_with_model(policy, &mut stream, &NativeBackend, &mut Vanilla, &ep, &model())
    }

    #[test]
    fn oracle_learns_separable_stream() {
        let r = run(StreamPolicy::Oracle, 150);
        assert!(r.metrics.oacc.value() > 60.0, "oacc {}", r.metrics.oacc.value());
        assert!(r.metrics.tacc > 80.0, "tacc {}", r.metrics.tacc);
        assert_eq!(r.metrics.trained, 150);
        assert_eq!(r.metrics.dropped, 0);
        // zero-latency updates: adaptation rate = 1
        assert!((r.metrics.adaptation_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn one_skip_drops_and_underperforms_oracle() {
        let oracle = run(StreamPolicy::Oracle, 150);
        let skip = run(StreamPolicy::OneSkip, 150);
        assert!(skip.metrics.dropped > 0, "training is slower than arrival");
        assert!(skip.metrics.trained < 150);
        assert!(skip.metrics.oacc.value() <= oracle.metrics.oacc.value() + 1.0);
        assert!(skip.metrics.adaptation_rate() < oracle.metrics.adaptation_rate());
    }

    #[test]
    fn buffered_policies_train_more_batches_than_one_skip() {
        let skip = run(StreamPolicy::OneSkip, 150);
        for p in [StreamPolicy::RandomN { buf: 8 }, StreamPolicy::LastN { buf: 8 }] {
            let r = run(p, 150);
            // buffered policies use more memory...
            assert!(r.metrics.mem_bytes > skip.metrics.mem_bytes);
            // ...and train whenever idle (same cadence), but never starve
            assert!(r.metrics.trained >= skip.metrics.trained);
        }
    }

    #[test]
    fn camel_trains_and_pays_selection_latency() {
        let camel = run(StreamPolicy::Camel { buf: 8 }, 150);
        let skip = run(StreamPolicy::OneSkip, 150);
        assert!(camel.metrics.trained > 0);
        // selection cost lowers the measured adaptation rate per update
        assert!(camel.metrics.mem_bytes > skip.metrics.mem_bytes);
        assert!(camel.metrics.oacc.value() > 30.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(StreamPolicy::RandomN { buf: 8 }, 60);
        let b = run(StreamPolicy::RandomN { buf: 8 }, 60);
        assert_eq!(a.metrics.oacc.value(), b.metrics.oacc.value());
        assert_eq!(a.params[0].w, b.params[0].w);
    }

    #[test]
    fn ocl_plugins_run_through_baseline_engine() {
        use crate::ocl::OclKind;
        for kind in OclKind::all() {
            let mut stream = mk_stream(40);
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            let mut plugin = kind.build(3);
            let r = run_baseline_with_model(
                StreamPolicy::Oracle,
                &mut stream,
                &NativeBackend,
                plugin.as_mut(),
                &ep,
                &model(),
            );
            assert!(r.metrics.trained > 0, "{}", kind.name());
            assert!(r.metrics.oacc.value() > 25.0, "{} oacc {}", kind.name(), r.metrics.oacc.value());
        }
    }
}
