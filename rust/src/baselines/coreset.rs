//! Greedy k-center coreset selection (the Camel baseline's sampler [46]).
//!
//! Selects a maximally diverse row subset across the buffered microbatches
//! (farthest-point traversal over raw feature space) and assembles them
//! into one training batch.

use super::Pending;
use crate::stream::Batch;

fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Pick `rows` diverse samples across `buffer` and assemble a batch.
/// The earliest contributing arrival is used as the batch's arrival (the
/// conservative latency accounting for the adaptation-rate metric).
pub fn select(buffer: &mut Vec<Pending>, rows: usize, features: usize) -> Pending {
    // flatten candidate rows
    let mut cand: Vec<(usize, usize)> = Vec::new(); // (buffer idx, row idx)
    for (bi, p) in buffer.iter().enumerate() {
        for ri in 0..p.batch.y.len() {
            cand.push((bi, ri));
        }
    }
    assert!(!cand.is_empty());
    let row = |&(bi, ri): &(usize, usize)| -> &[f32] {
        &buffer[bi].batch.x[ri * features..(ri + 1) * features]
    };

    // farthest-point greedy: start from the newest row
    let mut picked: Vec<(usize, usize)> = vec![*cand.last().unwrap()];
    let mut dists: Vec<f32> = cand.iter().map(|c| dist2(row(c), row(&picked[0]))).collect();
    while picked.len() < rows.min(cand.len()) {
        let (best, _) = dists
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let chosen = cand[best];
        picked.push(chosen);
        for (i, c) in cand.iter().enumerate() {
            dists[i] = dists[i].min(dist2(row(c), row(&chosen)));
        }
    }

    // assemble (repeat picks if the buffer has fewer rows than `rows`)
    let mut x = Vec::with_capacity(rows * features);
    let mut y = Vec::with_capacity(rows);
    let mut arrival = u64::MAX;
    let newest_id = buffer.last().unwrap().batch.id;
    for i in 0..rows {
        let (bi, ri) = picked[i % picked.len()];
        x.extend_from_slice(&buffer[bi].batch.x[ri * features..(ri + 1) * features]);
        y.push(buffer[bi].batch.y[ri]);
        arrival = arrival.min(buffer[bi].arrival);
    }
    // consume the newest batch slot (Camel keeps the rest buffered)
    buffer.pop();
    Pending { batch: Batch { id: newest_id, x, y }, arrival }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(id: u64, arrival: u64, xs: Vec<f32>, ys: Vec<i32>) -> Pending {
        Pending { batch: Batch { id, x: xs, y: ys }, arrival }
    }

    #[test]
    fn selects_diverse_rows() {
        // two tight clusters; a 2-row selection must take one from each
        let mut buf = vec![
            pend(0, 0, vec![0.0, 0.0, 0.01, 0.0], vec![0, 0]),
            pend(1, 10, vec![5.0, 5.0, 5.01, 5.0], vec![1, 1]),
        ];
        let p = select(&mut buf, 2, 2);
        let labels: std::collections::BTreeSet<i32> = p.batch.y.iter().copied().collect();
        assert_eq!(labels.len(), 2, "picked from both clusters: {:?}", p.batch.y);
        assert_eq!(p.arrival, 0, "earliest contributing arrival");
        assert_eq!(buf.len(), 1, "newest slot consumed");
    }

    #[test]
    fn handles_fewer_rows_than_requested() {
        let mut buf = vec![pend(0, 3, vec![1.0, 2.0], vec![7])];
        let p = select(&mut buf, 4, 2);
        assert_eq!(p.batch.y, vec![7, 7, 7, 7]);
        assert_eq!(p.batch.x.len(), 8);
    }
}
