//! Stale-gradient compensation policies (paper §5.1.2 + Table 4 baselines).
//!
//! A gradient computed against stage version `v` lands when the live stage
//! is at version `v + τ`. Each policy transforms `(grad, lr)` given the
//! staleness context:
//!   - `NoComp`     — apply raw (Pipedream-style zero-order assumption)
//!   - `StepAware`  — shrink the step 1/(1+τ) [33, 41]
//!   - `GapAware`   — shrink by the parameter-gap ratio [7]
//!   - `Fisher`     — one Taylor/diagonal-Fisher step over the whole jump
//!     Δθ = θ_{v+τ} − θ_v with fixed λ [14, 85]
//!   - `IterFisher` — the paper's contribution: apply the approximator
//!     A(g, Δθ; λ) = g + λ·g⊙g⊙Δθ once per *consecutive* version step
//!     (Eq. 9 / Alg. 1), with λ auto-tuned online from EMA statistics
//!     (Eq. 10–12).

use crate::backend::Backend;
use crate::model::GradBuf;

/// Which compensation policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompKind {
    NoComp,
    StepAware,
    GapAware,
    Fisher,
    IterFisher,
}

impl CompKind {
    pub fn name(&self) -> &'static str {
        match self {
            CompKind::NoComp => "None",
            CompKind::StepAware => "Step-Aware",
            CompKind::GapAware => "Gap-Aware",
            CompKind::Fisher => "Fisher",
            CompKind::IterFisher => "Iter-Fisher",
        }
    }

    pub fn all() -> [CompKind; 5] {
        [
            CompKind::NoComp,
            CompKind::StepAware,
            CompKind::GapAware,
            CompKind::Fisher,
            CompKind::IterFisher,
        ]
    }
}

/// Staleness context handed to the policy at update time.
pub struct CompContext<'a> {
    pub backend: &'a dyn Backend,
    /// staleness in version steps (0 = fresh)
    pub tau: u64,
    /// consecutive version deltas Δθ^{v→v+1}, oldest first (len == tau
    /// when the stash still holds the chain; may be shorter after
    /// eviction, in which case the remainder is covered by `jump`)
    pub chain: &'a [GradBuf],
    /// single jump Δθ = θ_now − θ_fwd
    pub jump: Option<&'a GradBuf>,
    /// learning rate the update will use (Gap-Aware normalization)
    pub lr: f32,
}

/// A compensation policy instance (one per worker-stage slot; IterFisher
/// keeps per-slot EMA state).
pub trait Compensator: Send {
    /// Returns the compensated gradient and an lr scale factor.
    fn compensate(&mut self, grad: GradBuf, ctx: &CompContext) -> (GradBuf, f32);

    /// Extra state bytes held (for the memory model; Alg. 1 notes the
    /// O(2 Σ|w|) cost of v_r/v_a when λ is being optimized).
    fn state_bytes(&self) -> usize {
        0
    }

    /// Whether the policy consumes the parameter-delta chain/jump. The
    /// engine skips materializing them (τ vector clones per update)
    /// when false — the PipeDream/NoComp hot path.
    fn needs_deltas(&self) -> bool {
        true
    }
}

/// Hyper-parameters (paper §12: λ=0.2, ν=2e-6 for Iter-Fisher).
#[derive(Debug, Clone, Copy)]
pub struct CompParams {
    pub lam0: f32,
    /// λ learning rate η_λ; 0 disables auto-tuning (and the EMA buffers)
    pub eta_lam: f32,
    /// EMA coefficient α (Eq. 11)
    pub alpha: f32,
    /// ℓ2 regularizer ν on λ (Eq. 10)
    pub nu: f32,
}

impl Default for CompParams {
    fn default() -> Self {
        CompParams { lam0: 0.2, eta_lam: 1e-3, alpha: 0.9, nu: 2e-6 }
    }
}

pub fn make(kind: CompKind, params: CompParams) -> Box<dyn Compensator> {
    match kind {
        CompKind::NoComp => Box::new(NoComp),
        CompKind::StepAware => Box::new(StepAware),
        CompKind::GapAware => Box::new(GapAware),
        CompKind::Fisher => Box::new(Fisher { lam: params.lam0 }),
        CompKind::IterFisher => Box::new(IterFisher::new(params)),
    }
}

// ---------------------------------------------------------------------
// Baselines
// ---------------------------------------------------------------------

struct NoComp;

impl Compensator for NoComp {
    fn compensate(&mut self, grad: GradBuf, _ctx: &CompContext) -> (GradBuf, f32) {
        (grad, 1.0)
    }

    fn needs_deltas(&self) -> bool {
        false
    }
}

struct StepAware;

impl Compensator for StepAware {
    fn compensate(&mut self, grad: GradBuf, ctx: &CompContext) -> (GradBuf, f32) {
        (grad, 1.0 / (1.0 + ctx.tau as f32))
    }

    fn needs_deltas(&self) -> bool {
        false
    }
}

struct GapAware;

impl Compensator for GapAware {
    fn compensate(&mut self, grad: GradBuf, ctx: &CompContext) -> (GradBuf, f32) {
        // gap = ||θ_now − θ_fwd|| normalized by the typical step ||lr·g||;
        // penalize the step by 1/(1+gap) (Barkai et al.'s per-update form).
        let scale = match ctx.jump {
            Some(jump) if ctx.tau > 0 => {
                let gap = jump.norm2().sqrt();
                let step = (ctx.lr as f64) * grad.norm2().sqrt().max(1e-12);
                1.0 / (1.0 + (gap / step.max(1e-12)) as f32 / (1.0 + ctx.tau as f32))
            }
            _ => 1.0,
        };
        (grad, scale)
    }
}

struct Fisher {
    lam: f32,
}

impl Compensator for Fisher {
    fn compensate(&mut self, grad: GradBuf, ctx: &CompContext) -> (GradBuf, f32) {
        match ctx.jump {
            Some(jump) if ctx.tau > 0 => (ctx.backend.compensate(&grad, jump, self.lam), 1.0),
            _ => (grad, 1.0),
        }
    }
}

// ---------------------------------------------------------------------
// Iter-Fisher (the paper's algorithm)
// ---------------------------------------------------------------------

struct IterFisher {
    params: CompParams,
    lam: f32,
    /// v_r: EMA of observed gradients (Alg. 1)
    v_r: Option<GradBuf>,
    /// v_a: EMA of g⊙g⊙Δθ
    v_a: Option<GradBuf>,
}

/// f64 lanes for the λ-tuning reduction: four independent chains folded
/// by a fixed pairwise tree (the scalar chain is latency-bound on long
/// parameter vectors). Deterministic — the schedule depends only on the
/// slice length.
const LAM_LANES: usize = 4;

/// One slice's contribution to the λ gradient statistics:
/// `(Σ v_a·(Δv_r − λ·v_a), Σ v_a²)` with `Δv_r = (1-α)(g − v_r)`.
fn lam_stats(v_r: &[f32], g: &[f32], v_a: &[f32], a: f32, lam: f32) -> (f64, f64) {
    let mut dots = [0.0f64; LAM_LANES];
    let mut norms = [0.0f64; LAM_LANES];
    let blocks = v_r.len() / LAM_LANES * LAM_LANES;
    let mut i = 0;
    while i < blocks {
        for l in 0..LAM_LANES {
            let va = v_a[i + l] as f64;
            let dvr = ((1.0 - a) * (g[i + l] - v_r[i + l])) as f64;
            dots[l] += va * (dvr - lam as f64 * va);
            norms[l] += va * va;
        }
        i += LAM_LANES;
    }
    for i in blocks..v_r.len() {
        let va = v_a[i] as f64;
        let dvr = ((1.0 - a) * (g[i] - v_r[i])) as f64;
        dots[0] += va * (dvr - lam as f64 * va);
        norms[0] += va * va;
    }
    (
        (dots[0] + dots[1]) + (dots[2] + dots[3]),
        (norms[0] + norms[1]) + (norms[2] + norms[3]),
    )
}

/// EMA step `e = α·e + (1-α)·obs` (Eq. 11); pure map, autovectorizes.
fn ema_in(ema: &mut [f32], obs: &[f32], a: f32) {
    for (e, &o) in ema.iter_mut().zip(obs) {
        *e = a * *e + (1.0 - a) * o;
    }
}

/// EMA step over the fused observation `g⊙g⊙Δθ` — computed in-loop so no
/// observation buffer is ever materialized.
fn ema_in_ggd(ema: &mut [f32], g: &[f32], d: &[f32], a: f32) {
    for ((e, &gv), &dv) in ema.iter_mut().zip(g).zip(d) {
        *e = a * *e + (1.0 - a) * (gv * gv * dv);
    }
}

impl IterFisher {
    fn new(params: CompParams) -> Self {
        IterFisher { params, lam: params.lam0, v_r: None, v_a: None }
    }

    /// Alg. 1 lines 3–7: update λ from the one-step approximation error.
    fn tune_lambda(&mut self, grad: &GradBuf, first_delta: &GradBuf) {
        let a = self.params.alpha;
        if self.v_r.is_none() {
            self.v_r = Some(GradBuf {
                gw: vec![0.0; grad.gw.len()],
                gb: vec![0.0; grad.gb.len()],
            });
            self.v_a = Some(GradBuf {
                gw: vec![0.0; grad.gw.len()],
                gb: vec![0.0; grad.gb.len()],
            });
        }
        let v_r = self.v_r.as_mut().unwrap();
        let v_a = self.v_a.as_mut().unwrap();
        // Δv_r = (1-α)(g − v_r); ∇_λ ||Δv_r − λ v_a||² = −2 v_aᵀ(Δv_r − λ v_a)
        // (+ 2νλ from the ℓ2 term of Eq. 10)
        let (dw, nw) = lam_stats(&v_r.gw, &grad.gw, &v_a.gw, a, self.lam);
        let (db, nb) = lam_stats(&v_r.gb, &grad.gb, &v_a.gb, a, self.lam);
        let (dot, va_norm2) = (dw + db, nw + nb);
        let grad_lam = -2.0 * dot + 2.0 * self.params.nu as f64 * self.lam as f64;
        // normalized step keeps tuning stable across parameter scales
        let step = self.params.eta_lam as f64 * grad_lam / (1.0 + va_norm2);
        self.lam = (self.lam as f64 - step).clamp(0.0, 2.0) as f32;
        // EMA updates (Eq. 11); v_a observes g⊙g⊙Δθ for the first
        // version step, fused so nothing is allocated per update
        ema_in(&mut v_r.gw, &grad.gw, a);
        ema_in(&mut v_r.gb, &grad.gb, a);
        ema_in_ggd(&mut v_a.gw, &grad.gw, &first_delta.gw, a);
        ema_in_ggd(&mut v_a.gb, &grad.gb, &first_delta.gb, a);
    }
}

impl Compensator for IterFisher {
    fn compensate(&mut self, grad: GradBuf, ctx: &CompContext) -> (GradBuf, f32) {
        if ctx.tau == 0 {
            return (grad, 1.0);
        }
        if self.params.eta_lam > 0.0 {
            if let Some(first) = ctx.chain.first() {
                self.tune_lambda(&grad, first);
            }
        }
        // Eq. 9: iterate A over consecutive version deltas.
        let mut g = grad;
        for delta in ctx.chain {
            g = ctx.backend.compensate(&g, delta, self.lam);
        }
        // chain shorter than tau (stash eviction): cover the remainder
        // with one jump application — still strictly better than nothing.
        if (ctx.chain.len() as u64) < ctx.tau {
            if let Some(jump) = ctx.jump {
                g = ctx.backend.compensate(&g, jump, self.lam);
            }
        }
        (g, 1.0)
    }

    fn state_bytes(&self) -> usize {
        // Alg. 1: two extra buffers when λ is being optimized
        match (&self.v_r, self.params.eta_lam > 0.0) {
            (Some(v), true) => 2 * (v.gw.len() + v.gb.len()) * 4,
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;

    fn g(vals: &[f32]) -> GradBuf {
        GradBuf { gw: vals.to_vec(), gb: vec![0.1] }
    }

    fn ctx<'a>(
        backend: &'a NativeBackend,
        tau: u64,
        chain: &'a [GradBuf],
        jump: Option<&'a GradBuf>,
    ) -> CompContext<'a> {
        CompContext { backend, tau, chain, jump, lr: 0.1 }
    }

    #[test]
    fn fresh_gradients_untouched_by_all_policies() {
        let be = NativeBackend;
        for kind in CompKind::all() {
            let mut c = make(kind, CompParams::default());
            let (out, scale) = c.compensate(g(&[1.0, -2.0]), &ctx(&be, 0, &[], None));
            assert_eq!(out.gw, vec![1.0, -2.0], "{}", kind.name());
            assert_eq!(scale, 1.0, "{}", kind.name());
        }
    }

    #[test]
    fn step_aware_shrinks_with_tau() {
        let be = NativeBackend;
        let mut c = make(CompKind::StepAware, CompParams::default());
        let (_, s1) = c.compensate(g(&[1.0]), &ctx(&be, 1, &[], None));
        let (_, s3) = c.compensate(g(&[1.0]), &ctx(&be, 3, &[], None));
        assert_eq!(s1, 0.5);
        assert_eq!(s3, 0.25);
    }

    #[test]
    fn gap_aware_penalizes_large_gaps() {
        let be = NativeBackend;
        let mut c = make(CompKind::GapAware, CompParams::default());
        let small = g(&[0.01, 0.01]);
        let large = g(&[10.0, 10.0]);
        let (_, s_small) = c.compensate(g(&[1.0, 1.0]), &ctx(&be, 2, &[], Some(&small)));
        let (_, s_large) = c.compensate(g(&[1.0, 1.0]), &ctx(&be, 2, &[], Some(&large)));
        assert!(s_small > s_large, "{s_small} <= {s_large}");
        assert!(s_large < 1.0 && s_small <= 1.0);
    }

    #[test]
    fn fisher_single_jump_matches_eq8() {
        let be = NativeBackend;
        let mut c = make(CompKind::Fisher, CompParams { lam0: 0.5, ..Default::default() });
        let jump = g(&[0.2, -0.2]);
        let (out, s) = c.compensate(g(&[2.0, 1.0]), &ctx(&be, 2, &[], Some(&jump)));
        assert_eq!(s, 1.0);
        assert!((out.gw[0] - (2.0 + 0.5 * 4.0 * 0.2)).abs() < 1e-6);
        assert!((out.gw[1] - (1.0 + 0.5 * 1.0 * -0.2)).abs() < 1e-6);
    }

    #[test]
    fn iter_fisher_applies_chain_iteratively() {
        let be = NativeBackend;
        let params = CompParams { lam0: 0.5, eta_lam: 0.0, ..Default::default() };
        let mut c = make(CompKind::IterFisher, params);
        let chain = vec![g(&[0.1, 0.0]), g(&[0.2, 0.0])];
        let (out, _) = c.compensate(g(&[1.0, 1.0]), &ctx(&be, 2, &chain, None));
        // manual: g1 = 1 + .5*1*0.1 = 1.05; g2 = 1.05 + .5*1.05^2*0.2
        let g1 = 1.0f32 + 0.5 * 1.0 * 0.1;
        let g2 = g1 + 0.5 * g1 * g1 * 0.2;
        assert!((out.gw[0] - g2).abs() < 1e-6, "{} vs {g2}", out.gw[0]);
        assert_eq!(out.gw[1], 1.0, "zero deltas leave grad unchanged");
        // eta=0: no EMA state allocated
        assert_eq!(c.state_bytes(), 0);
    }

    #[test]
    fn iter_fisher_lambda_tuning_allocates_state_and_stays_bounded() {
        let be = NativeBackend;
        let params = CompParams { lam0: 0.2, eta_lam: 1e-2, ..Default::default() };
        let mut c = IterFisher::new(params);
        let chain = vec![g(&[0.05, -0.05])];
        for i in 0..50 {
            let gr = g(&[1.0 + 0.01 * i as f32, -1.0]);
            let cx = ctx(&be, 1, &chain, None);
            let _ = c.compensate(gr, &cx);
        }
        assert!(c.lam >= 0.0 && c.lam <= 2.0, "λ = {}", c.lam);
        assert!(c.state_bytes() > 0);
    }

    /// Iter-Fisher compensation reduces the true staleness error on a
    /// quadratic model where the Hessian is exactly diagonal.
    #[test]
    fn iter_fisher_reduces_staleness_error_on_quadratic() {
        // loss L(θ) = 0.5 Σ h_i (θ_i - t_i)^2, ∇L = h ⊙ (θ - t).
        // True Hessian diag = h; Fisher approx λ g⊙g stands in for it —
        // pick data scale where g ≈ O(1) so λ g⊙g ≈ h with λ = h.
        let be = NativeBackend;
        let h = [1.0f32, 1.0];
        let t = [0.0f32, 0.0];
        let grad_at = |th: &[f32; 2]| g(&[h[0] * (th[0] - t[0]), h[1] * (th[1] - t[1])]);
        let theta_old = [1.0f32, -1.0];
        let theta_new = [0.8f32, -0.7];
        let stale = grad_at(&theta_old);
        let fresh = grad_at(&theta_new);
        let delta = g(&[theta_new[0] - theta_old[0], theta_new[1] - theta_old[1]]);
        let chain = vec![delta];
        let params = CompParams { lam0: 1.0, eta_lam: 0.0, ..Default::default() };
        let mut c = make(CompKind::IterFisher, params);
        let (comp, _) = c.compensate(stale.clone(), &ctx(&be, 1, &chain, None));
        let err_raw: f32 = stale.gw.iter().zip(&fresh.gw).map(|(a, b)| (a - b).abs()).sum();
        let err_comp: f32 = comp.gw.iter().zip(&fresh.gw).map(|(a, b)| (a - b).abs()).sum();
        assert!(err_comp < err_raw, "comp {err_comp} !< raw {err_raw}");
    }
}
