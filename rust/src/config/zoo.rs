//! Model-zoo config parser for `configs/models.cfg`.
//!
//! The same file is parsed by `python/compile/zoo.py` to emit the artifact
//! set, so artifact names derived here (`runtime::artifact_names`) always
//! agree with what `make artifacts` produced.

use crate::util::error::{bail, Context, Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Layer activation. Hidden layers are ReLU; the final layer emits logits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Act {
    Relu,
    None,
}

impl Act {
    pub fn as_str(&self) -> &'static str {
        match self {
            Act::Relu => "relu",
            Act::None => "none",
        }
    }
}

/// One dense layer's static shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LayerShape {
    pub in_dim: usize,
    pub out_dim: usize,
    pub act: Act,
}

impl LayerShape {
    /// Number of parameters (weights + bias).
    pub fn param_count(&self) -> usize {
        self.in_dim * self.out_dim + self.out_dim
    }

    /// Output activation size per sample.
    pub fn act_count(&self) -> usize {
        self.out_dim
    }

    /// FLOPs of one forward pass at batch `b` (2*B*K*N matmul + bias/act).
    pub fn fwd_flops(&self, b: usize) -> u64 {
        (2 * b * self.in_dim * self.out_dim + 2 * b * self.out_dim) as u64
    }

    /// FLOPs of one backward pass at batch `b` (dX and dW matmuls).
    pub fn bwd_flops(&self, b: usize) -> u64 {
        2 * self.fwd_flops(b)
    }
}

/// A model from the zoo: a dense stack d0 -> d1 -> ... -> dk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub dims: Vec<usize>,
}

impl ModelSpec {
    pub fn features(&self) -> usize {
        self.dims[0]
    }

    pub fn classes(&self) -> usize {
        *self.dims.last().unwrap()
    }

    pub fn num_layers(&self) -> usize {
        self.dims.len() - 1
    }

    pub fn layers(&self) -> Vec<LayerShape> {
        let last = self.dims.len() - 2;
        (0..self.dims.len() - 1)
            .map(|i| LayerShape {
                in_dim: self.dims[i],
                out_dim: self.dims[i + 1],
                act: if i == last { Act::None } else { Act::Relu },
            })
            .collect()
    }

    pub fn param_count(&self) -> usize {
        self.layers().iter().map(|l| l.param_count()).sum()
    }
}

/// Parsed zoo: microbatch size + named models.
#[derive(Debug, Clone)]
pub struct Zoo {
    pub batch: usize,
    pub models: BTreeMap<String, ModelSpec>,
}

impl Zoo {
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .with_context(|| format!("unknown model {name:?} (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Distinct layer shapes across the zoo (artifact enumeration order is
    /// irrelevant; this is a set).
    pub fn distinct_layer_shapes(&self) -> Vec<LayerShape> {
        let mut set: Vec<LayerShape> = Vec::new();
        for m in self.models.values() {
            for l in m.layers() {
                if !set.contains(&l) {
                    set.push(l);
                }
            }
        }
        set
    }

    pub fn distinct_class_counts(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.models.values().map(|m| m.classes()).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Parse `models.cfg` text.
pub fn parse_zoo(text: &str, origin: &str) -> Result<Zoo> {
    let mut batch = None;
    let mut models = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        match parts[0] {
            "batch" => {
                if parts.len() != 2 {
                    bail!("{origin}:{}: batch takes one int", lineno + 1);
                }
                batch = Some(parts[1].parse::<usize>()?);
            }
            "model" => {
                if parts.len() < 4 {
                    bail!("{origin}:{}: model needs a name and >=2 dims", lineno + 1);
                }
                let name = parts[1].to_string();
                let dims = parts[2..]
                    .iter()
                    .map(|p| p.parse::<usize>().map_err(Error::from))
                    .collect::<Result<Vec<_>>>()?;
                if dims.iter().any(|&d| d == 0) {
                    bail!("{origin}:{}: dims must be positive", lineno + 1);
                }
                if models.insert(name.clone(), ModelSpec { name, dims }).is_some() {
                    bail!("{origin}:{}: duplicate model", lineno + 1);
                }
            }
            other => bail!("{origin}:{}: unknown directive {other:?}", lineno + 1),
        }
    }
    let batch = batch.with_context(|| format!("{origin}: missing 'batch'"))?;
    if models.is_empty() {
        bail!("{origin}: no models");
    }
    Ok(Zoo { batch, models })
}

/// Load the zoo from a path (default: `configs/models.cfg` under the repo
/// root, located relative to the executable's CWD).
pub fn load_zoo(path: &Path) -> Result<Zoo> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading zoo config {}", path.display()))?;
    parse_zoo(&text, &path.display().to_string())
}

/// Default path used by binaries/tests (relative to repo root).
pub fn default_zoo() -> Result<Zoo> {
    load_zoo(Path::new(&crate::config::repo_path("configs/models.cfg")))
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = "batch 4\nmodel tiny 6 5 3\nmodel deep 8 8 8 8 2\n";

    #[test]
    fn parses_models_and_layers() {
        let zoo = parse_zoo(TINY, "test").unwrap();
        assert_eq!(zoo.batch, 4);
        let tiny = zoo.model("tiny").unwrap();
        let layers = tiny.layers();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0], LayerShape { in_dim: 6, out_dim: 5, act: Act::Relu });
        assert_eq!(layers[1], LayerShape { in_dim: 5, out_dim: 3, act: Act::None });
        assert_eq!(tiny.param_count(), 6 * 5 + 5 + 5 * 3 + 3);
        assert_eq!(tiny.classes(), 3);
        assert_eq!(tiny.features(), 6);
    }

    #[test]
    fn distinct_shapes_dedup() {
        let zoo = parse_zoo(TINY, "test").unwrap();
        let shapes = zoo.distinct_layer_shapes();
        // deep has 3 identical relu 8x8 layers -> deduped to one
        assert_eq!(
            shapes.len(),
            2 /* tiny */ + 2, /* deep: 8x8 relu, 8x2 none */
        );
        assert_eq!(zoo.distinct_class_counts(), vec![2, 3]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_zoo("model a 4 2 3\n", "t").is_err()); // no batch
        assert!(parse_zoo("batch 4\nmodel a 5\n", "t").is_err()); // one dim
        assert!(parse_zoo("batch 4\nmodel a 4 0 3\n", "t").is_err()); // zero dim
        assert!(parse_zoo("batch 4\nwat 1\n", "t").is_err()); // bad directive
        assert!(parse_zoo("batch 4\nmodel a 4 3\nmodel a 4 3\n", "t").is_err()); // dup
        assert!(parse_zoo("batch 4\n", "t").is_err()); // no models
    }

    #[test]
    fn real_config_parses_and_matches_python_expectations() {
        let zoo = default_zoo().unwrap();
        assert_eq!(zoo.batch, 16);
        assert!(zoo.models.len() >= 10);
        // the paper's model tiers are all present
        for name in ["mlp", "mnistnet10", "convnet10", "resnet11", "mobilenet11"] {
            assert!(zoo.models.contains_key(name), "{name}");
        }
    }

    #[test]
    fn flops_monotone_in_batch() {
        let l = LayerShape { in_dim: 8, out_dim: 4, act: Act::Relu };
        assert!(l.fwd_flops(2) < l.fwd_flops(4));
        assert_eq!(l.bwd_flops(2), 2 * l.fwd_flops(2));
    }
}
