//! Configuration: the shared model zoo (`zoo`) and repo-root resolution so
//! binaries, tests, and examples find `configs/` and `artifacts/` no matter
//! which working directory cargo launches them from.

pub mod zoo;

pub use zoo::{Act, LayerShape, ModelSpec, Zoo};

use std::path::{Path, PathBuf};

/// Resolve `rel` against the repo root. Walks up from CWD (then from the
/// executable's location) until a directory containing `configs/models.cfg`
/// is found; falls back to CWD-relative.
pub fn repo_path(rel: &str) -> String {
    fn find_root(mut dir: PathBuf) -> Option<PathBuf> {
        loop {
            if dir.join("configs/models.cfg").exists() {
                return Some(dir);
            }
            if !dir.pop() {
                return None;
            }
        }
    }
    let root = std::env::current_dir()
        .ok()
        .and_then(find_root)
        .or_else(|| {
            std::env::current_exe()
                .ok()
                .and_then(|p| p.parent().map(Path::to_path_buf))
                .and_then(find_root)
        });
    match root {
        Some(r) => r.join(rel).display().to_string(),
        None => rel.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repo_path_finds_configs() {
        let p = repo_path("configs/models.cfg");
        assert!(std::path::Path::new(&p).exists(), "{p}");
    }
}
