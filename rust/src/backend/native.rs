//! Pure-rust reference backend.
//!
//! Mirrors `python/compile/kernels/ref.py` op for op, but the matmuls run
//! on the tiled/register-blocked kernels in [`super::kernels`] (bias init
//! and the ReLU mask are fused into the kernel passes) and every scratch
//! or output buffer can come from a shared [`Workspace`] pool via the
//! `*_pooled` entry points, so steady-state microbatches allocate nothing.
//! The plain `Backend` methods remain allocation-per-call (each uses a
//! private serial workspace) and stay bit-identical across kernel thread
//! counts — see the determinism contract in [`super::kernels`].

use super::{kernels, Backend, BwdOut, Workspace};
use crate::config::{Act, LayerShape};
use crate::model::{GradBuf, LayerParams};

#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

fn softmax_rows(classes: usize, logits: &[f32], batch: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; logits.len()];
    for i in 0..batch {
        let row = &logits[i * classes..(i + 1) * classes];
        let orow = &mut out[i * classes..(i + 1) * classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = (v - m).exp();
            sum += *o;
        }
        orow.iter_mut().for_each(|o| *o /= sum);
    }
    out
}

impl Backend for NativeBackend {
    fn share(&self) -> std::sync::Arc<dyn Backend> {
        // stateless: a fresh instance is indistinguishable from `self`
        std::sync::Arc::new(NativeBackend)
    }

    fn dense_fwd(&self, shape: &LayerShape, p: &LayerParams, x: &[f32], batch: usize) -> Vec<f32> {
        let (k, n) = (shape.in_dim, shape.out_dim);
        debug_assert_eq!(x.len(), batch * k);
        let mut z = vec![0.0f32; batch * n];
        kernels::dense_fwd_into(&mut z, x, &p.w, &p.b, batch, k, n, shape.act == Act::Relu, 1);
        z
    }

    fn dense_fwd_pooled(
        &self,
        shape: &LayerShape,
        p: &LayerParams,
        x: &[f32],
        batch: usize,
        ws: &Workspace,
    ) -> Vec<f32> {
        let (k, n) = (shape.in_dim, shape.out_dim);
        debug_assert_eq!(x.len(), batch * k);
        let mut z = ws.pool.take(batch * n);
        kernels::dense_fwd_into(
            &mut z,
            x,
            &p.w,
            &p.b,
            batch,
            k,
            n,
            shape.act == Act::Relu,
            ws.threads,
        );
        z
    }

    fn dense_bwd(
        &self,
        shape: &LayerShape,
        p: &LayerParams,
        x: &[f32],
        g: &[f32],
        batch: usize,
    ) -> BwdOut {
        self.dense_bwd_pooled(shape, p, x, g, batch, &Workspace::serial())
    }

    fn dense_bwd_pooled(
        &self,
        shape: &LayerShape,
        p: &LayerParams,
        x: &[f32],
        g: &[f32],
        batch: usize,
        ws: &Workspace,
    ) -> BwdOut {
        let (k, n) = (shape.in_dim, shape.out_dim);
        debug_assert_eq!(g.len(), batch * n);
        let pool = &ws.pool;
        // Activation recomputation (T1): recompute z rather than stash it;
        // the ReLU mask is fused into the copy into the pooled gz buffer.
        let mut gz = pool.take(batch * n);
        if shape.act == Act::Relu {
            let mut z = pool.take(batch * n);
            kernels::dense_fwd_into(&mut z, x, &p.w, &p.b, batch, k, n, false, ws.threads);
            kernels::relu_mask_into(&mut gz, g, &z);
            pool.put(z);
        } else {
            gz.copy_from_slice(g);
        }
        let mut gx = pool.take_zeroed(batch * k);
        kernels::matmul_bt_acc(&mut gx, &gz, &p.w, batch, n, k, ws.threads);
        let mut gw = pool.take_zeroed(k * n);
        kernels::matmul_at_acc(&mut gw, x, &gz, k, batch, n, ws.threads);
        let mut gb = pool.take_zeroed(n);
        kernels::col_sum_acc(&mut gb, &gz, batch, n);
        pool.put(gz);
        BwdOut { gx, grads: GradBuf { gw, gb } }
    }

    fn loss_grad_ce(&self, classes: usize, logits: &[f32], labels: &[i32]) -> (Vec<f32>, f32) {
        let batch = labels.len();
        let mut g = softmax_rows(classes, logits, batch);
        let mut loss = 0.0f32;
        let inv_b = 1.0 / batch as f32;
        for (i, &y) in labels.iter().enumerate() {
            let row = &mut g[i * classes..(i + 1) * classes];
            loss -= row[y as usize].max(1e-30).ln();
            row[y as usize] -= 1.0;
            row.iter_mut().for_each(|v| *v *= inv_b);
        }
        (g, loss * inv_b)
    }

    fn loss_grad_lwf(
        &self,
        classes: usize,
        logits: &[f32],
        labels: &[i32],
        teacher: &[f32],
        alpha: f32,
    ) -> (Vec<f32>, f32) {
        const T: f32 = 2.0; // matches python LWF_TEMPERATURE
        let batch = labels.len();
        let (gce, lce) = self.loss_grad_ce(classes, logits, labels);
        // distillation: T^2 * KL(softmax(teacher/T) || softmax(logits/T))
        let st: Vec<f32> = logits.iter().map(|v| v / T).collect();
        let tt: Vec<f32> = teacher.iter().map(|v| v / T).collect();
        let ps = softmax_rows(classes, &st, batch);
        let pt = softmax_rows(classes, &tt, batch);
        let mut kl = 0.0f32;
        for i in 0..batch * classes {
            if pt[i] > 0.0 {
                kl += pt[i] * (pt[i].max(1e-30).ln() - ps[i].max(1e-30).ln());
            }
        }
        kl = kl / batch as f32 * T * T;
        // d/dlogits [T^2 * mean_KL] = T * (ps - pt) / batch  (chain rule:
        // d(logits/T)/dlogits = 1/T, dKL/d(st) = (ps - pt)/batch, times T^2)
        let mut g = vec![0.0f32; batch * classes];
        let scale = T / batch as f32;
        for i in 0..batch * classes {
            g[i] = (1.0 - alpha) * gce[i] + alpha * scale * (ps[i] - pt[i]);
        }
        (g, (1.0 - alpha) * lce + alpha * kl)
    }

    fn compensate(&self, g: &GradBuf, d: &GradBuf, lam: f32) -> GradBuf {
        let mut gw = vec![0.0f32; g.gw.len()];
        kernels::compensate_into(&mut gw, &g.gw, &d.gw, lam);
        let mut gb = vec![0.0f32; g.gb.len()];
        kernels::compensate_into(&mut gb, &g.gb, &d.gb, lam);
        GradBuf { gw, gb }
    }

    fn compensate_inplace(&self, g: &mut GradBuf, d: &GradBuf, lam: f32) {
        kernels::compensate_slice_inplace(&mut g.gw, &d.gw, lam);
        kernels::compensate_slice_inplace(&mut g.gb, &d.gb, lam);
    }

    fn sgd(&self, p: &LayerParams, g: &GradBuf, lr: f32) -> LayerParams {
        let mut w = vec![0.0f32; p.w.len()];
        kernels::sgd_into(&mut w, &p.w, &g.gw, lr);
        let mut b = vec![0.0f32; p.b.len()];
        kernels::sgd_into(&mut b, &p.b, &g.gb, lr);
        LayerParams { w, b }
    }

    fn sgd_pooled(&self, p: &LayerParams, g: &GradBuf, lr: f32, ws: &Workspace) -> LayerParams {
        let mut w = ws.pool.take(p.w.len());
        kernels::sgd_into(&mut w, &p.w, &g.gw, lr);
        let mut b = ws.pool.take(p.b.len());
        kernels::sgd_into(&mut b, &p.b, &g.gb, lr);
        LayerParams { w, b }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::pool::BufferPool;
    use crate::backend::{accuracy, backward_all, ce_loss, forward_all};
    use crate::util::{property, Rng};

    fn shape(i: usize, o: usize, act: Act) -> LayerShape {
        LayerShape { in_dim: i, out_dim: o, act }
    }

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn fwd_known_values() {
        // x=(1,2), w=[[1,0],[0,1]], b=(0.5,-10) relu -> (1.5, 0)
        let s = shape(2, 2, Act::Relu);
        let p = LayerParams { w: vec![1.0, 0.0, 0.0, 1.0], b: vec![0.5, -10.0] };
        let y = NativeBackend.dense_fwd(&s, &p, &[1.0, 2.0], 1);
        assert_eq!(y, vec![1.5, 0.0]);
        let s2 = shape(2, 2, Act::None);
        let y2 = NativeBackend.dense_fwd(&s2, &p, &[1.0, 2.0], 1);
        assert_eq!(y2, vec![1.5, -8.0]);
    }

    /// Finite-difference check of the full bwd pass.
    #[test]
    fn bwd_matches_finite_difference() {
        property("bwd_fd", 10, |rng| {
            let (b, k, n) = (2, 1 + rng.below(5), 1 + rng.below(5));
            let act = if rng.uniform() < 0.5 { Act::Relu } else { Act::None };
            let s = shape(k, n, act);
            let p = LayerParams {
                w: randvec(rng, k * n),
                b: randvec(rng, n),
            };
            let x = randvec(rng, b * k);
            let g = randvec(rng, b * n);
            let f = |p: &LayerParams, x: &[f32]| -> f32 {
                let y = NativeBackend.dense_fwd(&s, p, x, b);
                y.iter().zip(&g).map(|(&y, &g)| y * g).sum()
            };
            let out = NativeBackend.dense_bwd(&s, &p, &x, &g, b);
            let eps = 1e-2f32;
            // spot-check a few coordinates of each gradient
            for _ in 0..4 {
                let i = rng.below(k * n);
                let mut p2 = p.clone();
                p2.w[i] += eps;
                let mut p3 = p.clone();
                p3.w[i] -= eps;
                let fd = (f(&p2, &x) - f(&p3, &x)) / (2.0 * eps);
                assert!(
                    (fd - out.grads.gw[i]).abs() < 2e-2 * (1.0 + fd.abs()),
                    "gw[{i}]: fd={fd} got={}",
                    out.grads.gw[i]
                );
                let j = rng.below(b * k);
                let mut x2 = x.clone();
                x2[j] += eps;
                let mut x3 = x.clone();
                x3[j] -= eps;
                let fdx = (f(&p, &x2) - f(&p, &x3)) / (2.0 * eps);
                assert!(
                    (fdx - out.gx[j]).abs() < 2e-2 * (1.0 + fdx.abs()),
                    "gx[{j}]: fd={fdx} got={}",
                    out.gx[j]
                );
            }
        });
    }

    /// Pooled entry points recycle buffers without changing any numerics.
    #[test]
    fn pooled_paths_match_unpooled_bitwise() {
        property("pooled_eq", 10, |rng| {
            let (b, k, n) = (1 + rng.below(6), 1 + rng.below(9), 1 + rng.below(9));
            let act = if rng.uniform() < 0.5 { Act::Relu } else { Act::None };
            let s = shape(k, n, act);
            let p = LayerParams { w: randvec(rng, k * n), b: randvec(rng, n) };
            let x = randvec(rng, b * k);
            let g = randvec(rng, b * n);
            let ws = Workspace::new(BufferPool::new(), 1);
            let be = NativeBackend;
            // run twice so the second pass consumes recycled (dirty) buffers
            for _ in 0..2 {
                let y0 = be.dense_fwd(&s, &p, &x, b);
                let y1 = be.dense_fwd_pooled(&s, &p, &x, b, &ws);
                assert_eq!(y0, y1);
                let o0 = be.dense_bwd(&s, &p, &x, &g, b);
                let o1 = be.dense_bwd_pooled(&s, &p, &x, &g, b, &ws);
                assert_eq!(o0.gx, o1.gx);
                assert_eq!(o0.grads.gw, o1.grads.gw);
                assert_eq!(o0.grads.gb, o1.grads.gb);
                let grads = GradBuf { gw: o0.grads.gw.clone(), gb: o0.grads.gb.clone() };
                let p0 = be.sgd(&p, &grads, 0.1);
                let p1 = be.sgd_pooled(&p, &grads, 0.1, &ws);
                assert_eq!(p0.w, p1.w);
                assert_eq!(p0.b, p1.b);
                // recycle the outputs so pass two hits the shelves
                ws.pool.put(y1);
                ws.pool.put(o1.gx);
                ws.pool.put(o1.grads.gw);
                ws.pool.put(o1.grads.gb);
                ws.pool.put(p1.w);
                ws.pool.put(p1.b);
            }
            assert!(ws.pool.stats().takes > 0);
        });
    }

    #[test]
    fn ce_grad_is_softmax_minus_onehot() {
        let logits = vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0];
        let labels = vec![2, 0];
        let (g, loss) = NativeBackend.loss_grad_ce(3, &logits, &labels);
        // row 1: softmax(1,2,3), y=2
        let z: f32 = (1.0f32).exp() + (2.0f32).exp() + (3.0f32).exp();
        let p2 = (3.0f32).exp() / z;
        assert!((g[2] - (p2 - 1.0) / 2.0).abs() < 1e-6);
        // row 2: uniform softmax, y=0
        assert!((g[3] - (1.0 / 3.0 - 1.0) / 2.0).abs() < 1e-6);
        assert!((g[4] - (1.0 / 3.0) / 2.0).abs() < 1e-6);
        // loss equals ce_loss helper
        assert!((loss - ce_loss(3, &logits, &labels)).abs() < 1e-6);
        // grad rows sum to zero
        assert!(g[0..3].iter().sum::<f32>().abs() < 1e-6);
    }

    #[test]
    fn lwf_alpha_zero_is_ce_and_self_teacher_pure_distill_zero() {
        let mut rng = Rng::new(3);
        let logits = randvec(&mut rng, 4 * 5);
        let teacher = randvec(&mut rng, 4 * 5);
        let labels = vec![0, 1, 2, 3];
        let (g0, l0) = NativeBackend.loss_grad_lwf(5, &logits, &labels, &teacher, 0.0);
        let (gce, lce) = NativeBackend.loss_grad_ce(5, &logits, &labels);
        for (a, b) in g0.iter().zip(&gce) {
            assert!((a - b).abs() < 1e-6);
        }
        assert!((l0 - lce).abs() < 1e-6);
        let (g1, l1) = NativeBackend.loss_grad_lwf(5, &logits, &labels, &logits, 1.0);
        assert!(g1.iter().all(|v| v.abs() < 1e-6));
        assert!(l1.abs() < 1e-6);
    }

    #[test]
    fn lwf_grad_matches_finite_difference() {
        let mut rng = Rng::new(11);
        let (b, c) = (3, 4);
        let logits = randvec(&mut rng, b * c);
        let teacher = randvec(&mut rng, b * c);
        let labels = vec![1, 0, 3];
        let alpha = 0.6;
        let (g, _) = NativeBackend.loss_grad_lwf(c, &logits, &labels, &teacher, alpha);
        let f = |l: &[f32]| NativeBackend.loss_grad_lwf(c, l, &labels, &teacher, alpha).1;
        let eps = 1e-2f32;
        for i in 0..b * c {
            let mut l2 = logits.clone();
            l2[i] += eps;
            let mut l3 = logits.clone();
            l3[i] -= eps;
            let fd = (f(&l2) - f(&l3)) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 1e-3, "i={i} fd={fd} got={}", g[i]);
        }
    }

    #[test]
    fn compensate_and_sgd() {
        let g = GradBuf { gw: vec![2.0, -1.0], gb: vec![0.5] };
        let d = GradBuf { gw: vec![0.1, 0.2], gb: vec![-0.4] };
        let c = NativeBackend.compensate(&g, &d, 0.5);
        assert!((c.gw[0] - (2.0 + 0.5 * 4.0 * 0.1)).abs() < 1e-6);
        assert!((c.gw[1] - (-1.0 + 0.5 * 1.0 * 0.2)).abs() < 1e-6);
        assert!((c.gb[0] - (0.5 + 0.5 * 0.25 * -0.4)).abs() < 1e-6);
        // in-place form computes the same thing on the same buffer
        let mut g2 = GradBuf { gw: g.gw.clone(), gb: g.gb.clone() };
        NativeBackend.compensate_inplace(&mut g2, &d, 0.5);
        assert_eq!(g2.gw, c.gw);
        assert_eq!(g2.gb, c.gb);
        let p = LayerParams { w: vec![1.0, 1.0], b: vec![1.0] };
        let p2 = NativeBackend.sgd(&p, &g, 0.1);
        assert_eq!(p2.w, vec![0.8, 1.1]);
        assert_eq!(p2.b, vec![0.95]);
    }

    #[test]
    fn full_chain_learns_xor_like_task() {
        // 2-layer net on a linearly-nonseparable toy problem: loss drops.
        let shapes = [shape(2, 16, Act::Relu), shape(16, 2, Act::None)];
        let mut rng = Rng::new(21);
        let mut params = vec![
            LayerParams::init(&shapes[0], &mut rng),
            LayerParams::init(&shapes[1], &mut rng),
        ];
        let x = vec![0.0, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0];
        let y = vec![0, 1, 1, 0];
        let be = NativeBackend;
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..300 {
            let (inputs, logits) = forward_all(&be, &shapes, &params, &x, 4);
            let (gl, loss) = be.loss_grad_ce(2, &logits, &y);
            if step == 0 {
                first = loss;
            }
            last = loss;
            let grads = backward_all(&be, &shapes, &params, &inputs, &gl, 4);
            for (p, g) in params.iter_mut().zip(&grads) {
                *p = be.sgd(p, g, 0.5);
            }
        }
        assert!(last < first * 0.2, "first={first} last={last}");
        let (_, logits) = forward_all(&be, &shapes, &params, &x, 4);
        assert!(accuracy(2, &logits, &y) >= 0.99);
    }
}
