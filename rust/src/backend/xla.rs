//! XLA/PJRT backend: every `Backend` op dispatches to the corresponding
//! AOT artifact. The artifact batch is fixed at lowering time; callers with
//! a smaller logical batch are zero-padded up (labels padded with class 0
//! and the padded rows' gradients masked out by rescaling).
//!
//! Compiled without the `xla` cargo feature this is a stub whose
//! constructors fail (via the stub [`Runtime`]), letting every caller
//! compile and skip gracefully.

#[cfg(feature = "xla")]
mod real {
    use super::super::{Backend, BwdOut};
    use crate::config::LayerShape;
    use crate::model::{GradBuf, LayerParams};
    use crate::runtime::{lit_f32, lit_i32, lit_scalar, names, to_f32, Runtime};
    use crate::util::error::Result;

    /// The runtime sits behind an `Arc` so [`Backend::share`] handles
    /// reuse one PJRT client and one compiled-executable cache across
    /// every device thread.
    pub struct XlaBackend {
        rt: std::sync::Arc<Runtime>,
    }

    impl XlaBackend {
        pub fn new(rt: Runtime) -> Self {
            XlaBackend { rt: std::sync::Arc::new(rt) }
        }

        pub fn open_default() -> Result<Self> {
            Ok(XlaBackend::new(Runtime::open_default()?))
        }

        pub fn runtime(&self) -> &Runtime {
            &self.rt
        }

        fn ab(&self) -> usize {
            self.rt.batch()
        }

        /// Pad a (batch, dim) row-major buffer with zero rows up to the
        /// artifact batch.
        fn pad_rows(&self, x: &[f32], batch: usize, dim: usize) -> Vec<f32> {
            let ab = self.ab();
            assert!(batch <= ab, "batch {batch} exceeds artifact batch {ab}");
            if batch == ab {
                return x.to_vec();
            }
            let mut out = vec![0.0f32; ab * dim];
            out[..batch * dim].copy_from_slice(x);
            out
        }

        fn unpad_rows(&self, x: Vec<f32>, batch: usize, dim: usize) -> Vec<f32> {
            if batch == self.ab() {
                x
            } else {
                x[..batch * dim].to_vec()
            }
        }
    }

    impl Backend for XlaBackend {
        fn share(&self) -> std::sync::Arc<dyn Backend> {
            // same PJRT client + executable cache, new owner
            std::sync::Arc::new(XlaBackend { rt: std::sync::Arc::clone(&self.rt) })
        }

        fn dense_fwd(
            &self,
            shape: &LayerShape,
            p: &LayerParams,
            x: &[f32],
            batch: usize,
        ) -> Vec<f32> {
            let (k, n, ab) = (shape.in_dim, shape.out_dim, self.ab());
            let xp = self.pad_rows(x, batch, k);
            let out = self
                .rt
                .exec(
                    &names::dense_fwd(shape),
                    &[
                        lit_f32(&xp, &[ab as i64, k as i64]).unwrap(),
                        lit_f32(&p.w, &[k as i64, n as i64]).unwrap(),
                        lit_f32(&p.b, &[n as i64]).unwrap(),
                    ],
                )
                .expect("dense_fwd artifact");
            self.unpad_rows(to_f32(&out[0]).unwrap(), batch, n)
        }

        fn dense_bwd(
            &self,
            shape: &LayerShape,
            p: &LayerParams,
            x: &[f32],
            g: &[f32],
            batch: usize,
        ) -> BwdOut {
            let (k, n, ab) = (shape.in_dim, shape.out_dim, self.ab());
            let xp = self.pad_rows(x, batch, k);
            let gp = self.pad_rows(g, batch, n);
            let out = self
                .rt
                .exec(
                    &names::dense_bwd(shape),
                    &[
                        lit_f32(&xp, &[ab as i64, k as i64]).unwrap(),
                        lit_f32(&p.w, &[k as i64, n as i64]).unwrap(),
                        lit_f32(&p.b, &[n as i64]).unwrap(),
                        lit_f32(&gp, &[ab as i64, n as i64]).unwrap(),
                    ],
                )
                .expect("dense_bwd artifact");
            // Padded rows have zero upstream grad, so gw/gb are unaffected.
            BwdOut {
                gx: self.unpad_rows(to_f32(&out[0]).unwrap(), batch, k),
                grads: GradBuf {
                    gw: to_f32(&out[1]).unwrap(),
                    gb: to_f32(&out[2]).unwrap(),
                },
            }
        }

        fn loss_grad_ce(&self, classes: usize, logits: &[f32], labels: &[i32]) -> (Vec<f32>, f32) {
            let (batch, ab) = (labels.len(), self.ab());
            let lp = self.pad_rows(logits, batch, classes);
            let mut yp = labels.to_vec();
            yp.resize(ab, 0);
            let out = self
                .rt
                .exec(
                    &names::loss_ce(classes),
                    &[
                        lit_f32(&lp, &[ab as i64, classes as i64]).unwrap(),
                        lit_i32(&yp),
                    ],
                )
                .expect("loss_ce artifact");
            let mut g = self.unpad_rows(to_f32(&out[0]).unwrap(), batch, classes);
            let loss = to_f32(&out[1]).unwrap()[0];
            // The artifact divides by the *artifact* batch; rescale to the
            // logical batch and drop the padded rows' (nonzero)
            // contribution.
            if batch != ab {
                let scale = ab as f32 / batch as f32;
                g.iter_mut().for_each(|v| *v *= scale);
                // loss over padded rows is garbage — recompute natively.
                return (g, super::super::ce_loss(classes, logits, labels));
            }
            (g, loss)
        }

        fn loss_grad_lwf(
            &self,
            classes: usize,
            logits: &[f32],
            labels: &[i32],
            teacher: &[f32],
            alpha: f32,
        ) -> (Vec<f32>, f32) {
            let (batch, ab) = (labels.len(), self.ab());
            let lp = self.pad_rows(logits, batch, classes);
            let tp = self.pad_rows(teacher, batch, classes);
            let mut yp = labels.to_vec();
            yp.resize(ab, 0);
            let out = self
                .rt
                .exec(
                    &names::loss_lwf(classes),
                    &[
                        lit_f32(&lp, &[ab as i64, classes as i64]).unwrap(),
                        lit_i32(&yp),
                        lit_f32(&tp, &[ab as i64, classes as i64]).unwrap(),
                        lit_scalar(alpha),
                    ],
                )
                .expect("loss_lwf artifact");
            let mut g = self.unpad_rows(to_f32(&out[0]).unwrap(), batch, classes);
            let loss = to_f32(&out[1]).unwrap()[0];
            if batch != ab {
                let scale = ab as f32 / batch as f32;
                g.iter_mut().for_each(|v| *v *= scale);
                // like loss_ce: the padded rows' CE+distillation terms
                // poison the artifact's mean loss — recompute natively.
                let (_, native_loss) = crate::backend::native::NativeBackend
                    .loss_grad_lwf(classes, logits, labels, teacher, alpha);
                return (g, native_loss);
            }
            (g, loss)
        }

        fn compensate(&self, g: &GradBuf, d: &GradBuf, lam: f32) -> GradBuf {
            // Shape is recoverable from the buffer lengths: gw is (K, N),
            // gb is (N,).
            let n = g.gb.len();
            let k = g.gw.len() / n;
            let shape = LayerShape { in_dim: k, out_dim: n, act: crate::config::Act::Relu };
            let out = self
                .rt
                .exec(
                    &names::compensate(&shape),
                    &[
                        lit_f32(&g.gw, &[k as i64, n as i64]).unwrap(),
                        lit_f32(&g.gb, &[n as i64]).unwrap(),
                        lit_f32(&d.gw, &[k as i64, n as i64]).unwrap(),
                        lit_f32(&d.gb, &[n as i64]).unwrap(),
                        lit_scalar(lam),
                    ],
                )
                .expect("compensate artifact");
            GradBuf {
                gw: to_f32(&out[0]).unwrap(),
                gb: to_f32(&out[1]).unwrap(),
            }
        }

        fn sgd(&self, p: &LayerParams, g: &GradBuf, lr: f32) -> LayerParams {
            let n = p.b.len();
            let k = p.w.len() / n;
            let shape = LayerShape { in_dim: k, out_dim: n, act: crate::config::Act::Relu };
            let out = self
                .rt
                .exec(
                    &names::sgd(&shape),
                    &[
                        lit_f32(&p.w, &[k as i64, n as i64]).unwrap(),
                        lit_f32(&p.b, &[n as i64]).unwrap(),
                        lit_f32(&g.gw, &[k as i64, n as i64]).unwrap(),
                        lit_f32(&g.gb, &[n as i64]).unwrap(),
                        lit_scalar(lr),
                    ],
                )
                .expect("sgd artifact");
            LayerParams {
                w: to_f32(&out[0]).unwrap(),
                b: to_f32(&out[1]).unwrap(),
            }
        }
    }
}

#[cfg(feature = "xla")]
pub use real::XlaBackend;

#[cfg(not(feature = "xla"))]
mod stub {
    use super::super::{Backend, BwdOut};
    use crate::config::LayerShape;
    use crate::model::{GradBuf, LayerParams};
    use crate::runtime::Runtime;
    use crate::util::error::Result;

    /// Uninhabited without the `xla` feature: `open_default()` always
    /// returns the stub runtime's error, so none of the `Backend` methods
    /// can ever be reached.
    pub struct XlaBackend {
        rt: Runtime,
    }

    impl XlaBackend {
        pub fn new(rt: Runtime) -> Self {
            XlaBackend { rt }
        }

        pub fn open_default() -> Result<Self> {
            Ok(XlaBackend { rt: Runtime::open_default()? })
        }

        pub fn runtime(&self) -> &Runtime {
            &self.rt
        }
    }

    impl Backend for XlaBackend {
        fn share(&self) -> std::sync::Arc<dyn Backend> {
            unreachable!("built without the xla feature")
        }

        fn dense_fwd(&self, _: &LayerShape, _: &LayerParams, _: &[f32], _: usize) -> Vec<f32> {
            unreachable!("built without the xla feature")
        }

        fn dense_bwd(
            &self,
            _: &LayerShape,
            _: &LayerParams,
            _: &[f32],
            _: &[f32],
            _: usize,
        ) -> BwdOut {
            unreachable!("built without the xla feature")
        }

        fn loss_grad_ce(&self, _: usize, _: &[f32], _: &[i32]) -> (Vec<f32>, f32) {
            unreachable!("built without the xla feature")
        }

        fn loss_grad_lwf(
            &self,
            _: usize,
            _: &[f32],
            _: &[i32],
            _: &[f32],
            _: f32,
        ) -> (Vec<f32>, f32) {
            unreachable!("built without the xla feature")
        }

        fn compensate(&self, _: &GradBuf, _: &GradBuf, _: f32) -> GradBuf {
            unreachable!("built without the xla feature")
        }

        fn sgd(&self, _: &LayerParams, _: &GradBuf, _: f32) -> LayerParams {
            unreachable!("built without the xla feature")
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaBackend;
