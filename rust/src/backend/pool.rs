//! Recycled `Vec<f32>` buffers for zero-allocation steady-state steps.
//!
//! Every microbatch used to allocate fresh vectors at each handoff:
//! activations between stages, `gz`/`gx`/`gw` inside the backward pass,
//! new parameter vectors at every SGD step. [`BufferPool`] is a shared
//! free-list of `Vec<f32>` keyed by exact length (the engine's buffer
//! sizes are a small, fixed set per plan), so once a run is warm, every
//! `take` is a recycle and steady-state microbatches allocate nothing.
//!
//! The pool is **one shared store** for the whole session — the handle is
//! a cheap [`Clone`] over an `Arc`, passed to the scheduler thread and
//! every device thread alike. That is load-bearing: buffers migrate across
//! threads (the scheduler copies a stage input, a device consumes and
//! frees it), so per-thread pools would leak in one direction and miss
//! forever in the other. Contention is negligible — a microbatch performs
//! tens of pool ops against ms-scale matmuls.
//!
//! Pooled free lists are deliberately **not** counted in the measured
//! memory ledger: they are recyclable scratch, not plan-attributable state
//! (params/stash/activations/compensator), and each shelf is capped at
//! [`SHELF_CAP`] buffers so the store stays bounded under streams that
//! keep injecting fresh batch buffers.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Max recycled buffers kept per size class; beyond this, `put` drops the
/// buffer (frees it) instead of shelving it.
pub const SHELF_CAP: usize = 32;

/// Take/put counters, readable at any time through any pool handle.
/// `misses` counts `take` calls that had to heap-allocate — the "steady
/// state allocations per microbatch" number of the bench trajectory.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub takes: u64,
    pub misses: u64,
    pub puts: u64,
    /// puts dropped because the size class was already at [`SHELF_CAP`]
    pub dropped: u64,
}

impl PoolStats {
    /// Counter-wise difference vs an earlier snapshot.
    pub fn since(&self, earlier: &PoolStats) -> PoolStats {
        PoolStats {
            takes: self.takes - earlier.takes,
            misses: self.misses - earlier.misses,
            puts: self.puts - earlier.puts,
            dropped: self.dropped - earlier.dropped,
        }
    }
}

struct Inner {
    shelves: Mutex<HashMap<usize, Vec<Vec<f32>>>>,
    takes: AtomicU64,
    misses: AtomicU64,
    puts: AtomicU64,
    dropped: AtomicU64,
}

/// Shared buffer store; `Clone` shares the same shelves and counters.
#[derive(Clone)]
pub struct BufferPool {
    inner: Arc<Inner>,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for BufferPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BufferPool").field("stats", &self.stats()).finish()
    }
}

impl BufferPool {
    pub fn new() -> Self {
        BufferPool {
            inner: Arc::new(Inner {
                shelves: Mutex::new(HashMap::new()),
                takes: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                puts: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
            }),
        }
    }

    /// A buffer of exactly `len` elements with **unspecified contents**
    /// (recycled bits or zeros) — the caller must fully overwrite it.
    pub fn take(&self, len: usize) -> Vec<f32> {
        self.inner.takes.fetch_add(1, Ordering::Relaxed);
        if let Some(v) = self.inner.shelves.lock().unwrap().get_mut(&len).and_then(Vec::pop) {
            debug_assert_eq!(v.len(), len);
            return v;
        }
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        vec![0.0; len]
    }

    /// A buffer of exactly `len` zeros (accumulator init).
    pub fn take_zeroed(&self, len: usize) -> Vec<f32> {
        self.inner.takes.fetch_add(1, Ordering::Relaxed);
        if let Some(mut v) = self.inner.shelves.lock().unwrap().get_mut(&len).and_then(Vec::pop) {
            debug_assert_eq!(v.len(), len);
            v.iter_mut().for_each(|x| *x = 0.0);
            return v;
        }
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        vec![0.0; len]
    }

    /// Return a buffer for reuse. Empty vectors are ignored; full shelves
    /// drop the buffer (the store stays bounded).
    pub fn put(&self, v: Vec<f32>) {
        if v.is_empty() {
            return;
        }
        self.inner.puts.fetch_add(1, Ordering::Relaxed);
        let mut shelves = self.inner.shelves.lock().unwrap();
        let shelf = shelves.entry(v.len()).or_default();
        if shelf.len() < SHELF_CAP {
            shelf.push(v);
        } else {
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            takes: self.inner.takes.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            puts: self.inner.puts.load(Ordering::Relaxed),
            dropped: self.inner.dropped.load(Ordering::Relaxed),
        }
    }

    /// f32 slots currently shelved (introspection only; ledger-exempt).
    pub fn free_f32s(&self) -> usize {
        self.inner
            .shelves
            .lock()
            .unwrap()
            .iter()
            .map(|(len, shelf)| len * shelf.len())
            .sum()
    }
}

/// Everything a kernel call site needs beyond its operands: the shared
/// buffer store and the intra-stage worker count (1 = serial, the
/// deterministic default; the tiled kernels are bit-identical across
/// thread counts either way — see [`super::kernels`]).
#[derive(Clone, Debug)]
pub struct Workspace {
    pub pool: BufferPool,
    pub threads: usize,
}

impl Workspace {
    pub fn new(pool: BufferPool, threads: usize) -> Self {
        Workspace { pool, threads: threads.max(1) }
    }

    /// A private single-threaded workspace with its own (cold) pool — the
    /// default for entry points that predate pooling.
    pub fn serial() -> Self {
        Workspace { pool: BufferPool::new(), threads: 1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_miss_then_roundtrip_hit() {
        let pool = BufferPool::new();
        let a = pool.take(8);
        assert_eq!(a.len(), 8);
        let s = pool.stats();
        assert_eq!((s.takes, s.misses), (1, 1));
        let ptr = a.as_ptr() as usize;
        pool.put(a);
        let b = pool.take(8);
        // same allocation came back; no new miss
        assert_eq!(b.as_ptr() as usize, ptr);
        let s = pool.stats();
        assert_eq!((s.takes, s.misses, s.puts), (2, 1, 1));
    }

    #[test]
    fn concurrent_takes_never_alias() {
        let pool = BufferPool::new();
        let a = pool.take(16);
        let b = pool.take(16);
        assert_ne!(a.as_ptr(), b.as_ptr());
        pool.put(a);
        pool.put(b);
        let c = pool.take(16);
        let d = pool.take(16);
        assert_ne!(c.as_ptr(), d.as_ptr());
        assert_eq!(pool.stats().misses, 2); // both shelved buffers reused
    }

    #[test]
    fn size_classes_are_exact_and_zeroed_take_is_clean() {
        let pool = BufferPool::new();
        let mut a = pool.take(4);
        a.fill(3.5);
        pool.put(a);
        // different length -> fresh allocation, not a resized recycle
        let b = pool.take(5);
        assert_eq!(b.len(), 5);
        assert_eq!(pool.stats().misses, 2);
        // recycled buffer through take_zeroed comes back clean
        let z = pool.take_zeroed(4);
        assert_eq!(z, vec![0.0; 4]);
        assert_eq!(pool.stats().misses, 2);
        assert_eq!(pool.free_f32s(), 0);
    }

    #[test]
    fn shelves_are_capped() {
        let pool = BufferPool::new();
        let bufs: Vec<_> = (0..SHELF_CAP + 3).map(|_| pool.take(2)).collect();
        for b in bufs {
            pool.put(b);
        }
        let s = pool.stats();
        assert_eq!(s.dropped, 3);
        assert_eq!(pool.free_f32s(), SHELF_CAP * 2);
    }

    #[test]
    fn clones_share_the_store_across_threads() {
        let pool = BufferPool::new();
        let a = pool.take(32);
        let remote = pool.clone();
        std::thread::spawn(move || remote.put(a)).join().unwrap();
        let _b = pool.take(32);
        let s = pool.stats();
        assert_eq!((s.takes, s.misses, s.puts), (2, 1, 1));
        let since = pool.stats().since(&s);
        assert_eq!(since, PoolStats::default());
    }
}
