//! Tiled / lane-blocked matmul kernels with optional row-parallelism.
//!
//! This is the numeric hot path of [`super::native::NativeBackend`]. Three
//! matmul flavors cover one dense layer's step (fwd `x@w`, bwd-input
//! `g@wᵀ`, bwd-weight `xᵀ@g`), each in two forms:
//!
//!   - `naive_*` — the straight reference loops (the pre-tiling kernels,
//!     kept as the ground truth for property tests and the kernel bench);
//!   - the tiled entry points — cache-blocked over the reduction dim with
//!     fixed-size `[f32; LANES]` register tiles in the innermost loops
//!     (stable-Rust autovectorization: LLVM maps the lane arrays onto
//!     SIMD registers), plus an optional `std::thread::scope` fan-out
//!     that splits the *output rows* across `threads` workers.
//!
//! # Determinism contract (lane-blocked accumulation)
//!
//! `matmul_acc` and `matmul_at_acc` accumulate every output element in
//! ascending reduction (`kk`) order — exactly the order of the naive
//! loops. Lane blocking only tiles the *column* (`j`) dimension: each
//! output element belongs to exactly one lane of one column tile, and
//! within that tile the `kk` loop runs ascending over each cache block,
//! with cache blocks visited ascending, so the floating-point additions
//! hitting any single element are the naive kernel's additions in the
//! naive kernel's order. Column tiles narrow to 8-wide and then to a
//! scalar tail with the same per-element order, and the parallel path
//! partitions whole output rows, so results are **bit-identical** to the
//! naive kernels for every thread count, tile width, and shape.
//!
//! `matmul_bt_acc` is the exemption: each dot product accumulates on
//! [`DOT_LANES`] independent lanes folded by a fixed pairwise tree (the
//! serial FP chain is latency-bound). Its rounding differs from the
//! naive kernel (tolerance-checked in tests), but the lane schedule is a
//! pure function of `k`, so it too is bit-identical *across thread
//! counts*. Net: changing `FERRET_KERNEL_THREADS` never changes any
//! numeric result, and lockstep runs stay deterministic. Planner sweeps
//! default to 1 thread only to avoid oversubscription, not for
//! reproducibility.
//!
//! The post-ReLU sparse-skip fast path (`av == 0.0 → skip`) of the
//! forward/weight kernels is preserved per `kk` step inside the lane
//! tiles — it both keeps the zero-operand semantics of the naive loops
//! (`0 * inf` is never materialized) and skips whole 64-wide tile
//! updates on sparse activations.

/// Reduction-dimension block: `KB` rows of `b` (`KB×n` floats) stay hot in
/// L1/L2 while the same block is replayed against the c-rows.
const KB: usize = 32;

/// Output-row register block for `matmul_acc`: this many c-rows share one
/// pass over a `b` block.
const RB: usize = 4;

/// SIMD lane width the column tiles are built from. 8 × f32 = one AVX2
/// register; NEON/SSE targets split each tile into two/four registers.
const LANES: usize = 8;

/// Widest column tile: 8 lane groups = 64 columns. Eight independent
/// 8-wide FMA chains per row are enough to hide FMA latency on two
/// issue ports.
const NWIDE: usize = 8;

/// Dot-product lanes for the `bt` flavor: four independent 8-wide chains.
const DOT_LANES: usize = 32;

/// Below this many FLOPs a kernel runs single-threaded: scoped-thread
/// spawn/join costs tens of µs, so only ms-scale matmuls amortize it.
const PAR_MIN_FLOPS: usize = 1 << 19;

/// Resolve the kernel-thread knob: a nonzero builder value wins, else the
/// `FERRET_KERNEL_THREADS` environment variable, else 1 (serial — the
/// deterministic planner-sweep default).
pub fn resolve_threads(knob: usize) -> usize {
    if knob != 0 {
        return knob;
    }
    std::env::var("FERRET_KERNEL_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

/// Effective worker count for an output of `rows` rows and `flops` total
/// FLOPs: capped by rows, forced serial under [`PAR_MIN_FLOPS`].
fn effective_threads(threads: usize, rows: usize, flops: usize) -> usize {
    let t = threads.max(1).min(rows.max(1));
    if flops < PAR_MIN_FLOPS {
        1
    } else {
        t
    }
}

fn rows_per_chunk(rows: usize, t: usize) -> usize {
    (rows + t - 1) / t
}

// ---------------------------------------------------------------------------
// naive reference kernels (shape-guarded; ground truth for tests + bench)
// ---------------------------------------------------------------------------

/// c (m x n) += a (m x k) @ b (k x n), row-major. Reference loops.
pub fn naive_matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // post-ReLU activations are sparse
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// c (m x n) += a (m x k) @ bᵀ where b is (n x k) row-major. Reference.
pub fn naive_matmul_bt_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for kk in 0..k {
                s += arow[kk] * brow[kk];
            }
            crow[j] += s;
        }
    }
}

/// c (m x n) += aᵀ @ b where a is (k x m), b is (k x n), row-major.
/// Reference.
pub fn naive_matmul_at_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// lane-blocked single-thread building blocks
// ---------------------------------------------------------------------------

/// One register tile of `NW × LANES` columns of one c-row, accumulated
/// over the `kb..ke` slice of the reduction dim. The a-element for step
/// `kk` is `a[aoff + kk * astep]` — `astep == 1` walks a row of `a`
/// (`matmul_acc`), `astep == m` walks a column (`matmul_at_acc`). The
/// accumulators live in `NW` fixed `[f32; LANES]` arrays that LLVM keeps
/// in SIMD registers; per element the adds run in ascending `kk` order,
/// so the tile is bit-identical to the naive loops.
#[inline(always)]
fn acc_tile<const NW: usize>(
    crow: &mut [f32],
    a: &[f32],
    aoff: usize,
    astep: usize,
    b: &[f32],
    kb: usize,
    ke: usize,
    n: usize,
    j0: usize,
) {
    let mut acc = [[0.0f32; LANES]; NW];
    for (g, accg) in acc.iter_mut().enumerate() {
        let c0 = j0 + g * LANES;
        accg.copy_from_slice(&crow[c0..c0 + LANES]);
    }
    for kk in kb..ke {
        let av = a[aoff + kk * astep];
        if av == 0.0 {
            continue; // post-ReLU sparsity; also keeps 0·inf out
        }
        let brow = &b[kk * n + j0..kk * n + j0 + NW * LANES];
        for (g, accg) in acc.iter_mut().enumerate() {
            let bseg = &brow[g * LANES..(g + 1) * LANES];
            for l in 0..LANES {
                accg[l] += av * bseg[l];
            }
        }
    }
    for (g, accg) in acc.iter().enumerate() {
        let c0 = j0 + g * LANES;
        crow[c0..c0 + LANES].copy_from_slice(accg);
    }
}

/// Scalar column tail `j0..n` of one c-row (same per-element order).
#[inline(always)]
fn acc_tail(
    crow: &mut [f32],
    a: &[f32],
    aoff: usize,
    astep: usize,
    b: &[f32],
    kb: usize,
    ke: usize,
    n: usize,
    j0: usize,
) {
    for kk in kb..ke {
        let av = a[aoff + kk * astep];
        if av == 0.0 {
            continue;
        }
        let brow = &b[kk * n..(kk + 1) * n];
        for j in j0..n {
            crow[j] += av * brow[j];
        }
    }
}

/// One c-row over one `kb..ke` cache block: widest tiles first, then
/// 8-wide tiles, then the scalar tail.
#[inline(always)]
fn acc_row(
    crow: &mut [f32],
    a: &[f32],
    aoff: usize,
    astep: usize,
    b: &[f32],
    kb: usize,
    ke: usize,
    n: usize,
) {
    let mut j0 = 0;
    while j0 + NWIDE * LANES <= n {
        acc_tile::<NWIDE>(crow, a, aoff, astep, b, kb, ke, n, j0);
        j0 += NWIDE * LANES;
    }
    while j0 + LANES <= n {
        acc_tile::<1>(crow, a, aoff, astep, b, kb, ke, n, j0);
        j0 += LANES;
    }
    if j0 < n {
        acc_tail(crow, a, aoff, astep, b, kb, ke, n, j0);
    }
}

/// Blocked `c += a @ b` over `rows` rows of `c`/`a`. `RB` c-rows replay
/// each `KB`-row block of `b` while it is cache-hot; within a row the
/// columns run as lane tiles ([`acc_row`]); per-element accumulation
/// stays in ascending `kk` order (bit-identical to naive).
fn matmul_acc_block(c: &mut [f32], a: &[f32], b: &[f32], rows: usize, k: usize, n: usize) {
    let mut ib = 0;
    while ib < rows {
        let ie = (ib + RB).min(rows);
        let mut kb = 0;
        while kb < k {
            let ke = (kb + KB).min(k);
            for i in ib..ie {
                acc_row(&mut c[i * n..(i + 1) * n], a, i * k, 1, b, kb, ke, n);
            }
            kb = ke;
        }
        ib = ie;
    }
}

/// `c += a @ bᵀ` over `rows` rows: each dot product accumulates on
/// [`DOT_LANES`] independent lanes (four 8-wide FMA chains) folded by a
/// fixed pairwise tree, plus an ascending scalar tail. The schedule is a
/// pure function of `k` — thread-invariant, but rounds differently from
/// the naive serial chain.
fn matmul_bt_block(c: &mut [f32], a: &[f32], b: &[f32], rows: usize, k: usize, n: usize) {
    let kv = k / DOT_LANES * DOT_LANES;
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut lanes = [0.0f32; DOT_LANES];
            let mut kk = 0;
            while kk < kv {
                for l in 0..DOT_LANES {
                    lanes[l] += arow[kk + l] * brow[kk + l];
                }
                kk += DOT_LANES;
            }
            // fixed pairwise tree: 32 → 16 → 8 → 4 → 2 → 1
            let mut width = DOT_LANES;
            while width > 1 {
                width /= 2;
                for l in 0..width {
                    lanes[l] += lanes[l + width];
                }
            }
            let mut s = lanes[0];
            for kk in kv..k {
                s += arow[kk] * brow[kk];
            }
            crow[j] += s;
        }
    }
}

/// `c += aᵀ @ b` for output rows `i0..i0+rows` of the full (m x n)
/// product; `a` is the full (k x m) matrix. Loop-interchanged so each
/// c-row stays hot across a `KB` block of the reduction dim; columns run
/// as lane tiles with the a-element strided down a column of `a`;
/// per-element order is ascending `kk` (bit-identical to naive).
fn matmul_at_block(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    i0: usize,
    m: usize,
    rows: usize,
    k: usize,
    n: usize,
) {
    let mut kb = 0;
    while kb < k {
        let ke = (kb + KB).min(k);
        for r in 0..rows {
            acc_row(&mut c[r * n..(r + 1) * n], a, i0 + r, m, b, kb, ke, n);
        }
        kb = ke;
    }
}

// ---------------------------------------------------------------------------
// public tiled entry points (optional row-parallel fan-out)
// ---------------------------------------------------------------------------

/// c (m x n) += a (m x k) @ b (k x n). Lane-tiled; splits c-rows across
/// up to `threads` scoped workers. Bit-identical to [`naive_matmul_acc`].
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let t = effective_threads(threads, m, 2 * m * k * n);
    if t <= 1 {
        matmul_acc_block(c, a, b, m, k, n);
        return;
    }
    let per = rows_per_chunk(m, t);
    std::thread::scope(|s| {
        for (ci, ai) in c.chunks_mut(per * n).zip(a.chunks(per * k)) {
            let rows = ci.len() / n;
            s.spawn(move || matmul_acc_block(ci, ai, b, rows, k, n));
        }
    });
}

/// c (m x n) += a (m x k) @ bᵀ, b (n x k). Lane-parallel dot products;
/// splits c-rows across up to `threads` scoped workers. Result is
/// independent of the thread count (fixed per-element order) but rounds
/// differently from [`naive_matmul_bt_acc`].
pub fn matmul_bt_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let t = effective_threads(threads, m, 2 * m * k * n);
    if t <= 1 {
        matmul_bt_block(c, a, b, m, k, n);
        return;
    }
    let per = rows_per_chunk(m, t);
    std::thread::scope(|s| {
        for (ci, ai) in c.chunks_mut(per * n).zip(a.chunks(per * k)) {
            let rows = ci.len() / n;
            s.spawn(move || matmul_bt_block(ci, ai, b, rows, k, n));
        }
    });
}

/// c (m x n) += aᵀ @ b, a (k x m), b (k x n). Lane-tiled; splits c-rows
/// across up to `threads` scoped workers. Bit-identical to
/// [`naive_matmul_at_acc`].
pub fn matmul_at_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let t = effective_threads(threads, m, 2 * m * k * n);
    if t <= 1 {
        matmul_at_block(c, a, b, 0, m, m, k, n);
        return;
    }
    let per = rows_per_chunk(m, t);
    std::thread::scope(|s| {
        let mut i0 = 0;
        for ci in c.chunks_mut(per * n) {
            let rows = ci.len() / n;
            s.spawn(move || matmul_at_block(ci, a, b, i0, m, rows, k, n));
            i0 += rows;
        }
    });
}

/// Fused dense forward: `z = x @ w + bias`, optional ReLU — the bias init
/// and the activation run inside each worker's row chunk, so the whole
/// layer is one pass per chunk instead of three over `z`.
#[allow(clippy::too_many_arguments)]
pub fn dense_fwd_into(
    z: &mut [f32],
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    batch: usize,
    k: usize,
    n: usize,
    relu: bool,
    threads: usize,
) {
    debug_assert_eq!(x.len(), batch * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(z.len(), batch * n);
    let t = effective_threads(threads, batch, 2 * batch * k * n);
    let fill = |zc: &mut [f32], xc: &[f32]| {
        let rows = zc.len() / n;
        for r in 0..rows {
            zc[r * n..(r + 1) * n].copy_from_slice(bias);
        }
        matmul_acc_block(zc, xc, w, rows, k, n);
        if relu {
            relu_inplace(zc);
        }
    };
    if t <= 1 {
        fill(z, x);
        return;
    }
    let per = rows_per_chunk(batch, t);
    let fill = &fill;
    std::thread::scope(|s| {
        for (zc, xc) in z.chunks_mut(per * n).zip(x.chunks(per * k)) {
            s.spawn(move || fill(zc, xc));
        }
    });
}

/// In-place ReLU in [`LANES`]-wide blocks plus a scalar tail.
#[inline(always)]
fn relu_inplace(z: &mut [f32]) {
    let mut it = z.chunks_exact_mut(LANES);
    for block in &mut it {
        for v in block {
            *v = v.max(0.0);
        }
    }
    for v in it.into_remainder() {
        *v = v.max(0.0);
    }
}

/// Fused ReLU mask: `gz[i] = if z[i] <= 0 { 0 } else { g[i] }` in one pass
/// straight into the (pooled) output buffer; [`LANES`]-wide blocks so the
/// select lowers to a SIMD blend.
pub fn relu_mask_into(gz: &mut [f32], g: &[f32], z: &[f32]) {
    debug_assert_eq!(gz.len(), g.len());
    debug_assert_eq!(gz.len(), z.len());
    let blocks = gz.len() / LANES * LANES;
    let (gzb, gzt) = gz.split_at_mut(blocks);
    for ((ob, gb), zb) in gzb
        .chunks_exact_mut(LANES)
        .zip(g[..blocks].chunks_exact(LANES))
        .zip(z[..blocks].chunks_exact(LANES))
    {
        for l in 0..LANES {
            ob[l] = if zb[l] <= 0.0 { 0.0 } else { gb[l] };
        }
    }
    for ((o, &gv), &zv) in gzt.iter_mut().zip(&g[blocks..]).zip(&z[blocks..]) {
        *o = if zv <= 0.0 { 0.0 } else { gv };
    }
}

/// Elementwise SGD step into a (pooled, possibly dirty) output buffer:
/// `out[i] = p[i] - lr * g[i]` in [`LANES`]-wide blocks. Pure map — the
/// blocking never changes bits, it only guarantees the vector lowering.
pub fn sgd_into(out: &mut [f32], p: &[f32], g: &[f32], lr: f32) {
    debug_assert_eq!(out.len(), p.len());
    debug_assert_eq!(out.len(), g.len());
    let blocks = out.len() / LANES * LANES;
    let (ob, ot) = out.split_at_mut(blocks);
    for ((o, pb), gb) in ob
        .chunks_exact_mut(LANES)
        .zip(p[..blocks].chunks_exact(LANES))
        .zip(g[..blocks].chunks_exact(LANES))
    {
        for l in 0..LANES {
            o[l] = pb[l] - lr * gb[l];
        }
    }
    for ((o, &pv), &gv) in ot.iter_mut().zip(&p[blocks..]).zip(&g[blocks..]) {
        *o = pv - lr * gv;
    }
}

/// Fisher compensation map into `out`: `out[i] = g[i] + lam·g[i]²·d[i]`
/// ([`crate::compensate`] Eq. 9 inner step), [`LANES`]-wide blocks.
pub fn compensate_into(out: &mut [f32], g: &[f32], d: &[f32], lam: f32) {
    debug_assert_eq!(out.len(), g.len());
    debug_assert_eq!(out.len(), d.len());
    let blocks = out.len() / LANES * LANES;
    let (ob, ot) = out.split_at_mut(blocks);
    for ((o, gb), db) in ob
        .chunks_exact_mut(LANES)
        .zip(g[..blocks].chunks_exact(LANES))
        .zip(d[..blocks].chunks_exact(LANES))
    {
        for l in 0..LANES {
            o[l] = gb[l] + lam * gb[l] * gb[l] * db[l];
        }
    }
    for ((o, &gv), &dv) in ot.iter_mut().zip(&g[blocks..]).zip(&d[blocks..]) {
        *o = gv + lam * gv * gv * dv;
    }
}

/// In-place Fisher compensation: `g[i] += lam·g[i]²·d[i]`, [`LANES`]-wide
/// blocks, no allocation (the freerun update hot path).
pub fn compensate_slice_inplace(g: &mut [f32], d: &[f32], lam: f32) {
    debug_assert_eq!(g.len(), d.len());
    let blocks = g.len() / LANES * LANES;
    let (gb, gt) = g.split_at_mut(blocks);
    for (gseg, dseg) in gb.chunks_exact_mut(LANES).zip(d[..blocks].chunks_exact(LANES)) {
        for l in 0..LANES {
            let g0 = gseg[l];
            gseg[l] = g0 + lam * g0 * g0 * dseg[l];
        }
    }
    for (gv, &dv) in gt.iter_mut().zip(&d[blocks..]) {
        let g0 = *gv;
        *gv = g0 + lam * g0 * g0 * dv;
    }
}

/// Column reduction `gb[j] += Σ_i gz[i·n + j]` (bias gradient). Lane
/// tiles hold 8 column sums in registers across all rows; per element
/// the row order is ascending `i` — bit-identical to the scalar loop.
pub fn col_sum_acc(gb: &mut [f32], gz: &[f32], rows: usize, n: usize) {
    debug_assert_eq!(gb.len(), n);
    debug_assert_eq!(gz.len(), rows * n);
    let blocks = n / LANES * LANES;
    let mut j0 = 0;
    while j0 < blocks {
        let mut acc: [f32; LANES] = gb[j0..j0 + LANES].try_into().expect("lane block");
        for i in 0..rows {
            let row = &gz[i * n + j0..i * n + j0 + LANES];
            for l in 0..LANES {
                acc[l] += row[l];
            }
        }
        gb[j0..j0 + LANES].copy_from_slice(&acc);
        j0 += LANES;
    }
    for j in blocks..n {
        let mut s = gb[j];
        for i in 0..rows {
            s += gz[i * n + j];
        }
        gb[j] = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{property, Rng};

    fn randvec(rng: &mut Rng, n: usize, sparse: bool) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if sparse && rng.uniform() < 0.5 {
                    0.0
                } else {
                    rng.normal_f32(0.0, 1.0)
                }
            })
            .collect()
    }

    #[test]
    fn tiled_acc_and_at_are_bit_identical_to_naive() {
        property("kern_exact", 20, |rng| {
            let (m, k, n) = (1 + rng.below(33), 1 + rng.below(70), 1 + rng.below(40));
            let sparse = rng.uniform() < 0.5;
            let a = randvec(rng, m * k, sparse);
            let b = randvec(rng, k * n, false);
            let at = randvec(rng, k * m, sparse);
            for threads in [1, 3] {
                let mut c0 = randvec(rng, m * n, false);
                let mut c1 = c0.clone();
                naive_matmul_acc(&mut c0, &a, &b, m, k, n);
                matmul_acc(&mut c1, &a, &b, m, k, n, threads);
                assert_eq!(c0, c1, "acc m={m} k={k} n={n} t={threads}");

                let mut d0 = randvec(rng, m * n, false);
                let mut d1 = d0.clone();
                naive_matmul_at_acc(&mut d0, &at, &b, m, k, n);
                matmul_at_acc(&mut d1, &at, &b, m, k, n, threads);
                assert_eq!(d0, d1, "at m={m} k={k} n={n} t={threads}");
            }
        });
    }

    #[test]
    fn lane_tiles_are_bit_identical_across_every_tile_boundary() {
        // deterministic sweep across the tile-width boundaries: scalar
        // tail only (n < 8), one 8-wide tile, the 64-wide tile edge, and
        // k straddling the KB cache block
        let mut rng = Rng::new(0xD06_F00D);
        for n in [1, 7, 8, 9, 63, 64, 65, 72] {
            for k in [1, 7, 31, 32, 33, 40] {
                let m = 5;
                let a = randvec(&mut rng, m * k, true);
                let b = randvec(&mut rng, k * n, false);
                let at = randvec(&mut rng, k * m, true);
                let mut c0 = randvec(&mut rng, m * n, false);
                let mut c1 = c0.clone();
                naive_matmul_acc(&mut c0, &a, &b, m, k, n);
                matmul_acc(&mut c1, &a, &b, m, k, n, 1);
                assert_eq!(c0, c1, "acc k={k} n={n}");
                let mut d0 = randvec(&mut rng, m * n, false);
                let mut d1 = d0.clone();
                naive_matmul_at_acc(&mut d0, &at, &b, m, k, n);
                matmul_at_acc(&mut d1, &at, &b, m, k, n, 1);
                assert_eq!(d0, d1, "at k={k} n={n}");
            }
        }
    }

    #[test]
    fn bt_matches_naive_within_tolerance_and_is_thread_invariant() {
        property("kern_bt", 20, |rng| {
            let (m, k, n) = (1 + rng.below(33), 1 + rng.below(70), 1 + rng.below(40));
            let a = randvec(rng, m * k, false);
            let b = randvec(rng, n * k, false);
            let mut c0 = vec![0.0f32; m * n];
            naive_matmul_bt_acc(&mut c0, &a, &b, m, k, n);
            let mut c1 = vec![0.0f32; m * n];
            matmul_bt_acc(&mut c1, &a, &b, m, k, n, 1);
            let mut c3 = vec![0.0f32; m * n];
            matmul_bt_acc(&mut c3, &a, &b, m, k, n, 3);
            // lane accumulators round differently from the serial chain,
            // but identically for every thread count
            assert_eq!(c1, c3, "bt thread-variant m={m} k={k} n={n}");
            for (x, y) in c0.iter().zip(&c1) {
                assert!((x - y).abs() < 1e-3 * (1.0 + x.abs()), "bt {x} vs {y}");
            }
        });
    }

    #[test]
    fn fused_fwd_matches_unfused() {
        property("kern_fwd", 20, |rng| {
            let (b, k, n) = (1 + rng.below(18), 1 + rng.below(30), 1 + rng.below(30));
            let x = randvec(rng, b * k, rng.uniform() < 0.5);
            let w = randvec(rng, k * n, false);
            let bias = randvec(rng, n, false);
            let relu = rng.uniform() < 0.5;
            // reference: bias-init rows, naive matmul, then activation
            let mut zr = vec![0.0f32; b * n];
            for r in 0..b {
                zr[r * n..(r + 1) * n].copy_from_slice(&bias);
            }
            naive_matmul_acc(&mut zr, &x, &w, b, k, n);
            if relu {
                zr.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            for threads in [1, 3] {
                let mut z = vec![7.0f32; b * n]; // junk: must be fully overwritten
                dense_fwd_into(&mut z, &x, &w, &bias, b, k, n, relu, threads);
                assert_eq!(z, zr, "fwd b={b} k={k} n={n} relu={relu} t={threads}");
            }
        });
    }

    #[test]
    fn relu_mask_zeroes_non_positive_slots() {
        let z = [1.0, 0.0, -2.0, 0.5];
        let g = [10.0, 20.0, 30.0, 40.0];
        let mut gz = [9.0f32; 4];
        relu_mask_into(&mut gz, &g, &z);
        assert_eq!(gz, [10.0, 0.0, 0.0, 40.0]);
        // across the lane boundary too
        let n = LANES + 3;
        let z: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let g: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        let mut gz = vec![9.0f32; n];
        relu_mask_into(&mut gz, &g, &z);
        for i in 0..n {
            assert_eq!(gz[i], if i % 2 == 0 { g[i] } else { 0.0 }, "slot {i}");
        }
    }

    #[test]
    fn elementwise_maps_match_scalar_reference_across_lane_boundaries() {
        let mut rng = Rng::new(77);
        for len in [1, 7, 8, 9, 24, 65] {
            let p = randvec(&mut rng, len, false);
            let g = randvec(&mut rng, len, false);
            let d = randvec(&mut rng, len, false);
            let mut out = vec![9.0f32; len];
            sgd_into(&mut out, &p, &g, 0.05);
            let want: Vec<f32> = p.iter().zip(&g).map(|(&pv, &gv)| pv - 0.05 * gv).collect();
            assert_eq!(out, want, "sgd len={len}");
            let mut out = vec![9.0f32; len];
            compensate_into(&mut out, &g, &d, 0.2);
            let want: Vec<f32> =
                g.iter().zip(&d).map(|(&gv, &dv)| gv + 0.2 * gv * gv * dv).collect();
            assert_eq!(out, want, "compensate len={len}");
            let mut gi = g.clone();
            compensate_slice_inplace(&mut gi, &d, 0.2);
            assert_eq!(gi, want, "compensate_inplace len={len}");
        }
    }

    #[test]
    fn col_sum_matches_scalar_reference_bitwise() {
        let mut rng = Rng::new(78);
        for (rows, n) in [(1, 1), (3, 7), (4, 8), (5, 13), (16, 65)] {
            let gz = randvec(&mut rng, rows * n, false);
            let init = randvec(&mut rng, n, false);
            let mut want = init.clone();
            for i in 0..rows {
                for j in 0..n {
                    want[j] += gz[i * n + j];
                }
            }
            let mut got = init.clone();
            col_sum_acc(&mut got, &gz, rows, n);
            assert_eq!(got, want, "rows={rows} n={n}");
        }
    }

    #[test]
    fn resolve_threads_prefers_knob_then_env_default_one() {
        assert_eq!(resolve_threads(3), 3);
        // knob 0 + unset env -> 1 (the test env must not set the var)
        if std::env::var("FERRET_KERNEL_THREADS").is_err() {
            assert_eq!(resolve_threads(0), 1);
        }
    }
}
