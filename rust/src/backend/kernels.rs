//! Tiled / register-blocked matmul kernels with optional row-parallelism.
//!
//! This is the numeric hot path of [`super::native::NativeBackend`]. Three
//! matmul flavors cover one dense layer's step (fwd `x@w`, bwd-input
//! `g@wᵀ`, bwd-weight `xᵀ@g`), each in two forms:
//!
//!   - `naive_*` — the straight reference loops (the pre-tiling kernels,
//!     kept as the ground truth for property tests and the kernel bench);
//!   - the tiled entry points — cache-blocked over the reduction dim, with
//!     the working c-rows kept hot across a block, and an optional
//!     `std::thread::scope` fan-out that splits the *output rows* across
//!     `threads` workers.
//!
//! # Determinism contract
//!
//! `matmul_acc` and `matmul_at_acc` accumulate every output element in
//! ascending reduction (`kk`) order — exactly the order of the naive
//! loops — and the parallel path partitions whole output rows, so their
//! results are **bit-identical** to the naive kernels for every thread
//! count. `matmul_bt_acc` breaks each dot product into four independent
//! accumulators (the serial FP chain is latency-bound); its rounding
//! differs from the naive kernel, but the order is still fixed per
//! element, so it too is bit-identical *across thread counts*. Net:
//! changing `FERRET_KERNEL_THREADS` never changes any numeric result, and
//! lockstep runs stay deterministic. Planner sweeps default to 1 thread
//! only to avoid oversubscription, not for reproducibility.
//!
//! The post-ReLU sparse-skip fast path (`av == 0.0 → skip`) of the
//! forward/weight kernels is preserved in the tiled forms.

/// Reduction-dimension block: `KB` rows of `b` (`KB×n` floats) stay hot in
/// L1/L2 while the same block is replayed against the c-rows.
const KB: usize = 32;

/// Output-row register block for `matmul_acc`: this many c-rows share one
/// pass over a `b` block.
const RB: usize = 4;

/// Below this many FLOPs a kernel runs single-threaded: scoped-thread
/// spawn/join costs tens of µs, so only ms-scale matmuls amortize it.
const PAR_MIN_FLOPS: usize = 1 << 19;

/// Resolve the kernel-thread knob: a nonzero builder value wins, else the
/// `FERRET_KERNEL_THREADS` environment variable, else 1 (serial — the
/// deterministic planner-sweep default).
pub fn resolve_threads(knob: usize) -> usize {
    if knob != 0 {
        return knob;
    }
    std::env::var("FERRET_KERNEL_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

/// Effective worker count for an output of `rows` rows and `flops` total
/// FLOPs: capped by rows, forced serial under [`PAR_MIN_FLOPS`].
fn effective_threads(threads: usize, rows: usize, flops: usize) -> usize {
    let t = threads.max(1).min(rows.max(1));
    if flops < PAR_MIN_FLOPS {
        1
    } else {
        t
    }
}

fn rows_per_chunk(rows: usize, t: usize) -> usize {
    (rows + t - 1) / t
}

// ---------------------------------------------------------------------------
// naive reference kernels (shape-guarded; ground truth for tests + bench)
// ---------------------------------------------------------------------------

/// c (m x n) += a (m x k) @ b (k x n), row-major. Reference loops.
pub fn naive_matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // post-ReLU activations are sparse
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// c (m x n) += a (m x k) @ bᵀ where b is (n x k) row-major. Reference.
pub fn naive_matmul_bt_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for kk in 0..k {
                s += arow[kk] * brow[kk];
            }
            crow[j] += s;
        }
    }
}

/// c (m x n) += aᵀ @ b where a is (k x m), b is (k x n), row-major.
/// Reference.
pub fn naive_matmul_at_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// tiled single-thread blocks
// ---------------------------------------------------------------------------

/// Blocked `c += a @ b` over `rows` rows of `c`/`a`. `RB` c-rows replay
/// each `KB`-row block of `b` while it is cache-hot; per-element
/// accumulation stays in ascending `kk` order (bit-identical to naive).
fn matmul_acc_block(c: &mut [f32], a: &[f32], b: &[f32], rows: usize, k: usize, n: usize) {
    let mut ib = 0;
    while ib < rows {
        let ie = (ib + RB).min(rows);
        let mut kb = 0;
        while kb < k {
            let ke = (kb + KB).min(k);
            for i in ib..ie {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in kb..ke {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
            kb = ke;
        }
        ib = ie;
    }
}

/// `c += a @ bᵀ` over `rows` rows: each dot product runs on four
/// independent accumulators to break the serial FP add chain.
fn matmul_bt_block(c: &mut [f32], a: &[f32], b: &[f32], rows: usize, k: usize, n: usize) {
    let k4 = k / 4 * 4;
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut kk = 0;
            while kk < k4 {
                s0 += arow[kk] * brow[kk];
                s1 += arow[kk + 1] * brow[kk + 1];
                s2 += arow[kk + 2] * brow[kk + 2];
                s3 += arow[kk + 3] * brow[kk + 3];
                kk += 4;
            }
            let mut s = (s0 + s1) + (s2 + s3);
            for kk in k4..k {
                s += arow[kk] * brow[kk];
            }
            crow[j] += s;
        }
    }
}

/// `c += aᵀ @ b` for output rows `i0..i0+rows` of the full (m x n)
/// product; `a` is the full (k x m) matrix. Loop-interchanged so each
/// c-row stays hot across a `KB` block of the reduction dim; per-element
/// order is ascending `kk` (bit-identical to naive).
fn matmul_at_block(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    i0: usize,
    m: usize,
    rows: usize,
    k: usize,
    n: usize,
) {
    let mut kb = 0;
    while kb < k {
        let ke = (kb + KB).min(k);
        for r in 0..rows {
            let crow = &mut c[r * n..(r + 1) * n];
            for kk in kb..ke {
                let av = a[kk * m + i0 + r];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
        kb = ke;
    }
}

// ---------------------------------------------------------------------------
// public tiled entry points (optional row-parallel fan-out)
// ---------------------------------------------------------------------------

/// c (m x n) += a (m x k) @ b (k x n). Tiled; splits c-rows across up to
/// `threads` scoped workers. Bit-identical to [`naive_matmul_acc`].
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let t = effective_threads(threads, m, 2 * m * k * n);
    if t <= 1 {
        matmul_acc_block(c, a, b, m, k, n);
        return;
    }
    let per = rows_per_chunk(m, t);
    std::thread::scope(|s| {
        for (ci, ai) in c.chunks_mut(per * n).zip(a.chunks(per * k)) {
            let rows = ci.len() / n;
            s.spawn(move || matmul_acc_block(ci, ai, b, rows, k, n));
        }
    });
}

/// c (m x n) += a (m x k) @ bᵀ, b (n x k). Unrolled dot products; splits
/// c-rows across up to `threads` scoped workers. Result is independent of
/// the thread count (fixed per-element order) but rounds differently from
/// [`naive_matmul_bt_acc`].
pub fn matmul_bt_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let t = effective_threads(threads, m, 2 * m * k * n);
    if t <= 1 {
        matmul_bt_block(c, a, b, m, k, n);
        return;
    }
    let per = rows_per_chunk(m, t);
    std::thread::scope(|s| {
        for (ci, ai) in c.chunks_mut(per * n).zip(a.chunks(per * k)) {
            let rows = ci.len() / n;
            s.spawn(move || matmul_bt_block(ci, ai, b, rows, k, n));
        }
    });
}

/// c (m x n) += aᵀ @ b, a (k x m), b (k x n). Tiled; splits c-rows across
/// up to `threads` scoped workers. Bit-identical to
/// [`naive_matmul_at_acc`].
pub fn matmul_at_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let t = effective_threads(threads, m, 2 * m * k * n);
    if t <= 1 {
        matmul_at_block(c, a, b, 0, m, m, k, n);
        return;
    }
    let per = rows_per_chunk(m, t);
    std::thread::scope(|s| {
        let mut i0 = 0;
        for ci in c.chunks_mut(per * n) {
            let rows = ci.len() / n;
            s.spawn(move || matmul_at_block(ci, a, b, i0, m, rows, k, n));
            i0 += rows;
        }
    });
}

/// Fused dense forward: `z = x @ w + bias`, optional ReLU — the bias init
/// and the activation run inside each worker's row chunk, so the whole
/// layer is one pass per chunk instead of three over `z`.
#[allow(clippy::too_many_arguments)]
pub fn dense_fwd_into(
    z: &mut [f32],
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    batch: usize,
    k: usize,
    n: usize,
    relu: bool,
    threads: usize,
) {
    debug_assert_eq!(x.len(), batch * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(z.len(), batch * n);
    let t = effective_threads(threads, batch, 2 * batch * k * n);
    let fill = |zc: &mut [f32], xc: &[f32]| {
        let rows = zc.len() / n;
        for r in 0..rows {
            zc[r * n..(r + 1) * n].copy_from_slice(bias);
        }
        matmul_acc_block(zc, xc, w, rows, k, n);
        if relu {
            zc.iter_mut().for_each(|v| *v = v.max(0.0));
        }
    };
    if t <= 1 {
        fill(z, x);
        return;
    }
    let per = rows_per_chunk(batch, t);
    let fill = &fill;
    std::thread::scope(|s| {
        for (zc, xc) in z.chunks_mut(per * n).zip(x.chunks(per * k)) {
            s.spawn(move || fill(zc, xc));
        }
    });
}

/// Fused ReLU mask: `gz[i] = if z[i] <= 0 { 0 } else { g[i] }` in one pass
/// straight into the (pooled) output buffer.
pub fn relu_mask_into(gz: &mut [f32], g: &[f32], z: &[f32]) {
    debug_assert_eq!(gz.len(), g.len());
    debug_assert_eq!(gz.len(), z.len());
    for ((o, &gv), &zv) in gz.iter_mut().zip(g).zip(z) {
        *o = if zv <= 0.0 { 0.0 } else { gv };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{property, Rng};

    fn randvec(rng: &mut Rng, n: usize, sparse: bool) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if sparse && rng.uniform() < 0.5 {
                    0.0
                } else {
                    rng.normal_f32(0.0, 1.0)
                }
            })
            .collect()
    }

    #[test]
    fn tiled_acc_and_at_are_bit_identical_to_naive() {
        property("kern_exact", 20, |rng| {
            let (m, k, n) = (1 + rng.below(33), 1 + rng.below(70), 1 + rng.below(40));
            let sparse = rng.uniform() < 0.5;
            let a = randvec(rng, m * k, sparse);
            let b = randvec(rng, k * n, false);
            let at = randvec(rng, k * m, sparse);
            for threads in [1, 3] {
                let mut c0 = randvec(rng, m * n, false);
                let mut c1 = c0.clone();
                naive_matmul_acc(&mut c0, &a, &b, m, k, n);
                matmul_acc(&mut c1, &a, &b, m, k, n, threads);
                assert_eq!(c0, c1, "acc m={m} k={k} n={n} t={threads}");

                let mut d0 = randvec(rng, m * n, false);
                let mut d1 = d0.clone();
                naive_matmul_at_acc(&mut d0, &at, &b, m, k, n);
                matmul_at_acc(&mut d1, &at, &b, m, k, n, threads);
                assert_eq!(d0, d1, "at m={m} k={k} n={n} t={threads}");
            }
        });
    }

    #[test]
    fn bt_matches_naive_within_tolerance_and_is_thread_invariant() {
        property("kern_bt", 20, |rng| {
            let (m, k, n) = (1 + rng.below(33), 1 + rng.below(70), 1 + rng.below(40));
            let a = randvec(rng, m * k, false);
            let b = randvec(rng, n * k, false);
            let mut c0 = vec![0.0f32; m * n];
            naive_matmul_bt_acc(&mut c0, &a, &b, m, k, n);
            let mut c1 = vec![0.0f32; m * n];
            matmul_bt_acc(&mut c1, &a, &b, m, k, n, 1);
            let mut c3 = vec![0.0f32; m * n];
            matmul_bt_acc(&mut c3, &a, &b, m, k, n, 3);
            // unrolled accumulators round differently from the serial
            // chain, but identically for every thread count
            assert_eq!(c1, c3, "bt thread-variant m={m} k={k} n={n}");
            for (x, y) in c0.iter().zip(&c1) {
                assert!((x - y).abs() < 1e-3 * (1.0 + x.abs()), "bt {x} vs {y}");
            }
        });
    }

    #[test]
    fn fused_fwd_matches_unfused() {
        property("kern_fwd", 20, |rng| {
            let (b, k, n) = (1 + rng.below(18), 1 + rng.below(30), 1 + rng.below(30));
            let x = randvec(rng, b * k, rng.uniform() < 0.5);
            let w = randvec(rng, k * n, false);
            let bias = randvec(rng, n, false);
            let relu = rng.uniform() < 0.5;
            // reference: bias-init rows, naive matmul, then activation
            let mut zr = vec![0.0f32; b * n];
            for r in 0..b {
                zr[r * n..(r + 1) * n].copy_from_slice(&bias);
            }
            naive_matmul_acc(&mut zr, &x, &w, b, k, n);
            if relu {
                zr.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            for threads in [1, 3] {
                let mut z = vec![7.0f32; b * n]; // junk: must be fully overwritten
                dense_fwd_into(&mut z, &x, &w, &bias, b, k, n, relu, threads);
                assert_eq!(z, zr, "fwd b={b} k={k} n={n} relu={relu} t={threads}");
            }
        });
    }

    #[test]
    fn relu_mask_zeroes_non_positive_slots() {
        let z = [1.0, 0.0, -2.0, 0.5];
        let g = [10.0, 20.0, 30.0, 40.0];
        let mut gz = [9.0f32; 4];
        relu_mask_into(&mut gz, &g, &z);
        assert_eq!(gz, [10.0, 0.0, 0.0, 40.0]);
    }

    #[test]
    fn resolve_threads_prefers_knob_then_env_default_one() {
        assert_eq!(resolve_threads(3), 3);
        // knob 0 + unset env -> 1 (the test env must not set the var)
        if std::env::var("FERRET_KERNEL_THREADS").is_err() {
            assert_eq!(resolve_threads(0), 1);
        }
    }
}
