//! Compute backends.
//!
//! `Backend` is the numeric contract of one pipeline-stage step. Two
//! implementations:
//!   - [`native::NativeBackend`] — pure-rust math on the tiled/parallel
//!     kernels in [`kernels`]. Used by unit tests and by the large table
//!     sweeps where thousands of runs make per-call PJRT dispatch the
//!     wrong tool — and, since the hot-path rebuild, by the throughput
//!     benches (`BENCH_0006.json`).
//!   - [`xla::XlaBackend`] — loads the AOT HLO-text artifacts emitted by
//!     `python/compile/aot.py` and executes them on the PJRT CPU client.
//!     This is the production request path; integration tests assert it
//!     matches `NativeBackend` to float tolerance.
//!
//! Two support layers sit underneath:
//!   - [`kernels`] — register-blocked/tiled matmuls with an optional
//!     scoped-thread row fan-out. Their determinism contract (bit-identical
//!     results for every thread count) is what lets the engine expose a
//!     kernel-thread knob without giving up lockstep reproducibility.
//!   - [`pool`] — the shared [`BufferPool`] + [`Workspace`]. The `*_pooled`
//!     trait methods take a `&Workspace` and draw scratch/output buffers
//!     from its pool; their defaults fall back to the allocating methods,
//!     so backends (e.g. XLA) adopt pooling incrementally.

pub mod kernels;
pub mod native;
pub mod pool;
pub mod xla;

pub use pool::{BufferPool, PoolStats, Workspace};

use crate::config::LayerShape;
use crate::model::{GradBuf, LayerParams};

/// Result of a backward step: gradient wrt the stage input plus the
/// parameter gradients.
pub struct BwdOut {
    pub gx: Vec<f32>,
    pub grads: GradBuf,
}

/// One pipeline stage's numeric operations. `batch` is the leading dim of
/// `x`/`g`; the XLA backend requires it to equal the artifact batch.
///
/// `Send + Sync` because the threaded pipeline executor shares one backend
/// reference across every (worker, stage) device thread.
///
/// The `*_pooled` variants are the hot-path forms: identical numerics, but
/// scratch and output buffers come from (and return to) the shared
/// [`Workspace`] pool, and the kernel fan-out width follows
/// `Workspace::threads`. Defaults delegate to the allocating methods so
/// implementing them is optional.
pub trait Backend: Send + Sync {
    /// y = act(x @ w + b); x: (batch, in_dim) row-major.
    fn dense_fwd(&self, shape: &LayerShape, p: &LayerParams, x: &[f32], batch: usize) -> Vec<f32>;

    /// Backward with activation recomputation; g: (batch, out_dim).
    fn dense_bwd(
        &self,
        shape: &LayerShape,
        p: &LayerParams,
        x: &[f32],
        g: &[f32],
        batch: usize,
    ) -> BwdOut;

    /// Pooled [`Backend::dense_fwd`]: the output comes from `ws.pool` (the
    /// caller owns it and should eventually `put` it back).
    fn dense_fwd_pooled(
        &self,
        shape: &LayerShape,
        p: &LayerParams,
        x: &[f32],
        batch: usize,
        ws: &Workspace,
    ) -> Vec<f32> {
        let _ = ws;
        self.dense_fwd(shape, p, x, batch)
    }

    /// Pooled [`Backend::dense_bwd`]: `gx`/`gw`/`gb` come from `ws.pool`;
    /// internal scratch is returned to the pool before this call returns.
    fn dense_bwd_pooled(
        &self,
        shape: &LayerShape,
        p: &LayerParams,
        x: &[f32],
        g: &[f32],
        batch: usize,
        ws: &Workspace,
    ) -> BwdOut {
        let _ = ws;
        self.dense_bwd(shape, p, x, g, batch)
    }

    /// Softmax cross-entropy head: (dL/dlogits, loss). labels: (batch,).
    fn loss_grad_ce(&self, classes: usize, logits: &[f32], labels: &[i32]) -> (Vec<f32>, f32);

    /// LwF head: CE + temperature-2 distillation toward `teacher` logits.
    fn loss_grad_lwf(
        &self,
        classes: usize,
        logits: &[f32],
        labels: &[i32],
        teacher: &[f32],
        alpha: f32,
    ) -> (Vec<f32>, f32);

    /// One Iter-Fisher compensation step (Eq. 8): g + lam * g^2 * dtheta.
    fn compensate(&self, g: &GradBuf, d: &GradBuf, lam: f32) -> GradBuf;

    /// In-place [`Backend::compensate`]: overwrites `g` with the
    /// compensated gradient (no allocation on the update path).
    fn compensate_inplace(&self, g: &mut GradBuf, d: &GradBuf, lam: f32) {
        *g = self.compensate(g, d, lam);
    }

    /// SGD step: p - lr * g.
    fn sgd(&self, p: &LayerParams, g: &GradBuf, lr: f32) -> LayerParams;

    /// Pooled [`Backend::sgd`]: the new parameter vectors come from
    /// `ws.pool` (retired versions are recycled back by the engine).
    fn sgd_pooled(&self, p: &LayerParams, g: &GradBuf, lr: f32, ws: &Workspace) -> LayerParams {
        let _ = ws;
        self.sgd(p, g, lr)
    }

    /// An owned, thread-shareable handle to this backend. Device threads
    /// of the session-owned [`crate::pipeline::executor::ThreadedExecutor`]
    /// capture the handle, which is what lets them outlive the borrow a
    /// call entered with (no `std::thread::scope` on the entry path). The
    /// handle must *share* internal state — e.g. compiled-executable
    /// caches — with `self`, not reinitialize it; stateless backends may
    /// return a fresh instance.
    fn share(&self) -> std::sync::Arc<dyn Backend>;
}

/// Forward a full dense stack, returning per-layer inputs (stashed for the
/// backward chain, T1-style) and the logits. Generic over owned
/// (`LayerParams`) and shared (`Arc<LayerParams>`) parameter slices.
pub fn forward_all<P: std::borrow::Borrow<LayerParams>>(
    backend: &dyn Backend,
    shapes: &[LayerShape],
    params: &[P],
    x: &[f32],
    batch: usize,
) -> (Vec<Vec<f32>>, Vec<f32>) {
    let mut inputs = Vec::with_capacity(shapes.len());
    let mut h = x.to_vec();
    for (shape, p) in shapes.iter().zip(params) {
        inputs.push(h.clone());
        h = backend.dense_fwd(shape, p.borrow(), &h, batch);
    }
    (inputs, h)
}

/// Backward a full dense stack given stashed inputs and dL/dlogits.
/// Returns per-layer gradients (aligned with `shapes`).
pub fn backward_all<P: std::borrow::Borrow<LayerParams>>(
    backend: &dyn Backend,
    shapes: &[LayerShape],
    params: &[P],
    inputs: &[Vec<f32>],
    gout: &[f32],
    batch: usize,
) -> Vec<GradBuf> {
    let mut grads: Vec<Option<GradBuf>> = (0..shapes.len()).map(|_| None).collect();
    let mut g = gout.to_vec();
    for i in (0..shapes.len()).rev() {
        let out = backend.dense_bwd(&shapes[i], params[i].borrow(), &inputs[i], &g, batch);
        g = out.gx;
        grads[i] = Some(out.grads);
    }
    grads.into_iter().map(Option::unwrap).collect()
}

/// Batch accuracy from logits (argmax) vs labels.
pub fn accuracy(classes: usize, logits: &[f32], labels: &[i32]) -> f64 {
    let batch = labels.len();
    debug_assert_eq!(logits.len(), batch * classes);
    let correct = labels
        .iter()
        .enumerate()
        .filter(|(i, &y)| {
            crate::util::argmax(&logits[i * classes..(i + 1) * classes]) == y as usize
        })
        .count();
    correct as f64 / batch as f64
}

/// Softmax cross-entropy loss only (no grad) — used by MIR's interference
/// scoring where gradients are not needed.
pub fn ce_loss(classes: usize, logits: &[f32], labels: &[i32]) -> f32 {
    let batch = labels.len();
    let mut loss = 0.0f32;
    for (i, &y) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
        loss += lse - row[y as usize];
    }
    loss / batch as f32
}
