//! Training engines — layered as clock / scheduler / executor / policy.
//!
//! All engines share the same contract: consume microbatches (from any
//! [`Stream`] — [`SyntheticStream`] is the built-in generator — or pushed
//! by hand through a [`session::Session`]), train through a [`Backend`]
//! with an [`OclPlugin`], and fill a [`RunMetrics`]. Time is measured in
//! ticks; data arrives every `t^d` ticks (one microbatch per arrival, the
//! paper's `D^t`).
//!
//! The subsystem is split into five layers, plus a cross-cutting
//! observability layer:
//!
//!   - [`sched`]    — the reusable scheduling core: event queue, 1F1B
//!     backward-preemption priority, microbatch→worker routing, per-stage
//!     version counters, admission capacity, freerun flight tracking, and
//!     the shared predict-and-drop path. Pure mechanism; no numerics.
//!     Also home of the time sources: [`sched::Mode`] selects between a
//!     [`sched::VirtualClock`] (lockstep — the event heap replays analytic
//!     `tf`/`tb` costs, deterministic and executor-independent) and a
//!     [`sched::WallClock`] (freerun — 1 tick = 1µs of real time; arrivals
//!     are paced by real intervals and `Done` completions are stamped when
//!     device threads actually finish).
//!   - [`executor`] — where device work runs. [`executor::SimExecutor`]
//!     computes inline on the scheduler thread (the planner's cheap
//!     discrete-event simulation); [`executor::ThreadedExecutor`] runs one
//!     OS thread per (worker, stage) device with channel-based
//!     activation/gradient exchange over `Arc`-shared parameter snapshots.
//!     Lockstep joins per-device FIFO (`finish`, metric-identical across
//!     executors); freerun drains whichever device finishes first
//!     (`try_finish_any` / `wait_any`). In freerun, SGD + gradient
//!     compensation also run on the owning device thread against
//!     [`executor::StageCell`]s, so observed staleness is emergent.
//!   - [`engine`] / [`sync`] — policy: the fine-grained asynchronous
//!     engine (Ferret, PipeDream, PipeDream-2BW — Table 3's right half)
//!     drives sched + executor and layers weight stashing, gradient
//!     compensation, and OCL plugins on top; [`sync`] covers the
//!     flight-based synchronous schedules (DAPPLE, Zero-Bubble,
//!     Hanayo-kW — Table 3's left half).
//!   - [`session`] — the public run surface. A [`session::Session`] owns
//!     the loop state the old run-to-completion functions kept on their
//!     stack (clocks, budget cursor, pending arrivals, the executor —
//!     including the device threads, joined on finish/drop), and exposes
//!     it incrementally: push-based `ingest`, `step`/`drain`, live
//!     `metrics()`, imperative `set_budget`, `finish`. Input comes from
//!     any [`crate::stream::Stream`] (via `run_stream`) or from hand-fed
//!     batches; `run_async`/`run_async_with` remain as thin shims.
//!   - [`crate::obs`] — the observability layer over all of the above.
//!     Both engines' dispatch/completion paths emit per-device
//!     Fwd/Bwd/Update/Augment spans (and the transition protocol emits
//!     Drain/Replan spans) into an opt-in [`crate::obs::Recorder`]
//!     stamped from the run's [`Clock`] — deterministic virtual ticks in
//!     lockstep, real microseconds in freerun. Derived accounting
//!     (per-device utilization, bubble fraction, stall attribution, a
//!     live staleness gauge, windowed latency percentiles) is exposed
//!     live via [`Session::obs_snapshot`], streamed as JSON lines
//!     (`--metrics-out`), or exported as a Perfetto/Chrome trace
//!     (`--span-trace`). Always-on (recorder-independent) busy/device
//!     time totals land in [`RunMetrics::busy_us`] /
//!     [`RunMetrics::device_us`], so `ferret replay --gate` can catch
//!     utilization regressions.
//!
//! Under a dynamic [`crate::budget::BudgetSchedule`], the async engine is
//! **phase-structured**: each phase runs one plan; a schedule step (or a
//! measured-memory ledger breach) triggers the plan-transition protocol —
//!
//!   1. *drain*: stop admitting, hold arriving batches (the stream does
//!      not wait), and let every in-flight microbatch finish under the
//!      old plan — no batch is lost or double-counted;
//!   2. *re-plan*: re-invoke Alg. 2/3 at the budget now in force, seeded
//!      with a profile refreshed from this run's measured per-stage
//!      forward/backward times (exactly the replayed analytic costs in
//!      lockstep, real device-thread service times in freerun);
//!   3. *transition*: carry the per-layer live weights and compensator
//!      state into the new partition (stages are views over layers, so
//!      merges/splits lose nothing), rebuild the scheduling core for the
//!      new worker/stage topology, restart the weight stash at version 0
//!      with plan-derived capacity, and `Executor::reconfigure` the
//!      device-thread set;
//!   4. *resume*: admit the held batches and continue the same stream.
//!
//! Lockstep transitions happen at batch-arrival boundaries and stay
//! deterministic and metric-identical across executors
//! (tests/budget_replan.rs); freerun drains against the wall clock.
//!
//! Single-device stream baselines (Oracle/1-Skip/…) live in
//! [`crate::baselines`].
//!
//! [`Stream`]: crate::stream::Stream
//! [`SyntheticStream`]: crate::stream::SyntheticStream
//! [`Backend`]: crate::backend::Backend
//! [`OclPlugin`]: crate::ocl::OclPlugin
//! [`RunMetrics`]: crate::metrics::RunMetrics

pub mod engine;
pub mod executor;
pub mod sched;
pub mod session;
pub mod sync;

pub use sched::{Clock, Mode, VirtualClock, WallClock};
pub use session::{Session, SessionBuilder, SessionStep};

use crate::metrics::RunMetrics;
use crate::model::SharedParams;

/// Engine-independent run parameters.
#[derive(Debug, Clone, Copy)]
pub struct EngineParams {
    /// SGD learning rate (paper §12: 1e-3; synthetic streams use larger)
    pub lr: f32,
    /// data-value decay constant `c` (Def. 4.1), per tick;
    /// 0.0 = derive as `decay_for_td(td)` (scale-invariant default)
    pub decay_c: f64,
    /// arrival interval `t^d` in ticks
    pub td: u64,
    /// held-out test samples per class for `tacc`
    pub tacc_per_class: usize,
    /// weight-init / tie-break seed
    pub seed: u64,
    /// per-layer version-stash capacity; 0 = derive from the worker/stage
    /// counts (deep-pipeline tests shrink it to force eviction fallbacks)
    pub stash_cap: usize,
    /// intra-stage kernel worker threads; 0 = read `FERRET_KERNEL_THREADS`
    /// (defaulting to 1 — serial, the planner-sweep default). The tiled
    /// kernels are bit-identical across thread counts, so this knob trades
    /// only wall-clock, never numerics (see [`crate::backend::kernels`]).
    pub kernel_threads: usize,
    /// pin each threaded-executor device thread to a CPU from the
    /// process's allowed set, round-robin in spawn order (`--pin-devices`;
    /// Linux `sched_setaffinity`, no-op elsewhere). A placement hint only
    /// — never affects numerics (see [`crate::util::affinity`]).
    pub pin_devices: bool,
}

impl Default for EngineParams {
    fn default() -> Self {
        EngineParams {
            lr: 0.05,
            decay_c: 0.0,
            td: 0, // 0 = derive from profile (max layer fwd time)
            tacc_per_class: 8,
            seed: 42,
            stash_cap: 0,
            kernel_threads: 0,
            pin_devices: false,
        }
    }
}

impl EngineParams {
    /// Resolve the decay constant against the actual arrival interval.
    pub fn decay(&self, td: u64) -> f64 {
        if self.decay_c > 0.0 {
            self.decay_c
        } else {
            crate::planner::costmodel::decay_for_td(td)
        }
    }
}

/// Outcome of one engine run.
pub struct RunResult {
    pub metrics: RunMetrics,
    /// final full-model parameters (for external evaluation)
    pub params: Vec<SharedParams>,
}
