//! Training engines over virtual time.
//!
//! All engines share the same contract: consume a [`SyntheticStream`],
//! train through a [`Backend`] with an [`OclPlugin`], and fill a
//! [`RunMetrics`]. Virtual time is measured in ticks; data arrives every
//! `t^d` ticks (one microbatch per arrival, the paper's `D^t`).
//!
//! - [`sync`]   — flight-based synchronous pipeline schedules
//!   (DAPPLE, Zero-Bubble, Hanayo-kW): Table 3's left half.
//! - [`engine`] — the fine-grained asynchronous event engine
//!   (Ferret, PipeDream, PipeDream-2BW): Table 3's right half and the
//!   system under test everywhere else.
//!
//! Single-device stream baselines (Oracle/1-Skip/…) live in
//! [`crate::baselines`].

pub mod engine;
pub mod sync;

use crate::metrics::RunMetrics;
use crate::model::LayerParams;

/// Engine-independent run parameters.
#[derive(Debug, Clone, Copy)]
pub struct EngineParams {
    /// SGD learning rate (paper §12: 1e-3; synthetic streams use larger)
    pub lr: f32,
    /// data-value decay constant `c` (Def. 4.1), per tick;
    /// 0.0 = derive as `decay_for_td(td)` (scale-invariant default)
    pub decay_c: f64,
    /// arrival interval `t^d` in ticks
    pub td: u64,
    /// held-out test samples per class for `tacc`
    pub tacc_per_class: usize,
    /// weight-init / tie-break seed
    pub seed: u64,
}

impl Default for EngineParams {
    fn default() -> Self {
        EngineParams {
            lr: 0.05,
            decay_c: 0.0,
            td: 0, // 0 = derive from profile (max layer fwd time)
            tacc_per_class: 8,
            seed: 42,
        }
    }
}

impl EngineParams {
    /// Resolve the decay constant against the actual arrival interval.
    pub fn decay(&self, td: u64) -> f64 {
        if self.decay_c > 0.0 {
            self.decay_c
        } else {
            crate::planner::costmodel::decay_for_td(td)
        }
    }
}

/// Outcome of one engine run.
pub struct RunResult {
    pub metrics: RunMetrics,
    /// final full-model parameters (for external evaluation)
    pub params: Vec<LayerParams>,
}
