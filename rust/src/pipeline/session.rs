//! Session-oriented engine API: a long-lived, push-based surface over the
//! asynchronous pipeline engine.
//!
//! The historical entry point ([`run_async_with`]) is a run-to-completion
//! free function: it demands the whole stream up front and only returns
//! once the stream ends. A production OCL service ingests *live* traffic —
//! batches arrive one at a time, metrics must be observable mid-stream,
//! and the memory budget can change while the learner is running. The
//! session API decomposes the run loop into exactly those pieces:
//!
//!   - [`Session::builder`] — validated construction
//!     (`Session::builder(backend, model).batch(..).plugin(..).build()?`);
//!     configuration mistakes surface as typed
//!     [`crate::util::error::Error`]s instead of engine panics. Omitting
//!     [`SessionBuilder::config`] auto-plans an unconstrained Ferret
//!     pipeline for the model.
//!   - [`Session::ingest`] — push one batch (non-blocking: it is queued
//!     and admitted at its scheduled arrival slot).
//!   - [`Session::step`] / [`Session::drain`] — advance the lockstep event
//!     heap (or the freerun completion loop) one event (resp. as far as it
//!     goes without more input).
//!   - [`Session::metrics`] — live [`RunMetrics`] snapshot mid-stream.
//!   - [`Session::set_budget`] — imperative budget change: triggers the
//!     same drain → re-plan → transition protocol as a
//!     `--budget-schedule` step (see `pipeline::engine`).
//!   - [`Session::finish`] — complete all outstanding work, evaluate the
//!     final metrics, join the device threads, and return the
//!     [`RunResult`].
//!
//! ## Lockstep exactness
//!
//! Driving a lockstep session by `ingest`/`step` reproduces the pull-based
//! [`run_async_with`] metrics *bit for bit* (pinned by
//! `tests/session.rs`). The subtlety is event-heap tie-breaking: equal
//! virtual times pop in insertion order, so the session must insert each
//! `Arrive` event at the same point in the event sequence the pull loop
//! would — i.e. while processing the *previous* arrival, whether or not
//! the next batch has been ingested yet. The session therefore schedules
//! each next arrival speculatively; if its batch has not arrived when the
//! event reaches the head of the heap, [`Session::step`] reports
//! [`SessionStep::Starved`] and leaves the heap untouched (a pop would
//! commit to an ordering the pull loop never sees). A speculative arrival
//! that never materialises is discarded at [`Session::finish`], exactly
//! where the pull loop would have stopped scheduling arrivals.
//!
//! ## Thread ownership
//!
//! A session owns its executor. For
//! [`ExecutorKind::Threaded`] that means the device threads themselves:
//! they capture [`Backend::share`] handles and are joined when the session
//! is finished *or dropped* — a session abandoned mid-stream cannot leak
//! device threads (`tests/session.rs` pins drop-without-finish). Nothing
//! on this path uses `std::thread::scope`, which is what frees the session
//! from the run-to-completion shape: a scope cannot outlive the function
//! call that opened it.

use std::collections::VecDeque;
use std::sync::MutexGuard;
use std::time::Duration;

use crate::backend::{kernels, Backend, BufferPool, PoolStats, Workspace};
use crate::bail;
use crate::budget::{BudgetSchedule, BudgetState};
use crate::compensate::CompKind;
use crate::config::{LayerShape, ModelSpec};
use crate::metrics::{eval_tacc, RunMetrics};
use crate::obs::SpanKind as ObsSpanKind;
use crate::ocl::{OclCtx, OclPlugin, PluginCell, Vanilla};
use crate::pipeline::engine::{AsyncCfg, AsyncEngine, EngineIo};
use crate::pipeline::executor::{Executor, ExecutorKind, SimExecutor, ThreadedExecutor};
use crate::pipeline::sched::{Clock, Ev, Mode, VirtualClock, WallClock};
use crate::pipeline::{EngineParams, RunResult};
use crate::planner::costmodel::{decay_for_td, mem_footprint};
use crate::planner::{plan, Profile};
use crate::stream::{arrival_interval_us, Batch, Stream, SyntheticStream, TestSet};
use crate::trace::{batch_hash, BatchRec, FinishRec, Header, ReplanRec, TraceWriter, WorkerRec};
use crate::util::error::Result;

/// The OCL plugin a session runs with: borrowed from the caller (the
/// common case — plugins are stateful and callers often inspect them
/// afterwards), owned (the builder's default no-op `Vanilla`), or shared
/// behind a [`PluginCell`] when the freerun augment offload is active —
/// the stage-0 device thread then runs the `augment` hook through the same
/// cell the scheduler steps through.
enum PluginSlot<'a> {
    Owned(Box<dyn OclPlugin>),
    Borrowed(&'a mut dyn OclPlugin),
    Shared(PluginCell),
}

/// Exclusive access to the session's plugin for the duration of one engine
/// step: a plain reborrow for owned/borrowed slots, a mutex guard for a
/// shared cell. Taken *after* any blocking executor wait, and only by
/// statements that hand it straight to [`EngineIo`] — a device thread
/// holding the cell for an `augment` call therefore never waits on the
/// scheduler, and vice versa.
enum PluginGuard<'g> {
    Direct(&'g mut dyn OclPlugin),
    Locked(MutexGuard<'g, Box<dyn OclPlugin>>),
}

impl PluginGuard<'_> {
    fn as_mut(&mut self) -> &mut dyn OclPlugin {
        match self {
            PluginGuard::Direct(p) => &mut **p,
            PluginGuard::Locked(g) => &mut ***g,
        }
    }
}

impl PluginSlot<'_> {
    fn guard(&mut self) -> PluginGuard<'_> {
        match self {
            PluginSlot::Owned(p) => PluginGuard::Direct(p.as_mut()),
            PluginSlot::Borrowed(p) => PluginGuard::Direct(&mut **p),
            PluginSlot::Shared(c) => PluginGuard::Locked(c.lock()),
        }
    }

    /// Run `f` against the plugin (locking a shared cell for the call).
    fn with<R>(&self, f: impl FnOnce(&dyn OclPlugin) -> R) -> R {
        match self {
            PluginSlot::Owned(p) => f(p.as_ref()),
            PluginSlot::Borrowed(p) => f(&**p),
            PluginSlot::Shared(c) => f(c.lock().as_ref()),
        }
    }
}

/// Outcome of one [`Session::step`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionStep {
    /// An event, completion, or plan transition was processed.
    Progressed,
    /// Blocked on something the caller controls or time provides: the
    /// next scheduled arrival has no ingested batch yet (lockstep), or
    /// all outstanding work is in flight / not yet due (freerun —
    /// [`Session::drain`] blocks through these).
    Starved,
    /// Everything ingested so far is fully processed.
    Idle,
}

/// Validated builder for a [`Session`] — see [`Session::builder`].
pub struct SessionBuilder<'a> {
    backend: &'a dyn Backend,
    model: &'a ModelSpec,
    cfg: Option<AsyncCfg>,
    plugin: PluginSlot<'a>,
    executor: ExecutorKind,
    mode: Mode,
    ep: EngineParams,
    budget: Option<BudgetSchedule>,
    batch: usize,
    test: Option<TestSet>,
    /// micro-benchmark reps for a measured initial profile (0 = analytic)
    measured_reps: u32,
    /// trace artifact destination ([`SessionBuilder::record_trace`])
    trace_path: Option<String>,
    /// pre-built trace sink; takes precedence over `trace_path`
    trace_writer: Option<TraceWriter>,
    /// enable the span recorder without any export
    /// ([`SessionBuilder::record_spans`])
    record_spans: bool,
    /// Chrome trace-event export destination ([`SessionBuilder::span_trace`])
    span_trace: Option<String>,
    /// snapshot-stream destination + cadence in arrivals
    /// ([`SessionBuilder::metrics_out`])
    metrics_out: Option<(String, u64)>,
}

impl<'a> SessionBuilder<'a> {
    /// Engine configuration (schedule family, partition, T1–T4 knobs,
    /// compensation, budget schedule). Omitted: an unconstrained Ferret
    /// plan for the model with Iter-Fisher compensation.
    pub fn config(mut self, cfg: AsyncCfg) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// OCL plugin hooks (replay mixing, distillation, importance
    /// regularization). Default: [`Vanilla`] (no forgetting mitigation).
    pub fn plugin(mut self, plugin: &'a mut dyn OclPlugin) -> Self {
        self.plugin = PluginSlot::Borrowed(plugin);
        self
    }

    /// Owned variant of [`SessionBuilder::plugin`] for callers that do not
    /// need the plugin back after the run.
    pub fn owned_plugin(mut self, plugin: Box<dyn OclPlugin>) -> Self {
        self.plugin = PluginSlot::Owned(plugin);
        self
    }

    /// Where device work runs. Default: [`ExecutorKind::Sim`] (inline).
    pub fn executor(mut self, kind: ExecutorKind) -> Self {
        self.executor = kind;
        self
    }

    /// Time source. Default: [`Mode::Lockstep`] (virtual, deterministic).
    pub fn mode(mut self, mode: Mode) -> Self {
        self.mode = mode;
        self
    }

    /// Time-varying budget schedule; overrides the one in the config.
    /// (For an *imperative* change mid-stream use [`Session::set_budget`].)
    pub fn budget(mut self, schedule: BudgetSchedule) -> Self {
        self.budget = Some(schedule);
        self
    }

    /// Engine-independent run parameters (lr, seed, t^d, stash capacity).
    pub fn engine_params(mut self, ep: EngineParams) -> Self {
        self.ep = ep;
        self
    }

    /// Microbatch rows per ingested batch. Required: the analytic profile
    /// (stage times, default t^d) is resolved against it at build time.
    pub fn batch(mut self, rows: usize) -> Self {
        self.batch = rows;
        self
    }

    /// Held-out evaluation set for the final test accuracy. Optional: a
    /// hand-fed session without one reports `tacc = 0`, and
    /// [`Session::run_stream`] takes the test set from its stream.
    pub fn test_set(mut self, test: TestSet) -> Self {
        self.test = Some(test);
        self
    }

    /// Seed the *initial* plan from a measured profile
    /// ([`Profile::measured`] — `reps` timed fwd/bwd reps per layer on this
    /// session's backend) instead of the analytic FLOPs model. Default off
    /// (`reps = 0`): measured initial profiles are wall-clock dependent, so
    /// deterministic sweeps and the lockstep equivalence suites keep the
    /// analytic base. Mid-stream re-plans already fold measured stage
    /// times in either way.
    pub fn measured_profile(mut self, reps: u32) -> Self {
        self.measured_reps = reps;
        self
    }

    /// Record this session's run as a `ferret-trace/1` JSON-lines artifact
    /// at `path` (see the [`crate::trace`] module docs for the schema and
    /// the determinism contract): stream identity (per-batch content
    /// hashes, arrival stamps) plus every planner decision. The file is
    /// created at build time; parent directories are created as needed.
    pub fn record_trace(mut self, path: &str) -> Self {
        self.trace_path = Some(path.to_string());
        self
    }

    /// Record into a caller-supplied sink instead of a file — e.g.
    /// [`TraceWriter::in_memory`] for replay drivers and tests.
    pub fn record_trace_writer(mut self, writer: TraceWriter) -> Self {
        self.trace_writer = Some(writer);
        self
    }

    /// Enable the span recorder (see [`crate::obs`]) without exporting
    /// anything: per-device span timelines and the derived pipeline
    /// accounting become observable live via [`Session::obs_snapshot`].
    /// Implied by [`SessionBuilder::span_trace`] and
    /// [`SessionBuilder::metrics_out`]. Default off — a disabled recorder
    /// is a single no-op enum match on the hot path.
    pub fn record_spans(mut self) -> Self {
        self.record_spans = true;
        self
    }

    /// Export the recorded spans as Chrome trace-event JSON at `path`
    /// when the session finishes — open it in `ui.perfetto.dev` (see
    /// [`crate::obs::write_chrome_trace`]). Implies span recording.
    pub fn span_trace(mut self, path: &str) -> Self {
        self.span_trace = Some(path.to_string());
        self
    }

    /// Stream one [`crate::obs::Snapshot`] JSON line to `path` every
    /// `interval` stream arrivals, plus a final record at finish. The
    /// cadence counts arrivals, not wall time, so a lockstep stream
    /// replays an identical snapshot sequence. Implies span recording;
    /// `interval == 0` reads as 1. The file is created at build time with
    /// a schema header (see the [`crate::obs`] module docs).
    pub fn metrics_out(mut self, path: &str, interval: u64) -> Self {
        self.metrics_out = Some((path.to_string(), interval.max(1)));
        self
    }

    /// Validate and assemble the session. Returns a typed error (never
    /// panics) when the configuration cannot run: zero batch rows, a
    /// partition that does not cover the model, worker knob vectors of the
    /// wrong arity, zero accumulation counts, a zero plugin cadence, or a
    /// malformed budget schedule.
    pub fn build(self) -> Result<Session<'a>> {
        let SessionBuilder {
            backend,
            model,
            cfg,
            plugin,
            executor,
            mode,
            ep,
            budget,
            batch,
            test,
            measured_reps,
            trace_path,
            trace_writer,
            record_spans,
            span_trace,
            metrics_out,
        } = self;
        let mut plugin = plugin;
        if batch == 0 {
            bail!("session: batch rows must be > 0 (set SessionBuilder::batch)");
        }
        // lr == 0 stays legal (frozen-weights ablations ran under the old
        // entry point); only non-finite or negative rates are rejected
        if !ep.lr.is_finite() || ep.lr < 0.0 {
            bail!("session: learning rate must be finite and >= 0 (got {})", ep.lr);
        }
        let prof = if measured_reps > 0 {
            Profile::measured(backend, model, batch, measured_reps)
        } else {
            Profile::analytic(model, batch)
        };
        let td = if ep.td == 0 { prof.default_td() } else { ep.td };
        let decay = decay_for_td(td);
        let mut cfg = match cfg {
            Some(c) => c,
            None => {
                let out = plan(&prof, td, f64::INFINITY, decay);
                AsyncCfg::ferret(out.partition, out.config, CompKind::IterFisher)
            }
        };
        if let Some(b) = budget {
            cfg.budget = b;
        }
        // validate() first: num_stages() is bounds.len() - 1 and would
        // underflow on an empty hand-built partition
        if !cfg.partition.validate(model.num_layers()) {
            bail!(
                "session: partition {:?} does not cover the model's {} layers",
                cfg.partition.bounds,
                model.num_layers()
            );
        }
        let p = cfg.partition.num_stages();
        if cfg.pipe.workers.is_empty() {
            bail!("session: pipeline config has no workers");
        }
        if !cfg.pipe.workers.iter().any(|w| w.active()) {
            bail!("session: pipeline config has no active worker (T4 removed them all)");
        }
        for (i, w) in cfg.pipe.workers.iter().enumerate() {
            if w.accum.len() != p || w.omit.len() != p {
                bail!(
                    "session: worker {i} carries {} accum / {} omit entries for {p} stages",
                    w.accum.len(),
                    w.omit.len()
                );
            }
            if w.accum.iter().any(|&a| a == 0) {
                bail!("session: worker {i} has a zero accumulation count (T2 must be >= 1)");
            }
        }
        if cfg.plugin_cadence == 0 {
            bail!("session: plugin_cadence must be >= 1");
        }
        for step in &cfg.budget.steps {
            if step.bytes.is_nan() || step.bytes < 0.0 {
                bail!("session: budget step at {:?} has invalid byte count {}", step.at, step.bytes);
            }
        }
        let features = model.features();
        let classes = model.classes();
        if let Some(t) = &test {
            if t.n == 0 || t.x.len() != t.n * features || t.y.len() != t.n {
                bail!(
                    "session: test set shape mismatch ({} values / {} labels for {} samples x {} \
                     features)",
                    t.x.len(),
                    t.y.len(),
                    t.n,
                    features
                );
            }
        }

        // lockstep virtual time never reaches wall-clock stamps: drop
        // `u<N>` steps so they cannot block batch-index steps behind them
        let budget_state = match mode {
            Mode::Lockstep => BudgetState::without_wall_steps(&cfg.budget),
            Mode::Freerun => BudgetState::new(&cfg.budget),
        };
        let mut engine = AsyncEngine::new(backend, model, cfg, &ep);
        engine.stage_times(&prof);
        engine.decay_c = match mode {
            Mode::Lockstep => ep.decay(td),
            // freerun ages updates in wall microseconds (1 tick replayed
            // as WALL_TICK_US µs): rescale so the adaptation rate stays
            // comparable with lockstep at any replay speed
            Mode::Freerun => ep.decay(td) / crate::stream::WALL_TICK_US as f64,
        };
        if mode == Mode::Freerun {
            engine.build_cells();
            // ship the plain-CE loss head with last-stage forwards so it
            // runs on the device thread; plugins with a custom head
            // (ce_loss_head() == false) keep it on the scheduler thread
            engine.set_loss_offload(plugin.with(|p| p.ce_loss_head()));
            // move an owned plugin behind a shared cell so the stage-0
            // device thread runs the `augment` hook itself (replay mixing
            // off the scheduler's critical path). Threaded only: the
            // inline executor would re-enter the cell's lock from the
            // scheduler thread and deadlock. A borrowed plugin cannot be
            // shared with 'static device threads and keeps the
            // scheduler-side hook.
            if executor == ExecutorKind::Threaded {
                plugin = match plugin {
                    PluginSlot::Owned(p) => {
                        let cell = PluginCell::new(p);
                        engine.set_augment_cell(cell.clone());
                        PluginSlot::Shared(cell)
                    }
                    other => other,
                };
            }
        }
        // one session-wide workspace: the scheduler, the executor's device
        // threads, and the engine's update path all recycle through the
        // same buffer pool, and stage kernels use the resolved thread count
        let kthreads = kernels::resolve_threads(ep.kernel_threads);
        let ws = Workspace::new(BufferPool::new(), kthreads);
        engine.set_workspace(ws.clone());

        // span recording: any observability consumer turns it on; without
        // one the engine keeps the no-op recorder (pinned overhead-free by
        // the perf-compare gate)
        if record_spans || span_trace.is_some() || metrics_out.is_some() {
            engine.obs = crate::obs::Recorder::on();
        }
        let (snap_writer, snap_interval) = match &metrics_out {
            Some((path, interval)) => {
                (Some(crate::obs::SnapshotWriter::create(path, *interval)?), *interval)
            }
            None => (None, 1),
        };

        // trace header: written at build time so even an aborted run leaves
        // a parseable (if truncated) artifact. Records the *resolved* td
        // and kernel-thread count, and the plan the engine will actually
        // start under — whether caller-supplied or auto-planned.
        let mut tracer = match (trace_writer, trace_path) {
            (Some(w), _) => Some(w),
            (None, Some(p)) => Some(TraceWriter::to_path(&p)?),
            (None, None) => None,
        };
        if let Some(tr) = tracer.as_mut() {
            let c = &engine.cfg;
            tr.header(&Header {
                schema: crate::trace::SCHEMA.into(),
                model: model.name.clone(),
                dims: model.dims.clone(),
                batch,
                features,
                classes,
                mode: mode.name().into(),
                executor: executor.name().into(),
                lr: ep.lr,
                decay_c: ep.decay_c,
                td,
                tacc_per_class: ep.tacc_per_class,
                seed: ep.seed,
                stash_cap: ep.stash_cap,
                kernel_threads: kthreads,
                schedule: c.schedule.name().into(),
                partition: c.partition.bounds.clone(),
                workers: c
                    .pipe
                    .workers
                    .iter()
                    .map(|w| WorkerRec {
                        delay: w.delay,
                        recompute: w.recompute,
                        accum: w.accum.clone(),
                        omit: w.omit.clone(),
                    })
                    .collect(),
                comp: c.comp_kind.name().into(),
                comp_params: [
                    c.comp_params.lam0,
                    c.comp_params.eta_lam,
                    c.comp_params.alpha,
                    c.comp_params.nu,
                ],
                plugin: plugin.with(|p| p.name()).into(),
                plugin_cadence: c.plugin_cadence,
                budget: c.budget.spec_string(),
                plan_id: crate::planner::plan_content_id(&c.partition, &c.pipe, 0),
                measured_reps,
            });
        }
        let executor: Box<dyn Executor + 'a> = match executor {
            ExecutorKind::Sim => Box::new(SimExecutor::with_workspace(backend, ws.clone())),
            ExecutorKind::Threaded => Box::new(ThreadedExecutor::spawn_pinned(
                backend.share(),
                &engine.devices(),
                ws.clone(),
                ep.pin_devices,
            )),
        };
        let metrics = RunMetrics { exec_threads: executor.threads(), ..Default::default() };
        Ok(Session {
            backend,
            engine,
            ws,
            executor,
            plugin,
            metrics,
            prof,
            shapes: model.layers(),
            classes,
            features,
            batch,
            td,
            td_us: arrival_interval_us(td),
            decay,
            mode,
            ep,
            vclock: VirtualClock::new(),
            wclock: None,
            budget: budget_state,
            arrived: 0,
            arrive_scheduled: false,
            pending: VecDeque::new(),
            held: VecDeque::new(),
            drain_from: None,
            test,
            tracer,
            span_trace,
            snap_writer,
            snap_interval,
            snap_pending: 0,
            device_mark: 0,
        })
    }
}

/// A long-lived engine run: push batches in, observe metrics live, change
/// the budget imperatively, finish (or drop) whenever the caller decides.
/// Construct with [`Session::builder`]; see the module docs for the
/// surface and its guarantees.
pub struct Session<'a> {
    backend: &'a dyn Backend,
    engine: AsyncEngine<'a>,
    /// session-wide buffer pool + kernel thread count (cloned into the
    /// engine and the executor's device threads)
    ws: Workspace,
    executor: Box<dyn Executor + 'a>,
    plugin: PluginSlot<'a>,
    metrics: RunMetrics,
    /// analytic profile of the model at this session's batch size (base
    /// for the measured rescale at each re-plan)
    prof: Profile,
    shapes: Vec<LayerShape>,
    classes: usize,
    features: usize,
    batch: usize,
    /// arrival interval in virtual ticks
    td: u64,
    /// arrival interval in wall microseconds (freerun pacing)
    td_us: u64,
    /// planner decay constant for mid-stream re-plans
    decay: f64,
    mode: Mode,
    ep: EngineParams,
    vclock: VirtualClock,
    /// freerun wall clock, started lazily at the first timed operation so
    /// setup (test-set generation, bulk ingest) is not charged as lateness
    wclock: Option<WallClock>,
    budget: BudgetState,
    /// stream position: sequence number of the next arrival
    arrived: u64,
    /// lockstep: an `Arrive` event is in the heap for seq `arrived`
    arrive_scheduled: bool,
    /// ingested batches not yet admitted (FIFO = arrival order)
    pending: VecDeque<Batch>,
    /// batches held across a drain: (payload, seq, arrival stamp)
    held: VecDeque<(Batch, u64, u64)>,
    /// wall/virtual stamp when the current drain began (None = no drain)
    drain_from: Option<u64>,
    test: Option<TestSet>,
    /// trace artifact sink; None when the session is not being recorded
    tracer: Option<TraceWriter>,
    /// Chrome trace-event export destination, written at finish
    span_trace: Option<String>,
    /// observability snapshot streamer (`--metrics-out`)
    snap_writer: Option<crate::obs::SnapshotWriter>,
    /// snapshot cadence in stream arrivals (>= 1)
    snap_interval: u64,
    /// arrivals since the last streamed snapshot
    snap_pending: u64,
    /// clock stamp up to which device-time has been integrated into
    /// [`RunMetrics::device_us`] (advanced at each re-plan — the device
    /// set can change there — and closed out at finish)
    device_mark: u64,
}

/// Assemble the per-step [`EngineIo`] bundle from the session's disjoint
/// fields (a macro so the field borrows stay visible to the borrow
/// checker at every call site). Takes a [`PluginGuard`] bound by a `let`
/// at the call site — match arms are temporary scopes, so a guard created
/// inside the macro expansion could not outlive the engine call.
macro_rules! io {
    ($s:expr, $pg:expr) => {
        &mut EngineIo {
            plugin: $pg.as_mut(),
            ctx: OclCtx {
                backend: $s.backend,
                shapes: &$s.shapes,
                classes: $s.classes,
                batch: $s.batch,
                features: $s.features,
            },
            metrics: &mut $s.metrics,
            executor: &mut *$s.executor,
        }
    };
}

impl<'a> Session<'a> {
    /// Start building a session for `model` on `backend`.
    pub fn builder(backend: &'a dyn Backend, model: &'a ModelSpec) -> SessionBuilder<'a> {
        SessionBuilder {
            backend,
            model,
            cfg: None,
            plugin: PluginSlot::Owned(Box::new(Vanilla)),
            executor: ExecutorKind::Sim,
            mode: Mode::Lockstep,
            ep: EngineParams::default(),
            budget: None,
            batch: 0,
            test: None,
            measured_reps: 0,
            trace_path: None,
            trace_writer: None,
            record_spans: false,
            span_trace: None,
            metrics_out: None,
        }
    }

    /// Push one batch into the session. Non-blocking: the batch is queued
    /// and admitted at its scheduled arrival slot (`seq * t^d` in virtual
    /// ticks, or its due microsecond on the freerun wall clock) by the
    /// next [`Session::step`]/[`Session::drain`]/[`Session::finish`].
    ///
    /// A misshapen batch is rejected with a typed error (and not queued)
    /// instead of panicking later inside backend math: `x` must hold
    /// exactly `y.len()` rows of the model's feature dimension, with at
    /// least one and at most the builder's row count.
    pub fn ingest(&mut self, batch: Batch) -> Result<()> {
        if batch.y.is_empty() || batch.y.len() > self.batch {
            bail!(
                "session: batch {} carries {} rows (expected 1..={})",
                batch.id,
                batch.y.len(),
                self.batch
            );
        }
        if batch.x.len() != batch.y.len() * self.features {
            bail!(
                "session: batch {} has {} values for {} rows x {} features",
                batch.id,
                batch.x.len(),
                batch.y.len(),
                self.features
            );
        }
        self.pending.push_back(batch);
        if self.mode == Mode::Lockstep && !self.arrive_scheduled && self.drain_from.is_none() {
            // first batch of a phase: schedule its arrival. (Mid-phase the
            // previous arrival already scheduled this one speculatively,
            // and during a drain the post-transition resume schedules it.)
            self.engine.sched.events.push(self.arrived * self.td, Ev::Arrive);
            self.arrive_scheduled = true;
        }
        Ok(())
    }

    /// Advance the session by (at most) one scheduler event — one heap pop
    /// in lockstep; one admit/completion/transition sweep in freerun.
    /// Never blocks; [`SessionStep::Starved`] says what a blocking caller
    /// ([`Session::drain`] / [`Session::finish`]) would wait on.
    pub fn step(&mut self) -> Result<SessionStep> {
        match self.mode {
            Mode::Lockstep => self.step_lockstep(false),
            Mode::Freerun => self.step_freerun(false),
        }
    }

    /// Run the session as far as it can go with what has been ingested:
    /// until [`SessionStep::Idle`] (all work done) or
    /// [`SessionStep::Starved`] on missing input. In freerun mode this
    /// blocks on in-flight device work and not-yet-due arrivals; in
    /// lockstep it never blocks on time (virtual time jumps).
    pub fn drain(&mut self) -> Result<()> {
        match self.mode {
            Mode::Lockstep => {
                while self.step_lockstep(false)? == SessionStep::Progressed {}
                Ok(())
            }
            Mode::Freerun => self.drain_freerun(false),
        }
    }

    /// Live metrics snapshot: online accuracy so far, losses, latency
    /// samples, staleness histogram, ledger peaks/trace, re-plan history.
    /// `mem_bytes` and `tacc` are finalized by [`Session::finish`].
    pub fn metrics(&self) -> &RunMetrics {
        &self.metrics
    }

    /// Batches ingested but not yet admitted to the pipeline.
    pub fn backlog(&self) -> usize {
        self.pending.len() + self.held.len()
    }

    /// Live counters of the session-wide buffer pool: takes, misses
    /// (allocations), puts, and drops. `misses` flat-lining while `takes`
    /// climbs is the zero-copy steady state; [`Session::finish`] copies
    /// the final stats into [`RunMetrics::pool`].
    pub fn pool_stats(&self) -> PoolStats {
        self.ws.pool.stats()
    }

    /// Live observability snapshot at the clock's current reading:
    /// per-device busy time and utilization, bubble fraction, drain /
    /// re-plan stall attribution, the staleness gauge, and latency
    /// percentiles over a sliding window — all from the span recorder
    /// (zeros unless the builder enabled recording via
    /// [`SessionBuilder::record_spans`] / [`SessionBuilder::span_trace`] /
    /// [`SessionBuilder::metrics_out`]) — plus metrics-side counters
    /// (online accuracy so far, measured ledger bytes, buffer-pool stats,
    /// arrival/train/drop counts) that are live either way.
    pub fn obs_snapshot(&self) -> crate::obs::Snapshot {
        let now = match self.mode {
            Mode::Lockstep => self.vclock.now(),
            Mode::Freerun => self.wclock.as_ref().map_or(0, |c| c.now()),
        };
        self.snapshot_at(now)
    }

    /// Recorder-side snapshot at `now`, completed with the metrics-side
    /// fields only the session can see.
    fn snapshot_at(&self, now: u64) -> crate::obs::Snapshot {
        let mut s = self.engine.obs.snapshot(now);
        s.oacc = self.metrics.oacc.value();
        s.ledger_bytes = self.engine.ledger_snapshot().total() as u64;
        let pool = self.ws.pool.stats();
        s.pool_takes = pool.takes;
        s.pool_misses = pool.misses;
        s.pool_puts = pool.puts;
        s.arrivals = self.metrics.arrivals();
        s.trained = self.metrics.trained;
        s.dropped = self.metrics.dropped;
        s
    }

    /// Count one arrival against the snapshot-stream cadence and append a
    /// record if due. A failing telemetry sink must not abort training,
    /// so write errors are swallowed here (creation errors still surface
    /// at build time).
    fn tick_snapshot_stream(&mut self, now: u64) {
        if self.snap_writer.is_none() {
            return;
        }
        self.snap_pending += 1;
        if self.snap_pending < self.snap_interval {
            return;
        }
        self.snap_pending = 0;
        let snap = self.snapshot_at(now);
        if let Some(w) = self.snap_writer.as_mut() {
            let _ = w.write(&snap);
        }
    }

    /// Imperatively change the memory budget: arms the drain → re-plan →
    /// transition protocol exactly as a `--budget-schedule` step would
    /// (in-flight microbatches finish under the old plan, learned weights
    /// carry over, the stash and device threads are rebuilt for the new
    /// plan). Also switches the session to dynamic-budget accounting
    /// (per-update ledger trace, breach triggers) if it was static.
    ///
    /// Like a schedule step, the transition itself fires only if there is
    /// traffic ahead to re-plan for: a budget change after the final batch
    /// is discarded at [`Session::finish`] with `replans` unchanged (there
    /// is no new plan to run anything under). A NaN or negative byte count
    /// is rejected with a typed error (same rule the builder applies to
    /// schedule steps) and leaves the session untouched.
    pub fn set_budget(&mut self, bytes: f64) -> Result<()> {
        if bytes.is_nan() || bytes < 0.0 {
            bail!("session: set_budget expects a non-negative byte count (got {bytes})");
        }
        self.engine.force_dynamic_budget();
        self.budget.set_current(bytes);
        if self.drain_from.is_none() {
            // read the clock without starting it: a pre-traffic budget
            // change must not charge setup time as arrival lateness — the
            // drain then nominally begins at t = 0, the start of the run
            let now = match self.mode {
                Mode::Lockstep => self.vclock.now(),
                Mode::Freerun => self.wclock.as_ref().map_or(0, |c| c.now()),
            };
            self.drain_from = Some(now);
        }
        Ok(())
    }

    /// Complete every outstanding arrival and device task, finalize the
    /// metrics (analytic memory, final ledger state, test accuracy if a
    /// test set was provided), join the device threads, and return the
    /// result. Ingested batches are all processed first — `finish` only
    /// declares that no *further* batches will arrive.
    pub fn finish(mut self) -> Result<RunResult> {
        match self.mode {
            Mode::Lockstep => loop {
                match self.step_lockstep(true)? {
                    SessionStep::Progressed => {}
                    SessionStep::Starved | SessionStep::Idle => break,
                }
            },
            Mode::Freerun => self.drain_freerun(true)?,
        }
        self.metrics.ledger.observe(self.engine.ledger_snapshot());
        debug_assert_eq!(self.engine.sched.inflight, 0, "every admitted job retired");

        // close the observability accounting at the final clock reading:
        // flush the engine's always-on busy counter and integrate the last
        // phase's device-time (both are live whether or not the span
        // recorder is on, so replays reproduce them too)
        let t_end = match self.mode {
            Mode::Lockstep => self.vclock.now(),
            Mode::Freerun => self.wclock.as_ref().map_or(0, |c| c.now()),
        };
        self.metrics.integrate_device_time(
            self.engine.devices().len(),
            t_end.saturating_sub(self.device_mark),
        );
        self.device_mark = t_end;
        self.metrics.busy_us = self.engine.busy_ticks;

        // analytic memory (Eq. 4) + plugin + compensator state
        self.metrics.mem_bytes =
            mem_footprint(&self.engine.cfg.partition, &self.prof, &self.engine.cfg.pipe)
                + self.plugin.with(|p| p.memory_bytes()) as f64
                + self.engine.comp_state_bytes() as f64;
        let params = self.engine.final_params();
        if let Some(test) = &self.test {
            self.metrics.tacc =
                eval_tacc(self.backend, &self.shapes, &params, self.classes, test, self.batch);
        }
        self.metrics.pool = self.ws.pool.stats();
        if let Some(tr) = self.tracer.as_mut() {
            tr.finish(&FinishRec {
                oacc: self.metrics.oacc.value(),
                tacc: self.metrics.tacc,
                arrivals: self.metrics.arrivals(),
                trained: self.metrics.trained,
                dropped: self.metrics.dropped,
                replans: self.metrics.replans,
                mem_bytes: self.metrics.mem_bytes,
                peak_ledger: self.metrics.ledger.peak_total,
                p50: self.metrics.latency_percentile(50.0),
                p95: self.metrics.latency_percentile(95.0),
                p99: self.metrics.latency_percentile(99.0),
                oacc_curve: self.metrics.oacc.curve.clone(),
                busy_us: self.metrics.busy_us,
                device_us: self.metrics.device_us,
            });
        }
        // final observability exports: one closing snapshot on the metrics
        // stream, and the Perfetto/Chrome span trace
        if self.snap_writer.is_some() {
            let snap = self.snapshot_at(t_end);
            if let Some(w) = self.snap_writer.as_mut() {
                let _ = w.write(&snap);
            }
        }
        if let Some(path) = self.span_trace.take() {
            if let Err(e) = crate::obs::write_chrome_trace(&path, &self.engine.obs.spans()) {
                eprintln!("ferret: span trace export failed: {e}");
            }
        }
        // moving the metrics out drops the executor, which joins every
        // device thread — nothing survives the session
        let Session { metrics, .. } = self;
        Ok(RunResult { metrics, params })
    }

    /// Ingest an entire [`Stream`] and run to completion — the bridge from
    /// the closed-world API (and what the [`run_async`]/[`run_async_with`]
    /// shims call). Takes the test set from the stream unless the builder
    /// provided one. Batches are generated one arrival ahead in both
    /// modes, exactly like the historical pull loops — the stream is never
    /// materialized in memory, so arbitrarily long streams run in O(1)
    /// batch buffering.
    ///
    /// A stream yielding a batch that does not match the session's model
    /// (wrong feature dimension, zero or too many rows) is a typed error,
    /// not a panic: the partial run is abandoned, and dropping the session
    /// joins its device threads on the way out.
    pub fn run_stream(mut self, stream: &mut dyn Stream) -> Result<RunResult> {
        if self.test.is_none() {
            self.test = Some(stream.test_set(self.ep.tacc_per_class));
        }
        if let Some(tr) = self.tracer.as_mut() {
            if let Some(spec) = stream.provenance() {
                tr.stream(&spec);
            }
        }
        match self.mode {
            Mode::Lockstep => {
                while let Some(b) = stream.next_batch() {
                    self.ingest(b)?;
                    self.drain()?;
                }
            }
            Mode::Freerun => {
                while let Some(b) = stream.next_batch() {
                    self.ingest(b)?;
                    // admit (or hold) the queued batch before generating
                    // the next one: at most a single-batch lookahead is
                    // ever buffered, and completions are serviced while
                    // waiting for its wall-clock due time
                    while !self.pending.is_empty() {
                        if self.step_freerun(false)? != SessionStep::Progressed {
                            self.wait_freerun()?;
                        }
                    }
                }
            }
        }
        self.finish()
    }

    // -----------------------------------------------------------------
    // Lockstep stepping
    // -----------------------------------------------------------------

    fn step_lockstep(&mut self, finishing: bool) -> Result<SessionStep> {
        let Some((_, head)) = self.engine.sched.events.peek() else {
            return self.lockstep_phase_end(finishing);
        };
        if matches!(head, Ev::Arrive) && self.pending.is_empty() {
            if !finishing {
                // popping would commit a tie-break order the pull loop
                // never sees — stall with the heap untouched
                return Ok(SessionStep::Starved);
            }
            // stream over: the speculatively scheduled arrival never
            // happened (the pull loop would not have scheduled it at all)
            let _ = self.engine.sched.events.pop();
            self.arrive_scheduled = false;
            return Ok(SessionStep::Progressed);
        }
        let Some((te, ev)) = self.engine.sched.events.pop() else {
            bail!("session: event heap emptied between peek and pop");
        };
        self.vclock.advance(te);
        let t = self.vclock.now();
        match ev {
            Ev::Arrive => self.lockstep_arrive(te, t)?,
            Ev::Done { worker: w, stage: s, job, bwd } => {
                let mut pg = self.plugin.guard();
                self.engine.on_done_lockstep(w, s, job, bwd, t, io!(self, pg))?;
                drop(pg);
                if self.engine.dynamic_budget() {
                    let snap = self.engine.ledger_snapshot();
                    self.metrics.ledger.observe(snap);
                    if self.drain_from.is_none() && self.budget.breached(snap.total()) {
                        self.drain_from = Some(t);
                    }
                }
            }
        }
        Ok(SessionStep::Progressed)
    }

    /// Process one lockstep arrival: its event popped at stream stamp
    /// `te`, the clock now at `t` (later than `te` right after a drain).
    fn lockstep_arrive(&mut self, te: u64, t: u64) -> Result<()> {
        let Some(batch) = self.pending.pop_front() else {
            bail!("session: arrival event popped with no ingested batch queued");
        };
        self.metrics.record_arrival();
        let seq = self.arrived;
        self.arrived += 1;
        self.arrive_scheduled = false;
        self.tick_snapshot_stream(t);
        // advance the budget cursor even mid-drain so the pending re-plan
        // sees the newest budget in force
        let stepped = self.budget.step_due(seq, 0);
        let held = self.drain_from.is_some() || stepped;
        if let Some(tr) = self.tracer.as_mut() {
            tr.batch(&BatchRec {
                seq,
                id: batch.id,
                rows: batch.y.len(),
                hash: batch_hash(&batch),
                arrival: te,
                admitted: t,
                held,
            });
        }
        if held {
            // budget boundary (or mid-drain arrival): hold the batch, stop
            // admitting, and let the in-flight microbatches finish under
            // the old plan — nothing is dropped by the transition
            if self.drain_from.is_none() {
                self.drain_from = Some(t);
            }
            self.held.push_back((batch, seq, te));
            return Ok(());
        }
        // schedule the next arrival *before* admitting (admission pushes
        // `Done` events; the pull loop orders its pushes the same way) —
        // speculative: finish() discards it if no further batch arrives
        self.engine.sched.events.push(self.arrived * self.td, Ev::Arrive);
        self.arrive_scheduled = true;
        let mut pg = self.plugin.guard();
        self.engine.admit_lockstep(batch, seq, te, t, io!(self, pg))
    }

    /// The phase's event heap is empty: idle, or a completed drain whose
    /// plan transition takes effect now.
    fn lockstep_phase_end(&mut self, finishing: bool) -> Result<SessionStep> {
        let Some(t0) = self.drain_from else { return Ok(SessionStep::Idle) };
        if self.held.is_empty() && self.pending.is_empty() {
            if finishing {
                // the breach/step landed after the last arrival: nothing
                // ahead to re-plan for (the pull loop's final break)
                self.drain_from = None;
                return Ok(SessionStep::Idle);
            }
            return Ok(SessionStep::Starved);
        }
        let now = self.vclock.now();
        // flush partially-filled accumulators as final updates under the
        // old plan — the drained backwards' gradients are applied, not
        // discarded, even when `accum > 1` left a remainder
        for (w, s) in self.engine.pending_accumulators() {
            let mut pg = self.plugin.guard();
            self.engine.apply_update(w, s, now, io!(self, pg))?;
        }
        self.replan(t0, now);
        if let Some((batch, seq, at)) = self.held.pop_front() {
            let mut pg = self.plugin.guard();
            self.engine.admit_lockstep(batch, seq, at, now, io!(self, pg))?;
        }
        // lockstep can hold at most one batch per drain: holding suppresses
        // every further Arrive until the post-transition resume below
        debug_assert!(self.held.is_empty(), "second lockstep batch held across a drain");
        // arrivals keep their original absolute cadence: the stream did
        // not wait for the transition
        self.engine.sched.events.push(self.arrived * self.td, Ev::Arrive);
        self.arrive_scheduled = true;
        Ok(SessionStep::Progressed)
    }

    /// Drain complete: re-plan at the budget in force — planner seeded by
    /// the measured-profile refresh — and execute the plan transition.
    /// One definition for both time modes, so the re-plan protocol cannot
    /// silently diverge between them. `t0` is when the drain began; `now`
    /// stamps the transition.
    fn replan(&mut self, t0: u64, now: u64) {
        // stall attribution: the drain span covers held admissions; the
        // re-plan ordinal ties both spans to this transition
        let ordinal = self.metrics.replans;
        self.engine.obs.record(crate::obs::ENGINE_DEVICE, ObsSpanKind::Drain, ordinal, t0, now, 0);
        // close out the old plan's device-time integral before the device
        // set changes (utilization is defined phase-by-phase against the
        // devices that were actually live)
        self.metrics
            .integrate_device_time(self.engine.devices().len(), now.saturating_sub(self.device_mark));
        self.device_mark = now;
        let refreshed = self.engine.refreshed_profile(&self.prof);
        let out = plan(&refreshed, self.td, self.budget.current(), self.decay);
        if let Some(tr) = self.tracer.as_mut() {
            // read the measured stage means BEFORE the transition: it
            // resets the engine's observation windows for the new plan
            let (tf, tb) = self.engine.measured_stage_means();
            tr.replan(&ReplanRec {
                t: now,
                t0,
                drain: now.saturating_sub(t0),
                budget: self.budget.current(),
                tf,
                tb,
                plan_id: out.plan_id(),
                partition: out.partition.bounds.clone(),
                active_workers: out.config.active_workers(),
                mem_bytes: out.mem_bytes,
                rate: out.rate,
                feasible: out.feasible,
                tc: out.tc,
            });
        }
        self.engine.transition(&out, &refreshed, &mut *self.executor);
        // the re-plan span: zero-width in lockstep (the transition is
        // atomic in virtual time), measured microseconds in freerun
        let end = match self.mode {
            Mode::Lockstep => now,
            Mode::Freerun => self.wclock.as_ref().map_or(now, |c| c.now()),
        };
        self.engine.obs.record(crate::obs::ENGINE_DEVICE, ObsSpanKind::Replan, ordinal, now, end, 0);
        self.metrics.record_replan(now, now.saturating_sub(t0), out.mem_bytes);
        self.metrics.exec_threads = self.metrics.exec_threads.max(self.executor.threads());
        self.drain_from = None;
    }

    // -----------------------------------------------------------------
    // Freerun stepping
    // -----------------------------------------------------------------

    /// The freerun wall clock, started on first use.
    fn wall_now(&mut self) -> u64 {
        self.wclock.get_or_insert_with(WallClock::new).now()
    }

    /// One non-blocking freerun sweep: admit every due arrival, collect
    /// every finished completion, meter the budget, and execute a plan
    /// transition if a drain just completed.
    fn step_freerun(&mut self, finishing: bool) -> Result<SessionStep> {
        let mut progressed = false;
        // admit every ingested arrival already due on the wall clock
        while !self.pending.is_empty() && self.wall_now() >= self.arrived * self.td_us {
            let Some(batch) = self.pending.pop_front() else {
                break; // just checked non-empty; defensive
            };
            let due = self.arrived * self.td_us;
            let seq = self.arrived;
            self.arrived += 1;
            self.metrics.record_arrival();
            // advance the budget cursor even mid-drain so the pending
            // re-plan sees the newest budget in force
            let now = self.wall_now();
            self.tick_snapshot_stream(now);
            let stepped = self.budget.step_due(seq, now);
            let held = self.drain_from.is_some() || stepped;
            if let Some(tr) = self.tracer.as_mut() {
                tr.batch(&BatchRec {
                    seq,
                    id: batch.id,
                    rows: batch.y.len(),
                    hash: batch_hash(&batch),
                    arrival: due,
                    admitted: now,
                    held,
                });
            }
            if held {
                if self.drain_from.is_none() {
                    self.drain_from = Some(now);
                }
                self.held.push_back((batch, seq, due));
            } else {
                let t = self.wall_now();
                let mut pg = self.plugin.guard();
                self.engine.on_arrive_free(batch, seq, due, t, io!(self, pg))?;
            }
            progressed = true;
        }
        // react to whichever device finished first. The plugin guard is
        // taken after the non-blocking poll, so a device running an
        // offloaded augment only ever contends with the engine step
        // itself, never with a scheduler-side wait.
        while let Some(((w, s), out)) = self.executor.try_finish_any() {
            let t = self.wall_now();
            let mut pg = self.plugin.guard();
            self.engine.on_done_free(w, s, out, t, io!(self, pg))?;
            progressed = true;
        }
        if self.engine.dynamic_budget() {
            // wall-time (`u<N>`) steps must fire between arrivals too;
            // `arrived` = next seq, so a batch step fires here at the same
            // boundary the admit-site check would give it
            let now = self.wall_now();
            if self.budget.step_due(self.arrived, now) && self.drain_from.is_none() {
                self.drain_from = Some(now);
            }
            let snap = self.engine.ledger_snapshot();
            self.metrics.ledger.observe(snap);
            if self.drain_from.is_none() && self.budget.breached(snap.total()) {
                self.drain_from = Some(self.wall_now());
            }
        }
        // plan transition once the drain completes (no task in flight)
        if self.engine.flights == 0 && self.drain_from.is_some() {
            if self.held.is_empty() && self.pending.is_empty() {
                if finishing {
                    self.drain_from = None; // nothing ahead to re-plan for
                }
                // not finishing: the drain stays armed — the next ingest
                // re-plans before its batch is admitted
            } else {
                // flush partially-filled accumulators as final updates
                // under the old plan (they fly as Update tasks; the next
                // fully-drained sweep performs the transition)
                let pending_accs = self.engine.pending_accumulators();
                if !pending_accs.is_empty() {
                    let t = self.wall_now();
                    for (w, s) in pending_accs {
                        let mut pg = self.plugin.guard();
                        self.engine.dispatch_update_free(w, s, t, io!(self, pg))?;
                    }
                    return Ok(SessionStep::Progressed);
                }
                let Some(t0) = self.drain_from.take() else {
                    bail!("session: drain stamp vanished mid-transition");
                };
                let now = self.wall_now();
                self.replan(t0, now);
                let resumed: Vec<(Batch, u64, u64)> = self.held.drain(..).collect();
                for (batch, seq, due) in resumed {
                    let t = self.wall_now();
                    let mut pg = self.plugin.guard();
                    self.engine.on_arrive_free(batch, seq, due, t, io!(self, pg))?;
                }
                return Ok(SessionStep::Progressed);
            }
        }
        if progressed {
            Ok(SessionStep::Progressed)
        } else if self.engine.flights == 0 && self.pending.is_empty() && self.held.is_empty() {
            // a pending drain with nothing ahead also parks here: it will
            // fire when the next batch is ingested (or clear at finish)
            Ok(SessionStep::Idle)
        } else {
            Ok(SessionStep::Starved)
        }
    }

    /// Block once on whatever the freerun loop is waiting for: the
    /// completion channel (waking for the next scheduled arrival) when
    /// work is in flight, or the next arrival's due time otherwise.
    fn wait_freerun(&mut self) -> Result<()> {
        if self.engine.flights > 0 {
            // sleep on the completion channel, but wake for the next
            // scheduled arrival
            let timeout = if !self.pending.is_empty() {
                let due = self.arrived * self.td_us;
                Duration::from_micros(due.saturating_sub(self.wall_now()).max(1))
            } else {
                Duration::from_millis(100)
            };
            if let Some(((w, s), out)) = self.executor.wait_any(timeout) {
                let t = self.wall_now();
                let mut pg = self.plugin.guard();
                self.engine.on_done_free(w, s, out, t, io!(self, pg))?;
            }
        } else if !self.pending.is_empty() {
            let due = self.arrived * self.td_us;
            self.wall_now(); // ensure the clock exists
            if let Some(c) = self.wclock.as_ref() {
                c.sleep_until(due);
            }
        }
        Ok(())
    }

    /// Blocking freerun loop: sweep, then sleep on the completion channel
    /// (waking for the next scheduled arrival) until everything ingested
    /// is fully processed.
    fn drain_freerun(&mut self, finishing: bool) -> Result<()> {
        loop {
            match self.step_freerun(finishing)? {
                SessionStep::Progressed => continue,
                SessionStep::Idle => break,
                SessionStep::Starved => {
                    if self.engine.flights == 0 && self.pending.is_empty() {
                        break; // defensive: nothing to wait on
                    }
                    self.wait_freerun()?;
                }
            }
        }
        Ok(())
    }
}

/// Build + run with an explicit executor and time-mode choice — the
/// historical one-call surface, kept as a thin shim over [`Session`] so
/// the pre-session test suites (`executor_equiv`, `budget_replan`,
/// `freerun`, `sched_props`) pin that the session redesign is
/// behavior-preserving. `Threaded` runs one session-owned OS thread per
/// active (worker, stage) device; `Mode::Freerun` paces the run against
/// the wall clock instead of the virtual event heap.
#[allow(clippy::too_many_arguments)] // historical signature, deliberately frozen
pub fn run_async_with(
    cfg: AsyncCfg,
    stream: &mut SyntheticStream,
    backend: &dyn Backend,
    plugin: &mut dyn OclPlugin,
    ep: &EngineParams,
    model: &ModelSpec,
    kind: ExecutorKind,
    mode: Mode,
) -> RunResult {
    let batch = stream.spec().batch;
    let session = Session::builder(backend, model)
        .config(cfg)
        .plugin(plugin)
        .engine_params(*ep)
        .executor(kind)
        .mode(mode)
        .batch(batch)
        // ferret-lint: allow(entry-panic) — frozen legacy shim: the config
        // comes from this crate's own planners/baselines, which the builder
        // validates by construction
        .build().expect("run_async_with: invalid engine configuration");
    // a SyntheticStream always matches the model it was specced against
    // ferret-lint: allow(entry-panic) — frozen legacy shim over the fallible
    // Session::run_stream; synthetic streams match their own spec
    session.run_stream(stream).expect("run_async_with: stream batches match the model")
}

/// Convenience: build + run in one call on the simulation executor in
/// lockstep (virtual-time) mode.
pub fn run_async(
    cfg: AsyncCfg,
    stream: &mut SyntheticStream,
    backend: &dyn Backend,
    plugin: &mut dyn OclPlugin,
    ep: &EngineParams,
    model: &ModelSpec,
) -> RunResult {
    run_async_with(cfg, stream, backend, plugin, ep, model, ExecutorKind::Sim, Mode::Lockstep)
}
