//! Synchronous pipeline-parallel schedules (Table 3's left half):
//! DAPPLE [24], Zero-Bubble [66], and Hanayo-kW [49].
//!
//! Synchronous schedules process microbatches in *flights* of `F = P`
//! microbatches and update parameters once per flight (no staleness —
//! which is exactly why they lose online accuracy: updates land late and
//! data queues or drops while the flight drains). The per-flight makespan
//! models each schedule's bubble structure:
//!
//!   DAPPLE      m·(t^f+t^b) + (P−1)·(t^f+t^b)   classic 1F1B flush bubble
//!   Zero-Bubble m·(t^f+t^b) + (P−1)·t^f          B/W split fills the
//!                                                 backward bubble
//!   Hanayo-kW   m·(t^f+t^b) + ceil((P−1)/k)·(t^f+t^b)  k waves divide
//!                                                 the bubble
//!
//! Memory (documented approximations of each paper's reported footprint):
//!   DAPPLE      2|w| + Σ_j (P−j)·|a_j|            weights+grads, 1F1B acts
//!   Zero-Bubble 3|w| + Σ_j (P−j)·|a_j|            staged W-phase grads
//!   Hanayo-kW   (1+k)|w| + Σ_j (P−j)·|a_j|        k wave model replicas

use crate::backend::{accuracy, backward_all, forward_all, Backend};
use crate::metrics::{eval_tacc, RunMetrics};
use crate::model::{GradBuf, LiveParams, SharedParams};
use crate::ocl::{OclCtx, OclPlugin};
use crate::pipeline::sched::predict_only;
use crate::pipeline::{EngineParams, RunResult};
use crate::planner::{Partition, Profile};
use crate::stream::{Batch, SyntheticStream};
use std::collections::VecDeque;
use std::sync::Arc;

/// Which synchronous schedule to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncSchedule {
    Dapple,
    ZeroBubble,
    Hanayo { waves: usize },
}

impl SyncSchedule {
    pub fn name(&self) -> String {
        match self {
            SyncSchedule::Dapple => "DAPPLE".into(),
            SyncSchedule::ZeroBubble => "ZB".into(),
            SyncSchedule::Hanayo { waves } => format!("Hanayo{waves}W"),
        }
    }

    /// Per-flight makespan for `m` microbatches.
    pub fn makespan(&self, m: u64, p: u64, tf: u64, tb: u64) -> u64 {
        let unit = tf + tb;
        match self {
            SyncSchedule::Dapple => m * unit + (p - 1) * unit,
            SyncSchedule::ZeroBubble => m * unit + (p - 1) * tf,
            SyncSchedule::Hanayo { waves } => {
                m * unit + (p - 1).div_ceil(*waves as u64) * unit
            }
        }
    }

    /// Analytic memory footprint in bytes (see module docs).
    pub fn mem_bytes(&self, part: &Partition, prof: &Profile, flight: usize) -> f64 {
        let p = part.num_stages();
        let w: usize = (0..p).map(|j| part.stage_params(prof, j)).sum();
        let acts: usize = (0..p)
            .map(|j| (p - j).min(flight) * part.stage_acts(prof, j))
            .sum();
        let weight_copies = match self {
            SyncSchedule::Dapple => 2.0,
            SyncSchedule::ZeroBubble => 3.0,
            SyncSchedule::Hanayo { waves } => 1.0 + *waves as f64,
        };
        (weight_copies * w as f64 + acts as f64) * 4.0
    }
}

struct FlightState<'a> {
    backend: &'a dyn Backend,
    shapes: Vec<crate::config::LayerShape>,
    classes: usize,
    flight: usize,
    p: u64,
    tf: u64,
    tb: u64,
    lr: f32,
    decay_c: f64,
}

impl FlightState<'_> {
    /// Process up to one flight from the queue; returns the completion time.
    #[allow(clippy::too_many_arguments)]
    fn process(
        &self,
        schedule: SyncSchedule,
        queue: &mut VecDeque<(Batch, u64)>,
        params: &mut [SharedParams],
        plugin: &mut dyn OclPlugin,
        ctx: &OclCtx,
        metrics: &mut RunMetrics,
        start: u64,
    ) -> u64 {
        let m = queue.len().min(self.flight) as u64;
        if m == 0 {
            return start;
        }
        let end = start + schedule.makespan(m, self.p, self.tf, self.tb);
        let mut acc: Option<Vec<GradBuf>> = None;
        let mut arrivals = Vec::new();
        for _ in 0..m {
            let (batch, arrival) = queue.pop_front().unwrap();
            arrivals.push(arrival);
            let batch = plugin.augment(batch, params, ctx);
            let (inputs, logits) =
                forward_all(self.backend, &self.shapes, params, &batch.x, batch.y.len());
            // sync pipelines predict with flight-start weights
            metrics.record_prediction(start, accuracy(self.classes, &logits, &batch.y));
            let (gl, loss) = plugin.loss_grad(&logits, &batch.y, &batch.x, ctx);
            metrics.record_loss(end, loss);
            let grads =
                backward_all(self.backend, &self.shapes, params, &inputs, &gl, batch.y.len());
            match &mut acc {
                None => acc = Some(grads),
                Some(a) => {
                    for (ag, g) in a.iter_mut().zip(&grads) {
                        ag.add(g);
                    }
                }
            }
        }
        // one synchronous update per flight
        let mut grads = acc.unwrap();
        let scale = 1.0 / m as f32;
        for (i, g) in grads.iter_mut().enumerate() {
            g.scale(scale);
            plugin.adjust_layer_grad(i, g, &params[i], ctx);
        }
        for (pm, g) in params.iter_mut().zip(&grads) {
            *pm = Arc::new(self.backend.sgd(pm, g, self.lr));
        }
        plugin.after_update(params, ctx);
        for arrival in arrivals {
            metrics.record_update(end.saturating_sub(arrival), self.decay_c, 1.0);
        }
        end
    }
}

/// Run a synchronous pipeline schedule over a stream.
pub fn run_sync(
    schedule: SyncSchedule,
    stream: &mut SyntheticStream,
    backend: &dyn Backend,
    plugin: &mut dyn OclPlugin,
    ep: &EngineParams,
    model: &crate::config::ModelSpec,
    partition: &Partition,
) -> RunResult {
    let spec = stream.spec().clone();
    let shapes = model.layers();
    let prof = Profile::analytic(model, spec.batch);
    let td = if ep.td == 0 { prof.default_td() } else { ep.td };
    let p = partition.num_stages() as u64;
    let flight = (p.max(1)) as usize;
    let queue_cap = 2 * flight;

    let fs = FlightState {
        backend,
        shapes: shapes.clone(),
        classes: spec.classes,
        flight,
        p,
        tf: partition.tf(&prof),
        tb: partition.tb(&prof),
        lr: ep.lr,
        decay_c: ep.decay(td),
    };

    let mut params = LiveParams::init(model, ep.seed).layers;
    let mut metrics = RunMetrics::default();
    let ctx = OclCtx {
        backend,
        shapes: &shapes,
        classes: spec.classes,
        batch: spec.batch,
        features: spec.features,
    };
    let test = stream.test_set(ep.tacc_per_class);

    let mut queue: VecDeque<(Batch, u64)> = VecDeque::new();
    let mut busy_until = 0u64;

    while let Some(batch) = stream.next_batch() {
        let t = batch.id * td;
        metrics.record_arrival();
        // drain flights that completed before this arrival
        while busy_until <= t && !queue.is_empty() {
            let start = busy_until.max(queue.front().unwrap().1);
            if start > t {
                break;
            }
            busy_until = fs.process(schedule, &mut queue, &mut params, plugin, &ctx, &mut metrics, start);
        }
        if queue.len() >= queue_cap {
            // queue overflow: the shared predict-and-drop path
            predict_only(backend, &shapes, &params, spec.classes, &batch.x, &batch.y, t, &mut metrics);
        } else {
            queue.push_back((batch, t));
        }
        metrics
            .observe_live_bytes(queue.len() * (spec.batch * spec.features * 4 + spec.batch * 4));
    }
    // drain the tail
    while !queue.is_empty() {
        let start = busy_until.max(queue.front().unwrap().1);
        busy_until = fs.process(schedule, &mut queue, &mut params, plugin, &ctx, &mut metrics, start);
    }

    metrics.mem_bytes =
        schedule.mem_bytes(partition, &prof, flight) + plugin.memory_bytes() as f64;
    metrics.tacc = eval_tacc(backend, &shapes, &params, spec.classes, &test, spec.batch);
    RunResult { metrics, params }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::ocl::Vanilla;
    use crate::stream::{DriftKind, StreamSpec};

    fn mk_stream(n: usize) -> SyntheticStream {
        SyntheticStream::new(StreamSpec {
            name: "t".into(),
            features: 16,
            classes: 4,
            batch: 8,
            num_batches: n,
            kind: DriftKind::Stationary,
            margin: 3.0,
            noise: 0.5,
            seed: 13,
        })
    }

    fn model() -> crate::config::ModelSpec {
        crate::config::ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
    }

    fn run(s: SyncSchedule, n: usize) -> RunResult {
        let m = model();
        let part = Partition::per_layer(m.num_layers());
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        run_sync(s, &mut mk_stream(n), &NativeBackend, &mut Vanilla, &ep, &m, &part)
    }

    #[test]
    fn makespans_ordering() {
        // ZB < Hanayo3W <= Hanayo1W == DAPPLE bubble structure
        let (m, p, tf, tb) = (4u64, 4u64, 10u64, 20u64);
        let d = SyncSchedule::Dapple.makespan(m, p, tf, tb);
        let z = SyncSchedule::ZeroBubble.makespan(m, p, tf, tb);
        let h1 = SyncSchedule::Hanayo { waves: 1 }.makespan(m, p, tf, tb);
        let h3 = SyncSchedule::Hanayo { waves: 3 }.makespan(m, p, tf, tb);
        assert!(z < d);
        assert_eq!(h1, d);
        assert!(h3 < h1 && h3 >= z, "h3={h3} h1={h1} z={z}");
    }

    #[test]
    fn all_schedules_learn_but_drop_under_pressure() {
        for s in [
            SyncSchedule::Dapple,
            SyncSchedule::ZeroBubble,
            SyncSchedule::Hanayo { waves: 2 },
        ] {
            let r = run(s, 120);
            assert!(r.metrics.trained > 0, "{}", s.name());
            assert!(r.metrics.oacc.value() > 25.0, "{} oacc {}", s.name(), r.metrics.oacc.value());
            // arrivals come every max-layer-fwd tick but flights cost much
            // more: the queue must overflow
            assert!(r.metrics.dropped > 0, "{}", s.name());
            assert!(r.metrics.adaptation_rate() < 1.0);
        }
    }

    #[test]
    fn zb_adapts_at_least_as_fast_as_dapple() {
        let d = run(SyncSchedule::Dapple, 150);
        let z = run(SyncSchedule::ZeroBubble, 150);
        assert!(
            z.metrics.adaptation_rate() >= d.metrics.adaptation_rate(),
            "zb {} vs dapple {}",
            z.metrics.adaptation_rate(),
            d.metrics.adaptation_rate()
        );
    }

    #[test]
    fn hanayo_memory_grows_with_waves() {
        let m = model();
        let part = Partition::per_layer(m.num_layers());
        let prof = Profile::analytic(&m, 8);
        let m1 = SyncSchedule::Hanayo { waves: 1 }.mem_bytes(&part, &prof, 4);
        let m3 = SyncSchedule::Hanayo { waves: 3 }.mem_bytes(&part, &prof, 4);
        assert!(m3 > m1);
        let d = SyncSchedule::Dapple.mem_bytes(&part, &prof, 4);
        let z = SyncSchedule::ZeroBubble.mem_bytes(&part, &prof, 4);
        assert!(z > d);
    }

    #[test]
    fn deterministic() {
        let a = run(SyncSchedule::Dapple, 60);
        let b = run(SyncSchedule::Dapple, 60);
        assert_eq!(a.metrics.oacc.value(), b.metrics.oacc.value());
    }
}
