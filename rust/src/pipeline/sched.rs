//! Reusable scheduling core of the pipeline engines.
//!
//! Owns the mechanisms every schedule needs, independent of *where* the
//! math runs (see [`crate::pipeline::executor`]) and of the policy knobs
//! (compensation, plugins) the engine layers on top:
//!
//!   - [`Clock`] / [`Mode`] — *when* the schedule advances. A
//!     [`VirtualClock`] replays the analytic `tf`/`tb` costs through the
//!     event heap (lockstep: metrics identical on every executor); a
//!     [`WallClock`] stamps events with real elapsed microseconds so
//!     `Done` completions land whenever the device thread actually
//!     finishes and `Arrive` events are paced by real arrival intervals
//!     (freerun: staleness and latency are emergent, not simulated).
//!   - [`EventQueue`] — deterministic virtual-time event heap (ties broken
//!     by insertion order).
//!   - [`SchedCore`]  — (worker, stage) device slots with 1F1B
//!     backward-preemption priority, microbatch→worker round-robin
//!     routing, per-stage version counters, in-flight accounting and
//!     admission capacity. In freerun the same slots track in-flight
//!     [`Flight`]s paired FIFO with the executor's completion stream.
//!   - [`predict_only`] — the shared "over capacity: predict with live
//!     weights, drop from training" path used by both the async and the
//!     sync engines.

use std::borrow::Borrow;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::time::{Duration, Instant};

use crate::backend::{accuracy, forward_all, Backend};
use crate::bail;
use crate::config::LayerShape;
use crate::metrics::RunMetrics;
use crate::model::{GradBuf, LayerParams};
use crate::util::error::Result;

/// How an async engine advances time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Virtual time: the event heap replays analytic `tf`/`tb` costs.
    /// Deterministic; metrics are identical across executors.
    Lockstep,
    /// Wall-clock time: arrivals are paced in real microseconds and
    /// completions are stamped when device threads actually finish, so
    /// contention, stage imbalance, and staleness are observed, not
    /// simulated. Requires a seeded stream but is not bit-deterministic.
    Freerun,
}

impl Mode {
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Lockstep => "lockstep",
            Mode::Freerun => "freerun",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "lockstep" => Some(Mode::Lockstep),
            "freerun" => Some(Mode::Freerun),
            _ => None,
        }
    }
}

/// Time source of a pipeline run, in ticks. Lockstep ticks are the
/// analytic profile's virtual unit; freerun ticks are real microseconds.
pub trait Clock {
    /// Current time in ticks.
    fn now(&self) -> u64;
    /// Advance to `t`. Virtual clocks follow the event heap; wall clocks
    /// ignore this (real time advances on its own).
    fn advance(&mut self, t: u64);
}

/// Lockstep clock: a cursor the engine advances to each popped event.
#[derive(Debug, Default)]
pub struct VirtualClock {
    t: u64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { t: 0 }
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> u64 {
        self.t
    }

    fn advance(&mut self, t: u64) {
        self.t = self.t.max(t);
    }
}

/// Freerun clock: 1 tick = 1 microsecond since the run started.
#[derive(Debug)]
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        // ferret-lint: allow(det-time) — freerun mode is wall-clock by definition; lockstep never builds a WallClock
        WallClock { start: Instant::now() }
    }

    /// Sleep the scheduler thread until tick `t` (no-op if already past).
    pub fn sleep_until(&self, t: u64) {
        let now = self.now();
        if t > now {
            std::thread::sleep(Duration::from_micros(t - now));
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    fn advance(&mut self, _t: u64) {}
}

/// Scheduler event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Ev {
    /// next stream batch arrives
    Arrive,
    /// a (worker, stage) device finished a pass for a job
    Done { worker: usize, stage: usize, job: usize, bwd: bool },
}

/// Virtual-time event heap; equal-time events pop in push order.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(u64, u64, Ev)>>,
    seq: u64,
}

impl EventQueue {
    pub fn push(&mut self, t: u64, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse((t, self.seq, ev)));
    }

    pub fn pop(&mut self) -> Option<(u64, Ev)> {
        self.heap.pop().map(|Reverse((t, _, ev))| (t, ev))
    }

    /// Next event without removing it — the push-based session peeks so an
    /// `Arrive` whose batch has not been ingested yet can stall (rather
    /// than pop) and keep the heap's tie-break order intact.
    pub fn peek(&self) -> Option<(u64, Ev)> {
        self.heap.peek().map(|Reverse((t, _, ev))| (*t, *ev))
    }
}

/// One in-flight microbatch.
pub struct Job {
    pub arrival: u64,
    pub seq: u64,
    /// source batch id (preserved when the augment hook is offloaded and
    /// the device rebuilds the [`crate::stream::Batch`] itself)
    pub batch_id: u64,
    pub y: Vec<i32>,
    /// original input rows (LwF teacher forward)
    pub batch_x: Vec<f32>,
    /// freerun augment offload: the stage-0 forward carries the raw rows
    /// plus an `AugmentSpec`; until its completion patches this job, `y` /
    /// `batch_x` / `stage_inputs[0]` hold pre-augment values
    pub augment_pending: bool,
    /// per-stage input activations (filled as the forward advances)
    pub stage_inputs: Vec<Option<Vec<f32>>>,
    /// stage version each forward used (weight stashing)
    pub fwd_version: Vec<u64>,
    /// upstream grad flowing backward
    pub grad: Option<Vec<f32>>,
    pub done: bool,
}

/// Work in flight on a device in free-running mode, paired FIFO with the
/// executor's per-device completion stream (wall-clock runs have no
/// virtual `Done` events carrying the job id, so the scheduler remembers
/// what it shipped).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Flight {
    Fwd { job: usize },
    Bwd { job: usize },
    /// Parameter update shipped to the owning device thread; `arrivals`
    /// are the arrival stamps of the contributing microbatches (for the
    /// update-latency metrics when the completion lands).
    Update { arrivals: Vec<u64> },
}

/// One (worker, stage) device.
pub struct Slot {
    pub busy_until: u64,
    pub fwd_q: VecDeque<usize>,
    pub bwd_q: VecDeque<usize>,
    /// accumulated grads (per layer of the stage), T2
    pub acc: Option<Vec<GradBuf>>,
    pub acc_count: u64,
    pub acc_arrivals: Vec<u64>,
    pub acc_from_version: u64,
    /// freerun: dispatched-but-not-completed work with its dispatch
    /// stamp, in dispatch order (the stamp feeds measured per-stage
    /// service times into re-planning)
    pub flight: VecDeque<(Flight, u64)>,
}

impl Slot {
    fn new() -> Self {
        Slot {
            busy_until: 0,
            fwd_q: VecDeque::new(),
            bwd_q: VecDeque::new(),
            acc: None,
            acc_count: 0,
            acc_arrivals: Vec::new(),
            acc_from_version: u64::MAX,
            flight: VecDeque::new(),
        }
    }
}

/// Static per-stage metadata (layer range + virtual-time costs).
pub struct StageMeta {
    pub layers: std::ops::Range<usize>,
    pub tf: u64,
    pub tb: u64,
    pub params: usize,
}

/// Work selected for an idle device: job index to run backward/forward.
pub enum WorkSel {
    Bwd(usize),
    Fwd(usize),
}

/// Scheduling state shared by the async schedules: device slots, routing,
/// versions, events, and in-flight accounting. The engine applies policy
/// (omission, compensation, plugins) on top.
pub struct SchedCore {
    pub stages: Vec<StageMeta>,
    /// `slots[worker][stage]`
    pub slots: Vec<Vec<Slot>>,
    pub active_workers: Vec<usize>,
    /// per-stage parameter version counter
    pub version: Vec<u64>,
    pub jobs: Vec<Job>,
    pub events: EventQueue,
    pub inflight: usize,
    /// per-active-worker cap on in-flight jobs
    pub inflight_cap: usize,
}

impl SchedCore {
    pub fn new(stages: Vec<StageMeta>, n_workers: usize, active_workers: Vec<usize>) -> Self {
        let p = stages.len();
        let slots = (0..n_workers)
            .map(|_| (0..p).map(|_| Slot::new()).collect())
            .collect();
        SchedCore {
            stages,
            slots,
            active_workers,
            version: vec![0; p],
            jobs: Vec::new(),
            events: EventQueue::default(),
            inflight: 0,
            inflight_cap: 2 * (p + 1),
        }
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// True when a new arrival cannot be admitted for training.
    pub fn over_capacity(&self) -> bool {
        self.active_workers.is_empty()
            || self.inflight >= self.inflight_cap * self.active_workers.len()
    }

    /// Microbatch `seq` goes to active worker `seq mod N_active`.
    ///
    /// Errors when no worker is active — a degenerate plan (every worker
    /// delayed out of the schedule, or a mid-`reconfigure` window) used
    /// to panic here with a modulo-by-zero; callers that have already
    /// checked [`SchedCore::over_capacity`] (which is true whenever
    /// `active_workers` is empty) may safely `expect` the result.
    pub fn route(&self, seq: u64) -> Result<usize> {
        if self.active_workers.is_empty() {
            bail!(
                "sched: cannot route microbatch {seq}: the current plan \
                 has no active workers (degenerate plan or mid-transition)"
            );
        }
        Ok(self.active_workers[(seq as usize) % self.active_workers.len()])
    }

    /// Admit a job: queue its first forward on its routed worker. Returns
    /// (job id, worker); errors when the plan has no active worker to
    /// route to (see [`SchedCore::route`]).
    pub fn admit(&mut self, job: Job) -> Result<(usize, usize)> {
        let w = self.route(job.seq)?;
        self.jobs.push(job);
        self.inflight += 1;
        let id = self.jobs.len() - 1;
        self.slots[w][0].fwd_q.push_back(id);
        Ok((id, w))
    }

    /// 1F1B: pick the next queued work for device (w, s) at time `t` —
    /// backward work preempts queued forwards. `None` when the device is
    /// busy or idle with empty queues.
    pub fn select_work(&mut self, w: usize, s: usize, t: u64) -> Option<WorkSel> {
        if self.slots[w][s].busy_until > t {
            return None;
        }
        if let Some(job) = self.slots[w][s].bwd_q.pop_front() {
            return Some(WorkSel::Bwd(job));
        }
        self.slots[w][s].fwd_q.pop_front().map(WorkSel::Fwd)
    }

    /// Mark device (w, s) busy until `end` and schedule its completion.
    pub fn dispatch(&mut self, w: usize, s: usize, end: u64, job: usize, bwd: bool) {
        self.slots[w][s].busy_until = end;
        self.events.push(end, Ev::Done { worker: w, stage: s, job, bwd });
    }

    /// Freerun dispatch at wall time `t`: the device is busy until its
    /// real completion arrives (no virtual `Done` event); remember what
    /// flew — and when — so the completion can be paired FIFO and the
    /// service time measured. The `(flight, t)` stamp returned later by
    /// [`SchedCore::complete_flight`] doubles as the span interval for
    /// the observability recorder ([`crate::obs`]) and as the busy-time
    /// increment behind utilization accounting — it is the single source
    /// of truth for "device (w, s) worked from `t` to completion".
    pub fn dispatch_flight(&mut self, w: usize, s: usize, flight: Flight, t: u64) {
        self.slots[w][s].busy_until = u64::MAX;
        self.slots[w][s].flight.push_back((flight, t));
    }

    /// Pair a freerun completion with its dispatch (per-device FIFO) and
    /// free the device at wall time `t`. Returns the flight and its
    /// dispatch stamp.
    pub fn complete_flight(&mut self, w: usize, s: usize, t: u64) -> (Flight, u64) {
        let f = self.slots[w][s].flight.pop_front().expect("completion without flight");
        if self.slots[w][s].flight.is_empty() {
            self.slots[w][s].busy_until = t;
        }
        f
    }

    /// Retire a job from the in-flight set, freeing its payloads.
    pub fn retire(&mut self, job: usize) {
        let j = &mut self.jobs[job];
        j.done = true;
        j.stage_inputs = vec![];
        j.batch_x = vec![];
        j.grad = None;
        self.inflight -= 1;
    }

    /// Active (worker, stage) device pairs — the executor's thread set.
    pub fn devices(&self) -> Vec<(usize, usize)> {
        let p = self.num_stages();
        self.active_workers
            .iter()
            .flat_map(|&w| (0..p).map(move |s| (w, s)))
            .collect()
    }
}

/// Shared over-capacity path: predict the arriving batch with the live
/// weights and record it as dropped from training.
#[allow(clippy::too_many_arguments)]
pub fn predict_only<P: Borrow<LayerParams>>(
    backend: &dyn Backend,
    shapes: &[LayerShape],
    params: &[P],
    classes: usize,
    x: &[f32],
    y: &[i32],
    t: u64,
    metrics: &mut RunMetrics,
) {
    let (_, logits) = forward_all(backend, shapes, params, x, y.len());
    metrics.record_prediction(t, accuracy(classes, &logits, y));
    metrics.record_drop();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn core(workers: usize, stages: usize) -> SchedCore {
        let stages = (0..stages)
            .map(|j| StageMeta { layers: j..j + 1, tf: 10, tb: 20, params: 100 })
            .collect();
        SchedCore::new(stages, workers, (0..workers).collect())
    }

    fn job(seq: u64) -> Job {
        Job {
            arrival: seq * 10,
            seq,
            batch_id: seq,
            y: vec![0, 1],
            batch_x: vec![0.0; 4],
            augment_pending: false,
            stage_inputs: vec![Some(vec![0.0; 4]), None],
            fwd_version: vec![0; 2],
            grad: None,
            done: false,
        }
    }

    #[test]
    fn event_queue_orders_by_time_then_insertion() {
        let mut q = EventQueue::default();
        assert!(q.peek().is_none());
        q.push(5, Ev::Arrive);
        q.push(3, Ev::Done { worker: 0, stage: 0, job: 0, bwd: false });
        q.push(5, Ev::Done { worker: 1, stage: 0, job: 1, bwd: true });
        assert_eq!(q.peek().unwrap().0, 3, "peek sees the head");
        assert_eq!(q.pop().unwrap().0, 3);
        // equal times: first-pushed first
        assert_eq!(q.peek().unwrap(), (5, Ev::Arrive), "peek does not remove");
        assert_eq!(q.pop().unwrap(), (5, Ev::Arrive));
        assert!(matches!(q.pop().unwrap().1, Ev::Done { worker: 1, .. }));
        assert!(q.pop().is_none());
    }

    #[test]
    fn routing_round_robins_over_active_workers() {
        let mut c = core(3, 2);
        c.active_workers = vec![0, 2]; // worker 1 removed (T4)
        assert_eq!(c.route(0).unwrap(), 0);
        assert_eq!(c.route(1).unwrap(), 2);
        assert_eq!(c.route(2).unwrap(), 0);
    }

    #[test]
    fn routing_with_no_active_workers_is_a_typed_error() {
        let mut c = core(3, 2);
        c.active_workers.clear(); // degenerate plan: every worker delayed out
        let e = c.route(7).expect_err("empty active_workers must not panic");
        assert!(e.to_string().contains("no active workers"), "{e}");
        let e = c.admit(job(7)).expect_err("admit routes, so it fails too");
        assert!(e.to_string().contains("no active workers"), "{e}");
        assert_eq!(c.inflight, 0, "failed admit leaves no half-admitted job");
        assert!(c.jobs.is_empty());
        // over_capacity is the guard engines check before admitting
        assert!(c.over_capacity());
    }

    #[test]
    fn one_f_one_b_priority_and_busy_gating() {
        let mut c = core(1, 2);
        c.jobs.push(job(0));
        c.jobs.push(job(1));
        c.slots[0][0].fwd_q.push_back(0);
        c.slots[0][0].bwd_q.push_back(1);
        // backward preempts the queued forward
        assert!(matches!(c.select_work(0, 0, 0), Some(WorkSel::Bwd(1))));
        c.dispatch(0, 0, 25, 1, true);
        // device busy: nothing selectable before t=25
        assert!(c.select_work(0, 0, 10).is_none());
        assert!(matches!(c.select_work(0, 0, 25), Some(WorkSel::Fwd(0))));
    }

    #[test]
    fn admission_capacity_and_retire() {
        let mut c = core(1, 2);
        assert!(!c.over_capacity());
        let cap = c.inflight_cap;
        for i in 0..cap as u64 {
            c.admit(job(i)).unwrap();
        }
        assert!(c.over_capacity());
        c.retire(0);
        assert!(!c.over_capacity());
        assert!(c.jobs[0].done);
        assert!(c.jobs[0].stage_inputs.is_empty(), "payload freed");
        // no active workers -> always over capacity
        c.active_workers.clear();
        assert!(c.over_capacity());
    }

    #[test]
    fn devices_enumerates_active_worker_stages() {
        let mut c = core(2, 3);
        c.active_workers = vec![1];
        assert_eq!(c.devices(), vec![(1, 0), (1, 1), (1, 2)]);
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in [Mode::Lockstep, Mode::Freerun] {
            assert_eq!(Mode::parse(m.name()), Some(m));
        }
        assert_eq!(Mode::parse("warp"), None);
    }

    #[test]
    fn virtual_clock_follows_events_monotonically() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance(7);
        assert_eq!(c.now(), 7);
        // never runs backwards, even if the caller hands an older stamp
        c.advance(3);
        assert_eq!(c.now(), 7);
    }

    #[test]
    fn wall_clock_is_monotone_and_ignores_advance() {
        let mut c = WallClock::new();
        let a = c.now();
        c.advance(u64::MAX); // ignored
        std::thread::sleep(Duration::from_millis(2));
        let b = c.now();
        assert!(b >= a + 1000, "{a} -> {b}");
        c.sleep_until(b + 500);
        assert!(c.now() >= b + 500);
    }

    #[test]
    fn flights_pair_fifo_and_gate_the_device() {
        let mut c = core(1, 1);
        c.dispatch_flight(0, 0, Flight::Fwd { job: 3 }, 10);
        // busy for the whole flight: nothing selectable at any time
        c.slots[0][0].fwd_q.push_back(4);
        assert!(c.select_work(0, 0, u64::MAX - 1).is_none());
        c.dispatch_flight(0, 0, Flight::Update { arrivals: vec![1, 2] }, 20);
        // completion pairs FIFO and hands back the dispatch stamp
        assert_eq!(c.complete_flight(0, 0, 50), (Flight::Fwd { job: 3 }, 10));
        // still one flight outstanding -> still busy
        assert!(c.select_work(0, 0, 60).is_none());
        assert_eq!(
            c.complete_flight(0, 0, 80),
            (Flight::Update { arrivals: vec![1, 2] }, 20)
        );
        // freed at the completion stamp
        assert!(matches!(c.select_work(0, 0, 80), Some(WorkSel::Fwd(4))));
    }
}
