//! Fine-grained asynchronous pipeline engine (paper §5.1) — the system
//! under test. Also runs the PipeDream [58] and PipeDream-2BW [59]
//! baselines, which are specific points in Ferret's configuration space:
//!
//!   PipeDream       N interleaved workers, weight stashing, accum 1,
//!                   no omission, no recomputation.
//!   PipeDream-2BW   same but gradient accumulation 2 / double-buffered
//!                   weight versions.
//!   Ferret          a planned `PipeConfig` (T1–T4 per worker/stage) from
//!                   Alg. 2/3 + a gradient-compensation policy.
//!
//! Mechanics: the engine drives the scheduling core
//! ([`crate::pipeline::sched::SchedCore`] — event queue, 1F1B priority,
//! routing) and dispatches stage math to an
//! [`Executor`](crate::pipeline::executor::Executor): virtual-time
//! simulation inline ([`ExecutorKind::Sim`]) or genuinely parallel device
//! threads ([`ExecutorKind::Threaded`]). Each (worker, stage) pair is a
//! device with its own timeline; 1F1B priority (backward work preempts
//! queued forward work). Microbatch `i` goes to worker `i mod N_active`.
//! Stage parameters are shared across workers (asynchronous data-parallel
//! pipelining — the source of the staleness the compensation algorithms
//! fight). Weight stashing keeps, per layer, the `Arc` snapshots in-flight
//! forwards were computed with; Iter-Fisher walks the snapshot chain at
//! update time (Eq. 9).

use crate::backend::Backend;
use crate::compensate::{make, CompContext, CompKind, CompParams, Compensator};
use crate::config::{LayerShape, ModelSpec};
use crate::metrics::{eval_tacc, RunMetrics};
use crate::model::{GradBuf, LiveParams, StashSet};
use crate::ocl::{OclCtx, OclPlugin};
use crate::pipeline::executor::{Executor, ExecutorKind, SimExecutor, StageTask, ThreadedExecutor};
use crate::pipeline::sched::{predict_only, Ev, Job, SchedCore, StageMeta, WorkSel};
use crate::pipeline::{EngineParams, RunResult};
use crate::planner::costmodel::{mem_footprint, PipeConfig};
use crate::planner::{Partition, Profile};
use crate::stream::SyntheticStream;

/// Asynchronous schedule family (Table 3's right half).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AsyncSchedule {
    Pipedream,
    Pipedream2BW,
    Ferret,
}

impl AsyncSchedule {
    pub fn name(&self) -> &'static str {
        match self {
            AsyncSchedule::Pipedream => "Pipedream",
            AsyncSchedule::Pipedream2BW => "Pipedream2BW",
            AsyncSchedule::Ferret => "Ferret",
        }
    }
}

/// Full engine configuration.
pub struct AsyncCfg {
    pub schedule: AsyncSchedule,
    pub partition: Partition,
    pub pipe: PipeConfig,
    pub comp_kind: CompKind,
    pub comp_params: CompParams,
    /// call plugin.after_update every k-th stage update (teacher refresh)
    pub plugin_cadence: u64,
}

impl AsyncCfg {
    /// Baseline configs: PipeDream / 2BW from the initial (unreduced)
    /// configuration; Ferret from a planned `PipeConfig`.
    pub fn baseline(
        schedule: AsyncSchedule,
        partition: Partition,
        prof: &Profile,
        td: u64,
    ) -> Self {
        let stages = partition.num_stages();
        let (tf, tb) = (partition.tf(prof), partition.tb(prof));
        let mut pipe = PipeConfig::initial(stages, tf, tb, false, td);
        if schedule == AsyncSchedule::Pipedream2BW {
            for w in &mut pipe.workers {
                w.accum = vec![2; stages];
            }
        }
        AsyncCfg {
            schedule,
            partition,
            pipe,
            comp_kind: CompKind::NoComp,
            comp_params: CompParams::default(),
            plugin_cadence: 8,
        }
    }

    pub fn ferret(partition: Partition, pipe: PipeConfig, comp_kind: CompKind) -> Self {
        AsyncCfg {
            schedule: AsyncSchedule::Ferret,
            partition,
            pipe,
            comp_kind,
            comp_params: CompParams::default(),
            plugin_cadence: 8,
        }
    }
}

/// The engine proper: policy (stashing, compensation, plugins, metrics) on
/// top of the scheduling core, numeric work delegated to an executor.
pub struct AsyncEngine<'a> {
    backend: &'a dyn Backend,
    shapes: Vec<LayerShape>,
    cfg: AsyncCfg,
    sched: SchedCore,
    /// live parameters, one `Arc` per model layer (stages index into it)
    params: LiveParams,
    /// per-layer snapshot history
    stash: StashSet,
    /// per-layer compensators, shared across workers (λ and the EMA
    /// buffers are stage-level statistics — Alg. 1's O(2Σ|w|) memory)
    comps: Vec<Box<dyn Compensator>>,
    lr: f32,
    decay_c: f64,
    total_params: usize,
    update_count: u64,
}

impl<'a> AsyncEngine<'a> {
    pub fn new(
        backend: &'a dyn Backend,
        model: &ModelSpec,
        cfg: AsyncCfg,
        ep: &EngineParams,
    ) -> Self {
        let shapes = model.layers();
        let prof = Profile::analytic(model, 1); // sizes only here
        let stages: Vec<StageMeta> = (0..cfg.partition.num_stages())
            .map(|j| StageMeta {
                layers: cfg.partition.stage_layers(j),
                tf: 0,
                tb: 0,
                params: cfg.partition.stage_params(&prof, j),
            })
            .collect();
        let params = LiveParams::init(model, ep.seed);
        let n_workers = cfg.pipe.workers.len();
        let p = stages.len();
        let stash_cap = if ep.stash_cap > 0 {
            ep.stash_cap
        } else {
            n_workers * (p + 2) + 4
        };
        let stash = StashSet::new(&params, stash_cap);
        let active_workers: Vec<usize> = cfg
            .pipe
            .workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.active())
            .map(|(i, _)| i)
            .collect();
        let total_params: usize = shapes.iter().map(|s| s.param_count()).sum();
        let comps = shapes.iter().map(|_| make(cfg.comp_kind, cfg.comp_params)).collect();
        AsyncEngine {
            backend,
            shapes,
            cfg,
            sched: SchedCore::new(stages, n_workers, active_workers),
            params,
            stash,
            comps,
            lr: ep.lr,
            decay_c: 0.0, // resolved in run() once td is known
            total_params,
            update_count: 0,
        }
    }

    /// Active (worker, stage) devices — the executor's thread set.
    pub fn devices(&self) -> Vec<(usize, usize)> {
        self.sched.devices()
    }

    fn stage_times(&mut self, part_prof: &Profile) {
        for j in 0..self.sched.stages.len() {
            self.sched.stages[j].tf = self.cfg.partition.stage_tf(part_prof, j);
            self.sched.stages[j].tb = self.cfg.partition.stage_tb(part_prof, j);
            self.sched.stages[j].params = self.cfg.partition.stage_params(part_prof, j);
        }
    }

    /// Build the stage task for a forward on the live parameters.
    fn fwd_task(&self, s: usize, x: Vec<f32>, rows: usize) -> StageTask {
        let layers = self.sched.stages[s].layers.clone();
        StageTask {
            shapes: layers.clone().map(|l| self.shapes[l]).collect(),
            params: layers.map(|l| self.params.layers[l].clone()).collect(),
            x,
            rows,
            gout: None,
        }
    }

    /// Build the stage task for a backward against the stashed version
    /// `ver` (fallback: live = zero staleness).
    fn bwd_task(&self, s: usize, ver: u64, x: Vec<f32>, gout: Vec<f32>, rows: usize) -> StageTask {
        let layers = self.sched.stages[s].layers.clone();
        StageTask {
            shapes: layers.clone().map(|l| self.shapes[l]).collect(),
            params: layers.map(|l| self.stash.resolve(l, ver, &self.params)).collect(),
            x,
            rows,
            gout: Some(gout),
        }
    }

    /// Try to start work on a (worker, stage) device at time `t`.
    fn kick(&mut self, w: usize, s: usize, t: u64, executor: &mut dyn Executor) {
        loop {
            let sel = match self.sched.select_work(w, s, t) {
                None => return,
                Some(sel) => sel,
            };
            match sel {
                WorkSel::Bwd(job) => {
                    let omit = self.cfg.pipe.workers[w].omit[s];
                    if omit > 0 && self.sched.jobs[job].seq % (omit + 1) != 0 {
                        // T3: skip this backward (and the whole upstream
                        // chain); device still free — look for more work
                        self.sched.retire(job);
                        continue;
                    }
                    let rows = self.sched.jobs[job].y.len();
                    let ver = self.sched.jobs[job].fwd_version[s];
                    // both buffers are dead after this dispatch: the stage-s
                    // input was already consumed by the stage-s forward, and
                    // grad is overwritten with gx at the Done event
                    let x = self.sched.jobs[job].stage_inputs[s].take().expect("stage input");
                    let gout = self.sched.jobs[job].grad.take().expect("upstream grad");
                    executor.start((w, s), self.bwd_task(s, ver, x, gout, rows));
                    let mut dur = self.sched.stages[s].tb;
                    if self.cfg.pipe.workers[w].recompute {
                        dur += self.sched.stages[s].tf; // T1: extra forward
                    }
                    self.sched.dispatch(w, s, t + dur.max(1), job, true);
                    return;
                }
                WorkSel::Fwd(job) => {
                    let rows = self.sched.jobs[job].y.len();
                    let x = self.sched.jobs[job].stage_inputs[s].clone().expect("stage input");
                    self.sched.jobs[job].fwd_version[s] = self.sched.version[s];
                    executor.start((w, s), self.fwd_task(s, x, rows));
                    let end = t + self.sched.stages[s].tf.max(1);
                    self.sched.dispatch(w, s, end, job, false);
                    return;
                }
            }
        }
    }

    /// Apply an accumulated update on (worker, stage) at time `t`.
    fn apply_update(
        &mut self,
        w: usize,
        s: usize,
        t: u64,
        plugin: &mut dyn OclPlugin,
        ctx: &OclCtx,
        metrics: &mut RunMetrics,
    ) {
        let slot = &mut self.sched.slots[w][s];
        let mut grads = slot.acc.take().expect("accumulated grads");
        let count = slot.acc_count;
        let arrivals = std::mem::take(&mut slot.acc_arrivals);
        let from_ver = slot.acc_from_version;
        slot.acc_count = 0;
        slot.acc_from_version = u64::MAX;

        let scale = 1.0 / count as f32;
        let cur_ver = self.sched.version[s];
        let tau = cur_ver.saturating_sub(from_ver);
        let layers: Vec<usize> = self.sched.stages[s].layers.clone().collect();
        for (i, &l) in layers.iter().enumerate() {
            let mut g = std::mem::replace(&mut grads[i], GradBuf { gw: vec![], gb: vec![] });
            g.scale(scale);
            // compensation toward the live version; skip materializing the
            // delta chain (τ clones of the stage params) when the policy
            // does not consume it — the NoComp/StepAware hot path
            let (chain, jump) = if self.comps[l].needs_deltas() && tau > 0 {
                (
                    self.stash.delta_chain(l, from_ver, cur_ver).unwrap_or_default(),
                    self.stash.jump_delta(l, from_ver, cur_ver),
                )
            } else {
                (Vec::new(), None)
            };
            let cctx = CompContext {
                backend: self.backend,
                tau,
                chain: &chain,
                jump: jump.as_ref(),
                lr: self.lr,
            };
            let (mut g, lr_scale) = self.comps[l].compensate(g, &cctx);
            plugin.adjust_layer_grad(l, &mut g, &self.params.layers[l], ctx);
            let updated = self.backend.sgd(&self.params.layers[l], &g, self.lr * lr_scale);
            self.params.set(l, updated);
        }
        self.sched.version[s] += 1;
        let new_ver = self.sched.version[s];
        self.stash.push_stage(&layers, new_ver, &self.params);
        let frac = self.sched.stages[s].params as f64 / self.total_params as f64;
        for a in arrivals {
            metrics.record_update(t.saturating_sub(a), self.decay_c, frac);
        }
        self.update_count += 1;
        if self.update_count % self.cfg.plugin_cadence == 0 {
            plugin.after_update(&self.params.layers, ctx);
        }
    }

    /// Run to completion over the stream, dispatching stage math to
    /// `executor`.
    pub fn run(
        mut self,
        stream: &mut SyntheticStream,
        plugin: &mut dyn OclPlugin,
        ep: &EngineParams,
        model: &ModelSpec,
        executor: &mut dyn Executor,
    ) -> RunResult {
        let spec = stream.spec().clone();
        let prof = Profile::analytic(model, spec.batch);
        self.stage_times(&prof);
        let td = if ep.td == 0 { prof.default_td() } else { ep.td };
        self.decay_c = ep.decay(td);
        let shapes = self.shapes.clone();
        let ctx = OclCtx {
            backend: self.backend,
            shapes: &shapes,
            classes: spec.classes,
            batch: spec.batch,
            features: spec.features,
        };
        let mut metrics = RunMetrics::default();
        let test = stream.test_set(ep.tacc_per_class);
        metrics.exec_threads = executor.threads();
        let p = self.sched.num_stages();

        let mut arrived = 0u64;
        let mut next_batch = stream.next_batch();
        if next_batch.is_some() {
            self.sched.events.push(0, Ev::Arrive);
        }

        while let Some((t, ev)) = self.sched.events.pop() {
            match ev {
                Ev::Arrive => {
                    let batch = next_batch.take().expect("arrive without batch");
                    metrics.record_arrival();
                    let seq = arrived;
                    arrived += 1;
                    next_batch = stream.next_batch();
                    if next_batch.is_some() {
                        self.sched.events.push(arrived * td, Ev::Arrive);
                    }
                    if self.sched.over_capacity() {
                        // predict with live weights; drop from training
                        predict_only(
                            self.backend,
                            &self.shapes,
                            &self.params.layers,
                            spec.classes,
                            &batch.x,
                            &batch.y,
                            t,
                            &mut metrics,
                        );
                        continue;
                    }
                    let batch = plugin.augment(batch, &self.params.layers, &ctx);
                    let mut stage_inputs: Vec<Option<Vec<f32>>> = vec![None; p];
                    stage_inputs[0] = Some(batch.x.clone());
                    let (_, w) = self.sched.admit(Job {
                        arrival: t,
                        seq,
                        y: batch.y,
                        batch_x: batch.x,
                        stage_inputs,
                        fwd_version: vec![0; p],
                        grad: None,
                        done: false,
                    });
                    self.kick(w, 0, t, executor);
                }
                Ev::Done { worker: w, stage: s, job, bwd } => {
                    let result = executor.finish((w, s));
                    if !bwd {
                        if s + 1 < p {
                            self.sched.jobs[job].stage_inputs[s + 1] = Some(result.out);
                            self.sched.slots[w][s + 1].fwd_q.push_back(job);
                            self.kick(w, s + 1, t, executor);
                        } else {
                            // logits ready: prediction + loss head
                            let logits = result.out;
                            let (y, bx) = (
                                self.sched.jobs[job].y.clone(),
                                self.sched.jobs[job].batch_x.clone(),
                            );
                            metrics.record_prediction(
                                t,
                                crate::backend::accuracy(spec.classes, &logits, &y),
                            );
                            let (gl, loss) = plugin.loss_grad(&logits, &y, &bx, &ctx);
                            metrics.record_loss(t, loss);
                            self.sched.jobs[job].grad = Some(gl);
                            self.sched.slots[w][s].bwd_q.push_back(job);
                        }
                    } else {
                        // deliver the backward results to the accumulator
                        let grads = result.grads.expect("bwd grads");
                        let gx = result.out;
                        let slot = &mut self.sched.slots[w][s];
                        match &mut slot.acc {
                            None => slot.acc = Some(grads),
                            Some(a) => {
                                for (ag, g) in a.iter_mut().zip(&grads) {
                                    ag.add(g);
                                }
                            }
                        }
                        slot.acc_count += 1;
                        slot.acc_arrivals.push(self.sched.jobs[job].arrival);
                        slot.acc_from_version =
                            slot.acc_from_version.min(self.sched.jobs[job].fwd_version[s]);
                        if slot.acc_count >= self.cfg.pipe.workers[w].accum[s] {
                            self.apply_update(w, s, t, plugin, &ctx, &mut metrics);
                        }
                        if s > 0 {
                            self.sched.jobs[job].grad = Some(gx);
                            self.sched.slots[w][s - 1].bwd_q.push_back(job);
                            self.kick(w, s - 1, t, executor);
                        } else {
                            self.sched.retire(job);
                        }
                    }
                    self.kick(w, s, t, executor);
                    metrics.observe_live_bytes(self.stash.bytes());
                }
            }
        }

        // analytic memory (Eq. 4) + plugin + compensator state
        let comp_bytes: usize = self.comps.iter().map(|c| c.state_bytes()).sum();
        metrics.mem_bytes = mem_footprint(&self.cfg.partition, &prof, &self.cfg.pipe)
            + plugin.memory_bytes() as f64
            + comp_bytes as f64;
        metrics.tacc = eval_tacc(
            self.backend,
            &self.shapes,
            &self.params.layers,
            spec.classes,
            &test,
            spec.batch,
        );
        RunResult { metrics, params: self.params.layers }
    }
}

/// Build + run with an explicit executor choice. `Threaded` spawns one OS
/// thread per active (worker, stage) device for the duration of the run.
pub fn run_async_with(
    cfg: AsyncCfg,
    stream: &mut SyntheticStream,
    backend: &dyn Backend,
    plugin: &mut dyn OclPlugin,
    ep: &EngineParams,
    model: &ModelSpec,
    kind: ExecutorKind,
) -> RunResult {
    let engine = AsyncEngine::new(backend, model, cfg, ep);
    match kind {
        ExecutorKind::Sim => {
            let mut ex = SimExecutor::new(backend);
            engine.run(stream, plugin, ep, model, &mut ex)
        }
        ExecutorKind::Threaded => {
            let devices = engine.devices();
            std::thread::scope(|scope| {
                let mut ex = ThreadedExecutor::spawn(scope, backend, &devices);
                engine.run(stream, plugin, ep, model, &mut ex)
            })
        }
    }
}

/// Convenience: build + run in one call on the simulation executor.
pub fn run_async(
    cfg: AsyncCfg,
    stream: &mut SyntheticStream,
    backend: &dyn Backend,
    plugin: &mut dyn OclPlugin,
    ep: &EngineParams,
    model: &ModelSpec,
) -> RunResult {
    run_async_with(cfg, stream, backend, plugin, ep, model, ExecutorKind::Sim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::ocl::Vanilla;
    use crate::stream::{DriftKind, StreamSpec};

    fn mk_stream(n: usize, seed: u64) -> SyntheticStream {
        SyntheticStream::new(StreamSpec {
            name: "t".into(),
            features: 16,
            classes: 4,
            batch: 8,
            num_batches: n,
            kind: DriftKind::Stationary,
            margin: 3.0,
            noise: 0.5,
            seed,
        })
    }

    fn model() -> ModelSpec {
        ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
    }

    fn run_sched(schedule: AsyncSchedule, n: usize) -> RunResult {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        let cfg = AsyncCfg::baseline(schedule, part, &prof, td);
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        run_async(cfg, &mut mk_stream(n, 31), &NativeBackend, &mut Vanilla, &ep, &m)
    }

    #[test]
    fn pipedream_learns_with_low_drop_rate() {
        let r = run_sched(AsyncSchedule::Pipedream, 150);
        assert!(r.metrics.trained > 0);
        assert!(
            r.metrics.oacc.value() > 40.0,
            "oacc {} trained {} dropped {}",
            r.metrics.oacc.value(),
            r.metrics.trained,
            r.metrics.dropped
        );
        // interleaved workers keep up with the stream
        let drop_rate = r.metrics.dropped as f64 / 150.0;
        assert!(drop_rate < 0.2, "drop rate {drop_rate}");
        assert!(r.metrics.tacc > 70.0, "tacc {}", r.metrics.tacc);
    }

    #[test]
    fn async_lands_updates_quickly() {
        let r = run_sched(AsyncSchedule::Pipedream, 150);
        assert!(r.metrics.adaptation_rate() > 0.3, "{}", r.metrics.adaptation_rate());
    }

    #[test]
    fn twobw_uses_less_memory_than_pipedream() {
        let p = run_sched(AsyncSchedule::Pipedream, 80);
        let b = run_sched(AsyncSchedule::Pipedream2BW, 80);
        assert!(b.metrics.mem_bytes < p.metrics.mem_bytes);
        assert!(b.metrics.oacc.value() > 30.0);
    }

    #[test]
    fn ferret_with_planned_config_meets_budget_and_learns() {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let td = prof.default_td();
        let unconstrained = crate::planner::plan(&prof, td, f64::INFINITY, 1e-4);
        let budget = unconstrained.mem_bytes * 0.5;
        let planned = crate::planner::plan(&prof, td, budget, 1e-4);
        assert!(planned.feasible);
        let cfg =
            AsyncCfg::ferret(planned.partition.clone(), planned.config.clone(), CompKind::IterFisher);
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        let r = run_async(cfg, &mut mk_stream(150, 31), &NativeBackend, &mut Vanilla, &ep, &m);
        // engine memory = plan memory + compensator state
        assert!(r.metrics.oacc.value() > 35.0, "oacc {}", r.metrics.oacc.value());
        assert!(r.metrics.trained > 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_sched(AsyncSchedule::Pipedream, 60);
        let b = run_sched(AsyncSchedule::Pipedream, 60);
        assert_eq!(a.metrics.oacc.value(), b.metrics.oacc.value());
        assert_eq!(a.params[0].w, b.params[0].w);
    }

    #[test]
    fn omission_engine_still_learns() {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        let mut cfg = AsyncCfg::baseline(AsyncSchedule::Ferret, part.clone(), &prof, td);
        for w in &mut cfg.pipe.workers {
            w.omit[0] = (part.num_stages() - 1) as u64;
        }
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        let r = run_async(cfg, &mut mk_stream(80, 7), &NativeBackend, &mut Vanilla, &ep, &m);
        assert!(r.metrics.trained > 0);
        assert!(r.metrics.oacc.value() > 20.0, "oacc {}", r.metrics.oacc.value());
    }

    #[test]
    fn compensation_policies_all_run() {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        for kind in CompKind::all() {
            let base = AsyncCfg::baseline(AsyncSchedule::Ferret, part.clone(), &prof, td);
            let cfg = AsyncCfg { comp_kind: kind, ..base };
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            let r = run_async(cfg, &mut mk_stream(60, 3), &NativeBackend, &mut Vanilla, &ep, &m);
            assert!(r.metrics.trained > 0, "{}", kind.name());
            assert!(
                r.metrics.oacc.value() > 20.0,
                "{}: {}",
                kind.name(),
                r.metrics.oacc.value()
            );
        }
    }

    #[test]
    fn ocl_plugins_run_through_async_engine() {
        use crate::ocl::OclKind;
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        for kind in OclKind::all() {
            let cfg = AsyncCfg::baseline(AsyncSchedule::Ferret, part.clone(), &prof, td);
            let mut plugin = kind.build(5);
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            let r = run_async(cfg, &mut mk_stream(50, 9), &NativeBackend, plugin.as_mut(), &ep, &m);
            assert!(r.metrics.trained > 0, "{}", kind.name());
        }
    }

    #[test]
    fn threaded_executor_runs_every_schedule() {
        for schedule in [AsyncSchedule::Pipedream, AsyncSchedule::Pipedream2BW] {
            let m = model();
            let prof = Profile::analytic(&m, 8);
            let part = Partition::per_layer(m.num_layers());
            let td = prof.default_td();
            let cfg = AsyncCfg::baseline(schedule, part, &prof, td);
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            let r = run_async_with(
                cfg,
                &mut mk_stream(60, 31),
                &NativeBackend,
                &mut Vanilla,
                &ep,
                &m,
                ExecutorKind::Threaded,
            );
            assert!(r.metrics.trained > 0, "{}", schedule.name());
            assert!(r.metrics.exec_threads > 1, "{}", schedule.name());
        }
    }
}
