//! Fine-grained asynchronous pipeline engine (paper §5.1) — the system
//! under test. Also runs the PipeDream [58] and PipeDream-2BW [59]
//! baselines, which are specific points in Ferret's configuration space:
//!
//!   PipeDream       N interleaved workers, weight stashing, accum 1,
//!                   no omission, no recomputation.
//!   PipeDream-2BW   same but gradient accumulation 2 / double-buffered
//!                   weight versions.
//!   Ferret          a planned `PipeConfig` (T1–T4 per worker/stage) from
//!                   Alg. 2/3 + a gradient-compensation policy.
//!
//! Mechanics: a discrete-event simulation over virtual time. Each
//! (worker, stage) pair is a device with its own timeline; 1F1B priority
//! (backward work preempts queued forward work). Microbatch `i` goes to
//! worker `i mod N_active`. Stage parameters are shared across workers
//! (asynchronous data-parallel pipelining — the source of the staleness
//! the compensation algorithms fight). Weight stashing keeps, per layer,
//! the snapshots in-flight forwards were computed with; Iter-Fisher walks
//! the snapshot chain at update time (Eq. 9).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::backend::{accuracy, Backend};
use crate::compensate::{make, CompContext, CompKind, CompParams, Compensator};
use crate::config::{LayerShape, ModelSpec};
use crate::metrics::{eval_tacc, RunMetrics};
use crate::model::{GradBuf, LayerParams, ModelParams, VersionStash};
use crate::ocl::{OclCtx, OclPlugin};
use crate::pipeline::{EngineParams, RunResult};
use crate::planner::costmodel::{mem_footprint, PipeConfig};
use crate::planner::{Partition, Profile};
use crate::stream::SyntheticStream;

/// Asynchronous schedule family (Table 3's right half).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AsyncSchedule {
    Pipedream,
    Pipedream2BW,
    Ferret,
}

impl AsyncSchedule {
    pub fn name(&self) -> &'static str {
        match self {
            AsyncSchedule::Pipedream => "Pipedream",
            AsyncSchedule::Pipedream2BW => "Pipedream2BW",
            AsyncSchedule::Ferret => "Ferret",
        }
    }
}

/// Full engine configuration.
pub struct AsyncCfg {
    pub schedule: AsyncSchedule,
    pub partition: Partition,
    pub pipe: PipeConfig,
    pub comp_kind: CompKind,
    pub comp_params: CompParams,
    /// call plugin.after_update every k-th stage update (teacher refresh)
    pub plugin_cadence: u64,
}

impl AsyncCfg {
    /// Baseline configs: PipeDream / 2BW from the initial (unreduced)
    /// configuration; Ferret from a planned `PipeConfig`.
    pub fn baseline(
        schedule: AsyncSchedule,
        partition: Partition,
        prof: &Profile,
        td: u64,
    ) -> Self {
        let stages = partition.num_stages();
        let (tf, tb) = (partition.tf(prof), partition.tb(prof));
        let mut pipe = PipeConfig::initial(stages, tf, tb, false, td);
        if schedule == AsyncSchedule::Pipedream2BW {
            for w in &mut pipe.workers {
                w.accum = vec![2; stages];
            }
        }
        AsyncCfg {
            schedule,
            partition,
            pipe,
            comp_kind: CompKind::NoComp,
            comp_params: CompParams::default(),
            plugin_cadence: 8,
        }
    }

    pub fn ferret(partition: Partition, pipe: PipeConfig, comp_kind: CompKind) -> Self {
        AsyncCfg {
            schedule: AsyncSchedule::Ferret,
            partition,
            pipe,
            comp_kind,
            comp_params: CompParams::default(),
            plugin_cadence: 8,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// next stream batch arrives
    Arrive,
    /// a (worker, stage) device finished a pass for a job
    Done { worker: usize, stage: usize, job: usize, bwd: bool },
}

struct Job {
    arrival: u64,
    seq: u64,
    y: Vec<i32>,
    /// original input rows (LwF teacher forward)
    batch_x: Vec<f32>,
    /// per-stage input activations (filled as the forward advances)
    stage_inputs: Vec<Option<Vec<f32>>>,
    /// stage version each forward used (weight stashing)
    fwd_version: Vec<u64>,
    /// upstream grad flowing backward
    grad: Option<Vec<f32>>,
    /// per-layer grads computed by the in-progress backward (delivered at
    /// the Done event)
    pending_grads: Option<Vec<GradBuf>>,
    pending_gx: Option<Vec<f32>>,
    done: bool,
}

/// One (worker, stage) device.
struct Slot {
    busy_until: u64,
    fwd_q: VecDeque<usize>,
    bwd_q: VecDeque<usize>,
    /// accumulated grads (per layer of the stage), T2
    acc: Option<Vec<GradBuf>>,
    acc_count: u64,
    acc_arrivals: Vec<u64>,
    acc_from_version: u64,
}

struct StageMeta {
    layers: std::ops::Range<usize>,
    tf: u64,
    tb: u64,
    params: usize,
}

/// The engine proper.
pub struct AsyncEngine<'a> {
    backend: &'a dyn Backend,
    shapes: Vec<LayerShape>,
    cfg: AsyncCfg,
    stages: Vec<StageMeta>,
    /// live parameters, one entry per model layer (stages index into it)
    params: Vec<LayerParams>,
    /// per-stage version counter
    version: Vec<u64>,
    /// per-layer snapshot history
    stash: Vec<VersionStash>,
    /// slots[worker][stage]
    slots: Vec<Vec<Slot>>,
    active_workers: Vec<usize>,
    /// per-layer compensators, shared across workers (λ and the EMA
    /// buffers are stage-level statistics — Alg. 1's O(2Σ|w|) memory)
    comps: Vec<Box<dyn Compensator>>,
    jobs: Vec<Job>,
    heap: BinaryHeap<Reverse<(u64, u64, Ev)>>,
    heap_seq: u64,
    lr: f32,
    decay_c: f64,
    total_params: usize,
    update_count: u64,
    inflight: usize,
    inflight_cap: usize,
}

impl<'a> AsyncEngine<'a> {
    pub fn new(
        backend: &'a dyn Backend,
        model: &ModelSpec,
        cfg: AsyncCfg,
        ep: &EngineParams,
    ) -> Self {
        let shapes = model.layers();
        let prof = Profile::analytic(model, 1); // sizes only here
        let stages: Vec<StageMeta> = (0..cfg.partition.num_stages())
            .map(|j| StageMeta {
                layers: cfg.partition.stage_layers(j),
                tf: 0,
                tb: 0,
                params: cfg.partition.stage_params(&prof, j),
            })
            .collect();
        let params = ModelParams::init(model, ep.seed).layers;
        let n_workers = cfg.pipe.workers.len();
        let p = stages.len();
        let stash_cap = n_workers * (p + 2) + 4;
        let stash: Vec<VersionStash> = params
            .iter()
            .map(|lp| {
                let mut s = VersionStash::new(stash_cap.max(2));
                s.push(0, lp.clone());
                s
            })
            .collect();
        let slots: Vec<Vec<Slot>> = (0..n_workers)
            .map(|_| {
                (0..p)
                    .map(|_| Slot {
                        busy_until: 0,
                        fwd_q: VecDeque::new(),
                        bwd_q: VecDeque::new(),
                        acc: None,
                        acc_count: 0,
                        acc_arrivals: Vec::new(),
                        acc_from_version: u64::MAX,
                    })
                    .collect()
            })
            .collect();
        let active_workers: Vec<usize> = cfg
            .pipe
            .workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.active())
            .map(|(i, _)| i)
            .collect();
        let total_params: usize = shapes.iter().map(|s| s.param_count()).sum();
        let comps = shapes.iter().map(|_| make(cfg.comp_kind, cfg.comp_params)).collect();
        AsyncEngine {
            backend,
            shapes,
            cfg,
            stages,
            params,
            version: vec![0; p],
            stash,
            slots,
            active_workers,
            comps,
            jobs: Vec::new(),
            heap: BinaryHeap::new(),
            heap_seq: 0,
            lr: ep.lr,
            decay_c: 0.0, // resolved in run() once td is known
            total_params,
            update_count: 0,
            inflight: 0,
            inflight_cap: 2 * (p + 1),
        }
    }

    fn push_ev(&mut self, t: u64, ev: Ev) {
        self.heap_seq += 1;
        self.heap.push(Reverse((t, self.heap_seq, ev)));
    }

    fn stage_times(&mut self, part_prof: &Profile) {
        for j in 0..self.stages.len() {
            self.stages[j].tf = self.cfg.partition.stage_tf(part_prof, j);
            self.stages[j].tb = self.cfg.partition.stage_tb(part_prof, j);
            self.stages[j].params = self.cfg.partition.stage_params(part_prof, j);
        }
    }

    /// Forward one stage's layer chain on the live parameters.
    fn stage_fwd(&self, stage: usize, x: &[f32], rows: usize) -> Vec<f32> {
        let mut h = x.to_vec();
        for l in self.stages[stage].layers.clone() {
            h = self.backend.dense_fwd(&self.shapes[l], &self.params[l], &h, rows);
        }
        h
    }

    /// Backward one stage using stashed parameters of `ver`, recomputing
    /// inner activations from the stashed stage input.
    fn stage_bwd(
        &self,
        stage: usize,
        ver: u64,
        x: &[f32],
        gout: &[f32],
        rows: usize,
    ) -> (Vec<f32>, Vec<GradBuf>) {
        let layers: Vec<usize> = self.stages[stage].layers.clone().collect();
        // resolve stashed params (fallback: live = zero staleness)
        let stage_params: Vec<&LayerParams> = layers
            .iter()
            .map(|&l| self.stash[l].get(ver).unwrap_or(&self.params[l]))
            .collect();
        // recompute inner activations (T1-style; numerically identical)
        let mut inputs: Vec<Vec<f32>> = Vec::with_capacity(layers.len());
        let mut h = x.to_vec();
        for (i, &l) in layers.iter().enumerate() {
            inputs.push(h.clone());
            if i + 1 < layers.len() {
                h = self.backend.dense_fwd(&self.shapes[l], stage_params[i], &h, rows);
            }
        }
        let mut grads: Vec<Option<GradBuf>> = layers.iter().map(|_| None).collect();
        let mut g = gout.to_vec();
        for i in (0..layers.len()).rev() {
            let l = layers[i];
            let out = self
                .backend
                .dense_bwd(&self.shapes[l], stage_params[i], &inputs[i], &g, rows);
            g = out.gx;
            grads[i] = Some(out.grads);
        }
        (g, grads.into_iter().map(Option::unwrap).collect())
    }

    /// Try to start work on a (worker, stage) device at time `t`.
    fn kick(&mut self, w: usize, s: usize, t: u64) {
        loop {
            if self.slots[w][s].busy_until > t {
                return;
            }
            // 1F1B: backward first
            if let Some(job) = self.slots[w][s].bwd_q.pop_front() {
                let omit = self.cfg.pipe.workers[w].omit[s];
                if omit > 0 && self.jobs[job].seq % (omit + 1) != 0 {
                    // T3: skip this backward (and the whole upstream chain)
                    self.jobs[job].done = true;
                    self.inflight -= 1;
                    continue; // device still free: look for more work
                }
                let rows = self.jobs[job].y.len();
                let ver = self.jobs[job].fwd_version[s];
                let x = self.jobs[job].stage_inputs[s].clone().expect("stage input");
                let gout = self.jobs[job].grad.clone().expect("upstream grad");
                let (gx, grads) = self.stage_bwd(s, ver, &x, &gout, rows);
                self.jobs[job].pending_gx = Some(gx);
                self.jobs[job].pending_grads = Some(grads);
                let mut dur = self.stages[s].tb;
                if self.cfg.pipe.workers[w].recompute {
                    dur += self.stages[s].tf; // T1: extra forward pass
                }
                let end = t + dur.max(1);
                self.slots[w][s].busy_until = end;
                self.push_ev(end, Ev::Done { worker: w, stage: s, job, bwd: true });
                return;
            }
            if let Some(job) = self.slots[w][s].fwd_q.pop_front() {
                let rows = self.jobs[job].y.len();
                let x = self.jobs[job].stage_inputs[s].clone().expect("stage input");
                let out = self.stage_fwd(s, &x, rows);
                self.jobs[job].fwd_version[s] = self.version[s];
                if s + 1 < self.stages.len() {
                    self.jobs[job].stage_inputs[s + 1] = Some(out);
                } else {
                    self.jobs[job].pending_gx = Some(out); // logits parked here
                }
                let end = t + self.stages[s].tf.max(1);
                self.slots[w][s].busy_until = end;
                self.push_ev(end, Ev::Done { worker: w, stage: s, job, bwd: false });
                return;
            }
            return;
        }
    }

    /// Apply an accumulated update on (worker, stage) at time `t`.
    fn apply_update(
        &mut self,
        w: usize,
        s: usize,
        t: u64,
        plugin: &mut dyn OclPlugin,
        ctx: &OclCtx,
        metrics: &mut RunMetrics,
    ) {
        let slot = &mut self.slots[w][s];
        let mut grads = slot.acc.take().expect("accumulated grads");
        let count = slot.acc_count;
        let arrivals = std::mem::take(&mut slot.acc_arrivals);
        let from_ver = slot.acc_from_version;
        slot.acc_count = 0;
        slot.acc_from_version = u64::MAX;

        let scale = 1.0 / count as f32;
        let cur_ver = self.version[s];
        let tau = cur_ver.saturating_sub(from_ver);
        let layers: Vec<usize> = self.stages[s].layers.clone().collect();
        for (i, &l) in layers.iter().enumerate() {
            let mut g = std::mem::replace(&mut grads[i], GradBuf { gw: vec![], gb: vec![] });
            g.scale(scale);
            // compensation toward the live version; skip materializing the
            // delta chain (τ clones of the stage params) when the policy
            // does not consume it — the NoComp/StepAware hot path
            let (chain, jump) = if self.comps[l].needs_deltas() && tau > 0 {
                (
                    self.stash[l].delta_chain(from_ver, cur_ver).unwrap_or_default(),
                    self.stash[l].jump_delta(from_ver, cur_ver),
                )
            } else {
                (Vec::new(), None)
            };
            let cctx = CompContext {
                backend: self.backend,
                tau,
                chain: &chain,
                jump: jump.as_ref(),
                lr: self.lr,
            };
            let (mut g, lr_scale) = self.comps[l].compensate(g, &cctx);
            plugin.adjust_layer_grad(l, &mut g, &self.params[l], ctx);
            self.params[l] = self.backend.sgd(&self.params[l], &g, self.lr * lr_scale);
        }
        self.version[s] += 1;
        let new_ver = self.version[s];
        for &l in &layers {
            self.stash[l].push(new_ver, self.params[l].clone());
        }
        let frac = self.stages[s].params as f64 / self.total_params as f64;
        for a in arrivals {
            metrics.record_update(t.saturating_sub(a), self.decay_c, frac);
        }
        self.update_count += 1;
        if self.update_count % self.cfg.plugin_cadence == 0 {
            plugin.after_update(&self.params, ctx);
        }
    }

    fn live_stash_bytes(&self) -> usize {
        self.stash.iter().map(|s| s.bytes()).sum()
    }

    /// Run to completion over the stream.
    pub fn run(
        mut self,
        stream: &mut SyntheticStream,
        plugin: &mut dyn OclPlugin,
        ep: &EngineParams,
        model: &ModelSpec,
    ) -> RunResult {
        let spec = stream.spec().clone();
        let prof = Profile::analytic(model, spec.batch);
        self.stage_times(&prof);
        let td = if ep.td == 0 { prof.default_td() } else { ep.td };
        self.decay_c = ep.decay(td);
        let shapes = self.shapes.clone();
        let ctx = OclCtx {
            backend: self.backend,
            shapes: &shapes,
            classes: spec.classes,
            batch: spec.batch,
            features: spec.features,
        };
        let mut metrics = RunMetrics::default();
        let test = stream.test_set(ep.tacc_per_class);
        let p = self.stages.len();

        let mut arrived = 0u64;
        let mut next_batch = stream.next_batch();
        if next_batch.is_some() {
            self.push_ev(0, Ev::Arrive);
        }

        while let Some(Reverse((t, _, ev))) = self.heap.pop() {
            match ev {
                Ev::Arrive => {
                    let batch = next_batch.take().expect("arrive without batch");
                    metrics.record_arrival();
                    let seq = arrived;
                    arrived += 1;
                    next_batch = stream.next_batch();
                    if next_batch.is_some() {
                        self.push_ev(arrived * td, Ev::Arrive);
                    }
                    let over_capacity = self.active_workers.is_empty()
                        || self.inflight >= self.inflight_cap * self.active_workers.len();
                    if over_capacity {
                        // predict with live weights; drop from training
                        let (_, logits) = crate::backend::forward_all(
                            self.backend,
                            &self.shapes,
                            &self.params,
                            &batch.x,
                            batch.y.len(),
                        );
                        metrics.record_prediction(t, accuracy(spec.classes, &logits, &batch.y));
                        metrics.record_drop();
                        continue;
                    }
                    let w = self.active_workers[(seq as usize) % self.active_workers.len()];
                    let batch = plugin.augment(batch, &self.params, &ctx);
                    let mut stage_inputs: Vec<Option<Vec<f32>>> = vec![None; p];
                    stage_inputs[0] = Some(batch.x.clone());
                    self.jobs.push(Job {
                        arrival: t,
                        seq,
                        y: batch.y,
                        batch_x: batch.x,
                        stage_inputs,
                        fwd_version: vec![0; p],
                        grad: None,
                        pending_grads: None,
                        pending_gx: None,
                        done: false,
                    });
                    self.inflight += 1;
                    let id = self.jobs.len() - 1;
                    self.slots[w][0].fwd_q.push_back(id);
                    self.kick(w, 0, t);
                }
                Ev::Done { worker: w, stage: s, job, bwd } => {
                    if !bwd {
                        if s + 1 < p {
                            self.slots[w][s + 1].fwd_q.push_back(job);
                            self.kick(w, s + 1, t);
                        } else {
                            // logits ready: prediction + loss head
                            let logits = self.jobs[job].pending_gx.take().expect("logits");
                            let (y, bx) =
                                (self.jobs[job].y.clone(), self.jobs[job].batch_x.clone());
                            metrics.record_prediction(t, accuracy(spec.classes, &logits, &y));
                            let (gl, loss) = plugin.loss_grad(&logits, &y, &bx, &ctx);
                            metrics.record_loss(t, loss);
                            self.jobs[job].grad = Some(gl);
                            self.slots[w][s].bwd_q.push_back(job);
                        }
                    } else {
                        // deliver the backward results computed at dispatch
                        let grads = self.jobs[job].pending_grads.take().expect("grads");
                        let gx = self.jobs[job].pending_gx.take().expect("gx");
                        let slot = &mut self.slots[w][s];
                        match &mut slot.acc {
                            None => slot.acc = Some(grads),
                            Some(a) => {
                                for (ag, g) in a.iter_mut().zip(&grads) {
                                    ag.add(g);
                                }
                            }
                        }
                        slot.acc_count += 1;
                        slot.acc_arrivals.push(self.jobs[job].arrival);
                        slot.acc_from_version =
                            slot.acc_from_version.min(self.jobs[job].fwd_version[s]);
                        if slot.acc_count >= self.cfg.pipe.workers[w].accum[s] {
                            self.apply_update(w, s, t, plugin, &ctx, &mut metrics);
                        }
                        if s > 0 {
                            self.jobs[job].grad = Some(gx);
                            self.slots[w][s - 1].bwd_q.push_back(job);
                            self.kick(w, s - 1, t);
                        } else {
                            self.jobs[job].done = true;
                            self.inflight -= 1;
                            // free payloads
                            self.jobs[job].stage_inputs = vec![];
                            self.jobs[job].batch_x = vec![];
                            self.jobs[job].grad = None;
                        }
                    }
                    self.kick(w, s, t);
                    metrics.observe_live_bytes(self.live_stash_bytes());
                }
            }
        }

        // analytic memory (Eq. 4) + plugin + compensator state
        let comp_bytes: usize = self.comps.iter().map(|c| c.state_bytes()).sum();
        metrics.mem_bytes = mem_footprint(&self.cfg.partition, &prof, &self.cfg.pipe)
            + plugin.memory_bytes() as f64
            + comp_bytes as f64;
        metrics.tacc = eval_tacc(
            self.backend,
            &self.shapes,
            &self.params,
            spec.classes,
            &test,
            spec.batch,
        );
        RunResult { metrics, params: self.params }
    }
}

/// Convenience: build + run in one call.
pub fn run_async(
    cfg: AsyncCfg,
    stream: &mut SyntheticStream,
    backend: &dyn Backend,
    plugin: &mut dyn OclPlugin,
    ep: &EngineParams,
    model: &ModelSpec,
) -> RunResult {
    AsyncEngine::new(backend, model, cfg, ep).run(stream, plugin, ep, model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::ocl::Vanilla;
    use crate::stream::{DriftKind, StreamSpec};

    fn mk_stream(n: usize, seed: u64) -> SyntheticStream {
        SyntheticStream::new(StreamSpec {
            name: "t".into(),
            features: 16,
            classes: 4,
            batch: 8,
            num_batches: n,
            kind: DriftKind::Stationary,
            margin: 3.0,
            noise: 0.5,
            seed,
        })
    }

    fn model() -> ModelSpec {
        ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
    }

    fn run_sched(schedule: AsyncSchedule, n: usize) -> RunResult {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        let cfg = AsyncCfg::baseline(schedule, part, &prof, td);
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        run_async(cfg, &mut mk_stream(n, 31), &NativeBackend, &mut Vanilla, &ep, &m)
    }

    #[test]
    fn pipedream_learns_with_low_drop_rate() {
        let r = run_sched(AsyncSchedule::Pipedream, 150);
        assert!(r.metrics.trained > 0);
        assert!(
            r.metrics.oacc.value() > 40.0,
            "oacc {} trained {} dropped {}",
            r.metrics.oacc.value(),
            r.metrics.trained,
            r.metrics.dropped
        );
        // interleaved workers keep up with the stream
        let drop_rate = r.metrics.dropped as f64 / 150.0;
        assert!(drop_rate < 0.2, "drop rate {drop_rate}");
        assert!(r.metrics.tacc > 70.0, "tacc {}", r.metrics.tacc);
    }

    #[test]
    fn async_lands_updates_quickly() {
        let r = run_sched(AsyncSchedule::Pipedream, 150);
        assert!(r.metrics.adaptation_rate() > 0.3, "{}", r.metrics.adaptation_rate());
    }

    #[test]
    fn twobw_uses_less_memory_than_pipedream() {
        let p = run_sched(AsyncSchedule::Pipedream, 80);
        let b = run_sched(AsyncSchedule::Pipedream2BW, 80);
        assert!(b.metrics.mem_bytes < p.metrics.mem_bytes);
        assert!(b.metrics.oacc.value() > 30.0);
    }

    #[test]
    fn ferret_with_planned_config_meets_budget_and_learns() {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let td = prof.default_td();
        let unconstrained = crate::planner::plan(&prof, td, f64::INFINITY, 1e-4);
        let budget = unconstrained.mem_bytes * 0.5;
        let planned = crate::planner::plan(&prof, td, budget, 1e-4);
        assert!(planned.feasible);
        let cfg =
            AsyncCfg::ferret(planned.partition.clone(), planned.config.clone(), CompKind::IterFisher);
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        let r = run_async(cfg, &mut mk_stream(150, 31), &NativeBackend, &mut Vanilla, &ep, &m);
        // engine memory = plan memory + compensator state
        assert!(r.metrics.oacc.value() > 35.0, "oacc {}", r.metrics.oacc.value());
        assert!(r.metrics.trained > 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_sched(AsyncSchedule::Pipedream, 60);
        let b = run_sched(AsyncSchedule::Pipedream, 60);
        assert_eq!(a.metrics.oacc.value(), b.metrics.oacc.value());
        assert_eq!(a.params[0].w, b.params[0].w);
    }

    #[test]
    fn omission_engine_still_learns() {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        let mut cfg = AsyncCfg::baseline(AsyncSchedule::Ferret, part.clone(), &prof, td);
        for w in &mut cfg.pipe.workers {
            w.omit[0] = (part.num_stages() - 1) as u64;
        }
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        let r = run_async(cfg, &mut mk_stream(80, 7), &NativeBackend, &mut Vanilla, &ep, &m);
        assert!(r.metrics.trained > 0);
        assert!(r.metrics.oacc.value() > 20.0, "oacc {}", r.metrics.oacc.value());
    }

    #[test]
    fn compensation_policies_all_run() {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        for kind in CompKind::all() {
            let base = AsyncCfg::baseline(AsyncSchedule::Ferret, part.clone(), &prof, td);
            let cfg = AsyncCfg { comp_kind: kind, ..base };
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            let r = run_async(cfg, &mut mk_stream(60, 3), &NativeBackend, &mut Vanilla, &ep, &m);
            assert!(r.metrics.trained > 0, "{}", kind.name());
            assert!(
                r.metrics.oacc.value() > 20.0,
                "{}: {}",
                kind.name(),
                r.metrics.oacc.value()
            );
        }
    }

    #[test]
    fn ocl_plugins_run_through_async_engine() {
        use crate::ocl::OclKind;
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        for kind in OclKind::all() {
            let cfg = AsyncCfg::baseline(AsyncSchedule::Ferret, part.clone(), &prof, td);
            let mut plugin = kind.build(5);
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            let r = run_async(cfg, &mut mk_stream(50, 9), &NativeBackend, plugin.as_mut(), &ep, &m);
            assert!(r.metrics.trained > 0, "{}", kind.name());
        }
    }
}
