//! Fine-grained asynchronous pipeline engine (paper §5.1) — the system
//! under test. Also runs the PipeDream [58] and PipeDream-2BW [59]
//! baselines, which are specific points in Ferret's configuration space:
//!
//!   PipeDream       N interleaved workers, weight stashing, accum 1,
//!                   no omission, no recomputation.
//!   PipeDream-2BW   same but gradient accumulation 2 / double-buffered
//!                   weight versions.
//!   Ferret          a planned `PipeConfig` (T1–T4 per worker/stage) from
//!                   Alg. 2/3 + a gradient-compensation policy.
//!
//! Mechanics: the engine drives the scheduling core
//! ([`crate::pipeline::sched::SchedCore`] — event queue, 1F1B priority,
//! routing) and dispatches stage math to an
//! [`Executor`](crate::pipeline::executor::Executor): virtual-time
//! simulation inline ([`crate::pipeline::executor::ExecutorKind::Sim`]) or
//! genuinely parallel device threads
//! ([`crate::pipeline::executor::ExecutorKind::Threaded`]). The run loop
//! itself lives in [`crate::pipeline::session`]: a
//! [`Session`](crate::pipeline::session::Session) owns the clocks, the
//! budget cursor, and the executor, and drives this engine's
//! step methods either incrementally (push-based `ingest`/`step`) or to
//! completion (`run_stream` / the [`run_async_with`] shim).
//! Each (worker, stage) pair is a
//! device with its own timeline; 1F1B priority (backward work preempts
//! queued forward work). Microbatch `i` goes to worker `i mod N_active`.
//! Stage parameters are shared across workers (asynchronous data-parallel
//! pipelining — the source of the staleness the compensation algorithms
//! fight). Weight stashing keeps, per layer, the `Arc` snapshots in-flight
//! forwards were computed with; Iter-Fisher walks the snapshot chain at
//! update time (Eq. 9).

use std::sync::Arc;

use crate::backend::{Backend, Workspace};
use crate::budget::{BudgetSchedule, LedgerSnapshot};
use crate::compensate::{make, CompContext, CompKind, CompParams, Compensator};
use crate::config::{LayerShape, ModelSpec};
use crate::metrics::RunMetrics;
use crate::model::{GradBuf, LiveParams, SharedParams, StashSet};
use crate::obs::{Recorder, SpanKind};
use crate::ocl::{OclCtx, OclPlugin, PluginCell};
use crate::pipeline::executor::{
    recycle_grad, recycle_params, AugmentSpec, DeviceTask, Executor, LossSpec, StageCell,
    StageTask, UpdateTask,
};
use crate::pipeline::sched::{predict_only, Flight, Job, SchedCore, StageMeta, WorkSel};
use crate::pipeline::EngineParams;
use crate::planner::costmodel::{plan_versions, PipeConfig};
use crate::planner::{Partition, PlanOutcome, Profile};
use crate::stream::Batch;
use crate::util::error::{bail, Context, Result};

// The one-call entry points are thin shims over the session API; re-export
// them here so `pipeline::engine::{run_async, run_async_with}` keeps
// resolving for existing callers and tests.
// ferret-lint: allow(layering) — re-export shim for the frozen legacy entry points
pub use crate::pipeline::session::{run_async, run_async_with};

/// Asynchronous schedule family (Table 3's right half).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AsyncSchedule {
    Pipedream,
    Pipedream2BW,
    Ferret,
}

impl AsyncSchedule {
    pub fn name(&self) -> &'static str {
        match self {
            AsyncSchedule::Pipedream => "Pipedream",
            AsyncSchedule::Pipedream2BW => "Pipedream2BW",
            AsyncSchedule::Ferret => "Ferret",
        }
    }
}

/// Full engine configuration.
pub struct AsyncCfg {
    pub schedule: AsyncSchedule,
    pub partition: Partition,
    pub pipe: PipeConfig,
    pub comp_kind: CompKind,
    pub comp_params: CompParams,
    /// call plugin.after_update every k-th stage update (teacher refresh)
    pub plugin_cadence: u64,
    /// time-varying memory budget; when dynamic, the engine meters the
    /// memory ledger against the budget in force and executes a plan
    /// transition at each schedule step (or ledger breach)
    pub budget: BudgetSchedule,
}

impl AsyncCfg {
    /// Baseline configs: PipeDream / 2BW from the initial (unreduced)
    /// configuration; Ferret from a planned `PipeConfig`.
    pub fn baseline(
        schedule: AsyncSchedule,
        partition: Partition,
        prof: &Profile,
        td: u64,
    ) -> Self {
        let stages = partition.num_stages();
        let (tf, tb) = (partition.tf(prof), partition.tb(prof));
        let mut pipe = PipeConfig::initial(stages, tf, tb, false, td);
        if schedule == AsyncSchedule::Pipedream2BW {
            for w in &mut pipe.workers {
                w.accum = vec![2; stages];
            }
        }
        AsyncCfg {
            schedule,
            partition,
            pipe,
            comp_kind: CompKind::NoComp,
            comp_params: CompParams::default(),
            plugin_cadence: 8,
            budget: BudgetSchedule::fixed(),
        }
    }

    pub fn ferret(partition: Partition, pipe: PipeConfig, comp_kind: CompKind) -> Self {
        AsyncCfg {
            schedule: AsyncSchedule::Ferret,
            partition,
            pipe,
            comp_kind,
            comp_params: CompParams::default(),
            plugin_cadence: 8,
            budget: BudgetSchedule::fixed(),
        }
    }

    /// Attach a time-varying budget schedule (mid-stream re-planning).
    pub fn with_budget(mut self, budget: BudgetSchedule) -> Self {
        self.budget = budget;
        self
    }
}

/// Per-call engine I/O bundle: the policy hooks and metric sinks every
/// engine step needs. The owning [`crate::pipeline::session::Session`]
/// assembles one from its own (disjoint) fields at each step, which keeps
/// the step methods' signatures small and the borrows untangled.
pub(crate) struct EngineIo<'e> {
    pub(crate) plugin: &'e mut dyn OclPlugin,
    pub(crate) ctx: OclCtx<'e>,
    pub(crate) metrics: &'e mut RunMetrics,
    pub(crate) executor: &'e mut dyn Executor,
}

/// The engine proper: policy (stashing, compensation, plugins, metrics) on
/// top of the scheduling core, numeric work delegated to an executor. The
/// step methods are driven incrementally by
/// [`crate::pipeline::session::Session`], which owns the loop state
/// (clocks, budget cursor, pending arrivals).
pub struct AsyncEngine<'a> {
    backend: &'a dyn Backend,
    shapes: Vec<LayerShape>,
    pub(crate) cfg: AsyncCfg,
    pub(crate) sched: SchedCore,
    /// live parameters, one `Arc` per model layer (stages index into it)
    params: LiveParams,
    /// per-layer snapshot history
    stash: StashSet,
    /// per-layer compensators, shared across workers (λ and the EMA
    /// buffers are stage-level statistics — Alg. 1's O(2Σ|w|) memory)
    comps: Vec<Box<dyn Compensator>>,
    lr: f32,
    pub(crate) decay_c: f64,
    total_params: usize,
    update_count: u64,
    /// stash capacity per layer (resolved in `new`; freerun cells reuse it)
    stash_cap: usize,
    /// the caller's explicit stash-capacity override (0 = derive), kept so
    /// plan transitions can re-derive the capacity for the new plan
    stash_override: usize,
    /// per-stage measured service times of the current phase — seeds the
    /// profile refresh when a plan transition re-invokes the planner
    meas: Vec<StageObs>,
    /// freerun: per-stage live state owned jointly with the device threads
    /// (empty in lockstep mode)
    cells: Vec<Arc<StageCell>>,
    /// freerun: device tasks dispatched but not yet completed
    pub(crate) flights: usize,
    /// an imperative `Session::set_budget` made the budget dynamic even
    /// though the configured schedule is static
    forced_dynamic: bool,
    /// session-shared buffer pool + kernel thread count; scheduler-side
    /// buffer copies and lockstep updates draw from (and recycle into) the
    /// same pool the executors use
    ws: Workspace,
    /// freerun only: ship the plain-CE loss head with last-stage forward
    /// tasks so it runs on the device thread (set by the session when the
    /// plugin reports [`crate::ocl::OclPlugin::ce_loss_head`])
    loss_offload: bool,
    /// freerun + threaded only: shared handle to the session's plugin;
    /// when set, stage-0 forwards carry an [`AugmentSpec`] and the plugin's
    /// `augment` hook runs on the owning device thread instead of the
    /// scheduler's admit path
    augment_cell: Option<PluginCell>,
    /// opt-in span recorder (see [`crate::obs`]); survives plan
    /// transitions so one run yields one contiguous timeline. Disabled
    /// (`Recorder::Off`) every call below is a no-op enum match.
    pub(crate) obs: Recorder,
    /// always-on device busy-time accumulator in clock ticks (lockstep:
    /// the replayed analytic service costs — deterministic and
    /// executor-independent; freerun: measured flight service times). The
    /// session folds it into [`crate::metrics::RunMetrics::busy_us`].
    pub(crate) busy_ticks: u64,
}

/// Accumulated measured forward/backward service times of one stage
/// (virtual ticks in lockstep — exactly the replayed analytic costs — and
/// real microseconds in freerun).
#[derive(Debug, Clone, Copy, Default)]
struct StageObs {
    tf_sum: u64,
    tf_n: u64,
    tb_sum: u64,
    tb_n: u64,
}

impl StageObs {
    fn mean_tf(&self) -> Option<f64> {
        (self.tf_n > 0).then(|| self.tf_sum as f64 / self.tf_n as f64)
    }

    fn mean_tb(&self) -> Option<f64> {
        (self.tb_n > 0).then(|| self.tb_sum as f64 / self.tb_n as f64)
    }
}

/// Per-layer stash capacity: an explicit override wins; dynamic-budget
/// runs derive it from the plan's Eq. 4 version count so measured stash
/// bytes track the planned footprint across transitions; static runs keep
/// the historical headroom sizing (metric-compatible with older runs).
fn resolve_stash_cap(
    override_cap: usize,
    pipe: &PipeConfig,
    p: usize,
    n_workers: usize,
    dynamic: bool,
) -> usize {
    if override_cap > 0 {
        override_cap
    } else if dynamic {
        plan_versions(pipe, p).max(2)
    } else {
        n_workers * (p + 2) + 4
    }
}

impl<'a> AsyncEngine<'a> {
    pub fn new(
        backend: &'a dyn Backend,
        model: &ModelSpec,
        cfg: AsyncCfg,
        ep: &EngineParams,
    ) -> Self {
        let shapes = model.layers();
        let prof = Profile::analytic(model, 1); // sizes only here
        let stages: Vec<StageMeta> = (0..cfg.partition.num_stages())
            .map(|j| StageMeta {
                layers: cfg.partition.stage_layers(j),
                tf: 0,
                tb: 0,
                params: cfg.partition.stage_params(&prof, j),
            })
            .collect();
        let params = LiveParams::init(model, ep.seed);
        let n_workers = cfg.pipe.workers.len();
        let p = stages.len();
        let stash_cap =
            resolve_stash_cap(ep.stash_cap, &cfg.pipe, p, n_workers, cfg.budget.is_dynamic());
        let stash = StashSet::new(&params, stash_cap);
        let active_workers: Vec<usize> = cfg
            .pipe
            .workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.active())
            .map(|(i, _)| i)
            .collect();
        let total_params: usize = shapes.iter().map(|s| s.param_count()).sum();
        let comps = shapes.iter().map(|_| make(cfg.comp_kind, cfg.comp_params)).collect();
        AsyncEngine {
            backend,
            shapes,
            cfg,
            sched: SchedCore::new(stages, n_workers, active_workers),
            params,
            stash,
            comps,
            lr: ep.lr,
            decay_c: 0.0, // resolved by the session at build, once td is known
            total_params,
            update_count: 0,
            stash_cap,
            stash_override: ep.stash_cap,
            meas: vec![StageObs::default(); p],
            cells: Vec::new(),
            flights: 0,
            forced_dynamic: false,
            ws: Workspace::serial(),
            loss_offload: false,
            augment_cell: None,
            obs: Recorder::default(),
            busy_ticks: 0,
        }
    }

    /// Install the session-shared workspace (pool + kernel threads). The
    /// session passes the same handle to the executor it builds, so device
    /// threads and the scheduler recycle through one pool.
    pub(crate) fn set_workspace(&mut self, ws: Workspace) {
        self.ws = ws;
    }

    /// Enable shipping the CE loss head with last-stage forward tasks
    /// (freerun only; requires a plain-CE plugin — see
    /// [`crate::ocl::OclPlugin::ce_loss_head`]).
    pub(crate) fn set_loss_offload(&mut self, on: bool) {
        self.loss_offload = on;
    }

    /// Install the shared plugin cell that moves the `augment` hook onto
    /// the stage-0 device thread (freerun + threaded executor only; the
    /// session decides — an inline executor would deadlock on the cell's
    /// non-reentrant lock).
    pub(crate) fn set_augment_cell(&mut self, cell: PluginCell) {
        self.augment_cell = Some(cell);
    }

    /// The budget is dynamic: a time-varying schedule is configured, or an
    /// imperative [`Session::set_budget`] call made it so. Gates ledger
    /// tracing and the plan-derived stash sizing.
    ///
    /// [`Session::set_budget`]: crate::pipeline::session::Session::set_budget
    pub(crate) fn dynamic_budget(&self) -> bool {
        self.forced_dynamic || self.cfg.budget.is_dynamic()
    }

    /// Active (worker, stage) devices — the executor's thread set.
    pub fn devices(&self) -> Vec<(usize, usize)> {
        self.sched.devices()
    }

    pub(crate) fn stage_times(&mut self, part_prof: &Profile) {
        for j in 0..self.sched.stages.len() {
            self.sched.stages[j].tf = self.cfg.partition.stage_tf(part_prof, j);
            self.sched.stages[j].tb = self.cfg.partition.stage_tb(part_prof, j);
            self.sched.stages[j].params = self.cfg.partition.stage_params(part_prof, j);
        }
    }

    /// Assemble a stage task from an already-resolved parameter snapshot
    /// — the single construction point shared by the lockstep
    /// (`self.params`/`self.stash`) and freerun (`StageCell`) sources.
    fn stage_task(
        &self,
        s: usize,
        params: Vec<SharedParams>,
        x: Vec<f32>,
        rows: usize,
        gout: Option<Vec<f32>>,
    ) -> StageTask {
        let layers = self.sched.stages[s].layers.clone();
        StageTask {
            shapes: layers.map(|l| self.shapes[l]).collect(),
            params,
            x,
            rows,
            gout,
            loss: None,
            augment: None,
        }
    }

    /// Pool-backed copy of a slice (steady state: no allocation).
    fn pooled_copy(&self, src: &[f32]) -> Vec<f32> {
        let mut v = self.ws.pool.take(src.len());
        v.copy_from_slice(src);
        v
    }

    /// Build the stage task for a forward on the live parameters.
    fn fwd_task(&self, s: usize, x: Vec<f32>, rows: usize) -> StageTask {
        let layers = self.sched.stages[s].layers.clone();
        let params = layers.map(|l| self.params.layers[l].clone()).collect();
        self.stage_task(s, params, x, rows, None)
    }

    /// Build the stage task for a backward against the stashed version
    /// `ver` (fallback: live = zero staleness).
    fn bwd_task(&self, s: usize, ver: u64, x: Vec<f32>, gout: Vec<f32>, rows: usize) -> StageTask {
        let layers = self.sched.stages[s].layers.clone();
        let params = layers.map(|l| self.stash.resolve(l, ver, &self.params)).collect();
        self.stage_task(s, params, x, rows, Some(gout))
    }

    /// Fold a backward's per-layer gradients into the slot accumulator,
    /// recycling the incoming buffers when they only added into an
    /// existing accumulator. Shared by the lockstep and freerun paths.
    fn accumulate(&mut self, w: usize, s: usize, job: usize, grads: Vec<GradBuf>) {
        let arrival = self.sched.jobs[job].arrival;
        let fwd_ver = self.sched.jobs[job].fwd_version[s];
        let slot = &mut self.sched.slots[w][s];
        match &mut slot.acc {
            None => slot.acc = Some(grads),
            Some(a) => {
                for (ag, g) in a.iter_mut().zip(&grads) {
                    ag.add(g);
                }
                for g in grads {
                    recycle_grad(&self.ws, g);
                }
            }
        }
        let slot = &mut self.sched.slots[w][s];
        slot.acc_count += 1;
        slot.acc_arrivals.push(arrival);
        slot.acc_from_version = slot.acc_from_version.min(fwd_ver);
    }

    /// Retire a finished (or omitted) job and recycle every buffer it
    /// still holds — its stream batch copy, unconsumed stage inputs, and
    /// any parked gradient.
    fn retire_job(&mut self, job: usize) {
        let bx = std::mem::take(&mut self.sched.jobs[job].batch_x);
        self.ws.pool.put(bx);
        for i in 0..self.sched.num_stages() {
            if let Some(x) = self.sched.jobs[job].stage_inputs[i].take() {
                self.ws.pool.put(x);
            }
        }
        if let Some(g) = self.sched.jobs[job].grad.take() {
            self.ws.pool.put(g);
        }
        self.sched.retire(job);
    }

    /// Try to start work on a (worker, stage) device at time `t`.
    fn kick(&mut self, w: usize, s: usize, t: u64, executor: &mut dyn Executor) -> Result<()> {
        loop {
            let sel = match self.sched.select_work(w, s, t) {
                None => return Ok(()),
                Some(sel) => sel,
            };
            match sel {
                WorkSel::Bwd(job) => {
                    let omit = self.cfg.pipe.workers[w].omit[s];
                    if omit > 0 && self.sched.jobs[job].seq % (omit + 1) != 0 {
                        // T3: skip this backward (and the whole upstream
                        // chain); device still free — look for more work
                        self.retire_job(job);
                        continue;
                    }
                    let rows = self.sched.jobs[job].y.len();
                    let ver = self.sched.jobs[job].fwd_version[s];
                    // both buffers are dead after this dispatch: the stage-s
                    // input was already consumed by the stage-s forward, and
                    // grad is overwritten with gx at the Done event
                    let Some(x) = self.sched.jobs[job].stage_inputs[s].take() else {
                        bail!("engine: backward on stage {s} has no stashed stage input");
                    };
                    let Some(gout) = self.sched.jobs[job].grad.take() else {
                        bail!("engine: backward on stage {s} has no upstream gradient");
                    };
                    executor
                        .start((w, s), DeviceTask::Stage(self.bwd_task(s, ver, x, gout, rows)))?;
                    let mut dur = self.sched.stages[s].tb;
                    if self.cfg.pipe.workers[w].recompute {
                        dur += self.sched.stages[s].tf; // T1: extra forward
                    }
                    // lockstep "measures" the replayed analytic cost, so a
                    // re-plan's profile refresh is exact (and deterministic)
                    self.meas[s].tb_sum += self.sched.stages[s].tb;
                    self.meas[s].tb_n += 1;
                    let end = t + dur.max(1);
                    self.busy_ticks += end - t;
                    self.obs.record((w, s), SpanKind::Bwd, self.sched.jobs[job].seq, t, end, ver);
                    self.sched.dispatch(w, s, end, job, true);
                    return Ok(());
                }
                WorkSel::Fwd(job) => {
                    let rows = self.sched.jobs[job].y.len();
                    // the stage keeps its input for the backward recompute,
                    // so the forward gets a pooled copy, not a fresh clone
                    let Some(src) = self.sched.jobs[job].stage_inputs[s].as_ref() else {
                        bail!("engine: forward on stage {s} has no stage input");
                    };
                    let x = self.pooled_copy(src);
                    self.sched.jobs[job].fwd_version[s] = self.sched.version[s];
                    executor.start((w, s), DeviceTask::Stage(self.fwd_task(s, x, rows)))?;
                    let end = t + self.sched.stages[s].tf.max(1);
                    self.meas[s].tf_sum += self.sched.stages[s].tf;
                    self.meas[s].tf_n += 1;
                    self.busy_ticks += end - t;
                    self.obs.record(
                        (w, s),
                        SpanKind::Fwd,
                        self.sched.jobs[job].seq,
                        t,
                        end,
                        self.sched.jobs[job].fwd_version[s],
                    );
                    self.sched.dispatch(w, s, end, job, false);
                    return Ok(());
                }
            }
        }
    }

    /// Apply an accumulated update on (worker, stage) at time `t`.
    pub(crate) fn apply_update(&mut self, w: usize, s: usize, t: u64, io: &mut EngineIo) -> Result<()> {
        let slot = &mut self.sched.slots[w][s];
        let Some(mut grads) = slot.acc.take() else {
            bail!("engine: update on (w{w}, s{s}) with no accumulated gradients");
        };
        let count = slot.acc_count;
        let arrivals = std::mem::take(&mut slot.acc_arrivals);
        let from_ver = slot.acc_from_version;
        slot.acc_count = 0;
        slot.acc_from_version = u64::MAX;

        let scale = 1.0 / count as f32;
        let cur_ver = self.sched.version[s];
        let tau = cur_ver.saturating_sub(from_ver);
        io.metrics.record_staleness(tau);
        let layers: Vec<usize> = self.sched.stages[s].layers.clone().collect();
        for (i, &l) in layers.iter().enumerate() {
            let mut g = std::mem::replace(&mut grads[i], GradBuf { gw: vec![], gb: vec![] });
            g.scale(scale);
            // compensation toward the live version; skip materializing the
            // delta chain (τ clones of the stage params) when the policy
            // does not consume it — the NoComp/StepAware hot path
            let (chain, jump) = if self.comps[l].needs_deltas() && tau > 0 {
                (
                    self.stash.delta_chain(l, from_ver, cur_ver).unwrap_or_default(),
                    self.stash.jump_delta(l, from_ver, cur_ver),
                )
            } else {
                (Vec::new(), None)
            };
            let cctx = CompContext {
                backend: self.backend,
                tau,
                chain: &chain,
                jump: jump.as_ref(),
                lr: self.lr,
            };
            let (mut g, lr_scale) = self.comps[l].compensate(g, &cctx);
            io.plugin.adjust_layer_grad(l, &mut g, &self.params.layers[l], &io.ctx);
            let updated = self.backend.sgd_pooled(&self.params.layers[l], &g, self.lr * lr_scale, &self.ws);
            recycle_grad(&self.ws, g);
            for d in chain {
                recycle_grad(&self.ws, d);
            }
            if let Some(d) = jump {
                recycle_grad(&self.ws, d);
            }
            let old = self.params.replace(l, updated);
            recycle_params(&self.ws, old);
        }
        self.sched.version[s] += 1;
        let new_ver = self.sched.version[s];
        self.obs.gauge_staleness(tau);
        self.obs.record((w, s), SpanKind::Update, count, t, t, new_ver);
        for evicted in self.stash.push_stage(&layers, new_ver, &self.params) {
            recycle_params(&self.ws, evicted);
        }
        let frac = self.sched.stages[s].params as f64 / self.total_params as f64;
        for a in arrivals {
            io.metrics.record_update(t.saturating_sub(a), self.decay_c, frac);
        }
        self.update_count += 1;
        if self.update_count % self.cfg.plugin_cadence == 0 {
            io.plugin.after_update(&self.params.layers, &io.ctx);
        }
        if self.dynamic_budget() {
            io.metrics.ledger.record(t, self.ledger_snapshot());
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Dynamic budgets: the memory ledger and plan transitions
    // -----------------------------------------------------------------

    /// Meter the bytes the engine actually holds right now, by category:
    /// live parameters, stashed weight versions physically distinct from
    /// the live copy, in-flight activations/gradients/labels (plus the
    /// per-slot gradient accumulators), and compensator state.
    ///
    /// Cost: one linear walk over the phase's job table and slots per
    /// call (retired jobs are skipped but still iterated). Metering runs
    /// once per scheduler event in dynamic-budget runs — O(phase length)
    /// per event. If phases ever reach many thousands of batches, switch
    /// to incremental byte counters maintained at admit/retire/accumulate.
    pub(crate) fn ledger_snapshot(&self) -> LedgerSnapshot {
        let f32s = std::mem::size_of::<f32>();
        let params = self.total_params * f32s;
        let (stash, comps) = if self.cells.is_empty() {
            (
                self.stash.bytes_excl_live(&self.params),
                self.comps.iter().map(|c| c.state_bytes()).sum(),
            )
        } else {
            (
                self.cells.iter().map(|c| c.stash_bytes_excl_live()).sum(),
                self.cells.iter().map(|c| c.comp_state_bytes()).sum(),
            )
        };
        let mut acts = 0usize;
        for j in &self.sched.jobs {
            if j.done {
                continue;
            }
            acts += j.batch_x.len() * f32s;
            acts += j.y.len() * std::mem::size_of::<i32>();
            acts += j.stage_inputs.iter().flatten().map(|x| x.len() * f32s).sum::<usize>();
            acts += j.grad.as_ref().map_or(0, |g| g.len() * f32s);
        }
        for row in &self.sched.slots {
            for slot in row {
                if let Some(acc) = &slot.acc {
                    acts += acc.iter().map(|g| (g.gw.len() + g.gb.len()) * f32s).sum::<usize>();
                }
            }
        }
        LedgerSnapshot { params, stash, acts, comps }
    }

    /// (worker, stage) slots holding a partially-filled gradient
    /// accumulator — flushed as final updates under the old plan before a
    /// transition tears its topology down, so the drain loses no training
    /// signal even when `accum > 1` leaves an under-threshold remainder.
    pub(crate) fn pending_accumulators(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for (w, row) in self.sched.slots.iter().enumerate() {
            for (s, slot) in row.iter().enumerate() {
                if slot.acc_count > 0 {
                    v.push((w, s));
                }
            }
        }
        v
    }

    /// This run's profile: the analytic base rescaled so per-stage times
    /// match the phase's measured means (`Profile::rescale_stages`). In
    /// lockstep the measured means equal the replayed analytic costs, so
    /// the refresh is exact; in freerun it folds real device-thread
    /// service times (µs) into the next plan.
    pub(crate) fn refreshed_profile(&self, base: &Profile) -> Profile {
        let (tf, tb) = self.measured_stage_means();
        base.rescale_stages(&self.cfg.partition, &tf, &tb)
    }

    /// Per-stage measured mean forward/backward times (µs) for the
    /// current phase — the `StageObs` seed every re-plan starts from.
    /// `None` where a stage has no samples yet. Read this *before*
    /// [`AsyncEngine::transition`], which resets the observations.
    pub(crate) fn measured_stage_means(&self) -> (Vec<Option<f64>>, Vec<Option<f64>>) {
        let tf = self.meas.iter().map(|o| o.mean_tf()).collect();
        let tb = self.meas.iter().map(|o| o.mean_tb()).collect();
        (tf, tb)
    }

    /// Execute a plan transition after a full drain (no job in flight, no
    /// device task outstanding):
    ///
    ///   1. learned weights survive — per-layer live parameters (and the
    ///      per-layer compensator EMA state) carry over; stage grouping is
    ///      only a view over layers, so merging/splitting stages loses
    ///      nothing;
    ///   2. the scheduling core is rebuilt for the new worker/stage
    ///      topology (fresh version counters, empty queues);
    ///   3. the weight stash restarts at version 0 of the live weights,
    ///      with capacity re-derived from the new plan;
    ///   4. freerun stage cells are rebuilt around the carried-over state;
    ///   5. the executor re-spawns/retires device threads to match.
    pub(crate) fn transition(
        &mut self,
        out: &PlanOutcome,
        prof: &Profile,
        executor: &mut dyn Executor,
    ) {
        let freerun = !self.cells.is_empty();
        let retained_comps: Vec<Box<dyn Compensator>> = if freerun {
            let live = self.free_params();
            for (l, p) in live.into_iter().enumerate() {
                self.params.layers[l] = p;
            }
            self.cells.iter().flat_map(|c| c.take_comps()).collect()
        } else {
            Vec::new()
        };
        self.cfg.partition = out.partition.clone();
        self.cfg.pipe = out.config.clone();
        let p = self.cfg.partition.num_stages();
        let stages: Vec<StageMeta> = (0..p)
            .map(|j| StageMeta {
                layers: self.cfg.partition.stage_layers(j),
                tf: self.cfg.partition.stage_tf(prof, j),
                tb: self.cfg.partition.stage_tb(prof, j),
                params: self.cfg.partition.stage_params(prof, j),
            })
            .collect();
        let n_workers = self.cfg.pipe.workers.len();
        let active: Vec<usize> = self
            .cfg
            .pipe
            .workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.active())
            .map(|(i, _)| i)
            .collect();
        self.sched = SchedCore::new(stages, n_workers, active);
        self.stash_cap =
            resolve_stash_cap(self.stash_override, &self.cfg.pipe, p, n_workers, self.dynamic_budget());
        self.stash = StashSet::new(&self.params, self.stash_cap);
        self.meas = vec![StageObs::default(); p];
        if freerun {
            self.build_cells_from(retained_comps);
        }
        executor.reconfigure(&self.devices());
    }

    /// Admit one stream batch to the lockstep pipeline (or predict-and-
    /// drop when over capacity). `arrival` is the batch's stream stamp;
    /// `now` is when the engine actually gets to it (later than `arrival`
    /// after a drain — the stream does not wait for a re-plan).
    pub(crate) fn admit_lockstep(
        &mut self,
        batch: Batch,
        seq: u64,
        arrival: u64,
        now: u64,
        io: &mut EngineIo,
    ) -> Result<()> {
        if self.sched.over_capacity() {
            // predict with live weights; drop from training
            predict_only(
                self.backend,
                &self.shapes,
                &self.params.layers,
                io.ctx.classes,
                &batch.x,
                &batch.y,
                now,
                io.metrics,
            );
            return Ok(());
        }
        let batch = io.plugin.augment(batch, &self.params.layers, &io.ctx);
        let p = self.sched.num_stages();
        let mut stage_inputs: Vec<Option<Vec<f32>>> = vec![None; p];
        stage_inputs[0] = Some(self.pooled_copy(&batch.x));
        let (_, w) = self
            .sched
            .admit(Job {
                arrival,
                seq,
                batch_id: batch.id,
                y: batch.y,
                batch_x: batch.x,
                augment_pending: false,
                stage_inputs,
                fwd_version: vec![0; p],
                grad: None,
                done: false,
            })
            .context("engine: admit failed despite the over_capacity() guard above")?;
        self.kick(w, 0, now, io.executor)
    }

    /// Handle one lockstep `Done` event at virtual time `t`: join the
    /// device's FIFO result, route activations/gradients onward, run the
    /// loss head at the last stage, and apply updates when an accumulation
    /// window fills. (The session layer owns the event heap and the
    /// budget/breach bookkeeping around this.)
    pub(crate) fn on_done_lockstep(
        &mut self,
        w: usize,
        s: usize,
        job: usize,
        bwd: bool,
        t: u64,
        io: &mut EngineIo,
    ) -> Result<()> {
        let p = self.sched.num_stages();
        let result = io.executor.finish((w, s))?.into_stage()?;
        if !bwd {
            if s + 1 < p {
                self.sched.jobs[job].stage_inputs[s + 1] = Some(result.out);
                self.sched.slots[w][s + 1].fwd_q.push_back(job);
                self.kick(w, s + 1, t, io.executor)?;
            } else {
                // logits ready: prediction + loss head
                let logits = result.out;
                let y = self.sched.jobs[job].y.clone();
                let bx = self.pooled_copy(&self.sched.jobs[job].batch_x);
                io.metrics
                    .record_prediction(t, crate::backend::accuracy(io.ctx.classes, &logits, &y));
                let lat = t.saturating_sub(self.sched.jobs[job].arrival);
                io.metrics.record_latency(lat);
                self.obs.note_latency(lat);
                let (gl, loss) = io.plugin.loss_grad(&logits, &y, &bx, &io.ctx);
                io.metrics.record_loss(t, loss);
                self.ws.pool.put(logits);
                self.ws.pool.put(bx);
                self.sched.jobs[job].grad = Some(gl);
                self.sched.slots[w][s].bwd_q.push_back(job);
            }
        } else {
            // deliver the backward results to the accumulator
            let Some(grads) = result.grads else {
                bail!("engine: backward completion on stage {s} carried no gradients");
            };
            let gx = result.out;
            self.accumulate(w, s, job, grads);
            if self.sched.slots[w][s].acc_count >= self.cfg.pipe.workers[w].accum[s] {
                self.apply_update(w, s, t, io)?;
            }
            if s > 0 {
                self.sched.jobs[job].grad = Some(gx);
                self.sched.slots[w][s - 1].bwd_q.push_back(job);
                self.kick(w, s - 1, t, io.executor)?;
            } else {
                self.ws.pool.put(gx);
                self.retire_job(job);
            }
        }
        self.kick(w, s, t, io.executor)?;
        io.metrics.observe_live_bytes(self.stash.bytes());
        Ok(())
    }

    /// Mark the budget dynamic after an imperative
    /// [`Session::set_budget`](crate::pipeline::session::Session::set_budget):
    /// flips per-update ledger tracing on and makes the next transition
    /// size the stash from the plan's version count, exactly as a
    /// scheduled dynamic budget would. (A flag, not a synthetic schedule
    /// step — the configured `BudgetSchedule` stays untouched.)
    pub(crate) fn force_dynamic_budget(&mut self) {
        self.forced_dynamic = true;
    }

    /// Compensator state bytes, from whichever side owns the compensators
    /// (engine in lockstep, stage cells in freerun).
    pub(crate) fn comp_state_bytes(&self) -> usize {
        if self.cells.is_empty() {
            self.comps.iter().map(|c| c.state_bytes()).sum()
        } else {
            self.cells.iter().map(|c| c.comp_state_bytes()).sum()
        }
    }

    /// Final full-model parameters (live layers in lockstep, assembled
    /// from the stage cells in freerun).
    pub(crate) fn final_params(&self) -> Vec<SharedParams> {
        if self.cells.is_empty() {
            self.params.layers.clone()
        } else {
            self.free_params()
        }
    }

    // -----------------------------------------------------------------
    // Free-running wall-clock mode
    // -----------------------------------------------------------------

    /// Move the per-stage live state (params, stash, compensators) into
    /// `Arc`-shared [`StageCell`]s so updates can run on device threads.
    pub(crate) fn build_cells(&mut self) {
        let comps: Vec<Box<dyn Compensator>> = self
            .shapes
            .iter()
            .map(|_| make(self.cfg.comp_kind, self.cfg.comp_params))
            .collect();
        self.build_cells_from(comps);
    }

    /// (Re)build the freerun stage cells from the engine's live params,
    /// regrouping the given per-layer compensators (one per model layer,
    /// in layer order) by the current partition — plan transitions hand
    /// back the previous cells' compensators so EMA state survives a
    /// stage merge/split.
    fn build_cells_from(&mut self, comps: Vec<Box<dyn Compensator>>) {
        let p = self.sched.num_stages();
        let mut comps = comps.into_iter();
        self.cells = (0..p)
            .map(|s| {
                let layers: Vec<usize> = self.sched.stages[s].layers.clone().collect();
                let params: Vec<SharedParams> =
                    layers.iter().map(|&l| self.params.layers[l].clone()).collect();
                let cell_comps: Vec<Box<dyn Compensator>> = layers
                    .iter()
                    // ferret-lint: allow(entry-panic) — both callers hand over
                    // exactly one compensator per model layer; a shortfall is a
                    // construction bug, not a runtime input
                    .map(|_| comps.next().expect("one compensator per layer"))
                    .collect();
                StageCell::new(layers, params, self.stash_cap, cell_comps)
            })
            .collect();
    }

    /// Full-model live snapshot assembled from the stage cells (stages
    /// cover contiguous layer ranges in order).
    pub(crate) fn free_params(&self) -> Vec<SharedParams> {
        let mut v = Vec::with_capacity(self.shapes.len());
        for cell in &self.cells {
            v.extend(cell.snapshot().0);
        }
        v
    }

    /// Try to start stage work on device (w, s) at wall time `t`.
    fn kick_free(&mut self, w: usize, s: usize, t: u64, io: &mut EngineIo) -> Result<()> {
        loop {
            let sel = match self.sched.select_work(w, s, t) {
                None => return Ok(()),
                Some(sel) => sel,
            };
            match sel {
                WorkSel::Bwd(job) => {
                    let omit = self.cfg.pipe.workers[w].omit[s];
                    if omit > 0 && self.sched.jobs[job].seq % (omit + 1) != 0 {
                        // T3: skip this backward (and the whole upstream
                        // chain); device still free — look for more work
                        self.retire_job(job);
                        continue;
                    }
                    let rows = self.sched.jobs[job].y.len();
                    let ver = self.sched.jobs[job].fwd_version[s];
                    let Some(x) = self.sched.jobs[job].stage_inputs[s].take() else {
                        bail!("engine: backward on stage {s} has no stashed stage input");
                    };
                    let Some(gout) = self.sched.jobs[job].grad.take() else {
                        bail!("engine: backward on stage {s} has no upstream gradient");
                    };
                    let task = self.stage_task(s, self.cells[s].resolve(ver), x, rows, Some(gout));
                    io.executor.start((w, s), DeviceTask::Stage(task))?;
                    self.sched.dispatch_flight(w, s, Flight::Bwd { job }, t);
                    self.flights += 1;
                    return Ok(());
                }
                WorkSel::Fwd(job) => {
                    let rows = self.sched.jobs[job].y.len();
                    // offloaded augment: the stage-0 forward takes the raw
                    // batch rows zero-copy; the device runs the plugin hook
                    // and ships the augmented copies back with its
                    // completion (augment preserves row count)
                    let pending = s == 0 && self.sched.jobs[job].augment_pending;
                    let x = if pending {
                        std::mem::take(&mut self.sched.jobs[job].batch_x)
                    } else {
                        let Some(src) = self.sched.jobs[job].stage_inputs[s].as_ref() else {
                            bail!("engine: forward on stage {s} has no stage input");
                        };
                        self.pooled_copy(src)
                    };
                    let (params, ver) = self.cells[s].snapshot();
                    self.sched.jobs[job].fwd_version[s] = ver;
                    let mut task = self.stage_task(s, params, x, rows, None);
                    if pending {
                        // snapshot at dispatch, not admission: MIR's
                        // interference scoring sees the freshest model
                        let Some(plugin) = self.augment_cell.clone() else {
                            bail!("engine: augment-pending job dispatched without an augment cell");
                        };
                        task.augment = Some(AugmentSpec {
                            plugin,
                            params: self.free_params(),
                            shapes: self.shapes.clone(),
                            labels: self.sched.jobs[job].y.clone(),
                            batch_id: self.sched.jobs[job].batch_id,
                            classes: io.ctx.classes,
                            batch: io.ctx.batch,
                            features: io.ctx.features,
                        });
                    }
                    if self.loss_offload && s + 1 == self.sched.num_stages() {
                        // ship the CE loss head with the last-stage forward:
                        // the device computes dL/dlogits + loss + accuracy.
                        // With a same-task augment (p == 1) the device
                        // substitutes the augmented labels itself.
                        let Some(last) = self.shapes.last() else {
                            bail!("engine: model has no layers");
                        };
                        task.loss = Some(LossSpec {
                            classes: last.out_dim,
                            labels: self.sched.jobs[job].y.clone(),
                        });
                    }
                    io.executor.start((w, s), DeviceTask::Stage(task))?;
                    self.sched.dispatch_flight(w, s, Flight::Fwd { job }, t);
                    self.flights += 1;
                    return Ok(());
                }
            }
        }
    }

    /// Ship an accumulated update to its owning device thread. Plugin
    /// gradient adjustment happens here (the OCL policy stays on the
    /// control thread); compensation + SGD run wherever the executor puts
    /// the task — under the threaded executor, on device (w, s) itself.
    ///
    /// Deliberate ordering difference vs lockstep's `apply_update`: there,
    /// compensation runs first and `adjust_layer_grad` sees the
    /// compensated gradient against the live params; here the plugin
    /// adjusts the raw averaged gradient against the dispatch-time
    /// snapshot, and the device compensates the result toward whatever
    /// version is live at execution. Keeping the plugin at dispatch is
    /// what lets the update itself leave the scheduler thread; the
    /// freerun-vs-lockstep tolerance tests use the plugin-free path where
    /// the orders coincide.
    pub(crate) fn dispatch_update_free(
        &mut self,
        w: usize,
        s: usize,
        t: u64,
        io: &mut EngineIo,
    ) -> Result<()> {
        let slot = &mut self.sched.slots[w][s];
        let Some(mut grads) = slot.acc.take() else {
            bail!("engine: update on (w{w}, s{s}) with no accumulated gradients");
        };
        let count = slot.acc_count;
        let arrivals = std::mem::take(&mut slot.acc_arrivals);
        let from_version = slot.acc_from_version;
        slot.acc_count = 0;
        slot.acc_from_version = u64::MAX;
        let scale = 1.0 / count as f32;
        let layers: Vec<usize> = self.sched.stages[s].layers.clone().collect();
        let (snap, _) = self.cells[s].snapshot();
        for (i, &l) in layers.iter().enumerate() {
            grads[i].scale(scale);
            io.plugin.adjust_layer_grad(l, &mut grads[i], &snap[i], &io.ctx);
        }
        io.executor.start(
            (w, s),
            DeviceTask::Update(UpdateTask {
                cell: self.cells[s].clone(),
                grads,
                from_version,
                lr: self.lr,
            }),
        )?;
        self.sched.dispatch_flight(w, s, Flight::Update { arrivals }, t);
        self.flights += 1;
        Ok(())
    }

    /// Admit one arriving batch at wall time `now` (its scheduled arrival
    /// stamp is `arrival`; admission may run late under load or after a
    /// plan-transition drain). The arrival itself is counted at the
    /// admission site — batches held across a drain are admitted later but
    /// arrive on time.
    pub(crate) fn on_arrive_free(
        &mut self,
        batch: Batch,
        seq: u64,
        arrival: u64,
        now: u64,
        io: &mut EngineIo,
    ) -> Result<()> {
        if self.sched.over_capacity() {
            // predict with live weights; drop from training
            let params = self.free_params();
            predict_only(
                self.backend,
                &self.shapes,
                &params,
                io.ctx.classes,
                &batch.x,
                &batch.y,
                now,
                io.metrics,
            );
            return Ok(());
        }
        let offload = self.augment_cell.is_some();
        let batch = if offload {
            // augment runs on the stage-0 device thread at dispatch; the
            // job carries the raw rows until the completion patches it
            batch
        } else {
            let params = self.free_params();
            io.plugin.augment(batch, &params, &io.ctx)
        };
        let p = self.sched.num_stages();
        let mut stage_inputs: Vec<Option<Vec<f32>>> = vec![None; p];
        if !offload {
            stage_inputs[0] = Some(self.pooled_copy(&batch.x));
        }
        let (_, w) = self
            .sched
            .admit(Job {
                arrival,
                seq,
                batch_id: batch.id,
                y: batch.y,
                batch_x: batch.x,
                augment_pending: offload,
                stage_inputs,
                fwd_version: vec![0; p],
                grad: None,
                done: false,
            })
            .context("engine: admit failed despite the over_capacity() guard above")?;
        self.kick_free(w, 0, now, io)
    }

    /// One device completion at wall time `t`, paired FIFO with its
    /// dispatch via the slot's flight queue.
    pub(crate) fn on_done_free(
        &mut self,
        w: usize,
        s: usize,
        out: crate::pipeline::executor::DeviceOutput,
        t: u64,
        io: &mut EngineIo,
    ) -> Result<()> {
        self.flights -= 1;
        let (flight, dispatched) = self.sched.complete_flight(w, s, t);
        // measured service time of this flight, whatever its kind
        self.busy_ticks += t.saturating_sub(dispatched);
        let p = self.sched.num_stages();
        match flight {
            Flight::Fwd { job } => {
                // measured service time (µs) seeds the next re-plan
                self.meas[s].tf_sum += t.saturating_sub(dispatched);
                self.meas[s].tf_n += 1;
                let result = out.into_stage()?;
                if self.obs.is_on() {
                    // carve the measured augment prefix out of the forward
                    // span (stage-0 offloaded augmentation runs first on
                    // the device thread; `aug_us` is 0 everywhere else)
                    let seq = self.sched.jobs[job].seq;
                    let ver = self.sched.jobs[job].fwd_version[s];
                    let aug_end = dispatched.saturating_add(result.aug_us).min(t);
                    if result.aug_us > 0 {
                        self.obs.record((w, s), SpanKind::Augment, seq, dispatched, aug_end, ver);
                    }
                    self.obs.record((w, s), SpanKind::Fwd, seq, aug_end, t, ver);
                }
                if let Some(aug) = result.augmented {
                    // adopt the device-augmented batch as the job's
                    // identity: rows/labels (replay mixing may have
                    // replaced both) and the stage-0 backward input.
                    // `batch_x` was take()'n empty at dispatch — the
                    // recycle below is a no-op unless a future path
                    // leaves rows behind.
                    let j = &mut self.sched.jobs[job];
                    self.ws.pool.put(std::mem::replace(&mut j.batch_x, aug.x));
                    j.y = aug.y;
                    j.stage_inputs[0] = Some(aug.x_input);
                    j.augment_pending = false;
                }
                if s + 1 < p {
                    self.sched.jobs[job].stage_inputs[s + 1] = Some(result.out);
                    self.sched.slots[w][s + 1].fwd_q.push_back(job);
                    self.kick_free(w, s + 1, t, io)?;
                } else if let Some((gl, loss, acc)) = result.loss {
                    // offloaded loss head: the device already computed
                    // dL/dlogits + loss + accuracy (bitwise what the
                    // scheduler-side CE path would produce)
                    let logits = result.out;
                    io.metrics.record_prediction(t, acc);
                    let lat = t.saturating_sub(self.sched.jobs[job].arrival);
                    io.metrics.record_latency(lat);
                    self.obs.note_latency(lat);
                    io.metrics.record_loss(t, loss);
                    self.ws.pool.put(logits);
                    self.sched.jobs[job].grad = Some(gl);
                    self.sched.slots[w][s].bwd_q.push_back(job);
                } else {
                    // logits ready: prediction + loss head on this thread
                    let logits = result.out;
                    let y = self.sched.jobs[job].y.clone();
                    let bx = self.pooled_copy(&self.sched.jobs[job].batch_x);
                    io.metrics
                        .record_prediction(t, crate::backend::accuracy(io.ctx.classes, &logits, &y));
                    let lat = t.saturating_sub(self.sched.jobs[job].arrival);
                    io.metrics.record_latency(lat);
                    self.obs.note_latency(lat);
                    let (gl, loss) = io.plugin.loss_grad(&logits, &y, &bx, &io.ctx);
                    io.metrics.record_loss(t, loss);
                    self.ws.pool.put(logits);
                    self.ws.pool.put(bx);
                    self.sched.jobs[job].grad = Some(gl);
                    self.sched.slots[w][s].bwd_q.push_back(job);
                }
            }
            Flight::Bwd { job } => {
                self.meas[s].tb_sum += t.saturating_sub(dispatched);
                self.meas[s].tb_n += 1;
                let result = out.into_stage()?;
                self.obs.record(
                    (w, s),
                    SpanKind::Bwd,
                    self.sched.jobs[job].seq,
                    dispatched,
                    t,
                    self.sched.jobs[job].fwd_version[s],
                );
                let Some(grads) = result.grads else {
                    bail!("engine: backward completion on stage {s} carried no gradients");
                };
                let gx = result.out;
                self.accumulate(w, s, job, grads);
                if self.sched.slots[w][s].acc_count >= self.cfg.pipe.workers[w].accum[s] {
                    self.dispatch_update_free(w, s, t, io)?;
                }
                if s > 0 {
                    self.sched.jobs[job].grad = Some(gx);
                    self.sched.slots[w][s - 1].bwd_q.push_back(job);
                    self.kick_free(w, s - 1, t, io)?;
                } else {
                    self.ws.pool.put(gx);
                    self.retire_job(job);
                }
            }
            Flight::Update { arrivals } => {
                let outcome = out.into_update()?;
                io.metrics.record_staleness(outcome.staleness);
                self.obs.gauge_staleness(outcome.staleness);
                self.obs.record(
                    (w, s),
                    SpanKind::Update,
                    arrivals.len() as u64,
                    dispatched,
                    t,
                    outcome.new_version,
                );
                let frac = self.sched.stages[s].params as f64 / self.total_params as f64;
                for a in arrivals {
                    io.metrics.record_update(t.saturating_sub(a), self.decay_c, frac);
                }
                self.update_count += 1;
                if self.update_count % self.cfg.plugin_cadence == 0 {
                    let snap = self.free_params();
                    io.plugin.after_update(&snap, &io.ctx);
                }
                let bytes: usize = self.cells.iter().map(|c| c.stash_bytes()).sum();
                io.metrics.observe_live_bytes(bytes);
                if self.dynamic_budget() {
                    io.metrics.ledger.record(t, self.ledger_snapshot());
                }
            }
        }
        self.kick_free(w, s, t, io)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::ocl::Vanilla;
    use crate::pipeline::executor::ExecutorKind;
    use crate::pipeline::sched::Mode;
    use crate::pipeline::RunResult;
    use crate::stream::{DriftKind, StreamSpec, SyntheticStream};

    fn mk_stream(n: usize, seed: u64) -> SyntheticStream {
        SyntheticStream::new(StreamSpec {
            name: "t".into(),
            features: 16,
            classes: 4,
            batch: 8,
            num_batches: n,
            kind: DriftKind::Stationary,
            margin: 3.0,
            noise: 0.5,
            seed,
        })
    }

    fn model() -> ModelSpec {
        ModelSpec { name: "t".into(), dims: vec![16, 32, 16, 4] }
    }

    fn run_sched(schedule: AsyncSchedule, n: usize) -> RunResult {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        let cfg = AsyncCfg::baseline(schedule, part, &prof, td);
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        run_async(cfg, &mut mk_stream(n, 31), &NativeBackend, &mut Vanilla, &ep, &m)
    }

    #[test]
    fn pipedream_learns_with_low_drop_rate() {
        let r = run_sched(AsyncSchedule::Pipedream, 150);
        assert!(r.metrics.trained > 0);
        assert!(
            r.metrics.oacc.value() > 40.0,
            "oacc {} trained {} dropped {}",
            r.metrics.oacc.value(),
            r.metrics.trained,
            r.metrics.dropped
        );
        // interleaved workers keep up with the stream
        let drop_rate = r.metrics.dropped as f64 / 150.0;
        assert!(drop_rate < 0.2, "drop rate {drop_rate}");
        assert!(r.metrics.tacc > 70.0, "tacc {}", r.metrics.tacc);
    }

    #[test]
    fn async_lands_updates_quickly() {
        let r = run_sched(AsyncSchedule::Pipedream, 150);
        assert!(r.metrics.adaptation_rate() > 0.3, "{}", r.metrics.adaptation_rate());
    }

    #[test]
    fn twobw_uses_less_memory_than_pipedream() {
        let p = run_sched(AsyncSchedule::Pipedream, 80);
        let b = run_sched(AsyncSchedule::Pipedream2BW, 80);
        assert!(b.metrics.mem_bytes < p.metrics.mem_bytes);
        assert!(b.metrics.oacc.value() > 30.0);
    }

    #[test]
    fn ferret_with_planned_config_meets_budget_and_learns() {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let td = prof.default_td();
        let unconstrained = crate::planner::plan(&prof, td, f64::INFINITY, 1e-4);
        let budget = unconstrained.mem_bytes * 0.5;
        let planned = crate::planner::plan(&prof, td, budget, 1e-4);
        assert!(planned.feasible);
        let cfg =
            AsyncCfg::ferret(planned.partition.clone(), planned.config.clone(), CompKind::IterFisher);
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        let r = run_async(cfg, &mut mk_stream(150, 31), &NativeBackend, &mut Vanilla, &ep, &m);
        // engine memory = plan memory + compensator state
        assert!(r.metrics.oacc.value() > 35.0, "oacc {}", r.metrics.oacc.value());
        assert!(r.metrics.trained > 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_sched(AsyncSchedule::Pipedream, 60);
        let b = run_sched(AsyncSchedule::Pipedream, 60);
        assert_eq!(a.metrics.oacc.value(), b.metrics.oacc.value());
        assert_eq!(a.params[0].w, b.params[0].w);
    }

    #[test]
    fn omission_engine_still_learns() {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        let mut cfg = AsyncCfg::baseline(AsyncSchedule::Ferret, part.clone(), &prof, td);
        for w in &mut cfg.pipe.workers {
            w.omit[0] = (part.num_stages() - 1) as u64;
        }
        let ep = EngineParams { lr: 0.2, ..Default::default() };
        let r = run_async(cfg, &mut mk_stream(80, 7), &NativeBackend, &mut Vanilla, &ep, &m);
        assert!(r.metrics.trained > 0);
        assert!(r.metrics.oacc.value() > 20.0, "oacc {}", r.metrics.oacc.value());
    }

    #[test]
    fn compensation_policies_all_run() {
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        for kind in CompKind::all() {
            let base = AsyncCfg::baseline(AsyncSchedule::Ferret, part.clone(), &prof, td);
            let cfg = AsyncCfg { comp_kind: kind, ..base };
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            let r = run_async(cfg, &mut mk_stream(60, 3), &NativeBackend, &mut Vanilla, &ep, &m);
            assert!(r.metrics.trained > 0, "{}", kind.name());
            assert!(
                r.metrics.oacc.value() > 20.0,
                "{}: {}",
                kind.name(),
                r.metrics.oacc.value()
            );
        }
    }

    #[test]
    fn ocl_plugins_run_through_async_engine() {
        use crate::ocl::OclKind;
        let m = model();
        let prof = Profile::analytic(&m, 8);
        let part = Partition::per_layer(m.num_layers());
        let td = prof.default_td();
        for kind in OclKind::all() {
            let cfg = AsyncCfg::baseline(AsyncSchedule::Ferret, part.clone(), &prof, td);
            let mut plugin = kind.build(5);
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            let r = run_async(cfg, &mut mk_stream(50, 9), &NativeBackend, plugin.as_mut(), &ep, &m);
            assert!(r.metrics.trained > 0, "{}", kind.name());
        }
    }

    #[test]
    fn threaded_executor_runs_every_schedule() {
        for schedule in [AsyncSchedule::Pipedream, AsyncSchedule::Pipedream2BW] {
            let m = model();
            let prof = Profile::analytic(&m, 8);
            let part = Partition::per_layer(m.num_layers());
            let td = prof.default_td();
            let cfg = AsyncCfg::baseline(schedule, part, &prof, td);
            let ep = EngineParams { lr: 0.2, ..Default::default() };
            let r = run_async_with(
                cfg,
                &mut mk_stream(60, 31),
                &NativeBackend,
                &mut Vanilla,
                &ep,
                &m,
                ExecutorKind::Threaded,
                Mode::Lockstep,
            );
            assert!(r.metrics.trained > 0, "{}", schedule.name());
            assert!(r.metrics.exec_threads > 1, "{}", schedule.name());
        }
    }

    #[test]
    fn lockstep_records_latency_and_staleness_observability() {
        let r = run_sched(AsyncSchedule::Pipedream, 80);
        // one latency sample per trained-path prediction
        assert_eq!(r.metrics.latencies.len() as u64, 80 - r.metrics.dropped);
        assert!(r.metrics.latency_percentile(50.0) > 0, "pipeline latency is nonzero");
        // every update recorded a staleness bucket
        let hist_total: u64 = r.metrics.staleness_hist.iter().sum();
        assert_eq!(hist_total, r.metrics.trained);
    }
}
