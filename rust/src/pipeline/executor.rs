//! Execution backends for the asynchronous pipeline scheduler.
//!
//! The scheduler ([`crate::pipeline::sched`]) decides *what* runs on which
//! (worker, stage) device and *when*; an [`Executor`] decides *where* the
//! numeric work actually happens:
//!
//!   - [`SimExecutor`]      — runs each device task inline on the scheduler
//!     thread at dispatch time. This is the discrete-event simulation used
//!     by the planner sweeps: cheap, deterministic, single-threaded.
//!   - [`ThreadedExecutor`] — one OS thread per (worker, stage) device,
//!     fed over channels. Device tasks carry `Arc`-shared parameter
//!     snapshots, so device threads compute concurrently.
//!
//! A device task is either a [`StageTask`] (forward / backward math) or an
//! [`UpdateTask`] (SGD + gradient compensation against a [`StageCell`],
//! the stage state owned by its device thread in free-running mode).
//!
//! Two completion paths serve the two scheduling modes
//! ([`crate::pipeline::sched::Mode`]):
//!
//!   - `finish(dev)` — blocking, per-device FIFO. The lockstep engine
//!     joins each task at its virtual `Done` event, so with the same seed
//!     both executors produce identical `RunMetrics`
//!     (tests/executor_equiv.rs pins this). A device normally has one
//!     task in flight, but at an exact-tick boundary (`busy_until == t`)
//!     the scheduler may dispatch the next task while the previous `Done`
//!     is still queued, so executors queue per-device results rather than
//!     hold a single slot.
//!   - `try_finish_any` / `wait_any` — non-blocking (resp. bounded-wait)
//!     drain of *any* device's completion, in real completion order. The
//!     freerun engine reacts to whichever device finishes first.
//!
//! # Buffers and kernel threads
//!
//! Every executor carries a [`Workspace`]: the session-wide shared
//! [`crate::backend::BufferPool`] plus the intra-stage kernel thread
//! count. Stage math runs through the backend's `*_pooled` entry points,
//! consumed inputs and retired gradient buffers go back to the pool, and
//! steady-state microbatches allocate nothing. Determinism is unaffected:
//! the tiled kernels are bit-identical across kernel thread counts (see
//! [`crate::backend::kernels`]), and pooling only recycles allocations —
//! it never changes a value. The legacy constructors
//! ([`SimExecutor::new`], [`ThreadedExecutor::spawn`]) use a private
//! serial workspace.
//!
//! In freerun mode a forward [`StageTask`] may additionally carry a
//! [`LossSpec`]: the last-stage device then computes the CE loss head
//! (dL/dlogits, loss, accuracy) itself, keeping the scheduler thread's
//! admit/drain critical section free of numeric work. The lockstep path
//! never sets it, so lockstep metrics stay byte-identical.
//!
//! Symmetrically, a stage-0 forward may carry an [`AugmentSpec`]: the
//! device locks the session's shared [`PluginCell`], runs the plugin's
//! `augment` hook (replay mixing, interference scoring) on the raw batch
//! rows, and returns the augmented copies ([`AugmentedBatch`]) with its
//! completion for the scheduler to adopt as the job's identity. With both
//! offloads active the freerun scheduler loop does no per-microbatch
//! numeric work at all.
//!
//! # Device-thread affinity
//!
//! [`ThreadedExecutor::spawn_pinned`] optionally pins each device thread
//! to a CPU from the process's allowed set, round-robin in spawn order
//! (`EngineParams.pin_devices` / `--pin-devices`). Pinning is a Linux
//! `sched_setaffinity` call (see [`crate::util::affinity`]), a no-op
//! elsewhere, and never affects numerics — only cache locality.

// ferret-lint: allow(det-map) — device links are keyed by (worker, stage)
// and only ever looked up or drained wholesale, never iterated in an
// order-sensitive way (see `ThreadedExecutor::links`).
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::backend::{Backend, Workspace};
use crate::util::error::{bail, Result};
use crate::compensate::{CompContext, Compensator};
use crate::config::LayerShape;
use crate::model::{GradBuf, SharedParams, VersionStash};
use crate::ocl::{OclCtx, PluginCell};
use crate::stream::Batch;

/// Which executor to run an async engine with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Discrete-event simulation on the scheduler thread (virtual time).
    Sim,
    /// One OS thread per (worker, stage) device; real parallel compute.
    Threaded,
}

impl ExecutorKind {
    pub fn name(&self) -> &'static str {
        match self {
            ExecutorKind::Sim => "sim",
            ExecutorKind::Threaded => "threaded",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sim" => Some(ExecutorKind::Sim),
            "threaded" => Some(ExecutorKind::Threaded),
            _ => None,
        }
    }
}

/// One unit of stage math: a stage forward (`gout == None`) or a stage
/// backward with activation recomputation (`gout == Some`). Parameters are
/// the exact snapshots the scheduler resolved at dispatch (live for
/// forward, stashed-by-version for backward).
pub struct StageTask {
    pub shapes: Vec<LayerShape>,
    pub params: Vec<SharedParams>,
    /// stage input activations, (rows, in_dim) row-major
    pub x: Vec<f32>,
    pub rows: usize,
    /// upstream gradient — present iff this is a backward task
    pub gout: Option<Vec<f32>>,
    /// offloaded CE loss head (freerun, last-stage forwards only): the
    /// device computes dL/dlogits + loss + accuracy from its own forward
    /// output instead of shipping logits back to the scheduler thread
    pub loss: Option<LossSpec>,
    /// offloaded plugin `augment` hook (freerun, stage-0 forwards only):
    /// `x` holds the *raw* batch rows; the device augments them before
    /// running the layer chain and ships the augmented copies back
    pub augment: Option<AugmentSpec>,
}

/// The data a device needs to run the plain-CE loss head in place.
pub struct LossSpec {
    pub classes: usize,
    pub labels: Vec<i32>,
}

/// Everything a stage-0 device needs to run the plugin's `augment` hook
/// in place of the scheduler thread, snapshotted at dispatch. The hook
/// must preserve the batch's row count (every in-tree plugin does:
/// replay mixing replaces rows, it never adds or removes them).
pub struct AugmentSpec {
    /// shared handle to the session's plugin
    pub plugin: PluginCell,
    /// full-model live snapshot at dispatch (interference scoring)
    pub params: Vec<SharedParams>,
    /// full-model layer shapes (the plugin ctx spans the whole model)
    pub shapes: Vec<LayerShape>,
    /// the arriving batch's labels (pre-augment)
    pub labels: Vec<i32>,
    pub batch_id: u64,
    pub classes: usize,
    /// session microbatch row capacity ([`OclCtx::batch`])
    pub batch: usize,
    pub features: usize,
}

/// The augmented batch a device hands back from an offloaded
/// [`AugmentSpec`]: pooled copies the scheduler adopts as the job's batch
/// identity (`x`, `y` — replay mixing may have replaced rows and labels)
/// and as its stage-0 backward input (`x_input`).
pub struct AugmentedBatch {
    pub x: Vec<f32>,
    pub x_input: Vec<f32>,
    pub y: Vec<i32>,
}

/// Result of a [`StageTask`]: forward output activations (or logits), or
/// the input-gradient plus per-layer parameter gradients for a backward.
pub struct StageOutput {
    pub out: Vec<f32>,
    pub grads: Option<Vec<GradBuf>>,
    /// (dL/dlogits, loss, accuracy) — present iff the task carried a
    /// [`LossSpec`] (offloaded freerun loss head)
    pub loss: Option<(Vec<f32>, f32, f64)>,
    /// present iff the task carried an [`AugmentSpec`]
    pub augmented: Option<AugmentedBatch>,
    /// wall microseconds the offloaded augment hook took on the device
    /// thread (0 when no [`AugmentSpec`] rode along — lockstep never sets
    /// one, so replayed timelines stay deterministic). The engine's span
    /// recorder carves this prefix out of the forward span.
    pub aug_us: u64,
}

/// Live state of one pipeline stage, shared between the scheduler thread
/// and the stage's device threads in free-running mode. Holds the stage's
/// live `Arc`-shared versioned parameters, its weight stash, and its
/// per-layer compensators behind one lock, so `apply_update` (SGD +
/// gradient compensation) runs wherever the [`UpdateTask`] is executed —
/// on the owning device thread under [`ThreadedExecutor`]. The scheduler
/// only ever takes brief snapshots for dispatch; whatever version it
/// observes *is* the staleness the update later measures.
pub struct StageCell {
    /// global layer ids this stage owns (immutable)
    pub layers: Vec<usize>,
    inner: Mutex<CellInner>,
}

struct CellInner {
    /// live parameters, one entry per stage layer
    params: Vec<SharedParams>,
    /// per-layer bounded version history (weight stashing / delta chains)
    stash: Vec<VersionStash>,
    /// per-layer compensation policies (stateful: Iter-Fisher EMA)
    comps: Vec<Box<dyn Compensator>>,
    version: u64,
}

impl StageCell {
    /// Seed a cell at version 0 from the engine's initial parameters.
    pub fn new(
        layers: Vec<usize>,
        params: Vec<SharedParams>,
        stash_cap: usize,
        comps: Vec<Box<dyn Compensator>>,
    ) -> Arc<Self> {
        let stash = params
            .iter()
            .map(|p| {
                let mut s = VersionStash::new(stash_cap.max(2));
                s.push(0, p.clone());
                s
            })
            .collect();
        Arc::new(StageCell {
            layers,
            inner: Mutex::new(CellInner { params, stash, comps, version: 0 }),
        })
    }

    /// Live parameter snapshot + its version (forward dispatch).
    pub fn snapshot(&self) -> (Vec<SharedParams>, u64) {
        // ferret-lint: allow(entry-panic) — lock poisoning only re-raises a
        // device-thread panic; no session input reaches this expect
        let inner = self.inner.lock().expect("stage cell");
        (inner.params.clone(), inner.version)
    }

    /// Parameters as of stashed `version`, falling back to the live copy
    /// (zero staleness) after eviction — backward dispatch.
    pub fn resolve(&self, version: u64) -> Vec<SharedParams> {
        // ferret-lint: allow(entry-panic) — poisoning-only, see `snapshot`
        let inner = self.inner.lock().expect("stage cell");
        inner
            .params
            .iter()
            .enumerate()
            .map(|(i, live)| inner.stash[i].get(version).cloned().unwrap_or_else(|| live.clone()))
            .collect()
    }

    pub fn version(&self) -> u64 {
        // ferret-lint: allow(entry-panic) — poisoning-only, see `snapshot`
        self.inner.lock().expect("stage cell").version
    }

    /// Logical stash bytes (measured-memory cross-check vs Eq. 4).
    pub fn stash_bytes(&self) -> usize {
        // ferret-lint: allow(entry-panic) — poisoning-only, see `snapshot`
        let inner = self.inner.lock().expect("stage cell");
        inner.stash.iter().map(|s| s.bytes()).sum()
    }

    /// Extra compensator state bytes (Alg. 1's EMA buffers).
    pub fn comp_state_bytes(&self) -> usize {
        // ferret-lint: allow(entry-panic) — poisoning-only, see `snapshot`
        let inner = self.inner.lock().expect("stage cell");
        inner.comps.iter().map(|c| c.state_bytes()).sum()
    }

    /// Stash bytes excluding entries that alias the live `Arc` — the
    /// memory ledger's physical accounting (`stash_bytes` stays logical,
    /// comparable with Eq. 4).
    pub fn stash_bytes_excl_live(&self) -> usize {
        // ferret-lint: allow(entry-panic) — poisoning-only, see `snapshot`
        let inner = self.inner.lock().expect("stage cell");
        inner
            .stash
            .iter()
            .zip(&inner.params)
            .map(|(s, live)| s.bytes_excl(live))
            .sum()
    }

    /// Move the per-layer compensators out of a retiring cell (plan
    /// transitions: the cell is fully drained, and the EMA state survives
    /// into the stage that owns these layers under the next plan).
    pub fn take_comps(&self) -> Vec<Box<dyn Compensator>> {
        // ferret-lint: allow(entry-panic) — poisoning-only, see `snapshot`
        std::mem::take(&mut self.inner.lock().expect("stage cell").comps)
    }

    /// Apply an averaged gradient that was computed against `from_version`:
    /// compensate toward the *current* live version (whatever it is by the
    /// time this runs — the observed staleness), SGD-step every stage
    /// layer, bump the version, and stash the new snapshot. New parameter
    /// vectors come from `ws`; consumed gradients, delta scratch, and any
    /// snapshot the stash cap evicts go back to it.
    pub fn apply_update(
        &self,
        backend: &dyn Backend,
        mut grads: Vec<GradBuf>,
        from_version: u64,
        lr: f32,
        ws: &Workspace,
    ) -> UpdateOutcome {
        // ferret-lint: allow(entry-panic) — poisoning-only, see `snapshot`
        let mut guard = self.inner.lock().expect("stage cell");
        let inner = &mut *guard;
        let cur = inner.version;
        let tau = cur.saturating_sub(from_version);
        for i in 0..inner.params.len() {
            let g = std::mem::replace(&mut grads[i], GradBuf { gw: vec![], gb: vec![] });
            let (chain, jump) = if inner.comps[i].needs_deltas() && tau > 0 {
                (
                    inner.stash[i].delta_chain(from_version, cur).unwrap_or_default(),
                    inner.stash[i].jump_delta(from_version, cur),
                )
            } else {
                (Vec::new(), None)
            };
            let cctx = CompContext { backend, tau, chain: &chain, jump: jump.as_ref(), lr };
            let (g, lr_scale) = inner.comps[i].compensate(g, &cctx);
            let updated = backend.sgd_pooled(&inner.params[i], &g, lr * lr_scale, ws);
            recycle_grad(ws, g);
            for d in chain {
                recycle_grad(ws, d);
            }
            if let Some(d) = jump {
                recycle_grad(ws, d);
            }
            inner.params[i] = Arc::new(updated);
        }
        inner.version += 1;
        let new_version = inner.version;
        for i in 0..inner.params.len() {
            let p = inner.params[i].clone();
            if let Some(evicted) = inner.stash[i].push(new_version, p) {
                recycle_params(ws, evicted);
            }
        }
        UpdateOutcome { new_version, staleness: tau }
    }
}

/// Hand a consumed gradient's buffers back to the pool.
pub fn recycle_grad(ws: &Workspace, g: GradBuf) {
    ws.pool.put(g.gw);
    ws.pool.put(g.gb);
}

/// Recycle a retired parameter snapshot if nothing else aliases it (the
/// stash/flights may still hold clones — then the `Arc` simply drops).
pub fn recycle_params(ws: &Workspace, p: SharedParams) {
    if let Ok(lp) = Arc::try_unwrap(p) {
        let (w, b) = lp.into_buffers();
        ws.pool.put(w);
        ws.pool.put(b);
    }
}

/// A parameter update shipped to the owning device thread (freerun).
/// `grads` are already averaged over the accumulation window and
/// plugin-adjusted at dispatch; compensation + SGD happen at execution.
pub struct UpdateTask {
    pub cell: Arc<StageCell>,
    pub grads: Vec<GradBuf>,
    /// minimum forward version of the contributing microbatches
    pub from_version: u64,
    pub lr: f32,
}

/// Result of an [`UpdateTask`].
pub struct UpdateOutcome {
    pub new_version: u64,
    /// staleness τ observed at application time (versions the stage
    /// advanced between the contributing forwards and this update)
    pub staleness: u64,
}

/// One unit of device work.
pub enum DeviceTask {
    Stage(StageTask),
    Update(UpdateTask),
}

/// Result of a [`DeviceTask`].
pub enum DeviceOutput {
    Stage(StageOutput),
    Update(UpdateOutcome),
}

impl DeviceOutput {
    pub fn into_stage(self) -> Result<StageOutput> {
        match self {
            DeviceOutput::Stage(s) => Ok(s),
            DeviceOutput::Update(_) => {
                bail!("executor: expected a stage output, got an update outcome")
            }
        }
    }

    pub fn into_update(self) -> Result<UpdateOutcome> {
        match self {
            DeviceOutput::Update(u) => Ok(u),
            DeviceOutput::Stage(_) => {
                bail!("executor: expected an update outcome, got a stage output")
            }
        }
    }
}

/// Execute one stage task through a backend — the single numeric routine
/// shared by every executor (and therefore bit-identical across them).
/// Consumes the task so activation/gradient buffers move instead of copy.
/// Runs against a private serial workspace; the executors use
/// [`run_stage_in`] with the session-shared one.
pub fn run_stage(backend: &dyn Backend, task: StageTask) -> StageOutput {
    run_stage_in(backend, task, &Workspace::serial())
}

/// [`run_stage`] against an explicit workspace: stage math goes through
/// the backend's pooled entry points, and every consumed buffer (the stage
/// input, intermediate activations, the upstream gradient) is recycled, so
/// a steady-state microbatch allocates nothing.
pub fn run_stage_in(backend: &dyn Backend, task: StageTask, ws: &Workspace) -> StageOutput {
    match task.gout {
        None => {
            let mut h = task.x;
            let mut aug_us = 0u64;
            let augmented = task.augment.map(|spec| {
                // ferret-lint: allow(det-time) — freerun-only span metric;
                // lockstep tasks never carry an AugmentSpec, so replayed
                // timelines see aug_us == 0 deterministically
                let aug_t0 = std::time::Instant::now();
                // offloaded augment hook: lock the shared plugin, run it
                // on the raw rows, and keep pooled copies of the result
                // for the scheduler to adopt (batch identity + stage-0
                // backward input). The lock spans only the hook itself.
                let ctx = OclCtx {
                    backend,
                    shapes: &spec.shapes,
                    classes: spec.classes,
                    batch: spec.batch,
                    features: spec.features,
                };
                let raw = Batch { id: spec.batch_id, x: std::mem::take(&mut h), y: spec.labels };
                let batch = spec.plugin.lock().augment(raw, &spec.params, &ctx);
                h = batch.x;
                let mut x = ws.pool.take(h.len());
                x.copy_from_slice(&h);
                let mut x_input = ws.pool.take(h.len());
                x_input.copy_from_slice(&h);
                aug_us = aug_t0.elapsed().as_micros() as u64;
                AugmentedBatch { x, x_input, y: batch.y }
            });
            // forward the stage's layer chain
            for (shape, p) in task.shapes.iter().zip(&task.params) {
                let next = backend.dense_fwd_pooled(shape, p, &h, task.rows, ws);
                ws.pool.put(std::mem::replace(&mut h, next));
            }
            let loss = task.loss.map(|spec| {
                // an offloaded augment may have replaced labels (replay
                // mixing) — the loss head must see the augmented ones
                let labels = augmented.as_ref().map_or(&spec.labels[..], |a| &a.y[..]);
                let (gl, l) = backend.loss_grad_ce(spec.classes, &h, labels);
                let acc = crate::backend::accuracy(spec.classes, &h, labels);
                (gl, l, acc)
            });
            StageOutput { out: h, grads: None, loss, augmented, aug_us }
        }
        Some(gout) => {
            // recompute inner activations from the stage input (T1-style;
            // numerically identical to stashing them)
            let n = task.shapes.len();
            let mut inputs: Vec<Vec<f32>> = Vec::with_capacity(n);
            let mut h = task.x;
            for i in 0..n {
                if i + 1 < n {
                    let next =
                        backend.dense_fwd_pooled(&task.shapes[i], &task.params[i], &h, task.rows, ws);
                    inputs.push(std::mem::replace(&mut h, next));
                } else {
                    inputs.push(std::mem::take(&mut h));
                }
            }
            let mut grads: Vec<Option<GradBuf>> = (0..n).map(|_| None).collect();
            let mut g = gout;
            for i in (0..n).rev() {
                let out = backend.dense_bwd_pooled(
                    &task.shapes[i],
                    &task.params[i],
                    &inputs[i],
                    &g,
                    task.rows,
                    ws,
                );
                ws.pool.put(std::mem::replace(&mut g, out.gx));
                grads[i] = Some(out.grads);
            }
            for x in inputs {
                ws.pool.put(x);
            }
            StageOutput {
                // every slot was filled by the reverse walk above; flatten
                // instead of unwrap keeps the path panic-free
                out: g,
                grads: Some(grads.into_iter().flatten().collect()),
                loss: None,
                augmented: None,
                aug_us: 0,
            }
        }
    }
}

/// Execute any device task — stage math or a stage-cell update — against
/// a private serial workspace (see [`run_device_task_in`]).
pub fn run_device_task(backend: &dyn Backend, task: DeviceTask) -> DeviceOutput {
    run_device_task_in(backend, task, &Workspace::serial())
}

/// Execute any device task against an explicit workspace.
pub fn run_device_task_in(backend: &dyn Backend, task: DeviceTask, ws: &Workspace) -> DeviceOutput {
    match task {
        DeviceTask::Stage(t) => DeviceOutput::Stage(run_stage_in(backend, t, ws)),
        DeviceTask::Update(t) => {
            DeviceOutput::Update(t.cell.apply_update(backend, t.grads, t.from_version, t.lr, ws))
        }
    }
}

/// Where device tasks run. Per device, `finish` returns results in
/// `start` order; `try_finish_any` / `wait_any` drain completions across
/// all devices in completion order.
///
/// `start` / `finish` are fallible: dispatching to an unknown device or
/// joining a device with nothing in flight is an engine-logic error that
/// surfaces as a typed [`crate::util::error::Error`] on the session path
/// instead of a panic.
pub trait Executor {
    fn start(&mut self, dev: (usize, usize), task: DeviceTask) -> Result<()>;
    /// Blocking per-device FIFO join (the lockstep engine's `Done` path).
    fn finish(&mut self, dev: (usize, usize)) -> Result<DeviceOutput>;
    /// Non-blocking: the next completed task from any device, if ready.
    fn try_finish_any(&mut self) -> Option<((usize, usize), DeviceOutput)>;
    /// Block up to `timeout` for any device to complete.
    fn wait_any(&mut self, timeout: Duration) -> Option<((usize, usize), DeviceOutput)>;
    /// Number of compute threads backing this executor (1 = inline).
    fn threads(&self) -> usize;
    /// Adjust the device set mid-run (plan transitions): spawn backing
    /// threads for new devices, retire threads for removed ones. The
    /// caller must be fully drained — no task in flight — when
    /// reconfiguring. Inline executors need no adjustment.
    fn reconfigure(&mut self, _devices: &[(usize, usize)]) {}
}

/// Inline executor: computes at dispatch on the calling thread and parks
/// the result until the scheduler collects it — exactly the historical
/// single-threaded simulation behavior.
pub struct SimExecutor<'a> {
    backend: &'a dyn Backend,
    ws: Workspace,
    /// parked results in completion (== dispatch) order; per-device FIFO
    /// is a consequence, so exact-tick double dispatch pairs correctly
    pending: VecDeque<((usize, usize), DeviceOutput)>,
}

impl<'a> SimExecutor<'a> {
    /// Inline executor with a private serial workspace (planner sweeps).
    pub fn new(backend: &'a dyn Backend) -> Self {
        Self::with_workspace(backend, Workspace::serial())
    }

    /// Inline executor sharing the session workspace (pool + threads).
    pub fn with_workspace(backend: &'a dyn Backend, ws: Workspace) -> Self {
        SimExecutor { backend, ws, pending: VecDeque::new() }
    }
}

impl Executor for SimExecutor<'_> {
    fn start(&mut self, dev: (usize, usize), task: DeviceTask) -> Result<()> {
        let out = run_device_task_in(self.backend, task, &self.ws);
        self.pending.push_back((dev, out));
        Ok(())
    }

    fn finish(&mut self, dev: (usize, usize)) -> Result<DeviceOutput> {
        if let Some(i) = self.pending.iter().position(|(d, _)| *d == dev) {
            if let Some((_, out)) = self.pending.remove(i) {
                return Ok(out);
            }
        }
        bail!("executor: no in-flight task on device (w{}, s{})", dev.0, dev.1)
    }

    fn try_finish_any(&mut self) -> Option<((usize, usize), DeviceOutput)> {
        self.pending.pop_front()
    }

    fn wait_any(&mut self, _timeout: Duration) -> Option<((usize, usize), DeviceOutput)> {
        // inline execution: everything started has already completed
        self.pending.pop_front()
    }

    fn threads(&self) -> usize {
        1
    }
}

/// One OS thread per (worker, stage) device, exchanging tasks and results
/// over channels. All devices report into one shared completion channel
/// (per-device order is preserved — each device is a single producer), so
/// the scheduler can block on "whichever device finishes first".
///
/// The executor *owns* its device threads: each thread captures an
/// [`Backend::share`] handle (an `Arc`, so caches are shared with the
/// caller's backend) and a [`JoinHandle`] is retained per device. That is
/// what lets a long-lived [`crate::pipeline::session::Session`] keep
/// devices running across an unbounded number of calls — a
/// `std::thread::scope` cannot outlive the function that opened it. Plan
/// transitions spawn/retire devices mid-run (`reconfigure`); dropping the
/// executor closes every task channel and joins every device thread, so
/// no thread outlives the session that owns it.
pub struct ThreadedExecutor {
    backend: Arc<dyn Backend>,
    ws: Workspace,
    // ferret-lint: allow(det-map) — keyed (worker, stage) lookups only, never iterated
    links: HashMap<(usize, usize), DeviceLink>,
    done_tx: Sender<((usize, usize), DeviceOutput)>,
    done_rx: Receiver<((usize, usize), DeviceOutput)>,
    /// completions drained while waiting for a specific device in `finish`
    parked: VecDeque<((usize, usize), DeviceOutput)>,
    /// pin each device thread to a CPU from the allowed set (Linux only)
    pin: bool,
    /// device threads spawned so far — the round-robin pin slot counter
    /// (deterministic: devices spawn in `devices()` order, and respawns
    /// during `reconfigure` keep counting)
    spawned: usize,
}

/// One device thread: its task channel plus the handle joined at retire
/// or executor drop.
struct DeviceLink {
    task_tx: Sender<DeviceTask>,
    thread: JoinHandle<()>,
}

impl ThreadedExecutor {
    /// Spawn device threads with a private serial workspace.
    pub fn spawn(backend: Arc<dyn Backend>, devices: &[(usize, usize)]) -> Self {
        Self::spawn_with(backend, devices, Workspace::serial())
    }

    /// Spawn device threads sharing the session workspace: every device
    /// clones the same pool handle, so buffers recycle across threads.
    pub fn spawn_with(
        backend: Arc<dyn Backend>,
        devices: &[(usize, usize)],
        ws: Workspace,
    ) -> Self {
        Self::spawn_pinned(backend, devices, ws, false)
    }

    /// [`ThreadedExecutor::spawn_with`] with optional CPU affinity: when
    /// `pin` is set, each device thread pins itself to one CPU from the
    /// process's allowed set, round-robin in spawn order. Linux only
    /// (no-op elsewhere); numerics are unaffected either way.
    pub fn spawn_pinned(
        backend: Arc<dyn Backend>,
        devices: &[(usize, usize)],
        ws: Workspace,
        pin: bool,
    ) -> Self {
        let (done_tx, done_rx) = channel::<((usize, usize), DeviceOutput)>();
        let mut ex = ThreadedExecutor {
            backend,
            ws,
            // ferret-lint: allow(det-map) — keyed (worker, stage) lookups only, never iterated
            links: HashMap::new(),
            done_tx,
            done_rx,
            parked: VecDeque::new(),
            pin,
            spawned: 0,
        };
        for &dev in devices {
            ex.spawn_device(dev);
        }
        ex
    }

    fn spawn_device(&mut self, dev: (usize, usize)) {
        let (task_tx, task_rx) = channel::<DeviceTask>();
        let out_tx = self.done_tx.clone();
        let backend = Arc::clone(&self.backend);
        let ws = self.ws.clone();
        let pin_slot = self.pin.then_some(self.spawned);
        self.spawned += 1;
        let thread = std::thread::spawn(move || {
            if let Some(slot) = pin_slot {
                // best-effort: a restricted cpuset or non-Linux host just
                // leaves the thread unpinned
                crate::util::affinity::pin_current_thread(slot);
            }
            while let Ok(task) = task_rx.recv() {
                let out = run_device_task_in(backend.as_ref(), task, &ws);
                if out_tx.send((dev, out)).is_err() {
                    break;
                }
            }
        });
        self.links.insert(dev, DeviceLink { task_tx, thread });
    }

    /// Close one device's task channel and join its thread (it finishes
    /// any task already received, then its recv loop ends). A device-task
    /// panic is re-raised here — the behavior the old `thread::scope` join
    /// gave — unless this thread is already unwinding, where a second
    /// panic would abort the process; then it is reported and swallowed.
    fn retire_device(link: DeviceLink) {
        drop(link.task_tx);
        if let Err(payload) = link.thread.join() {
            if std::thread::panicking() {
                eprintln!("ferret: device thread panicked during teardown (already unwinding)");
            } else {
                std::panic::resume_unwind(payload);
            }
        }
    }
}

impl Drop for ThreadedExecutor {
    fn drop(&mut self) {
        // explicit join on drop: the session (or legacy shim) that owns
        // this executor cannot leak device threads past its own lifetime
        for (_, link) in std::mem::take(&mut self.links) {
            Self::retire_device(link);
        }
    }
}

impl Executor for ThreadedExecutor {
    fn start(&mut self, dev: (usize, usize), task: DeviceTask) -> Result<()> {
        let link = match self.links.get(&dev) {
            Some(l) => l,
            None => bail!("executor: no device thread for (w{}, s{})", dev.0, dev.1),
        };
        if link.task_tx.send(task).is_err() {
            bail!(
                "executor: device thread (w{}, s{}) exited before accepting a task",
                dev.0,
                dev.1
            );
        }
        Ok(())
    }

    fn finish(&mut self, dev: (usize, usize)) -> Result<DeviceOutput> {
        if let Some(i) = self.parked.iter().position(|(d, _)| *d == dev) {
            if let Some((_, out)) = self.parked.remove(i) {
                return Ok(out);
            }
        }
        loop {
            match self.done_rx.recv() {
                Ok((d, out)) if d == dev => return Ok(out),
                Ok(parked) => self.parked.push_back(parked),
                Err(_) => bail!(
                    "executor: completion channel closed while waiting on (w{}, s{})",
                    dev.0,
                    dev.1
                ),
            }
        }
    }

    fn try_finish_any(&mut self) -> Option<((usize, usize), DeviceOutput)> {
        if let Some(x) = self.parked.pop_front() {
            return Some(x);
        }
        self.done_rx.try_recv().ok()
    }

    fn wait_any(&mut self, timeout: Duration) -> Option<((usize, usize), DeviceOutput)> {
        if let Some(x) = self.parked.pop_front() {
            return Some(x);
        }
        self.done_rx.recv_timeout(timeout).ok()
    }

    fn threads(&self) -> usize {
        self.links.len()
    }

    fn reconfigure(&mut self, devices: &[(usize, usize)]) {
        // retire devices not in the new set: dropping the sender ends the
        // device thread's recv loop (it is idle — the caller drained), and
        // the join keeps thread count == device count at all times
        let retired: Vec<(usize, usize)> =
            self.links.keys().copied().filter(|d| !devices.contains(d)).collect();
        for dev in retired {
            if let Some(link) = self.links.remove(&dev) {
                Self::retire_device(link);
            }
        }
        for &dev in devices {
            if !self.links.contains_key(&dev) {
                self.spawn_device(dev);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::compensate::{make, CompKind, CompParams};
    use crate::config::Act;
    use crate::model::LayerParams;
    use std::sync::Arc;

    fn task(bwd: bool) -> StageTask {
        let shapes = vec![
            LayerShape { in_dim: 2, out_dim: 3, act: Act::Relu },
            LayerShape { in_dim: 3, out_dim: 2, act: Act::None },
        ];
        let params = vec![
            Arc::new(LayerParams { w: vec![0.5; 6], b: vec![0.1; 3] }),
            Arc::new(LayerParams { w: vec![-0.25; 6], b: vec![0.0; 2] }),
        ];
        StageTask {
            shapes,
            params,
            x: vec![1.0, -2.0, 0.5, 0.25],
            rows: 2,
            gout: bwd.then(|| vec![0.3, -0.1, 0.2, 0.4]),
            loss: None,
            augment: None,
        }
    }

    fn stage(bwd: bool) -> DeviceTask {
        DeviceTask::Stage(task(bwd))
    }

    #[test]
    fn sim_and_threaded_produce_identical_stage_results() {
        let be = NativeBackend;
        for bwd in [false, true] {
            let mut sim = SimExecutor::new(&be);
            sim.start((0, 0), stage(bwd)).unwrap();
            let a = sim.finish((0, 0)).unwrap().into_stage().unwrap();
            let mut th = ThreadedExecutor::spawn(be.share(), &[(0, 0)]);
            th.start((0, 0), stage(bwd)).unwrap();
            let b = th.finish((0, 0)).unwrap().into_stage().unwrap();
            drop(th); // owned threads join here, not at a scope's end
            assert_eq!(a.out, b.out, "bwd={bwd}");
            match (a.grads, b.grads) {
                (None, None) => assert!(!bwd),
                (Some(ga), Some(gb)) => {
                    assert!(bwd);
                    assert_eq!(ga.len(), gb.len());
                    for (x, y) in ga.iter().zip(&gb) {
                        assert_eq!(x.gw, y.gw);
                        assert_eq!(x.gb, y.gb);
                    }
                }
                _ => panic!("executor grads disagree"),
            }
        }
    }

    /// At an exact-tick boundary the scheduler can dispatch a device's
    /// next task while the previous Done is still queued — results must
    /// pair FIFO on both executors.
    #[test]
    fn double_dispatch_on_one_device_pairs_fifo() {
        let be = NativeBackend;
        let fwd = run_stage(&be, task(false));
        let bwd = run_stage(&be, task(true));
        let mut sim = SimExecutor::new(&be);
        sim.start((0, 0), stage(true)).unwrap(); // earlier bwd, Done still queued
        sim.start((0, 0), stage(false)).unwrap(); // next fwd dispatched at same tick
        let first = sim.finish((0, 0)).unwrap().into_stage().unwrap();
        let second = sim.finish((0, 0)).unwrap().into_stage().unwrap();
        assert_eq!(first.out, bwd.out, "first finish gets the earlier task");
        assert!(first.grads.is_some());
        assert_eq!(second.out, fwd.out);
        assert!(second.grads.is_none());
        let mut th = ThreadedExecutor::spawn(be.share(), &[(0, 0)]);
        th.start((0, 0), stage(true)).unwrap();
        th.start((0, 0), stage(false)).unwrap();
        let (tf, ts) = (
            th.finish((0, 0)).unwrap().into_stage().unwrap(),
            th.finish((0, 0)).unwrap().into_stage().unwrap(),
        );
        assert_eq!(tf.out, bwd.out);
        assert_eq!(ts.out, fwd.out);
    }

    #[test]
    fn threaded_executor_overlaps_devices() {
        let be = NativeBackend;
        let devices = [(0, 0), (0, 1), (1, 0), (1, 1)];
        let mut th = ThreadedExecutor::spawn(be.share(), &devices);
        assert_eq!(th.threads(), 4);
        // all four devices in flight simultaneously before any join
        for &d in &devices {
            th.start(d, stage(false)).unwrap();
        }
        let outs = devices.map(|d| th.finish(d).unwrap().into_stage().unwrap());
        let reference = run_stage(&be, task(false));
        for o in outs {
            assert_eq!(o.out, reference.out);
        }
    }

    /// The completion-drain path returns whatever finished, across
    /// devices, and reports nothing when the executor is idle.
    #[test]
    fn drain_any_returns_completions_then_empties() {
        let be = NativeBackend;
        let mut th = ThreadedExecutor::spawn(be.share(), &[(0, 0), (0, 1)]);
        assert!(th.try_finish_any().is_none(), "idle executor");
        th.start((0, 0), stage(false)).unwrap();
        th.start((0, 1), stage(false)).unwrap();
        let mut seen = Vec::new();
        while seen.len() < 2 {
            if let Some((dev, out)) = th.wait_any(Duration::from_secs(5)) {
                assert!(out.into_stage().unwrap().grads.is_none());
                seen.push(dev);
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![(0, 0), (0, 1)]);
        assert!(th.wait_any(Duration::from_millis(10)).is_none(), "drained");
        drop(th);
        // the sim executor drains in dispatch order
        let mut sim = SimExecutor::new(&be);
        assert!(sim.try_finish_any().is_none());
        sim.start((0, 1), stage(false)).unwrap();
        sim.start((0, 0), stage(true)).unwrap();
        assert_eq!(sim.try_finish_any().expect("first").0, (0, 1));
        assert_eq!(sim.wait_any(Duration::ZERO).expect("second").0, (0, 0));
        assert!(sim.try_finish_any().is_none());
    }

    /// Plan transitions resize the device set mid-run: new devices spawn,
    /// retired ones exit, surviving ones keep working.
    #[test]
    fn reconfigure_respawns_and_retires_devices() {
        let be = NativeBackend;
        let mut th = ThreadedExecutor::spawn(be.share(), &[(0, 0), (0, 1)]);
        th.start((0, 0), stage(false)).unwrap();
        th.finish((0, 0)).unwrap();
        // drained: retire (0,1) — its thread joins inside reconfigure —
        // keep (0,0), add (1,0)
        th.reconfigure(&[(0, 0), (1, 0)]);
        assert_eq!(th.threads(), 2);
        th.start((0, 0), stage(false)).unwrap();
        th.start((1, 0), stage(true)).unwrap();
        assert!(th.finish((0, 0)).unwrap().into_stage().unwrap().grads.is_none());
        assert!(th.finish((1, 0)).unwrap().into_stage().unwrap().grads.is_some());
        drop(th);
        // inline executor: reconfigure is a no-op
        let mut sim = SimExecutor::new(&be);
        sim.reconfigure(&[(9, 9)]);
        sim.start((9, 9), stage(false)).unwrap();
        assert!(sim.finish((9, 9)).unwrap().into_stage().unwrap().grads.is_none());
    }

    /// Update tasks mutate the stage cell wherever they run; the observed
    /// staleness is whatever the cell version advanced to in between.
    #[test]
    fn update_task_applies_sgd_on_the_device_thread() {
        let be = NativeBackend;
        let p0 = Arc::new(LayerParams { w: vec![1.0, 2.0], b: vec![0.5] });
        let cell = StageCell::new(
            vec![0],
            vec![p0],
            4,
            vec![make(CompKind::NoComp, CompParams::default())],
        );
        let g = GradBuf { gw: vec![1.0, -1.0], gb: vec![2.0] };
        let mut th = ThreadedExecutor::spawn(be.share(), &[(0, 0)]);
        th.start(
            (0, 0),
            DeviceTask::Update(UpdateTask {
                cell: cell.clone(),
                grads: vec![g],
                from_version: 0,
                lr: 0.5,
            }),
        )
        .unwrap();
        let outcome = th.finish((0, 0)).unwrap().into_update().unwrap();
        drop(th);
        assert_eq!(outcome.new_version, 1);
        assert_eq!(outcome.staleness, 0);
        assert_eq!(cell.version(), 1);
        let (params, ver) = cell.snapshot();
        assert_eq!(ver, 1);
        assert_eq!(params[0].w, vec![0.5, 2.5]);
        assert_eq!(params[0].b, vec![-0.5]);
        // version 0 still resolvable from the stash (weight stashing)
        assert_eq!(cell.resolve(0)[0].w, vec![1.0, 2.0]);
        // a second update computed against version 0 observes staleness 1
        let g2 = GradBuf { gw: vec![0.0, 0.0], gb: vec![0.0] };
        let o2 = cell.apply_update(&be, vec![g2], 0, 0.5, &Workspace::serial());
        assert_eq!(o2.staleness, 1);
        assert_eq!(o2.new_version, 2);
    }

    /// The pooled stage path must be bit-identical to the legacy serial
    /// one, and a steady stream of identical tasks must stop allocating
    /// once the pool is warm.
    #[test]
    fn pooled_stage_path_is_bitwise_identical_and_stops_allocating() {
        let be = NativeBackend;
        let ws = Workspace::serial();
        for bwd in [false, true] {
            let a = run_stage(&be, task(bwd));
            let b = run_stage_in(&be, task(bwd), &ws);
            assert_eq!(a.out, b.out, "bwd={bwd}");
            // recycle the outputs so later iterations reuse dirty buffers
            ws.pool.put(b.out);
            if let Some(gs) = b.grads {
                for g in gs {
                    recycle_grad(&ws, g);
                }
            }
        }
        // warm pool: repeated identical tasks must hit the pool every time
        for bwd in [false, true] {
            let before = ws.pool.stats();
            let out = run_stage_in(&be, task(bwd), &ws);
            let delta = ws.pool.stats().since(&before);
            assert_eq!(delta.misses, 0, "steady state allocated (bwd={bwd})");
            ws.pool.put(out.out);
            if let Some(gs) = out.grads {
                for g in gs {
                    recycle_grad(&ws, g);
                }
            }
        }
    }

    /// Probe plugin for the augment-offload tests: records which thread
    /// ran the hook, shifts every input value by +1, and reverses the
    /// labels (so label adoption is observable too).
    struct AugmentProbe {
        threads: Arc<Mutex<Vec<std::thread::ThreadId>>>,
    }

    impl crate::ocl::OclPlugin for AugmentProbe {
        fn name(&self) -> &'static str {
            "augment-probe"
        }

        fn augment(
            &mut self,
            mut batch: Batch,
            _params: &[SharedParams],
            _ctx: &OclCtx,
        ) -> Batch {
            self.threads.lock().expect("probe log").push(std::thread::current().id());
            for v in &mut batch.x {
                *v += 1.0;
            }
            batch.y.reverse();
            batch
        }
    }

    fn augment_spec(cell: PluginCell, labels: Vec<i32>) -> AugmentSpec {
        AugmentSpec {
            plugin: cell,
            params: Vec::new(),
            shapes: vec![
                LayerShape { in_dim: 2, out_dim: 3, act: Act::Relu },
                LayerShape { in_dim: 3, out_dim: 2, act: Act::None },
            ],
            labels,
            batch_id: 7,
            classes: 2,
            batch: 2,
            features: 2,
        }
    }

    /// An offloaded augment hook runs on the *device* thread, the forward
    /// consumes the augmented rows, and the completion carries pooled
    /// copies of the augmented batch (x, x_input, reversed labels).
    #[test]
    fn offloaded_augment_runs_on_the_device_thread() {
        let be = NativeBackend;
        let log = Arc::new(Mutex::new(Vec::new()));
        let cell = PluginCell::new(Box::new(AugmentProbe { threads: log.clone() }));
        // reference: forward of (x + 1) through the same params
        let mut shifted = task(false);
        for v in &mut shifted.x {
            *v += 1.0;
        }
        let reference = run_stage(&be, shifted);
        let mut t = task(false);
        let raw_x = t.x.clone();
        t.augment = Some(augment_spec(cell.clone(), vec![0, 1]));
        t.loss = Some(LossSpec { classes: 2, labels: vec![0, 1] });
        let mut th = ThreadedExecutor::spawn(be.share(), &[(0, 0)]);
        th.start((0, 0), DeviceTask::Stage(t)).unwrap();
        let out = th.finish((0, 0)).unwrap().into_stage().unwrap();
        drop(th);
        let ran_on = log.lock().expect("probe log").clone();
        assert_eq!(ran_on.len(), 1, "augment ran exactly once");
        assert_ne!(ran_on[0], std::thread::current().id(), "augment left the scheduler thread");
        assert_eq!(out.out, reference.out, "forward consumed the augmented rows");
        let aug = out.augmented.expect("augmented batch returned");
        let want: Vec<f32> = raw_x.iter().map(|v| v + 1.0).collect();
        assert_eq!(aug.x, want);
        assert_eq!(aug.x_input, want);
        assert_eq!(aug.y, vec![1, 0], "labels reversed by the hook");
        // the offloaded loss head saw the *augmented* labels
        let (g_ref, l_ref) = be.loss_grad_ce(2, &reference.out, &[1, 0]);
        let acc_ref = crate::backend::accuracy(2, &reference.out, &[1, 0]);
        let (gl, l, acc) = out.loss.expect("loss head ran");
        assert_eq!(gl, g_ref);
        assert_eq!(l, l_ref);
        assert_eq!(acc, acc_ref);
    }

    /// Pinned executors produce identical results — affinity is a pure
    /// placement hint and must never leak into numerics.
    #[test]
    fn pinned_executor_matches_unpinned_results() {
        let be = NativeBackend;
        let reference = run_stage(&be, task(true));
        let mut th = ThreadedExecutor::spawn_pinned(
            be.share(),
            &[(0, 0), (0, 1)],
            Workspace::serial(),
            true,
        );
        th.start((0, 0), stage(true)).unwrap();
        th.start((0, 1), stage(true)).unwrap();
        for dev in [(0, 0), (0, 1)] {
            let out = th.finish(dev).unwrap().into_stage().unwrap();
            assert_eq!(out.out, reference.out);
        }
        // reconfigure keeps counting pin slots without panicking
        th.reconfigure(&[(0, 0), (2, 0)]);
        th.start((2, 0), stage(false)).unwrap();
        assert!(th.finish((2, 0)).unwrap().into_stage().unwrap().grads.is_none());
    }

    /// An offloaded CE loss head must reproduce exactly what the
    /// scheduler-side path (forward, then loss_grad_ce + accuracy on the
    /// logits) would have computed.
    #[test]
    fn offloaded_loss_head_matches_scheduler_side_computation() {
        let be = NativeBackend;
        let labels = vec![0, 1];
        let plain = run_stage(&be, task(false));
        let (g_ref, l_ref) = be.loss_grad_ce(2, &plain.out, &labels);
        let acc_ref = crate::backend::accuracy(2, &plain.out, &labels);
        let mut t = task(false);
        t.loss = Some(LossSpec { classes: 2, labels });
        let out = run_stage(&be, t);
        assert_eq!(out.out, plain.out, "logits unchanged by the loss head");
        let (gl, l, acc) = out.loss.expect("loss head ran");
        assert_eq!(gl, g_ref);
        assert_eq!(l, l_ref);
        assert_eq!(acc, acc_ref);
    }
}
