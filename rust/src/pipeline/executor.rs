//! Execution backends for the asynchronous pipeline scheduler.
//!
//! The scheduler ([`crate::pipeline::sched`]) decides *what* runs on which
//! (worker, stage) device and *when* in virtual time; an [`Executor`]
//! decides *where* the numeric work actually happens:
//!
//!   - [`SimExecutor`]      — runs each stage task inline on the scheduler
//!     thread at dispatch time. This is the discrete-event simulation used
//!     by the planner sweeps: cheap, deterministic, single-threaded.
//!   - [`ThreadedExecutor`] — one OS thread per (worker, stage) device,
//!     fed over channels. Stage tasks carry `Arc`-shared parameter
//!     snapshots, so device threads compute concurrently while the
//!     scheduler keeps ordering updates in virtual time ("lockstep").
//!
//! Both executors run the *same* schedule and the same math on the same
//! inputs, so a run's `RunMetrics` are identical between them — the
//! equivalence test in `tests/executor_equiv.rs` pins this. The contract:
//! per device, tasks complete FIFO — `start` dispatches, `finish` joins at
//! that task's `Done` event. A device normally has one task in flight, but
//! at an exact-tick boundary (`busy_until == t`) the scheduler may dispatch
//! the next task while the previous `Done` is still queued, so executors
//! must queue per-device results rather than hold a single slot.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::Scope;

use crate::backend::Backend;
use crate::config::LayerShape;
use crate::model::{GradBuf, SharedParams};

/// Which executor to run an async engine with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Discrete-event simulation on the scheduler thread (virtual time).
    Sim,
    /// One OS thread per (worker, stage) device; real parallel compute.
    Threaded,
}

impl ExecutorKind {
    pub fn name(&self) -> &'static str {
        match self {
            ExecutorKind::Sim => "sim",
            ExecutorKind::Threaded => "threaded",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sim" => Some(ExecutorKind::Sim),
            "threaded" => Some(ExecutorKind::Threaded),
            _ => None,
        }
    }
}

/// One unit of device work: a stage forward (`gout == None`) or a stage
/// backward with activation recomputation (`gout == Some`). Parameters are
/// the exact snapshots the scheduler resolved at dispatch (live for
/// forward, stashed-by-version for backward).
pub struct StageTask {
    pub shapes: Vec<LayerShape>,
    pub params: Vec<SharedParams>,
    /// stage input activations, (rows, in_dim) row-major
    pub x: Vec<f32>,
    pub rows: usize,
    /// upstream gradient — present iff this is a backward task
    pub gout: Option<Vec<f32>>,
}

/// Result of a [`StageTask`]: forward output activations (or logits), or
/// the input-gradient plus per-layer parameter gradients for a backward.
pub struct StageOutput {
    pub out: Vec<f32>,
    pub grads: Option<Vec<GradBuf>>,
}

/// Execute one stage task through a backend — the single numeric routine
/// shared by every executor (and therefore bit-identical across them).
/// Consumes the task so activation/gradient buffers move instead of copy.
pub fn run_stage(backend: &dyn Backend, task: StageTask) -> StageOutput {
    match task.gout {
        None => {
            // forward the stage's layer chain
            let mut h = task.x;
            for (shape, p) in task.shapes.iter().zip(&task.params) {
                h = backend.dense_fwd(shape, p, &h, task.rows);
            }
            StageOutput { out: h, grads: None }
        }
        Some(gout) => {
            // recompute inner activations from the stage input (T1-style;
            // numerically identical to stashing them)
            let n = task.shapes.len();
            let mut inputs: Vec<Vec<f32>> = Vec::with_capacity(n);
            let mut h = task.x;
            for i in 0..n {
                if i + 1 < n {
                    let next = backend.dense_fwd(&task.shapes[i], &task.params[i], &h, task.rows);
                    inputs.push(std::mem::replace(&mut h, next));
                } else {
                    inputs.push(std::mem::take(&mut h));
                }
            }
            let mut grads: Vec<Option<GradBuf>> = (0..n).map(|_| None).collect();
            let mut g = gout;
            for i in (0..n).rev() {
                let out =
                    backend.dense_bwd(&task.shapes[i], &task.params[i], &inputs[i], &g, task.rows);
                g = out.gx;
                grads[i] = Some(out.grads);
            }
            StageOutput {
                out: g,
                grads: Some(grads.into_iter().map(Option::unwrap).collect()),
            }
        }
    }
}

/// Where stage tasks run. Per device, `finish` returns results in
/// `start` order (the scheduler's per-device `Done` events are strictly
/// time-ordered, so FIFO pairing is exact).
pub trait Executor {
    fn start(&mut self, dev: (usize, usize), task: StageTask);
    fn finish(&mut self, dev: (usize, usize)) -> StageOutput;
    /// Number of compute threads backing this executor (1 = inline).
    fn threads(&self) -> usize;
}

/// Inline executor: computes at dispatch on the calling thread and parks
/// the result until the scheduler's `Done` event collects it — exactly the
/// historical single-threaded simulation behavior.
pub struct SimExecutor<'a> {
    backend: &'a dyn Backend,
    /// per-device FIFO of parked results (mirrors the threaded executor's
    /// channel semantics, so exact-tick double dispatch pairs correctly)
    pending: HashMap<(usize, usize), VecDeque<StageOutput>>,
}

impl<'a> SimExecutor<'a> {
    pub fn new(backend: &'a dyn Backend) -> Self {
        SimExecutor { backend, pending: HashMap::new() }
    }
}

impl Executor for SimExecutor<'_> {
    fn start(&mut self, dev: (usize, usize), task: StageTask) {
        let out = run_stage(self.backend, task);
        self.pending.entry(dev).or_default().push_back(out);
    }

    fn finish(&mut self, dev: (usize, usize)) -> StageOutput {
        self.pending
            .get_mut(&dev)
            .and_then(VecDeque::pop_front)
            .expect("no in-flight task on device")
    }

    fn threads(&self) -> usize {
        1
    }
}

struct DeviceLink {
    tx: Sender<StageTask>,
    rx: Receiver<StageOutput>,
}

/// One OS thread per (worker, stage) device, exchanging activations and
/// gradients over channels. Spawned inside a [`std::thread::scope`] so the
/// backend can be borrowed (it must be `Sync` — enforced by the `Backend`
/// supertrait). Dropping the executor closes the task channels and the
/// device threads exit; the scope joins them.
pub struct ThreadedExecutor {
    links: HashMap<(usize, usize), DeviceLink>,
}

impl ThreadedExecutor {
    pub fn spawn<'scope, 'env>(
        scope: &'scope Scope<'scope, 'env>,
        backend: &'env dyn Backend,
        devices: &[(usize, usize)],
    ) -> Self {
        let mut links = HashMap::new();
        for &dev in devices {
            let (task_tx, task_rx) = channel::<StageTask>();
            let (out_tx, out_rx) = channel::<StageOutput>();
            scope.spawn(move || {
                while let Ok(task) = task_rx.recv() {
                    if out_tx.send(run_stage(backend, task)).is_err() {
                        break;
                    }
                }
            });
            links.insert(dev, DeviceLink { tx: task_tx, rx: out_rx });
        }
        ThreadedExecutor { links }
    }
}

impl Executor for ThreadedExecutor {
    fn start(&mut self, dev: (usize, usize), task: StageTask) {
        self.links[&dev].tx.send(task).expect("device thread alive");
    }

    fn finish(&mut self, dev: (usize, usize)) -> StageOutput {
        self.links[&dev].rx.recv().expect("device thread alive")
    }

    fn threads(&self) -> usize {
        self.links.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::Act;
    use crate::model::LayerParams;
    use std::sync::Arc;

    fn task(bwd: bool) -> StageTask {
        let shapes = vec![
            LayerShape { in_dim: 2, out_dim: 3, act: Act::Relu },
            LayerShape { in_dim: 3, out_dim: 2, act: Act::None },
        ];
        let params = vec![
            Arc::new(LayerParams { w: vec![0.5; 6], b: vec![0.1; 3] }),
            Arc::new(LayerParams { w: vec![-0.25; 6], b: vec![0.0; 2] }),
        ];
        StageTask {
            shapes,
            params,
            x: vec![1.0, -2.0, 0.5, 0.25],
            rows: 2,
            gout: bwd.then(|| vec![0.3, -0.1, 0.2, 0.4]),
        }
    }

    #[test]
    fn sim_and_threaded_produce_identical_stage_results() {
        let be = NativeBackend;
        for bwd in [false, true] {
            let mut sim = SimExecutor::new(&be);
            sim.start((0, 0), task(bwd));
            let a = sim.finish((0, 0));
            let b = std::thread::scope(|s| {
                let mut th = ThreadedExecutor::spawn(s, &be, &[(0, 0)]);
                th.start((0, 0), task(bwd));
                th.finish((0, 0))
            });
            assert_eq!(a.out, b.out, "bwd={bwd}");
            match (a.grads, b.grads) {
                (None, None) => assert!(!bwd),
                (Some(ga), Some(gb)) => {
                    assert!(bwd);
                    assert_eq!(ga.len(), gb.len());
                    for (x, y) in ga.iter().zip(&gb) {
                        assert_eq!(x.gw, y.gw);
                        assert_eq!(x.gb, y.gb);
                    }
                }
                _ => panic!("executor grads disagree"),
            }
        }
    }

    /// At an exact-tick boundary the scheduler can dispatch a device's
    /// next task while the previous Done is still queued — results must
    /// pair FIFO on both executors.
    #[test]
    fn double_dispatch_on_one_device_pairs_fifo() {
        let be = NativeBackend;
        let fwd = run_stage(&be, task(false));
        let bwd = run_stage(&be, task(true));
        let mut sim = SimExecutor::new(&be);
        sim.start((0, 0), task(true)); // earlier bwd, Done still queued
        sim.start((0, 0), task(false)); // next fwd dispatched at same tick
        let first = sim.finish((0, 0));
        let second = sim.finish((0, 0));
        assert_eq!(first.out, bwd.out, "first finish gets the earlier task");
        assert!(first.grads.is_some());
        assert_eq!(second.out, fwd.out);
        assert!(second.grads.is_none());
        let (tf, ts) = std::thread::scope(|s| {
            let mut th = ThreadedExecutor::spawn(s, &be, &[(0, 0)]);
            th.start((0, 0), task(true));
            th.start((0, 0), task(false));
            (th.finish((0, 0)), th.finish((0, 0)))
        });
        assert_eq!(tf.out, bwd.out);
        assert_eq!(ts.out, fwd.out);
    }

    #[test]
    fn threaded_executor_overlaps_devices() {
        let be = NativeBackend;
        let devices = [(0, 0), (0, 1), (1, 0), (1, 1)];
        let outs = std::thread::scope(|s| {
            let mut th = ThreadedExecutor::spawn(s, &be, &devices);
            assert_eq!(th.threads(), 4);
            // all four devices in flight simultaneously before any join
            for &d in &devices {
                th.start(d, task(false));
            }
            devices.map(|d| th.finish(d))
        });
        let reference = run_stage(&be, task(false));
        for o in outs {
            assert_eq!(o.out, reference.out);
        }
    }
}
