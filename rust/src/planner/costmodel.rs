//! Closed-form adaptation rate `R_F` (Eq. 3) and memory footprint `M_F`
//! (Eq. 4) of a fine-grained pipeline configuration.

use super::profile::{Partition, Profile};
use crate::util::lcm_all;

/// Data-value decay constant `c` of Def. 4.1 (per virtual tick) for
/// small-tick unit tests. Real runs derive `c` from the arrival interval
/// via [`decay_for_td`] — the paper tunes `c` per dataset; tying it to
/// `t^d` keeps the exponent scale-invariant across model sizes.
pub const DEFAULT_DECAY: f64 = 2e-4;

/// Scale-invariant decay: data loses ~5% of its value per arrival
/// interval, i.e. `c = 0.05 / t^d`.
pub fn decay_for_td(td: u64) -> f64 {
    0.05 / td.max(1) as f64
}

/// Per-worker knobs (`c_n^d`, `c_n^r`, `c_{n,j}^a`, `c_{n,j}^o`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerCfg {
    /// processing delay / interleave slot; -1 = removed (T4)
    pub delay: i64,
    /// activation recomputation (T1)
    pub recompute: bool,
    /// gradient accumulation steps per stage (T2), >= 1
    pub accum: Vec<u64>,
    /// back-propagation omission steps per stage (T3), >= 0
    pub omit: Vec<u64>,
}

impl WorkerCfg {
    pub fn fresh(slot: i64, stages: usize, recompute: bool) -> Self {
        WorkerCfg {
            delay: slot,
            recompute,
            accum: vec![1; stages],
            omit: vec![0; stages],
        }
    }

    pub fn active(&self) -> bool {
        self.delay >= 0
    }
}

/// A full pipeline configuration `C`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipeConfig {
    pub workers: Vec<WorkerCfg>,
}

impl PipeConfig {
    /// The paper's initial configuration: `N = ceil((t^f+t^b+c^r t^f)/t^d)`
    /// workers, `c_n^d = n`, accumulation 1, no omission.
    pub fn initial(stages: usize, tf: u64, tb: u64, recompute: bool, td: u64) -> Self {
        let extra = if recompute { tf } else { 0 };
        let n = crate::util::cdiv(tf + tb + extra, td.max(1)).max(1);
        PipeConfig {
            workers: (0..n)
                .map(|i| WorkerCfg::fresh(i as i64, stages, recompute))
                .collect(),
        }
    }

    pub fn active_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.active()).count()
    }

    pub fn num_stages(&self) -> usize {
        self.workers.first().map(|w| w.accum.len()).unwrap_or(0)
    }
}

/// Eq. 3 term `A_{i,j}` (see paper §5.1.1).
#[allow(clippy::too_many_arguments)]
fn a_term(
    i: usize,
    j: u64,
    p: usize,
    tf: f64,
    tb: f64,
    cr: f64,
    lcm_tail: f64,
    decay: f64,
    v_d: f64,
) -> f64 {
    let (i, j, p) = (i as f64, j as f64, p as f64);
    let exponent = -decay * ((p + j) * tf + (p - i + j) * tb + cr * (p - i + j) * tf);
    exponent.exp() * v_d / (lcm_tail * (tf + tb + cr * tf))
}

/// Adaptation rate `R_F^T` (Eq. 3) with `V_D = 1`. Time unit: ticks.
pub fn adaptation_rate(
    part: &Partition,
    prof: &Profile,
    cfg: &PipeConfig,
    decay: f64,
) -> f64 {
    let p = part.num_stages();
    let tf = part.tf(prof) as f64;
    let tb = part.tb(prof) as f64;
    let total_w: f64 = (0..p).map(|i| part.stage_params(prof, i) as f64).sum();
    let mut r = 0.0;
    for w in cfg.workers.iter().filter(|w| w.active()) {
        let cr = if w.recompute { 1.0 } else { 0.0 };
        for i in 0..p {
            let frac = part.stage_params(prof, i) as f64 / total_w;
            let ca = w.accum[i].max(1);
            let lcm_tail = lcm_all((i..p).map(|k| w.omit[k] + 1)) as f64;
            let mut inner = 0.0;
            for j in 0..ca {
                inner += a_term(i, j, p, tf, tb, cr, lcm_tail, decay, 1.0);
            }
            r += frac * inner / ca as f64;
        }
    }
    r
}

/// Memory footprint `M_F` (Eq. 4) in **bytes** (f32 counts x 4).
pub fn mem_footprint(part: &Partition, prof: &Profile, cfg: &PipeConfig) -> f64 {
    let p = part.num_stages();
    let mut total = 0.0f64;
    for w in cfg.workers.iter().filter(|w| w.active()) {
        for i in 0..p {
            let ca = w.accum[i].max(1);
            let versions = (1 + crate::util::cdiv((p - i - 1) as u64, ca))
                .saturating_sub(w.omit[i])
                .max(1) as f64;
            let acts = part.stage_acts(prof, i) as f64;
            let internal = if w.recompute {
                part.stage_internal_acts(prof, i) as f64
            } else {
                0.0
            };
            let per_version = part.stage_params(prof, i) as f64 + acts - internal;
            total += versions * per_version;
        }
    }
    total * 4.0
}

/// Max stashed weight versions any stage needs under `cfg` — the version
/// term of Eq. 4 (`1 + ceil((P-i-1)/c_a) - c_o`, floored at 1). Seeds the
/// engine's per-layer stash capacity in dynamic-budget runs so measured
/// stash bytes track the planned footprint instead of a fixed headroom.
pub fn plan_versions(cfg: &PipeConfig, p: usize) -> usize {
    let mut vers = 1u64;
    for w in cfg.workers.iter().filter(|w| w.active()) {
        for i in 0..p {
            let ca = w.accum[i].max(1);
            let v = (1 + crate::util::cdiv((p - i - 1) as u64, ca))
                .saturating_sub(w.omit[i])
                .max(1);
            vers = vers.max(v);
        }
    }
    vers as usize
}

/// Memory of a plain single-copy trainer (one model + one set of
/// activations + one gradient buffer) — the `M_B` reference used for the
/// 1-Skip/Oracle baselines in the agm tables.
pub fn single_copy_bytes(prof: &Profile) -> f64 {
    let params: usize = prof.w.iter().sum();
    let acts: usize = prof.a.iter().sum();
    ((2 * params + acts) * 4) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Partition, Profile) {
        let prof = Profile {
            t_f: vec![10, 10, 10, 10],
            t_b: vec![20, 20, 20, 20],
            w: vec![1000, 1000, 1000, 1000],
            a: vec![160, 160, 160, 160],
        };
        (Partition::per_layer(4), prof)
    }

    #[test]
    fn initial_config_worker_count() {
        let (part, prof) = setup();
        let (tf, tb) = (part.tf(&prof), part.tb(&prof));
        // td = tf: N = ceil((10+20)/10) = 3 without recompute, 4 with
        let c0 = PipeConfig::initial(4, tf, tb, false, 10);
        assert_eq!(c0.active_workers(), 3);
        let c1 = PipeConfig::initial(4, tf, tb, true, 10);
        assert_eq!(c1.active_workers(), 4);
    }

    #[test]
    fn more_workers_more_rate_and_memory() {
        let (part, prof) = setup();
        let mut c1 = PipeConfig::initial(4, 10, 20, false, 10);
        c1.workers.truncate(1);
        let c3 = PipeConfig::initial(4, 10, 20, false, 10);
        let r1 = adaptation_rate(&part, &prof, &c1, DEFAULT_DECAY);
        let r3 = adaptation_rate(&part, &prof, &c3, DEFAULT_DECAY);
        assert!(r3 > r1 * 2.5, "r1={r1} r3={r3}");
        let m1 = mem_footprint(&part, &prof, &c1);
        let m3 = mem_footprint(&part, &prof, &c3);
        assert!((m3 - 3.0 * m1).abs() < 1e-6);
    }

    #[test]
    fn recompute_trades_rate_for_memory() {
        let (part, prof) = setup();
        let base = PipeConfig {
            workers: vec![WorkerCfg::fresh(0, 4, false)],
        };
        let rec = PipeConfig {
            workers: vec![WorkerCfg::fresh(0, 4, true)],
        };
        assert!(
            adaptation_rate(&part, &prof, &rec, DEFAULT_DECAY)
                < adaptation_rate(&part, &prof, &base, DEFAULT_DECAY)
        );
        // per-layer stages have no internal activations, so recompute only
        // helps multi-layer stages:
        assert_eq!(
            mem_footprint(&part, &prof, &rec),
            mem_footprint(&part, &prof, &base)
        );
        let two_stage = Partition { bounds: vec![0, 2, 4] };
        assert!(
            mem_footprint(&two_stage, &prof, &rec) < mem_footprint(&two_stage, &prof, &base)
        );
    }

    #[test]
    fn accumulation_reduces_memory_and_rate() {
        let (part, prof) = setup();
        let mut cfg = PipeConfig {
            workers: vec![WorkerCfg::fresh(0, 4, false)],
        };
        let r0 = adaptation_rate(&part, &prof, &cfg, DEFAULT_DECAY);
        let m0 = mem_footprint(&part, &prof, &cfg);
        cfg.workers[0].accum[0] = 3; // stage 0 stores fewer versions
        let r1 = adaptation_rate(&part, &prof, &cfg, DEFAULT_DECAY);
        let m1 = mem_footprint(&part, &prof, &cfg);
        assert!(m1 < m0, "m0={m0} m1={m1}");
        assert!(r1 < r0, "r0={r0} r1={r1}");
    }

    #[test]
    fn omission_reduces_memory_and_rate() {
        let (part, prof) = setup();
        let mut cfg = PipeConfig {
            workers: vec![WorkerCfg::fresh(0, 4, false)],
        };
        let r0 = adaptation_rate(&part, &prof, &cfg, DEFAULT_DECAY);
        let m0 = mem_footprint(&part, &prof, &cfg);
        cfg.workers[0].omit[0] = 3; // stage 0: full omission (P-1-0)
        cfg.workers[0].accum[0] = 1;
        let r1 = adaptation_rate(&part, &prof, &cfg, DEFAULT_DECAY);
        let m1 = mem_footprint(&part, &prof, &cfg);
        assert!(m1 < m0);
        assert!(r1 < r0);
        // stage 0 now stores exactly one version
        // removed worker contributes nothing
        cfg.workers[0].delay = -1;
        assert_eq!(mem_footprint(&part, &prof, &cfg), 0.0);
        assert_eq!(adaptation_rate(&part, &prof, &cfg, DEFAULT_DECAY), 0.0);
    }

    #[test]
    fn later_stages_store_fewer_versions() {
        // Eq. 4: stage i stores 1 + ceil((P-i-1)/c_a) versions; the last
        // stage stores exactly 1.
        let (_, prof) = setup();
        let part = Partition::per_layer(4);
        let one_stage_only = |stage: usize| -> f64 {
            let cfg = PipeConfig {
                workers: vec![WorkerCfg::fresh(0, 4, false)],
            };
            // isolate stage contribution by zeroing others via subtraction
            let full = mem_footprint(&part, &prof, &cfg);
            let mut cfg2 = cfg.clone();
            cfg2.workers[0].omit[stage] = (4 - 1 - stage) as u64;
            full - mem_footprint(&part, &prof, &cfg2)
        };
        // earlier stages hold more versions -> bigger reduction from
        // fully omitting them
        assert!(one_stage_only(0) > one_stage_only(2));
    }

    #[test]
    fn plan_versions_follows_accum_and_omission() {
        let p = 4;
        let cfg = PipeConfig {
            workers: vec![WorkerCfg::fresh(0, p, false)],
        };
        // accum 1, no omission: stage 0 stores 1 + (P-1) = 4 versions
        assert_eq!(plan_versions(&cfg, p), 4);
        let mut acc = cfg.clone();
        for j in 0..p {
            acc.workers[0].accum[j] = 3;
        }
        // 1 + ceil(3/3) = 2 at stage 0
        assert_eq!(plan_versions(&acc, p), 2);
        let mut omitted = cfg.clone();
        omitted.workers[0].omit[0] = 3;
        // stage 0 fully omitted -> floor 1; stage 1 dominates with 3
        assert_eq!(plan_versions(&omitted, p), 3);
        let mut none = cfg;
        none.workers[0].delay = -1;
        assert_eq!(plan_versions(&none, p), 1, "no active workers");
    }

    #[test]
    fn single_copy_reference() {
        let (_, prof) = setup();
        assert_eq!(single_copy_bytes(&prof), ((2 * 4000 + 640) * 4) as f64);
    }
}
